//! CLI: `cargo run -p repro-lint [--release] [REPO_ROOT]`.
//!
//! Exits 0 when the tree is clean, 1 on any diagnostic (CI blocks on
//! this), 2 when the root does not look like the repo.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let root = std::env::args().nth(1).map(PathBuf::from).unwrap_or_else(|| PathBuf::from("."));
    if !root.join("rust/src").is_dir() {
        eprintln!(
            "repro-lint: `{}` has no rust/src — run from the repo root or pass it as arg 1",
            root.display()
        );
        return ExitCode::from(2);
    }
    let (report, files) = repro_lint::lint_repo(&root);
    for d in &report.diags {
        println!("{d}");
    }
    for (path, line, rule) in &report.unused_waivers {
        eprintln!("warning: {path}:{line}: unused waiver for `{rule}` — remove it");
    }
    eprintln!(
        "repro-lint: {} file(s), {} diagnostic(s), {} waiver(s) honored, {} unused",
        files,
        report.diags.len(),
        report.waivers_used,
        report.unused_waivers.len()
    );
    if report.diags.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
