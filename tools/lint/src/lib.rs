//! repro-lint — mechanical enforcement of the mtfl-dpc safety contracts.
//!
//! The repo's correctness story rests on invariants that used to live only
//! in DESIGN.md prose and runtime spot-checks. This crate turns each of
//! them into a blocking diagnostic (DESIGN.md §13 maps every rule to the
//! design section it enforces and the CI job that runs it):
//!
//! | rule id            | invariant                                                    |
//! |--------------------|--------------------------------------------------------------|
//! | `no-fma`           | no fused multiply-add anywhere (`mul_add`, `_mm256_fmadd_*`, |
//! |                    | `vfmaq_*`, …) — DESIGN.md §12 accumulation contract          |
//! | `kernel-reduction` | float reductions route through `linalg/simd.rs` — no         |
//! |                    | `.sum::<f32/f64>()` or `acc += a*b` fold loops in library    |
//! |                    | code outside the kernel layer                                |
//! | `no-spawn`         | `std::thread::{spawn, scope, Builder}` only inside           |
//! |                    | `util/executor.rs` — DESIGN.md §11 zero-spawn invariant      |
//! | `confined-unsafe`  | `unsafe` only in `linalg/simd.rs` + `util/executor.rs`, and  |
//! |                    | every occurrence carries a `// SAFETY:` (or `# Safety` doc)  |
//! |                    | justification on or directly above its line                  |
//! | `nondeterminism`   | no `Instant`/`SystemTime`/entropy-seeded RNG outside         |
//! |                    | `util/{timer,rng}.rs` and the bench harness                  |
//!
//! ## Scoping
//!
//! `no-fma`, `no-spawn`, and `confined-unsafe` apply to every scanned file
//! (`rust/src`, `rust/tests`, `rust/benches`, `examples`). The two
//! determinism-of-results rules are scoped to library code, where the
//! pinned bit-streams are produced:
//!
//! * `kernel-reduction` applies to `rust/src` only (tests/benches/examples
//!   legitimately hold naive reference reductions to compare the kernels
//!   against) and skips `#[cfg(test)]` items for the same reason.
//! * `nondeterminism` skips `rust/benches` (a timing harness measures
//!   wallclock by definition) and `#[cfg(test)]` items.
//!
//! ## Detection strategy
//!
//! Most rules run on the raw token stream of the whole file, so they see
//! into `macro_rules!` bodies that `syn` item visitors skip; the
//! `kernel-reduction` fold rule needs expression structure (`+=` with a
//! float-shaped right-hand side) and runs on the parsed AST. The fold
//! heuristic flags `acc += rhs` where `rhs` contains a float literal, a
//! product of two non-integer-literal operands, or a `powi`/`powf` call —
//! integer work counters (`col_ops += 2 * d`) and plain re-accumulation of
//! kernel partials (`total += sumsq_serial_f64(rt)`) pass. `// SAFETY:`
//! detection reads the raw source lines, since comments never reach the
//! token stream.
//!
//! ## Waivers
//!
//! A deliberate exception is recorded in place, with its reason:
//!
//! ```text
//! // repro-lint: allow(kernel-reduction): T-length secular fold, serial order pinned
//! ```
//!
//! A waiver suppresses its rule on its own line and the line directly
//! below. `allow-file(rule)` (anywhere in the file) waives the whole
//! file. Waivers without a reason, or naming an unknown rule, are
//! themselves diagnostics; unused waivers are reported as warnings so
//! stale exceptions cannot accumulate silently.

use proc_macro2::{TokenStream, TokenTree};
use std::fmt;
use std::path::{Path, PathBuf};
use syn::spanned::Spanned;
use syn::visit::Visit;

/// Every rule id this lint can emit (fixture tests assert against these).
pub const RULES: [&str; 5] =
    ["no-fma", "kernel-reduction", "no-spawn", "confined-unsafe", "nondeterminism"];

/// One finding, pointing at the offending token.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// rule id (one of [`RULES`], or `parse-error` / `bad-waiver`)
    pub rule: String,
    /// repo-relative path, `/`-separated
    pub path: String,
    /// 1-based line of the offending token
    pub line: usize,
    /// 1-based column of the offending token
    pub col: usize,
    /// what fired and what to do instead
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}:{} [{}] {}", self.path, self.line, self.col, self.rule, self.msg)
    }
}

/// Outcome of linting one file or a whole tree.
#[derive(Debug, Default)]
pub struct Report {
    /// blocking findings (empty = pass)
    pub diags: Vec<Diagnostic>,
    /// waivers that suppressed at least one finding
    pub waivers_used: usize,
    /// waivers that suppressed nothing: (path, line, rule)
    pub unused_waivers: Vec<(String, usize, String)>,
}

// ---------------------------------------------------------------------------
// Rule scoping: which files each rule applies to
// ---------------------------------------------------------------------------

fn in_dir(rel: &str, dir: &str) -> bool {
    rel.starts_with(dir) && rel.as_bytes().get(dir.len()) == Some(&b'/')
}

const KERNEL_HOME: &str = "rust/src/linalg/simd.rs";
const UNSAFE_ALLOWED: [&str; 2] = [KERNEL_HOME, "rust/src/util/executor.rs"];
const SPAWN_ALLOWED: [&str; 2] = ["rust/src/util/executor.rs", "rust/src/util/loom_model.rs"];
const TIME_ALLOWED: [&str; 3] =
    ["rust/src/util/timer.rs", "rust/src/util/rng.rs", "rust/src/bench.rs"];

fn reduction_in_scope(rel: &str) -> bool {
    in_dir(rel, "rust/src") && rel != KERNEL_HOME
}

fn nondet_in_scope(rel: &str) -> bool {
    !in_dir(rel, "rust/benches") && !TIME_ALLOWED.contains(&rel)
}

// ---------------------------------------------------------------------------
// Waivers
// ---------------------------------------------------------------------------

struct Waiver {
    line: usize,
    rule: String,
    file_level: bool,
    used: bool,
}

fn parse_waivers(rel: &str, lines: &[&str]) -> (Vec<Waiver>, Vec<Diagnostic>) {
    let mut waivers = Vec::new();
    let mut diags = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let line = i + 1;
        let Some(pos) = raw.find("repro-lint:") else { continue };
        let mut bad = |msg: &str| {
            diags.push(Diagnostic {
                rule: "bad-waiver".into(),
                path: rel.to_string(),
                line,
                col: pos + 1,
                msg: msg.to_string(),
            });
        };
        let rest = raw[pos + "repro-lint:".len()..].trim_start();
        let (file_level, rest) = if let Some(r) = rest.strip_prefix("allow-file(") {
            (true, r)
        } else if let Some(r) = rest.strip_prefix("allow(") {
            (false, r)
        } else {
            bad("expected `repro-lint: allow(<rule>): <reason>` or `allow-file(...)`");
            continue;
        };
        let Some(close) = rest.find(')') else {
            bad("unclosed `allow(` in waiver");
            continue;
        };
        let rule = rest[..close].trim();
        if !RULES.contains(&rule) {
            bad(&format!("waiver names unknown rule `{rule}`"));
            continue;
        }
        let reason = rest[close + 1..].trim_start_matches(':').trim();
        if reason.is_empty() {
            bad("waiver must state a reason: `allow(<rule>): <reason>`");
            continue;
        }
        waivers.push(Waiver { line, rule: rule.to_string(), file_level, used: false });
    }
    (waivers, diags)
}

fn waived(waivers: &mut [Waiver], rule: &str, line: usize) -> bool {
    for w in waivers.iter_mut() {
        if w.rule == rule && (w.file_level || w.line == line || w.line + 1 == line) {
            w.used = true;
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// Raw-source helpers: SAFETY comments
// ---------------------------------------------------------------------------

/// `unsafe` on `line` is justified when that line, or the contiguous run
/// of comment/attribute lines directly above it, contains `SAFETY:` (block
/// comments) or `# Safety` (rustdoc sections on `unsafe fn`).
fn has_safety_comment(lines: &[&str], line: usize) -> bool {
    let ok = |s: &str| s.contains("SAFETY:") || s.contains("# Safety");
    if line == 0 || line > lines.len() {
        return false;
    }
    if ok(lines[line - 1]) {
        return true;
    }
    let mut idx = line - 1; // 0-based index of the `unsafe` line itself
    while idx > 0 {
        idx -= 1;
        let t = lines[idx].trim_start();
        if t.starts_with("//") || t.starts_with("#[") || t.starts_with("#![") {
            if ok(t) {
                return true;
            }
        } else {
            break;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// Token-stream scan (sees macro bodies too)
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct Hit {
    rule: &'static str,
    line: usize,
    col: usize,
    msg: String,
}

fn is_fma_ident(s: &str) -> bool {
    s == "mul_add"
        || s.contains("fmadd")
        || s.contains("fmsub")
        || s.contains("fnmadd")
        || s.contains("fnmsub")
        || s.starts_with("vfma")
        || s.starts_with("vfms")
}

/// Nearest ident strictly before `i`, skipping `::` punctuation — so
/// `std::thread::spawn` resolves `spawn`'s qualifier to `thread`.
fn prev_path_ident(toks: &[TokenTree], i: usize) -> Option<String> {
    let mut j = i;
    while j > 0 {
        j -= 1;
        match &toks[j] {
            TokenTree::Punct(p) if p.as_char() == ':' => continue,
            TokenTree::Ident(id) => return Some(id.to_string()),
            _ => return None,
        }
    }
    None
}

/// Does `sum` at index `i` carry a `::<f32>` / `::<f64>` turbofish?
fn float_turbofish(toks: &[TokenTree], i: usize) -> bool {
    let punct = |k: usize, c: char| {
        matches!(toks.get(k), Some(TokenTree::Punct(p)) if p.as_char() == c)
    };
    punct(i + 1, ':')
        && punct(i + 2, ':')
        && punct(i + 3, '<')
        && matches!(toks.get(i + 4), Some(TokenTree::Ident(id))
            if id == "f32" || id == "f64")
}

fn scan_tokens(ts: TokenStream, hits: &mut Vec<Hit>) {
    let toks: Vec<TokenTree> = ts.into_iter().collect();
    for (i, t) in toks.iter().enumerate() {
        match t {
            TokenTree::Group(g) => scan_tokens(g.stream(), hits),
            TokenTree::Ident(id) => {
                let s = id.to_string();
                let start = id.span().start();
                let (line, col) = (start.line, start.column + 1);
                if is_fma_ident(&s) {
                    hits.push(Hit {
                        rule: "no-fma",
                        line,
                        col,
                        msg: format!(
                            "`{s}` fuses the multiply — the §12 accumulation contract \
                             requires the product rounded before the add"
                        ),
                    });
                }
                if s == "unsafe" {
                    hits.push(Hit {
                        rule: "confined-unsafe",
                        line,
                        col,
                        msg: String::new(), // finalized in the filter stage
                    });
                }
                if matches!(
                    s.as_str(),
                    "Instant" | "SystemTime" | "thread_rng" | "from_entropy" | "OsRng"
                        | "getrandom"
                ) {
                    hits.push(Hit {
                        rule: "nondeterminism",
                        line,
                        col,
                        msg: format!(
                            "`{s}` is ambient nondeterminism — route wallclock through \
                             util::Stopwatch and randomness through util::Pcg64"
                        ),
                    });
                }
                if matches!(s.as_str(), "spawn" | "scope" | "Builder")
                    && prev_path_ident(&toks, i).as_deref() == Some("thread")
                {
                    hits.push(Hit {
                        rule: "no-spawn",
                        line,
                        col,
                        msg: format!(
                            "`thread::{s}` outside util/executor.rs breaks the §11 \
                             zero-spawn invariant — use the persistent executor"
                        ),
                    });
                }
                if s == "sum" && float_turbofish(&toks, i) {
                    hits.push(Hit {
                        rule: "kernel-reduction",
                        line,
                        col,
                        msg: "`.sum::<float>()` outside the kernel layer — use the \
                              linalg::simd serial helpers or the blocked kernels"
                            .into(),
                    });
                }
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------------
// AST passes: cfg(test) ranges + the `+=` fold rule
// ---------------------------------------------------------------------------

fn has_cfg_test(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| {
        if a.path().is_ident("test") {
            return true;
        }
        if !a.path().is_ident("cfg") {
            return false;
        }
        let mut found = false;
        let _ = a.parse_nested_meta(|m| {
            if m.path.is_ident("test") {
                found = true;
            }
            Ok(())
        });
        found
    })
}

struct TestRanges<'a> {
    ranges: &'a mut Vec<(usize, usize)>,
}

impl<'ast> Visit<'ast> for TestRanges<'_> {
    fn visit_item(&mut self, node: &'ast syn::Item) {
        let attrs = match node {
            syn::Item::Mod(i) => Some(&i.attrs),
            syn::Item::Fn(i) => Some(&i.attrs),
            syn::Item::Impl(i) => Some(&i.attrs),
            syn::Item::Struct(i) => Some(&i.attrs),
            syn::Item::Enum(i) => Some(&i.attrs),
            syn::Item::Const(i) => Some(&i.attrs),
            syn::Item::Static(i) => Some(&i.attrs),
            syn::Item::Trait(i) => Some(&i.attrs),
            syn::Item::Type(i) => Some(&i.attrs),
            syn::Item::Use(i) => Some(&i.attrs),
            _ => None,
        };
        if let Some(attrs) = attrs {
            if has_cfg_test(attrs) {
                let sp = node.span();
                self.ranges.push((sp.start().line, sp.end().line));
                return; // the whole item is test-gated; no need to descend
            }
        }
        syn::visit::visit_item(self, node);
    }
}

fn is_int_lit(e: &syn::Expr) -> bool {
    match e {
        syn::Expr::Lit(l) => matches!(l.lit, syn::Lit::Int(_)),
        syn::Expr::Unary(u) => is_int_lit(&u.expr),
        syn::Expr::Paren(p) => is_int_lit(&p.expr),
        syn::Expr::Cast(c) => is_int_lit(&c.expr),
        _ => false,
    }
}

/// Float-shaped right-hand side of an `acc += rhs`: a float literal, a
/// product of two non-integer-literal operands, or a `powi`/`powf` call.
fn rhs_is_float_fold(e: &syn::Expr) -> bool {
    match e {
        syn::Expr::Lit(l) => matches!(l.lit, syn::Lit::Float(_)),
        syn::Expr::Binary(b) => {
            if matches!(b.op, syn::BinOp::Mul(_))
                && !is_int_lit(&b.left)
                && !is_int_lit(&b.right)
            {
                return true;
            }
            rhs_is_float_fold(&b.left) || rhs_is_float_fold(&b.right)
        }
        syn::Expr::MethodCall(m) => {
            let id = m.method.to_string();
            id == "powi"
                || id == "powf"
                || rhs_is_float_fold(&m.receiver)
                || m.args.iter().any(rhs_is_float_fold)
        }
        syn::Expr::Call(c) => c.args.iter().any(rhs_is_float_fold),
        syn::Expr::Paren(p) => rhs_is_float_fold(&p.expr),
        syn::Expr::Cast(c) => rhs_is_float_fold(&c.expr),
        syn::Expr::Unary(u) => rhs_is_float_fold(&u.expr),
        syn::Expr::Reference(r) => rhs_is_float_fold(&r.expr),
        syn::Expr::Index(ix) => rhs_is_float_fold(&ix.expr) || rhs_is_float_fold(&ix.index),
        _ => false,
    }
}

struct FoldVisitor<'a> {
    hits: &'a mut Vec<Hit>,
}

impl<'ast> Visit<'ast> for FoldVisitor<'_> {
    fn visit_expr_binary(&mut self, node: &'ast syn::ExprBinary) {
        if matches!(node.op, syn::BinOp::AddAssign(_)) && rhs_is_float_fold(&node.right) {
            let start = node.span().start();
            self.hits.push(Hit {
                rule: "kernel-reduction",
                line: start.line,
                col: start.column + 1,
                msg: "float accumulation fold outside the kernel layer — use the \
                      linalg::simd serial helpers or the blocked kernels"
                    .into(),
            });
        }
        syn::visit::visit_expr_binary(self, node);
    }
}

// ---------------------------------------------------------------------------
// Per-file entry point
// ---------------------------------------------------------------------------

/// Lint one file's source. `rel` is its repo-relative path (`/`-separated;
/// rule scoping keys on it).
pub fn lint_source(rel: &str, src: &str) -> Report {
    let rel = rel.replace('\\', "/");
    let lines: Vec<&str> = src.lines().collect();
    let (mut waivers, mut diags) = parse_waivers(&rel, &lines);

    let mut test_ranges: Vec<(usize, usize)> = Vec::new();
    let mut hits: Vec<Hit> = Vec::new();

    match syn::parse_file(src) {
        Ok(ast) => {
            TestRanges { ranges: &mut test_ranges }.visit_file(&ast);
            if reduction_in_scope(&rel) {
                FoldVisitor { hits: &mut hits }.visit_file(&ast);
            }
        }
        Err(e) => {
            let start = e.span().start();
            diags.push(Diagnostic {
                rule: "parse-error".into(),
                path: rel.clone(),
                line: start.line,
                col: start.column + 1,
                msg: format!("file does not parse: {e}"),
            });
        }
    }

    match src.parse::<TokenStream>() {
        Ok(ts) => scan_tokens(ts, &mut hits),
        Err(_) => {} // already reported via syn above
    }

    let in_test =
        |line: usize| test_ranges.iter().any(|&(s, e)| line >= s && line <= e);

    for h in hits {
        let (keep, msg) = match h.rule {
            "no-fma" => (true, h.msg),
            "no-spawn" => (!SPAWN_ALLOWED.contains(&rel.as_str()), h.msg),
            "kernel-reduction" => (reduction_in_scope(&rel) && !in_test(h.line), h.msg),
            "nondeterminism" => (nondet_in_scope(&rel) && !in_test(h.line), h.msg),
            "confined-unsafe" => {
                if UNSAFE_ALLOWED.contains(&rel.as_str()) {
                    (
                        !has_safety_comment(&lines, h.line),
                        "`unsafe` in an allowlisted file without a `// SAFETY:` \
                         justification on or above its line"
                            .to_string(),
                    )
                } else {
                    (
                        true,
                        "`unsafe` outside linalg/simd.rs + util/executor.rs — the \
                         allowlist is closed (DESIGN.md §13)"
                            .to_string(),
                    )
                }
            }
            _ => (true, h.msg),
        };
        if keep && !waived(&mut waivers, h.rule, h.line) {
            diags.push(Diagnostic {
                rule: h.rule.to_string(),
                path: rel.clone(),
                line: h.line,
                col: h.col,
                msg,
            });
        }
    }

    let mut report = Report::default();
    for w in &waivers {
        if w.used {
            report.waivers_used += 1;
        } else {
            report.unused_waivers.push((rel.clone(), w.line, w.rule.clone()));
        }
    }
    diags.sort_by_key(|d| (d.line, d.col, d.rule.clone()));
    report.diags = diags;
    report
}

// ---------------------------------------------------------------------------
// Tree walker
// ---------------------------------------------------------------------------

/// The four source trees the lint covers, relative to the repo root.
pub const SCAN_ROOTS: [&str; 4] = ["rust/src", "rust/tests", "rust/benches", "examples"];

fn collect(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    for entry in rd.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect(&p, out);
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
}

/// Lint every `.rs` file under [`SCAN_ROOTS`]. Returns the merged report
/// and the number of files scanned.
pub fn lint_repo(root: &Path) -> (Report, usize) {
    let mut files = Vec::new();
    for d in SCAN_ROOTS {
        collect(&root.join(d), &mut files);
    }
    files.sort();
    let mut merged = Report::default();
    for p in &files {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = match std::fs::read_to_string(p) {
            Ok(s) => s,
            Err(e) => {
                merged.diags.push(Diagnostic {
                    rule: "parse-error".into(),
                    path: rel,
                    line: 1,
                    col: 1,
                    msg: format!("unreadable: {e}"),
                });
                continue;
            }
        };
        let r = lint_source(&rel, &src);
        merged.diags.extend(r.diags);
        merged.waivers_used += r.waivers_used;
        merged.unused_waivers.extend(r.unused_waivers);
    }
    (merged, files.len())
}
