//! Fixture-driven proof that every rule is live: each known-bad snippet
//! must fire with the exact rule id and line, the clean fixture and the
//! full repo tree must pass, and the scoping/waiver machinery must behave
//! as documented.

use repro_lint::{lint_repo, lint_source, Report};
use std::path::Path;

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

/// (line, rule) pairs of a report, sorted.
fn fired(r: &Report) -> Vec<(usize, String)> {
    let mut v: Vec<_> = r.diags.iter().map(|d| (d.line, d.rule.clone())).collect();
    v.sort();
    v
}

#[test]
fn no_fma_fires_on_method_and_intrinsic() {
    let r = lint_source("rust/src/ops.rs", &fixture("bad_fma.rs"));
    assert_eq!(
        fired(&r),
        vec![(3, "no-fma".to_string()), (6, "no-fma".to_string())],
        "{:#?}",
        r.diags
    );
}

#[test]
fn kernel_reduction_fires_on_sum_and_fold() {
    let r = lint_source("rust/src/ops.rs", &fixture("bad_reduction.rs"));
    assert_eq!(
        fired(&r),
        vec![(4, "kernel-reduction".to_string()), (10, "kernel-reduction".to_string())],
        "{:#?}",
        r.diags
    );
}

#[test]
fn kernel_reduction_is_scoped_to_library_code() {
    // the same source is a legitimate reference reduction in a test file,
    // in a bench, and inside the kernel layer itself
    for rel in ["rust/tests/foo.rs", "rust/benches/foo.rs", "rust/src/linalg/simd.rs"] {
        let r = lint_source(rel, &fixture("bad_reduction.rs"));
        assert!(r.diags.is_empty(), "{rel} should be out of scope: {:#?}", r.diags);
    }
    // ... and inside a #[cfg(test)] module of library code
    let src = format!("#[cfg(test)]\nmod tests {{\n{}\n}}\n", fixture("bad_reduction.rs"));
    let r = lint_source("rust/src/ops.rs", &src);
    assert!(r.diags.is_empty(), "cfg(test) should be exempt: {:#?}", r.diags);
}

#[test]
fn penalty_module_is_library_scope_for_every_rule() {
    // the penalty seam (rust/src/penalty/, PR 8) is library code producing
    // pinned bit-streams: the determinism rules must treat it exactly like
    // ops.rs — in scope, with no accidental allowlisting
    for name in ["mod.rs", "l21.rs", "sgl.rs", "gowl.rs", "loss.rs"] {
        let rel = format!("rust/src/penalty/{name}");
        let r = lint_source(&rel, &fixture("bad_reduction.rs"));
        assert!(
            fired(&r).iter().all(|(_, rule)| rule == "kernel-reduction")
                && r.diags.len() == 2,
            "{rel} must be kernel-reduction scope: {:#?}",
            r.diags
        );
        let r = lint_source(&rel, &fixture("bad_fma.rs"));
        assert_eq!(r.diags.len(), 2, "{rel} must be no-fma scope: {:#?}", r.diags);
        let r = lint_source(&rel, &fixture("bad_unsafe.rs"));
        assert_eq!(
            fired(&r),
            vec![(4, "confined-unsafe".to_string())],
            "{rel} must not join the unsafe allowlist: {:#?}",
            r.diags
        );
    }
}

#[test]
fn serve_module_is_library_scope_for_every_rule() {
    // the serving layer (rust/src/serve/, PR 9) is long-lived daemon code
    // whose predict path feeds the bit-parity contract: all five rules
    // must treat it exactly like ops.rs. The nondeterminism check is the
    // load-bearing one — a daemon is where ad-hoc `Instant` reads would
    // creep in, and every wall-clock read must route through Stopwatch.
    let files = ["mod.rs", "json.rs", "proto.rs", "cache.rs", "stats.rs", "server.rs", "load.rs"];
    for rel in files
        .iter()
        .map(|name| format!("rust/src/serve/{name}"))
        .chain(std::iter::once("rust/src/util/shutdown.rs".to_string()))
    {
        let r = lint_source(&rel, &fixture("bad_reduction.rs"));
        assert!(
            fired(&r).iter().all(|(_, rule)| rule == "kernel-reduction") && r.diags.len() == 2,
            "{rel} must be kernel-reduction scope: {:#?}",
            r.diags
        );
        let r = lint_source(&rel, &fixture("bad_fma.rs"));
        assert_eq!(r.diags.len(), 2, "{rel} must be no-fma scope: {:#?}", r.diags);
        let r = lint_source(&rel, &fixture("bad_unsafe.rs"));
        assert_eq!(
            fired(&r),
            vec![(4, "confined-unsafe".to_string())],
            "{rel} must not join the unsafe allowlist: {:#?}",
            r.diags
        );
        let r = lint_source(&rel, &fixture("bad_spawn.rs"));
        assert_eq!(r.diags.len(), 2, "{rel} must be no-spawn scope: {:#?}", r.diags);
        let r = lint_source(&rel, &fixture("bad_nondet.rs"));
        assert!(
            fired(&r).iter().all(|(_, rule)| rule == "nondeterminism") && r.diags.len() == 3,
            "{rel} must not join the timing allowlist: {:#?}",
            r.diags
        );
    }
}

#[test]
fn distrib_and_checkpoint_modules_are_library_scope_for_every_rule() {
    // the cluster layer (PR 10) is the most tempting place to cheat on
    // the contracts: a coordinator "just timing a worker" with Instant,
    // a worker thread instead of a process, an ad-hoc float fold while
    // merging sweep parts. All five rules must treat distrib.rs and
    // checkpoint.rs exactly like ops.rs — in scope, no allowlists.
    let files = ["rust/src/coordinator/distrib.rs", "rust/src/coordinator/checkpoint.rs"];
    for rel in files {
        let r = lint_source(rel, &fixture("bad_reduction.rs"));
        assert!(
            fired(&r).iter().all(|(_, rule)| rule == "kernel-reduction") && r.diags.len() == 2,
            "{rel} must be kernel-reduction scope: {:#?}",
            r.diags
        );
        let r = lint_source(rel, &fixture("bad_fma.rs"));
        assert_eq!(r.diags.len(), 2, "{rel} must be no-fma scope: {:#?}", r.diags);
        let r = lint_source(rel, &fixture("bad_unsafe.rs"));
        assert_eq!(
            fired(&r),
            vec![(4, "confined-unsafe".to_string())],
            "{rel} must not join the unsafe allowlist: {:#?}",
            r.diags
        );
        let r = lint_source(rel, &fixture("bad_spawn.rs"));
        assert_eq!(r.diags.len(), 2, "{rel} must be no-spawn scope: {:#?}", r.diags);
        let r = lint_source(rel, &fixture("bad_nondet.rs"));
        assert!(
            fired(&r).iter().all(|(_, rule)| rule == "nondeterminism") && r.diags.len() == 3,
            "{rel} must not join the timing allowlist: {:#?}",
            r.diags
        );
    }
}

#[test]
fn no_spawn_fires_on_spawn_and_scope() {
    let r = lint_source("rust/src/coordinator/cv.rs", &fixture("bad_spawn.rs"));
    assert_eq!(
        fired(&r),
        vec![(4, "no-spawn".to_string()), (5, "no-spawn".to_string())],
        "{:#?}",
        r.diags
    );
    // ... but the executor itself is the allowlisted home
    let r = lint_source("rust/src/util/executor.rs", &fixture("bad_spawn.rs"));
    assert!(r.diags.is_empty(), "{:#?}", r.diags);
}

#[test]
fn confined_unsafe_fires_outside_the_allowlist() {
    let r = lint_source("rust/src/data/io.rs", &fixture("bad_unsafe.rs"));
    assert_eq!(fired(&r), vec![(4, "confined-unsafe".to_string())], "{:#?}", r.diags);
}

#[test]
fn allowlisted_unsafe_requires_a_safety_comment() {
    // same snippet inside the kernel layer: still fires, because the
    // block carries no justification ...
    let r = lint_source("rust/src/linalg/simd.rs", &fixture("bad_unsafe.rs"));
    assert_eq!(fired(&r), vec![(4, "confined-unsafe".to_string())], "{:#?}", r.diags);
    // ... and passes once a SAFETY comment sits directly above the block
    let src = "pub fn peek(v: &[u8]) -> u8 {\n    \
               // SAFETY: slice pointers are valid for reads of len >= 1\n    \
               unsafe { *v.as_ptr() }\n}\n";
    let r = lint_source("rust/src/linalg/simd.rs", src);
    assert!(r.diags.is_empty(), "{:#?}", r.diags);
}

#[test]
fn nondeterminism_fires_on_instant_and_systemtime() {
    let r = lint_source("rust/src/coordinator/cv.rs", &fixture("bad_nondet.rs"));
    assert_eq!(
        fired(&r),
        vec![
            (3, "nondeterminism".to_string()),
            (4, "nondeterminism".to_string()),
            (8, "nondeterminism".to_string())
        ],
        "{:#?}",
        r.diags
    );
    // the timing substrate and the bench harness are the allowlisted homes
    for rel in ["rust/src/util/timer.rs", "rust/src/bench.rs", "rust/benches/exec.rs"] {
        let r = lint_source(rel, &fixture("bad_nondet.rs"));
        assert!(r.diags.is_empty(), "{rel} should be allowlisted: {:#?}", r.diags);
    }
}

#[test]
fn clean_fixture_passes_and_honors_its_waiver() {
    let r = lint_source("rust/src/ops.rs", &fixture("clean.rs"));
    assert!(r.diags.is_empty(), "{:#?}", r.diags);
    assert_eq!(r.waivers_used, 1);
    assert!(r.unused_waivers.is_empty(), "{:?}", r.unused_waivers);
}

#[test]
fn file_level_waiver_covers_the_whole_file() {
    let src = format!(
        "// repro-lint: allow-file(kernel-reduction): reference fold, reason here\n{}",
        fixture("bad_reduction.rs")
    );
    let r = lint_source("rust/src/ops.rs", &src);
    assert!(r.diags.is_empty(), "{:#?}", r.diags);
    assert_eq!(r.waivers_used, 1);
}

#[test]
fn malformed_waivers_are_diagnostics() {
    // unknown rule
    let r = lint_source("rust/src/ops.rs", "// repro-lint: allow(no-such-rule): why\n");
    assert_eq!(fired(&r), vec![(1, "bad-waiver".to_string())], "{:#?}", r.diags);
    // missing reason
    let r = lint_source("rust/src/ops.rs", "// repro-lint: allow(no-fma):\n");
    assert_eq!(fired(&r), vec![(1, "bad-waiver".to_string())], "{:#?}", r.diags);
    // unused waivers surface as warnings, not diagnostics
    let r = lint_source("rust/src/ops.rs", "// repro-lint: allow(no-fma): nothing here\n");
    assert!(r.diags.is_empty());
    assert_eq!(r.unused_waivers.len(), 1);
}

#[test]
fn macro_bodies_are_scanned() {
    // syn's item visitors do not descend into macro_rules! bodies; the
    // token-level pass must still catch a fused op hidden there
    let src = "macro_rules! sneaky {\n    () => {\n        a.mul_add(b, c)\n    };\n}\n";
    let r = lint_source("rust/src/ops.rs", src);
    assert_eq!(fired(&r), vec![(3, "no-fma".to_string())], "{:#?}", r.diags);
}

#[test]
fn full_tree_is_clean() {
    // the acceptance gate: `cargo run -p repro-lint` over the real repo
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let (report, files) = lint_repo(&root);
    assert!(files > 40, "walker found only {files} files — wrong root?");
    assert!(
        report.diags.is_empty(),
        "the repo tree must lint clean:\n{}",
        report
            .diags
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(
        report.unused_waivers.is_empty(),
        "stale waivers: {:?}",
        report.unused_waivers
    );
}
