// kernel-reduction fixture: float reductions belong to linalg/simd.rs —
// both the iterator sum and the manual fold loop must fire.
pub fn total(v: &[f64]) -> f64 {
    v.iter().sum::<f64>()
}

pub fn sumsq(v: &[f64]) -> f64 {
    let mut acc = 0.0;
    for &x in v {
        acc += x * x;
    }
    acc
}
