// confined-unsafe fixture: `unsafe` outside the two allowlisted kernel
// files is rejected outright, justified or not.
pub fn peek(v: &[u8]) -> u8 {
    unsafe { *v.as_ptr() }
}
