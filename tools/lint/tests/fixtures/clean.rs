// Clean fixture: the shapes library code is supposed to use — integer
// work counters, re-accumulation of kernel partials, and an explicit,
// reasoned waiver. Must produce zero diagnostics.
pub fn counters(cols: usize, d: usize) -> usize {
    let mut col_ops = 0usize;
    for _ in 0..cols {
        col_ops += 2 * d; // integer work accounting, not a float fold
    }
    col_ops
}

pub fn refold(partials: &[f64]) -> f64 {
    let mut total = 0.0;
    for &p in partials {
        total += p; // left-to-right re-fold of kernel partials: no product
    }
    total
}

pub fn waived(v: &[f64]) -> f64 {
    // repro-lint: allow(kernel-reduction): fixture exercising the waiver path
    v.iter().sum::<f64>()
}
