// nondeterminism fixture: wallclock types are confined to util/timer.rs
// and the bench harness; entropy-seeded RNG is banned everywhere.
pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}

pub fn epoch() {
    let _ = std::time::SystemTime::now();
}
