// no-fma fixture: the §12 accumulation contract rounds every product
// before the add, so fused multiply-adds are banned on every backend.
use core::arch::x86_64::_mm256_fmadd_pd;

pub fn fused(a: f64, b: f64, c: f64) -> f64 {
    a.mul_add(b, c)
}
