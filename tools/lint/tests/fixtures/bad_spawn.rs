// no-spawn fixture: thread creation is util/executor.rs's monopoly
// (DESIGN.md §11 — the zero-spawn invariant the tests pin dynamically).
pub fn fan_out() {
    let h = std::thread::spawn(|| 1 + 1);
    std::thread::scope(|_s| {});
    h.join().unwrap();
}
