"""Adversarial edge cases for the QP1QC secular solver (the numerical core
of DPC): branch boundaries, degenerate inputs, extreme dynamic range, and
f32 behaviour of the fused kernel."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, screen_scores
from compile.kernels.screen import secular_newton_batch


def newton(a, b2, delta):
    return np.asarray(
        secular_newton_batch(jnp.asarray(a, jnp.float64), jnp.asarray(b2, jnp.float64), delta)
    )


def bisect(a, b2, delta):
    return np.asarray(
        ref.secular_bisect(jnp.asarray(a, jnp.float64), jnp.asarray(b2, jnp.float64), delta, iters=400)
    )


def test_duplicate_max_norms_with_nonzero_a():
    # |I| = 2 with q nonzero on I: the Newton branch must handle the pole
    a = np.array([[1.0, -1.0, 0.2]])
    b2 = np.array([[2.0, 2.0, 0.5]])
    for delta in [0.1, 1.0, 10.0]:
        np.testing.assert_allclose(newton(a, b2, delta), bisect(a, b2, delta), rtol=1e-9)


def test_duplicate_max_norms_with_zero_a():
    # |I| = 3, q = 0 on I: closed-form branch with free boundary directions
    a = np.array([[0.0, 0.0, 0.0, 0.3]])
    b2 = np.array([[1.5, 1.5, 1.5, 0.2]])
    delta = 5.0
    got = newton(a, b2, delta)[0]
    # ubar_3 = c_3/(amin - beta_3), c_3 = 2*sqrt(0.2)*0.3
    c3 = 2.0 * np.sqrt(0.2) * 0.3
    ub3 = c3 / (3.0 - 0.4)
    want = 0.09 + 1.5 * delta**2 + 0.5 * c3 * ub3
    np.testing.assert_allclose(got, want, rtol=1e-10)


def test_extreme_dynamic_range():
    rng = np.random.default_rng(5)
    a = rng.standard_normal((64, 4)) * np.logspace(-4, 4, 64)[:, None]
    b2 = np.abs(rng.standard_normal((64, 4))) * np.logspace(4, -4, 64)[:, None] + 1e-12
    for delta in [1e-4, 1.0, 1e4]:
        np.testing.assert_allclose(
            newton(a, b2, delta), bisect(a, b2, delta), rtol=1e-7,
            err_msg=f"delta={delta}",
        )


def test_single_task_equals_cauchy_schwarz():
    rng = np.random.default_rng(6)
    a = rng.standard_normal((32, 1))
    b2 = np.abs(rng.standard_normal((32, 1))) + 1e-6
    delta = 0.8
    want = (np.abs(a[:, 0]) + np.sqrt(b2[:, 0]) * delta) ** 2
    np.testing.assert_allclose(newton(a, b2, delta), want, rtol=1e-9)


def test_one_zero_norm_task_is_inert():
    # a task with a zero column contributes nothing
    rng = np.random.default_rng(7)
    a2 = rng.standard_normal((16, 2))
    b2_2 = np.abs(rng.standard_normal((16, 2))) + 0.1
    a3 = np.concatenate([a2, np.zeros((16, 1))], axis=1)
    b2_3 = np.concatenate([b2_2, np.zeros((16, 1))], axis=1)
    np.testing.assert_allclose(newton(a3, b2_3, 0.7), newton(a2, b2_2, 0.7), rtol=1e-10)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(1e-6, 1e3))
def test_newton_vs_bisect_fuzz(seed, delta):
    rng = np.random.default_rng(seed)
    t = int(rng.integers(1, 8))
    d = int(rng.integers(1, 40))
    a = rng.standard_normal((d, t)) * rng.uniform(1e-3, 1e2)
    b2 = np.abs(rng.standard_normal((d, t))) * rng.uniform(1e-3, 1e2)
    np.testing.assert_allclose(newton(a, b2, delta), bisect(a, b2, delta), rtol=1e-7, atol=1e-12)


def test_f32_kernel_close_to_f64_truth():
    # the AOT engine runs the kernel in f32 with a 1e-3 safety margin;
    # verify the margin covers the f32 error for realistic score ranges
    rng = np.random.default_rng(9)
    t, n, d = 4, 16, 64
    X = rng.standard_normal((t, n, d)).astype(np.float32)
    o = (rng.standard_normal((t, n)) * 0.3).astype(np.float32)
    delta = 0.25
    s32 = np.asarray(screen_scores(jnp.asarray(X), jnp.asarray(o), jnp.asarray([delta], jnp.float32), block_d=16))
    s64 = np.asarray(ref.screen_scores(jnp.asarray(X, jnp.float64), jnp.asarray(o, jnp.float64), delta))
    near_one = (s64 > 0.2) & (s64 < 5.0)
    rel = np.abs(s32[near_one] - s64[near_one]) / s64[near_one]
    assert rel.max() < 1e-3, f"f32 error {rel.max()} exceeds the engine margin"
