"""L2 graph tests: FISTA chunks, lambda_max, Theorem 5 ball, DPC safety."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def make_problem(t=3, n=12, d=40, sparsity=0.2, noise=0.01, seed=0, dtype=np.float64):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((t, n, d)).astype(dtype)
    W = np.zeros((d, t), dtype)
    active = rng.choice(d, max(1, int(sparsity * d)), replace=False)
    W[active] = rng.standard_normal((len(active), t))
    y = np.einsum("tnd,dt->tn", X, W) + noise * rng.standard_normal((t, n))
    return jnp.asarray(X), jnp.asarray(y.astype(dtype))


def solve_tight(X, y, lam, steps=4000):
    W, obj, gap = ref.fista(X, y, lam, steps=steps)
    assert float(gap) < 1e-8 * max(1.0, float(obj)), f"gap={float(gap)}"
    return W


# ---------------------------------------------------------------------------
# lambda_max (Theorem 1)
# ---------------------------------------------------------------------------


def test_lammax_zero_solution_above():
    X, y = make_problem(seed=1)
    lmax, _ = ref.lambda_max(X, y)
    W = solve_tight(X, y, float(lmax) * 1.0001)
    assert float(jnp.max(jnp.abs(W))) < 1e-7


def test_lammax_nonzero_solution_below():
    X, y = make_problem(seed=2)
    lmax, _ = ref.lambda_max(X, y)
    W = solve_tight(X, y, float(lmax) * 0.95)
    assert float(jnp.max(jnp.abs(W))) > 1e-6


def test_lammax_fn_matches_ref():
    X, y = make_problem(seed=3, dtype=np.float32)
    lm_arr, n, g = model.lammax_fn(X, y)
    lmax, lstar = ref.lambda_max(X, y)
    np.testing.assert_allclose(float(lm_arr[0]), float(lmax), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g), np.asarray(ref.gscore(X, y)), rtol=1e-5)
    want_n = ref.normal_at_lmax(X, y)
    np.testing.assert_allclose(np.asarray(n), np.asarray(want_n), rtol=1e-5, atol=1e-6)


def test_theta_at_lammax_is_feasible():
    X, y = make_problem(seed=4)
    lmax, _ = ref.lambda_max(X, y)
    g = ref.gscore(X, y / lmax)
    assert float(jnp.max(g)) <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# Theorem 5: the ball really contains theta*(lambda)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ratio,ratio0", [(0.5, 1.0), (0.3, 0.5), (0.8, 1.0), (0.05, 0.1)])
def test_ball_contains_dual_optimum(ratio, ratio0):
    X, y = make_problem(t=2, n=10, d=30, seed=5)
    lmax, _ = ref.lambda_max(X, y)
    lam, lam0 = float(lmax) * ratio, float(lmax) * ratio0
    if ratio0 >= 1.0:
        theta0 = y / lam0
        n = ref.normal_at_lmax(X, y)
    else:
        W0 = solve_tight(X, y, lam0)
        theta0 = (y - ref.matmul_xw(X, W0)) / lam0
        n = y / lam0 - theta0
    o, delta = ref.dpc_ball(y, theta0, n, lam, lam0)
    W = solve_tight(X, y, lam)
    theta = (y - ref.matmul_xw(X, W)) / lam
    dist = float(jnp.sqrt(jnp.sum((theta - o) ** 2)))
    # allow solver tolerance on top of the certified radius
    assert dist <= float(delta) + 1e-5, (dist, float(delta))


def test_ball_geometry_signs():
    # Theorem 5 parts 2-3: <y, n> >= 0 and <r, n> >= 0
    X, y = make_problem(t=2, n=10, d=30, seed=6)
    lmax, _ = ref.lambda_max(X, y)
    lam0 = float(lmax) * 0.6
    W0 = solve_tight(X, y, lam0)
    theta0 = (y - ref.matmul_xw(X, W0)) / lam0
    n = y / lam0 - theta0
    assert float(jnp.sum(y * n)) >= -1e-8
    for ratio in [0.5, 0.3, 0.1]:
        r = y / (float(lmax) * ratio) - theta0
        assert float(jnp.sum(r * n)) >= -1e-8


# ---------------------------------------------------------------------------
# DPC safety (Theorem 8) — the headline property
# ---------------------------------------------------------------------------


def test_dpc_rejects_only_true_zero_rows():
    X, y = make_problem(t=2, n=10, d=50, sparsity=0.1, seed=7)
    lmax, _ = ref.lambda_max(X, y)
    lam0, lam = float(lmax), float(lmax) * 0.5
    rejected = ref.dpc_rejects(X, y, y / lam0, ref.normal_at_lmax(X, y), lam, lam0)
    W = solve_tight(X, y, lam)
    row_norms = np.asarray(jnp.sqrt(jnp.sum(W * W, axis=1)))
    assert np.all(row_norms[np.asarray(rejected)] < 1e-7)
    assert int(np.sum(np.asarray(rejected))) > 0  # the rule does something


def test_dpc_sequential_safety_along_grid():
    X, y = make_problem(t=2, n=8, d=40, sparsity=0.15, seed=8)
    lmax, _ = ref.lambda_max(X, y)
    lams = float(lmax) * np.logspace(0, -2, 12)[1:]
    theta0, n, lam0 = y / float(lmax), ref.normal_at_lmax(X, y), float(lmax)
    for lam in lams:
        lam = float(lam)
        rejected = np.asarray(ref.dpc_rejects(X, y, theta0, n, lam, lam0))
        W = solve_tight(X, y, lam, steps=20000)  # small lam converges slowly
        rn = np.asarray(jnp.sqrt(jnp.sum(W * W, axis=1)))
        assert np.all(rn[rejected] < 1e-7), f"unsafe rejection at lam={lam}"
        theta0 = (y - ref.matmul_xw(X, W)) / lam
        n = y / lam - theta0
        lam0 = lam


def test_path_with_dpc_matches_unscreened_path():
    X, y = make_problem(t=2, n=8, d=30, sparsity=0.2, seed=9)
    lmax, _ = ref.lambda_max(X, y)
    lams = [float(lmax) * r for r in (0.7, 0.4, 0.2)]
    screened = model.path_with_dpc(X, y, lams, fista_steps=3000)
    for (W_s, keep), lam in zip(screened, lams):
        W_full = solve_tight(X, y, lam, steps=3000)
        np.testing.assert_allclose(
            np.asarray(W_s), np.asarray(W_full), atol=5e-5,
            err_msg=f"screened/unscreened mismatch at lam={lam}",
        )


# ---------------------------------------------------------------------------
# FISTA chunk graph (the AOT solver ABI)
# ---------------------------------------------------------------------------


def test_fista_chunks_equal_monolithic():
    X, y = make_problem(t=3, n=10, d=24, seed=10, dtype=np.float32)
    lmax, _ = ref.lambda_max(X, y)
    lam = float(lmax) * 0.4
    L = ref.lipschitz(X)
    # two 30-step chunks == one 60-step run
    fn = model.make_fista_fn(30)
    T, N, D = X.shape
    W = V = jnp.zeros((D, T), jnp.float32)
    t = jnp.asarray([1.0], jnp.float32)
    lam_a = jnp.asarray([lam], jnp.float32)
    L_a = jnp.asarray([float(L)], jnp.float32)
    for _ in range(2):
        W, V, t, R, obj, gap = fn(X, y, W, V, t, lam_a, L_a)
    W_ref, _, _ = ref.fista(X, y, lam, steps=60, L=float(L))
    np.testing.assert_allclose(np.asarray(W), np.asarray(W_ref), rtol=2e-4, atol=2e-5)
    # returned residual must be consistent with W
    np.testing.assert_allclose(
        np.asarray(R), np.asarray(ref.matmul_xw(X, W) - y), rtol=2e-4, atol=2e-5
    )


def test_fista_gap_decreases_and_bounds_suboptimality():
    X, y = make_problem(t=2, n=10, d=20, seed=11)
    lmax, _ = ref.lambda_max(X, y)
    lam = float(lmax) * 0.3
    gaps = [float(ref.fista(X, y, lam, steps=s)[2]) for s in (20, 100, 600)]
    assert gaps[2] < gaps[1] < gaps[0]
    assert gaps[2] >= -1e-10  # weak duality


def test_lipschitz_fn_upper_bounds_spectral_norms():
    X, _ = make_problem(t=4, n=12, d=16, seed=12, dtype=np.float32)
    (L,) = model.lipschitz_fn(X)
    true = max(
        float(np.linalg.norm(np.asarray(X)[t], 2) ** 2) for t in range(X.shape[0])
    )
    assert float(L[0]) >= true * 0.999
    assert float(L[0]) <= true * 1.01


def test_screen_fn_matches_ref_pipeline():
    X, y = make_problem(t=2, n=10, d=32, seed=13, dtype=np.float32)
    lmax, _ = ref.lambda_max(X, y)
    lam0, lam = float(lmax), 0.5 * float(lmax)
    theta0 = y / lam0
    n = ref.normal_at_lmax(X, y)
    fn = model.make_screen_fn(model.pick_block(32))
    (s,) = fn(X, y, theta0, n, jnp.asarray([lam], jnp.float32))
    o, delta = ref.dpc_ball(y, theta0, n, lam, lam0)
    want = ref.screen_scores(X, o, float(delta))
    np.testing.assert_allclose(np.asarray(s), np.asarray(want), rtol=5e-4, atol=1e-5)
