"""AOT lowering smoke tests: artifacts exist, parse as HLO text, manifest ABI
matches what model.py promises."""

import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))  # python/


@pytest.fixture(scope="module")
def artifact_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--configs", "quick"],
        cwd=HERE,
        env=env,
        check=True,
    )
    return out


def read_manifest(artifact_dir):
    lines = (artifact_dir / "manifest.tsv").read_text().strip().split("\n")
    header = lines[0].split("\t")
    return [dict(zip(header, l.split("\t"))) for l in lines[1:]]


def test_manifest_complete(artifact_dir):
    rows = read_manifest(artifact_dir)
    kinds = sorted({r["kind"] for r in rows})
    assert kinds == ["fista", "lammax", "lipschitz", "screen"]
    # quick config: 1 lammax + 1 screen + 3 buckets x (fista + lipschitz)
    assert len(rows) == 2 + 2 * 3


def test_artifacts_are_parsable_hlo_text(artifact_dir):
    for row in read_manifest(artifact_dir):
        text = (artifact_dir / (row["name"] + ".hlo.txt")).read_text()
        assert text.startswith("HloModule"), row["name"]
        assert "ENTRY" in text, row["name"]


def test_manifest_abi_shapes(artifact_dir):
    rows = {r["name"]: r for r in read_manifest(artifact_dir)}
    lm = rows["lammax_quick"]
    T, N, D = int(lm["T"]), int(lm["N"]), int(lm["D"])
    assert lm["inputs"] == f"{T}x{N}x{D}:f32;{T}x{N}:f32"
    assert lm["outputs"] == f"1:f32;{T}x{N}:f32;{D}:f32"
    sc = rows["screen_quick"]
    assert sc["inputs"] == f"{T}x{N}x{D}:f32;{T}x{N}:f32;{T}x{N}:f32;{T}x{N}:f32;1:f32"
    assert sc["outputs"] == f"{D}:f32"
    fi = rows["fista_quick_b64"]
    assert fi["inputs"].startswith(f"{T}x{N}x64:f32")
    assert fi["outputs"] == f"64x{T}:f32;64x{T}:f32;1:f32;{T}x{N}:f32;1:f32;1:f32"


def test_screen_artifact_mentions_while_loop(artifact_dir):
    # the fused Pallas screen kernel lowers (interpret mode) to a loop +
    # dynamic slices over the d grid — sanity that the kernel is really in
    # the module rather than constant-folded away
    text = (artifact_dir / "screen_quick.hlo.txt").read_text()
    assert "while" in text or "dynamic-slice" in text
