import jax

# Build-time tests run in f64 so oracles are tight; artifacts themselves are
# lowered without x64 (aot.py) and stay f32.
jax.config.update("jax_enable_x64", True)
