"""Pallas kernels vs the pure-jnp oracle (ref.py) — the core L1 signal.

Hypothesis sweeps shapes/dtypes; every kernel must match ref to float
tolerance on arbitrary inputs, including adversarial ones (zero columns,
duplicate column norms, huge dynamic range).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gscore, grad21, matmul_xw, prox21, screen_scores
from compile.kernels import ref
from compile.kernels.screen import secular_newton_batch

RNG = np.random.default_rng(0)


def rand_problem(t, n, d, dtype=np.float32, scale=1.0, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    X = (rng.standard_normal((t, n, d)) * scale).astype(dtype)
    o = rng.standard_normal((t, n)).astype(dtype)
    return X, o


shape_st = st.tuples(
    st.integers(1, 5),               # T
    st.integers(1, 24),              # N
    st.sampled_from([4, 8, 16, 64]), # D (divisible by the chosen blocks)
    st.sampled_from([np.float32, np.float64]),
    st.integers(0, 2**31 - 1),
)


@settings(max_examples=25, deadline=None)
@given(shape_st)
def test_gscore_matches_ref(args):
    t, n, d, dtype, seed = args
    X, th = rand_problem(t, n, d, dtype, seed=seed)
    got = gscore(jnp.asarray(X), jnp.asarray(th), block_d=4)
    want = ref.gscore(jnp.asarray(X), jnp.asarray(th))
    rtol = 1e-5 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(shape_st)
def test_matmul_xw_matches_ref(args):
    t, n, d, dtype, seed = args
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((t, n, d)).astype(dtype)
    W = rng.standard_normal((d, t)).astype(dtype)
    got = matmul_xw(jnp.asarray(X), jnp.asarray(W), block_d=4)
    want = ref.matmul_xw(jnp.asarray(X), jnp.asarray(W))
    rtol = 2e-5 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(shape_st)
def test_grad21_matches_ref(args):
    t, n, d, dtype, seed = args
    X, r = rand_problem(t, n, d, dtype, seed=seed)
    got = grad21(jnp.asarray(X), jnp.asarray(r), block_d=4)
    want = ref.grad21(jnp.asarray(X), jnp.asarray(r))
    rtol = 1e-5 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(shape_st, st.floats(0.0, 5.0))
def test_prox21_matches_ref(args, kappa):
    t, _, d, dtype, seed = args
    rng = np.random.default_rng(seed)
    W = rng.standard_normal((d, t)).astype(dtype)
    got = prox21(jnp.asarray(W), jnp.asarray([kappa], dtype=dtype), block_d=4)
    want = ref.prox21(jnp.asarray(W), kappa)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_prox21_zero_row_stays_zero():
    W = np.zeros((8, 3), np.float32)
    got = prox21(jnp.asarray(W), jnp.asarray([1.0], jnp.float32), block_d=4)
    assert np.all(np.asarray(got) == 0.0)


def test_prox21_exact_shrink_value():
    # a single row with norm 5, kappa=2 -> scaled by 3/5
    W = np.zeros((4, 2), np.float32)
    W[1] = [3.0, 4.0]
    got = np.asarray(prox21(jnp.asarray(W), jnp.asarray([2.0], jnp.float32), block_d=4))
    np.testing.assert_allclose(got[1], [1.8, 2.4], rtol=1e-6)
    assert np.all(got[0] == 0) and np.all(got[2:] == 0)


@settings(max_examples=20, deadline=None)
@given(shape_st, st.floats(1e-3, 10.0))
def test_screen_kernel_matches_oracle(args, delta):
    t, n, d, dtype, seed = args
    X, o = rand_problem(t, n, d, dtype, seed=seed)
    Xj, oj = jnp.asarray(X), jnp.asarray(o)
    got = screen_scores(Xj, oj, jnp.asarray([delta], dtype), block_d=4)
    want = ref.screen_scores(Xj, oj, delta)
    rtol = 2e-4 if dtype == np.float32 else 1e-9
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=1e-5)


def test_screen_zero_columns_rejected():
    # zero feature columns must give s = 0 < 1 (padding-correctness)
    X = np.zeros((2, 8, 8), np.float32)
    X[:, :, :4] = RNG.standard_normal((2, 8, 4)).astype(np.float32)
    o = RNG.standard_normal((2, 8)).astype(np.float32)
    s = np.asarray(screen_scores(jnp.asarray(X), jnp.asarray(o), jnp.asarray([0.5], jnp.float32), block_d=4))
    assert np.all(s[4:] == 0.0)
    assert np.all(s[:4] > 0.0)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 6),
    st.integers(1, 64),
    st.floats(1e-4, 100.0),
    st.integers(0, 2**31 - 1),
)
def test_secular_newton_matches_bisect_f64(t, d, delta, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((d, t)) * 3.0
    b2 = np.abs(rng.standard_normal((d, t))) ** 2 + 1e-8
    got = secular_newton_batch(jnp.asarray(a), jnp.asarray(b2), delta)
    want = ref.secular_bisect(jnp.asarray(a), jnp.asarray(b2), delta, iters=400)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-8, atol=1e-10)


def test_secular_closed_form_branch():
    # q vanishes on the active set (a=0 at the max-norm task) and ||ubar||<=Delta:
    # alpha* = 2 rho^2 exactly, s = sum a^2 + rho^2 Delta^2 + 1/2 q^T ubar.
    a = np.array([[0.0, 0.1]])
    b2 = np.array([[4.0, 1.0]])  # rho^2 = 4 attained at t=0, a_0 = 0
    delta = 10.0  # large so ||ubar|| <= Delta
    got = float(secular_newton_batch(jnp.asarray(a), jnp.asarray(b2), delta)[0])
    # ubar_1 = c_1/(amin-beta_1) = (2*1*0.1)/(8-2) = 1/30
    ubar1 = 0.2 / 6.0
    want = 0.1**2 + 4.0 * delta**2 + 0.5 * 0.2 * ubar1
    np.testing.assert_allclose(got, want, rtol=1e-10)


def test_secular_pure_quadratic():
    # all a = 0: s = rho^2 Delta^2 (maximize sum b^2 u^2 over ||u||<=Delta)
    a = np.zeros((3, 4))
    b2 = np.abs(np.random.default_rng(1).standard_normal((3, 4))) + 0.1
    delta = 2.5
    got = np.asarray(secular_newton_batch(jnp.asarray(a), jnp.asarray(b2), delta))
    want = np.max(b2, axis=1) * delta**2
    np.testing.assert_allclose(got, want, rtol=1e-10)


def test_secular_is_upper_bound_by_sampling():
    # s_l >= g_l(theta) for theta sampled in the ball (safety of the max),
    # and the max over boundary samples approaches s_l in low dimension.
    rng = np.random.default_rng(7)
    t, n, d = 2, 6, 8
    X = rng.standard_normal((t, n, d)).astype(np.float64)
    o = rng.standard_normal((t, n))
    delta = 0.7
    s = np.asarray(ref.screen_scores(jnp.asarray(X), jnp.asarray(o), delta))
    best = np.zeros(d)
    for _ in range(4000):
        pert = rng.standard_normal((t, n))
        pert *= delta / np.linalg.norm(pert)
        th = o + pert
        g = np.asarray(ref.gscore(jnp.asarray(X), jnp.asarray(th)))
        assert np.all(g <= s + 1e-9), "sampled g exceeded the certified max"
        best = np.maximum(best, g)
    # in (t*n)=12 dims random boundary sampling gets within ~25%
    assert np.all(best >= 0.5 * s)


def test_secular_delta_zero_is_center_score():
    rng = np.random.default_rng(3)
    a = rng.standard_normal((5, 3))
    b2 = np.abs(rng.standard_normal((5, 3)))
    got = np.asarray(secular_newton_batch(jnp.asarray(a), jnp.asarray(b2), 0.0))
    np.testing.assert_allclose(got, np.sum(a * a, axis=1), rtol=1e-12)


def test_secular_monotone_in_delta():
    rng = np.random.default_rng(11)
    a = rng.standard_normal((16, 4))
    b2 = np.abs(rng.standard_normal((16, 4))) + 0.05
    prev = None
    for delta in [0.0, 0.1, 0.5, 1.0, 3.0]:
        s = np.asarray(secular_newton_batch(jnp.asarray(a), jnp.asarray(b2), delta))
        if prev is not None:
            assert np.all(s >= prev - 1e-10)
        prev = s
