"""L1 Pallas kernel: dual constraint scores g_l(theta) for all features.

g_l(theta) = sum_t <x_l^{(t)}, theta_t>^2  (Eq. 16) is the sweep behind
lambda_max (Thm 1), the dual-feasibility scaling in duality gaps, and the
KKT screening check.  Tiled over d: each grid step holds a (T, N, d_blk)
slab in VMEM and issues per-task (1,N)x(N,d_blk) MXU contractions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gscore_kernel(x_ref, th_ref, g_ref):
    x = x_ref[...]       # (T, N, d_blk)
    th = th_ref[...]     # (T, N)
    c = jnp.einsum("tnd,tn->dt", x, th)
    g_ref[...] = jnp.sum(c * c, axis=1)


@functools.partial(jax.jit, static_argnames=("block_d",))
def gscore(X, theta, block_d=512):
    """g: (D,). D must divide by block_d (pad with zero columns: g=0)."""
    T, N, D = X.shape
    block_d = min(block_d, D)
    assert D % block_d == 0, (D, block_d)
    return pl.pallas_call(
        _gscore_kernel,
        grid=(D // block_d,),
        in_specs=[
            pl.BlockSpec((T, N, block_d), lambda i: (0, 0, i)),
            pl.BlockSpec((T, N), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((D,), X.dtype),
        interpret=True,
    )(X, theta)
