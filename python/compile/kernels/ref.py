"""Pure-jnp oracles for every Pallas kernel and for the DPC math.

These are the *reference semantics*: deliberately simple, written straight
from the paper's equations, and independent of the Pallas implementations
(e.g. the QP1QC oracle uses bisection on the secular equation while the
kernel uses safeguarded Newton). pytest compares kernels against this file.

Conventions (shared across the whole repo):
  X      : (T, N, D)  — task-stacked data matrices, equal N per task
  y      : (T, N)     — responses
  theta  : (T, N)     — dual variable (one block per task)
  W      : (D, T)     — weight matrix, rows are feature groups
  o      : (T, N)     — ball center from Theorem 5
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Elementary pieces
# ---------------------------------------------------------------------------


def task_corr(X, v):
    """c[l, t] = <x_l^{(t)}, v_t>   (the dual correlation sweep).  -> (D, T)."""
    return jnp.einsum("tnd,tn->dt", X, v)


def gscore(X, theta):
    """g_l(theta) = sum_t <x_l^{(t)}, theta_t>^2  (Eq. 16).  -> (D,)."""
    c = task_corr(X, theta)
    return jnp.sum(c * c, axis=1)


def col_sqnorms(X):
    """b2[l, t] = ||x_l^{(t)}||^2.  -> (D, T)."""
    return jnp.einsum("tnd,tnd->dt", X, X)


def lambda_max(X, y):
    """Theorem 1: lambda_max = max_l sqrt(g_l(y)); also returns argmax l*."""
    g = gscore(X, y)
    lstar = jnp.argmax(g)
    return jnp.sqrt(g[lstar]), lstar


def normal_at_lmax(X, y):
    """n(lambda_max) = grad g_{l*}(y / lambda_max)  (Eq. 20, second case).

    n_t = 2 <x_{l*}^{(t)}, y_t/lmax> x_{l*}^{(t)}    -> (T, N)
    """
    lmax, lstar = lambda_max(X, y)
    xs = X[:, :, lstar]  # (T, N)
    coef = 2.0 * jnp.einsum("tn,tn->t", xs, y) / lmax  # (T,)
    return coef[:, None] * xs


def prox21(W, kappa):
    """Row-wise group soft-threshold: prox of kappa * ||.||_{2,1}."""
    rn = jnp.sqrt(jnp.sum(W * W, axis=1, keepdims=True))
    scale = jnp.maximum(0.0, 1.0 - kappa / jnp.maximum(rn, 1e-38))
    return scale * W


def matmul_xw(X, W):
    """Z[t, n] = (X_t w_t)[n].  -> (T, N)."""
    return jnp.einsum("tnd,dt->tn", X, W)


def grad21(X, R):
    """G[l, t] = <x_l^{(t)}, R_t> — gradient of the smooth loss when
    R = X W - y.  -> (D, T)."""
    return jnp.einsum("tnd,tn->dt", X, R)


def primal_obj(X, y, W, lam):
    R = matmul_xw(X, W) - y
    return 0.5 * jnp.sum(R * R) + lam * jnp.sum(jnp.sqrt(jnp.sum(W * W, axis=1)))


def dual_obj(y, theta, lam):
    """D(theta) = 0.5||y||^2 - lam^2/2 ||y/lam - theta||^2  (Eq. 11)."""
    diff = y / lam - theta
    return 0.5 * jnp.sum(y * y) - 0.5 * lam * lam * jnp.sum(diff * diff)


def dual_feasible_point(X, y, W, lam):
    """Scale the residual into the dual feasible set F (for duality gaps)."""
    z = (y - matmul_xw(X, W)) / lam
    m = jnp.sqrt(jnp.max(gscore(X, z)))
    return z / jnp.maximum(1.0, m)


def duality_gap(X, y, W, lam):
    th = dual_feasible_point(X, y, W, lam)
    return primal_obj(X, y, W, lam) - dual_obj(y, th, lam)


# ---------------------------------------------------------------------------
# Theorem 5: the ball containing theta*(lambda)
# ---------------------------------------------------------------------------


def dpc_ball(y, theta0, n, lam, lam0):
    """Center o(lam, lam0) and radius Delta of Theta(lam, lam0)  (Eqs. 21-24).

    `theta0` is theta*(lam0); `n` is n(lam0) (Eq. 20) — the caller picks the
    residual vector (lam0 < lmax) or the gradient at y/lmax (lam0 = lmax).
    """
    r = y / lam - theta0
    nn = jnp.sum(n * n)
    rp = r - (jnp.sum(n * r) / jnp.maximum(nn, 1e-38)) * n
    o = theta0 + 0.5 * rp
    delta = 0.5 * jnp.sqrt(jnp.sum(rp * rp))
    return o, delta


# ---------------------------------------------------------------------------
# QP1QC oracle (Theorem 7) — bisection on the secular equation.
# ---------------------------------------------------------------------------


def secular_bisect(a, b2, delta, iters=200):
    """Reference solve of s_l = max_{theta in ball} g_l(theta), vectorized
    over features.

    a  : (D, T)  a[l,t] = <x_l^{(t)}, o_t>
    b2 : (D, T)  b2[l,t] = ||x_l^{(t)}||^2
    delta : scalar ball radius.

    Implements Theorem 7 with H = -2 diag(b2), q = -2 b |a| and solves
    ||u(alpha)|| = Delta on (2 rho^2, inf) by bisection — slow but
    unconditionally correct, which is what an oracle should be.
    """
    a = jnp.asarray(a, jnp.float64)
    b2 = jnp.asarray(b2, jnp.float64)
    delta = jnp.asarray(delta, jnp.float64)

    absa = jnp.abs(a)
    c = 2.0 * jnp.sqrt(b2) * absa  # -q  (so u(alpha) = c / (alpha - beta))
    beta = 2.0 * b2  # -diag(H)
    amin = jnp.max(beta, axis=1)  # 2 rho_l^2, (D,)
    ssq = jnp.sum(a * a, axis=1)  # sum_t <x,o>^2

    # Closed-form branch (Thm 7.2): the linear term vanishes on the active
    # index set I (where b2 attains rho^2) and ||ubar|| <= Delta.
    is_I = beta >= amin[:, None] * (1.0 - 1e-12)
    denom = jnp.maximum(amin[:, None] - beta, 1e-300)
    ubar = jnp.where(is_I, 0.0, c / denom)
    ctol = 1e-12 * (1.0 + jnp.max(c))
    qI_zero = jnp.all(jnp.where(is_I, c <= ctol, True), axis=1)
    closed = qI_zero & (jnp.sqrt(jnp.sum(ubar * ubar, axis=1)) <= delta)
    s_closed = ssq + 0.5 * amin * delta**2 + 0.5 * jnp.sum(c * ubar, axis=1)

    # Bisection branch on [amin, amin + ||c||/Delta]:
    # ||u(alpha)|| <= ||c|| / (alpha - amin), so phi(hi) >= 0.
    lo = amin
    hi = amin + jnp.sqrt(jnp.sum(c * c, axis=1)) / jnp.maximum(delta, 1e-300)
    hi = jnp.maximum(hi, amin * (1 + 1e-6) + 1e-6)

    def norm_u(alpha):
        u = c / jnp.maximum(alpha[:, None] - beta, 1e-300)
        return jnp.sqrt(jnp.sum(u * u, axis=1))

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        too_big = norm_u(mid) > delta  # alpha too small
        return jnp.where(too_big, mid, lo), jnp.where(too_big, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    alpha = 0.5 * (lo + hi)
    u = c / jnp.maximum(alpha[:, None] - beta, 1e-300)
    s_active = ssq + 0.5 * alpha * delta**2 + 0.5 * jnp.sum(c * u, axis=1)

    trivial = (delta <= 0.0) | (amin <= 1e-300)
    return jnp.where(trivial, ssq, jnp.where(closed, s_closed, s_active))


def screen_scores(X, o, delta, iters=200):
    """s_l(lam, lam0) for every feature (the left side of R*)."""
    a = task_corr(X, o)
    b2 = col_sqnorms(X)
    return secular_bisect(a, b2, delta, iters=iters)


def dpc_rejects(X, y, theta0, n, lam, lam0):
    """Full DPC rule (Thm 8): boolean mask of features certified inactive."""
    o, delta = dpc_ball(y, theta0, n, lam, lam0)
    s = screen_scores(X, o, delta)
    return s < 1.0


# ---------------------------------------------------------------------------
# Reference FISTA solver (used to validate the L2 scan and the rust solver)
# ---------------------------------------------------------------------------


def lipschitz(X, iters=100, seed=0):
    """L = max_t sigma_max(X_t)^2 by simultaneous power iteration."""
    T, N, D = X.shape
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, (T, D), dtype=X.dtype)

    def body(_, v):
        w = jnp.einsum("tnd,td->tn", X, v)
        u = jnp.einsum("tnd,tn->td", X, w)
        return u / jnp.maximum(jnp.sqrt(jnp.sum(u * u, axis=1, keepdims=True)), 1e-38)

    v = jax.lax.fori_loop(0, iters, body, v)
    w = jnp.einsum("tnd,td->tn", X, v)
    return jnp.max(jnp.sum(w * w, axis=1) / jnp.maximum(jnp.sum(v * v, axis=1), 1e-38))


def fista(X, y, lam, W0=None, steps=500, L=None):
    """Plain-jnp FISTA on problem (1); returns (W, obj, gap)."""
    T, N, D = X.shape
    if W0 is None:
        W0 = jnp.zeros((D, T), X.dtype)
    if L is None:
        L = lipschitz(X)
    L = jnp.maximum(L, 1e-12)

    def step(carry, _):
        W, V, t = carry
        R = matmul_xw(X, V) - y
        G = grad21(X, R)
        Wn = prox21(V - G / L, lam / L)
        tn = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        Vn = Wn + ((t - 1.0) / tn) * (Wn - W)
        return (Wn, Vn, tn), None

    (W, _, _), _ = jax.lax.scan(
        step, (W0, W0, jnp.asarray(1.0, X.dtype)), None, length=steps
    )
    return W, primal_obj(X, y, W, lam), duality_gap(X, y, W, lam)
