"""L1 Pallas kernels for the multi-matrix matmuls in the FISTA hot loop.

matmul_xw : Z[t] = X_t w_t     — forward residual sweep, accumulated
            across d-blocks (the grid is the reduction axis; the output
            block is revisited every step, the canonical Pallas
            accumulation pattern).
grad21    : G[l,t] = <x_l^{(t)}, R_t>  — gradient sweep, tiled over d.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xw_kernel(x_ref, w_ref, z_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        z_ref[...] = jnp.zeros_like(z_ref)

    x = x_ref[...]     # (T, N, d_blk)
    w = w_ref[...]     # (d_blk, T)
    z_ref[...] += jnp.einsum("tnd,dt->tn", x, w)


@functools.partial(jax.jit, static_argnames=("block_d",))
def matmul_xw(X, W, block_d=512):
    """Z: (T, N) = stack_t X_t w_t."""
    T, N, D = X.shape
    block_d = min(block_d, D)
    assert D % block_d == 0, (D, block_d)
    return pl.pallas_call(
        _xw_kernel,
        grid=(D // block_d,),
        in_specs=[
            pl.BlockSpec((T, N, block_d), lambda i: (0, 0, i)),
            pl.BlockSpec((block_d, T), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((T, N), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, N), X.dtype),
        interpret=True,
    )(X, W)


def _grad_kernel(x_ref, r_ref, g_ref):
    x = x_ref[...]     # (T, N, d_blk)
    r = r_ref[...]     # (T, N)
    g_ref[...] = jnp.einsum("tnd,tn->dt", x, r)


@functools.partial(jax.jit, static_argnames=("block_d",))
def grad21(X, R, block_d=512):
    """G: (D, T) with G[l,t] = <x_l^{(t)}, R_t>."""
    T, N, D = X.shape
    block_d = min(block_d, D)
    assert D % block_d == 0, (D, block_d)
    return pl.pallas_call(
        _grad_kernel,
        grid=(D // block_d,),
        in_specs=[
            pl.BlockSpec((T, N, block_d), lambda i: (0, 0, i)),
            pl.BlockSpec((T, N), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_d, T), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((D, T), X.dtype),
        interpret=True,
    )(X, R)
