"""L1 Pallas kernel: fused DPC screening scores (the paper's hot spot).

For every feature l the kernel computes, in one pass over a VMEM-resident
(T, N, d_blk) slab of X:

    a[l,t]  = <x_l^{(t)}, o_t>          (MXU: (1,N)x(N,d_blk) per task)
    b2[l,t] = ||x_l^{(t)}||^2
    s_l     = max_{theta in ball(o, Delta)} g_l(theta)   (Theorem 7)

The inner max is the QP1QC of Theorem 7: minimize
psi(u) = 1/2 u^T H u + q^T u over ||u|| <= Delta with H = -2 diag(b2),
q_t = -2 b_t |a_t|.  alpha* solves the secular equation
||u(alpha)|| = Delta, u_t(alpha) = c_t/(alpha - beta_t) with c = -q,
beta = -diag(H); we run a *safeguarded Newton* (Eqs. 29-30, bracketed by
[2 rho^2, 2 rho^2 + ||c||/Delta]) vectorized across the d_blk features —
pure VPU work, no HBM round-trip between the moments and the solve.

Fusing the moment computation with the secular solve is the point of this
kernel: a naive implementation writes a, b2 back to HBM (2*d*T floats) and
re-reads them; here they never leave VMEM/registers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEWTON_ITERS = 30


def secular_newton_batch(a, b2, delta):
    """Vectorized Theorem-7 solve; a, b2: (D, T), delta scalar -> s: (D,).

    Same math as ref.secular_bisect but with a bracketed Newton iteration
    (monotone from the left since 1/||u(alpha)|| is concave increasing;
    the bracket is only a float-safety net).
    """
    dt = a.dtype
    absa = jnp.abs(a)
    b = jnp.sqrt(b2)
    c = 2.0 * b * absa                     # -q
    beta = 2.0 * b2                        # -diag(H)
    amin = jnp.max(beta, axis=1)           # 2 rho^2
    ssq = jnp.sum(a * a, axis=1)
    delta = jnp.asarray(delta, dt)

    eps = jnp.asarray(1e-6 if dt == jnp.float32 else 1e-12, dt)
    tiny = jnp.asarray(1e-30 if dt == jnp.float32 else 1e-290, dt)

    # ---- closed-form branch (Thm 7.2/7.3) ----
    is_I = beta >= amin[:, None] * (1.0 - 8.0 * eps)
    denom = jnp.maximum(amin[:, None] - beta, tiny)
    ubar = jnp.where(is_I, 0.0, c / denom)
    ctol = eps * (1.0 + jnp.max(c))
    qI_zero = jnp.all(jnp.where(is_I, c <= ctol, True), axis=1)
    closed = qI_zero & (jnp.sqrt(jnp.sum(ubar * ubar, axis=1)) <= delta)
    s_closed = ssq + 0.5 * amin * delta * delta + 0.5 * jnp.sum(c * ubar, axis=1)

    # ---- Newton branch ----
    cnorm = jnp.sqrt(jnp.sum(c * c, axis=1))
    lo0 = amin * (1.0 + eps) + tiny
    hi0 = amin + cnorm / jnp.maximum(delta, tiny) + tiny
    alpha0 = jnp.minimum(lo0, hi0)  # start at the left end: phi < 0 there

    def newton_body(_, state):
        alpha, lo, hi = state
        gap = jnp.maximum(alpha[:, None] - beta, tiny)
        u = c / gap
        un2 = jnp.sum(u * u, axis=1)
        un = jnp.sqrt(un2)
        # phi = 1/un - 1/delta ; phi' = sum(u^2/gap) / un^3
        uhu = jnp.sum(u * u / gap, axis=1)
        # Paper Eq. (30): alpha += un^2 (un - delta) / (delta * u^T (H+aI)^-1 u)
        step = un2 * (un - delta) / jnp.maximum(delta * uhu, tiny)
        anew = alpha + step
        # bracket maintenance: phi<0 (un>delta) => alpha* above; else below
        lo = jnp.where(un > delta, alpha, lo)
        hi = jnp.where(un > delta, hi, alpha)
        bad = (anew <= lo) | (anew >= hi) | ~jnp.isfinite(anew)
        anew = jnp.where(bad, 0.5 * (lo + hi), anew)
        return anew, lo, hi

    alpha, _, _ = jax.lax.fori_loop(
        0, NEWTON_ITERS, newton_body, (alpha0, lo0 * 0.0 + amin, hi0)
    )
    u = c / jnp.maximum(alpha[:, None] - beta, tiny)
    s_active = ssq + 0.5 * alpha * delta * delta + 0.5 * jnp.sum(c * u, axis=1)

    trivial = (delta <= 0.0) | (amin <= tiny)
    return jnp.where(trivial, ssq, jnp.where(closed, s_closed, s_active))


def _screen_kernel(x_ref, o_ref, d_ref, s_ref):
    x = x_ref[...]          # (T, N, d_blk)
    o = o_ref[...]          # (T, N)
    delta = d_ref[0]
    a = jnp.einsum("tnd,tn->dt", x, o)
    b2 = jnp.einsum("tnd,tnd->dt", x, x)
    s_ref[...] = secular_newton_batch(a, b2, delta)


@functools.partial(jax.jit, static_argnames=("block_d",))
def screen_scores(X, o, delta, block_d=512):
    """s_l for all features via the fused Pallas kernel.

    X: (T,N,D), o: (T,N), delta: (1,) array. D must be divisible by block_d
    (aot.py pads datasets to the block size; zero columns give s=0 < 1 and
    are screened, which is correct).
    """
    T, N, D = X.shape
    block_d = min(block_d, D)
    assert D % block_d == 0, (D, block_d)
    grid = (D // block_d,)
    return pl.pallas_call(
        _screen_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, N, block_d), lambda i: (0, 0, i)),
            pl.BlockSpec((T, N), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((D,), X.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(X, o, jnp.reshape(delta, (1,)))
