"""L1 Pallas kernel: row-wise group soft-threshold (prox of kappa*||.||_2,1).

The prox in every FISTA step: each row w^l of W shrinks toward 0 by
max(0, 1 - kappa/||w^l||).  Tiled over d; pure VPU elementwise work on a
(d_blk, T) block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _prox_kernel(w_ref, k_ref, o_ref):
    w = w_ref[...]            # (d_blk, T)
    kappa = k_ref[0]
    rn = jnp.sqrt(jnp.sum(w * w, axis=1, keepdims=True))
    scale = jnp.maximum(0.0, 1.0 - kappa / jnp.maximum(rn, 1e-38))
    o_ref[...] = scale * w


@functools.partial(jax.jit, static_argnames=("block_d",))
def prox21(W, kappa, block_d=2048):
    """W: (D,T), kappa: (1,) array -> shrunk W."""
    D, T = W.shape
    block_d = min(block_d, D)
    assert D % block_d == 0, (D, block_d)
    return pl.pallas_call(
        _prox_kernel,
        grid=(D // block_d,),
        in_specs=[
            pl.BlockSpec((block_d, T), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_d, T), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((D, T), W.dtype),
        interpret=True,
    )(W, jnp.reshape(kappa, (1,)))
