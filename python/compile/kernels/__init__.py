"""L1 Pallas kernels (build-time only) + the pure-jnp oracle (ref)."""

from . import ref  # noqa: F401
from .gscore import gscore  # noqa: F401
from .matmul import grad21, matmul_xw  # noqa: F401
from .prox21 import prox21  # noqa: F401
from .screen import screen_scores, secular_newton_batch  # noqa: F401
