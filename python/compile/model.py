"""L2 JAX graphs — the compute surfaces lowered to HLO artifacts.

Each public function here is one AOT artifact (see aot.py). All scalars
cross the FFI boundary as shape-(1,) f32 arrays (the rust runtime passes
rank-1 literals; XLA scalars add no value and the crate's Literal API is
simplest for vectors). Every function returns a flat tuple of arrays.

Graphs:
  lammax_fn    : (X, y) -> (lam_max(1,), n(T,N), g(D,))          [Thm 1 + Eq. 20]
  screen_fn    : (X, y, theta0, n, lam, lam0) -> (s(D,),)        [Thm 5 + 7 + 8]
  lipschitz_fn : (X,) -> (L(1,),)                                [power iteration]
  fista_fn     : (X, y, W0, V0, t0, lam, L) ->
                 (W, V, t(1,), R(T,N), obj(1,), gap(1,))         [K-step chunk]

The screening graph calls the fused Pallas `screen` kernel (L1) so the
kernel lowers into the same HLO module; FISTA's matmuls are plain jnp
einsums (XLA's native gemm fusion beats an interpret-mode Pallas matmul
on CPU — see DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.screen import screen_scores


def pick_block(d: int, target: int = 512) -> int:
    """Largest divisor of d that is <= target (Pallas d-tiling)."""
    best = 1
    for b in range(1, min(d, target) + 1):
        if d % b == 0:
            best = b
    return best


# ---------------------------------------------------------------------------


def lammax_fn(X, y):
    """lambda_max, the normal-cone vector n(lambda_max), and g_l(y)."""
    g = ref.gscore(X, y)
    lstar = jnp.argmax(g)
    lmax = jnp.sqrt(g[lstar])
    xs = X[:, :, lstar]                                   # (T, N)
    coef = 2.0 * jnp.einsum("tn,tn->t", xs, y) / lmax     # (T,)
    n = coef[:, None] * xs
    return jnp.reshape(lmax, (1,)), n, g


def make_screen_fn(block_d: int):
    def screen_fn(X, y, theta0, n, lam):
        """DPC scores s_l(lam, lam0) for all features (Theorem 7).

        The ball needs only theta0/n(lam0)/lam — lam0 itself is folded into
        those vectors, so it is not part of the ABI (jax would DCE an unused
        parameter out of the lowered HLO anyway).
        """
        o, delta = ref.dpc_ball(y, theta0, n, lam[0], 1.0)
        s = screen_scores(X, o, delta, block_d=block_d)
        return (s,)

    return screen_fn


def lipschitz_fn(X):
    """L = max_t sigma_max(X_t)^2 — 80 rounds of simultaneous power iteration.

    Deterministic pseudo-random init (no RNG key in the artifact ABI):
    a Weyl sequence over feature indices, strictly positive so it cannot be
    orthogonal to the top eigenvector of the PSD Gram by accident.
    """
    T, N, D = X.shape
    idx = jnp.arange(T * D, dtype=X.dtype).reshape(T, D)
    v = 1.0 + 0.5 * jnp.sin(idx * 0.6180339887)

    def body(_, v):
        w = jnp.einsum("tnd,td->tn", X, v)
        u = jnp.einsum("tnd,tn->td", X, w)
        return u / jnp.maximum(jnp.sqrt(jnp.sum(u * u, axis=1, keepdims=True)), 1e-38)

    v = jax.lax.fori_loop(0, 80, body, v)
    w = jnp.einsum("tnd,td->tn", X, v)
    L = jnp.max(jnp.sum(w * w, axis=1) / jnp.maximum(jnp.sum(v * v, axis=1), 1e-38))
    return (jnp.reshape(L * 1.0001, (1,)),)  # 1e-4 safety factor on the step bound


def make_fista_fn(steps: int):
    def fista_fn(X, y, W0, V0, t0, lam, L):
        """One `steps`-iteration FISTA chunk + duality gap at the end."""
        lam_s = lam[0]
        L_s = jnp.maximum(L[0], 1e-12)

        def step(carry, _):
            W, V, t = carry
            R = ref.matmul_xw(X, V) - y
            G = ref.grad21(X, R)
            Wn = ref.prox21(V - G / L_s, lam_s / L_s)
            tn = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
            Vn = Wn + ((t - 1.0) / tn) * (Wn - W)
            return (Wn, Vn, tn), None

        (W, V, t), _ = jax.lax.scan(step, (W0, V0, t0[0]), None, length=steps)
        R = ref.matmul_xw(X, W) - y
        obj = 0.5 * jnp.sum(R * R) + lam_s * jnp.sum(jnp.sqrt(jnp.sum(W * W, axis=1)))
        # dual feasible point from the residual
        z = -R / lam_s
        m = jnp.sqrt(jnp.maximum(jnp.max(ref.gscore(X, z)), 1e-38))
        thf = z / jnp.maximum(1.0, m)
        dob = ref.dual_obj(y, thf, lam_s)
        gap = obj - dob
        return (
            W,
            V,
            jnp.reshape(t, (1,)),
            R,
            jnp.reshape(obj, (1,)),
            jnp.reshape(gap, (1,)),
        )

    return fista_fn


# ---------------------------------------------------------------------------
# Convenience: an end-to-end jnp path step (used by python tests only;
# the production path lives in the rust coordinator).
# ---------------------------------------------------------------------------


def path_with_dpc(X, y, lams, fista_steps=800):
    """Sequential-DPC lambda path in pure jax — the oracle for the rust
    coordinator's integration tests. Returns per-lambda (W, keep_mask)."""
    T, N, D = X.shape
    lmax_arr, n0, _ = lammax_fn(X, y)
    lmax = float(lmax_arr[0])
    out = []
    theta0 = y / lmax
    n = n0
    Wprev = jnp.zeros((D, T), X.dtype)
    lam0 = lmax
    for lam in lams:
        lam = float(lam)
        o, delta = ref.dpc_ball(y, theta0, n, lam, lam0)
        s = ref.screen_scores(X, o, delta)
        keep = s >= 1.0
        Xr = X[:, :, keep]
        if Xr.shape[2] == 0:
            W = jnp.zeros((D, T), X.dtype)
        else:
            Wr, _, _ = ref.fista(Xr, y, lam, W0=Wprev[keep, :], steps=fista_steps)
            W = jnp.zeros((D, T), X.dtype).at[keep, :].set(Wr)
        out.append((W, keep))
        R = ref.matmul_xw(X, W) - y
        theta0 = -R / lam
        n = y / lam - theta0
        lam0 = lam
        Wprev = W
    return out
