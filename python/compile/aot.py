"""AOT lowering: jax (L2, calling L1 Pallas) -> HLO *text* artifacts.

HLO text (NOT `lowered.compile()` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (the version behind the published `xla` rust
crate) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts
Config selection: --configs quick,synth2k (or env MTFL_AOT_CONFIGS).

Every artifact is registered in <out>/manifest.tsv with its full ABI
(shapes/dtypes of inputs and outputs) so the rust runtime can type-check
calls before touching PJRT.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


# (T, N, D, solver buckets, fista chunk steps). Buckets are the reduced
# dimensions the coordinator packs screened problems into; each gets its
# own fixed-shape fista/lipschitz executable.
CONFIGS = {
    # tiny shapes for unit/integration tests — compile in seconds
    "quick": dict(T=4, N=16, D=256, buckets=[64, 128, 256], steps=40),
    # synthetic-experiment scale (scaled from the paper's 50x50x10k+)
    "synth2k": dict(T=20, N=50, D=2000, buckets=[250, 500, 1000, 2000], steps=50),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def lower_one(fn, in_specs):
    lowered = jax.jit(fn).lower(*in_specs)
    out_tree = lowered.out_info
    outs = jax.tree_util.tree_leaves(out_tree)
    return to_hlo_text(lowered), [(tuple(o.shape), "f32") for o in outs]


def fmt_shapes(specs):
    return ";".join("x".join(map(str, s.shape)) + ":f32" for s in specs)


def fmt_out(outs):
    return ";".join("x".join(map(str, s)) + ":" + d for s, d in outs)


def emit(out_dir, rows, name, fn, in_specs, kind, cfg_name, cfg, bucket=0, steps=0):
    path = os.path.join(out_dir, name + ".hlo.txt")
    text, outs = lower_one(fn, in_specs)
    with open(path, "w") as f:
        f.write(text)
    rows.append(
        "\t".join(
            [
                name,
                kind,
                cfg_name,
                str(cfg["T"]),
                str(cfg["N"]),
                str(cfg["D"]),
                str(bucket),
                str(steps),
                fmt_shapes(in_specs),
                fmt_out(outs),
            ]
        )
    )
    print(f"  wrote {name}.hlo.txt ({len(text)} chars)")


def build_config(out_dir, rows, cfg_name, cfg):
    T, N, D = cfg["T"], cfg["N"], cfg["D"]
    print(f"config {cfg_name}: T={T} N={N} D={D} buckets={cfg['buckets']}")

    x = spec(T, N, D)
    y = spec(T, N)
    s1 = spec(1)

    emit(out_dir, rows, f"lammax_{cfg_name}", model.lammax_fn, [x, y], "lammax", cfg_name, cfg)

    block_d = model.pick_block(D)
    emit(
        out_dir,
        rows,
        f"screen_{cfg_name}",
        model.make_screen_fn(block_d),
        [x, y, spec(T, N), spec(T, N), s1],
        "screen",
        cfg_name,
        cfg,
    )

    for b in cfg["buckets"]:
        xb = spec(T, N, b)
        wb = spec(b, T)
        emit(
            out_dir,
            rows,
            f"lipschitz_{cfg_name}_b{b}",
            model.lipschitz_fn,
            [xb],
            "lipschitz",
            cfg_name,
            cfg,
            bucket=b,
        )
        emit(
            out_dir,
            rows,
            f"fista_{cfg_name}_b{b}",
            model.make_fista_fn(cfg["steps"]),
            [xb, y, wb, wb, s1, s1, s1],
            "fista",
            cfg_name,
            cfg,
            bucket=b,
            steps=cfg["steps"],
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--configs",
        default=os.environ.get("MTFL_AOT_CONFIGS", "quick,synth2k"),
        help="comma-separated subset of: " + ",".join(CONFIGS),
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    rows = []
    for cfg_name in args.configs.split(","):
        cfg_name = cfg_name.strip()
        if not cfg_name:
            continue
        build_config(args.out, rows, cfg_name, CONFIGS[cfg_name])

    header = "name\tkind\tcfg\tT\tN\tD\tbucket\tsteps\tinputs\toutputs"
    with open(os.path.join(args.out, "manifest.tsv"), "w") as f:
        f.write(header + "\n" + "\n".join(rows) + "\n")
    print(f"manifest: {len(rows)} artifacts -> {args.out}/manifest.tsv")


if __name__ == "__main__":
    main()
