//! ADNI-style workload: d >> N SNP regression across 10 brain-region
//! tasks — the regime where the paper reports its largest speedup (272x on
//! half a million SNPs). Demonstrates screening in the extreme-dimension
//! regime plus the memory win of feature compaction.
//!
//!     cargo run --release --example adni_sim [--d 20000] [--baseline]

use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::coordinator::path::{run_path, EngineKind, PathOptions, ScreenerKind};
use mtfl_dpc::data::snpsim::{snpsim, SnpSimOptions};
use mtfl_dpc::solver::SolveOptions;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let d = args
        .iter()
        .position(|a| a == "--d")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000usize);
    let run_baseline = args.iter().any(|a| a == "--baseline");

    println!("generating SNP dataset: 10 tasks x (25 x {d}) genotypes, LD rho=0.7 ...");
    let (ds, truth) = snpsim(&SnpSimOptions {
        tasks: 10,
        n: 25,
        d,
        causal: 40,
        ..Default::default()
    });
    let xbytes: usize = ds.mem_bytes();
    println!("X memory: {:.1} MB, d/N = {}", xbytes as f64 / 1e6, d / 25);

    let opts = PathOptions {
        ratios: lambda_grid(50, 1.0, 0.01),
        solve: SolveOptions { tol: 1e-6, ..Default::default() },
        screener: ScreenerKind::Dpc,
        ..Default::default()
    };
    let res = run_path(&ds, &opts, &EngineKind::Exact)?;
    println!(
        "DPC path: {:.2}s total (screen {:.2}s, solve {:.2}s)",
        res.total_secs, res.screen_secs, res.solve_secs
    );
    println!("mean rejection ratio: {:.4}", res.mean_rejection_ratio());
    let max_kept = res.records.iter().map(|r| r.kept).max().unwrap();
    println!(
        "max features ever given to the solver: {max_kept} of {d} \
         ({:.2}% of the design matrix materialized)",
        100.0 * max_kept as f64 / d as f64
    );

    // causal-SNP recovery at the smallest lambda
    let t = ds.t();
    let active: Vec<usize> = res
        .last_w
        .chunks_exact(t)
        .enumerate()
        .filter_map(|(l, row)| {
            (row.iter().map(|v| v * v).sum::<f64>().sqrt() > 1e-7).then_some(l)
        })
        .collect();
    let hits = truth.active.iter().filter(|l| active.contains(l)).count();
    println!(
        "smallest-lambda active set: {} SNPs, {hits}/{} causal recovered",
        active.len(),
        truth.active.len()
    );

    if run_baseline {
        println!("\nrunning unscreened baseline (slow) ...");
        let mut b = opts.clone();
        b.screener = ScreenerKind::None;
        let base = run_path(&ds, &b, &EngineKind::Exact)?;
        println!(
            "baseline {:.2}s  =>  speedup {:.1}x",
            base.total_secs,
            base.total_secs / res.total_secs.max(1e-9)
        );
    } else {
        println!("\n(pass --baseline to time the unscreened solver for the speedup ratio)");
    }
    Ok(())
}
