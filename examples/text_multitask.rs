//! TDT2-style one-vs-rest text classification: sparse Zipf-weighted
//! documents, dead-vocabulary pruning, then a screened λ-path that picks a
//! shared topical vocabulary across categories.
//!
//!     cargo run --release --example text_multitask

use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::coordinator::path::{run_path, EngineKind, PathOptions, ScreenerKind};
use mtfl_dpc::data::textsim::{nonzero_features, textsim, TextSimOptions};
use mtfl_dpc::solver::SolveOptions;

fn main() -> anyhow::Result<()> {
    let raw = textsim(&TextSimOptions {
        categories: 8,
        n_pos: 25,
        d: 6000,
        doc_len: 120,
        topic_terms: 40,
        seed: 11,
        ..Default::default()
    });
    println!(
        "corpus: {} one-vs-rest tasks, {} docs/task, vocabulary {}",
        raw.t(),
        raw.tasks[0].n,
        raw.d
    );

    // the paper prunes all-zero features first (36771 -> 24262 on TDT2)
    let kept_vocab = nonzero_features(&raw);
    let ds = raw.restrict(&kept_vocab);
    println!("after dead-term pruning: {} of {} terms", ds.d, raw.d);

    let opts = PathOptions {
        ratios: lambda_grid(40, 1.0, 0.01),
        solve: SolveOptions { tol: 1e-6, ..Default::default() },
        screener: ScreenerKind::Dpc,
        ..Default::default()
    };
    let res = run_path(&ds, &opts, &EngineKind::Exact)?;

    println!(
        "path: {:.2}s (screen {:.2}s); mean rejection {:.4}",
        res.total_secs,
        res.screen_secs,
        res.mean_rejection_ratio()
    );

    // show the selection trajectory: shared vocabulary size along the path
    println!("\n lambda/lmax   kept-by-DPC   active-terms");
    for r in res.records.iter().step_by(5) {
        println!(
            "   {:8.4}   {:>10}   {:>10}",
            r.ratio,
            r.kept,
            ds.d - r.inactive
        );
    }

    let t = ds.t();
    let shared_terms = res
        .last_w
        .chunks_exact(t)
        .filter(|row| row.iter().map(|v| v * v).sum::<f64>().sqrt() > 1e-7)
        .count();
    println!(
        "\nsmallest lambda selects {shared_terms} terms shared across all {} categories",
        ds.t()
    );
    Ok(())
}
