//! End-to-end driver (the EXPERIMENTS.md §E2E run): the full regularization
//! path on a real-sized synthetic workload, with and without DPC, on both
//! engines — proving all layers compose:
//!
//!   L1 Pallas screen kernel + L2 FISTA scan  →  HLO artifacts  →
//!   L3 rust coordinator (this binary) via PJRT, against the exact engine.
//!
//! Reports the paper's headline metrics: rejection-ratio curve and speedup.
//!
//!     make artifacts && cargo run --release --example e2e_path
//!     (add --quick for a CI-sized run)

use mtfl_dpc::coordinator::metrics::{mean_rejection_curve, speedup_row};
use mtfl_dpc::coordinator::path::{run_path, EngineKind, ScreenerKind};
use mtfl_dpc::coordinator::report;
use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::experiments::exp_opts;
use mtfl_dpc::runtime::AotEngine;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    // synth2k config shape (T=20, N=50, d=2000) so the AOT engine can run
    // the same problem; --quick uses the `quick` artifact config shape.
    let (t, n, d, grid) = if quick { (4, 16, 256, 12) } else { (20, 50, 2000, 50) };
    let cfg_note = if quick { "quick" } else { "synth2k" };
    let (ds, _) = synthetic1(&SynthOptions { t, n, d, seed: 7, ..Default::default() });
    println!("== e2e: {} (T={t}, N={n}, d={d}), {grid}-value grid ==\n", ds.name);

    // ---- exact engine: baseline (no screening) vs DPC ----
    let base = run_path(&ds, &exp_opts(grid, ScreenerKind::None), &EngineKind::Exact)?;
    println!(
        "exact baseline: {:.2}s total ({} lambda values)",
        base.total_secs,
        base.records.len()
    );
    let dpc = run_path(&ds, &exp_opts(grid, ScreenerKind::Dpc), &EngineKind::Exact)?;
    println!(
        "exact DPC+solver: {:.2}s total (screen {:.3}s)",
        dpc.total_secs, dpc.screen_secs
    );

    let row = speedup_row(&base, &dpc);
    println!("\n{}", report::render_table1(&[row]));

    let curve = mean_rejection_curve(&[dpc.clone()]);
    println!("{}", report::render_rejection_curve("e2e rejection curve (exact)", &curve));

    // ---- AOT engine (PJRT) if artifacts are present ----
    let dir = PathBuf::from("artifacts");
    if dir.join("manifest.tsv").exists() {
        match AotEngine::new(&dir) {
            Ok(engine) => {
                let mut opts = exp_opts(grid, ScreenerKind::Dpc);
                opts.aot_margin = 1e-3; // f32 engine float-safety margin
                match run_path(&ds, &opts, &EngineKind::Aot(&engine)) {
                    Ok(aot) => {
                        println!(
                            "AOT engine (PJRT, {cfg_note} config): {:.2}s total \
                             (screen {:.3}s), mean rejection {:.4}",
                            aot.total_secs,
                            aot.screen_secs,
                            aot.mean_rejection_ratio()
                        );
                        // cross-engine agreement on the path objectives
                        let mut max_rel = 0.0f64;
                        for (a, b) in aot.records.iter().zip(&dpc.records) {
                            let rel = (a.obj - b.obj).abs() / b.obj.abs().max(1.0);
                            max_rel = max_rel.max(rel);
                        }
                        println!("max relative objective deviation AOT vs exact: {max_rel:.2e}");
                    }
                    Err(e) => println!("AOT path skipped: {e}"),
                }
            }
            Err(e) => println!("AOT engine unavailable: {e}"),
        }
    } else {
        println!("(no artifacts/ — run `make artifacts` to exercise the AOT engine)");
    }

    println!("\nheadline: speedup {:.1}x, mean rejection {:.4}",
        base.total_secs / dpc.total_secs.max(1e-9),
        dpc.mean_rejection_ratio());
    Ok(())
}
