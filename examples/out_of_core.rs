//! Out-of-core screening end to end: shard a synthetic dataset to disk,
//! then run a screened λ-path over it **without ever loading the matrix**
//! (DESIGN.md §10).
//!
//!     cargo run --release --example out_of_core
//!
//! The walkthrough below is the screen-before-load story in miniature:
//!
//! 1. generate a dataset in RAM (a stand-in for data you could *not*
//!    generate in RAM — the pipeline below never relies on it again);
//! 2. convert it to the sharded MTD3 layout: fixed-width column blocks
//!    with per-block offsets and checksums (`repro shard` does the same
//!    from the command line);
//! 3. open the shard with a deliberately small block cache, so at any
//!    instant only a sliver of the matrix is resident;
//! 4. run the sequential-DPC path: every grid point streams the blocks
//!    through the screener, certifies most rows of W as zero, and
//!    materializes only the survivors for the solver;
//! 5. read the memory model off the run: peak materialized bytes vs the
//!    bytes a dense in-RAM load would have cost.

use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::coordinator::path::{run_path_sharded, PathOptions, ScreenerKind};
use mtfl_dpc::data::io::save_sharded;
use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::data::ShardedDataset;
use mtfl_dpc::solver::SolveOptions;

fn main() -> anyhow::Result<()> {
    // 1. A problem with many more features than the solver will ever see:
    //    4 tasks x 24 samples x 3000 features, 3% true support.
    let (ds, truth) = synthetic1(&SynthOptions {
        t: 4,
        n: 24,
        d: 3000,
        support_frac: 0.03,
        noise: 0.05,
        seed: 7,
    });
    println!(
        "dataset: T={} tasks, d={} features ({} truly active)",
        ds.t(),
        ds.d,
        truth.active.len()
    );

    // 2. Shard it: ~32 KiB column blocks, checksummed individually.
    let shard_path = std::env::temp_dir()
        .join(format!("mtfl_example_{}.mtd3", std::process::id()));
    let summary = save_sharded(&ds, &shard_path, 32 << 10)?;
    println!(
        "sharded into {} blocks of {} columns ({:.2} MiB payload on disk)",
        summary.blocks,
        summary.block_cols,
        summary.payload_bytes as f64 / (1024.0 * 1024.0)
    );
    drop(ds); // from here on, the matrix exists only on disk

    // 3. Open with a 1 MiB block cache — a stand-in for "d >> RAM".
    let sh = ShardedDataset::open_with_cache(&shard_path, 1 << 20)?;

    // 4. Screen-before-load λ-path: sequential DPC streams each grid
    //    point's ball over the blocks; the solver sees only survivors.
    let opts = PathOptions {
        ratios: lambda_grid(8, 1.0, 0.1),
        solve: SolveOptions { tol: 1e-6, ..Default::default() },
        screener: ScreenerKind::Dpc,
        ..Default::default()
    };
    let res = run_path_sharded(&sh, &opts)?;

    println!("\n   ratio     kept   materialized (% of dense)");
    for (rec, &mb) in res.path.records.iter().zip(&res.materialized_bytes) {
        println!(
            "   {:.4}  {:>6}   {:>10} B ({:>5.2}%)",
            rec.ratio,
            rec.kept,
            mb,
            100.0 * mb as f64 / res.dense_bytes as f64
        );
    }

    // 5. The memory model in one line: peak RSS ~ active set, not d.
    println!(
        "\npeak materialized {:.3} MiB vs {:.3} MiB dense ({:.1}%), \
         {:.2} MiB streamed from disk over {} block loads",
        res.peak_materialized_bytes as f64 / (1024.0 * 1024.0),
        res.dense_bytes as f64 / (1024.0 * 1024.0),
        100.0 * res.peak_materialized_bytes as f64 / res.dense_bytes as f64,
        res.bytes_read as f64 / (1024.0 * 1024.0),
        res.blocks_loaded
    );
    assert!(res.peak_materialized_bytes < res.dense_bytes as usize / 2);

    // the screen was safe: every truly active feature survived to the end
    let grid_len = res.path.records.len();
    let last_active: Vec<usize> = res
        .path
        .last_w
        .chunks_exact(sh.t())
        .enumerate()
        .filter_map(|(l, row)| (row.iter().any(|&v| v != 0.0)).then_some(l))
        .collect();
    let recovered = truth.active.iter().filter(|l| last_active.contains(l)).count();
    println!(
        "active set at the smallest lambda: {} features ({recovered} of the true \
         support) across a {grid_len}-point grid",
        last_active.len()
    );

    std::fs::remove_file(&shard_path).ok();
    println!("OK");
    Ok(())
}
