//! Why the *sequential* rule (Corollary 9) matters: compare
//!   (a) sequential DPC + warm starts (the paper's pipeline),
//!   (b) one-shot DPC from λ_max only,
//!   (c) no screening,
//! on the same grid, reporting per-λ kept-feature counts and total time.
//!
//!     cargo run --release --example warm_vs_cold

use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::coordinator::path::{run_path, EngineKind, PathOptions, ScreenerKind};
use mtfl_dpc::data::synthetic::{synthetic2, SynthOptions};
use mtfl_dpc::solver::SolveOptions;

fn main() -> anyhow::Result<()> {
    let (ds, _) =
        synthetic2(&SynthOptions { t: 10, n: 40, d: 1500, seed: 23, ..Default::default() });
    println!("dataset: {} (T={}, N=40, d={})\n", ds.name, ds.t(), ds.d);

    let mk = |k| PathOptions {
        ratios: lambda_grid(30, 1.0, 0.01),
        solve: SolveOptions { tol: 1e-6, ..Default::default() },
        screener: k,
        ..Default::default()
    };

    let seq = run_path(&ds, &mk(ScreenerKind::Dpc), &EngineKind::Exact)?;
    let one = run_path(&ds, &mk(ScreenerKind::DpcOneShot), &EngineKind::Exact)?;
    let base = run_path(&ds, &mk(ScreenerKind::None), &EngineKind::Exact)?;

    println!(" lambda/lmax    kept(seq)   kept(one-shot)   (of {})", ds.d);
    for (s, o) in seq.records.iter().zip(&one.records).step_by(4) {
        println!("   {:8.4}   {:>9}   {:>13}", s.ratio, s.kept, o.kept);
    }

    println!("\n                       total      screen     mean-rejection");
    for (name, r) in [("sequential DPC", &seq), ("one-shot DPC", &one), ("no screening", &base)] {
        println!(
            "  {:<20} {:>7.2}s   {:>7.3}s       {:.4}",
            name,
            r.total_secs,
            r.screen_secs,
            r.mean_rejection_ratio()
        );
    }
    println!(
        "\nspeedup: sequential {:.1}x, one-shot {:.1}x",
        base.total_secs / seq.total_secs.max(1e-9),
        base.total_secs / one.total_secs.max(1e-9)
    );
    Ok(())
}
