//! Quickstart — the 60-second tour of the public API, narrated.
//!
//!     cargo run --release --example quickstart
//!
//! The model is the multi-task group Lasso with one data matrix per task
//! (problem (1) of the paper):
//!
//! ```text
//! min_W  Σ_t ½‖y_t − X_t w_t‖² + λ‖W‖₂,₁
//! ```
//!
//! The ℓ2,1 penalty zeroes entire *rows* of W — a feature is kept or
//! discarded for all tasks at once. DPC ("dual polytope projection for
//! multiple data matrices") is a *safe screening rule*: before solving at
//! λ, it certifies a set of rows to be exactly zero in the optimum and
//! deletes them. "Safe" is a theorem, not a heuristic — the reduced
//! problem has the identical solution.
//!
//! The walkthrough below runs the whole pipeline in RAM:
//!
//! 1. generate a small multi-task problem;
//! 2. compute λ_max, the smallest λ with W* = 0 (Theorem 1) — it anchors
//!    both the tuning grid and the first screening reference;
//! 3. walk a descending λ grid with *sequential* DPC (Corollary 9):
//!    screen at λ_{k+1} from the solution at λ_k, solve the compacted
//!    problem, move the reference, repeat;
//! 4. cross-check the screened solve against an unscreened solve;
//! 5. verify the screening certificate against the KKT conditions.
//!
//! This is exactly what `coordinator::run_path` automates (plus warm
//! starts, gap certification and observers); the point here is to show
//! the seams. **The same pipeline also runs without the dataset in RAM**:
//! `examples/out_of_core.rs` shards a dataset to disk and screens it
//! block-by-block before loading only the survivors (DESIGN.md §10).

use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::ops;
use mtfl_dpc::screening::dpc::{DpcScreener, DualRef};
use mtfl_dpc::solver::{fista, SolveOptions};

fn main() -> anyhow::Result<()> {
    // 1. A multi-task dataset: 5 tasks, 40 samples each, 500 shared
    //    features, 5% of them truly active across all tasks (the shared-
    //    support premise that makes multi-task screening worthwhile).
    let (ds, truth) = synthetic1(&SynthOptions {
        t: 5,
        n: 40,
        d: 500,
        support_frac: 0.05,
        noise: 0.01,
        seed: 42,
    });
    println!("dataset: T={} tasks, N=40 samples each, d={} features", ds.t(), ds.d);
    println!("true support: {} features", truth.active.len());

    // 2. λ_max — above it the solution is exactly zero (Theorem 1), and
    //    the dual optimum is known in closed form: θ* = y/λ_max. That
    //    free, *exact* reference is what one-shot DPC screens from.
    let (dref, lam_max) = DualRef::at_lambda_max(&ds);
    println!("lambda_max = {lam_max:.4}");

    // 3. Walk down a λ grid with sequential DPC. At each step the
    //    screener builds a ball that provably contains the dual optimum
    //    θ*(λ) (Theorem 5), maximizes each feature's score over it
    //    (Theorem 7), and rejects every feature whose max stays below 1
    //    (Theorem 8) — those rows of W are zero, guaranteed. The solver
    //    then runs on the compacted problem, and the *solved* primal
    //    becomes the next, tighter reference (Corollary 9). This is why
    //    DPC can afford a 100-point grid: the ball shrinks as it walks.
    let screener = DpcScreener::new(&ds);
    let t_count = ds.t();
    let mut dref_seq = dref;
    let mut outcome = screener.screen(&ds, &dref_seq, 0.7 * lam_max);
    let mut lam = 0.7 * lam_max;
    for &ratio in &[0.7, 0.55, 0.42, 0.3] {
        lam = ratio * lam_max;
        outcome = screener.screen(&ds, &dref_seq, lam);
        println!(
            "DPC at lambda/lambda_max={ratio}: rejected {}/{} (sequential, Cor. 9)",
            outcome.num_rejected(),
            ds.d
        );
        // solve the reduced problem, embed the solution at full size, and
        // move the dual reference to it — `DualRef::from_solution` stores
        // a duality-gap certificate alongside, so screening stays safe
        // even though the solve stopped at finite tolerance (DESIGN.md §9)
        let keep = outcome.kept_indices();
        let sol = fista(&ds.restrict(&keep), lam, None, &SolveOptions::default());
        let mut w_full = vec![0.0f64; ds.d * t_count];
        for (j, &l) in keep.iter().enumerate() {
            w_full[l * t_count..(l + 1) * t_count]
                .copy_from_slice(&sol.w[j * t_count..(j + 1) * t_count]);
        }
        dref_seq = DualRef::from_solution(&ds, lam, &w_full);
    }

    // 4. Solve once more on the final compacted problem and compare with
    //    the unscreened solve: identical objective — screening deleted
    //    only provably-zero rows, it never changed the optimum.
    let keep = outcome.kept_indices();
    let reduced = ds.restrict(&keep);
    let sol = fista(&reduced, lam, None, &SolveOptions::default());
    println!(
        "solved reduced problem (d={} -> {}): obj={:.5}, gap={:.2e}, {} iters",
        ds.d,
        reduced.d,
        sol.obj,
        sol.gap,
        sol.iters
    );
    let full = fista(&ds, lam, None, &SolveOptions::default());
    println!(
        "full problem objective: {:.5}  (difference {:.2e})",
        full.obj,
        (full.obj - sol.obj).abs()
    );

    let active = full.active_set(ds.t(), 1e-7);
    let recovered = truth.active.iter().filter(|l| active.contains(l)).count();
    println!("active set: {} features ({recovered} of the true support)", active.len());

    // 5. The KKT cross-check: at the optimum, every feature's dual score
    //    g_l(θ*) saturates 1 exactly on active rows and stays below 1 on
    //    inactive ones — so every *rejected* feature must score < 1.
    let g = ops::gscore(
        &ds,
        &ops::stacked_scale(&ops::residual(&ds, &full.w), -1.0 / lam),
    );
    let max_rejected_g =
        outcome.rejected.iter().zip(&g).filter(|(r, _)| **r).map(|(_, &v)| v).fold(0.0, f64::max);
    println!("max g_l(theta*) over rejected features: {max_rejected_g:.4} (< 1 = safe)");
    assert!(max_rejected_g < 1.0);
    println!("OK");
    Ok(())
}
