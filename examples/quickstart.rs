//! Quickstart: generate a small multi-task problem, compute λ_max, screen
//! with DPC at one λ, and solve — the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart

use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::ops;
use mtfl_dpc::screening::dpc::{DpcScreener, DualRef};
use mtfl_dpc::solver::{fista, SolveOptions};

fn main() -> anyhow::Result<()> {
    // 1. A multi-task dataset: 5 tasks, 40 samples each, 500 shared features.
    let (ds, truth) = synthetic1(&SynthOptions {
        t: 5,
        n: 40,
        d: 500,
        support_frac: 0.05,
        noise: 0.01,
        seed: 42,
    });
    println!("dataset: T={} tasks, N=40 samples each, d={} features", ds.t(), ds.d);
    println!("true support: {} features", truth.active.len());

    // 2. λ_max — above it the solution is exactly zero (Theorem 1).
    let (dref, lam_max) = DualRef::at_lambda_max(&ds);
    println!("lambda_max = {lam_max:.4}");

    // 3. Screen at λ = 0.7 λ_max with DPC (safe: rejected features are
    //    *guaranteed* zero rows of the solution), solve the reduced
    //    problem, then screen *sequentially* (Corollary 9) at λ = 0.3 λ_max
    //    from that solution — the reference tightens as λ decreases.
    let screener = DpcScreener::new(&ds);
    let t_count = ds.t();
    let mut dref_seq = dref;
    let mut outcome = screener.screen(&ds, &dref_seq, 0.7 * lam_max);
    let mut lam = 0.7 * lam_max;
    for &ratio in &[0.7, 0.55, 0.42, 0.3] {
        lam = ratio * lam_max;
        outcome = screener.screen(&ds, &dref_seq, lam);
        println!(
            "DPC at lambda/lambda_max={ratio}: rejected {}/{} (sequential, Cor. 9)",
            outcome.num_rejected(),
            ds.d
        );
        // solve the reduced problem, embed, and move the dual reference
        let keep = outcome.kept_indices();
        let sol = fista(&ds.restrict(&keep), lam, None, &SolveOptions::default());
        let mut w_full = vec![0.0f64; ds.d * t_count];
        for (j, &l) in keep.iter().enumerate() {
            w_full[l * t_count..(l + 1) * t_count]
                .copy_from_slice(&sol.w[j * t_count..(j + 1) * t_count]);
        }
        dref_seq = DualRef::from_solution(&ds, lam, &w_full);
    }

    // 4. Solve on the compacted problem; embed the solution back.
    let keep = outcome.kept_indices();
    let reduced = ds.restrict(&keep);
    let sol = fista(&reduced, lam, None, &SolveOptions::default());
    println!(
        "solved reduced problem (d={} -> {}): obj={:.5}, gap={:.2e}, {} iters",
        ds.d,
        reduced.d,
        sol.obj,
        sol.gap,
        sol.iters
    );

    // 5. Verify against the full solve: identical objective.
    let full = fista(&ds, lam, None, &SolveOptions::default());
    println!(
        "full problem objective: {:.5}  (difference {:.2e})",
        full.obj,
        (full.obj - sol.obj).abs()
    );

    let active = full.active_set(ds.t(), 1e-7);
    let recovered = truth.active.iter().filter(|l| active.contains(l)).count();
    println!("active set: {} features ({recovered} of the true support)", active.len());

    // the screening certificate must agree with the solution
    let g = ops::gscore(
        &ds,
        &ops::stacked_scale(&ops::residual(&ds, &full.w), -1.0 / lam),
    );
    let max_rejected_g =
        outcome.rejected.iter().zip(&g).filter(|(r, _)| **r).map(|(_, &v)| v).fold(0.0, f64::max);
    println!("max g_l(theta*) over rejected features: {max_rejected_g:.4} (< 1 = safe)");
    assert!(max_rejected_g < 1.0);
    println!("OK");
    Ok(())
}
