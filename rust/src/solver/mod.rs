//! Exact (f64) solvers for the MTFL problem (1):
//!
//! * [`fista`] — accelerated proximal gradient with the ℓ2,1 prox and a
//!   duality-gap stopping rule (the algorithm family behind SLEP's
//!   `mtLeastR`, the paper's solver);
//! * [`bcd`] — cyclic block-coordinate descent over feature rows (an
//!   independent algorithm used to cross-validate FISTA and as a second
//!   baseline for Table 1).
//!
//! Both support warm starts — essential for the sequential λ-path.

pub mod bcd;
pub mod fista;
pub mod prox;

pub use bcd::bcd;
pub use fista::{fista, lipschitz};

/// Options shared by the solvers.
#[derive(Debug, Clone)]
pub struct SolveOptions {
    /// maximum iterations (FISTA steps or BCD sweeps)
    pub max_iters: usize,
    /// stop when duality gap <= tol * max(1, |obj|)
    pub tol: f64,
    /// evaluate the (expensive) duality gap every this many iterations
    pub check_every: usize,
    /// power-iteration count for the Lipschitz estimate
    pub power_iters: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions { max_iters: 20_000, tol: 1e-9, check_every: 25, power_iters: 60 }
    }
}

impl SolveOptions {
    /// Loose profile for benchmarking throughput (paper-style runs).
    pub fn loose() -> Self {
        SolveOptions { tol: 1e-6, ..Default::default() }
    }

    /// Tight profile for safety verification.
    pub fn tight() -> Self {
        SolveOptions { tol: 1e-11, max_iters: 200_000, ..Default::default() }
    }
}

/// Solver output.
#[derive(Debug, Clone)]
pub struct SolveResult {
    /// row-major (d x T)
    pub w: Vec<f64>,
    pub obj: f64,
    pub gap: f64,
    pub iters: usize,
    pub converged: bool,
    /// estimated Lipschitz constant (FISTA only; 0 for BCD)
    pub lipschitz: f64,
}

impl SolveResult {
    /// Row norms ‖w^l‖ — the quantity screening certifies to be zero.
    pub fn row_norms(&self, t_count: usize) -> Vec<f64> {
        self.w
            .chunks_exact(t_count)
            .map(|r| r.iter().map(|v| v * v).sum::<f64>().sqrt())
            .collect()
    }

    /// Indices of rows with norm > tol (the active set).
    pub fn active_set(&self, t_count: usize, tol: f64) -> Vec<usize> {
        self.row_norms(t_count)
            .iter()
            .enumerate()
            .filter_map(|(l, &n)| (n > tol).then_some(l))
            .collect()
    }
}
