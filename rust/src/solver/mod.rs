//! Exact (f64) solvers for the MTFL problem (1):
//!
//! * [`fista`] — accelerated proximal gradient with a duality-gap
//!   stopping rule (the algorithm family behind SLEP's `mtLeastR`, the
//!   paper's solver). Generic over the penalty seam: the prox, gap, and
//!   dynamic-screen steps all go through
//!   [`SolveOptions::penalty`](crate::penalty::Penalty) (DESIGN.md §14).
//! * [`bcd`] — cyclic block-coordinate descent over feature rows (an
//!   independent algorithm used to cross-validate FISTA and as a second
//!   baseline for Table 1). ℓ2,1-only: its per-row secular solve is the
//!   exact minimizer for the ℓ2,1 row subproblem and nothing else, so it
//!   asserts `penalty.supports_row_secular()` instead of silently
//!   solving the wrong problem.
//!
//! Both support warm starts — essential for the sequential λ-path.

pub mod bcd;
pub mod fista;
pub mod prox;

pub use bcd::bcd;
pub use fista::{fista, lipschitz};

use crate::data::Dataset;

/// Working-set bookkeeping for dynamic GAP-safe screening (DESIGN.md §9),
/// shared by both solvers: the live problem is either the caller's full
/// dataset or a compacted copy, and `keep` maps compacted rows back to the
/// full feature space.
pub(crate) struct DynamicSet {
    d_full: usize,
    t_count: usize,
    owned: Option<Dataset>,
    keep: Vec<usize>,
}

impl DynamicSet {
    pub(crate) fn new(d_full: usize, t_count: usize) -> Self {
        DynamicSet { d_full, t_count, owned: None, keep: Vec::new() }
    }

    /// The dataset iterations should run on.
    pub(crate) fn live<'a>(&'a self, full: &'a Dataset) -> &'a Dataset {
        self.owned.as_ref().unwrap_or(full)
    }

    /// Copy the kept rows of a (d_live × T) row-major buffer.
    pub(crate) fn compact_rows(&self, buf: &[f64], kept: &[usize]) -> Vec<f64> {
        let t = self.t_count;
        let mut out = Vec::with_capacity(kept.len() * t);
        for &j in kept {
            out.extend_from_slice(&buf[j * t..(j + 1) * t]);
        }
        out
    }

    /// Adopt a compacted dataset, composing the row map.
    pub(crate) fn shrink_to(&mut self, ds_small: Dataset, kept: Vec<usize>) {
        self.keep = match self.owned.is_some() {
            true => kept.iter().map(|&j| self.keep[j]).collect(),
            false => kept,
        };
        self.owned = Some(ds_small);
    }

    /// Scatter the live solution back to full size (rows dropped along the
    /// way are certified zero at the optimum).
    pub(crate) fn scatter(&self, w: Vec<f64>) -> Vec<f64> {
        if self.owned.is_none() {
            return w;
        }
        let t = self.t_count;
        let mut full = vec![0.0f64; self.d_full * t];
        for (j, &l) in self.keep.iter().enumerate() {
            full[l * t..(l + 1) * t].copy_from_slice(&w[j * t..(j + 1) * t]);
        }
        full
    }
}

/// Options shared by the solvers.
#[derive(Debug, Clone)]
pub struct SolveOptions {
    /// maximum iterations (FISTA steps or BCD sweeps)
    pub max_iters: usize,
    /// stop when duality gap <= tol * max(1, |obj|)
    pub tol: f64,
    /// evaluate the (expensive) duality gap every this many iterations
    /// (FISTA steps / BCD sweeps — both solvers honor the configured
    /// cadence identically, clamped only to ≥ 1)
    pub check_every: usize,
    /// power-iteration count for the Lipschitz estimate
    pub power_iters: usize,
    /// GAP-safe *dynamic* screening: every this many epochs (FISTA
    /// iterations / BCD sweeps) re-screen the live problem against the
    /// current duality-gap ball and compact the working set mid-solve;
    /// rejected rows are certified zero at the optimum and restored as
    /// zeros on exit. 0 disables (DESIGN.md §9).
    pub dynamic_every: usize,
    /// The row-structured penalty Ω of the objective (DESIGN.md §14).
    /// Part of the *problem definition*, carried here because every
    /// consumer of a `SolveOptions` — solver, path runner, CV, stability,
    /// experiments — needs the same penalty for its prox / gap / screen /
    /// λ_max calls to be mutually consistent. Defaults to the paper's
    /// ℓ2,1 norm, which reproduces the pre-seam behavior bit-for-bit.
    pub penalty: crate::penalty::PenaltyKind,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            max_iters: 20_000,
            tol: 1e-9,
            check_every: 25,
            power_iters: 60,
            dynamic_every: 0,
            penalty: crate::penalty::PenaltyKind::L21,
        }
    }
}

impl SolveOptions {
    /// Loose profile for benchmarking throughput (paper-style runs).
    pub fn loose() -> Self {
        SolveOptions { tol: 1e-6, ..Default::default() }
    }

    /// Tight profile for safety verification.
    pub fn tight() -> Self {
        SolveOptions { tol: 1e-11, max_iters: 200_000, ..Default::default() }
    }
}

/// Solver output.
#[derive(Debug, Clone)]
pub struct SolveResult {
    /// row-major (d x T) — always full problem size, with zeros on any
    /// rows dynamic screening removed mid-solve
    pub w: Vec<f64>,
    /// primal objective at `w`
    pub obj: f64,
    /// duality gap at `w` (the stopping certificate)
    pub gap: f64,
    /// iterations run (FISTA steps / BCD sweeps)
    pub iters: usize,
    /// whether the gap test passed before `max_iters`
    pub converged: bool,
    /// estimated Lipschitz constant (FISTA only; 0 for BCD)
    pub lipschitz: f64,
    /// total column-sweep operations, uniformly weighted: every epoch is
    /// charged 2× the live feature count (FISTA: forward + corr sweep;
    /// BCD: dot + axpy per column), and so is each duality-gap evaluation;
    /// a dynamic score sweep adds 1×. The work metric dynamic screening
    /// must shrink *net of its own overhead* (BENCH_gap)
    pub col_ops: usize,
}

impl SolveResult {
    /// Row norms ‖w^l‖ — the quantity screening certifies to be zero.
    /// Same contract kernel as the prox/`l21_norm` row passes.
    pub fn row_norms(&self, t_count: usize) -> Vec<f64> {
        self.w.chunks_exact(t_count).map(crate::linalg::nrm2_f64).collect()
    }

    /// Indices of rows with norm > tol (the active set).
    pub fn active_set(&self, t_count: usize, tol: f64) -> Vec<usize> {
        self.row_norms(t_count)
            .iter()
            .enumerate()
            .filter_map(|(l, &n)| (n > tol).then_some(l))
            .collect()
    }
}
