//! FISTA (accelerated proximal gradient) for the MTFL problem (1).
//!
//! Step size 1/L with L = max_t σ_max(X_t)² from power iteration: the
//! smooth part Σ_t ½‖X_t w_t − y_t‖² has a block-diagonal Hessian
//! blockdiag(X_tᵀX_t), so its Lipschitz constant is the max over tasks.
//! Stopping: duality gap against the scaled-residual feasible point
//! (exactly the certificate DPC's sequential rule consumes).

use super::{prox::prox21_inplace, SolveOptions, SolveResult};
use crate::data::Dataset;
use crate::ops;
use crate::util::Pcg64;

/// L = max_t σ_max(X_t)² via per-task power iteration (f64 accumulation,
/// backend-agnostic through [`crate::linalg::ColRef`]).
pub fn lipschitz(ds: &Dataset, iters: usize) -> f64 {
    let per_task = crate::util::scoped_pool((0..ds.t()).collect::<Vec<_>>(), usize::MAX, |ti| {
        let task = &ds.tasks[ti];
        let n = task.n;
        let mut rng = Pcg64::with_stream(0x11b5, ti as u64);
        let mut v: Vec<f64> = (0..ds.d).map(|_| rng.normal()).collect();
        let mut xv = vec![0.0f64; n];
        let mut sigma2 = 0.0f64;
        for _ in 0..iters {
            // xv = X v
            xv.fill(0.0);
            for l in 0..ds.d {
                let vl = v[l];
                if vl != 0.0 {
                    task.col(l).axpy_into(vl, &mut xv);
                }
            }
            // v = X^T xv
            for l in 0..ds.d {
                v[l] = task.col(l).dot_mixed(&xv);
            }
            let norm = crate::linalg::nrm2_f64(&v).max(1e-300);
            sigma2 = norm; // v = X^T X v_prev with ||v_prev|| = 1 => ||v|| -> sigma^2
            for vi in v.iter_mut() {
                *vi /= norm;
            }
        }
        sigma2
    });
    per_task.into_iter().fold(0.0f64, f64::max) * 1.0001 // small safety factor
}

/// Solve problem (1) at `lam`, warm-started from `w0` if given.
pub fn fista(ds: &Dataset, lam: f64, w0: Option<&[f64]>, opts: &SolveOptions) -> SolveResult {
    let t_count = ds.t();
    let dt = ds.d * t_count;
    let lcap = lipschitz(ds, opts.power_iters).max(1e-12);
    let step = 1.0 / lcap;
    let kappa = lam / lcap;

    let mut w: Vec<f64> = match w0 {
        Some(w0) => {
            assert_eq!(w0.len(), dt, "warm start has wrong shape");
            w0.to_vec()
        }
        None => vec![0.0; dt],
    };
    let mut v = w.clone();
    let mut t = 1.0f64;

    let mut obj = f64::INFINITY;
    let mut gap = f64::INFINITY;
    let mut iters = 0usize;
    let mut converged = false;

    for it in 1..=opts.max_iters {
        iters = it;
        // gradient at the momentum point V
        let r = ops::residual(ds, &v);
        let g = ops::task_corr(ds, &r); // (d x T)
        // W_new = prox(V - G/L)
        let mut w_new = vec![0.0f64; dt];
        for i in 0..dt {
            w_new[i] = v[i] - step * g[i];
        }
        prox21_inplace(&mut w_new, t_count, kappa);

        // O'Donoghue–Candès adaptive restart: when the momentum direction
        // opposes the latest step (⟨v − w_new, w_new − w⟩ > 0), drop the
        // momentum. Cuts small-λ iteration counts by ~2-5x (EXPERIMENTS.md
        // §Perf entry 2).
        let mut osc = 0.0f64;
        for i in 0..dt {
            osc += (v[i] - w_new[i]) * (w_new[i] - w[i]);
        }
        if osc > 0.0 {
            t = 1.0;
        }

        let t_new = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
        let momentum = (t - 1.0) / t_new;
        for i in 0..dt {
            v[i] = w_new[i] + momentum * (w_new[i] - w[i]);
        }
        w = w_new;
        t = t_new;

        if it % opts.check_every == 0 || it == opts.max_iters {
            let (o, gp, _) = ops::duality_gap(ds, &w, lam);
            obj = o;
            gap = gp;
            if gap <= opts.tol * obj.abs().max(1.0) {
                converged = true;
                break;
            }
        }
    }

    if !obj.is_finite() {
        let (o, gp, _) = ops::duality_gap(ds, &w, lam);
        obj = o;
        gap = gp;
    }

    SolveResult { w, obj, gap, iters, converged, lipschitz: lcap }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, SynthOptions};

    fn problem() -> Dataset {
        synthetic1(&SynthOptions { t: 3, n: 12, d: 30, seed: 8, ..Default::default() }).0
    }

    #[test]
    fn lipschitz_upper_bounds_columns() {
        // sigma_max^2 >= max column norm^2
        let ds = problem();
        let lcap = lipschitz(&ds, 60);
        let b2 = ds.col_sqnorms();
        let maxcol = b2.iter().cloned().fold(0.0f64, f64::max);
        assert!(lcap >= maxcol * 0.999, "L={lcap} maxcol={maxcol}");
    }

    #[test]
    fn converges_to_small_gap() {
        let ds = problem();
        let (lmax, _, _) = ops::lambda_max(&ds);
        let res = fista(&ds, 0.3 * lmax, None, &SolveOptions::default());
        assert!(res.converged, "gap={} after {} iters", res.gap, res.iters);
        assert!(res.gap <= 1e-9 * res.obj.max(1.0));
    }

    #[test]
    fn zero_solution_above_lmax() {
        let ds = problem();
        let (lmax, _, _) = ops::lambda_max(&ds);
        let res = fista(&ds, lmax * 1.001, None, &SolveOptions::default());
        assert!(res.w.iter().all(|&v| v == 0.0), "W must be exactly 0 at lam>lmax");
    }

    #[test]
    fn warm_start_converges_faster() {
        let ds = problem();
        let (lmax, _, _) = ops::lambda_max(&ds);
        let r1 = fista(&ds, 0.5 * lmax, None, &SolveOptions::default());
        let cold = fista(&ds, 0.45 * lmax, None, &SolveOptions::default());
        let warm = fista(&ds, 0.45 * lmax, Some(&r1.w), &SolveOptions::default());
        assert!(warm.iters <= cold.iters, "warm {} vs cold {}", warm.iters, cold.iters);
        assert!((warm.obj - cold.obj).abs() <= 1e-6 * cold.obj.abs().max(1.0));
    }

    #[test]
    fn kkt_active_rows_saturate_constraint() {
        // at the optimum, g_l(theta*) = 1 for active rows, <= 1 for all
        let ds = problem();
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.4 * lmax;
        let res = fista(&ds, lam, None, &SolveOptions::tight());
        let theta = ops::stacked_scale(&ops::residual(&ds, &res.w), -1.0 / lam);
        let g = ops::gscore(&ds, &theta);
        let active = res.active_set(ds.t(), 1e-8);
        assert!(!active.is_empty());
        for &l in &active {
            assert!((g[l] - 1.0).abs() < 1e-4, "g[{l}]={} for active row", g[l]);
        }
        for (l, &gl) in g.iter().enumerate() {
            assert!(gl <= 1.0 + 1e-4, "g[{l}]={gl} violates dual feasibility");
        }
    }

    #[test]
    fn objective_matches_bruteforce_eval() {
        let ds = problem();
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.5 * lmax;
        let res = fista(&ds, lam, None, &SolveOptions::default());
        let direct = ops::primal_obj(&ds, &res.w, lam);
        assert!((res.obj - direct).abs() < 1e-9 * direct.max(1.0));
    }
}
