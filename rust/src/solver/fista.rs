//! FISTA (accelerated proximal gradient) for the MTFL problem (1).
//!
//! Step size 1/L with L = max_t σ_max(X_t)² from power iteration: the
//! smooth part Σ_t ½‖X_t w_t − y_t‖² has a block-diagonal Hessian
//! blockdiag(X_tᵀX_t), so its Lipschitz constant is the max over tasks.
//! Stopping: duality gap against the scaled-residual feasible point
//! (exactly the certificate DPC's sequential rule consumes).
//!
//! Dynamic GAP-safe screening (`SolveOptions::dynamic_every`, DESIGN.md
//! §9): every K iterations the solver re-screens the live problem against
//! the gap ball of its own stopping certificate and *compacts the working
//! set mid-solve* — rows certified inactive stop paying for sweeps
//! immediately instead of at the next λ. Dropping rows only shrinks the
//! spectrum, so the original step size stays valid; the momentum sequence
//! restarts at each compaction and rejected rows are restored as zeros on
//! exit.

use super::{DynamicSet, SolveOptions, SolveResult};
use crate::data::Dataset;
use crate::ops;
use crate::penalty::Penalty;
use crate::screening::gap;
use crate::util::Pcg64;

/// L = max_t σ_max(X_t)² via per-task power iteration (f64 accumulation,
/// backend-agnostic through [`crate::linalg::ColRef`]). The per-task
/// fan-out runs on the persistent executor: called from inside a CV fold
/// or another parallel region it inlines on its worker (nested-safe,
/// DESIGN.md §11), and problems under the shared serial cutoff skip the
/// pool — the power sweeps cost `iters · sweep_work` touches.
pub fn lipschitz(ds: &Dataset, iters: usize) -> f64 {
    // the gate weighs the whole power run (iters sweeps), not one sweep
    let work = ds.sweep_work().saturating_mul(iters.max(1));
    let workers = if crate::util::serial_below(work) { 1 } else { usize::MAX };
    let per_task = crate::util::scoped_pool((0..ds.t()).collect::<Vec<_>>(), workers, |ti| {
        let task = &ds.tasks[ti];
        let n = task.n;
        let mut rng = Pcg64::with_stream(0x11b5, ti as u64);
        let mut v: Vec<f64> = (0..ds.d).map(|_| rng.normal()).collect();
        let mut xv = vec![0.0f64; n];
        let mut active: Vec<(usize, f64)> = Vec::with_capacity(ds.d);
        let mut sigma2 = 0.0f64;
        for _ in 0..iters {
            // xv = X v — blocked multi-column axpy panel (ops::axpy_panel)
            xv.fill(0.0);
            active.clear();
            active.extend(
                v.iter().enumerate().filter_map(|(l, &vl)| (vl != 0.0).then_some((l, vl))),
            );
            crate::ops::axpy_panel(task, &active, &mut xv);
            // v = X^T xv — blocked correlation panel (stride-1 output)
            v.fill(0.0);
            crate::ops::corr_panel(task, 0, ds.d, &xv, &mut v, 1);
            let norm = crate::linalg::nrm2_f64(&v).max(1e-300);
            sigma2 = norm; // v = X^T X v_prev with ||v_prev|| = 1 => ||v|| -> sigma^2
            for vi in v.iter_mut() {
                *vi /= norm;
            }
        }
        sigma2
    });
    per_task.into_iter().fold(0.0f64, f64::max) * 1.0001 // small safety factor
}

/// Solve the generalized problem (1) at `lam`, warm-started from `w0` if
/// given. The penalty comes from `opts.penalty` (DESIGN.md §14): the
/// prox step, the duality-gap certificate, and the dynamic re-screen all
/// use the same seam instance, so they stay mutually consistent for any
/// penalty. With the default ℓ2,1 penalty every call delegates to the
/// pre-seam kernels and the iterate sequence is bit-identical to before.
pub fn fista(ds: &Dataset, lam: f64, w0: Option<&[f64]>, opts: &SolveOptions) -> SolveResult {
    let pen: &dyn Penalty = &opts.penalty;
    let t_count = ds.t();
    let d_full = ds.d;
    let lcap = lipschitz(ds, opts.power_iters).max(1e-12);
    let step = 1.0 / lcap;
    let kappa = lam / lcap;

    let mut w: Vec<f64> = match w0 {
        Some(w0) => {
            assert_eq!(w0.len(), d_full * t_count, "warm start has wrong shape");
            w0.to_vec()
        }
        None => vec![0.0; d_full * t_count],
    };
    let mut v = w.clone();
    // reusable iterate buffer: the prox output is built here and swapped
    // into `w`, so the hot loop allocates nothing per iteration
    let mut w_buf: Vec<f64> = Vec::with_capacity(w.len());
    let mut t = 1.0f64;

    let mut ws = DynamicSet::new(d_full, t_count);
    let mut b2: Option<Vec<f64>> = None; // live col_sqnorms, built lazily

    let mut obj = f64::INFINITY;
    let mut gap = f64::INFINITY;
    let mut iters = 0usize;
    let mut converged = false;
    let mut col_ops = 0usize;

    for it in 1..=opts.max_iters {
        iters = it;
        let mut shrink: Option<(Dataset, Vec<usize>)> = None;
        {
            let dsc = ws.live(ds);
            let dtc = dsc.d * t_count;
            col_ops += 2 * dsc.d; // one iteration = forward pass + corr sweep
            // gradient at the momentum point V
            let r = ops::residual(dsc, &v);
            let g = ops::task_corr(dsc, &r); // (d x T)
            // W_new = prox(V - G/L), built in the reusable buffer via the
            // elementwise contract kernel
            w_buf.resize(dtc, 0.0);
            crate::linalg::scale_add(&v, -step, &g, &mut w_buf);
            pen.prox_inplace(&mut w_buf, t_count, kappa);

            // O'Donoghue–Candès adaptive restart: when the momentum
            // direction opposes the latest step (⟨v − w_new, w_new − w⟩ >
            // 0), drop the momentum. Cuts small-λ iteration counts by
            // ~2-5x (EXPERIMENTS.md §Perf entry 2).
            let mut osc = 0.0f64;
            for i in 0..dtc {
                // repro-lint: allow(kernel-reduction): restart heuristic — only the sign of osc matters, serial order pinned
                osc += (v[i] - w_buf[i]) * (w_buf[i] - w[i]);
            }
            if osc > 0.0 {
                t = 1.0;
            }

            let t_new = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
            let momentum = (t - 1.0) / t_new;
            for i in 0..dtc {
                v[i] = w_buf[i] + momentum * (w_buf[i] - w[i]);
            }
            // w <- w_new; the displaced iterate becomes next round's buffer
            std::mem::swap(&mut w, &mut w_buf);
            t = t_new;

            let due_check = it % opts.check_every.max(1) == 0 || it == opts.max_iters;
            let due_screen = opts.dynamic_every > 0 && it % opts.dynamic_every == 0 && dsc.d > 1;
            if due_check || due_screen {
                // the gap evaluation costs a forward pass + a corr sweep
                col_ops += 2 * dsc.d;
                let (o, gp, theta) = ops::duality_gap_for(dsc, &w, lam, pen);
                obj = o;
                gap = gp;
                if gap <= opts.tol * obj.abs().max(1.0) {
                    converged = true;
                } else if due_screen {
                    col_ops += dsc.d; // and so is the score sweep
                    let b2c = b2.get_or_insert_with(|| dsc.col_sqnorms());
                    if let Some(kept) = gap::dynamic_keep_for(dsc, b2c, &theta, gap, lam, pen) {
                        if !kept.is_empty() {
                            shrink = Some((dsc.restrict(&kept), kept));
                        }
                    }
                }
            }
        }
        if converged {
            break;
        }
        if let Some((ds_small, kept)) = shrink {
            w = ws.compact_rows(&w, &kept);
            v = w.clone(); // momentum restart on the compacted problem
            t = 1.0;
            if let Some(b2v) = b2.as_mut() {
                *b2v = ws.compact_rows(b2v, &kept);
            }
            ws.shrink_to(ds_small, kept);
        }
    }

    if !obj.is_finite() {
        let (o, gp, _) = ops::duality_gap_for(ws.live(ds), &w, lam, pen);
        obj = o;
        gap = gp;
    }

    let w = ws.scatter(w);
    SolveResult { w, obj, gap, iters, converged, lipschitz: lcap, col_ops }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, SynthOptions};

    fn problem() -> Dataset {
        synthetic1(&SynthOptions { t: 3, n: 12, d: 30, seed: 8, ..Default::default() }).0
    }

    #[test]
    fn lipschitz_upper_bounds_columns() {
        // sigma_max^2 >= max column norm^2
        let ds = problem();
        let lcap = lipschitz(&ds, 60);
        let b2 = ds.col_sqnorms();
        let maxcol = b2.iter().cloned().fold(0.0f64, f64::max);
        assert!(lcap >= maxcol * 0.999, "L={lcap} maxcol={maxcol}");
    }

    #[test]
    fn converges_to_small_gap() {
        let ds = problem();
        let (lmax, _, _) = ops::lambda_max(&ds);
        let res = fista(&ds, 0.3 * lmax, None, &SolveOptions::default());
        assert!(res.converged, "gap={} after {} iters", res.gap, res.iters);
        assert!(res.gap <= 1e-9 * res.obj.max(1.0));
    }

    #[test]
    fn zero_solution_above_lmax() {
        let ds = problem();
        let (lmax, _, _) = ops::lambda_max(&ds);
        let res = fista(&ds, lmax * 1.001, None, &SolveOptions::default());
        assert!(res.w.iter().all(|&v| v == 0.0), "W must be exactly 0 at lam>lmax");
    }

    #[test]
    fn warm_start_converges_faster() {
        let ds = problem();
        let (lmax, _, _) = ops::lambda_max(&ds);
        let r1 = fista(&ds, 0.5 * lmax, None, &SolveOptions::default());
        let cold = fista(&ds, 0.45 * lmax, None, &SolveOptions::default());
        let warm = fista(&ds, 0.45 * lmax, Some(&r1.w), &SolveOptions::default());
        assert!(warm.iters <= cold.iters, "warm {} vs cold {}", warm.iters, cold.iters);
        assert!((warm.obj - cold.obj).abs() <= 1e-6 * cold.obj.abs().max(1.0));
    }

    #[test]
    fn kkt_active_rows_saturate_constraint() {
        // at the optimum, g_l(theta*) = 1 for active rows, <= 1 for all
        let ds = problem();
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.4 * lmax;
        let res = fista(&ds, lam, None, &SolveOptions::tight());
        let theta = ops::stacked_scale(&ops::residual(&ds, &res.w), -1.0 / lam);
        let g = ops::gscore(&ds, &theta);
        let active = res.active_set(ds.t(), 1e-8);
        assert!(!active.is_empty());
        for &l in &active {
            assert!((g[l] - 1.0).abs() < 1e-4, "g[{l}]={} for active row", g[l]);
        }
        for (l, &gl) in g.iter().enumerate() {
            assert!(gl <= 1.0 + 1e-4, "g[{l}]={gl} violates dual feasibility");
        }
    }

    #[test]
    fn objective_matches_bruteforce_eval() {
        let ds = problem();
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.5 * lmax;
        let res = fista(&ds, lam, None, &SolveOptions::default());
        let direct = ops::primal_obj(&ds, &res.w, lam);
        assert!((res.obj - direct).abs() < 1e-9 * direct.max(1.0));
    }

    #[test]
    fn dynamic_screening_matches_static_with_fewer_col_ops() {
        let ds =
            synthetic1(&SynthOptions { t: 3, n: 14, d: 200, seed: 9, ..Default::default() }).0;
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.4 * lmax;
        let stat = fista(&ds, lam, None, &SolveOptions::default());
        let dynamic_opts = SolveOptions { dynamic_every: 10, ..Default::default() };
        let dyn_res = fista(&ds, lam, None, &dynamic_opts);
        assert!(dyn_res.converged, "dynamic run did not converge");
        assert_eq!(dyn_res.w.len(), ds.d * ds.t(), "w must come back full-size");
        let maxdiff = stat
            .w
            .iter()
            .zip(&dyn_res.w)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(maxdiff < 1e-5, "dynamic solution diverged by {maxdiff}");
        assert!(
            dyn_res.col_ops < stat.col_ops,
            "dynamic screening saved nothing: {} vs {}",
            dyn_res.col_ops,
            stat.col_ops
        );
    }

    #[test]
    fn generic_penalties_converge_and_beat_their_zero_matrix() {
        // sgl and gowl through the same solver: the gap certificate must
        // close and the solution must beat W = 0 in its own objective
        use crate::penalty::{Penalty, PenaltyKind};
        let ds = problem();
        for pk in [PenaltyKind::Sgl { alpha: 0.4 }, PenaltyKind::Gowl { gamma: 1.0 }] {
            let (lmax, _) = ops::lambda_max_for(&ds, &pk);
            let lam = 0.3 * lmax;
            let opts = SolveOptions { penalty: pk, tol: 1e-8, ..Default::default() };
            let res = fista(&ds, lam, None, &opts);
            assert!(res.converged, "{pk}: gap={} after {} iters", res.gap, res.iters);
            let at_zero = ops::primal_obj_for(&ds, &vec![0.0; ds.d * ds.t()], lam, &pk);
            assert!(res.obj < at_zero, "{pk}: obj {} not below zero-matrix {at_zero}", res.obj);
            // and above lambda_max the zero matrix must be optimal
            let zopts = SolveOptions { penalty: pk, ..Default::default() };
            let zres = fista(&ds, lmax * 1.001, None, &zopts);
            assert!(zres.w.iter().all(|&v| v == 0.0), "{pk}: W != 0 above lambda_max");
        }
    }

    #[test]
    fn dynamic_screening_safe_at_loose_tolerance() {
        // the gap ball is valid at every iterate, so even a loose dynamic
        // run must keep every truly active row
        let ds =
            synthetic1(&SynthOptions { t: 3, n: 14, d: 120, seed: 10, ..Default::default() }).0;
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.35 * lmax;
        let loose = SolveOptions { tol: 1e-3, dynamic_every: 5, ..Default::default() };
        let dyn_res = fista(&ds, lam, None, &loose);
        let stat = fista(&ds, lam, None, &SolveOptions { dynamic_every: 0, ..loose.clone() });
        // unsafe screening would freeze the objective above the static run
        assert!(
            dyn_res.obj <= stat.obj * (1.0 + 5e-3),
            "dynamic obj {} stuck above static {}",
            dyn_res.obj,
            stat.obj
        );
        // clearly-active rows (by a tight reference) must survive
        let tight = fista(&ds, lam, None, &SolveOptions::tight());
        let tight_norms = tight.row_norms(ds.t());
        let dyn_norms = dyn_res.row_norms(ds.t());
        for (l, (&tn, &dn)) in tight_norms.iter().zip(&dyn_norms).enumerate() {
            if tn > 1e-1 {
                assert!(dn > 0.0, "dynamic screening zeroed active row {l} (norm {tn})");
            }
        }
    }
}
