//! Cyclic block-coordinate descent over feature rows.
//!
//! For row l, with residuals r_t = y_t − Σ_{j≠l} w_j x_j^{(t)}, the update
//! minimizes ½Σ_t‖r_t − v_t x_l^{(t)}‖² + λ‖v‖ over v ∈ R^T:
//!
//!   c_t = <x_l^{(t)}, r_t>,  b2_t = ‖x_l^{(t)}‖²
//!   v = 0                        if ‖c‖ ≤ λ
//!   v_t = c_t ν / (b2_t ν + λ)   otherwise, where ν = ‖v‖ solves the
//!   secular equation f(ν) = Σ_t c_t²/(b2_t ν + λ)² = 1  (f strictly
//!   decreasing from ‖c‖²/λ² > 1), found by safeguarded Newton.
//!
//! This is an algorithm *independent* of FISTA (different trajectory,
//! different fixed-point characterization), which makes agreement between
//! the two a strong correctness check on both.
//!
//! Dynamic GAP-safe screening (`SolveOptions::dynamic_every`, DESIGN.md
//! §9): every K sweeps the live duality-gap ball certifies rows inactive;
//! their (possibly nonzero) iterate mass is returned to the residual and
//! the working set is compacted, so later sweeps skip them entirely.
//!
//! Execution model: the cyclic sweep itself is inherently serial (each
//! row update feeds the next row's residual), so BCD's parallelism lives
//! entirely in the `ops`/screening sweeps it calls — all routed through
//! the persistent executor, and all inline when BCD runs inside a CV
//! fold or stability subsample (DESIGN.md §11).

use super::{DynamicSet, SolveOptions, SolveResult};
use crate::data::Dataset;
use crate::ops;
use crate::screening::gap;

/// Solve the row secular equation; returns ν = ‖v‖ (0 if ‖c‖ <= lam).
fn row_nu(c: &[f64], b2: &[f64], lam: f64) -> f64 {
    let cn2 = crate::linalg::dot_f64(c, c);
    if cn2.sqrt() <= lam {
        return 0.0;
    }
    let f = |nu: f64| -> f64 {
        // repro-lint: allow(kernel-reduction): T-length secular fold (T ~ tasks, tiny); serial iterator order is the pinned order
        c.iter().zip(b2).map(|(&ct, &bt)| (ct / (bt * nu + lam)).powi(2)).sum::<f64>()
    };
    // bracket: f(0) > 1; grow hi until f(hi) < 1
    let mut lo = 0.0f64;
    let mut hi = 1.0f64;
    let mut guard = 0;
    while f(hi) > 1.0 {
        lo = hi;
        hi *= 4.0;
        guard += 1;
        if guard > 200 {
            break;
        }
    }
    // safeguarded Newton on h(nu) = f(nu) - 1 (f convex decreasing)
    let mut nu = 0.5 * (lo + hi);
    for _ in 0..100 {
        let mut fv = 0.0f64;
        let mut dfv = 0.0f64;
        for (&ct, &bt) in c.iter().zip(b2) {
            let den = bt * nu + lam;
            let r = ct / den;
            // repro-lint: allow(kernel-reduction): T-length Newton fold sharing r between f and f' — serial loop order pinned
            fv += r * r;
            // repro-lint: allow(kernel-reduction): derivative half of the fold above
            dfv += -2.0 * r * r * bt / den;
        }
        if fv > 1.0 {
            lo = nu;
        } else {
            hi = nu;
        }
        let step = (fv - 1.0) / dfv.min(-1e-300);
        let mut next = nu + step; // Newton: nu - (f-1)/f'
        if !(next > lo && next < hi) || !next.is_finite() {
            next = 0.5 * (lo + hi);
        }
        if (next - nu).abs() <= 1e-15 * nu.max(1.0) {
            nu = next;
            break;
        }
        nu = next;
    }
    nu
}

/// Cyclic BCD; `w0` warm start optional.
///
/// # Panics
///
/// Panics if `opts.penalty` does not support the per-row secular solve
/// (only ℓ2,1 does — see [`crate::penalty::Penalty::supports_row_secular`]).
/// The row update *is* the ℓ2,1 subproblem's exact minimizer; running it
/// under another penalty would silently solve the wrong problem.
pub fn bcd(ds: &Dataset, lam: f64, w0: Option<&[f64]>, opts: &SolveOptions) -> SolveResult {
    use crate::penalty::Penalty;
    let pen: &dyn Penalty = &opts.penalty;
    assert!(
        pen.supports_row_secular(),
        "BCD's row update is the exact ℓ2,1 secular solve; penalty {} has a different \
         row subproblem — use the FISTA solver for it",
        pen.name()
    );
    let t_count = ds.t();
    let d_full = ds.d;
    let mut w: Vec<f64> = match w0 {
        Some(w0) => w0.to_vec(),
        None => vec![0.0; d_full * t_count],
    };
    let mut b2_all = ds.col_sqnorms(); // (d x T)

    // dynamic-screening working set (see module docs)
    let mut ws = DynamicSet::new(d_full, t_count);

    // residuals r_t = y_t - X_t w_t
    let mut r: ops::Stacked = {
        let z = ops::forward(ds, &w);
        ds.tasks
            .iter()
            .zip(z)
            .map(|(task, zt)| {
                task.y.iter().zip(zt).map(|(&yi, zi)| yi as f64 - zi).collect()
            })
            .collect()
    };

    let mut c = vec![0.0f64; t_count];
    let mut obj = f64::INFINITY;
    let mut gap = f64::INFINITY;
    let mut sweeps = 0usize;
    let mut converged = false;
    let mut col_ops = 0usize;

    for sweep in 1..=opts.max_iters {
        sweeps = sweep;
        let mut shrink: Option<(Dataset, Vec<usize>)> = None;
        {
            let dsc = ws.live(ds);
            let d = dsc.d;
            col_ops += 2 * d; // one sweep = a dot + an axpy per live column
            let mut max_change = 0.0f64;
            for l in 0..d {
                let b2 = &b2_all[l * t_count..(l + 1) * t_count];
                // c_t = <x_l, r_t> + b2_t * w_lt   (residual with row l removed)
                for ti in 0..t_count {
                    c[ti] =
                        dsc.tasks[ti].col(l).dot_mixed(&r[ti]) + b2[ti] * w[l * t_count + ti];
                }
                let nu = row_nu(&c, b2, lam);
                for ti in 0..t_count {
                    let old = w[l * t_count + ti];
                    let new = if nu == 0.0 { 0.0 } else { c[ti] * nu / (b2[ti] * nu + lam) };
                    let delta = new - old;
                    if delta != 0.0 {
                        dsc.tasks[ti].col(l).axpy_into(-delta, &mut r[ti]);
                        w[l * t_count + ti] = new;
                        max_change = max_change.max(delta.abs());
                    }
                }
            }

            // cadence must mean the same thing as FISTA's: clamp only to
            // ≥ 1 (a historical clamp to ≤ 5 silently quintupled the
            // configured gap-check frequency), and force a final-iteration
            // check so a coarse cadence can't exit with stale obj/gap
            let due_check = sweep % opts.check_every.max(1) == 0
                || sweep == opts.max_iters
                || max_change == 0.0;
            let due_screen = opts.dynamic_every > 0 && sweep % opts.dynamic_every == 0 && d > 1;
            if due_check || due_screen {
                // the gap evaluation costs a forward pass + a corr sweep
                col_ops += 2 * d;
                let (o, gp, theta) = ops::duality_gap_for(dsc, &w, lam, pen);
                obj = o;
                gap = gp;
                if gap <= opts.tol * obj.abs().max(1.0) {
                    converged = true;
                } else if due_screen {
                    col_ops += d; // and so is the score sweep
                    if let Some(kept) = gap::dynamic_keep_for(dsc, &b2_all, &theta, gap, lam, pen)
                    {
                        if !kept.is_empty() {
                            // return the dropped rows' iterate mass to the
                            // residual before they leave the working set —
                            // one blocked axpy panel per task, columns in
                            // ascending order exactly as the old per-row
                            // loop visited them
                            let mut is_kept = vec![false; d];
                            for &j in &kept {
                                is_kept[j] = true;
                            }
                            for ti in 0..t_count {
                                let dropped: Vec<(usize, f64)> = is_kept
                                    .iter()
                                    .enumerate()
                                    .filter(|&(_, &kj)| !kj)
                                    .filter_map(|(j, _)| {
                                        let wj = w[j * t_count + ti];
                                        (wj != 0.0).then_some((j, wj))
                                    })
                                    .collect();
                                crate::ops::axpy_panel(&dsc.tasks[ti], &dropped, &mut r[ti]);
                            }
                            shrink = Some((dsc.restrict(&kept), kept));
                        }
                    }
                }
            }
        }
        if converged {
            break;
        }
        if let Some((ds_small, kept)) = shrink {
            w = ws.compact_rows(&w, &kept);
            b2_all = ws.compact_rows(&b2_all, &kept);
            ws.shrink_to(ds_small, kept);
        }
    }

    if !obj.is_finite() {
        let (o, gp, _) = ops::duality_gap_for(ws.live(ds), &w, lam, pen);
        obj = o;
        gap = gp;
    }

    let w = ws.scatter(w);
    SolveResult { w, obj, gap, iters: sweeps, converged, lipschitz: 0.0, col_ops }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, synthetic2, SynthOptions};
    use crate::solver::fista;

    fn problem() -> Dataset {
        synthetic1(&SynthOptions { t: 3, n: 12, d: 30, seed: 8, ..Default::default() }).0
    }

    #[test]
    fn row_nu_zero_iff_small_correlation() {
        assert_eq!(row_nu(&[0.3, 0.4], &[1.0, 2.0], 0.6), 0.0); // ||c||=0.5 < 0.6
        assert!(row_nu(&[3.0, 4.0], &[1.0, 2.0], 0.6) > 0.0);
    }

    #[test]
    fn row_nu_satisfies_fixed_point() {
        let c = [2.0, -1.5, 0.7];
        let b2 = [1.3, 0.2, 2.5];
        let lam = 0.9;
        let nu = row_nu(&c, &b2, lam);
        let vnorm2: f64 = c
            .iter()
            .zip(&b2)
            .map(|(&ct, &bt)| (ct * nu / (bt * nu + lam)).powi(2))
            .sum();
        assert!((vnorm2.sqrt() - nu).abs() < 1e-10, "nu={nu} ||v||={}", vnorm2.sqrt());
    }

    #[test]
    fn bcd_converges() {
        let ds = problem();
        let (lmax, _, _) = ops::lambda_max(&ds);
        let res = bcd(&ds, 0.3 * lmax, None, &SolveOptions::default());
        assert!(res.converged, "gap={}", res.gap);
    }

    #[test]
    fn bcd_and_fista_agree() {
        type Gen = fn(&SynthOptions) -> (Dataset, crate::data::GroundTruth);
        let cases: [(u64, Gen); 2] = [(1, synthetic1), (2, synthetic2)];
        for (seed, mk) in cases {
            let (ds, _) = mk(&SynthOptions { t: 2, n: 10, d: 20, seed, ..Default::default() });
            let (lmax, _, _) = ops::lambda_max(&ds);
            let lam = 0.35 * lmax;
            let a = bcd(&ds, lam, None, &SolveOptions::tight());
            let b = fista(&ds, lam, None, &SolveOptions::tight());
            let maxdiff = a
                .w
                .iter()
                .zip(&b.w)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f64, f64::max);
            assert!(maxdiff < 1e-5, "solvers disagree: {maxdiff}");
            assert!((a.obj - b.obj).abs() < 1e-8 * a.obj.max(1.0));
        }
    }

    #[test]
    fn bcd_honors_configured_check_cadence() {
        // regression: check_every used to be silently clamped to ≤ 5, so a
        // configured cadence of 37 checked the gap every 5 sweeps. Count
        // gap evaluations through the col_ops ledger (2d per sweep + 2d
        // per check, no dynamic screening): an honored cadence of 37 pays
        // for exactly one check on a problem converging within 37 sweeps,
        // while the legacy clamp paid one per 5 sweeps.
        let ds = problem();
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.3 * lmax;
        let opts = |check_every| SolveOptions { check_every, tol: 1e-10, ..Default::default() };
        let fast = bcd(&ds, lam, None, &opts(1));
        assert!(
            fast.converged && fast.iters > 5 && fast.iters <= 37,
            "premise: needs 5 < sweeps <= 37 at this tolerance, got {}",
            fast.iters
        );
        let coarse = bcd(&ds, lam, None, &opts(37));
        assert!(coarse.converged);
        assert!(coarse.iters >= fast.iters);
        let checks = coarse.col_ops / (2 * ds.d) - coarse.iters;
        assert_eq!(
            checks, 1,
            "cadence 37 must evaluate the gap exactly once in {} sweeps \
             (the legacy ≤5 clamp would have paid for {} checks)",
            coarse.iters,
            coarse.iters.div_ceil(5)
        );
    }

    #[test]
    #[should_panic(expected = "row update is the exact ℓ2,1 secular solve")]
    fn bcd_rejects_non_l21_penalties() {
        let ds = problem();
        let opts = SolveOptions {
            penalty: crate::penalty::PenaltyKind::Sgl { alpha: 0.5 },
            ..Default::default()
        };
        let _ = bcd(&ds, 1.0, None, &opts);
    }

    #[test]
    fn bcd_zero_above_lmax() {
        let ds = problem();
        let (lmax, _, _) = ops::lambda_max(&ds);
        let res = bcd(&ds, lmax * 1.01, None, &SolveOptions::default());
        assert!(res.w.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn bcd_dynamic_matches_static_with_fewer_col_ops() {
        let ds =
            synthetic1(&SynthOptions { t: 3, n: 14, d: 200, seed: 9, ..Default::default() }).0;
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.4 * lmax;
        let stat = bcd(&ds, lam, None, &SolveOptions::default());
        let dyn_res = bcd(&ds, lam, None, &SolveOptions { dynamic_every: 3, ..Default::default() });
        assert!(dyn_res.converged, "dynamic BCD did not converge");
        assert_eq!(dyn_res.w.len(), ds.d * ds.t());
        let maxdiff = stat
            .w
            .iter()
            .zip(&dyn_res.w)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        assert!(maxdiff < 1e-5, "dynamic BCD diverged by {maxdiff}");
        assert!(
            dyn_res.col_ops < stat.col_ops,
            "dynamic BCD saved nothing: {} vs {}",
            dyn_res.col_ops,
            stat.col_ops
        );
    }
}
