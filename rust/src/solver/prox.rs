//! Proximal operator of κ‖·‖₂,₁ — the row-wise group soft-threshold.
//! This is the concrete kernel behind [`crate::penalty::L21`]'s
//! `prox_inplace`; the generic seam (DESIGN.md §14) delegates here so
//! ℓ2,1 results stay bit-identical to the pre-seam code.

use crate::penalty::ActiveRowCount;

/// In-place prox on a row-major (d x T) matrix: each row shrinks by
/// max(0, 1 − κ/‖row‖).
///
/// Returns the **active-row count** ([`ActiveRowCount`]): the number of
/// rows left nonzero by the prox. A row is counted iff its norm exceeded
/// κ — equivalently, iff at least one of its entries is nonzero
/// afterwards — so the count always equals the number of nonzero rows of
/// the output (`active_count_equals_nonzero_rows` pins this, including
/// the κ = 0 edge where already-zero rows still do not count).
/// Row norms use the contract kernel ([`crate::linalg::nrm2_f64`]) — the
/// same one `ops::l21_norm`/`ops::row_is_active` use, so the prox's
/// survive/zero decision and the bookkeeping's activity predicate can
/// never disagree on a row.
pub fn prox21_inplace(w: &mut [f64], t_count: usize, kappa: f64) -> ActiveRowCount {
    debug_assert_eq!(w.len() % t_count, 0);
    let mut alive = 0usize;
    for row in w.chunks_exact_mut(t_count) {
        let norm = crate::linalg::nrm2_f64(row);
        if norm <= kappa {
            row.fill(0.0);
        } else {
            let s = 1.0 - kappa / norm;
            for v in row.iter_mut() {
                *v *= s;
            }
            alive += 1;
        }
    }
    alive
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shrinks_exactly() {
        let mut w = vec![3.0, 4.0, /* row2 */ 0.3, 0.4];
        let alive = prox21_inplace(&mut w, 2, 1.0);
        // row1 norm 5 -> scale 0.8 ; row2 norm 0.5 <= 1 -> zero
        assert_eq!(alive, 1);
        assert!((w[0] - 2.4).abs() < 1e-12 && (w[1] - 3.2).abs() < 1e-12);
        assert_eq!(&w[2..], &[0.0, 0.0]);
    }

    #[test]
    fn kappa_zero_is_identity() {
        let mut w = vec![1.0, -2.0, 3.0];
        prox21_inplace(&mut w, 3, 0.0);
        assert_eq!(w, vec![1.0, -2.0, 3.0]);
    }

    #[test]
    fn active_count_equals_nonzero_rows() {
        // the documented return contract: count == rows with any nonzero
        // entry after the prox, across surviving / shrunk-to-zero /
        // already-zero rows and the κ = 0 edge case
        let cases: &[(Vec<f64>, f64)] = &[
            (vec![3.0, 4.0, 0.3, 0.4, 0.0, 0.0, -1.0, 2.0], 1.0),
            (vec![3.0, 4.0, 0.3, 0.4, 0.0, 0.0, -1.0, 2.0], 0.0),
            (vec![0.0, 0.0, 0.0, 0.0], 0.5),
            (vec![1e-12, 0.0, 5.0, -5.0], 1e-9),
        ];
        for (w0, kappa) in cases {
            let mut w = w0.clone();
            let alive = prox21_inplace(&mut w, 2, *kappa);
            let nonzero_rows =
                w.chunks_exact(2).filter(|row| row.iter().any(|&v| v != 0.0)).count();
            assert_eq!(
                alive, nonzero_rows,
                "count contract broken for kappa={kappa}: w_out={w:?}"
            );
        }
    }

    #[test]
    fn prox_is_nonexpansive() {
        // |prox(a) - prox(b)| <= |a - b| row-wise
        let mut a: Vec<f64> = vec![2.0, 0.5, -1.0, 0.2];
        let mut b: Vec<f64> = vec![1.5, 0.7, -0.8, 0.1];
        let dist0: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).powi(2)).sum();
        prox21_inplace(&mut a, 2, 0.9);
        prox21_inplace(&mut b, 2, 0.9);
        let dist1: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).powi(2)).sum();
        assert!(dist1 <= dist0 + 1e-12);
    }

    #[test]
    fn optimality_condition_of_prox() {
        // v = prox_k(z) satisfies z - v in k * subdiff ||v||: for v != 0,
        // z - v = k v/||v||
        let z = vec![3.0, -4.0];
        let mut v = z.clone();
        prox21_inplace(&mut v, 2, 2.0);
        let vn = (v[0] * v[0] + v[1] * v[1]).sqrt();
        for i in 0..2 {
            assert!(((z[i] - v[i]) - 2.0 * v[i] / vn).abs() < 1e-12);
        }
    }
}
