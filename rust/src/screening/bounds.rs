//! Ablation screeners (DESIGN.md §8, experiment ABL1).
//!
//! * [`cs_scores`] — replaces the exact QP1QC max with the Cauchy–Schwarz
//!   upper bound s^CS_l = Σ_t (|a_t| + Δ·b_t)². Still *safe* (it upper
//!   bounds g_l over the ball) but strictly looser for T > 1 — the max of
//!   the sum is bounded by the sum of per-task maxima, which ignores the
//!   shared ‖u‖ ≤ Δ budget. Quantifies what Theorem 7 buys.
//! * [`center_scores`] — g_l at the ball center only. NOT safe (a
//!   heuristic, like the Strong-Rule family without the check); included
//!   to measure how often unsafe screening actually mis-rejects.
//!
//! Both ablations bound the ℓ2,1 constraint functional g_l specifically
//! and are compared against the ℓ2,1 QP1QC scores, so this module stays
//! outside the penalty seam (DESIGN.md §14) — ABL1 is an ablation of the
//! paper's rule, not of the generic screener.

use super::{dpc::DualRef, ScreenOutcome};
use crate::data::Dataset;
use crate::ops::Stacked;
use crate::util::{parallel_chunks, serial_below};

fn moments(
    ds: &Dataset,
    b2: &[f64],
    o: &Stacked,
    f: impl Fn(&[f64], &[f64]) -> f64 + Sync,
) -> Vec<f64> {
    let t_count = ds.t();
    // shared serial-cutoff policy: stored sweep work, not d·N (CSC sweeps
    // touch only nonzeros); moments ride the same cache-blocked panels as
    // task_corr (ops::corr_chunk)
    let workers = if serial_below(ds.sweep_work()) { 1 } else { usize::MAX };
    let out = parallel_chunks(ds.d, workers, |_, start, end| {
        let corr = crate::ops::corr_chunk(ds, start, end, o);
        let mut part = vec![0.0f64; end - start];
        for l in start..end {
            let a = &corr[(l - start) * t_count..(l - start + 1) * t_count];
            part[l - start] = f(a, &b2[l * t_count..(l + 1) * t_count]);
        }
        part
    });
    out.concat()
}

/// Safe Cauchy–Schwarz bound: Σ_t (|a_t| + Δ b_t)².
pub fn cs_scores(ds: &Dataset, b2: &[f64], o: &Stacked, delta: f64) -> Vec<f64> {
    moments(ds, b2, o, |a, b2| {
        a.iter()
            .zip(b2)
            .map(|(&at, &bt)| {
                let v = at.abs() + delta * bt.sqrt();
                v * v
            })
            .sum()
    })
}

/// Unsafe center heuristic: Σ_t a_t².
pub fn center_scores(ds: &Dataset, b2: &[f64], o: &Stacked) -> Vec<f64> {
    moments(ds, b2, o, |a, _| a.iter().map(|v| v * v).sum())
}

/// A screener with the same interface as DPC but CS scores (ablation).
pub struct CsScreener {
    b2: Vec<f64>,
}

impl CsScreener {
    /// Build the screener, caching the b² table (one O(nnz) sweep).
    pub fn new(ds: &Dataset) -> Self {
        CsScreener { b2: ds.col_sqnorms() }
    }

    /// DPC ball + CS scores at λ from a reference at λ0 ≥ λ.
    pub fn screen(&self, ds: &Dataset, dref: &DualRef, lam: f64) -> ScreenOutcome {
        let (o, delta) = super::dpc::ball(ds, dref, lam);
        let scores = cs_scores(ds, &self.b2, &o, delta);
        let rejected = scores.iter().map(|&s| s < 1.0).collect();
        ScreenOutcome { rejected, scores, delta }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, SynthOptions};
    use crate::screening::dpc::{ball, DpcScreener, DualRef};

    #[test]
    fn cs_upper_bounds_exact_scores() {
        let (ds, _) =
            synthetic1(&SynthOptions { t: 4, n: 10, d: 50, seed: 7, ..Default::default() });
        let (dref, lmax) = DualRef::at_lambda_max(&ds);
        let (o, delta) = ball(&ds, &dref, 0.4 * lmax);
        let b2 = ds.col_sqnorms();
        let exact = DpcScreener::new(&ds).scores(&ds, &o, delta);
        let cs = cs_scores(&ds, &b2, &o, delta);
        let center = center_scores(&ds, &b2, &o);
        for l in 0..ds.d {
            assert!(cs[l] >= exact[l] - 1e-9, "CS not an upper bound at {l}");
            assert!(center[l] <= exact[l] + 1e-9, "center not a lower bound at {l}");
        }
    }

    #[test]
    fn cs_equals_exact_for_single_task() {
        // T = 1: Cauchy–Schwarz is tight, the two scores coincide
        let (ds, _) =
            synthetic1(&SynthOptions { t: 1, n: 12, d: 30, seed: 8, ..Default::default() });
        let (dref, lmax) = DualRef::at_lambda_max(&ds);
        let (o, delta) = ball(&ds, &dref, 0.5 * lmax);
        let exact = DpcScreener::new(&ds).scores(&ds, &o, delta);
        let cs = cs_scores(&ds, &ds.col_sqnorms(), &o, delta);
        for l in 0..ds.d {
            assert!((exact[l] - cs[l]).abs() < 1e-9 * cs[l].max(1.0), "l={l}");
        }
    }

    #[test]
    fn cs_screener_rejects_no_more_than_dpc_is_wrong_way() {
        // looser bound => CS rejects a subset of DPC's rejections
        let (ds, _) =
            synthetic1(&SynthOptions { t: 4, n: 10, d: 80, seed: 9, ..Default::default() });
        let (dref, lmax) = DualRef::at_lambda_max(&ds);
        let dpc = DpcScreener::new(&ds).screen(&ds, &dref, 0.5 * lmax);
        let cs = CsScreener::new(&ds).screen(&ds, &dref, 0.5 * lmax);
        for l in 0..ds.d {
            if cs.rejected[l] {
                assert!(dpc.rejected[l], "CS rejected {l} that exact DPC kept");
            }
        }
        assert!(cs.num_rejected() <= dpc.num_rejected());
    }
}
