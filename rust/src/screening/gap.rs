//! GAP-safe screening: balls for θ*(λ) certified by the duality gap of
//! *any* primal/dual feasible pair (Ndiaye, Fercoq, Gramfort & Salmon,
//! "GAP Safe screening rules for sparse multi-task and multi-class
//! models" — see PAPERS.md), specialized to the multi-matrix MTFL dual.
//! This is the principled repair for the inexact-reference hole in the
//! sequential DPC rule, and the machinery behind dynamic screening inside
//! the solver loop (DESIGN.md §9).
//!
//! Geometry: the dual objective D(θ) = ½‖y‖² − λ²/2·‖y/λ − θ‖² is
//! λ²-strongly concave, so for the maximizer θ*(λ) over the convex
//! feasible set F and any feasible θ,
//!
//!   D(θ) ≤ D(θ*) − λ²/2·‖θ − θ*‖²  and  D(θ*) = P(W*) ≤ P(W)
//!   ⇒  ‖θ*(λ) − θ‖ ≤ √(2·(P(W) − D(θ)))/λ.
//!
//! No exactness assumption on anything: the ball is valid at every solver
//! iterate, which is exactly what lets the solvers re-screen mid-solve as
//! the gap shrinks.

use super::{ball_scores, ball_scores_for, ScreenOutcome};
use crate::data::Dataset;
use crate::ops::{self, Stacked};
use crate::penalty::Penalty;

/// ‖θ*(λ) − θ‖ ≤ √(2·max(gap, 0))/λ for any feasible pair with duality
/// gap `gap` (strong concavity of the dual — module docs).
pub fn certified_radius(gap: f64, lam: f64) -> f64 {
    (2.0 * gap.max(0.0)).sqrt() / lam
}

/// A duality-gap-certified ball around θ*(λ).
#[derive(Debug, Clone)]
pub struct GapBall {
    /// dual-feasible center (the scaled residual of the primal iterate)
    pub center: Stacked,
    /// √(2·gap)/λ — the strong-concavity radius
    pub radius: f64,
    /// the certifying gap P(W) − D(center)
    pub gap: f64,
}

impl GapBall {
    /// Ball from a primal iterate: one residual + one correlation sweep.
    pub fn from_primal(ds: &Dataset, lam: f64, w: &[f64]) -> GapBall {
        let (_, gap, theta) = ops::duality_gap(ds, w, lam);
        GapBall::from_feasible(theta, gap, lam)
    }

    /// Penalty-generic [`GapBall::from_primal`]: the gap and the feasible
    /// center both come from the penalty's own objective and dual scaling
    /// (`ops::duality_gap_for`), so the strong-concavity radius certifies
    /// the *right* dual optimum. With [`crate::penalty::L21`] this is
    /// bit-identical to `from_primal`.
    pub fn from_primal_for(ds: &Dataset, lam: f64, w: &[f64], pen: &dyn Penalty) -> GapBall {
        let (_, gap, theta) = ops::duality_gap_for(ds, w, lam, pen);
        GapBall::from_feasible(theta, gap, lam)
    }

    /// Ball from an already-evaluated feasible pair — the solvers reuse
    /// the (gap, θ) they compute for the stopping test, so a dynamic
    /// screen costs only the score sweep.
    pub fn from_feasible(center: Stacked, gap: f64, lam: f64) -> GapBall {
        GapBall { radius: certified_radius(gap, lam), center, gap }
    }
}

/// The GAP-safe screener: Theorem-7 score maximization over a gap ball.
/// Caches the λ-independent b² column-norm moments like [`super::dpc::DpcScreener`].
pub struct GapScreener {
    b2: Vec<f64>,
}

impl GapScreener {
    /// Build the screener, caching the b² table (one O(nnz) sweep).
    pub fn new(ds: &Dataset) -> Self {
        GapScreener { b2: ds.col_sqnorms() }
    }

    /// Screen with an explicit ball.
    pub fn screen(&self, ds: &Dataset, ball: &GapBall) -> ScreenOutcome {
        let scores = ball_scores(ds, &self.b2, &ball.center, ball.radius);
        let rejected = scores.iter().map(|&s| s < 1.0).collect();
        ScreenOutcome { rejected, scores, delta: ball.radius }
    }

    /// Screen at λ from a primal iterate (the path coordinator's static
    /// per-λ use: the warm-start vector certifies the ball).
    pub fn screen_primal(&self, ds: &Dataset, lam: f64, w: &[f64]) -> ScreenOutcome {
        self.screen(ds, &GapBall::from_primal(ds, lam, w))
    }

    /// Penalty-generic [`GapScreener::screen`]: scores come from the
    /// penalty's own ball test ([`ball_scores_for`]); the s < 1 rejection
    /// contract is shared across penalties.
    pub fn screen_for(&self, ds: &Dataset, ball: &GapBall, pen: &dyn Penalty) -> ScreenOutcome {
        let scores = ball_scores_for(ds, &self.b2, &ball.center, ball.radius, pen);
        let rejected = scores.iter().map(|&s| s < 1.0).collect();
        ScreenOutcome { rejected, scores, delta: ball.radius }
    }

    /// Penalty-generic [`GapScreener::screen_primal`].
    pub fn screen_primal_for(
        &self,
        ds: &Dataset,
        lam: f64,
        w: &[f64],
        pen: &dyn Penalty,
    ) -> ScreenOutcome {
        self.screen_for(ds, &GapBall::from_primal_for(ds, lam, w, pen), pen)
    }
}

/// One dynamic screen inside a solver: given the (obj, gap, θ_feasible)
/// triple the solver just evaluated for its stopping test, return the
/// locally-kept feature indices of the *current* (possibly already
/// compacted) problem, or `None` when the ball rejects nothing. `b2` must
/// be the current problem's column-norm table.
pub fn dynamic_keep(
    ds: &Dataset,
    b2: &[f64],
    theta: &Stacked,
    gap: f64,
    lam: f64,
) -> Option<Vec<usize>> {
    dynamic_keep_for(ds, b2, theta, gap, lam, &crate::penalty::L21)
}

/// Penalty-generic [`dynamic_keep`] (DESIGN.md §14): same certified
/// radius, same keep/reject bookkeeping, with the per-feature ball test
/// supplied by the penalty. The solvers pass their own
/// `SolveOptions::penalty` here so the mid-solve screen certifies rows of
/// the problem they are actually solving. With [`crate::penalty::L21`]
/// this is bit-identical to the ℓ2,1 path.
pub fn dynamic_keep_for(
    ds: &Dataset,
    b2: &[f64],
    theta: &Stacked,
    gap: f64,
    lam: f64,
    pen: &dyn Penalty,
) -> Option<Vec<usize>> {
    let radius = certified_radius(gap, lam);
    let scores = ball_scores_for(ds, b2, theta, radius, pen);
    let keep: Vec<usize> = scores
        .iter()
        .enumerate()
        .filter_map(|(l, &s)| (s >= 1.0).then_some(l))
        .collect();
    if keep.len() < ds.d {
        Some(keep)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, SynthOptions};
    use crate::solver::{fista, SolveOptions};

    fn problem(seed: u64) -> Dataset {
        synthetic1(&SynthOptions { t: 3, n: 12, d: 60, seed, ..Default::default() }).0
    }

    #[test]
    fn gap_ball_contains_dual_optimum_at_any_tolerance() {
        let ds = problem(31);
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.35 * lmax;
        let tight = fista(&ds, lam, None, &SolveOptions::tight());
        let theta_star = {
            let z = ops::stacked_scale(&ops::residual(&ds, &tight.w), -1.0 / lam);
            ops::dual_feasible(&ds, z).0
        };
        for tol in [1e-1, 1e-2, 1e-4] {
            let rough = fista(&ds, lam, None, &SolveOptions { tol, ..Default::default() });
            let ball = GapBall::from_primal(&ds, lam, &rough.w);
            assert!(ball.gap >= -1e-12, "weak duality violated: {}", ball.gap);
            assert_eq!(ball.radius, certified_radius(ball.gap, lam));
            let diff = ops::stacked_scale_add(&theta_star, -1.0, &ball.center);
            let dist = ops::stacked_sqnorm(&diff).sqrt();
            assert!(
                dist <= ball.radius + 1e-9,
                "tol {tol}: dist {dist} > radius {}",
                ball.radius
            );
        }
    }

    #[test]
    fn gap_screen_is_safe_from_loose_iterates() {
        let ds = problem(32);
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.4 * lmax;
        let rough = fista(&ds, lam, None, &SolveOptions { tol: 1e-3, ..Default::default() });
        let out = GapScreener::new(&ds).screen_primal(&ds, lam, &rough.w);
        let tight = fista(&ds, lam, None, &SolveOptions::tight());
        let rn = tight.row_norms(ds.t());
        for (l, (&rej, &norm)) in out.rejected.iter().zip(&rn).enumerate() {
            assert!(!rej || norm < 1e-8, "UNSAFE gap rejection of row {l} (norm {norm})");
        }
        assert!(out.num_rejected() > 0, "gap screen rejected nothing at tol 1e-3");
    }

    #[test]
    fn radius_shrinks_with_gap_and_rejection_grows() {
        let ds = problem(33);
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.4 * lmax;
        let sc = GapScreener::new(&ds);
        let mut radii = Vec::new();
        let mut rejected = Vec::new();
        for tol in [1e-1, 1e-3, 1e-6] {
            let sol = fista(&ds, lam, None, &SolveOptions { tol, ..Default::default() });
            let ball = GapBall::from_primal(&ds, lam, &sol.w);
            rejected.push(sc.screen(&ds, &ball).num_rejected());
            radii.push(ball.radius);
        }
        assert!(radii[2] <= radii[0] + 1e-12, "radius did not shrink: {radii:?}");
        assert!(rejected[2] >= rejected[0], "tighter gap screened less: {rejected:?}");
        assert!(rejected[2] > 0, "tight gap ball rejected nothing");
    }

    #[test]
    fn dynamic_keep_preserves_active_set() {
        let ds = problem(34);
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.3 * lmax;
        let rough = fista(&ds, lam, None, &SolveOptions { tol: 1e-4, ..Default::default() });
        let (obj, gap, theta) = ops::duality_gap(&ds, &rough.w, lam);
        assert!(obj.is_finite() && gap >= -1e-12);
        let b2 = ds.col_sqnorms();
        let keep = dynamic_keep(&ds, &b2, &theta, gap, lam).expect("should reject something");
        let tight = fista(&ds, lam, None, &SolveOptions::tight());
        for &l in &tight.active_set(ds.t(), 1e-8) {
            assert!(keep.contains(&l), "dynamic screen dropped active row {l}");
        }
    }

    #[test]
    fn certified_radius_handles_degenerate_gaps() {
        assert_eq!(certified_radius(0.0, 2.0), 0.0);
        assert_eq!(certified_radius(-1e-9, 2.0), 0.0); // fp noise clamps to 0
        assert!((certified_radius(2.0, 2.0) - 1.0).abs() < 1e-15);
    }
}
