//! Theorem 7: the per-feature QP1QC
//!
//!   s_l = max_{θ ∈ Ball(o, Δ)} Σ_t <x_l^{(t)}, θ_t>²
//!
//! reduces (via the paper's parametrization of the ball) to the diagonal
//! trust-region problem  min ½uᵀHu + qᵀu  s.t. ‖u‖ ≤ Δ  with
//! H = −2·diag(b²), q_t = −2 b_t|a_t|  where a_t = <x_l^{(t)}, o_t>,
//! b_t = ‖x_l^{(t)}‖. The optimal multiplier α* ≥ 2ρ² (ρ = max_t b_t)
//! solves the secular equation ‖u(α)‖ = Δ, u_t(α) = c_t/(α − β_t) with
//! c = −q, β = −diag(H); we use Gay/Moré–Sorensen safeguarded Newton
//! (Eqs. 29–30), which converges in a handful of iterations because
//! 1/‖u(α)‖ is concave increasing.
//!
//! Then  s_l = Σ_t a_t² + (α*/2)Δ² − ½ qᵀu*  (Theorem 7.4).

// repro-lint: allow-file(kernel-reduction): every fold here is T-length (T = task count, ~20) inside the per-feature Newton iteration — far below any SIMD cutoff, and the serial loop order IS the pinned order (DESIGN §12 governs n-length data folds, not these).

/// Result of one QP1QC solve (diagnostics carried for tests/benches).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Branch {
    /// Δ = 0 or all-zero feature: s = Σ a²
    Trivial,
    /// Theorem 7.2's hard case: α* = 2ρ², closed form
    Closed,
    /// interior Newton solve on (2ρ², ∞)
    Newton,
}

/// One QP1QC solve: the score plus solver diagnostics.
#[derive(Debug, Clone, Copy)]
pub struct Qp1qc {
    /// s_l — the maximum of g_l over the ball (the screening score)
    pub s: f64,
    /// the optimal trust-region multiplier α*
    pub alpha: f64,
    /// which solution branch produced the result
    pub branch: Branch,
    /// Newton iterations spent (0 on the closed-form branches)
    pub newton_iters: usize,
}

/// Solve the Theorem-7 max for one feature.
///
/// `a[t] = <x_l^{(t)}, o_t>`, `b2[t] = ‖x_l^{(t)}‖²`, Δ = ball radius.
pub fn qp1qc_max(a: &[f64], b2: &[f64], delta: f64) -> Qp1qc {
    debug_assert_eq!(a.len(), b2.len());
    let t = a.len();
    let ssq: f64 = a.iter().map(|v| v * v).sum();

    let amin = b2.iter().cloned().fold(0.0f64, f64::max) * 2.0; // 2ρ²
    if delta <= 0.0 || amin <= 1e-290 {
        return Qp1qc { s: ssq, alpha: amin, branch: Branch::Trivial, newton_iters: 0 };
    }

    // c_t = 2 b_t |a_t| (−q), β_t = 2 b_t² (−H diagonal)
    let mut cnorm2 = 0.0f64;
    let mut cmax = 0.0f64;
    let mut ubar_norm2 = 0.0f64;
    let mut q_dot_ubar = 0.0f64; // Σ c_t·ū_t (note: −½qᵀū = +½Σ c ū)
    let mut q_on_i = 0.0f64; // max c_t over the active index set I
    let itol = 1.0 - 1e-12;
    for ti in 0..t {
        let beta = 2.0 * b2[ti];
        let c = 2.0 * b2[ti].sqrt() * a[ti].abs();
        cnorm2 += c * c;
        cmax = cmax.max(c);
        if beta >= amin * itol {
            q_on_i = q_on_i.max(c);
        } else {
            let u = c / (amin - beta);
            ubar_norm2 += u * u;
            q_dot_ubar += c * u;
        }
    }

    // Closed-form branch (Thm 7.2/7.3): q vanishes on I and ‖ū‖ ≤ Δ.
    let ctol = 1e-12 * (1.0 + cmax);
    if q_on_i <= ctol && ubar_norm2.sqrt() <= delta {
        let s = ssq + 0.5 * amin * delta * delta + 0.5 * q_dot_ubar;
        return Qp1qc { s, alpha: amin, branch: Branch::Closed, newton_iters: 0 };
    }

    // Newton branch on (amin, amin + ‖c‖/Δ]
    let mut lo = amin;
    let mut hi = amin + cnorm2.sqrt() / delta + 1e-300;
    let mut alpha = amin * (1.0 + 1e-9) + 1e-300;
    alpha = alpha.min(0.5 * (lo + hi));
    let mut iters = 0usize;
    for k in 0..100 {
        iters = k + 1;
        // u(α), ‖u‖², uᵀ(H+αI)⁻¹u = Σ u²/(α−β)
        let mut un2 = 0.0f64;
        let mut uhu = 0.0f64;
        for ti in 0..t {
            let beta = 2.0 * b2[ti];
            let c = 2.0 * b2[ti].sqrt() * a[ti].abs();
            let gap = (alpha - beta).max(1e-300);
            let u = c / gap;
            un2 += u * u;
            uhu += u * u / gap;
        }
        let un = un2.sqrt();
        if (un - delta).abs() <= 1e-14 * delta {
            break;
        }
        if un > delta {
            lo = alpha; // φ(α) < 0: root is above
        } else {
            hi = alpha;
        }
        // Eq. (30)
        let mut next = alpha + un2 * (un - delta) / (delta * uhu).max(1e-300);
        if !(next > lo && next < hi) || !next.is_finite() {
            next = 0.5 * (lo + hi);
        }
        if (next - alpha).abs() <= 1e-16 * alpha.max(1.0) {
            alpha = next;
            break;
        }
        alpha = next;
    }

    // s = Σa² + α/2·Δ² + ½ Σ c·u(α)
    let mut cu = 0.0f64;
    for ti in 0..t {
        let beta = 2.0 * b2[ti];
        let c = 2.0 * b2[ti].sqrt() * a[ti].abs();
        cu += c * c / (alpha - beta).max(1e-300);
    }
    let s = ssq + 0.5 * alpha * delta * delta + 0.5 * cu;
    Qp1qc { s, alpha, branch: Branch::Newton, newton_iters: iters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    /// brute-force: max over boundary directions via projected gradient
    /// ascent from many starts (the ball max is attained on the boundary)
    fn brute_max(a: &[f64], b2: &[f64], delta: f64, rng: &mut Pcg64) -> f64 {
        // g(u) over the parametrized ball: sum_t (|a_t| + b_t u_t)^2 with
        // ||u|| <= delta and u_t >= -?? — we just sample u on the sphere
        // and take phi(u) = sum u² b² + 2|u| b |a| + a² (the inner Cauchy-
        // Schwarz max over directions), which matches the paper's phi.
        let t = a.len();
        let mut best = f64::MIN;
        for _ in 0..20_000 {
            let mut u: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            let n = crate::linalg::nrm2_f64(&u).max(1e-300);
            let scale = delta * rng.uniform().powf(0.3) / n;
            for v in u.iter_mut() {
                *v *= scale;
            }
            let val: f64 = (0..t)
                .map(|i| {
                    let b = b2[i].sqrt();
                    u[i] * u[i] * b2[i] + 2.0 * u[i].abs() * b * a[i].abs() + a[i] * a[i]
                })
                .sum();
            best = best.max(val);
        }
        best
    }

    #[test]
    fn newton_matches_bruteforce() {
        let mut rng = Pcg64::new(21);
        for _ in 0..30 {
            let t = 1 + rng.below(5) as usize;
            let a: Vec<f64> = (0..t).map(|_| rng.normal() * 2.0).collect();
            let b2: Vec<f64> = (0..t).map(|_| rng.normal().abs() + 0.01).collect();
            let delta = rng.uniform() * 3.0 + 0.01;
            let got = qp1qc_max(&a, &b2, delta);
            let brute = brute_max(&a, &b2, delta, &mut rng);
            assert!(
                got.s >= brute - 1e-8,
                "certified max below sampled value: {} < {brute}",
                got.s
            );
            assert!(
                got.s <= brute * 1.05 + 1e-6,
                "certified max too loose: {} vs {brute}",
                got.s
            );
        }
    }

    #[test]
    fn trivial_branch_delta_zero() {
        let r = qp1qc_max(&[1.0, -2.0], &[1.0, 1.0], 0.0);
        assert_eq!(r.branch, Branch::Trivial);
        assert!((r.s - 5.0).abs() < 1e-12);
    }

    #[test]
    fn trivial_branch_zero_feature() {
        let r = qp1qc_max(&[0.0, 0.0], &[0.0, 0.0], 2.0);
        assert_eq!(r.branch, Branch::Trivial);
        assert_eq!(r.s, 0.0);
    }

    #[test]
    fn closed_branch_pure_quadratic() {
        // all a = 0: s = ρ²Δ², α* = 2ρ²
        let r = qp1qc_max(&[0.0, 0.0, 0.0], &[4.0, 1.0, 0.5], 3.0);
        assert_eq!(r.branch, Branch::Closed);
        assert!((r.s - 4.0 * 9.0).abs() < 1e-12);
        assert!((r.alpha - 8.0).abs() < 1e-12);
    }

    #[test]
    fn closed_branch_formula() {
        // a = 0 exactly on the max-norm task, small elsewhere, big Δ
        let a = [0.0, 0.1];
        let b2 = [4.0, 1.0];
        let delta = 10.0;
        let r = qp1qc_max(&a, &b2, delta);
        assert_eq!(r.branch, Branch::Closed);
        let ubar1 = 0.2 / 6.0; // c_1/(amin - beta_1) = 0.2/(8-2)
        let want = 0.01 + 4.0 * delta * delta + 0.5 * 0.2 * ubar1;
        assert!((r.s - want).abs() < 1e-10, "{} vs {want}", r.s);
    }

    #[test]
    fn newton_alpha_on_boundary_constraint() {
        // for the Newton branch, ||u(alpha*)|| must equal delta
        let a = [1.5, -0.7, 0.2];
        let b2 = [2.0, 1.0, 0.3];
        let delta = 0.8;
        let r = qp1qc_max(&a, &b2, delta);
        assert_eq!(r.branch, Branch::Newton);
        let un2: f64 = (0..3)
            .map(|i| {
                let c = 2.0 * b2[i].sqrt() * a[i].abs();
                (c / (r.alpha - 2.0 * b2[i])).powi(2)
            })
            .sum();
        assert!(
            (un2.sqrt() - delta).abs() < 1e-10 * delta,
            "||u||={} delta={delta}",
            un2.sqrt()
        );
        assert!(r.newton_iters <= 20, "Newton took {} iters", r.newton_iters);
    }

    #[test]
    fn monotone_in_delta() {
        let a = [0.5, -1.0];
        let b2 = [1.0, 2.0];
        let mut prev = f64::MIN;
        for k in 0..20 {
            let delta = k as f64 * 0.2;
            let s = qp1qc_max(&a, &b2, delta).s;
            assert!(s >= prev - 1e-12);
            prev = s;
        }
    }

    #[test]
    fn center_score_lower_bounds() {
        // s >= g(center) = sum a^2 always
        let mut rng = Pcg64::new(33);
        for _ in 0..200 {
            let t = 1 + rng.below(6) as usize;
            let a: Vec<f64> = (0..t).map(|_| rng.normal()).collect();
            let b2: Vec<f64> = (0..t).map(|_| rng.normal().abs()).collect();
            let delta = rng.uniform() * 2.0;
            let ssq: f64 = a.iter().map(|v| v * v).sum();
            assert!(qp1qc_max(&a, &b2, delta).s >= ssq - 1e-12);
        }
    }

    #[test]
    fn single_task_closed_form() {
        // T=1: s = (|a| + bΔ)² exactly (Cauchy–Schwarz is tight)
        let a = [1.3];
        let b2 = [2.2];
        let delta = 0.9;
        let want = (1.3f64 + 2.2f64.sqrt() * delta).powi(2);
        let got = qp1qc_max(&a, &b2, delta).s;
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }
}
