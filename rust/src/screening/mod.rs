//! The DPC safe screening rule (the paper's contribution) and its
//! ablations.
//!
//! * [`secular`] — the per-feature QP1QC solve (Theorem 7 / Gay 1981);
//! * [`dpc`] — Theorem 5 ball + Theorem 8 / Corollary 9 rule;
//! * [`bounds`] — cheaper-but-looser score bounds (ablation ABL1);
//! * [`safety`] — post-hoc verifier that no active feature was rejected.

pub mod bounds;
pub mod dpc;
pub mod safety;
pub mod secular;

/// What a screener returns for one λ step.
#[derive(Debug, Clone)]
pub struct ScreenOutcome {
    /// certified-inactive features (safe to delete at this λ)
    pub rejected: Vec<bool>,
    /// raw scores s_l (max of g_l over the ball); s_l < 1 ⇒ rejected
    pub scores: Vec<f64>,
    /// ball radius used
    pub delta: f64,
}

impl ScreenOutcome {
    pub fn kept_indices(&self) -> Vec<usize> {
        self.rejected
            .iter()
            .enumerate()
            .filter_map(|(l, &r)| (!r).then_some(l))
            .collect()
    }

    pub fn num_rejected(&self) -> usize {
        self.rejected.iter().filter(|&&r| r).count()
    }
}
