//! The DPC safe screening rule (the paper's contribution), its gap-safe
//! extension, and its ablations.
//!
//! * [`secular`] — the per-feature QP1QC solve (Theorem 7 / Gay 1981);
//! * [`dpc`] — Theorem 5 ball + Theorem 8 / Corollary 9 rule, with the
//!   gap-inflated cut that keeps the sequential rule safe when the λ0
//!   reference comes from a finite-tolerance solve (DESIGN.md §9);
//! * [`gap`] — GAP-safe balls certified by the duality gap of any
//!   primal/dual feasible pair (Ndiaye et al.), usable per-λ and
//!   *dynamically inside the solver loop* as the gap shrinks;
//! * [`bounds`] — cheaper-but-looser score bounds (ablation ABL1);
//! * [`safety`] — post-hoc verifier that no active feature was rejected;
//! * [`shard`] — screen-before-load: the same DPC/GapSafe rules evaluated
//!   block-by-block against an out-of-core shard, so datasets that never
//!   fit in RAM are screened before they are (partially) loaded
//!   (DESIGN.md §10).
//!
//! Inexact-reference policy (DESIGN.md §9): every ball the exact engine
//! screens with is certified — either closed-form (λ_max) or inflated by a
//! duality-gap bound on the reference error. There is deliberately **no**
//! `margin` knob on the exact engine: a margin is a guess, a gap is a
//! certificate.

pub mod bounds;
pub mod dpc;
pub mod gap;
pub mod safety;
pub mod secular;
pub mod shard;

use crate::data::Dataset;
use crate::ops::Stacked;
use crate::util::{parallel_chunks, serial_below};

/// What a screener returns for one λ step.
#[derive(Debug, Clone)]
pub struct ScreenOutcome {
    /// certified-inactive features (safe to delete at this λ)
    pub rejected: Vec<bool>,
    /// raw scores s_l (max of g_l over the ball); s_l < 1 ⇒ rejected
    pub scores: Vec<f64>,
    /// ball radius used
    pub delta: f64,
}

impl ScreenOutcome {
    /// Surviving feature indices, ascending (the solver's column set).
    pub fn kept_indices(&self) -> Vec<usize> {
        self.rejected
            .iter()
            .enumerate()
            .filter_map(|(l, &r)| (!r).then_some(l))
            .collect()
    }

    /// Number of certified-inactive features.
    pub fn num_rejected(&self) -> usize {
        self.rejected.iter().filter(|&&r| r).count()
    }
}

/// Theorem-7 scores s_l = max g_l over the ball (o, Δ) for all features —
/// the sweep shared by the DPC and GAP-safe screeners. Parallel over
/// feature chunks on the persistent executor, gated by the shared
/// [`serial_below`] policy on the dataset's *stored* sweep work so sparse
/// CSC problems are not pooled as if they were dense. The correlation
/// moments come from the same cache-blocked panels as `task_corr`
/// ([`crate::ops::corr_chunk`]); only the per-feature secular solve is
/// local. `b2` is the cached (d × T) row-major column-squared-norm table.
///
/// ℓ2,1-specialized alias: delegates to [`ball_scores_for`] with the
/// [`crate::penalty::L21`] instance, whose chunk body is the exact
/// per-feature `qp1qc_max` loop this function always ran — bit-identical.
pub fn ball_scores(ds: &Dataset, b2: &[f64], o: &Stacked, delta: f64) -> Vec<f64> {
    ball_scores_for(ds, b2, o, delta, &crate::penalty::L21)
}

/// Penalty-generic ball-score sweep (DESIGN.md §14): the executor layout —
/// chunking, `serial_below` gating, cache-blocked `corr_chunk` panels —
/// stays here, while the per-chunk score math is the penalty's
/// [`crate::penalty::Penalty::ball_scores`]. Scores keep the universal
/// contract: s_l < 1 certifies row l inactive over the whole ball.
pub fn ball_scores_for(
    ds: &Dataset,
    b2: &[f64],
    o: &Stacked,
    delta: f64,
    pen: &dyn crate::penalty::Penalty,
) -> Vec<f64> {
    let t_count = ds.t();
    debug_assert_eq!(b2.len(), ds.d * t_count);
    let workers = if serial_below(ds.sweep_work()) { 1 } else { usize::MAX };
    let out = parallel_chunks(ds.d, workers, |_, start, end| {
        let corr = crate::ops::corr_chunk(ds, start, end, o);
        pen.ball_scores(&corr, &b2[start * t_count..end * t_count], t_count, delta)
    });
    out.concat()
}
