//! Screen-before-load: DPC / GAP-safe screening evaluated directly on an
//! out-of-core [`ShardedDataset`], block by block (DESIGN.md §10).
//!
//! The insight that makes this work: every ball the screeners use is an
//! O(N) object (a stacked dual center plus a radius), and the Theorem-7
//! score of a feature depends only on that ball and the feature's own
//! columns. So a column block can be faulted in, scored against the ball,
//! and discarded — no state about it survives except one bit (kept /
//! rejected) and its b² moments. Peak memory is the block cache plus the
//! O(d) score/keep vectors, never the matrix.
//!
//! The sweeps here mirror their in-RAM twins call-for-call
//! ([`super::ball_scores`], [`crate::ops::duality_gap`],
//! [`super::dpc::DualRef::from_solution`]), so a sharded screen produces
//! **bit-identical keep-sets** to the dense/CSC path on the same data —
//! the parity contract `rust/tests/shard_backend.rs` pins down.

use super::dpc::{ball_from_y, DualRef};
use super::gap::certified_radius;
use super::{ball_scores, ScreenOutcome};
use crate::data::ShardedDataset;
use crate::ops::{self, Stacked};
use anyhow::Result;

/// The out-of-core screener: caches the λ-independent b² column-norm
/// table (one streaming pass at construction) and scores every later ball
/// with one block-streamed sweep.
pub struct ShardScreener {
    /// (d × T) row-major ‖x_l^{(t)}‖², streamed once
    b2: Vec<f64>,
}

impl ShardScreener {
    /// Build the screener with one streaming b² pass over the shard.
    pub fn new(sh: &ShardedDataset) -> Result<Self> {
        Ok(ShardScreener { b2: ops::stream_col_sqnorms(sh)? })
    }

    /// Theorem-7 scores s_l over the ball (o, Δ) for every feature,
    /// streamed block-by-block with the shard's prefetch pipeline (block
    /// b+1 decodes while block b is scored — DESIGN.md §11). Bit-identical
    /// per column to [`super::dpc::DpcScreener::scores`] on the
    /// materialized dataset: consumption order is block order regardless
    /// of prefetch.
    pub fn scores(&self, sh: &ShardedDataset, o: &Stacked, delta: f64) -> Result<Vec<f64>> {
        let t_count = sh.t();
        let mut out = vec![0.0f64; sh.d()];
        sh.for_each_block_pipelined(|b, blk| {
            let range = sh.block_range(b);
            let b2_slice = &self.b2[range.start * t_count..range.end * t_count];
            let part = ball_scores(blk, b2_slice, o, delta);
            out[range].copy_from_slice(&part);
            Ok(())
        })?;
        Ok(out)
    }

    /// Screen with an explicit ball (the GAP-safe entry point — the
    /// caller certifies (o, Δ) from a duality gap).
    pub fn screen_ball(
        &self,
        sh: &ShardedDataset,
        o: &Stacked,
        delta: f64,
    ) -> Result<ScreenOutcome> {
        let scores = self.scores(sh, o, delta)?;
        let rejected = scores.iter().map(|&s| s < 1.0).collect();
        Ok(ScreenOutcome { rejected, scores, delta })
    }

    /// Full DPC step (Theorem 8 / Corollary 9) at λ from a gap-certified
    /// reference at λ0 ≥ λ. `y` is the shard's stacked response
    /// ([`ShardedDataset::y64`], cached by the caller across the grid).
    pub fn screen(
        &self,
        sh: &ShardedDataset,
        y: &Stacked,
        dref: &DualRef,
        lam: f64,
    ) -> Result<ScreenOutcome> {
        assert!(
            lam <= dref.lam0 * (1.0 + 1e-12),
            "DPC requires lam <= lam0 (got {lam} > {})",
            dref.lam0
        );
        let (o, delta) = ball_from_y(y, dref, lam);
        self.screen_ball(sh, &o, delta)
    }
}

/// The (obj, gap, θ_feasible) triple of [`crate::ops::duality_gap`],
/// evaluated against a shard: the primal objective, the duality gap, and
/// the dual-feasible scaling of the residual.
pub struct StreamedGap {
    /// primal objective P(W) at the evaluated solution
    pub obj: f64,
    /// duality gap P(W) − D(θ) (certifies every ball built from this)
    pub gap: f64,
    /// the dual-feasible scaled residual
    pub theta: Stacked,
}

/// Evaluate the duality-gap state at `lam` from a residual `r = X W − y`
/// and `penalty_value` = Ω(W), the penalty value of the W that produced
/// it (the ℓ2,1 norm here — see below). The feasibility scaling needs
/// max_l g_l over *all* features — that is the one full streamed sweep
/// sequential screening re-pays per grid point. Matches
/// [`crate::ops::duality_gap`] on the materialized dataset bit-for-bit
/// (same residual, same per-column dots, same fold).
///
/// Penalty scope (DESIGN.md §14): the streamed feasibility scaling is the
/// ℓ2,1 rule (max √g over streamed g-scores), so the sharded path is
/// ℓ2,1-only for now; `run_path_sharded` rejects other penalties up
/// front. Generalizing needs a streamed analogue of
/// `Penalty::infeasibility` — noted in ROADMAP.
pub fn streamed_gap(
    sh: &ShardedDataset,
    y: &Stacked,
    lam: f64,
    r: &Stacked,
    penalty_value: f64,
) -> Result<StreamedGap> {
    let obj = 0.5 * ops::stacked_sqnorm(r) + lam * penalty_value;
    let z = ops::stacked_scale(r, -1.0 / lam);
    let m = ops::stream_gscore(sh, &z)?.into_iter().fold(0.0f64, f64::max).sqrt();
    let theta = if m > 1.0 { ops::stacked_scale(&z, 1.0 / m) } else { z };
    let dual = ops::dual_obj(y, &theta, lam);
    Ok(StreamedGap { obj, gap: obj - dual, theta })
}

/// Sequential DPC reference from a streamed gap state — the sharded
/// analogue of [`DualRef::from_solution`]: same dual-feasible point, same
/// Eq. 20 normal, same √(2·gap)/λ0 certificate.
pub fn dual_ref_from_streamed(y: &Stacked, lam0: f64, sg: &StreamedGap) -> DualRef {
    let normal =
        ops::stacked_scale_add(&ops::stacked_scale(y, 1.0 / lam0), -1.0, &sg.theta);
    DualRef {
        lam0,
        theta0: sg.theta.clone(),
        normal,
        eps: certified_radius(sg.gap, lam0),
    }
}

/// The closed-form λ_max reference (Theorem 1 + Eq. 20 case 2) streamed:
/// one g-sweep for λ_max, then a single block load for the argmax
/// column's gradient normal. Returns (reference, λ_max).
pub fn dual_ref_at_lambda_max(sh: &ShardedDataset) -> Result<(DualRef, f64)> {
    let (lmax, lstar, _) = ops::stream_lambda_max(sh)?;
    let y = sh.y64();
    let theta0 = ops::stacked_scale(&y, 1.0 / lmax);
    let b = sh.block_of(lstar);
    let blk = sh.block(b)?;
    let local = lstar - sh.block_range(b).start;
    // Eq. 20 case 2, written out because block tasks carry no y (the
    // responses are header-resident): n_t = 2 <x_{l*}^{(t)}, y_t/λmax>
    // x_{l*}^{(t)} — same kernels, same order as `ops::normal_at_lmax`
    let normal: Stacked = blk
        .tasks
        .iter()
        .enumerate()
        .map(|(ti, task)| {
            let col = task.col(local);
            let c = 2.0 * col.dot_f32(&sh.y()[ti]) / lmax;
            let mut out = vec![0.0f64; task.n];
            col.axpy_into(c, &mut out);
            out
        })
        .collect();
    Ok((DualRef { lam0: lmax, theta0, normal, eps: 0.0 }, lmax))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::io::save_sharded;
    use crate::data::synthetic::{synthetic1, SynthOptions};
    use crate::data::Dataset;
    use crate::screening::dpc::DpcScreener;
    use crate::solver::{fista, SolveOptions};

    fn problem() -> Dataset {
        synthetic1(&SynthOptions { t: 3, n: 11, d: 64, seed: 41, ..Default::default() }).0
    }

    fn sharded(ds: &Dataset, tag: &str) -> (ShardedDataset, std::path::PathBuf) {
        let p = std::env::temp_dir()
            .join(format!("mtfl_scrshard_{}_{tag}.mtd3", std::process::id()));
        // narrow blocks so the streamed sweeps genuinely cross boundaries
        save_sharded(ds, &p, 11 * 3 * 4 * 5).unwrap();
        let sh = ShardedDataset::open(&p).unwrap();
        assert!(sh.n_blocks() > 3, "want multiple blocks, got {}", sh.n_blocks());
        (sh, p)
    }

    #[test]
    fn lambda_max_reference_matches_in_ram() {
        let ds = problem();
        let (sh, p) = sharded(&ds, "lmaxref");
        let (dref_ram, lmax_ram) = DualRef::at_lambda_max(&ds);
        let (dref_sh, lmax_sh) = dual_ref_at_lambda_max(&sh).unwrap();
        assert_eq!(lmax_sh.to_bits(), lmax_ram.to_bits());
        assert_eq!(dref_sh.lam0.to_bits(), dref_ram.lam0.to_bits());
        assert_eq!(dref_sh.theta0, dref_ram.theta0);
        assert_eq!(dref_sh.normal, dref_ram.normal);
        assert_eq!(dref_sh.eps, 0.0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn streamed_scores_and_keep_sets_match_dense_screener() {
        let ds = problem();
        let (sh, p) = sharded(&ds, "scores");
        let (dref, lmax) = DualRef::at_lambda_max(&ds);
        let y = sh.y64();
        let in_ram = DpcScreener::new(&ds);
        let streamed = ShardScreener::new(&sh).unwrap();
        for ratio in [0.9, 0.6, 0.35] {
            let lam = ratio * lmax;
            let a = in_ram.screen(&ds, &dref, lam);
            let b = streamed.screen(&sh, &y, &dref, lam).unwrap();
            assert_eq!(a.delta.to_bits(), b.delta.to_bits(), "ratio {ratio}");
            for l in 0..ds.d {
                assert_eq!(
                    a.scores[l].to_bits(),
                    b.scores[l].to_bits(),
                    "score mismatch at feature {l}, ratio {ratio}"
                );
            }
            assert_eq!(a.rejected, b.rejected, "keep-set mismatch at ratio {ratio}");
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn streamed_gap_matches_duality_gap_on_solution() {
        let ds = problem();
        let (sh, p) = sharded(&ds, "gap");
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.4 * lmax;
        let sol = fista(&ds, lam, None, &SolveOptions::default());
        let (obj_ram, gap_ram, theta_ram) = ops::duality_gap(&ds, &sol.w, lam);
        // the streamed form takes the residual + l21 the solver already has
        let r = ops::residual(&ds, &sol.w);
        let l21 = ops::l21_norm(&sol.w, ds.t());
        let y = sh.y64();
        let sg = streamed_gap(&sh, &y, lam, &r, l21).unwrap();
        assert_eq!(sg.obj.to_bits(), obj_ram.to_bits());
        assert_eq!(sg.gap.to_bits(), gap_ram.to_bits());
        assert_eq!(sg.theta, theta_ram);
        // and the sequential reference built from it matches from_solution
        let dref_ram = DualRef::from_solution(&ds, lam, &sol.w);
        let dref_sh = dual_ref_from_streamed(&y, lam, &sg);
        assert_eq!(dref_sh.theta0, dref_ram.theta0);
        assert_eq!(dref_sh.normal, dref_ram.normal);
        assert_eq!(dref_sh.eps.to_bits(), dref_ram.eps.to_bits());
        std::fs::remove_file(&p).ok();
    }
}
