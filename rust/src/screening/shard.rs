//! Screen-before-load: DPC / GAP-safe screening evaluated directly on an
//! out-of-core [`ShardedDataset`], block by block (DESIGN.md §10).
//!
//! The insight that makes this work: every ball the screeners use is an
//! O(N) object (a stacked dual center plus a radius), and the Theorem-7
//! score of a feature depends only on that ball and the feature's own
//! columns. So a column block can be faulted in, scored against the ball,
//! and discarded — no state about it survives except one bit (kept /
//! rejected) and its b² moments. Peak memory is the block cache plus the
//! O(d) score/keep vectors, never the matrix.
//!
//! The sweeps here mirror their in-RAM twins call-for-call
//! ([`super::ball_scores`], [`crate::ops::duality_gap`],
//! [`super::dpc::DualRef::from_solution`]), so a sharded screen produces
//! **bit-identical keep-sets** to the dense/CSC path on the same data —
//! the parity contract `rust/tests/shard_backend.rs` pins down.
//!
//! Every streamed sweep writes one contiguous per-block slice of a d- (or
//! d×T-) length output and the scalar folds happen once on the fully
//! assembled vector — that shape is what makes the sweeps distributable
//! (DESIGN.md §16): the [`ShardSweeps`] seam abstracts "produce the full
//! sweep vector", [`LocalSweeps`] streams it from the local shard, and
//! `coordinator::distrib` fans block ranges out to worker processes and
//! reassembles [`SweepPart`]s in fixed block order ([`merge_parts`]) —
//! bit-identical to the single-process sweep by construction.

use super::dpc::{ball_from_y, DualRef};
use super::gap::certified_radius;
use super::ScreenOutcome;
use crate::data::ShardedDataset;
use crate::ops::{self, Stacked};
use crate::penalty::{Penalty, PenaltyKind};
use anyhow::Result;
use std::ops::Range;

/// The out-of-core screener: caches the λ-independent b² column-norm
/// table (one streaming pass at construction) and scores every later ball
/// with one block-streamed sweep.
pub struct ShardScreener {
    /// (d × T) row-major ‖x_l^{(t)}‖², streamed once
    b2: Vec<f64>,
}

impl ShardScreener {
    /// Build the screener with one streaming b² pass over the shard.
    pub fn new(sh: &ShardedDataset) -> Result<Self> {
        Ok(ShardScreener { b2: ops::stream_col_sqnorms(sh)? })
    }

    /// Theorem-7 scores s_l over the ball (o, Δ) for every feature,
    /// streamed block-by-block with the shard's prefetch pipeline (block
    /// b+1 decodes while block b is scored — DESIGN.md §11). Bit-identical
    /// per column to [`super::dpc::DpcScreener::scores`] on the
    /// materialized dataset: consumption order is block order regardless
    /// of prefetch.
    pub fn scores(&self, sh: &ShardedDataset, o: &Stacked, delta: f64) -> Result<Vec<f64>> {
        self.scores_for(sh, o, delta, &crate::penalty::L21)
    }

    /// [`Self::scores`] generalized over the penalty seam: the per-block
    /// score math is the penalty's [`Penalty::ball_scores`] (via
    /// [`super::ball_scores_for`]), the streaming layout is unchanged.
    /// For ℓ2,1 this is the identical call chain as [`Self::scores`].
    pub fn scores_for(
        &self,
        sh: &ShardedDataset,
        o: &Stacked,
        delta: f64,
        pen: &dyn Penalty,
    ) -> Result<Vec<f64>> {
        let t_count = sh.t();
        let mut out = vec![0.0f64; sh.d()];
        sh.for_each_block_pipelined(|b, blk| {
            let range = sh.block_range(b);
            let b2_slice = &self.b2[range.start * t_count..range.end * t_count];
            let part = super::ball_scores_for(blk, b2_slice, o, delta, pen);
            out[range].copy_from_slice(&part);
            Ok(())
        })?;
        Ok(out)
    }

    /// Screen with an explicit ball (the GAP-safe entry point — the
    /// caller certifies (o, Δ) from a duality gap).
    pub fn screen_ball(
        &self,
        sh: &ShardedDataset,
        o: &Stacked,
        delta: f64,
    ) -> Result<ScreenOutcome> {
        let scores = self.scores(sh, o, delta)?;
        let rejected = scores.iter().map(|&s| s < 1.0).collect();
        Ok(ScreenOutcome { rejected, scores, delta })
    }

    /// Full DPC step (Theorem 8 / Corollary 9) at λ from a gap-certified
    /// reference at λ0 ≥ λ. `y` is the shard's stacked response
    /// ([`ShardedDataset::y64`], cached by the caller across the grid).
    pub fn screen(
        &self,
        sh: &ShardedDataset,
        y: &Stacked,
        dref: &DualRef,
        lam: f64,
    ) -> Result<ScreenOutcome> {
        assert!(
            lam <= dref.lam0 * (1.0 + 1e-12),
            "DPC requires lam <= lam0 (got {lam} > {})",
            dref.lam0
        );
        let (o, delta) = ball_from_y(y, dref, lam);
        self.screen_ball(sh, &o, delta)
    }
}

/// The (obj, gap, θ_feasible) triple of [`crate::ops::duality_gap`],
/// evaluated against a shard: the primal objective, the duality gap, and
/// the dual-feasible scaling of the residual.
pub struct StreamedGap {
    /// primal objective P(W) at the evaluated solution
    pub obj: f64,
    /// duality gap P(W) − D(θ) (certifies every ball built from this)
    pub gap: f64,
    /// the dual-feasible scaled residual
    pub theta: Stacked,
}

/// Evaluate the duality-gap state at `lam` from a residual `r = X W − y`
/// and `penalty_value` = Ω(W), the penalty value of the W that produced
/// it. The feasibility scaling needs the penalty's infeasibility over
/// *all* features — that is the one full streamed sweep sequential
/// screening re-pays per grid point. The per-feature half streams
/// block-by-block ([`crate::ops::stream_infeas_features`]) and the
/// global fold runs once ([`Penalty::infeas_finish`]); for ℓ2,1 this
/// matches [`crate::ops::duality_gap`] on the materialized dataset
/// bit-for-bit (same residual, same per-column dots, same
/// first-strict-maximum fold — `g_l ≥ 0` makes the witness-carrying fold
/// equal to the plain `max` the pre-seam code used).
pub fn streamed_gap(
    sh: &ShardedDataset,
    y: &Stacked,
    lam: f64,
    r: &Stacked,
    penalty_value: f64,
    pen: &dyn Penalty,
) -> Result<StreamedGap> {
    gap_from_sweep(y, lam, r, penalty_value, pen, &mut |z| {
        ops::stream_infeas_features(sh, z, pen)
    })
}

/// The engine behind [`streamed_gap`], parameterized over how the
/// per-feature infeasibility statistics of the scaled residual are
/// produced — a local block stream ([`streamed_gap`]) or a distributed
/// fan-out (`coordinator::distrib`). Everything else (objective, dual
/// scaling, dual objective) is O(N)/O(d) math on the coordinator, so the
/// two providers yield bit-identical gap states whenever their sweep
/// vectors are bit-identical.
pub fn gap_from_sweep(
    y: &Stacked,
    lam: f64,
    r: &Stacked,
    penalty_value: f64,
    pen: &dyn Penalty,
    infeas: &mut dyn FnMut(&Stacked) -> Result<Vec<f64>>,
) -> Result<StreamedGap> {
    let obj = 0.5 * ops::stacked_sqnorm(r) + lam * penalty_value;
    let z = ops::stacked_scale(r, -1.0 / lam);
    let (m, _) = pen.infeas_finish(&infeas(&z)?);
    let theta = if m > 1.0 { ops::stacked_scale(&z, 1.0 / m) } else { z };
    let dual = ops::dual_obj(y, &theta, lam);
    Ok(StreamedGap { obj, gap: obj - dual, theta })
}

/// Sequential DPC reference from a streamed gap state — the sharded
/// analogue of [`DualRef::from_solution`]: same dual-feasible point, same
/// Eq. 20 normal, same √(2·gap)/λ0 certificate.
pub fn dual_ref_from_streamed(y: &Stacked, lam0: f64, sg: &StreamedGap) -> DualRef {
    let normal =
        ops::stacked_scale_add(&ops::stacked_scale(y, 1.0 / lam0), -1.0, &sg.theta);
    DualRef {
        lam0,
        theta0: sg.theta.clone(),
        normal,
        eps: certified_radius(sg.gap, lam0),
    }
}

/// The closed-form λ_max reference (Theorem 1 + Eq. 20 case 2) streamed:
/// one g-sweep for λ_max, then a single block load for the argmax
/// column's gradient normal. Returns (reference, λ_max).
pub fn dual_ref_at_lambda_max(sh: &ShardedDataset) -> Result<(DualRef, f64)> {
    let (lmax, lstar, _) = ops::stream_lambda_max(sh)?;
    let dref = dual_ref_from_witness(sh, &sh.y64(), lmax, lstar)?;
    Ok((dref, lmax))
}

/// Build the λ_max [`DualRef`] from an already-computed (λ_max, witness
/// feature) pair — the tail of [`dual_ref_at_lambda_max`], split out so
/// a caller that obtained the pair from a *distributed* infeasibility
/// sweep (or any [`ShardSweeps`]) pays only the single witness-block
/// load here. The g-sweep fold and this constructor compose to exactly
/// [`dual_ref_at_lambda_max`].
pub fn dual_ref_from_witness(
    sh: &ShardedDataset,
    y: &Stacked,
    lmax: f64,
    lstar: usize,
) -> Result<DualRef> {
    let theta0 = ops::stacked_scale(y, 1.0 / lmax);
    let b = sh.block_of(lstar);
    let blk = sh.block(b)?;
    let local = lstar - sh.block_range(b).start;
    // Eq. 20 case 2, written out because block tasks carry no y (the
    // responses are header-resident): n_t = 2 <x_{l*}^{(t)}, y_t/λmax>
    // x_{l*}^{(t)} — same kernels, same order as `ops::normal_at_lmax`
    let normal: Stacked = blk
        .tasks
        .iter()
        .enumerate()
        .map(|(ti, task)| {
            let col = task.col(local);
            let c = 2.0 * col.dot_f32(&sh.y()[ti]) / lmax;
            let mut out = vec![0.0f64; task.n];
            col.axpy_into(c, &mut out);
            out
        })
        .collect();
    Ok(DualRef { lam0: lmax, theta0, normal, eps: 0.0 })
}

// ---------------------------------------------------------------------------
// the distribution seam (DESIGN.md §16)
// ---------------------------------------------------------------------------

/// One contiguous slice of a streamed sweep: the values for columns
/// `cols` of the full d-length (stride 1) or d×T-length (stride T)
/// sweep vector. Workers return these; [`merge_parts`] reassembles.
#[derive(Debug, Clone)]
pub struct SweepPart {
    /// the feature (column) range this part covers
    pub cols: Range<usize>,
    /// `(cols.len() × stride)` values, in ascending column order
    pub values: Vec<f64>,
}

/// Merge sweep parts into the full `d × stride` vector **in fixed column
/// order** — the bit-parity rule of DESIGN.md §16: every per-block slice
/// lands at the offset the single-process sweep would have written it
/// to, so the merged vector is bit-identical no matter which worker
/// produced which part or in what order replies arrived. Errors if the
/// parts do not tile `0..d` exactly (a gap, overlap, or short part means
/// a lost or duplicated block range — never silently screen on that).
pub fn merge_parts(d: usize, stride: usize, mut parts: Vec<SweepPart>) -> Result<Vec<f64>> {
    parts.sort_by_key(|p| p.cols.start);
    let mut out = Vec::with_capacity(d * stride);
    let mut next = 0usize;
    for p in &parts {
        anyhow::ensure!(
            p.cols.start == next && p.cols.end <= d,
            "sweep parts do not tile the column range: part {:?} at column {next} of {d}",
            p.cols
        );
        anyhow::ensure!(
            p.values.len() == (p.cols.end - p.cols.start) * stride,
            "sweep part {:?} carries {} values, want {} (stride {stride})",
            p.cols,
            p.values.len(),
            (p.cols.end - p.cols.start) * stride
        );
        out.extend_from_slice(&p.values);
        next = p.cols.end;
    }
    anyhow::ensure!(next == d, "sweep parts cover only {next} of {d} columns");
    Ok(out)
}

/// The sweep provider a sharded path run screens through: "produce the
/// full d-length sweep vector for this ball / this dual point". The
/// single-process path streams from the local shard ([`LocalSweeps`]);
/// the distributed coordinator (`coordinator::distrib`) fans block
/// ranges out to worker processes and merges their [`SweepPart`]s. The
/// path core is written against this trait, so both modes execute the
/// *same* grid loop — the bit-parity contract reduces to "same sweep
/// vectors in, same keep-sets and solutions out".
pub trait ShardSweeps {
    /// Theorem-7 / penalty ball scores over the ball `(o, delta)`, one
    /// per feature (the screening sweep).
    fn ball_scores(&mut self, o: &Stacked, delta: f64) -> Result<Vec<f64>>;

    /// Per-feature infeasibility statistics of the dual point `z`
    /// ([`Penalty::infeas_features`] streamed over all blocks) — the
    /// caller folds with [`Penalty::infeas_finish`].
    fn infeas_features(&mut self, z: &Stacked) -> Result<Vec<f64>>;

    /// Grid-step barrier: called once after every λ step with the step
    /// index, λ, and the surviving feature count. Single-process sweeps
    /// ignore it; the distributed provider uses it to broadcast the
    /// merged step summary and collect worker ledgers (DESIGN.md §16).
    fn step_done(&mut self, _step: usize, _lam: f64, _kept: usize) -> Result<()> {
        Ok(())
    }
}

/// [`ShardSweeps`] over the local shard: the screener's cached b² table
/// plus the block-streamed sweeps this module already provides. This is
/// exactly what `run_path_sharded` always executed — the trait's methods
/// delegate to the same functions in the same order.
pub struct LocalSweeps<'a> {
    sh: &'a ShardedDataset,
    pen: PenaltyKind,
    screener: ShardScreener,
}

impl<'a> LocalSweeps<'a> {
    /// Build the provider (one streaming b² pass, as
    /// [`ShardScreener::new`] always cost).
    pub fn new(sh: &'a ShardedDataset, pen: PenaltyKind) -> Result<Self> {
        Ok(LocalSweeps { sh, pen, screener: ShardScreener::new(sh)? })
    }
}

impl ShardSweeps for LocalSweeps<'_> {
    fn ball_scores(&mut self, o: &Stacked, delta: f64) -> Result<Vec<f64>> {
        self.screener.scores_for(self.sh, o, delta, &self.pen)
    }

    fn infeas_features(&mut self, z: &Stacked) -> Result<Vec<f64>> {
        ops::stream_infeas_features(self.sh, z, &self.pen)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::io::save_sharded;
    use crate::data::synthetic::{synthetic1, SynthOptions};
    use crate::data::Dataset;
    use crate::screening::dpc::DpcScreener;
    use crate::solver::{fista, SolveOptions};

    fn problem() -> Dataset {
        synthetic1(&SynthOptions { t: 3, n: 11, d: 64, seed: 41, ..Default::default() }).0
    }

    fn sharded(ds: &Dataset, tag: &str) -> (ShardedDataset, std::path::PathBuf) {
        let p = std::env::temp_dir()
            .join(format!("mtfl_scrshard_{}_{tag}.mtd3", std::process::id()));
        // narrow blocks so the streamed sweeps genuinely cross boundaries
        save_sharded(ds, &p, 11 * 3 * 4 * 5).unwrap();
        let sh = ShardedDataset::open(&p).unwrap();
        assert!(sh.n_blocks() > 3, "want multiple blocks, got {}", sh.n_blocks());
        (sh, p)
    }

    #[test]
    fn lambda_max_reference_matches_in_ram() {
        let ds = problem();
        let (sh, p) = sharded(&ds, "lmaxref");
        let (dref_ram, lmax_ram) = DualRef::at_lambda_max(&ds);
        let (dref_sh, lmax_sh) = dual_ref_at_lambda_max(&sh).unwrap();
        assert_eq!(lmax_sh.to_bits(), lmax_ram.to_bits());
        assert_eq!(dref_sh.lam0.to_bits(), dref_ram.lam0.to_bits());
        assert_eq!(dref_sh.theta0, dref_ram.theta0);
        assert_eq!(dref_sh.normal, dref_ram.normal);
        assert_eq!(dref_sh.eps, 0.0);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn streamed_scores_and_keep_sets_match_dense_screener() {
        let ds = problem();
        let (sh, p) = sharded(&ds, "scores");
        let (dref, lmax) = DualRef::at_lambda_max(&ds);
        let y = sh.y64();
        let in_ram = DpcScreener::new(&ds);
        let streamed = ShardScreener::new(&sh).unwrap();
        for ratio in [0.9, 0.6, 0.35] {
            let lam = ratio * lmax;
            let a = in_ram.screen(&ds, &dref, lam);
            let b = streamed.screen(&sh, &y, &dref, lam).unwrap();
            assert_eq!(a.delta.to_bits(), b.delta.to_bits(), "ratio {ratio}");
            for l in 0..ds.d {
                assert_eq!(
                    a.scores[l].to_bits(),
                    b.scores[l].to_bits(),
                    "score mismatch at feature {l}, ratio {ratio}"
                );
            }
            assert_eq!(a.rejected, b.rejected, "keep-set mismatch at ratio {ratio}");
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn streamed_gap_matches_duality_gap_on_solution() {
        let ds = problem();
        let (sh, p) = sharded(&ds, "gap");
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.4 * lmax;
        let sol = fista(&ds, lam, None, &SolveOptions::default());
        let (obj_ram, gap_ram, theta_ram) = ops::duality_gap(&ds, &sol.w, lam);
        // the streamed form takes the residual + l21 the solver already has
        let r = ops::residual(&ds, &sol.w);
        let l21 = ops::l21_norm(&sol.w, ds.t());
        let y = sh.y64();
        let sg = streamed_gap(&sh, &y, lam, &r, l21, &crate::penalty::L21).unwrap();
        assert_eq!(sg.obj.to_bits(), obj_ram.to_bits());
        assert_eq!(sg.gap.to_bits(), gap_ram.to_bits());
        assert_eq!(sg.theta, theta_ram);
        // and the sequential reference built from it matches from_solution
        let dref_ram = DualRef::from_solution(&ds, lam, &sol.w);
        let dref_sh = dual_ref_from_streamed(&y, lam, &sg);
        assert_eq!(dref_sh.theta0, dref_ram.theta0);
        assert_eq!(dref_sh.normal, dref_ram.normal);
        assert_eq!(dref_sh.eps.to_bits(), dref_ram.eps.to_bits());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn streamed_infeasibility_matches_in_ram_for_every_penalty() {
        // satellite of ROADMAP 4a: infeas_features streamed per block +
        // one infeas_finish fold must equal the in-RAM infeasibility
        // bit-for-bit for all three penalties (GOWL's sort runs on the
        // assembled vector, so block order must not matter)
        let ds = problem();
        let (sh, p) = sharded(&ds, "inf");
        let y = ops::y64(&ds);
        let corr = ops::task_corr(&ds, &y);
        for pk in [
            PenaltyKind::L21,
            PenaltyKind::Sgl { alpha: 0.4 },
            PenaltyKind::Gowl { gamma: 1.5 },
        ] {
            let (want_s, want_l) = pk.infeasibility(&corr, ds.t());
            let feats = ops::stream_infeas_features(&sh, &y, &pk).unwrap();
            let (got_s, got_l) = pk.infeas_finish(&feats);
            assert_eq!(got_s.to_bits(), want_s.to_bits(), "{pk}: scale mismatch");
            assert_eq!(got_l, want_l, "{pk}: witness mismatch");
        }
        // ... and for ℓ2,1 the streamed pair IS stream_lambda_max
        let (lmax, lstar, _) = ops::stream_lambda_max(&sh).unwrap();
        let feats = ops::stream_infeas_features(&sh, &y, &PenaltyKind::L21).unwrap();
        let (s, l) = PenaltyKind::L21.infeas_finish(&feats);
        assert_eq!(s.to_bits(), lmax.to_bits());
        assert_eq!(l, lstar);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn local_sweeps_match_the_raw_streamed_sweeps() {
        let ds = problem();
        let (sh, p) = sharded(&ds, "lsweeps");
        let (dref, lmax) = DualRef::at_lambda_max(&ds);
        let y = sh.y64();
        let lam = 0.5 * lmax;
        let (o, delta) = ball_from_y(&y, &dref, lam);
        let screener = ShardScreener::new(&sh).unwrap();
        let want_scores = screener.scores(&sh, &o, delta).unwrap();
        let mut sweeps = LocalSweeps::new(&sh, PenaltyKind::L21).unwrap();
        let got_scores = sweeps.ball_scores(&o, delta).unwrap();
        for (a, b) in want_scores.iter().zip(&got_scores) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let want_g = ops::stream_gscore(&sh, &y).unwrap();
        let got_g = sweeps.infeas_features(&y).unwrap();
        for (a, b) in want_g.iter().zip(&got_g) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        sweeps.step_done(0, lam, 3).unwrap(); // default barrier is a no-op
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn merge_parts_reassembles_in_column_order() {
        // arrival order must not matter; only column offsets do
        let parts = vec![
            SweepPart { cols: 3..5, values: vec![3.0, 4.0] },
            SweepPart { cols: 0..3, values: vec![0.0, 1.0, 2.0] },
        ];
        let v = merge_parts(5, 1, parts).unwrap();
        assert_eq!(v, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
        // stride > 1 (the b² table shape)
        let parts = vec![
            SweepPart { cols: 1..2, values: vec![2.0, 3.0] },
            SweepPart { cols: 0..1, values: vec![0.0, 1.0] },
        ];
        assert_eq!(merge_parts(2, 2, parts).unwrap(), vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn merge_parts_rejects_gaps_overlaps_and_short_parts() {
        let gap = vec![SweepPart { cols: 1..3, values: vec![1.0, 2.0] }];
        assert!(merge_parts(3, 1, gap).is_err(), "gap at the head must error");
        let overlap = vec![
            SweepPart { cols: 0..2, values: vec![0.0, 1.0] },
            SweepPart { cols: 1..3, values: vec![1.0, 2.0] },
        ];
        assert!(merge_parts(3, 1, overlap).is_err(), "overlap must error");
        let short = vec![SweepPart { cols: 0..2, values: vec![0.0] }];
        assert!(merge_parts(2, 1, short).is_err(), "wrong value count must error");
        let missing_tail = vec![SweepPart { cols: 0..2, values: vec![0.0, 1.0] }];
        assert!(merge_parts(3, 1, missing_tail).is_err(), "uncovered tail must error");
    }
}
