//! The DPC rule: Theorem 5 (ball estimation of θ*(λ)) + Theorem 7 (score
//! maximization) + Theorem 8 / Corollary 9 (the rejection test, sequential
//! along the λ grid).
//!
//! Inexact references (DESIGN.md §9): Theorem 5 assumes the reference
//! θ*(λ0) is the *exact* dual optimum, but along the path the reference
//! comes from a finite-tolerance solve. [`DualRef::from_solution`]
//! therefore stores the dual-feasible projection of the solved residual
//! together with `eps`, a duality-gap certificate on its distance to the
//! true θ*(λ0) ([`super::gap::certified_radius`]). [`ball`] consumes `eps`
//! by shifting Theorem 5's supporting-halfspace cut outward by a provable
//! slack; at `eps = 0` the construction reduces *exactly* to the paper's
//! ball, and at any `eps > 0` it still contains θ*(λ) — no unsound
//! `margin` knob anywhere.
//!
//! Penalty scope (DESIGN.md §14): every construction here — the y/λ_max
//! closed form, the Eq. 20 normal, the projection-halfspace cut — is the
//! geometry of the **ℓ2,1 dual ball** ‖Σ_t x_l^t θ_t‖ ≤ 1 and proves
//! nothing about other feasible sets. DPC therefore stays ℓ2,1-only
//! (`Penalty::supports_dpc_geometry`); other penalties screen through the
//! penalty-generic GAP-safe rule ([`super::gap`]), whose strong-concavity
//! ball never references the feasible set's shape.

use super::{gap, ScreenOutcome};
use crate::data::Dataset;
use crate::ops::{self, Stacked};

/// Reference point for the ball: everything Theorem 5 needs about λ0,
/// plus the gap certificate that makes an inexact reference safe.
#[derive(Debug, Clone)]
pub struct DualRef {
    /// the reference λ0 (screening targets λ ≤ λ0)
    pub lam0: f64,
    /// a dual-feasible approximation of θ*(λ0) (exact at λ_max)
    pub theta0: Stacked,
    /// n(λ0): the Eq. 20 normal direction at `theta0`
    pub normal: Stacked,
    /// certified bound on ‖theta0 − θ*(λ0)‖ (0 for closed-form references)
    pub eps: f64,
}

impl DualRef {
    /// The closed-form reference at λ0 = λ_max (Theorem 1 + Eq. 20 case 2).
    /// Exact, so `eps = 0`.
    pub fn at_lambda_max(ds: &Dataset) -> (Self, f64) {
        let (lmax, lstar, _) = ops::lambda_max(ds);
        let y = ops::y64(ds);
        let theta0 = ops::stacked_scale(&y, 1.0 / lmax);
        let normal = ops::normal_at_lmax(ds, lstar, lmax);
        (DualRef { lam0: lmax, theta0, normal, eps: 0.0 }, lmax)
    }

    /// Reference from a solved primal at λ0 < λ_max: the dual-feasible
    /// scaling of (y − Xw)/λ0 (Eq. 14 + Eq. 15), n(λ0) = y/λ0 − θ0
    /// (Eq. 20 case 1), and `eps = √(2·gap)/λ0` — the strong-concavity
    /// bound on how far the stored point can sit from the true θ*(λ0).
    pub fn from_solution(ds: &Dataset, lam0: f64, w: &[f64]) -> Self {
        let (_, gap0, theta0) = ops::duality_gap(ds, w, lam0);
        let y = ops::y64(ds);
        let normal = ops::stacked_scale_add(&ops::stacked_scale(&y, 1.0 / lam0), -1.0, &theta0);
        let eps = gap::certified_radius(gap0, lam0);
        DualRef { lam0, theta0, normal, eps }
    }
}

/// Ball Θ(λ, λ0) from Theorem 5, generalized to inexact references.
///
/// Geometry: θ*(λ) = P_F(y/λ) and `theta0 ∈ F`, so the projection
/// inequality ⟨y/λ − θ*, theta0 − θ*⟩ ≤ 0 puts θ*(λ) in the *plain* ball
/// with diameter [theta0, y/λ] — valid for any feasible reference, no
/// optimality needed. The Theorem-5 refinement cuts that ball with the
/// supporting halfspace of the normal n; with an inexact reference the
/// true halfspace is only known up to the slack
///
///   ⟨n, θ*(λ) − theta0⟩ ≤ eps·(‖n‖ + 2·eps + ‖y‖·|1/λ − 1/λ0|),
///
/// (expand n = (y/λ0 − θ*(λ0)) + (θ*(λ0) − theta0) and bound each term
/// with ‖θ*(λ0) − theta0‖ ≤ eps plus projection nonexpansiveness). The
/// returned ball is the smallest one enclosing plain-ball ∩ halfspace;
/// at eps = 0 it equals the paper's (o = θ0 + ½r⊥, Δ = ½‖r⊥‖).
pub fn ball(ds: &Dataset, dref: &DualRef, lam: f64) -> (Stacked, f64) {
    ball_from_y(&ops::y64(ds), dref, lam)
}

/// [`ball`] from a precomputed stacked response vector. The out-of-core
/// pipeline (`screening::shard`) goes through this entry point: the
/// shard keeps y resident in its header, and the ball construction is
/// O(N) — it never needs the matrix.
pub fn ball_from_y(y: &Stacked, dref: &DualRef, lam: f64) -> (Stacked, f64) {
    // r = y/λ − θ0 ; plain safe ball: center θ0 + ½r, radius ½‖r‖
    let r = ops::stacked_scale_add(&ops::stacked_scale(y, 1.0 / lam), -1.0, &dref.theta0);
    let o_plain = ops::stacked_scale_add(&dref.theta0, 0.5, &r);
    let delta_plain = 0.5 * ops::stacked_sqnorm(&r).sqrt();
    let nn = ops::stacked_sqnorm(&dref.normal);
    if nn <= 1e-290 {
        return (o_plain, delta_plain);
    }
    let nnorm = nn.sqrt();
    // inexact-reference slack on the halfspace cut (0 for exact refs)
    let slack = if dref.eps > 0.0 {
        let grid_step = ops::stacked_sqnorm(y).sqrt() * (1.0 / lam - 1.0 / dref.lam0).abs();
        dref.eps * (nnorm + 2.0 * dref.eps + grid_step)
    } else {
        0.0
    };
    // signed distance from the plain center to the shifted cut plane
    let t = (0.5 * ops::stacked_dot(&dref.normal, &r) - slack) / nnorm;
    if t <= 0.0 {
        // cut misses the plain ball's far half: no refinement available
        return (o_plain, delta_plain);
    }
    let t = t.min(delta_plain);
    let delta = (delta_plain * delta_plain - t * t).max(0.0).sqrt();
    let o = ops::stacked_scale_add(&o_plain, -t / nnorm, &dref.normal);
    (o, delta)
}

/// The DPC screener. Caches the per-(feature, task) squared column norms —
/// the b² moments of Theorem 7 — which are λ-independent.
pub struct DpcScreener {
    /// (d x T) row-major ‖x_l^{(t)}‖²
    b2: Vec<f64>,
}

impl DpcScreener {
    /// Build the screener, caching the b² table (one O(nnz) sweep).
    pub fn new(ds: &Dataset) -> Self {
        DpcScreener { b2: ds.col_sqnorms() }
    }

    /// Scores s_l for all features given a ball (o, Δ). Parallel over
    /// feature chunks; the a-moments (corr sweep) dominate the cost. The
    /// sweep goes through [`crate::linalg::ColRef`], so on CSC-backed
    /// datasets it touches only stored nonzeros — the paper's sparse
    /// text/genomics regime where screening pays for itself many times
    /// over.
    pub fn scores(&self, ds: &Dataset, o: &Stacked, delta: f64) -> Vec<f64> {
        super::ball_scores(ds, &self.b2, o, delta)
    }

    /// Full DPC step (Theorem 8 / Corollary 9): screen at λ given a
    /// reference at λ0 > λ. Safe at any reference accuracy — the ball
    /// carries the reference's gap certificate.
    pub fn screen(&self, ds: &Dataset, dref: &DualRef, lam: f64) -> ScreenOutcome {
        assert!(
            lam <= dref.lam0 * (1.0 + 1e-12),
            "DPC requires lam <= lam0 (got {lam} > {})",
            dref.lam0
        );
        let (o, delta) = ball(ds, dref, lam);
        let scores = self.scores(ds, &o, delta);
        let rejected = scores.iter().map(|&s| s < 1.0).collect();
        ScreenOutcome { rejected, scores, delta }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, synthetic2, SynthOptions};
    use crate::solver::{fista, SolveOptions};

    fn problem(seed: u64) -> Dataset {
        synthetic1(&SynthOptions { t: 3, n: 12, d: 60, seed, ..Default::default() }).0
    }

    /// θ*(λ) to solver precision, as the dual-feasible scaled residual.
    fn theta_star(ds: &Dataset, lam: f64) -> Stacked {
        let sol = fista(ds, lam, None, &SolveOptions::tight());
        let z = ops::stacked_scale(&ops::residual(ds, &sol.w), -1.0 / lam);
        ops::dual_feasible(ds, z).0
    }

    #[test]
    fn ball_contains_dual_optimum_from_lmax() {
        let ds = problem(1);
        let (dref, lmax) = DualRef::at_lambda_max(&ds);
        for ratio in [0.9, 0.6, 0.3, 0.1] {
            let lam = ratio * lmax;
            let (o, delta) = ball(&ds, &dref, lam);
            let theta = theta_star(&ds, lam);
            let diff = ops::stacked_scale_add(&theta, -1.0, &o);
            let dist = ops::stacked_sqnorm(&diff).sqrt();
            assert!(dist <= delta + 1e-6, "ratio {ratio}: dist {dist} > delta {delta}");
        }
    }

    #[test]
    fn ball_contains_dual_optimum_sequential() {
        let ds = problem(2);
        let (_, lmax) = DualRef::at_lambda_max(&ds);
        let lam0 = 0.5 * lmax;
        let sol0 = fista(&ds, lam0, None, &SolveOptions::tight());
        let dref = DualRef::from_solution(&ds, lam0, &sol0.w);
        for ratio in [0.45, 0.3, 0.2] {
            let lam = ratio * lmax;
            let (o, delta) = ball(&ds, &dref, lam);
            let theta = theta_star(&ds, lam);
            let diff = ops::stacked_scale_add(&theta, -1.0, &o);
            let dist = ops::stacked_sqnorm(&diff).sqrt();
            assert!(dist <= delta + 1e-6, "ratio {ratio}: {dist} > {delta}");
        }
    }

    #[test]
    fn ball_contains_dual_optimum_with_loose_reference() {
        // the bug this PR fixes: at solver tolerance 1e-3 the reference is
        // visibly off θ*(λ0); the gap-inflated cut must keep the ball safe
        let ds = problem(2);
        let (_, lmax) = DualRef::at_lambda_max(&ds);
        let lam0 = 0.5 * lmax;
        let loose = SolveOptions { tol: 1e-3, check_every: 1, ..Default::default() };
        let sol0 = fista(&ds, lam0, None, &loose);
        let dref = DualRef::from_solution(&ds, lam0, &sol0.w);
        assert!(dref.eps > 0.0, "loose solve must yield a nonzero certificate");
        for ratio_of_lam0 in [0.9999, 0.99, 0.9, 0.6] {
            let lam = ratio_of_lam0 * lam0;
            let (o, delta) = ball(&ds, &dref, lam);
            let theta = theta_star(&ds, lam);
            let diff = ops::stacked_scale_add(&theta, -1.0, &o);
            let dist = ops::stacked_sqnorm(&diff).sqrt();
            assert!(
                dist <= delta + 1e-6,
                "inflated ball missed theta* at {ratio_of_lam0}·lam0: {dist} > {delta}"
            );
        }
    }

    #[test]
    fn regression_uninflated_ball_misses_optimum_at_loose_tolerance() {
        // the pre-fix construction: raw residual point, no feasibility
        // scaling, no slack on the cut — Theorem 5 applied as if the
        // reference were exact. At tol 1e-3 it must *fail* to contain
        // θ*(λ) for some λ near λ0 (that failure is why `margin` existed).
        let ds = problem(2);
        let (_, lmax) = DualRef::at_lambda_max(&ds);
        let lam0 = 0.5 * lmax;
        let loose = SolveOptions { tol: 1e-3, check_every: 1, ..Default::default() };
        let sol0 = fista(&ds, lam0, None, &loose);
        let y = ops::y64(&ds);
        let theta0 = ops::stacked_scale(&ops::residual(&ds, &sol0.w), -1.0 / lam0);
        let normal =
            ops::stacked_scale_add(&ops::stacked_scale(&y, 1.0 / lam0), -1.0, &theta0);
        let nn = ops::stacked_sqnorm(&normal);
        let mut missed = false;
        for ratio_of_lam0 in [0.9999, 0.999, 0.99] {
            let lam = ratio_of_lam0 * lam0;
            let r = ops::stacked_scale_add(&ops::stacked_scale(&y, 1.0 / lam), -1.0, &theta0);
            let coef = ops::stacked_dot(&normal, &r) / nn;
            let rp = ops::stacked_scale_add(&r, -coef, &normal);
            let delta = 0.5 * ops::stacked_sqnorm(&rp).sqrt();
            let o = ops::stacked_scale_add(&theta0, 0.5, &rp);
            let theta = theta_star(&ds, lam);
            let diff = ops::stacked_scale_add(&theta, -1.0, &o);
            let dist = ops::stacked_sqnorm(&diff).sqrt();
            if dist > delta {
                missed = true;
            }
        }
        assert!(missed, "old uninflated ball never missed — regression target vanished");
    }

    #[test]
    fn dpc_is_safe_from_lmax() {
        let ds = problem(3);
        let (dref, lmax) = DualRef::at_lambda_max(&ds);
        let screener = DpcScreener::new(&ds);
        for ratio in [0.8, 0.5, 0.2] {
            let lam = ratio * lmax;
            let out = screener.screen(&ds, &dref, lam);
            let sol = fista(&ds, lam, None, &SolveOptions::tight());
            let rn = sol.row_norms(ds.t());
            for (l, (&rej, &norm)) in out.rejected.iter().zip(&rn).enumerate() {
                if rej {
                    assert!(norm < 1e-8, "UNSAFE: rejected active row {l} (norm {norm})");
                }
            }
            // far from lambda_max the one-shot ball is huge and may reject
            // nothing — only the nearer ratios must screen (the sequential
            // rule handles small lambda; see dpc_sequential_tighter test)
            if ratio >= 0.5 {
                assert!(out.num_rejected() > 0, "rule should reject something at {ratio}");
            }
        }
    }

    #[test]
    fn dpc_sequential_tighter_than_oneshot() {
        // Corollary 9: a reference at nearby lam0 rejects at least as many
        // features as screening from lam_max (the ball is smaller)
        let (ds, _) =
            synthetic2(&SynthOptions { t: 3, n: 12, d: 80, seed: 4, ..Default::default() });
        let (dref_max, lmax) = DualRef::at_lambda_max(&ds);
        let lam0 = 0.4 * lmax;
        let lam = 0.3 * lmax;
        let sol0 = fista(&ds, lam0, None, &SolveOptions::tight());
        let dref_seq = DualRef::from_solution(&ds, lam0, &sol0.w);
        let sc = DpcScreener::new(&ds);
        let one = sc.screen(&ds, &dref_max, lam).num_rejected();
        let seq = sc.screen(&ds, &dref_seq, lam).num_rejected();
        assert!(seq >= one, "sequential {seq} < one-shot {one}");
    }

    #[test]
    fn screen_at_lam0_rejects_inactive_of_lam0() {
        // λ = λ0: ball radius shrinks to ~0 around θ*(λ0); scores ≈ g(θ*)
        let ds = problem(5);
        let (_, lmax) = DualRef::at_lambda_max(&ds);
        let lam0 = 0.5 * lmax;
        let sol = fista(&ds, lam0, None, &SolveOptions::tight());
        let dref = DualRef::from_solution(&ds, lam0, &sol.w);
        let out = DpcScreener::new(&ds).screen(&ds, &dref, lam0 * 0.999999);
        let active = sol.active_set(ds.t(), 1e-8);
        let kept = out.kept_indices();
        for a in &active {
            assert!(kept.contains(a), "active row {a} was rejected at ~lam0");
        }
        // nearly all inactive rows should be rejected with a tiny ball
        let n_inactive = ds.d - active.len();
        assert!(out.num_rejected() as f64 >= 0.9 * n_inactive as f64);
    }

    #[test]
    #[should_panic(expected = "DPC requires")]
    fn rejects_wrong_direction() {
        let ds = problem(6);
        let (dref, lmax) = DualRef::at_lambda_max(&ds);
        let _ = DpcScreener::new(&ds).screen(&ds, &dref, lmax * 2.0);
    }
}
