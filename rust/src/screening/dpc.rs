//! The DPC rule: Theorem 5 (ball estimation of θ*(λ)) + Theorem 7 (score
//! maximization) + Theorem 8 / Corollary 9 (the rejection test, sequential
//! along the λ grid).

use super::{secular::qp1qc_max, ScreenOutcome};
use crate::data::Dataset;
use crate::ops::{self, Stacked};
use crate::util::parallel_chunks;

/// Reference point for the ball: everything Theorem 5 needs about λ0.
#[derive(Debug, Clone)]
pub struct DualRef {
    pub lam0: f64,
    /// θ*(λ0)
    pub theta0: Stacked,
    /// n(λ0) ∈ N_F(θ*(λ0)) (Eq. 20)
    pub normal: Stacked,
}

impl DualRef {
    /// The closed-form reference at λ0 = λ_max (Theorem 1 + Eq. 20 case 2).
    pub fn at_lambda_max(ds: &Dataset) -> (Self, f64) {
        let (lmax, lstar, _) = ops::lambda_max(ds);
        let y = ops::y64(ds);
        let theta0 = ops::stacked_scale(&y, 1.0 / lmax);
        let normal = ops::normal_at_lmax(ds, lstar, lmax);
        (DualRef { lam0: lmax, theta0, normal }, lmax)
    }

    /// Reference from a solved primal at λ0 < λ_max: θ*(λ0) = (y − Xw)/λ0
    /// (Eq. 14), n(λ0) = y/λ0 − θ*(λ0) (Eq. 20 case 1).
    pub fn from_solution(ds: &Dataset, lam0: f64, w: &[f64]) -> Self {
        let y = ops::y64(ds);
        let r = ops::residual(ds, w); // Xw − y
        let theta0 = ops::stacked_scale(&r, -1.0 / lam0);
        let normal = ops::stacked_scale_add(&ops::stacked_scale(&y, 1.0 / lam0), -1.0, &theta0);
        DualRef { lam0, theta0, normal }
    }
}

/// Ball Θ(λ, λ0) from Theorem 5: center o = θ0 + ½r⊥, radius Δ = ½‖r⊥‖.
pub fn ball(ds: &Dataset, dref: &DualRef, lam: f64) -> (Stacked, f64) {
    let y = ops::y64(ds);
    // r = y/λ − θ0
    let r = ops::stacked_scale_add(&ops::stacked_scale(&y, 1.0 / lam), -1.0, &dref.theta0);
    let nn = ops::stacked_sqnorm(&dref.normal);
    let rp = if nn > 1e-290 {
        let coef = ops::stacked_dot(&dref.normal, &r) / nn;
        ops::stacked_scale_add(&r, -coef, &dref.normal)
    } else {
        r
    };
    let delta = 0.5 * ops::stacked_sqnorm(&rp).sqrt();
    let o = ops::stacked_scale_add(&dref.theta0, 0.5, &rp);
    (o, delta)
}

/// The DPC screener. Caches the per-(feature, task) squared column norms —
/// the b² moments of Theorem 7 — which are λ-independent.
pub struct DpcScreener {
    /// (d x T) row-major ‖x_l^{(t)}‖²
    b2: Vec<f64>,
    t_count: usize,
    /// keep features whose score falls within `margin` below 1 (guards
    /// against solver inexactness in θ*(λ0); 0 = the paper's exact rule)
    pub margin: f64,
}

impl DpcScreener {
    pub fn new(ds: &Dataset) -> Self {
        DpcScreener { b2: ds.col_sqnorms(), t_count: ds.t(), margin: 0.0 }
    }

    pub fn with_margin(ds: &Dataset, margin: f64) -> Self {
        DpcScreener { margin, ..Self::new(ds) }
    }

    /// Scores s_l for all features given a ball (o, Δ). Parallel over
    /// feature chunks; the a-moments (corr sweep) dominate the cost. The
    /// sweep goes through [`crate::linalg::ColRef`], so on CSC-backed
    /// datasets it touches only stored nonzeros — the paper's sparse
    /// text/genomics regime where screening pays for itself many times
    /// over.
    pub fn scores(&self, ds: &Dataset, o: &Stacked, delta: f64) -> Vec<f64> {
        let t_count = self.t_count;
        let d = ds.d;
        let workers = if d * ds.total_n() < 500_000 { 1 } else { usize::MAX };
        let out = parallel_chunks(d, workers, |_, start, end| {
            let mut part = vec![0.0f64; end - start];
            let mut a = vec![0.0f64; t_count];
            for l in start..end {
                for (ti, task) in ds.tasks.iter().enumerate() {
                    a[ti] = task.col(l).dot_mixed(&o[ti]);
                }
                let b2 = &self.b2[l * t_count..(l + 1) * t_count];
                part[l - start] = qp1qc_max(&a, b2, delta).s;
            }
            part
        });
        out.concat()
    }

    /// Full DPC step (Theorem 8 / Corollary 9): screen at λ given a
    /// reference at λ0 > λ.
    pub fn screen(&self, ds: &Dataset, dref: &DualRef, lam: f64) -> ScreenOutcome {
        assert!(
            lam <= dref.lam0 * (1.0 + 1e-12),
            "DPC requires lam <= lam0 (got {lam} > {})",
            dref.lam0
        );
        let (o, delta) = ball(ds, dref, lam);
        let scores = self.scores(ds, &o, delta);
        let thr = 1.0 - self.margin;
        let rejected = scores.iter().map(|&s| s < thr).collect();
        ScreenOutcome { rejected, scores, delta }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, synthetic2, SynthOptions};
    use crate::solver::{fista, SolveOptions};

    fn problem(seed: u64) -> Dataset {
        synthetic1(&SynthOptions { t: 3, n: 12, d: 60, seed, ..Default::default() }).0
    }

    #[test]
    fn ball_contains_dual_optimum_from_lmax() {
        let ds = problem(1);
        let (dref, lmax) = DualRef::at_lambda_max(&ds);
        for ratio in [0.9, 0.6, 0.3, 0.1] {
            let lam = ratio * lmax;
            let (o, delta) = ball(&ds, &dref, lam);
            let sol = fista(&ds, lam, None, &SolveOptions::tight());
            let theta = ops::stacked_scale(&ops::residual(&ds, &sol.w), -1.0 / lam);
            let diff = ops::stacked_scale_add(&theta, -1.0, &o);
            let dist = ops::stacked_sqnorm(&diff).sqrt();
            assert!(dist <= delta + 1e-6, "ratio {ratio}: dist {dist} > delta {delta}");
        }
    }

    #[test]
    fn ball_contains_dual_optimum_sequential() {
        let ds = problem(2);
        let (_, lmax) = DualRef::at_lambda_max(&ds);
        let lam0 = 0.5 * lmax;
        let sol0 = fista(&ds, lam0, None, &SolveOptions::tight());
        let dref = DualRef::from_solution(&ds, lam0, &sol0.w);
        for ratio in [0.45, 0.3, 0.2] {
            let lam = ratio * lmax;
            let (o, delta) = ball(&ds, &dref, lam);
            let sol = fista(&ds, lam, None, &SolveOptions::tight());
            let theta = ops::stacked_scale(&ops::residual(&ds, &sol.w), -1.0 / lam);
            let diff = ops::stacked_scale_add(&theta, -1.0, &o);
            let dist = ops::stacked_sqnorm(&diff).sqrt();
            assert!(dist <= delta + 1e-6, "ratio {ratio}: {dist} > {delta}");
        }
    }

    #[test]
    fn dpc_is_safe_from_lmax() {
        let ds = problem(3);
        let (dref, lmax) = DualRef::at_lambda_max(&ds);
        let screener = DpcScreener::new(&ds);
        for ratio in [0.8, 0.5, 0.2] {
            let lam = ratio * lmax;
            let out = screener.screen(&ds, &dref, lam);
            let sol = fista(&ds, lam, None, &SolveOptions::tight());
            let rn = sol.row_norms(ds.t());
            for (l, (&rej, &norm)) in out.rejected.iter().zip(&rn).enumerate() {
                if rej {
                    assert!(norm < 1e-8, "UNSAFE: rejected active row {l} (norm {norm})");
                }
            }
            // far from lambda_max the one-shot ball is huge and may reject
            // nothing — only the nearer ratios must screen (the sequential
            // rule handles small lambda; see dpc_sequential_tighter test)
            if ratio >= 0.5 {
                assert!(out.num_rejected() > 0, "rule should reject something at {ratio}");
            }
        }
    }

    #[test]
    fn dpc_sequential_tighter_than_oneshot() {
        // Corollary 9: a reference at nearby lam0 rejects at least as many
        // features as screening from lam_max (the ball is smaller)
        let (ds, _) = synthetic2(&SynthOptions { t: 3, n: 12, d: 80, seed: 4, ..Default::default() });
        let (dref_max, lmax) = DualRef::at_lambda_max(&ds);
        let lam0 = 0.4 * lmax;
        let lam = 0.3 * lmax;
        let sol0 = fista(&ds, lam0, None, &SolveOptions::tight());
        let dref_seq = DualRef::from_solution(&ds, lam0, &sol0.w);
        let sc = DpcScreener::new(&ds);
        let one = sc.screen(&ds, &dref_max, lam).num_rejected();
        let seq = sc.screen(&ds, &dref_seq, lam).num_rejected();
        assert!(seq >= one, "sequential {seq} < one-shot {one}");
    }

    #[test]
    fn screen_at_lam0_rejects_inactive_of_lam0() {
        // λ = λ0: ball radius shrinks to ~0 around θ*(λ0); scores ≈ g(θ*)
        let ds = problem(5);
        let (_, lmax) = DualRef::at_lambda_max(&ds);
        let lam0 = 0.5 * lmax;
        let sol = fista(&ds, lam0, None, &SolveOptions::tight());
        let dref = DualRef::from_solution(&ds, lam0, &sol.w);
        let out = DpcScreener::new(&ds).screen(&ds, &dref, lam0 * 0.999999);
        let active = sol.active_set(ds.t(), 1e-8);
        let kept = out.kept_indices();
        for a in &active {
            assert!(kept.contains(a), "active row {a} was rejected at ~lam0");
        }
        // nearly all inactive rows should be rejected with a tiny ball
        let n_inactive = ds.d - active.len();
        assert!(out.num_rejected() as f64 >= 0.9 * n_inactive as f64);
    }

    #[test]
    #[should_panic(expected = "DPC requires")]
    fn rejects_wrong_direction() {
        let ds = problem(6);
        let (dref, lmax) = DualRef::at_lambda_max(&ds);
        let _ = DpcScreener::new(&ds).screen(&ds, &dref, lmax * 2.0);
    }
}
