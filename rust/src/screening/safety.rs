//! Post-hoc safety verification: "safe" means no rejected feature is
//! active in the true solution. This module certifies that claim against a
//! high-precision solve — used by the property tests and (optionally) by
//! the path coordinator in paranoid mode.

use crate::data::Dataset;
use crate::ops;

/// What the post-hoc verifier found for one screening outcome.
#[derive(Debug)]
pub struct SafetyReport {
    /// rejected features whose solution row norm exceeded tol (must be empty)
    pub violations: Vec<(usize, f64)>,
    /// max g_l(θ̂) over rejected features (must be < 1 for strict safety)
    pub max_rejected_g: f64,
    /// number of rejections examined
    pub checked: usize,
}

impl SafetyReport {
    /// True when no rejected feature was active in the solution.
    pub fn is_safe(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Verify a screening outcome against a solved W (row-norm check) and the
/// KKT dual certificate (g_l(θ̂) < 1 for every rejected l, Eq. 15).
///
/// ℓ2,1-specialized alias for [`verify_for`] with [`crate::penalty::L21`]:
/// the generic dual certificate `pen.dual_constraints(task_corr(θ̂))` is
/// exactly `ops::gscore`'s body for ℓ2,1, so this delegation is
/// bit-identical to the pre-seam verifier.
pub fn verify(
    ds: &Dataset,
    w: &[f64],
    lam: f64,
    rejected: &[bool],
    row_tol: f64,
) -> SafetyReport {
    verify_for(ds, w, lam, rejected, row_tol, &crate::penalty::L21)
}

/// Penalty-generic [`verify`] (DESIGN.md §14). The row-norm check is
/// penalty-independent (every row-structured Ω certifies row norms zero);
/// the dual certificate is the penalty's own constraint functional
/// g_l(θ̂) = [`crate::penalty::Penalty::dual_constraints`], which must be
/// < 1 on every rejected row at (near-)optimal θ̂ for the rejection to
/// have been safe.
pub fn verify_for(
    ds: &Dataset,
    w: &[f64],
    lam: f64,
    rejected: &[bool],
    row_tol: f64,
    pen: &dyn crate::penalty::Penalty,
) -> SafetyReport {
    let t_count = ds.t();
    let mut violations = Vec::new();
    for (l, &rej) in rejected.iter().enumerate() {
        if rej {
            let row = &w[l * t_count..(l + 1) * t_count];
            let norm = crate::linalg::nrm2_f64(row);
            if norm > row_tol {
                violations.push((l, norm));
            }
        }
    }

    let mut theta = ops::residual(ds, w);
    ops::stacked_scale_inplace(&mut theta, -1.0 / lam);
    let g = pen.dual_constraints(&ops::task_corr(ds, &theta), t_count);
    let max_rejected_g = rejected
        .iter()
        .zip(&g)
        .filter_map(|(&r, &gl)| r.then_some(gl))
        .fold(0.0f64, f64::max);

    SafetyReport {
        violations,
        max_rejected_g,
        checked: rejected.iter().filter(|&&r| r).count(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, SynthOptions};
    use crate::screening::dpc::{DpcScreener, DualRef};
    use crate::solver::{fista, SolveOptions};

    #[test]
    fn dpc_outcome_passes_verification() {
        let (ds, _) =
            synthetic1(&SynthOptions { t: 3, n: 12, d: 60, seed: 11, ..Default::default() });
        let (dref, lmax) = DualRef::at_lambda_max(&ds);
        let lam = 0.4 * lmax;
        let out = DpcScreener::new(&ds).screen(&ds, &dref, lam);
        let sol = fista(&ds, lam, None, &SolveOptions::tight());
        let report = verify(&ds, &sol.w, lam, &out.rejected, 1e-8);
        assert!(report.is_safe(), "violations: {:?}", report.violations);
        assert!(report.max_rejected_g < 1.0 + 1e-6);
        assert!(report.checked > 0);
    }

    #[test]
    fn detects_unsafe_rejection() {
        let (ds, _) =
            synthetic1(&SynthOptions { t: 2, n: 10, d: 30, seed: 12, ..Default::default() });
        let (_, lmax) = DualRef::at_lambda_max(&ds);
        let lam = 0.3 * lmax;
        let sol = fista(&ds, lam, None, &SolveOptions::default());
        let active = sol.active_set(ds.t(), 1e-6);
        assert!(!active.is_empty());
        let mut rejected = vec![false; ds.d];
        rejected[active[0]] = true; // deliberately reject an active row
        let report = verify(&ds, &sol.w, lam, &rejected, 1e-8);
        assert!(!report.is_safe());
    }
}
