//! Cluster-scale screening (DESIGN.md §16): a coordinator that fans the
//! streamed shard sweeps out to worker *processes* over TCP, and the
//! worker loop itself (`repro worker --connect HOST:PORT`).
//!
//! The distribution unit is the MTD3 block range. Every streamed sweep
//! writes disjoint per-block slices of a d-length vector and folds
//! scalars only on the assembled whole (screening::shard module docs),
//! so distributing is: partition `0..n_blocks` into contiguous ranges,
//! have each worker stream its ranges through its own `BlockCache` +
//! prefetch pipeline, and concatenate the returned [`SweepPart`]s in
//! fixed column order ([`merge_parts`]). The merged vector is
//! bit-identical to the single-process sweep by construction, so the
//! whole path run (keep-sets, solutions, records) is too — the
//! coordinator still materializes survivors and solves locally.
//!
//! Wire protocol: the serve layer's length-prefixed JSON frames
//! ([`crate::serve::proto`], [`crate::serve::json`] — bit-exact f64
//! round-trip), with a worker op-set disjoint from the serving ops:
//!
//! | op               | does                                              |
//! |------------------|---------------------------------------------------|
//! | `hello`          | open + validate the shard, fix the penalty        |
//! | `sweep_blocks`   | stream one block range (`scores`/`infeas`/`sqnorms`) |
//! | `merge`          | per-λ barrier: ack the merged grid step           |
//! | `checkpoint_ack` | ship the worker ledger (I/O + busy counters)      |
//! | `shutdown`       | reply, then exit the worker loop                  |
//!
//! Failure policy: a worker that drops its connection mid-sweep is
//! marked dead and its block ranges are reassigned round-robin to the
//! survivors — the sweep completes with identical bits because the merge
//! is by column offset, not by worker. A worker that *answers* with an
//! error (`ok:false`, e.g. a block checksum failure) is a hard stop:
//! that is a data problem reassignment must not paper over. Zero
//! survivors is a hard stop naming `--checkpoint` as the recovery path.

use super::checkpoint::CheckpointCfg;
use super::path::{
    run_path_sharded_core, PathObserver, PathOptions, ShardRunResult, WorkerLedger,
};
use crate::data::ShardedDataset;
use crate::ops::{self, Stacked};
use crate::penalty::PenaltyKind;
use crate::screening::shard::{merge_parts, ShardSweeps, SweepPart};
use crate::serve::json::{self, Value};
use crate::serve::proto;
use crate::util::Stopwatch;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Frame cap for worker traffic: a sweep reply carries one f64 per
/// column of the range (≈24 text bytes each), so 64 MiB covers ranges
/// into the millions of columns — far past where block partitioning
/// would have split them anyway.
pub const WORKER_MAX_FRAME: usize = 64 << 20;

/// Everything `repro path --distributed` needs besides the path options.
#[derive(Debug, Clone)]
pub struct DistribOptions {
    /// worker processes to run the sweeps on
    pub workers: usize,
    /// coordinator listen address (`127.0.0.1:0` = loopback, OS port)
    pub listen: String,
    /// spawn the workers as local child processes (default); with
    /// `--no-spawn` the coordinator waits for externally started
    /// `repro worker --connect` processes instead
    pub spawn_local: bool,
    /// seconds to wait for workers to connect / for any single reply
    pub worker_timeout_secs: f64,
    /// block-cache megabytes forwarded to spawned workers
    pub cache_mb: usize,
}

impl Default for DistribOptions {
    fn default() -> Self {
        DistribOptions {
            workers: 2,
            listen: "127.0.0.1:0".into(),
            spawn_local: true,
            worker_timeout_secs: 120.0,
            cache_mb: 256,
        }
    }
}

/// Contiguous near-equal partition of `0..nb` into `w` ranges (range `i`
/// is `[i·nb/w, (i+1)·nb/w)` — deterministic, order-preserving, exact
/// tiling; trailing ranges may be empty when `w > nb`).
pub fn partition_blocks(nb: usize, w: usize) -> Vec<Range<usize>> {
    assert!(w > 0, "partition needs at least one worker");
    (0..w).map(|i| (i * nb / w)..((i + 1) * nb / w)).collect()
}

// ---------------------------------------------------------------------------
// wire helpers (stacked vectors as nested JSON arrays; f64s round-trip
// bit-exactly through serve::json's shortest-decimal formatting)
// ---------------------------------------------------------------------------

fn stacked_to_json(s: &Stacked) -> Value {
    Value::Arr(s.iter().map(|t| Value::num_arr(t)).collect())
}

fn f64s_from_json(v: &Value) -> Result<Vec<f64>> {
    v.as_arr()
        .context("expected a number array")?
        .iter()
        .map(|x| x.as_f64().context("expected a number array"))
        .collect()
}

fn stacked_from_json(v: &Value) -> Result<Stacked> {
    v.as_arr()
        .context("expected a stacked (array-of-arrays) vector")?
        .iter()
        .map(f64s_from_json)
        .collect()
}

fn num_u64(v: u64) -> Value {
    Value::Num(v as f64)
}

fn penalty_wire(pen: &PenaltyKind) -> (&'static str, f64, f64) {
    match *pen {
        PenaltyKind::L21 => ("l21", 0.0, 0.0),
        PenaltyKind::Sgl { alpha } => ("sgl", alpha, 0.0),
        PenaltyKind::Gowl { gamma } => ("gowl", 0.0, gamma),
    }
}

// ---------------------------------------------------------------------------
// the worker loop (`repro worker --connect HOST:PORT`)
// ---------------------------------------------------------------------------

struct WorkerState {
    sh: ShardedDataset,
    pen: PenaltyKind,
    /// per-block b² tables, computed on first touch and cached — the
    /// worker-side twin of `ShardScreener`'s d×T table, restricted to
    /// the blocks this worker actually serves (bit-identical slices)
    b2: HashMap<usize, Vec<f64>>,
}

enum Handled {
    Reply(Value),
    Shutdown(Value),
}

/// The blocking worker loop: connect to the coordinator, answer framed
/// requests until `shutdown` or EOF (a vanished coordinator is a clean
/// exit — the worker owns no durable state). Single-threaded by design:
/// sweep parallelism inside a block uses the same data-parallel kernels
/// as every backend, process parallelism comes from running more workers.
pub fn run_worker(connect: &str, cache_mb: usize) -> Result<()> {
    // retry the connect briefly: workers and coordinator are started in
    // arbitrary order (`--no-spawn`, CI scripts), and the coordinator
    // only listens once it has bound its port
    let sw = Stopwatch::started();
    let mut stream = loop {
        match TcpStream::connect(connect) {
            Ok(s) => break s,
            Err(_) if sw.secs() < 30.0 => {
                std::thread::sleep(Duration::from_millis(100))
            }
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("connect to coordinator at {connect}"))
            }
        }
    };
    stream.set_nodelay(true).ok();
    let mut state: Option<WorkerState> = None;
    let mut busy = Stopwatch::new();
    let mut sweeps_served = 0u64;
    loop {
        let payload = match proto::read_frame(&mut stream, WORKER_MAX_FRAME) {
            Ok(p) => p,
            Err(_) => return Ok(()), // coordinator hung up — clean exit
        };
        let reply =
            match handle_frame(&payload, &mut state, cache_mb, &mut busy, &mut sweeps_served) {
                Ok(Handled::Reply(v)) => proto::ok_reply(v),
                Ok(Handled::Shutdown(v)) => {
                    proto::write_frame(&mut stream, proto::ok_reply(v).as_bytes())?;
                    return Ok(());
                }
                Err(e) => proto::err_reply(&format!("{e:#}")),
            };
        proto::write_frame(&mut stream, reply.as_bytes())?;
    }
}

fn handle_frame(
    payload: &[u8],
    state: &mut Option<WorkerState>,
    cache_mb: usize,
    busy: &mut Stopwatch,
    sweeps_served: &mut u64,
) -> Result<Handled> {
    let v = json::parse(std::str::from_utf8(payload).context("request not utf8")?)
        .map_err(|e| anyhow::anyhow!("bad request json: {e}"))?;
    let op = v.get("op").and_then(Value::as_str).context("request needs a string \"op\"")?;
    match op {
        "hello" => {
            let shard = v.get("shard").and_then(Value::as_str).context("hello needs \"shard\"")?;
            let name = v.get("name").and_then(Value::as_str).context("hello needs \"name\"")?;
            let d = v.get("d").and_then(Value::as_usize).context("hello needs \"d\"")?;
            let t = v.get("t").and_then(Value::as_usize).context("hello needs \"t\"")?;
            let nb = v
                .get("n_blocks")
                .and_then(Value::as_usize)
                .context("hello needs \"n_blocks\"")?;
            let pname = v
                .get("penalty")
                .and_then(Value::as_str)
                .context("hello needs \"penalty\"")?;
            let alpha = v.get("alpha").and_then(Value::as_f64).unwrap_or(0.0);
            let gamma = v.get("gamma").and_then(Value::as_f64).unwrap_or(0.0);
            let pen = PenaltyKind::parse(pname, alpha, gamma)?;
            let sh = ShardedDataset::open_with_cache(Path::new(shard), cache_mb << 20)?;
            anyhow::ensure!(
                sh.name() == name && sh.d() == d && sh.t() == t && sh.n_blocks() == nb,
                "shard mismatch: coordinator expects '{name}' (d={d}, T={t}, {nb} \
                 blocks) but {shard} holds '{}' (d={}, T={}, {} blocks) — are both \
                 sides pointing at the same file?",
                sh.name(),
                sh.d(),
                sh.t(),
                sh.n_blocks()
            );
            *state = Some(WorkerState { sh, pen, b2: HashMap::new() });
            Ok(Handled::Reply(Value::Obj(vec![
                ("d".into(), num_u64(d as u64)),
                ("t".into(), num_u64(t as u64)),
                ("n_blocks".into(), num_u64(nb as u64)),
            ])))
        }
        "sweep_blocks" => {
            let st = state.as_mut().context("hello must precede sweep_blocks")?;
            let kind = v
                .get("kind")
                .and_then(Value::as_str)
                .context("sweep_blocks needs \"kind\"")?;
            let blocks = v
                .get("blocks")
                .and_then(Value::as_arr)
                .context("sweep_blocks needs \"blocks\": [start, end]")?;
            anyhow::ensure!(blocks.len() == 2, "\"blocks\" must be [start, end]");
            let s = blocks[0].as_usize().context("block start must be a non-negative int")?;
            let e = blocks[1].as_usize().context("block end must be a non-negative int")?;
            anyhow::ensure!(
                s < e && e <= st.sh.n_blocks(),
                "block range {s}..{e} out of bounds for {} blocks",
                st.sh.n_blocks()
            );
            let payload_vec = match v.get("payload") {
                Some(p) => Some(stacked_from_json(p)?),
                None => None,
            };
            let delta = v.get("delta").and_then(Value::as_f64).unwrap_or(0.0);
            let t_count = st.sh.t();
            let span = st.sh.block_range(s).start..st.sh.block_range(e - 1).end;
            let stride = if kind == "sqnorms" { t_count } else { 1 };
            let mut values: Vec<f64> = Vec::with_capacity((span.end - span.start) * stride);
            let WorkerState { sh, pen, b2 } = st;
            let pen = *pen;
            busy.time(|| -> Result<()> {
                sh.for_each_block_range_pipelined(s..e, |b, blk| {
                    let part = match kind {
                        "scores" => {
                            let o = payload_vec
                                .as_ref()
                                .context("kind \"scores\" needs \"payload\" (the ball center)")?;
                            let b2 = b2.entry(b).or_insert_with(|| blk.col_sqnorms());
                            crate::screening::ball_scores_for(blk, b2, o, delta, &pen)
                        }
                        "infeas" => {
                            let z = payload_vec
                                .as_ref()
                                .context("kind \"infeas\" needs \"payload\" (the dual point)")?;
                            let corr = ops::task_corr(blk, z);
                            pen.infeas_features(&corr, t_count)
                        }
                        "sqnorms" => blk.col_sqnorms(),
                        other => anyhow::bail!(
                            "unknown sweep kind '{other}' (scores|infeas|sqnorms)"
                        ),
                    };
                    values.extend_from_slice(&part);
                    Ok(())
                })
            })?;
            *sweeps_served += 1;
            Ok(Handled::Reply(Value::Obj(vec![
                (
                    "cols".into(),
                    Value::Arr(vec![num_u64(span.start as u64), num_u64(span.end as u64)]),
                ),
                ("values".into(), Value::num_arr(&values)),
            ])))
        }
        "merge" => {
            anyhow::ensure!(state.is_some(), "hello must precede merge");
            Ok(Handled::Reply(Value::Str("ack".into())))
        }
        "checkpoint_ack" => {
            let st = state.as_ref().context("hello must precede checkpoint_ack")?;
            Ok(Handled::Reply(Value::Obj(vec![
                ("bytes_read".into(), num_u64(st.sh.bytes_read())),
                ("blocks_loaded".into(), num_u64(st.sh.blocks_loaded())),
                ("busy_secs".into(), Value::Num(busy.secs())),
                ("sweeps".into(), num_u64(*sweeps_served)),
            ])))
        }
        "shutdown" => Ok(Handled::Shutdown(Value::Str("bye".into()))),
        other => anyhow::bail!(
            "unknown worker op '{other}' (hello|sweep_blocks|merge|checkpoint_ack|shutdown)"
        ),
    }
}

// ---------------------------------------------------------------------------
// the coordinator
// ---------------------------------------------------------------------------

/// Accepts worker connections for a distributed path run.
pub struct Coordinator {
    listener: TcpListener,
    addr: String,
}

impl Coordinator {
    /// Bind the listen address (use port 0 to let the OS pick).
    pub fn bind(listen: &str) -> Result<Self> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("bind coordinator listener on {listen}"))?;
        let addr = listener.local_addr()?.to_string();
        Ok(Coordinator { listener, addr })
    }

    /// The bound address workers connect to (resolved port included).
    pub fn local_addr(&self) -> &str {
        &self.addr
    }

    /// Accept exactly `n` workers within the deadline (polled
    /// non-blocking so a missing worker yields an actionable error
    /// instead of hanging forever).
    pub fn accept_workers(&self, n: usize, timeout_secs: f64) -> Result<Vec<TcpStream>> {
        self.listener.set_nonblocking(true)?;
        let sw = Stopwatch::started();
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            match self.listener.accept() {
                Ok((s, _)) => {
                    s.set_nonblocking(false)?;
                    out.push(s);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    anyhow::ensure!(
                        sw.secs() < timeout_secs,
                        "only {} of {n} workers connected within {timeout_secs}s — \
                         start them with `repro worker --connect {}` or raise \
                         --worker-timeout",
                        out.len(),
                        self.addr
                    );
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(out)
    }
}

struct WorkerConn {
    stream: TcpStream,
    addr: String,
    alive: bool,
    /// block ranges this worker currently owns (grows on reassignment)
    ranges: Vec<Range<usize>>,
    sweeps: u64,
    bytes_shipped: u64,
    bytes_read: u64,
    blocks_loaded: u64,
    busy_secs: f64,
}

enum ReplyErr {
    /// connection-level failure: mark the worker dead, reassign its work
    Dead,
    /// the worker answered `ok:false` — a data/protocol error that
    /// reassignment must not paper over
    Fatal(String),
}

fn read_reply(stream: &mut TcpStream) -> std::result::Result<(usize, Value), ReplyErr> {
    let payload = proto::read_frame(stream, WORKER_MAX_FRAME).map_err(|_| ReplyErr::Dead)?;
    let text = std::str::from_utf8(&payload).map_err(|_| ReplyErr::Dead)?;
    let v = json::parse(text).map_err(|_| ReplyErr::Dead)?;
    match v.get("ok").and_then(Value::as_bool) {
        Some(true) => {
            let result = v.get("result").cloned().unwrap_or(Value::Null);
            Ok((payload.len(), result))
        }
        Some(false) => Err(ReplyErr::Fatal(
            v.get("error").and_then(Value::as_str).unwrap_or("unknown").to_string(),
        )),
        None => Err(ReplyErr::Dead),
    }
}

/// [`ShardSweeps`] over a fleet of worker processes: fan each sweep out
/// as one `sweep_blocks` request per owned block range, reassemble the
/// [`SweepPart`] replies in fixed column order, and survive worker
/// deaths by round-robin reassignment (module docs).
pub struct DistribSweeps<'a> {
    sh: &'a ShardedDataset,
    workers: Vec<WorkerConn>,
}

impl<'a> DistribSweeps<'a> {
    /// Accept `n` workers, hello each with the shard identity + penalty,
    /// and hand out the initial contiguous block partition.
    pub fn connect(
        sh: &'a ShardedDataset,
        shard_path: &Path,
        pen: PenaltyKind,
        coord: &Coordinator,
        n: usize,
        timeout_secs: f64,
    ) -> Result<Self> {
        anyhow::ensure!(n > 0, "--distributed needs at least one worker");
        let streams = coord.accept_workers(n, timeout_secs)?;
        let (pname, alpha, gamma) = penalty_wire(&pen);
        let hello = Value::Obj(vec![
            ("op".into(), Value::Str("hello".into())),
            ("shard".into(), Value::Str(shard_path.display().to_string())),
            ("name".into(), Value::Str(sh.name().into())),
            ("d".into(), num_u64(sh.d() as u64)),
            ("t".into(), num_u64(sh.t() as u64)),
            ("n_blocks".into(), num_u64(sh.n_blocks() as u64)),
            ("penalty".into(), Value::Str(pname.into())),
            ("alpha".into(), Value::Num(alpha)),
            ("gamma".into(), Value::Num(gamma)),
        ])
        .to_json();
        let parts = partition_blocks(sh.n_blocks(), n);
        let mut workers = Vec::with_capacity(n);
        for (i, mut stream) in streams.into_iter().enumerate() {
            stream.set_nodelay(true).ok();
            stream
                .set_read_timeout(Some(Duration::from_secs_f64(timeout_secs.max(0.001))))?;
            let addr =
                stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
            proto::write_frame(&mut stream, hello.as_bytes())
                .with_context(|| format!("hello worker {addr}"))?;
            match read_reply(&mut stream) {
                Ok(_) => {}
                Err(ReplyErr::Fatal(e)) => anyhow::bail!("worker {addr} refused hello: {e}"),
                Err(ReplyErr::Dead) => {
                    anyhow::bail!("worker {addr} hung up during hello")
                }
            }
            workers.push(WorkerConn {
                stream,
                addr,
                alive: true,
                ranges: vec![parts[i].clone()],
                sweeps: 0,
                bytes_shipped: 0,
                bytes_read: 0,
                blocks_loaded: 0,
                busy_secs: 0.0,
            });
        }
        Ok(DistribSweeps { sh, workers })
    }

    fn col_span(&self, r: &Range<usize>) -> Range<usize> {
        self.sh.block_range(r.start).start..self.sh.block_range(r.end - 1).end
    }

    /// One distributed sweep: request every live worker's owned ranges,
    /// read replies in request order, reassign orphaned ranges of dead
    /// workers to survivors, repeat until the parts tile `0..d`.
    fn fan_out(&mut self, build: &dyn Fn(Range<usize>) -> Value, stride: usize) -> Result<Vec<f64>> {
        let d = self.sh.d();
        let mut parts: Vec<SweepPart> = Vec::new();
        let mut pending: Vec<Vec<Range<usize>>> = self
            .workers
            .iter()
            .map(|w| w.ranges.iter().filter(|r| !r.is_empty()).cloned().collect())
            .collect();
        loop {
            // send phase: one request per pending range, per live worker
            for (i, w) in self.workers.iter_mut().enumerate() {
                if !w.alive {
                    continue;
                }
                for r in &pending[i] {
                    let req = build(r.clone()).to_json();
                    if proto::write_frame(&mut w.stream, req.as_bytes()).is_err() {
                        w.alive = false;
                        break;
                    }
                }
            }
            // read phase: replies arrive in request order per connection
            for i in 0..self.workers.len() {
                if !self.workers[i].alive {
                    continue;
                }
                let mut answered = 0usize;
                for k in 0..pending[i].len() {
                    let r = pending[i][k].clone();
                    let w = &mut self.workers[i];
                    match read_reply(&mut w.stream) {
                        Ok((len, result)) => {
                            let part = part_from_json(&result)?;
                            let want = self.col_span(&r);
                            anyhow::ensure!(
                                part.cols == want,
                                "worker {} answered columns {:?} for blocks {r:?} \
                                 (want {want:?})",
                                self.workers[i].addr,
                                part.cols
                            );
                            self.workers[i].sweeps += 1;
                            self.workers[i].bytes_shipped += len as u64;
                            parts.push(part);
                            answered += 1;
                        }
                        Err(ReplyErr::Fatal(e)) => {
                            anyhow::bail!("worker {}: {e}", self.workers[i].addr)
                        }
                        Err(ReplyErr::Dead) => {
                            self.workers[i].alive = false;
                            break;
                        }
                    }
                }
                pending[i].drain(..answered);
            }
            // orphan collection: a dead worker's unanswered ranges move on
            let mut orphans: Vec<Range<usize>> = Vec::new();
            for (i, w) in self.workers.iter_mut().enumerate() {
                if !w.alive {
                    orphans.append(&mut pending[i]);
                    w.ranges.clear();
                }
            }
            if orphans.is_empty() {
                break;
            }
            let live: Vec<usize> = self
                .workers
                .iter()
                .enumerate()
                .filter_map(|(i, w)| w.alive.then_some(i))
                .collect();
            anyhow::ensure!(
                !live.is_empty(),
                "all {} workers died mid-sweep — restart them and rerun (a \
                 --checkpoint run resumes at the interrupted grid step)",
                self.workers.len()
            );
            for (k, r) in orphans.into_iter().enumerate() {
                let i = live[k % live.len()];
                self.workers[i].ranges.push(r.clone());
                pending[i].push(r);
            }
        }
        merge_parts(d, stride, parts)
    }

    /// Broadcast one op to every live worker and read the acks; dead
    /// workers are marked (their ranges reassign at the next sweep).
    /// Returns each live worker's reply.
    fn broadcast(&mut self, req: &str) -> Result<Vec<(usize, Value)>> {
        let mut replies = Vec::new();
        for i in 0..self.workers.len() {
            let w = &mut self.workers[i];
            if !w.alive {
                continue;
            }
            if proto::write_frame(&mut w.stream, req.as_bytes()).is_err() {
                w.alive = false;
                continue;
            }
            match read_reply(&mut w.stream) {
                Ok((_, v)) => replies.push((i, v)),
                Err(ReplyErr::Fatal(e)) => {
                    anyhow::bail!("worker {}: {e}", self.workers[i].addr)
                }
                Err(ReplyErr::Dead) => self.workers[i].alive = false,
            }
        }
        Ok(replies)
    }

    /// Pull fresh I/O + busy counters from every live worker.
    fn sync_ledgers(&mut self) -> Result<()> {
        let req =
            Value::Obj(vec![("op".into(), Value::Str("checkpoint_ack".into()))]).to_json();
        for (i, v) in self.broadcast(&req)? {
            let w = &mut self.workers[i];
            w.bytes_read = v.get("bytes_read").and_then(Value::as_u64).unwrap_or(w.bytes_read);
            w.blocks_loaded =
                v.get("blocks_loaded").and_then(Value::as_u64).unwrap_or(w.blocks_loaded);
            w.busy_secs = v.get("busy_secs").and_then(Value::as_f64).unwrap_or(w.busy_secs);
        }
        Ok(())
    }

    /// Best-effort shutdown broadcast (workers also exit cleanly on EOF).
    pub fn shutdown(&mut self) {
        let req = Value::Obj(vec![("op".into(), Value::Str("shutdown".into()))]).to_json();
        let _ = self.broadcast(&req);
        for w in &mut self.workers {
            w.alive = false;
        }
    }

    /// The per-worker ledger for [`ShardRunResult::workers`].
    pub fn ledgers(&self) -> Vec<WorkerLedger> {
        self.workers
            .iter()
            .map(|w| WorkerLedger {
                addr: w.addr.clone(),
                blocks: w.ranges.iter().map(|r| r.len()).sum(),
                sweeps: w.sweeps,
                bytes_shipped: w.bytes_shipped,
                bytes_read: w.bytes_read,
                blocks_loaded: w.blocks_loaded,
                busy_secs: w.busy_secs,
            })
            .collect()
    }
}

fn part_from_json(v: &Value) -> Result<SweepPart> {
    let cols = v.get("cols").and_then(Value::as_arr).context("reply needs \"cols\"")?;
    anyhow::ensure!(cols.len() == 2, "\"cols\" must be [start, end]");
    let start = cols[0].as_usize().context("cols start must be a non-negative int")?;
    let end = cols[1].as_usize().context("cols end must be a non-negative int")?;
    let values = f64s_from_json(v.get("values").context("reply needs \"values\"")?)?;
    Ok(SweepPart { cols: start..end, values })
}

impl ShardSweeps for DistribSweeps<'_> {
    fn ball_scores(&mut self, o: &Stacked, delta: f64) -> Result<Vec<f64>> {
        let payload = stacked_to_json(o);
        self.fan_out(
            &|r| {
                Value::Obj(vec![
                    ("op".into(), Value::Str("sweep_blocks".into())),
                    ("kind".into(), Value::Str("scores".into())),
                    (
                        "blocks".into(),
                        Value::Arr(vec![num_u64(r.start as u64), num_u64(r.end as u64)]),
                    ),
                    ("delta".into(), Value::Num(delta)),
                    ("payload".into(), payload.clone()),
                ])
            },
            1,
        )
    }

    fn infeas_features(&mut self, z: &Stacked) -> Result<Vec<f64>> {
        let payload = stacked_to_json(z);
        self.fan_out(
            &|r| {
                Value::Obj(vec![
                    ("op".into(), Value::Str("sweep_blocks".into())),
                    ("kind".into(), Value::Str("infeas".into())),
                    (
                        "blocks".into(),
                        Value::Arr(vec![num_u64(r.start as u64), num_u64(r.end as u64)]),
                    ),
                    ("payload".into(), payload.clone()),
                ])
            },
            1,
        )
    }

    fn step_done(&mut self, step: usize, lam: f64, kept: usize) -> Result<()> {
        // merge barrier: every live worker acknowledges the merged step…
        let req = Value::Obj(vec![
            ("op".into(), Value::Str("merge".into())),
            ("step".into(), num_u64(step as u64)),
            ("lam".into(), Value::Num(lam)),
            ("kept".into(), num_u64(kept as u64)),
        ])
        .to_json();
        self.broadcast(&req)?;
        // …then ships its ledger, so a checkpoint written right after
        // this barrier reflects the step's true I/O accounting
        self.sync_ledgers()
    }
}

// ---------------------------------------------------------------------------
// the distributed path entry point
// ---------------------------------------------------------------------------

/// Kills leftover children on error paths; a clean run waits for them
/// after the shutdown broadcast.
struct ChildGuard(Vec<std::process::Child>);

impl ChildGuard {
    fn finish(&mut self) {
        for mut c in self.0.drain(..) {
            let _ = c.wait();
        }
    }
}

impl Drop for ChildGuard {
    fn drop(&mut self) {
        for c in self.0.iter_mut() {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// `repro path --backend sharded --distributed N`: run the out-of-core
/// grid loop ([`run_path_sharded_core`]) with the sweeps fanned out to
/// `N` worker processes. `shard_path` is handed to the workers verbatim
/// (same machine or shared filesystem). Keep-sets, solutions, and
/// records are bit-identical to the single-process
/// [`super::path::run_path_sharded`] — under worker loss included —
/// because every merged sweep vector is (module docs). Composes with
/// checkpoint/resume exactly like the single-process runner.
pub fn run_path_distributed(
    sh: &ShardedDataset,
    shard_path: &Path,
    opts: &PathOptions,
    dopts: &DistribOptions,
    obs: &mut dyn PathObserver,
    ckpt: Option<&CheckpointCfg>,
) -> Result<ShardRunResult> {
    let coord = Coordinator::bind(&dopts.listen)?;
    let mut children = ChildGuard(Vec::new());
    if dopts.spawn_local {
        let exe: PathBuf = std::env::current_exe()
            .context("locate the running binary to spawn local workers")?;
        for _ in 0..dopts.workers {
            children.0.push(
                std::process::Command::new(&exe)
                    .args([
                        "worker",
                        "--connect",
                        coord.local_addr(),
                        "--cache-mb",
                        &dopts.cache_mb.to_string(),
                    ])
                    .stdin(std::process::Stdio::null())
                    .stdout(std::process::Stdio::null())
                    .spawn()
                    .context("spawn local worker process")?,
            );
        }
    }
    let mut sweeps = DistribSweeps::connect(
        sh,
        shard_path,
        opts.solve.penalty,
        &coord,
        dopts.workers,
        dopts.worker_timeout_secs,
    )?;
    let run = run_path_sharded_core(sh, opts, obs, &mut sweeps, ckpt);
    sweeps.shutdown();
    children.finish();
    let mut res = run?;
    res.workers = sweeps.ledgers();
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_tiles_exactly_and_stays_contiguous() {
        for (nb, w) in [(10, 3), (7, 7), (5, 8), (1, 1), (100, 16)] {
            let parts = partition_blocks(nb, w);
            assert_eq!(parts.len(), w);
            let mut next = 0;
            for p in &parts {
                assert_eq!(p.start, next, "gap/overlap at {p:?} (nb={nb}, w={w})");
                next = p.end;
            }
            assert_eq!(next, nb, "partition must cover all blocks");
            // near-equal: no range more than one block bigger than another
            let lens: Vec<usize> = parts.iter().map(|p| p.len()).collect();
            let (lo, hi) =
                (lens.iter().copied().min().unwrap(), lens.iter().copied().max().unwrap());
            assert!(hi - lo <= 1, "unbalanced partition {lens:?}");
        }
    }

    #[test]
    fn penalty_wire_round_trips_through_parse() {
        for pen in [
            PenaltyKind::L21,
            PenaltyKind::Sgl { alpha: 0.35 },
            PenaltyKind::Gowl { gamma: 2.0 },
        ] {
            let (name, alpha, gamma) = penalty_wire(&pen);
            assert_eq!(PenaltyKind::parse(name, alpha, gamma).unwrap(), pen);
        }
    }

    #[test]
    fn sweep_parts_round_trip_the_json_wire_bit_exactly() {
        let vals = vec![1.0 / 3.0, -0.0, f64::MIN_POSITIVE, 2.5e300, 7.0];
        let reply = Value::Obj(vec![
            ("cols".into(), Value::Arr(vec![num_u64(3), num_u64(8)])),
            ("values".into(), Value::num_arr(&vals)),
        ]);
        // through the serializer and parser, as the coordinator sees it
        let back = json::parse(&reply.to_json()).unwrap();
        let part = part_from_json(&back).unwrap();
        assert_eq!(part.cols, 3..8);
        for (a, b) in part.values.iter().zip(&vals) {
            assert_eq!(a.to_bits(), b.to_bits(), "wire must not perturb f64 bits");
        }
        // stacked payloads too
        let z: Stacked = vec![vec![0.1, 0.2, 0.3], vec![-1.0 / 7.0]];
        let back = json::parse(&stacked_to_json(&z).to_json()).unwrap();
        assert_eq!(stacked_from_json(&back).unwrap(), z);
    }
}
