//! Per-λ checkpoint/resume for the out-of-core path runner (DESIGN.md
//! §16). After every grid point the sharded path core persists one MTC1
//! record (`ckpt_<step>.mtc1`, written atomically via
//! [`crate::data::io::write_record_atomic`]) carrying everything the
//! next step reads: the per-λ records so far, the sequential dual
//! reference, the warm start, and the streamed-gap state. `--resume`
//! loads the newest valid record, verifies it against the current run
//! configuration through a **prefix grid digest**, and re-enters the
//! grid loop at the next step.
//!
//! The resumed path is bit-identical to an uninterrupted run because
//! every input the loop reads at step k+1 is restored exactly — and
//! because checkpointed runs never skip the final reference update (the
//! single-process fast path does, since nothing reads the reference
//! after the last grid point; a checkpoint *is* a reader).
//!
//! The digest is a prefix digest on purpose: it binds the shard identity
//! (name/d/t), penalty, screener, solver, λ_max bits, and the bits of
//! every grid ratio **up to and including the checkpointed step** — so a
//! run over the first k points of a grid checkpoints identically to an
//! interrupted full-grid run, and resuming the longer grid from the
//! shorter prefix is legitimate, while any drift in what the restored
//! state actually depends on is refused.

use super::path::LambdaRecord;
use crate::data::io::{read_record, write_record_atomic, Fnv64};
use crate::ops::Stacked;
use crate::screening::dpc::DualRef;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Magic of one checkpoint record.
pub(crate) const MAGIC_CKPT: &[u8; 4] = b"MTC1";

/// Where checkpoints go and whether to resume from them (`repro path
/// --checkpoint DIR [--resume]`).
#[derive(Debug, Clone)]
pub struct CheckpointCfg {
    /// directory holding `ckpt_<step>.mtc1` records (created on demand)
    pub dir: PathBuf,
    /// load the newest valid record and continue from the next grid step
    pub resume: bool,
}

/// Everything the grid loop reads at step k+1, persisted after step k.
#[derive(Debug, Clone)]
pub struct PathCheckpoint {
    /// last completed grid step (0-based)
    pub step: usize,
    /// λ_max the run screened against (bit-compared on resume)
    pub lam_max: f64,
    /// per-λ records for steps `0..=step`
    pub records: Vec<LambdaRecord>,
    /// per-λ materialized-bytes ledger for steps `0..=step`
    pub materialized_bytes: Vec<usize>,
    /// sequential DPC reference after this step (ℓ2,1 screeners only)
    pub dref: Option<DualRef>,
    /// full-size warm start W (d × T, row-major)
    pub prev_w: Vec<f64>,
    /// residual of `prev_w` (the streamed-gap state)
    pub prev_r: Stacked,
    /// penalty value Ω(`prev_w`)
    pub prev_penval: f64,
}

/// The prefix grid digest (module docs): fnv64 over the run
/// configuration and `ratios[0..=step]`. `ratios_prefix` must be exactly
/// that inclusive prefix.
#[allow(clippy::too_many_arguments)]
pub fn grid_digest(
    name: &str,
    d: usize,
    t: usize,
    penalty: &str,
    screener: &str,
    solver: &str,
    lam_max: f64,
    ratios_prefix: &[f64],
) -> u64 {
    let mut h = Fnv64::new();
    for s in [name, penalty, screener, solver] {
        h.update(&(s.len() as u64).to_le_bytes());
        h.update(s.as_bytes());
    }
    h.update(&(d as u64).to_le_bytes());
    h.update(&(t as u64).to_le_bytes());
    h.update(&lam_max.to_bits().to_le_bytes());
    for &r in ratios_prefix {
        h.update(&r.to_bits().to_le_bytes());
    }
    h.digest()
}

/// Path of the step-`step` record inside `dir`.
pub fn step_path(dir: &Path, step: usize) -> PathBuf {
    dir.join(format!("ckpt_{step}.mtc1"))
}

// -- binary layout helpers (LE throughout, like every repo format) --

fn push_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn push_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn push_str(b: &mut Vec<u8>, s: &str) {
    push_u64(b, s.len() as u64);
    b.extend_from_slice(s.as_bytes());
}

fn push_f64s(b: &mut Vec<u8>, v: &[f64]) {
    push_u64(b, v.len() as u64);
    for &x in v {
        push_f64(b, x);
    }
}

fn push_stacked(b: &mut Vec<u8>, s: &Stacked) {
    push_u64(b, s.len() as u64);
    for task in s {
        push_f64s(b, task);
    }
}

struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + n <= self.b.len(),
            "checkpoint payload truncated at byte {} (want {n} more of {})",
            self.pos,
            self.b.len()
        );
        let out = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn us(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.us()?;
        String::from_utf8(self.take(n)?.to_vec()).context("checkpoint string not utf8")
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.us()?;
        let bytes = self.take(n.checked_mul(8).context("checkpoint vector overflows")?)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn stacked(&mut self) -> Result<Stacked> {
        let t = self.us()?;
        anyhow::ensure!(t <= 100_000, "checkpoint stacked vector has {t} tasks");
        (0..t).map(|_| self.f64s()).collect()
    }

    fn done(&self) -> Result<()> {
        anyhow::ensure!(
            self.pos == self.b.len(),
            "checkpoint payload has {} trailing bytes",
            self.b.len() - self.pos
        );
        Ok(())
    }
}

fn encode(ck: &PathCheckpoint, digest: u64, name: &str, d: usize, t: usize) -> Vec<u8> {
    let mut b = Vec::new();
    push_str(&mut b, name);
    push_u64(&mut b, d as u64);
    push_u64(&mut b, t as u64);
    push_u64(&mut b, ck.step as u64);
    push_u64(&mut b, digest);
    push_f64(&mut b, ck.lam_max);
    push_u64(&mut b, ck.records.len() as u64);
    for r in &ck.records {
        push_f64(&mut b, r.ratio);
        push_f64(&mut b, r.lam);
        push_u64(&mut b, r.rejected as u64);
        push_u64(&mut b, r.kept as u64);
        push_u64(&mut b, r.inactive as u64);
        push_f64(&mut b, r.rejection_ratio);
        push_f64(&mut b, r.screen_secs);
        push_f64(&mut b, r.solve_secs);
        push_u64(&mut b, r.solver_iters as u64);
        push_u64(&mut b, r.col_ops as u64);
        push_f64(&mut b, r.obj);
        push_f64(&mut b, r.gap);
    }
    push_u64(&mut b, ck.materialized_bytes.len() as u64);
    for &m in &ck.materialized_bytes {
        push_u64(&mut b, m as u64);
    }
    match &ck.dref {
        None => b.push(0),
        Some(dr) => {
            b.push(1);
            push_f64(&mut b, dr.lam0);
            push_f64(&mut b, dr.eps);
            push_stacked(&mut b, &dr.theta0);
            push_stacked(&mut b, &dr.normal);
        }
    }
    push_f64s(&mut b, &ck.prev_w);
    push_stacked(&mut b, &ck.prev_r);
    push_f64(&mut b, ck.prev_penval);
    b
}

fn decode(payload: &[u8]) -> Result<(PathCheckpoint, u64, String, usize, usize)> {
    let mut c = Dec { b: payload, pos: 0 };
    let name = c.str()?;
    let d = c.us()?;
    let t = c.us()?;
    let step = c.us()?;
    let digest = c.u64()?;
    let lam_max = c.f64()?;
    let n_rec = c.us()?;
    anyhow::ensure!(n_rec <= 1_000_000, "checkpoint claims {n_rec} records");
    let mut records = Vec::with_capacity(n_rec);
    for _ in 0..n_rec {
        records.push(LambdaRecord {
            ratio: c.f64()?,
            lam: c.f64()?,
            rejected: c.us()?,
            kept: c.us()?,
            inactive: c.us()?,
            rejection_ratio: c.f64()?,
            screen_secs: c.f64()?,
            solve_secs: c.f64()?,
            solver_iters: c.us()?,
            col_ops: c.us()?,
            obj: c.f64()?,
            gap: c.f64()?,
        });
    }
    let n_mat = c.us()?;
    anyhow::ensure!(n_mat <= 1_000_000, "checkpoint claims {n_mat} ledger rows");
    let materialized_bytes = (0..n_mat).map(|_| c.us()).collect::<Result<Vec<_>>>()?;
    let dref = match c.take(1)?[0] {
        0 => None,
        1 => {
            let lam0 = c.f64()?;
            let eps = c.f64()?;
            let theta0 = c.stacked()?;
            let normal = c.stacked()?;
            Some(DualRef { lam0, theta0, normal, eps })
        }
        other => anyhow::bail!("unknown dual-reference tag {other}"),
    };
    let prev_w = c.f64s()?;
    let prev_r = c.stacked()?;
    let prev_penval = c.f64()?;
    c.done()?;
    let ck = PathCheckpoint {
        step,
        lam_max,
        records,
        materialized_bytes,
        dref,
        prev_w,
        prev_r,
        prev_penval,
    };
    Ok((ck, digest, name, d, t))
}

/// Persist the step-`ck.step` record into `dir` (created on demand),
/// atomically — a crash mid-save leaves the previous step's record as
/// the newest valid one, never a torn file.
pub fn save(
    dir: &Path,
    ck: &PathCheckpoint,
    digest: u64,
    name: &str,
    d: usize,
    t: usize,
) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("--checkpoint {}: cannot create directory", dir.display()))?;
    let payload = encode(ck, digest, name, d, t);
    write_record_atomic(&step_path(dir, ck.step), MAGIC_CKPT, &payload)
        .with_context(|| format!("--checkpoint {}: cannot save step {}", dir.display(), ck.step))
}

/// Load the newest checkpoint in `dir`, validating shard identity.
/// Returns `None` when the directory holds no checkpoints (a fresh
/// `--resume` run simply starts at the grid head). A present-but-invalid
/// newest record — truncated, bit-flipped, or written against a
/// different shard — is a hard error naming `--checkpoint`: resuming is
/// an explicit request, and silently restarting would discard work (or
/// worse, mix states).
pub fn load_latest(
    dir: &Path,
    name: &str,
    d: usize,
    t: usize,
) -> Result<Option<(PathCheckpoint, u64)>> {
    if !dir.exists() {
        return Ok(None);
    }
    let mut newest: Option<(usize, PathBuf)> = None;
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("--checkpoint {}: cannot list directory", dir.display()))?
    {
        let path = entry?.path();
        let fname = match path.file_name().and_then(|s| s.to_str()) {
            Some(f) => f,
            None => continue,
        };
        let step = match fname
            .strip_prefix("ckpt_")
            .and_then(|s| s.strip_suffix(".mtc1"))
            .and_then(|s| s.parse::<usize>().ok())
        {
            Some(s) => s,
            None => continue,
        };
        let replace = match &newest {
            None => true,
            Some((s, _)) => step > *s,
        };
        if replace {
            newest = Some((step, path));
        }
    }
    let (step, path) = match newest {
        Some(n) => n,
        None => return Ok(None),
    };
    let payload = read_record(&path, MAGIC_CKPT).with_context(|| {
        format!(
            "--checkpoint {}: cannot resume from {} — delete the corrupt record \
             (older steps remain usable) or restart without --resume",
            dir.display(),
            path.display()
        )
    })?;
    let (ck, digest, ck_name, ck_d, ck_t) = decode(&payload)
        .with_context(|| format!("--checkpoint {}: malformed record {}", dir.display(), path.display()))?;
    anyhow::ensure!(
        ck.step == step,
        "--checkpoint {}: record {} claims step {} but is named step {step}",
        dir.display(),
        path.display(),
        ck.step
    );
    anyhow::ensure!(
        ck_name == name && ck_d == d && ck_t == t,
        "--checkpoint {}: record {} was written for dataset '{ck_name}' \
         (d={ck_d}, T={ck_t}), not '{name}' (d={d}, T={t})",
        dir.display(),
        path.display()
    );
    anyhow::ensure!(
        ck.records.len() == ck.step + 1 && ck.materialized_bytes.len() == ck.step + 1,
        "--checkpoint {}: record {} carries {} records for step {}",
        dir.display(),
        path.display(),
        ck.records.len(),
        ck.step
    );
    Ok(Some((ck, digest)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("mtfl_ckpt_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    fn rec(ratio: f64) -> LambdaRecord {
        LambdaRecord {
            ratio,
            lam: ratio * 2.5,
            rejected: 7,
            kept: 3,
            inactive: 8,
            rejection_ratio: 7.0 / 8.0,
            screen_secs: 0.25,
            solve_secs: 0.5,
            solver_iters: 12,
            col_ops: 99,
            obj: 1.5,
            gap: 1e-9,
        }
    }

    fn ckpt(step: usize) -> PathCheckpoint {
        PathCheckpoint {
            step,
            lam_max: 2.5,
            records: (0..=step).map(|s| rec(1.0 - 0.1 * s as f64)).collect(),
            materialized_bytes: (0..=step).map(|s| 1000 + s).collect(),
            dref: Some(DualRef {
                lam0: 2.5,
                theta0: vec![vec![0.5, -0.25], vec![0.125]],
                normal: vec![vec![1.0, 2.0], vec![-3.0]],
                eps: 1e-6,
            }),
            prev_w: vec![0.0, 1.0, -2.0, 0.5],
            prev_r: vec![vec![0.1, 0.2], vec![-0.3]],
            prev_penval: 3.75,
        }
    }

    #[test]
    fn round_trip_restores_every_field_bitwise() {
        let dir = tmpdir("roundtrip");
        let ck = ckpt(2);
        save(&dir, &ck, 0xdead_beef, "ds", 2, 2).unwrap();
        let (back, digest) = load_latest(&dir, "ds", 2, 2).unwrap().unwrap();
        assert_eq!(digest, 0xdead_beef);
        assert_eq!(back.step, ck.step);
        assert_eq!(back.lam_max.to_bits(), ck.lam_max.to_bits());
        assert_eq!(back.records.len(), ck.records.len());
        for (a, b) in back.records.iter().zip(&ck.records) {
            assert_eq!(a.ratio.to_bits(), b.ratio.to_bits());
            assert_eq!(a.lam.to_bits(), b.lam.to_bits());
            assert_eq!((a.rejected, a.kept, a.inactive), (b.rejected, b.kept, b.inactive));
            assert_eq!(a.obj.to_bits(), b.obj.to_bits());
            assert_eq!(a.gap.to_bits(), b.gap.to_bits());
        }
        assert_eq!(back.materialized_bytes, ck.materialized_bytes);
        let (da, db) = (back.dref.unwrap(), ck.dref.unwrap());
        assert_eq!(da.lam0.to_bits(), db.lam0.to_bits());
        assert_eq!(da.eps.to_bits(), db.eps.to_bits());
        assert_eq!(da.theta0, db.theta0);
        assert_eq!(da.normal, db.normal);
        assert_eq!(back.prev_w, ck.prev_w);
        assert_eq!(back.prev_r, ck.prev_r);
        assert_eq!(back.prev_penval.to_bits(), ck.prev_penval.to_bits());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latest_wins_and_a_missing_dref_survives() {
        let dir = tmpdir("latest");
        let mut ck0 = ckpt(0);
        ck0.dref = None;
        save(&dir, &ck0, 1, "ds", 2, 2).unwrap();
        save(&dir, &ckpt(1), 2, "ds", 2, 2).unwrap();
        let (back, digest) = load_latest(&dir, "ds", 2, 2).unwrap().unwrap();
        assert_eq!((back.step, digest), (1, 2));
        std::fs::remove_file(step_path(&dir, 1)).unwrap();
        let (back, _) = load_latest(&dir, "ds", 2, 2).unwrap().unwrap();
        assert_eq!(back.step, 0);
        assert!(back.dref.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_or_absent_dir_resumes_fresh() {
        let dir = tmpdir("empty");
        assert!(load_latest(&dir, "ds", 2, 2).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
        assert!(load_latest(&dir, "ds", 2, 2).unwrap().is_none());
    }

    #[test]
    fn corruption_truncation_and_wrong_shard_error_name_the_flag() {
        let dir = tmpdir("corrupt");
        save(&dir, &ckpt(0), 7, "ds", 2, 2).unwrap();
        let p = step_path(&dir, 0);

        // bit flip inside the payload
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{:#}", load_latest(&dir, "ds", 2, 2).unwrap_err());
        assert!(err.contains("--checkpoint"), "unactionable error: {err}");

        // truncation
        save(&dir, &ckpt(0), 7, "ds", 2, 2).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 3]).unwrap();
        let err = format!("{:#}", load_latest(&dir, "ds", 2, 2).unwrap_err());
        assert!(err.contains("--checkpoint"), "unactionable error: {err}");

        // written against a different shard
        save(&dir, &ckpt(0), 7, "ds", 2, 2).unwrap();
        let err = format!("{:#}", load_latest(&dir, "other", 2, 2).unwrap_err());
        assert!(err.contains("--checkpoint") && err.contains("other"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn grid_digest_is_a_prefix_digest() {
        let long = [1.0, 0.8, 0.6, 0.4];
        let short = &long[..2];
        let dig = |r: &[f64]| grid_digest("ds", 10, 3, "l21", "Dpc", "Fista", 2.5, r);
        // the digest at step 1 must not see ratios beyond step 1 — that is
        // what makes prefix-run checkpoints resumable into a longer grid
        assert_eq!(dig(&long[..2]), dig(short));
        assert_ne!(dig(&long[..2]), dig(&long[..3]));
        // and every configuration field is load-bearing
        assert_ne!(dig(short), grid_digest("ds", 11, 3, "l21", "Dpc", "Fista", 2.5, short));
        assert_ne!(dig(short), grid_digest("ds", 10, 3, "sgl(0.5)", "Dpc", "Fista", 2.5, short));
        assert_ne!(dig(short), grid_digest("ds", 10, 3, "l21", "Gap", "Fista", 2.5, short));
        assert_ne!(dig(short), grid_digest("ds", 10, 3, "l21", "Dpc", "Bcd", 2.5, short));
        assert_ne!(dig(short), grid_digest("ds", 10, 3, "l21", "Dpc", "Fista", 2.4, short));
    }
}
