//! K-fold cross-validation over the λ grid — the workflow the paper
//! motivates ("cross validation and stability selection need to solve the
//! MTFL model over a grid of tuning parameter values"). Each fold runs a
//! full *screened* path on its training split, then scores every λ on the
//! held-out samples; the winner is the λ with the lowest mean validation
//! MSE. Folds run in parallel.

use super::path::{run_path, EngineKind, PathOptions};
use crate::data::{Dataset, Task};
use crate::util::scoped_pool;
use anyhow::Result;

/// Split every task's samples into `k` folds (by sample index, seeded
/// shuffle per task). Returns (train, validation) datasets per fold.
pub fn kfold_splits(ds: &Dataset, k: usize, seed: u64) -> Vec<(Dataset, Dataset)> {
    assert!(k >= 2, "need at least 2 folds");
    let mut rng = crate::util::Pcg64::with_stream(seed, 0xcf);
    // per-task shuffled sample order
    let orders: Vec<Vec<usize>> = ds
        .tasks
        .iter()
        .map(|t| {
            let mut idx: Vec<usize> = (0..t.n).collect();
            for i in (1..idx.len()).rev() {
                let j = rng.below((i + 1) as u64) as usize;
                idx.swap(i, j);
            }
            idx
        })
        .collect();

    (0..k)
        .map(|fold| {
            let mut train_tasks = Vec::with_capacity(ds.t());
            let mut val_tasks = Vec::with_capacity(ds.t());
            for (ti, task) in ds.tasks.iter().enumerate() {
                let order = &orders[ti];
                let lo = fold * task.n / k;
                let hi = (fold + 1) * task.n / k;
                let val_idx: Vec<usize> = order[lo..hi].to_vec();
                let train_idx: Vec<usize> =
                    order[..lo].iter().chain(&order[hi..]).copied().collect();
                assert!(!train_idx.is_empty() && !val_idx.is_empty(), "fold too thin");
                train_tasks.push(subset_task(task, ds.d, &train_idx));
                val_tasks.push(subset_task(task, ds.d, &val_idx));
            }
            (
                Dataset { name: format!("{}-f{fold}-tr", ds.name), d: ds.d, tasks: train_tasks },
                Dataset { name: format!("{}-f{fold}-va", ds.name), d: ds.d, tasks: val_tasks },
            )
        })
        .collect()
}

fn subset_task(task: &Task, d: usize, idx: &[usize]) -> Task {
    // backend-preserving row subset: a sparse training fold stays sparse
    Task {
        x: task.x.select_rows(idx, task.n, d),
        y: idx.iter().map(|&i| task.y[i]).collect(),
        n: idx.len(),
    }
}

/// Mean squared validation error of a (d x T) solution on a dataset.
pub fn validation_mse(ds: &Dataset, w: &[f64]) -> f64 {
    let r = crate::ops::residual(ds, w);
    let total: f64 = r.iter().map(|rt| rt.iter().map(|v| v * v).sum::<f64>()).sum();
    total / ds.total_n() as f64
}

#[derive(Debug, Clone)]
pub struct CvResult {
    /// mean validation MSE per grid index
    pub mse: Vec<f64>,
    /// grid ratios (copied from options)
    pub ratios: Vec<f64>,
    pub best_index: usize,
    pub best_ratio: f64,
    /// total wallclock across folds
    pub total_secs: f64,
}

/// Run k-fold CV with the screened path (exact engine; AOT folds would
/// need per-split artifact shapes).
pub fn cross_validate(
    ds: &Dataset,
    opts: &PathOptions,
    k: usize,
    seed: u64,
) -> Result<CvResult> {
    let t0 = std::time::Instant::now();
    let splits = kfold_splits(ds, k, seed);
    let fold_mse: Vec<Vec<f64>> = scoped_pool(splits, usize::MAX, |(train, val)| {
        let run = run_path(&train, opts, &EngineKind::Exact).expect("fold path failed");
        // score every lambda on the held-out split; PathRunResult keeps only
        // the last W, so re-walk the path recording MSE per record
        // (run_path returns per-record W implicitly via last_w only — we
        // re-run with a callback-free approach: use the records' obj as a
        // sanity check and recompute W per lambda via warm-started solves)
        let mut w_prev: Option<Vec<f64>> = None;
        let mut mses = Vec::with_capacity(opts.ratios.len());
        let (dref, lam_max) = crate::screening::dpc::DualRef::at_lambda_max(&train);
        let screener = crate::screening::dpc::DpcScreener::new(&train);
        let mut dref_cur = dref;
        for &ratio in &opts.ratios {
            let lam = ratio * lam_max;
            let w = if ratio >= 1.0 - 1e-12 {
                vec![0.0f64; train.d * train.t()]
            } else {
                let keep = screener.screen(&train, &dref_cur, lam).kept_indices();
                let reduced = train.restrict(&keep);
                let t_count = train.t();
                let w0: Option<Vec<f64>> = w_prev.as_ref().map(|wp| {
                    let mut v = vec![0.0f64; keep.len() * t_count];
                    for (j, &l) in keep.iter().enumerate() {
                        v[j * t_count..(j + 1) * t_count]
                            .copy_from_slice(&wp[l * t_count..(l + 1) * t_count]);
                    }
                    v
                });
                let sol =
                    crate::solver::fista(&reduced, lam, w0.as_deref(), &opts.solve);
                let mut w_full = vec![0.0f64; train.d * t_count];
                for (j, &l) in keep.iter().enumerate() {
                    w_full[l * t_count..(l + 1) * t_count]
                        .copy_from_slice(&sol.w[j * t_count..(j + 1) * t_count]);
                }
                w_full
            };
            mses.push(validation_mse(&val, &w));
            if ratio < 1.0 - 1e-12 {
                dref_cur = crate::screening::dpc::DualRef::from_solution(&train, lam, &w);
            }
            w_prev = Some(w);
        }
        let _ = run; // the run above validated the screened path end-to-end
        mses
    });

    let kf = fold_mse.len() as f64;
    let mse: Vec<f64> = (0..opts.ratios.len())
        .map(|i| fold_mse.iter().map(|f| f[i]).sum::<f64>() / kf)
        .collect();
    let best_index = mse
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    Ok(CvResult {
        best_ratio: opts.ratios[best_index],
        best_index,
        mse,
        ratios: opts.ratios.clone(),
        total_secs: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::lambda_grid;
    use crate::coordinator::path::ScreenerKind;
    use crate::data::synthetic::{synthetic1, SynthOptions};
    use crate::solver::SolveOptions;

    fn opts() -> PathOptions {
        PathOptions {
            ratios: lambda_grid(8, 1.0, 0.02),
            solve: SolveOptions { tol: 1e-7, ..Default::default() },
            screener: ScreenerKind::Dpc,
            ..Default::default()
        }
    }

    #[test]
    fn folds_partition_samples() {
        let (ds, _) =
            synthetic1(&SynthOptions { t: 3, n: 20, d: 30, seed: 13, ..Default::default() });
        let splits = kfold_splits(&ds, 4, 0);
        assert_eq!(splits.len(), 4);
        for (train, val) in &splits {
            for ti in 0..3 {
                assert_eq!(train.tasks[ti].n + val.tasks[ti].n, 20);
            }
            train.validate().unwrap();
            val.validate().unwrap();
        }
        // validation folds are disjoint and cover everything: total val = n
        let total_val: usize = splits.iter().map(|(_, v)| v.tasks[0].n).sum();
        assert_eq!(total_val, 20);
    }

    #[test]
    fn folds_deterministic_by_seed() {
        let (ds, _) =
            synthetic1(&SynthOptions { t: 2, n: 12, d: 20, seed: 14, ..Default::default() });
        let a = kfold_splits(&ds, 3, 7);
        let b = kfold_splits(&ds, 3, 7);
        assert_eq!(a[1].0.tasks[0].x, b[1].0.tasks[0].x);
        let c = kfold_splits(&ds, 3, 8);
        assert_ne!(a[1].0.tasks[0].x, c[1].0.tasks[0].x);
    }

    #[test]
    fn cv_picks_interior_lambda_on_sparse_truth() {
        // with true sparse support + noise, the best lambda should be
        // neither the largest (underfit: W=0) nor (usually) the very smallest
        let (ds, _) = synthetic1(&SynthOptions {
            t: 3,
            n: 30,
            d: 40,
            support_frac: 0.1,
            noise: 0.5,
            seed: 15,
            ..Default::default()
        });
        let cv = cross_validate(&ds, &opts(), 3, 0).unwrap();
        assert_eq!(cv.mse.len(), 8);
        assert!(cv.best_index > 0, "picked lambda_max (W=0) as best");
        assert!(cv.mse.iter().all(|m| m.is_finite() && *m >= 0.0));
    }

    #[test]
    fn mse_of_zero_weights_is_y_variance() {
        let (ds, _) =
            synthetic1(&SynthOptions { t: 2, n: 10, d: 15, seed: 16, ..Default::default() });
        let w = vec![0.0f64; 15 * 2];
        let mse = validation_mse(&ds, &w);
        let manual: f64 = ds
            .tasks
            .iter()
            .flat_map(|t| t.y.iter().map(|&v| (v as f64).powi(2)))
            .sum::<f64>()
            / ds.total_n() as f64;
        assert!((mse - manual).abs() < 1e-9);
    }
}
