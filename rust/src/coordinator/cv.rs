//! K-fold cross-validation over the λ grid — the workflow the paper
//! motivates ("cross validation and stability selection need to solve the
//! MTFL model over a grid of tuning parameter values"). Each fold runs a
//! full *screened* path on its training split and scores every λ on the
//! held-out samples **inside that single pass**: a [`PathObserver`] hook
//! receives each per-λ solution as the path runner produces it, so the
//! fold pays for the path exactly once (the pre-observer implementation
//! re-solved the whole path a second time to recover per-λ solutions —
//! and hardcoded FISTA + DPC while doing it, ignoring the configured
//! screener/solver). The winner is the λ with the lowest mean validation
//! MSE. Folds run in parallel; per-fold failures propagate as errors.
//!
//! Penalty seam (DESIGN.md §14): the penalty rides along in
//! `PathOptions::solve.penalty` untouched — CV composes with any penalty
//! the path runner accepts. [`validation_mse`] is *loss*-owned, not
//! penalty-owned (held-out error is squared loss regardless of the
//! regularizer); a future multinomial loss would swap it through the
//! `penalty::loss` seam.

use super::path::{run_path_with, EngineKind, LambdaRecord, PathObserver, PathOptions};
use crate::data::{Dataset, Task};
use crate::linalg::simd::{sum_serial_f64, sumsq_serial_f64};
use crate::util::{scoped_pool, Stopwatch};
use anyhow::{Context, Result};

/// Split every task's samples into `k` folds (by sample index, seeded
/// shuffle per task). Returns (train, validation) datasets per fold, or an
/// error if `k < 2` or any fold would leave a task without train or
/// validation samples.
pub fn kfold_splits(ds: &Dataset, k: usize, seed: u64) -> Result<Vec<(Dataset, Dataset)>> {
    anyhow::ensure!(k >= 2, "cross-validation needs at least 2 folds, got k={k}");
    let mut rng = crate::util::Pcg64::with_stream(seed, 0xcf);
    // per-task shuffled sample order
    let orders: Vec<Vec<usize>> = ds
        .tasks
        .iter()
        .map(|t| {
            let mut idx: Vec<usize> = (0..t.n).collect();
            for i in (1..idx.len()).rev() {
                let j = rng.below((i + 1) as u64) as usize;
                idx.swap(i, j);
            }
            idx
        })
        .collect();

    let mut splits = Vec::with_capacity(k);
    for fold in 0..k {
        let mut train_tasks = Vec::with_capacity(ds.t());
        let mut val_tasks = Vec::with_capacity(ds.t());
        for (ti, task) in ds.tasks.iter().enumerate() {
            let order = &orders[ti];
            let lo = fold * task.n / k;
            let hi = (fold + 1) * task.n / k;
            let val_idx: Vec<usize> = order[lo..hi].to_vec();
            let train_idx: Vec<usize> =
                order[..lo].iter().chain(&order[hi..]).copied().collect();
            anyhow::ensure!(
                !train_idx.is_empty() && !val_idx.is_empty(),
                "fold {fold} of {k} leaves task {ti} (n={}) with an empty {} split — \
                 use fewer folds or more samples per task",
                task.n,
                if val_idx.is_empty() { "validation" } else { "training" }
            );
            train_tasks.push(subset_task(task, ds.d, &train_idx));
            val_tasks.push(subset_task(task, ds.d, &val_idx));
        }
        splits.push((
            Dataset { name: format!("{}-f{fold}-tr", ds.name), d: ds.d, tasks: train_tasks },
            Dataset { name: format!("{}-f{fold}-va", ds.name), d: ds.d, tasks: val_tasks },
        ));
    }
    Ok(splits)
}

fn subset_task(task: &Task, d: usize, idx: &[usize]) -> Task {
    // backend-preserving row subset: a sparse training fold stays sparse
    Task {
        x: task.x.select_rows(idx, task.n, d),
        y: idx.iter().map(|&i| task.y[i]).collect(),
        n: idx.len(),
    }
}

/// Mean squared validation error of a (d x T) solution on a dataset.
pub fn validation_mse(ds: &Dataset, w: &[f64]) -> f64 {
    let r = crate::ops::residual(ds, w);
    // per-task Σr² partials re-folded left to right: the same grouping
    // (and the same bits) as the old nested iterator sums, through the
    // pinned-order reduction home
    let mut total = 0.0f64;
    for rt in &r {
        total += sumsq_serial_f64(rt);
    }
    total / ds.total_n() as f64
}

/// Cross-validation output: the validation curve and its winner.
#[derive(Debug, Clone)]
pub struct CvResult {
    /// mean validation MSE per grid index
    pub mse: Vec<f64>,
    /// grid ratios (copied from options)
    pub ratios: Vec<f64>,
    /// grid index of the lowest mean validation MSE
    pub best_index: usize,
    /// λ/λ_max ratio at `best_index`
    pub best_ratio: f64,
    /// total solver column-sweep work across folds (one screened path per
    /// fold — the one-pass guarantee BENCH/tests pin down)
    pub col_ops: usize,
    /// per-fold breakdown of `col_ops`
    pub fold_col_ops: Vec<usize>,
    /// total wallclock across folds
    pub total_secs: f64,
}

/// Per-fold observer: scores every λ on the held-out split as the training
/// path streams its solutions.
struct HeldOutScorer<'a> {
    val: &'a Dataset,
    mse: Vec<f64>,
}

impl PathObserver for HeldOutScorer<'_> {
    fn on_solution(&mut self, _ratio: f64, _lam: f64, w_full: &[f64], _rec: &LambdaRecord) {
        self.mse.push(validation_mse(self.val, w_full));
    }
}

/// Run k-fold CV with the screened path (exact engine; AOT folds would
/// need per-split artifact shapes). Uses the screener and solver configured
/// in `opts` — every fold runs `run_path_with` exactly once, scoring each
/// held-out λ from the streamed per-λ solutions.
pub fn cross_validate(
    ds: &Dataset,
    opts: &PathOptions,
    k: usize,
    seed: u64,
) -> Result<CvResult> {
    let sw = Stopwatch::started();
    let splits = kfold_splits(ds, k, seed)?;
    // fold fan-out on the persistent executor's nested-safe scope: the
    // solver/sweep parallelism underneath runs inline on whichever worker
    // owns the fold, so cv→fista→ops composes to at most num_threads()
    // execution streams — min(k, W) while folds remain, since nested
    // work inlines rather than steals (DESIGN.md §11 documents the
    // trade-off) — where the old spawn-per-layer stack multiplied
    // workers into oversubscription instead
    let folds: Vec<Result<(Vec<f64>, usize)>> = scoped_pool(splits, usize::MAX, |(train, val)| {
        let mse = Vec::with_capacity(opts.ratios.len());
        let mut scorer = HeldOutScorer { val: &val, mse };
        let run = run_path_with(&train, opts, &EngineKind::Exact, &mut scorer)
            .with_context(|| format!("λ-path failed on fold split '{}'", train.name))?;
        Ok((scorer.mse, run.total_col_ops()))
    });

    let mut fold_mse = Vec::with_capacity(k);
    let mut fold_col_ops = Vec::with_capacity(k);
    for fold in folds {
        let (mse, ops) = fold?;
        debug_assert_eq!(mse.len(), opts.ratios.len());
        fold_mse.push(mse);
        fold_col_ops.push(ops);
    }

    let kf = fold_mse.len() as f64;
    let mut across = vec![0.0f64; fold_mse.len()];
    let mse: Vec<f64> = (0..opts.ratios.len())
        .map(|i| {
            for (g, f) in across.iter_mut().zip(&fold_mse) {
                *g = f[i];
            }
            sum_serial_f64(&across) / kf
        })
        .collect();
    let best_index = mse
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    Ok(CvResult {
        best_ratio: opts.ratios[best_index],
        best_index,
        mse,
        ratios: opts.ratios.clone(),
        col_ops: fold_col_ops.iter().sum(),
        fold_col_ops,
        total_secs: sw.secs(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::lambda_grid;
    use crate::coordinator::path::ScreenerKind;
    use crate::data::synthetic::{synthetic1, SynthOptions};
    use crate::solver::SolveOptions;

    fn opts() -> PathOptions {
        PathOptions {
            ratios: lambda_grid(8, 1.0, 0.02),
            solve: SolveOptions { tol: 1e-7, ..Default::default() },
            screener: ScreenerKind::Dpc,
            ..Default::default()
        }
    }

    #[test]
    fn folds_partition_samples() {
        let (ds, _) =
            synthetic1(&SynthOptions { t: 3, n: 20, d: 30, seed: 13, ..Default::default() });
        let splits = kfold_splits(&ds, 4, 0).unwrap();
        assert_eq!(splits.len(), 4);
        for (train, val) in &splits {
            for ti in 0..3 {
                assert_eq!(train.tasks[ti].n + val.tasks[ti].n, 20);
            }
            train.validate().unwrap();
            val.validate().unwrap();
        }
        // validation folds are disjoint and cover everything: total val = n
        let total_val: usize = splits.iter().map(|(_, v)| v.tasks[0].n).sum();
        assert_eq!(total_val, 20);
    }

    #[test]
    fn folds_deterministic_by_seed() {
        let (ds, _) =
            synthetic1(&SynthOptions { t: 2, n: 12, d: 20, seed: 14, ..Default::default() });
        let a = kfold_splits(&ds, 3, 7).unwrap();
        let b = kfold_splits(&ds, 3, 7).unwrap();
        assert_eq!(a[1].0.tasks[0].x, b[1].0.tasks[0].x);
        let c = kfold_splits(&ds, 3, 8).unwrap();
        assert_ne!(a[1].0.tasks[0].x, c[1].0.tasks[0].x);
    }

    #[test]
    fn degenerate_folds_are_errors_not_panics() {
        let (ds, _) =
            synthetic1(&SynthOptions { t: 2, n: 6, d: 10, seed: 19, ..Default::default() });
        // k < 2 is a usage error
        let err = kfold_splits(&ds, 1, 0).unwrap_err();
        assert!(err.to_string().contains("at least 2 folds"), "got: {err}");
        assert!(cross_validate(&ds, &opts(), 1, 0).is_err());
        // more folds than samples leaves an empty validation split
        let err = kfold_splits(&ds, 10, 0).unwrap_err();
        assert!(err.to_string().contains("empty"), "got: {err}");
        assert!(cross_validate(&ds, &opts(), 10, 0).is_err());
    }

    #[test]
    fn cv_picks_interior_lambda_on_sparse_truth() {
        // with true sparse support + noise, the best lambda should be
        // neither the largest (underfit: W=0) nor (usually) the very smallest
        let (ds, _) = synthetic1(&SynthOptions {
            t: 3,
            n: 30,
            d: 40,
            support_frac: 0.1,
            noise: 0.5,
            seed: 15,
        });
        let cv = cross_validate(&ds, &opts(), 3, 0).unwrap();
        assert_eq!(cv.mse.len(), 8);
        assert!(cv.best_index > 0, "picked lambda_max (W=0) as best");
        assert!(cv.mse.iter().all(|m| m.is_finite() && *m >= 0.0));
        assert_eq!(cv.fold_col_ops.len(), 3);
        assert_eq!(cv.col_ops, cv.fold_col_ops.iter().sum::<usize>());
    }

    #[test]
    fn mse_of_zero_weights_is_y_variance() {
        let (ds, _) =
            synthetic1(&SynthOptions { t: 2, n: 10, d: 15, seed: 16, ..Default::default() });
        let w = vec![0.0f64; 15 * 2];
        let mse = validation_mse(&ds, &w);
        let manual: f64 = ds
            .tasks
            .iter()
            .flat_map(|t| t.y.iter().map(|&v| (v as f64).powi(2)))
            .sum::<f64>()
            / ds.total_n() as f64;
        assert!((mse - manual).abs() < 1e-9);
    }
}
