//! Renderers that print the paper's tables/figures from metric structs —
//! the benches and the CLI both go through these so the output format is
//! uniform and diffable (EXPERIMENTS.md records these outputs verbatim).

use super::metrics::SpeedupRow;
use crate::bench::Table;

/// Table 1: running-time comparison.
pub fn render_table1(rows: &[SpeedupRow]) -> String {
    let mut t = Table::new(&[
        "dataset", "d", "solver(s)", "DPC(s)", "DPC+solver(s)", "speedup", "mean rej.",
    ]);
    for r in rows {
        t.row(&[
            r.dataset.clone(),
            r.d.to_string(),
            format!("{:.2}", r.solver_secs),
            format!("{:.3}", r.dpc_secs),
            format!("{:.2}", r.combined_secs),
            format!("{:.2}x", r.speedup),
            format!("{:.4}", r.mean_rejection),
        ]);
    }
    t.render()
}

/// Figure panel: rejection-ratio curve as aligned CSV (ratio, rejection).
/// Downstream plotting is a cut-and-paste away; the *shape* check (paper
/// comparison) reads these numbers directly.
pub fn render_rejection_curve(title: &str, curve: &[(f64, f64)]) -> String {
    let mut out = format!("# {title}\n# lambda/lambda_max, rejection_ratio\n");
    for (r, v) in curve {
        out.push_str(&format!("{r:.6}, {v:.6}\n"));
    }
    // compact sparkline-ish summary for terminals
    let buckets = 20.min(curve.len());
    if buckets > 1 {
        let mut bar = String::from("# [1.0 -> 0.01]: ");
        for i in 0..buckets {
            let idx = i * (curve.len() - 1) / (buckets - 1);
            let v = curve[idx].1;
            let ch = match (v * 8.0) as usize {
                0 => ' ',
                1 => '.',
                2 => ':',
                3 => '-',
                4 => '=',
                5 => '+',
                6 => '*',
                7 => '#',
                _ => '@',
            };
            bar.push(ch);
        }
        out.push_str(&bar);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_contains_speedup_column() {
        let rows = vec![SpeedupRow {
            dataset: "synthetic1".into(),
            d: 2000,
            solver_secs: 120.0,
            dpc_secs: 0.4,
            combined_secs: 6.0,
            speedup: 20.0,
            mean_rejection: 0.97,
        }];
        let s = render_table1(&rows);
        assert!(s.contains("20.00x"));
        assert!(s.contains("synthetic1"));
    }

    #[test]
    fn curve_renders_all_points() {
        let curve = vec![(1.0, 0.0), (0.5, 0.9), (0.01, 1.0)];
        let s = render_rejection_curve("fig1-panel", &curve);
        // 3 data rows (the header comment also contains one ", ")
        let data_rows = s.lines().filter(|l| !l.starts_with('#')).count();
        assert_eq!(data_rows, 3);
        assert!(s.contains("0.500000, 0.900000"));
    }
}
