//! Stability selection over the λ grid — the second grid workflow the
//! paper names ("cross validation and stability selection"). Subsample
//! half of every task's samples B times, run the *screened* path on each
//! subsample, and report per-feature selection frequencies; features
//! crossing `threshold` form the stable set (Meinshausen & Bühlmann 2010,
//! adapted to the shared-support MTFL setting).
//!
//! A feature counts as selected in a subsample if its solution row is
//! nonzero at *any* λ of the grid. The union-over-λ mask is accumulated by
//! a [`PathObserver`] as the path streams each per-λ solution — the
//! pre-observer implementation only tested the final (smallest-λ)
//! solution, silently missing features active only at larger λ.
//!
//! Penalty seam (DESIGN.md §14): penalty-agnostic by construction — the
//! penalty rides along in `PathOptions::solve.penalty`, and the
//! union-over-λ activity test (nonzero solution rows) is exactly the row
//! structure every [`crate::penalty::Penalty`] instance regularizes.

use super::path::{run_path_with, EngineKind, LambdaRecord, PathObserver, PathOptions};
use crate::data::{Dataset, Task};
use crate::util::{scoped_pool, Pcg64};
use anyhow::{Context, Result};

fn half_sample(ds: &Dataset, rng: &mut Pcg64) -> Dataset {
    let tasks = ds
        .tasks
        .iter()
        .map(|task| {
            let keep = rng.choose_distinct(task.n, (task.n / 2).max(1));
            // backend-preserving row subset (sparse subsamples stay sparse)
            Task {
                x: task.x.select_rows(&keep, task.n, ds.d),
                y: keep.iter().map(|&i| task.y[i]).collect(),
                n: keep.len(),
            }
        })
        .collect();
    Dataset { name: format!("{}-half", ds.name), d: ds.d, tasks }
}

/// Stability-selection output: selection frequencies and the stable set.
#[derive(Debug, Clone)]
pub struct StabilityResult {
    /// per feature: fraction of subsamples where the feature's solution
    /// row was nonzero at any λ of the grid
    pub frequency: Vec<f64>,
    /// features with frequency >= threshold
    pub stable: Vec<usize>,
    /// number of half-subsamples run
    pub subsamples: usize,
    /// total wallclock across subsamples
    pub total_secs: f64,
}

/// Union-over-λ active mask for one subsample's path: marks a feature as
/// soon as any streamed solution has a nonzero row for it.
struct EverActiveMask {
    mask: Vec<bool>,
    t_count: usize,
    tol: f64,
}

impl PathObserver for EverActiveMask {
    fn on_solution(&mut self, _ratio: f64, _lam: f64, w_full: &[f64], _rec: &LambdaRecord) {
        for (m, row) in self.mask.iter_mut().zip(w_full.chunks_exact(self.t_count)) {
            if !*m && crate::ops::row_is_active(row, self.tol) {
                *m = true;
            }
        }
    }
}

/// Run stability selection with `b` half-subsamples (parallel across the
/// pool); a feature counts as selected at a subsample if its solution row
/// is nonzero (row norm > `opts.active_tol`) at *any* λ of the grid.
pub fn stability_selection(
    ds: &Dataset,
    opts: &PathOptions,
    b: usize,
    threshold: f64,
    seed: u64,
) -> Result<StabilityResult> {
    anyhow::ensure!(b >= 2, "stability selection needs at least 2 subsamples, got b={b}");
    let sw = crate::util::Stopwatch::started();
    let mut root = Pcg64::with_stream(seed, 0x57ab);
    let subs: Vec<Dataset> = (0..b)
        .map(|i| {
            let mut r = root.split(i as u64);
            half_sample(ds, &mut r)
        })
        .collect();

    let t_count = ds.t();
    // subsample fan-out on the executor's nested-safe scope (DESIGN.md
    // §11): inner path/solver parallelism inlines on the owning worker,
    // never multiplying threads
    let masks: Vec<Result<Vec<bool>>> = scoped_pool(subs, usize::MAX, |sub| {
        let mut ever = EverActiveMask { mask: vec![false; sub.d], t_count, tol: opts.active_tol };
        run_path_with(&sub, opts, &EngineKind::Exact, &mut ever)
            .with_context(|| format!("λ-path failed on subsample '{}'", sub.name))?;
        Ok(ever.mask)
    });

    // integer hit counts, converted once — same values as accumulating
    // 1.0s in f64 (exact up to 2^53), without a float fold
    let mut hits = vec![0usize; ds.d];
    for mask in masks {
        for (l, m) in mask?.into_iter().enumerate() {
            if m {
                hits[l] += 1;
            }
        }
    }
    let frequency: Vec<f64> = hits.iter().map(|&c| c as f64 / b as f64).collect();
    let stable = frequency
        .iter()
        .enumerate()
        .filter_map(|(l, &f)| (f >= threshold).then_some(l))
        .collect();
    Ok(StabilityResult { frequency, stable, subsamples: b, total_secs: sw.secs() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::lambda_grid;
    use crate::coordinator::path::ScreenerKind;
    use crate::data::synthetic::{synthetic1, SynthOptions};
    use crate::solver::SolveOptions;

    #[test]
    fn stable_set_contains_strong_true_features() {
        let (ds, gt) = synthetic1(&SynthOptions {
            t: 3,
            n: 40,
            d: 60,
            support_frac: 0.08,
            noise: 0.05,
            seed: 51,
        });
        let opts = PathOptions {
            ratios: lambda_grid(6, 1.0, 0.1),
            solve: SolveOptions { tol: 1e-6, ..Default::default() },
            screener: ScreenerKind::Dpc,
            ..Default::default()
        };
        let res = stability_selection(&ds, &opts, 6, 0.8, 0).unwrap();
        assert_eq!(res.frequency.len(), 60);
        assert!(res.frequency.iter().all(|&f| (0.0..=1.0).contains(&f)));
        // strong true features should be stably selected
        let hits = gt.active.iter().filter(|l| res.stable.contains(l)).count();
        assert!(
            hits * 2 >= gt.active.len(),
            "stable set recovered {hits}/{}",
            gt.active.len()
        );
        // and the stable set should be a small fraction of all features
        assert!(res.stable.len() < 30, "stable set too large: {}", res.stable.len());
    }

    #[test]
    fn too_few_subsamples_is_an_error() {
        let (ds, _) =
            synthetic1(&SynthOptions { t: 2, n: 10, d: 10, seed: 53, ..Default::default() });
        let opts = PathOptions { ratios: lambda_grid(4, 1.0, 0.1), ..Default::default() };
        let err = stability_selection(&ds, &opts, 1, 0.8, 0).unwrap_err();
        assert!(err.to_string().contains("at least 2 subsamples"), "got: {err}");
    }

    #[test]
    fn half_sampling_halves_n() {
        let (ds, _) =
            synthetic1(&SynthOptions { t: 2, n: 20, d: 10, seed: 52, ..Default::default() });
        let mut rng = Pcg64::new(1);
        let half = half_sample(&ds, &mut rng);
        half.validate().unwrap();
        assert_eq!(half.tasks[0].n, 10);
        assert_eq!(half.d, 10);
    }
}
