//! The paper's tuning grid: K values of λ/λ_max equally spaced on a log
//! scale from `hi` down to `lo` (§5: 100 values, 1.0 → 0.01).

/// Ratios λ/λ_max, descending from `hi` to `lo` inclusive.
pub fn lambda_grid(k: usize, hi: f64, lo: f64) -> Vec<f64> {
    assert!(k >= 2 && hi > lo && lo > 0.0);
    let (lh, ll) = (hi.ln(), lo.ln());
    (0..k)
        .map(|i| (lh + (ll - lh) * i as f64 / (k - 1) as f64).exp())
        .collect()
}

/// The paper's default grid.
pub fn paper_grid(k: usize) -> Vec<f64> {
    lambda_grid(k, 1.0, 0.01)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints_and_monotone() {
        let g = paper_grid(100);
        assert_eq!(g.len(), 100);
        assert!((g[0] - 1.0).abs() < 1e-12);
        assert!((g[99] - 0.01).abs() < 1e-12);
        for w in g.windows(2) {
            assert!(w[1] < w[0]);
        }
    }

    #[test]
    fn log_spacing_is_uniform() {
        let g = lambda_grid(5, 1.0, 0.0001);
        for i in 0..4 {
            let r = g[i + 1] / g[i];
            assert!((r - 0.1).abs() < 1e-12, "ratio {r}");
        }
    }
}
