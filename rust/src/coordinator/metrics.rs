//! Experiment metrics: rejection-ratio aggregation across trials and the
//! Table-1 speedup accounting.
//!
//! [`RejectionCurve`] is the streaming form: it registers as a
//! [`PathObserver`] across repeated trials of the same grid and averages
//! per-index rejection ratios as records arrive, so the figure drivers
//! never have to retain whole [`PathRunResult`]s per trial.

use super::path::{LambdaRecord, PathObserver, PathRunResult};

/// Streaming accumulator for the Figs. 1–2 curves. Register it as the
/// observer of one `run_path_with` call per trial (all trials must share
/// the λ grid); read the averaged curve with [`RejectionCurve::curve`].
pub struct RejectionCurve {
    grid_len: usize,
    ratios: Vec<f64>,
    sums: Vec<f64>,
    seen: usize,
}

impl RejectionCurve {
    /// An empty accumulator for trials over a `grid_len`-point grid.
    pub fn new(grid_len: usize) -> Self {
        assert!(grid_len > 0, "empty λ grid");
        RejectionCurve {
            grid_len,
            ratios: Vec::with_capacity(grid_len),
            sums: vec![0.0; grid_len],
            seen: 0,
        }
    }

    /// Completed trials observed so far.
    pub fn trials(&self) -> usize {
        self.seen / self.grid_len
    }

    /// The (ratio, mean rejection ratio) curve across observed trials.
    pub fn curve(&self) -> Vec<(f64, f64)> {
        assert!(
            self.seen > 0 && self.seen % self.grid_len == 0,
            "curve read mid-trial: {} of {} records",
            self.seen % self.grid_len,
            self.grid_len
        );
        let t = self.trials() as f64;
        self.ratios.iter().zip(&self.sums).map(|(&r, &s)| (r, s / t)).collect()
    }
}

impl PathObserver for RejectionCurve {
    fn on_solution(&mut self, ratio: f64, _lam: f64, _w_full: &[f64], rec: &LambdaRecord) {
        let i = self.seen % self.grid_len;
        if self.trials() == 0 && i == self.ratios.len() {
            self.ratios.push(ratio);
        } else {
            assert!(
                (self.ratios[i] - ratio).abs() < 1e-12,
                "trials must share the grid: index {i} saw ratio {ratio} vs {}",
                self.ratios[i]
            );
        }
        self.sums[i] += rec.rejection_ratio;
        self.seen += 1;
    }
}

/// Mean rejection ratio per grid index across repeated trials
/// (the curves of Figs. 1–2), from retained run results.
pub fn mean_rejection_curve(runs: &[PathRunResult]) -> Vec<(f64, f64)> {
    assert!(!runs.is_empty());
    let k = runs[0].records.len();
    assert!(runs.iter().all(|r| r.records.len() == k), "trials must share the grid");
    let mut across = vec![0.0f64; runs.len()];
    (0..k)
        .map(|i| {
            let ratio = runs[0].records[i].ratio;
            for (g, r) in across.iter_mut().zip(runs) {
                *g = r.records[i].rejection_ratio;
            }
            // runs is non-empty (asserted above), so the mean's len.max(1)
            // divisor equals runs.len() — bit-identical to the old fold
            (ratio, crate::linalg::simd::mean_serial_f64(&across))
        })
        .collect()
}

/// One Table-1 row: timing comparison of a baseline (no screening) run
/// against a screened run of the *same* problem.
#[derive(Debug, Clone)]
pub struct SpeedupRow {
    /// workload name
    pub dataset: String,
    /// feature dimension
    pub d: usize,
    /// solver without screening (total path seconds)
    pub solver_secs: f64,
    /// screening rule cost alone
    pub dpc_secs: f64,
    /// screened path total (screen + reduced solve)
    pub combined_secs: f64,
    /// `solver_secs / combined_secs`
    pub speedup: f64,
    /// mean rejection ratio of the screened run
    pub mean_rejection: f64,
}

/// Assemble one Table-1 row from a baseline and a screened run of the
/// same problem.
pub fn speedup_row(baseline: &PathRunResult, screened: &PathRunResult) -> SpeedupRow {
    let solver_secs = baseline.total_secs;
    let combined = screened.total_secs;
    SpeedupRow {
        dataset: baseline.dataset.clone(),
        d: baseline.d,
        solver_secs,
        dpc_secs: screened.screen_secs,
        combined_secs: combined,
        speedup: solver_secs / combined.max(1e-12),
        mean_rejection: screened.mean_rejection_ratio(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::path::{LambdaRecord, PathRunResult};

    fn fake_run(rr: &[f64], total: f64, screen: f64) -> PathRunResult {
        PathRunResult {
            dataset: "fake".into(),
            d: 10,
            lam_max: 1.0,
            records: rr
                .iter()
                .enumerate()
                .map(|(i, &r)| LambdaRecord {
                    ratio: 1.0 / (i + 1) as f64,
                    lam: 0.0,
                    rejected: 0,
                    kept: 0,
                    inactive: 0,
                    rejection_ratio: r,
                    screen_secs: screen / rr.len() as f64,
                    solve_secs: 0.0,
                    solver_iters: 0,
                    col_ops: 0,
                    obj: 0.0,
                    gap: 0.0,
                })
                .collect(),
            screen_secs: screen,
            solve_secs: 0.0,
            total_secs: total,
            last_w: vec![],
        }
    }

    #[test]
    fn curve_averages_trials() {
        let a = fake_run(&[1.0, 0.8], 1.0, 0.1);
        let b = fake_run(&[0.5, 1.0], 1.0, 0.1);
        let c = mean_rejection_curve(&[a, b]);
        assert!((c[0].1 - 0.75).abs() < 1e-12);
        assert!((c[1].1 - 0.9).abs() < 1e-12);
    }

    #[test]
    fn rejection_curve_observer_matches_batch_mean() {
        let runs = [fake_run(&[1.0, 0.8], 1.0, 0.1), fake_run(&[0.5, 1.0], 1.0, 0.1)];
        let mut curve = RejectionCurve::new(2);
        for run in &runs {
            for rec in &run.records {
                curve.on_solution(rec.ratio, rec.lam, &[], rec);
            }
        }
        assert_eq!(curve.trials(), 2);
        assert_eq!(curve.curve(), mean_rejection_curve(&runs));
    }

    #[test]
    #[should_panic(expected = "mid-trial")]
    fn rejection_curve_rejects_partial_trials() {
        let run = fake_run(&[1.0, 0.8], 1.0, 0.1);
        let mut curve = RejectionCurve::new(2);
        curve.on_solution(run.records[0].ratio, 0.0, &[], &run.records[0]);
        let _ = curve.curve();
    }

    #[test]
    fn speedup_math() {
        let base = fake_run(&[0.0], 100.0, 0.0);
        let scr = fake_run(&[0.9], 5.0, 1.0);
        let row = speedup_row(&base, &scr);
        assert!((row.speedup - 20.0).abs() < 1e-9);
        assert!((row.dpc_secs - 1.0).abs() < 1e-12);
    }
}
