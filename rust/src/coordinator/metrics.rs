//! Experiment metrics: rejection-ratio aggregation across trials and the
//! Table-1 speedup accounting.

use super::path::PathRunResult;

/// Mean rejection ratio per grid index across repeated trials
/// (the curves of Figs. 1–2).
pub fn mean_rejection_curve(runs: &[PathRunResult]) -> Vec<(f64, f64)> {
    assert!(!runs.is_empty());
    let k = runs[0].records.len();
    assert!(runs.iter().all(|r| r.records.len() == k), "trials must share the grid");
    (0..k)
        .map(|i| {
            let ratio = runs[0].records[i].ratio;
            let mean = runs.iter().map(|r| r.records[i].rejection_ratio).sum::<f64>()
                / runs.len() as f64;
            (ratio, mean)
        })
        .collect()
}

/// One Table-1 row: timing comparison of a baseline (no screening) run
/// against a screened run of the *same* problem.
#[derive(Debug, Clone)]
pub struct SpeedupRow {
    pub dataset: String,
    pub d: usize,
    /// solver without screening (total path seconds)
    pub solver_secs: f64,
    /// screening rule cost alone
    pub dpc_secs: f64,
    /// screened path total (screen + reduced solve)
    pub combined_secs: f64,
    pub speedup: f64,
    pub mean_rejection: f64,
}

pub fn speedup_row(baseline: &PathRunResult, screened: &PathRunResult) -> SpeedupRow {
    let solver_secs = baseline.total_secs;
    let combined = screened.total_secs;
    SpeedupRow {
        dataset: baseline.dataset.clone(),
        d: baseline.d,
        solver_secs,
        dpc_secs: screened.screen_secs,
        combined_secs: combined,
        speedup: solver_secs / combined.max(1e-12),
        mean_rejection: screened.mean_rejection_ratio(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::path::{LambdaRecord, PathRunResult};

    fn fake_run(rr: &[f64], total: f64, screen: f64) -> PathRunResult {
        PathRunResult {
            dataset: "fake".into(),
            d: 10,
            lam_max: 1.0,
            records: rr
                .iter()
                .enumerate()
                .map(|(i, &r)| LambdaRecord {
                    ratio: 1.0 / (i + 1) as f64,
                    lam: 0.0,
                    rejected: 0,
                    kept: 0,
                    inactive: 0,
                    rejection_ratio: r,
                    screen_secs: screen / rr.len() as f64,
                    solve_secs: 0.0,
                    solver_iters: 0,
                    col_ops: 0,
                    obj: 0.0,
                    gap: 0.0,
                })
                .collect(),
            screen_secs: screen,
            solve_secs: 0.0,
            total_secs: total,
            last_w: vec![],
        }
    }

    #[test]
    fn curve_averages_trials() {
        let a = fake_run(&[1.0, 0.8], 1.0, 0.1);
        let b = fake_run(&[0.5, 1.0], 1.0, 0.1);
        let c = mean_rejection_curve(&[a, b]);
        assert!((c[0].1 - 0.75).abs() < 1e-12);
        assert!((c[1].1 - 0.9).abs() < 1e-12);
    }

    #[test]
    fn speedup_math() {
        let base = fake_run(&[0.0], 100.0, 0.0);
        let scr = fake_run(&[0.9], 5.0, 1.0);
        let row = speedup_row(&base, &scr);
        assert!((row.speedup - 20.0).abs() < 1e-9);
        assert!((row.dpc_secs - 1.0).abs() < 1e-12);
    }
}
