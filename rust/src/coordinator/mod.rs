//! L3 coordination: the λ-path runner with sequential DPC screening
//! (Corollary 9), the experiment metrics, and the report renderers that
//! regenerate the paper's tables and figures.

pub mod checkpoint;
pub mod cv;
pub mod distrib;
pub mod grid;
pub mod stability;
pub mod metrics;
pub mod path;
pub mod report;

pub use checkpoint::CheckpointCfg;
pub use distrib::{run_path_distributed, run_worker, DistribOptions};
pub use grid::lambda_grid;
pub use path::{
    run_path, run_path_sharded, run_path_sharded_checkpointed, run_path_sharded_with,
    run_path_with, EngineKind, FnObserver, LambdaRecord, PathObserver, PathOptions,
    PathRunResult, ScreenerKind, ShardRunResult, SolverKind, WorkerLedger,
};
