//! The λ-path runner: solve the MTFL model along the tuning grid, with or
//! without screening, on the exact engine or the AOT (PJRT) engine.
//!
//! Consumers that need every per-λ solution (CV held-out scoring,
//! stability selection, the figure accumulators) register a
//! [`PathObserver`] via [`run_path_with`] and receive each full-size W as
//! it is solved — one pass over the grid, no post-hoc re-walk.
//!
//! Sequential DPC (Corollary 9): at step k+1, the dual reference is
//! recovered from the *solved* primal at λ_k via Eq. (14); features whose
//! Theorem-7 score stays below 1 are deleted before the solver runs, and
//! the solver is warm-started from the previous solution. The reference
//! carries its duality-gap certificate, so the ball is safe at any solver
//! tolerance (DESIGN.md §9) — the exact engine has no `margin` knob.
//!
//! GAP-safe screening ([`ScreenerKind::GapSafe`]) instead certifies the
//! ball from the warm-start iterate's own duality gap at the *target* λ;
//! combined with `SolveOptions::dynamic_every` the solvers keep
//! re-screening mid-solve as the gap shrinks.
//!
//! Penalty seam (DESIGN.md §14): the path reads the penalty from
//! `opts.solve.penalty` and validates capabilities up front — DPC
//! variants and the BCD solver are ℓ2,1 geometry and are rejected for
//! other penalties with an actionable error; sparse-group lasso and
//! group OWL run through `None`/`GapSafe` + FISTA, with λ_max, gap
//! evaluation, screening scores, and safety verification all supplied by
//! the penalty's own operations.
//!
//! The exact path is storage-agnostic: screening, compaction
//! ([`Dataset::restrict`]), and both solvers address columns through
//! [`crate::linalg::ColRef`], so a CSC-backed dataset (text/genomics)
//! stays sparse through every screen→restrict→solve step — compaction is
//! pointer arithmetic on the stored entries, never a densify (DESIGN.md
//! §6). The AOT engine densifies at the PJRT ABI boundary only.
//!
//! Out-of-core (DESIGN.md §10): [`run_path_sharded`] runs the same grid
//! against an on-disk MTD3 shard with the screen-before-load pipeline —
//! each grid point streams column blocks through the screener, then
//! materializes only the certified survivors for the solver, so datasets
//! with `d ≫ RAM` run without ever being loaded. Keep-sets and solutions
//! match the in-RAM backends; [`ShardRunResult`] adds the bytes-
//! materialized accounting benched in `BENCH_shard.json`.

use super::checkpoint::{self, CheckpointCfg, PathCheckpoint};
use crate::data::{Dataset, ShardedDataset};
use crate::ops;
use crate::runtime::{buckets, AotEngine};
use crate::screening::bounds::CsScreener;
use crate::screening::dpc::{ball_from_y, DpcScreener, DualRef};
use crate::screening::gap::{certified_radius, GapScreener};
use crate::screening::safety;
use crate::screening::shard::{
    dual_ref_from_streamed, dual_ref_from_witness, gap_from_sweep, LocalSweeps, ShardSweeps,
};
use crate::screening::ScreenOutcome;
use crate::solver::{bcd, fista, SolveOptions};
use crate::util::Stopwatch;
use anyhow::{Context, Result};

/// Which screening rule runs ahead of each solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScreenerKind {
    /// no screening: the solver sees all d features at every λ (baseline)
    None,
    /// sequential DPC (the paper's rule, Corollary 9, gap-inflated)
    Dpc,
    /// DPC ball but Cauchy–Schwarz scores (ablation ABL1)
    DpcCs,
    /// DPC screened only from the λ_max reference (ablation ABL2)
    DpcOneShot,
    /// GAP-safe ball from the warm-start iterate's duality gap at the
    /// target λ (Ndiaye et al.; exact engine only)
    GapSafe,
}

/// Which exact solver runs on the compacted problem.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    /// accelerated proximal gradient ([`crate::solver::fista`])
    Fista,
    /// cyclic block-coordinate descent ([`crate::solver::bcd`])
    Bcd,
}

/// Which compute engine executes the path.
pub enum EngineKind<'a> {
    /// exact f64 path (self-contained, no artifacts)
    Exact,
    /// AOT artifacts through PJRT; dataset shape must match a config
    Aot(&'a AotEngine),
}

/// Everything a path run needs besides the dataset.
#[derive(Debug, Clone)]
pub struct PathOptions {
    /// λ/λ_max ratios, descending (see [`crate::coordinator::lambda_grid`])
    pub ratios: Vec<f64>,
    /// solver options (tolerance, iteration caps, dynamic screening)
    pub solve: SolveOptions,
    /// screening rule to run ahead of each solve
    pub screener: ScreenerKind,
    /// solver for the compacted per-λ problems
    pub solver: SolverKind,
    /// f32-precision guard for the **AOT engine only**: keep features
    /// scoring within this margin below 1 to absorb f32 sweep error. The
    /// exact engine ignores it — its safety under inexact references is
    /// carried by gap certificates, not a guessed slack (DESIGN.md §9).
    pub aot_margin: f64,
    /// row norm below which a solved feature counts as inactive (ground
    /// truth for rejection ratios)
    pub active_tol: f64,
    /// run the post-hoc safety verifier at every λ (slow; for tests)
    pub verify_safety: bool,
}

impl Default for PathOptions {
    fn default() -> Self {
        PathOptions {
            ratios: super::grid::paper_grid(100),
            solve: SolveOptions::default(),
            screener: ScreenerKind::Dpc,
            solver: SolverKind::Fista,
            aot_margin: 0.0,
            active_tol: 1e-8,
            verify_safety: false,
        }
    }
}

/// Per-λ record (one row of the figures' series).
#[derive(Debug, Clone)]
pub struct LambdaRecord {
    /// λ/λ_max grid ratio of this step
    pub ratio: f64,
    /// absolute λ of this step
    pub lam: f64,
    /// features rejected by screening
    pub rejected: usize,
    /// features handed to the solver
    pub kept: usize,
    /// ground-truth inactive count (from the solution)
    pub inactive: usize,
    /// rejected / inactive  (the paper's rejection ratio; 1.0 if inactive=0)
    pub rejection_ratio: f64,
    /// wallclock spent screening at this λ
    pub screen_secs: f64,
    /// wallclock spent solving at this λ
    pub solve_secs: f64,
    /// solver iterations (FISTA steps / BCD sweeps)
    pub solver_iters: usize,
    /// column-sweep operations the solver spent (see
    /// [`crate::solver::SolveResult::col_ops`])
    pub col_ops: usize,
    /// primal objective at the solution
    pub obj: f64,
    /// duality gap at the solution
    pub gap: f64,
}

/// A whole path run: per-λ records plus totals and the final solution.
#[derive(Debug, Clone)]
pub struct PathRunResult {
    /// workload name
    pub dataset: String,
    /// feature dimension
    pub d: usize,
    /// λ_max of the dataset (Theorem 1)
    pub lam_max: f64,
    /// one record per grid point, in grid order
    pub records: Vec<LambdaRecord>,
    /// total screening wallclock
    pub screen_secs: f64,
    /// total solver wallclock
    pub solve_secs: f64,
    /// end-to-end wallclock
    pub total_secs: f64,
    /// final-λ solution (row-major d x T) for downstream consumers
    pub last_w: Vec<f64>,
}

/// Per-λ streaming hook: the path runners call [`PathObserver::on_solution`]
/// once per grid point, in grid order, with the *full-size* (d × T) solution
/// and the step's [`LambdaRecord`]. This is the seam the grid workflows
/// (CV held-out scoring, stability selection's union-over-λ active mask,
/// the figure accumulators) hang off — they consume each solution as it is
/// produced instead of re-walking the path afterwards (DESIGN.md §4).
///
/// Closures become observers through the [`FnObserver`] adapter.
pub trait PathObserver {
    /// Called once per grid point, in grid order, with the full-size
    /// (d × T) solution and that step's record.
    fn on_solution(&mut self, ratio: f64, lam: f64, w_full: &[f64], rec: &LambdaRecord);
}

/// Adapter wrapping any `FnMut(ratio, lam, w_full, record)` closure as a
/// [`PathObserver`] (a blanket impl would collide with named observer
/// types under coherence, so the wrapper is explicit).
pub struct FnObserver<F>(pub F);

impl<F> PathObserver for FnObserver<F>
where
    F: FnMut(f64, f64, &[f64], &LambdaRecord),
{
    fn on_solution(&mut self, ratio: f64, lam: f64, w_full: &[f64], rec: &LambdaRecord) {
        (self.0)(ratio, lam, w_full, rec)
    }
}

impl PathRunResult {
    /// Mean of the per-λ rejection ratios (the figures' y-axis).
    pub fn mean_rejection_ratio(&self) -> f64 {
        let rs: Vec<f64> = self.records.iter().map(|r| r.rejection_ratio).collect();
        crate::linalg::simd::mean_serial_f64(&rs)
    }

    /// Total solver column-sweep work along the path (the BENCH_gap metric).
    pub fn total_col_ops(&self) -> usize {
        self.records.iter().map(|r| r.col_ops).sum()
    }

    /// Total solver epochs along the path.
    pub fn total_iters(&self) -> usize {
        self.records.iter().map(|r| r.solver_iters).sum()
    }
}

/// Run the full path. Dispatches on engine. Thin wrapper over
/// [`run_path_with`] with a no-op observer.
pub fn run_path(ds: &Dataset, opts: &PathOptions, engine: &EngineKind) -> Result<PathRunResult> {
    let mut noop = FnObserver(|_: f64, _: f64, _: &[f64], _: &LambdaRecord| {});
    run_path_with(ds, opts, engine, &mut noop)
}

/// Run the full path, streaming every per-λ solution to `obs` as it is
/// solved (see [`PathObserver`]). Dispatches on engine.
pub fn run_path_with(
    ds: &Dataset,
    opts: &PathOptions,
    engine: &EngineKind,
    obs: &mut dyn PathObserver,
) -> Result<PathRunResult> {
    match engine {
        EngineKind::Exact => run_path_exact(ds, opts, obs),
        EngineKind::Aot(e) => run_path_aot(ds, opts, e, obs),
    }
}

// ---------------------------------------------------------------------------
// exact engine
// ---------------------------------------------------------------------------

fn solve_exact(
    ds: &Dataset,
    lam: f64,
    w0: Option<&[f64]>,
    opts: &PathOptions,
) -> crate::solver::SolveResult {
    match opts.solver {
        SolverKind::Fista => fista(ds, lam, w0, &opts.solve),
        SolverKind::Bcd => bcd(ds, lam, w0, &opts.solve),
    }
}

fn run_path_exact(
    ds: &Dataset,
    opts: &PathOptions,
    obs: &mut dyn PathObserver,
) -> Result<PathRunResult> {
    ds.validate()?;
    let pen: &dyn crate::penalty::Penalty = &opts.solve.penalty;
    if !opts.solve.penalty.is_l21() {
        // capability gate (DESIGN.md §14): DPC's Theorem-5 ball and BCD's
        // row secular solve are ℓ2,1 geometry; fail here with a cure
        // instead of screening unsafely / solving the wrong problem
        anyhow::ensure!(
            matches!(opts.screener, ScreenerKind::None | ScreenerKind::GapSafe),
            "screener {:?} is ℓ2,1-only (DPC's Theorem-5 ball is ℓ2,1 dual geometry); \
             penalty {} screens with --screener gap or none",
            opts.screener,
            pen.name()
        );
        anyhow::ensure!(
            matches!(opts.solver, SolverKind::Fista),
            "solver Bcd is ℓ2,1-only (its row update is the ℓ2,1 secular solve); \
             penalty {} solves with --solver fista",
            pen.name()
        );
    }
    let t_count = ds.t();
    let mut total = Stopwatch::new();
    total.start();

    // each screener caches an O(nnz) b² sweep — build only the one in use
    let screener = matches!(opts.screener, ScreenerKind::Dpc | ScreenerKind::DpcOneShot)
        .then(|| DpcScreener::new(ds));
    let cs = matches!(opts.screener, ScreenerKind::DpcCs).then(|| CsScreener::new(ds));
    let gs = matches!(opts.screener, ScreenerKind::GapSafe).then(|| GapScreener::new(ds));
    // λ_max and the DPC dual reference: the closed-form reference exists
    // only in ℓ2,1 geometry; other penalties take λ_max from their own
    // infeasibility functional and never build a DualRef
    let l21_head = opts.solve.penalty.is_l21().then(|| DualRef::at_lambda_max(ds));
    let lam_max = match &l21_head {
        Some((_, lmax)) => *lmax,
        None => ops::lambda_max_for(ds, pen).0,
    };
    let dref0 = l21_head.map(|(d, _)| d);
    let mut dref = dref0.clone();

    let mut prev_w = vec![0.0f64; ds.d * t_count];
    let mut records = Vec::with_capacity(opts.ratios.len());

    for &ratio in &opts.ratios {
        let lam = ratio * lam_max;
        // -- screening phase --
        let mut step_screen = Stopwatch::new();
        let keep: Vec<usize> = if ratio >= 1.0 - 1e-12 {
            Vec::new() // Theorem 1: W*=0, keep nothing
        } else {
            match opts.screener {
                ScreenerKind::None => (0..ds.d).collect(),
                ScreenerKind::Dpc => step_screen
                    .time(|| {
                        screener.as_ref().unwrap().screen(ds, dref.as_ref().unwrap(), lam)
                    })
                    .kept_indices(),
                ScreenerKind::DpcOneShot => step_screen
                    .time(|| {
                        screener.as_ref().unwrap().screen(ds, dref0.as_ref().unwrap(), lam)
                    })
                    .kept_indices(),
                ScreenerKind::DpcCs => step_screen
                    .time(|| cs.as_ref().unwrap().screen(ds, dref.as_ref().unwrap(), lam))
                    .kept_indices(),
                ScreenerKind::GapSafe => step_screen
                    .time(|| gs.as_ref().unwrap().screen_primal_for(ds, lam, &prev_w, pen))
                    .kept_indices(),
            }
        };

        // -- solve phase (on the compacted problem) --
        let mut step_solve = Stopwatch::new();
        let mut w_full = vec![0.0f64; ds.d * t_count];
        let (obj, gap, iters, col_ops) = if keep.is_empty() {
            let (o, g, _) = ops::duality_gap_for(ds, &w_full, lam, pen);
            (o, g, 0, 0)
        } else if keep.len() == ds.d {
            let res = step_solve.time(|| solve_exact(ds, lam, Some(&prev_w), opts));
            w_full = res.w.clone();
            (res.obj, res.gap, res.iters, res.col_ops)
        } else {
            let ds_r = ds.restrict(&keep);
            let mut w0 = vec![0.0f64; keep.len() * t_count];
            for (j, &l) in keep.iter().enumerate() {
                w0[j * t_count..(j + 1) * t_count]
                    .copy_from_slice(&prev_w[l * t_count..(l + 1) * t_count]);
            }
            let res = step_solve.time(|| solve_exact(&ds_r, lam, Some(&w0), opts));
            for (j, &l) in keep.iter().enumerate() {
                w_full[l * t_count..(l + 1) * t_count]
                    .copy_from_slice(&res.w[j * t_count..(j + 1) * t_count]);
            }
            (res.obj, res.gap, res.iters, res.col_ops)
        };

        // -- bookkeeping --
        let rejected = ds.d - keep.len();
        let active = w_full
            .chunks_exact(t_count)
            .filter(|row| ops::row_is_active(row, opts.active_tol))
            .count();
        let inactive = ds.d - active;
        let rejection_ratio =
            if inactive == 0 { 1.0 } else { rejected as f64 / inactive as f64 };

        if opts.verify_safety && rejected > 0 {
            // A screened run can never incriminate itself: rejected rows
            // are zero in w_full by construction. The paranoid check
            // therefore solves the UNRESTRICTED problem independently and
            // verifies the rejections against that solution, plus an
            // objective-parity check (unsafe screening converges — to a
            // strictly worse optimum). Far slower than the run itself;
            // tests only.
            let mask: Vec<bool> = {
                let mut m = vec![true; ds.d];
                for &l in &keep {
                    m[l] = false;
                }
                m
            };
            // a tight reference regardless of the screened run's tolerance
            // — same penalty, or the verifier would solve a different
            // problem: the verifier must stay discriminating in exactly
            // the loose regime gap certification exists for
            let mut vopts = opts.clone();
            vopts.solve = crate::solver::SolveOptions {
                penalty: opts.solve.penalty,
                ..crate::solver::SolveOptions::tight()
            };
            let full = solve_exact(ds, lam, Some(&prev_w), &vopts);
            let report =
                safety::verify_for(ds, &full.w, lam, &mask, 10.0 * opts.active_tol, pen);
            anyhow::ensure!(
                report.is_safe(),
                "screening violated safety at ratio {ratio}: {:?}",
                report.violations
            );
            anyhow::ensure!(
                obj <= full.obj + 2.0 * opts.solve.tol * full.obj.abs().max(1.0) + 1e-12,
                "screened objective {obj} stuck above unrestricted {} at ratio {ratio}",
                full.obj
            );
        }

        records.push(LambdaRecord {
            ratio,
            lam,
            rejected,
            kept: keep.len(),
            inactive,
            rejection_ratio,
            screen_secs: step_screen.secs(),
            solve_secs: step_solve.secs(),
            solver_iters: iters,
            col_ops,
            obj,
            gap,
        });
        obs.on_solution(ratio, lam, &w_full, records.last().unwrap());

        // sequential reference update (Cor. 9): from this λ's solution,
        // with its gap certificate. At the grid head (λ ≥ λ_max, W = 0)
        // keep the λ_max reference — its Eq. 20 gradient normal is
        // strictly better than the zero normal a W=0 solution would
        // produce. Only the kinds that consume the reference pay for the
        // update (it costs a correlation sweep).
        let seq = matches!(opts.screener, ScreenerKind::Dpc | ScreenerKind::DpcCs);
        if seq && ratio < 1.0 - 1e-12 {
            dref = Some(DualRef::from_solution(ds, lam, &w_full));
        }
        prev_w = w_full;
    }

    total.stop();
    let screen_secs: f64 = records.iter().map(|r| r.screen_secs).sum();
    let solve_secs: f64 = records.iter().map(|r| r.solve_secs).sum();
    Ok(PathRunResult {
        dataset: ds.name.clone(),
        d: ds.d,
        lam_max,
        records,
        screen_secs,
        solve_secs,
        total_secs: total.secs(),
        last_w: prev_w,
    })
}

// ---------------------------------------------------------------------------
// sharded (out-of-core) engine: screen-before-load
// ---------------------------------------------------------------------------

/// Result of an out-of-core path run: the standard per-λ records plus the
/// memory-model accounting (`BENCH_shard.json` feeds from this).
#[derive(Debug, Clone)]
pub struct ShardRunResult {
    /// per-λ records and totals, schema-identical to an in-RAM run
    pub path: PathRunResult,
    /// bytes materialized for the solver at each grid point — the
    /// peak-RSS proxy (the matrix memory the solver actually saw)
    pub materialized_bytes: Vec<usize>,
    /// max over the grid of `materialized_bytes`
    pub peak_materialized_bytes: usize,
    /// what loading the full matrix dense in RAM would cost
    pub dense_bytes: u64,
    /// total shard payload on disk
    pub payload_bytes: u64,
    /// bytes read from disk across the run (cache misses only)
    pub bytes_read: u64,
    /// block loads from disk across the run (cache misses only)
    pub blocks_loaded: u64,
    /// prefetch-pipeline overlap across the run (DESIGN.md §11): issued
    /// next-block prefetches, those consumed while still resident (decode
    /// fully hidden behind compute), and wall time stalled on cold loads
    pub prefetch: crate::data::PrefetchStats,
    /// per-worker ledger of a distributed run (DESIGN.md §16) — empty for
    /// single-process runs; `BENCH_distrib.json` feeds from this
    pub workers: Vec<WorkerLedger>,
}

/// What one worker process contributed to a distributed run
/// (`coordinator::distrib`): its block assignment, the sweeps it served,
/// the bytes it shipped back over the wire, its own disk I/O, and the
/// wall time it spent busy (the utilization numerator — the denominator
/// is the run's `total_secs`).
#[derive(Debug, Clone)]
pub struct WorkerLedger {
    /// the worker's peer address as the coordinator saw it
    pub addr: String,
    /// blocks assigned to this worker (after any reassignment)
    pub blocks: usize,
    /// sweep requests this worker answered
    pub sweeps: u64,
    /// reply payload bytes shipped to the coordinator
    pub bytes_shipped: u64,
    /// bytes the worker read from its shard (cache misses only)
    pub bytes_read: u64,
    /// block loads the worker paid (cache misses only)
    pub blocks_loaded: u64,
    /// wall time the worker spent computing sweeps
    pub busy_secs: f64,
}

/// Run the λ-path out-of-core with a no-op observer (see
/// [`run_path_sharded_with`]).
pub fn run_path_sharded(sh: &ShardedDataset, opts: &PathOptions) -> Result<ShardRunResult> {
    let mut noop = FnObserver(|_: f64, _: f64, _: &[f64], _: &LambdaRecord| {});
    run_path_sharded_with(sh, opts, &mut noop)
}

/// The screen-before-load λ-path (DESIGN.md §10): every grid point
/// screens the *on-disk* shard block-by-block against a certified ball,
/// materializes only the surviving columns ([`ShardedDataset::restrict`])
/// and solves that in-RAM problem — peak matrix memory scales with the
/// active set, not with `d`. Supports the screeners whose balls are O(N)
/// objects (sequential DPC, one-shot DPC, GAP-safe); `None`/`DpcCs` and
/// `verify_safety` need the matrix resident and are rejected with an
/// error. Keep-sets and solutions match the in-RAM dense/CSC path
/// bit-for-bit / to solver tolerance (`rust/tests/shard_backend.rs`).
/// Every streamed sweep runs the shard's prefetch pipeline — block b+1
/// decodes while block b is scored (DESIGN.md §11) — and the run's
/// overlap ledger (prefetch hits, stall time) lands in
/// [`ShardRunResult::prefetch`].
pub fn run_path_sharded_with(
    sh: &ShardedDataset,
    opts: &PathOptions,
    obs: &mut dyn PathObserver,
) -> Result<ShardRunResult> {
    run_path_sharded_checkpointed(sh, opts, obs, None)
}

/// [`run_path_sharded_with`] plus per-λ checkpoint/resume (DESIGN.md
/// §16): with a [`CheckpointCfg`], every completed grid point persists an
/// atomic `ckpt_<step>.mtc1` record, and `resume` re-enters the grid at
/// the step after the newest valid record. Restored steps do **not**
/// replay the observer — they were already streamed by the interrupted
/// run. The resumed path is bit-identical to an uninterrupted one.
pub fn run_path_sharded_checkpointed(
    sh: &ShardedDataset,
    opts: &PathOptions,
    obs: &mut dyn PathObserver,
    ckpt: Option<&CheckpointCfg>,
) -> Result<ShardRunResult> {
    shard_caps(opts)?; // fail before the b² streaming pass
    let mut sweeps = LocalSweeps::new(sh, opts.solve.penalty)?;
    run_path_sharded_core(sh, opts, obs, &mut sweeps, ckpt)
}

/// The out-of-core capability gates, shared by every sharded entry point
/// (single-process and distributed): which screeners have O(N) balls,
/// why `verify_safety` cannot run here, and which components non-ℓ2,1
/// penalties are restricted to.
fn shard_caps(opts: &PathOptions) -> Result<()> {
    anyhow::ensure!(
        matches!(
            opts.screener,
            ScreenerKind::Dpc | ScreenerKind::DpcOneShot | ScreenerKind::GapSafe
        ),
        "screener {:?} is not supported out-of-core — the shard path exists to \
         avoid loading the matrix, so use dpc, oneshot or gap",
        opts.screener
    );
    anyhow::ensure!(
        !opts.verify_safety,
        "verify_safety re-solves the unrestricted problem and needs the matrix \
         in RAM — run it on the dense/CSC backends"
    );
    if !opts.solve.penalty.is_l21() {
        // same capability seam as the exact engine (DESIGN.md §14): the
        // DPC ball and the BCD row update are ℓ2,1 geometry; the streamed
        // sweeps themselves are penalty-generic (ROADMAP 4a)
        anyhow::ensure!(
            matches!(opts.screener, ScreenerKind::GapSafe),
            "screener {:?} is ℓ2,1-only (DPC's Theorem-5 ball is ℓ2,1 dual \
             geometry); penalty {} screens out-of-core with --screener gap",
            opts.screener,
            opts.solve.penalty
        );
        anyhow::ensure!(
            matches!(opts.solver, SolverKind::Fista),
            "solver Bcd is ℓ2,1-only (its row update is the ℓ2,1 secular solve); \
             penalty {} solves with --solver fista",
            opts.solve.penalty
        );
    }
    Ok(())
}

/// The grid loop every sharded mode executes, written against the
/// [`ShardSweeps`] seam: single-process runs pass [`LocalSweeps`], the
/// distributed coordinator passes its fan-out provider
/// (`coordinator::distrib`) — same loop, same fold order, same bits.
/// Scalar folds (λ_max, screening thresholds, gap scaling) always run
/// here on fully assembled sweep vectors; only the per-block vector
/// production is behind the seam. Public so tests (and exotic
/// deployments) can drive the loop with their own sweep provider.
pub fn run_path_sharded_core(
    sh: &ShardedDataset,
    opts: &PathOptions,
    obs: &mut dyn PathObserver,
    sweeps: &mut dyn ShardSweeps,
    ckpt: Option<&CheckpointCfg>,
) -> Result<ShardRunResult> {
    shard_caps(opts)?;
    let pen: &dyn crate::penalty::Penalty = &opts.solve.penalty;
    let t_count = sh.t();
    let d = sh.d();
    let bytes0 = sh.bytes_read();
    let blocks0 = sh.blocks_loaded();
    let pf0 = sh.prefetch_stats();
    let mut total = Stopwatch::new();
    total.start();

    let y = sh.y64();
    // λ_max from the penalty's infeasibility sweep (one pass over all
    // blocks — through the seam, so a distributed run fans it out); the
    // witness feature's single block load builds the closed-form DPC
    // reference, which exists only in ℓ2,1 geometry
    let (lam_max, lstar) = pen.infeas_finish(&sweeps.infeas_features(&y)?);
    let dref0 = if opts.solve.penalty.is_l21() {
        Some(dual_ref_from_witness(sh, &y, lam_max, lstar)?)
    } else {
        None
    };
    let mut dref = dref0.clone();

    // residual of W = 0, written as the in-RAM `ops::residual` computes it
    // (0.0 − y_i), so the head-of-grid gap states agree bit-for-bit
    let zero_residual = |y: &ops::Stacked| -> ops::Stacked {
        y.iter().map(|yt| yt.iter().map(|&v| 0.0 - v).collect()).collect()
    };

    let digest_at = |step: usize| {
        checkpoint::grid_digest(
            sh.name(),
            d,
            t_count,
            &opts.solve.penalty.to_string(),
            &format!("{:?}", opts.screener),
            &format!("{:?}", opts.solver),
            lam_max,
            &opts.ratios[..=step],
        )
    };

    let mut prev_w = vec![0.0f64; d * t_count];
    let mut prev_r = zero_residual(&y);
    let mut prev_penval = 0.0f64;
    let mut records = Vec::with_capacity(opts.ratios.len());
    let mut materialized_bytes = Vec::with_capacity(opts.ratios.len());
    let mut start_step = 0usize;

    if let Some(cfg) = ckpt {
        if cfg.resume {
            if let Some((ck, digest)) =
                checkpoint::load_latest(&cfg.dir, sh.name(), d, t_count)?
            {
                anyhow::ensure!(
                    ck.step < opts.ratios.len(),
                    "--checkpoint {}: newest record is at grid step {} but this \
                     grid has only {} points",
                    cfg.dir.display(),
                    ck.step,
                    opts.ratios.len()
                );
                anyhow::ensure!(
                    digest == digest_at(ck.step),
                    "--checkpoint {}: the step-{} record was written by a \
                     different run configuration (dataset, grid prefix, penalty, \
                     screener, solver or λ_max changed) — restart without \
                     --resume or point --checkpoint at the matching directory",
                    cfg.dir.display(),
                    ck.step
                );
                records = ck.records;
                materialized_bytes = ck.materialized_bytes;
                prev_w = ck.prev_w;
                prev_r = ck.prev_r;
                prev_penval = ck.prev_penval;
                if ck.dref.is_some() {
                    dref = ck.dref;
                }
                start_step = ck.step + 1;
            }
        }
    }

    for (step, &ratio) in opts.ratios.iter().enumerate().skip(start_step) {
        let lam = ratio * lam_max;
        // -- screening phase (streamed over the shard via the seam) --
        let mut step_screen = Stopwatch::new();
        let keep: Vec<usize> = if ratio >= 1.0 - 1e-12 {
            Vec::new() // Theorem 1: W* = 0, keep nothing
        } else {
            match opts.screener {
                ScreenerKind::Dpc | ScreenerKind::DpcOneShot => {
                    let dr = if matches!(opts.screener, ScreenerKind::Dpc) {
                        dref.as_ref().unwrap()
                    } else {
                        dref0.as_ref().unwrap()
                    };
                    assert!(
                        lam <= dr.lam0 * (1.0 + 1e-12),
                        "DPC requires lam <= lam0 (got {lam} > {})",
                        dr.lam0
                    );
                    let (o, delta) = ball_from_y(&y, dr, lam);
                    step_screen
                        .time(|| -> Result<ScreenOutcome> {
                            let scores = sweeps.ball_scores(&o, delta)?;
                            let rejected = scores.iter().map(|&s| s < 1.0).collect();
                            Ok(ScreenOutcome { rejected, scores, delta })
                        })?
                        .kept_indices()
                }
                ScreenerKind::GapSafe => step_screen
                    .time(|| -> Result<ScreenOutcome> {
                        let sg = gap_from_sweep(&y, lam, &prev_r, prev_penval, pen, &mut |z| {
                            sweeps.infeas_features(z)
                        })?;
                        let delta = certified_radius(sg.gap, lam);
                        let scores = sweeps.ball_scores(&sg.theta, delta)?;
                        let rejected = scores.iter().map(|&s| s < 1.0).collect();
                        Ok(ScreenOutcome { rejected, scores, delta })
                    })?
                    .kept_indices(),
                _ => unreachable!("rejected by the capability check above"),
            }
        };

        // -- materialize survivors + solve in RAM (coordinator-local) --
        let mut step_solve = Stopwatch::new();
        let mut w_full = vec![0.0f64; d * t_count];
        let mut materialized = 0usize;
        let (obj, gap, iters, col_ops, r_cur, penval_cur) = if keep.is_empty() {
            let r0 = zero_residual(&y);
            let sg = gap_from_sweep(&y, lam, &r0, 0.0, pen, &mut |z| {
                sweeps.infeas_features(z)
            })?;
            (sg.obj, sg.gap, 0, 0, r0, 0.0)
        } else {
            let ds_r = sh.restrict(&keep)?;
            materialized = ds_r.mem_bytes();
            let mut w0 = vec![0.0f64; keep.len() * t_count];
            for (j, &l) in keep.iter().enumerate() {
                w0[j * t_count..(j + 1) * t_count]
                    .copy_from_slice(&prev_w[l * t_count..(l + 1) * t_count]);
            }
            let res = step_solve.time(|| match opts.solver {
                SolverKind::Fista => fista(&ds_r, lam, Some(&w0), &opts.solve),
                SolverKind::Bcd => bcd(&ds_r, lam, Some(&w0), &opts.solve),
            });
            for (j, &l) in keep.iter().enumerate() {
                w_full[l * t_count..(l + 1) * t_count]
                    .copy_from_slice(&res.w[j * t_count..(j + 1) * t_count]);
            }
            let r = ops::residual(&ds_r, &res.w);
            // Ω on the restricted solution — identical to Ω on w_full for
            // every supported penalty: zero rows contribute +0.0 terms and
            // (for GOWL) sort behind every nonzero row norm
            let penval = pen.value(&res.w, t_count);
            (res.obj, res.gap, res.iters, res.col_ops, r, penval)
        };

        // -- bookkeeping (same ground-truth accounting as the exact path) --
        let rejected = d - keep.len();
        let active = w_full
            .chunks_exact(t_count)
            .filter(|row| ops::row_is_active(row, opts.active_tol))
            .count();
        let inactive = d - active;
        let rejection_ratio =
            if inactive == 0 { 1.0 } else { rejected as f64 / inactive as f64 };
        records.push(LambdaRecord {
            ratio,
            lam,
            rejected,
            kept: keep.len(),
            inactive,
            rejection_ratio,
            screen_secs: step_screen.secs(),
            solve_secs: step_solve.secs(),
            solver_iters: iters,
            col_ops,
            obj,
            gap,
        });
        materialized_bytes.push(materialized);
        obs.on_solution(ratio, lam, &w_full, records.last().unwrap());

        // sequential reference update (Cor. 9): re-streams the shard once
        // for the feasibility scaling of the new reference — the per-grid-
        // point re-stream the screen-before-load design pays for safety.
        // Skipped after the last grid point when nothing will read the
        // reference again (on a shard the wasted sweep is a full disk
        // pass) — but a checkpoint *is* a reader: a resumed longer grid
        // continues from this reference, so checkpointed runs always pay
        // the update
        let last = step + 1 == opts.ratios.len();
        if matches!(opts.screener, ScreenerKind::Dpc)
            && ratio < 1.0 - 1e-12
            && (!last || ckpt.is_some())
        {
            let sg = gap_from_sweep(&y, lam, &r_cur, penval_cur, pen, &mut |z| {
                sweeps.infeas_features(z)
            })?;
            dref = Some(dual_ref_from_streamed(&y, lam, &sg));
        }
        prev_w = w_full;
        prev_r = r_cur;
        prev_penval = penval_cur;

        // grid-step barrier (no-op single-process; the distributed
        // provider broadcasts the step summary and syncs worker ledgers)
        sweeps.step_done(step, lam, keep.len())?;

        if let Some(cfg) = ckpt {
            checkpoint::save(
                &cfg.dir,
                &PathCheckpoint {
                    step,
                    lam_max,
                    records: records.clone(),
                    materialized_bytes: materialized_bytes.clone(),
                    dref: dref.clone(),
                    prev_w: prev_w.clone(),
                    prev_r: prev_r.clone(),
                    prev_penval,
                },
                digest_at(step),
                sh.name(),
                d,
                t_count,
            )?;
        }
    }

    total.stop();
    let screen_secs: f64 = records.iter().map(|r| r.screen_secs).sum();
    let solve_secs: f64 = records.iter().map(|r| r.solve_secs).sum();
    let peak = materialized_bytes.iter().copied().max().unwrap_or(0);
    Ok(ShardRunResult {
        path: PathRunResult {
            dataset: sh.name().to_string(),
            d,
            lam_max,
            records,
            screen_secs,
            solve_secs,
            total_secs: total.secs(),
            last_w: prev_w,
        },
        materialized_bytes,
        peak_materialized_bytes: peak,
        dense_bytes: sh.dense_bytes(),
        payload_bytes: sh.payload_bytes(),
        bytes_read: sh.bytes_read() - bytes0,
        blocks_loaded: sh.blocks_loaded() - blocks0,
        prefetch: {
            let pf = sh.prefetch_stats();
            crate::data::PrefetchStats {
                issued: pf.issued - pf0.issued,
                hits: pf.hits - pf0.hits,
                stall_secs: (pf.stall_secs - pf0.stall_secs).max(0.0),
            }
        },
        workers: Vec::new(),
    })
}

// ---------------------------------------------------------------------------
// AOT engine
// ---------------------------------------------------------------------------

fn run_path_aot(
    ds: &Dataset,
    opts: &PathOptions,
    engine: &AotEngine,
    obs: &mut dyn PathObserver,
) -> Result<PathRunResult> {
    ds.validate()?;
    let t_count = ds.t();
    let n = ds
        .uniform_n()
        .context("AOT engine requires uniform task sizes (use the exact engine)")?;
    let cfg = engine
        .manifest
        .config_for(t_count, n, ds.d)
        .with_context(|| {
            format!(
                "no AOT config for shape T={t_count} N={n} D={} — regenerate artifacts \
                 or use the exact engine",
                ds.d
            )
        })?
        .to_string();
    let bucket_list = engine.manifest.buckets_for(&cfg);
    anyhow::ensure!(!bucket_list.is_empty(), "config {cfg} has no solver buckets");
    anyhow::ensure!(
        matches!(opts.solver, SolverKind::Fista),
        "the AOT engine only ships FISTA executables"
    );
    anyhow::ensure!(
        opts.aot_margin > 0.0 || matches!(opts.screener, ScreenerKind::None),
        "AOT screening runs in f32: a positive aot_margin is required"
    );
    anyhow::ensure!(
        !matches!(opts.screener, ScreenerKind::DpcCs | ScreenerKind::GapSafe),
        "screener {:?} is exact-engine only",
        opts.screener
    );
    anyhow::ensure!(
        opts.solve.dynamic_every == 0,
        "dynamic screening (dynamic_every > 0) is exact-engine only"
    );
    anyhow::ensure!(
        opts.solve.penalty.is_l21(),
        "penalty {} is exact-engine only: the AOT artifacts bake in the ℓ2,1 \
         prox and dual scaling",
        opts.solve.penalty
    );
    engine.warmup_config(&cfg)?;

    let mut total = Stopwatch::new();
    total.start();

    let x_full = ds.to_tnd()?;
    let y = ds.y_tn()?;

    // reference at λ_max via the lammax artifact
    let lm = engine.lammax(&cfg, &x_full, &y)?;
    let lam_max = lm.lam_max as f64;
    let theta0_init: Vec<f32> = y.iter().map(|&v| v / lm.lam_max).collect();
    let normal_init = lm.normal.clone();
    let mut theta0 = theta0_init.clone();
    let mut normal = normal_init.clone();

    let mut prev_w = vec![0.0f64; ds.d * t_count];
    let mut records = Vec::with_capacity(opts.ratios.len());
    let chunk_steps = engine
        .manifest
        .artifacts
        .iter()
        .find(|a| a.cfg == cfg && a.kind == "fista")
        .map(|a| a.steps)
        .unwrap_or(50);
    let max_chunks = (opts.solve.max_iters / chunk_steps.max(1)).max(1);

    for &ratio in &opts.ratios {
        let lam = (ratio * lam_max) as f32;
        let mut step_screen = Stopwatch::new();
        let keep: Vec<usize> = if ratio >= 1.0 - 1e-12 {
            Vec::new()
        } else {
            match opts.screener {
                ScreenerKind::None => (0..ds.d).collect(),
                ScreenerKind::Dpc | ScreenerKind::DpcOneShot => {
                    let (t0, n0) = if matches!(opts.screener, ScreenerKind::DpcOneShot) {
                        (&theta0_init, &normal_init)
                    } else {
                        (&theta0, &normal)
                    };
                    let s = step_screen.time(|| {
                        engine.screen(&cfg, &x_full, &y, t0, n0, lam)
                    })?;
                    let thr = (1.0 - opts.aot_margin) as f32;
                    s.iter().enumerate().filter_map(|(l, &v)| (v >= thr).then_some(l)).collect()
                }
                // rejected by the capability ensure! before the loop
                ScreenerKind::DpcCs | ScreenerKind::GapSafe => unreachable!(),
            }
        };

        let mut step_solve = Stopwatch::new();
        let mut w_full = vec![0.0f64; ds.d * t_count];
        let (obj, gap, iters, col_ops, residual): (f64, f64, usize, usize, Option<Vec<f32>>) =
            if keep.is_empty() {
                let (o, g, _) = ops::duality_gap(ds, &w_full, lam as f64);
                (o, g, 0, 0, None)
            } else {
                let db = buckets::pick_bucket(&bucket_list, keep.len())
                    .with_context(|| format!("no bucket ≥ {} in {bucket_list:?}", keep.len()))?;
                let x_r = buckets::pack_tnd(&ds.tasks, &keep, db);
                let w0 = buckets::pack_w(&prev_w, t_count, &keep, db);
                let (out, chunks) = step_solve.time(|| {
                    engine.fista_solve(
                        &cfg,
                        db,
                        &x_r,
                        &y,
                        &w0,
                        lam,
                        opts.solve.tol as f32,
                        max_chunks,
                    )
                })?;
                w_full = buckets::unpack_w(&out.w, t_count, &keep, db, ds.d);
                let iters = chunks * chunk_steps;
                // exact-engine convention (solver/mod.rs `col_ops`): 2 sweeps
                // per epoch (forward + corr) plus 2 per duality-gap check —
                // the artifact evaluates the gap once per chunk. Keeps
                // BENCH_gap comparisons across engines apples-to-apples.
                let col_ops = (2 * iters + 2 * chunks) * keep.len();
                (out.obj as f64, out.gap as f64, iters, col_ops, Some(out.r))
            };

        let rejected = ds.d - keep.len();
        let active = w_full
            .chunks_exact(t_count)
            .filter(|row| ops::row_is_active(row, opts.active_tol))
            .count();
        let inactive = ds.d - active;
        let rejection_ratio =
            if inactive == 0 { 1.0 } else { rejected as f64 / inactive as f64 };

        records.push(LambdaRecord {
            ratio,
            lam: lam as f64,
            rejected,
            kept: keep.len(),
            inactive,
            rejection_ratio,
            screen_secs: step_screen.secs(),
            solve_secs: step_solve.secs(),
            solver_iters: iters,
            col_ops,
            obj,
            gap,
        });
        obs.on_solution(ratio, lam as f64, &w_full, records.last().unwrap());

        // sequential dual reference from the residual (Eq. 14): θ = −R/λ
        if let Some(r) = residual {
            theta0 = r.iter().map(|&v| -v / lam).collect();
            normal = y.iter().zip(&theta0).map(|(&yi, &ti)| yi / lam - ti).collect();
        } else {
            // W = 0 at this λ: θ = y/λ is the exact dual optimum; at the
            // grid head (λ = λ_max) the normal is the Eq. 20 gradient
            theta0 = y.iter().map(|&v| v / lam).collect();
            normal = if ratio >= 1.0 - 1e-12 {
                normal_init.clone()
            } else {
                y.iter().zip(&theta0).map(|(&yi, &ti)| yi / lam - ti).collect()
            };
        }
        prev_w = w_full;
    }

    total.stop();
    let screen_secs: f64 = records.iter().map(|r| r.screen_secs).sum();
    let solve_secs: f64 = records.iter().map(|r| r.solve_secs).sum();
    Ok(PathRunResult {
        dataset: ds.name.clone(),
        d: ds.d,
        lam_max,
        records,
        screen_secs,
        solve_secs,
        total_secs: total.secs(),
        last_w: prev_w,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::grid::lambda_grid;
    use crate::data::synthetic::{synthetic1, SynthOptions};

    fn small() -> Dataset {
        synthetic1(&SynthOptions { t: 3, n: 12, d: 50, seed: 17, ..Default::default() }).0
    }

    fn opts(k: ScreenerKind) -> PathOptions {
        PathOptions {
            ratios: lambda_grid(8, 1.0, 0.05),
            screener: k,
            verify_safety: true,
            ..Default::default()
        }
    }

    #[test]
    fn observer_streams_every_solution_in_grid_order() {
        let ds = small();
        let o = opts(ScreenerKind::Dpc);
        let mut seen: Vec<(f64, Vec<f64>)> = Vec::new();
        let mut obs = FnObserver(|ratio: f64, lam: f64, w: &[f64], rec: &LambdaRecord| {
            assert_eq!(w.len(), ds.d * ds.t());
            assert_eq!(rec.ratio, ratio);
            assert_eq!(rec.lam, lam);
            seen.push((ratio, w.to_vec()));
        });
        let res = run_path_with(&ds, &o, &EngineKind::Exact, &mut obs).unwrap();
        drop(obs);
        assert_eq!(seen.len(), res.records.len());
        for (s, r) in seen.iter().zip(&res.records) {
            assert_eq!(s.0, r.ratio);
        }
        // the final streamed solution IS the run's last_w
        assert_eq!(seen.last().unwrap().1, res.last_w);
    }

    #[test]
    fn screened_path_matches_unscreened() {
        let ds = small();
        let with = run_path(&ds, &opts(ScreenerKind::Dpc), &EngineKind::Exact).unwrap();
        let without = run_path(&ds, &opts(ScreenerKind::None), &EngineKind::Exact).unwrap();
        for (a, b) in with.records.iter().zip(&without.records) {
            assert!((a.obj - b.obj).abs() <= 1e-6 * b.obj.abs().max(1.0),
                "objective mismatch at ratio {}: {} vs {}", a.ratio, a.obj, b.obj);
            assert_eq!(a.inactive, b.inactive, "active-set mismatch at {}", a.ratio);
        }
        let dmax = with
            .last_w
            .iter()
            .zip(&without.last_w)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f64, f64::max);
        assert!(dmax < 1e-5, "final W mismatch {dmax}");
    }

    #[test]
    fn gap_safe_path_matches_unscreened() {
        let ds = small();
        let with = run_path(&ds, &opts(ScreenerKind::GapSafe), &EngineKind::Exact).unwrap();
        let without = run_path(&ds, &opts(ScreenerKind::None), &EngineKind::Exact).unwrap();
        for (a, b) in with.records.iter().zip(&without.records) {
            assert!((a.obj - b.obj).abs() <= 1e-6 * b.obj.abs().max(1.0),
                "objective mismatch at ratio {}: {} vs {}", a.ratio, a.obj, b.obj);
            assert_eq!(a.inactive, b.inactive, "active-set mismatch at {}", a.ratio);
        }
        // warm starts get good along the path: GAP-safe must reject
        let rejected: usize = with.records.iter().map(|r| r.rejected).sum();
        assert!(rejected > 0, "GAP-safe screening never fired");
    }

    #[test]
    fn dynamic_screening_path_matches_and_saves_work() {
        let ds =
            synthetic1(&SynthOptions { t: 3, n: 14, d: 150, seed: 18, ..Default::default() }).0;
        // against the unscreened baseline the saving must be unambiguous:
        // the solver sees all 150 features and dynamic screening prunes
        // the inactive bulk mid-solve
        let stat = opts(ScreenerKind::None);
        let mut dynamic = opts(ScreenerKind::None);
        dynamic.solve.dynamic_every = 10;
        let a = run_path(&ds, &dynamic, &EngineKind::Exact).unwrap();
        let b = run_path(&ds, &stat, &EngineKind::Exact).unwrap();
        for (x, y) in a.records.iter().zip(&b.records) {
            assert!(
                (x.obj - y.obj).abs() <= 1e-6 * y.obj.abs().max(1.0),
                "dynamic obj diverged at ratio {}",
                x.ratio
            );
        }
        assert!(
            a.total_col_ops() < b.total_col_ops(),
            "dynamic screening saved no column sweeps: {} vs {}",
            a.total_col_ops(),
            b.total_col_ops()
        );
        // and composed with static DPC it must stay exact
        let mut dpc_dynamic = opts(ScreenerKind::Dpc);
        dpc_dynamic.solve.dynamic_every = 10;
        let c = run_path(&ds, &dpc_dynamic, &EngineKind::Exact).unwrap();
        for (x, y) in c.records.iter().zip(&b.records) {
            assert!(
                (x.obj - y.obj).abs() <= 1e-6 * y.obj.abs().max(1.0),
                "DPC+dynamic obj diverged at ratio {}",
                x.ratio
            );
        }
    }

    #[test]
    fn rejection_ratios_are_high_and_valid() {
        let ds = small();
        let res = run_path(&ds, &opts(ScreenerKind::Dpc), &EngineKind::Exact).unwrap();
        for r in &res.records[1..] {
            assert!(r.rejection_ratio >= 0.0 && r.rejection_ratio <= 1.0 + 1e-12);
        }
        assert!(res.mean_rejection_ratio() > 0.5, "mean {}", res.mean_rejection_ratio());
    }

    #[test]
    fn oneshot_rejects_no_more_than_sequential() {
        let ds = small();
        let seq = run_path(&ds, &opts(ScreenerKind::Dpc), &EngineKind::Exact).unwrap();
        let one = run_path(&ds, &opts(ScreenerKind::DpcOneShot), &EngineKind::Exact).unwrap();
        let s: usize = seq.records.iter().map(|r| r.rejected).sum();
        let o: usize = one.records.iter().map(|r| r.rejected).sum();
        assert!(o <= s, "one-shot {o} > sequential {s}");
    }

    #[test]
    fn cs_is_safe_but_looser() {
        let ds = small();
        let cs = run_path(&ds, &opts(ScreenerKind::DpcCs), &EngineKind::Exact).unwrap();
        let dpc = run_path(&ds, &opts(ScreenerKind::Dpc), &EngineKind::Exact).unwrap();
        let s: usize = cs.records.iter().map(|r| r.rejected).sum();
        let o: usize = dpc.records.iter().map(|r| r.rejected).sum();
        assert!(s <= o, "CS rejected more than exact DPC");
    }

    #[test]
    fn non_l21_penalties_are_gated_to_supported_components() {
        let ds = small();
        let sgl = crate::penalty::PenaltyKind::Sgl { alpha: 0.5 };
        // DPC screener: ℓ2,1 geometry, must be refused with a cure
        let mut o = opts(ScreenerKind::Dpc);
        o.solve.penalty = sgl;
        let err = run_path(&ds, &o, &EngineKind::Exact).unwrap_err().to_string();
        assert!(err.contains("--screener gap"), "unhelpful error: {err}");
        // BCD solver: ℓ2,1 row subproblem, must be refused with a cure
        let mut o = opts(ScreenerKind::GapSafe);
        o.solve.penalty = sgl;
        o.solver = SolverKind::Bcd;
        let err = run_path(&ds, &o, &EngineKind::Exact).unwrap_err().to_string();
        assert!(err.contains("--solver fista"), "unhelpful error: {err}");
    }

    #[test]
    fn generic_penalty_paths_run_screened_and_verified() {
        // GapSafe + FISTA + paranoid verification for both new penalties:
        // the λ_max head of the grid must solve to W = 0, every rejection
        // must survive the penalty-aware independent verifier, and the
        // screeners must actually fire somewhere along the grid
        let ds = small();
        for pk in [
            crate::penalty::PenaltyKind::Sgl { alpha: 0.4 },
            crate::penalty::PenaltyKind::Gowl { gamma: 1.0 },
        ] {
            let mut o = opts(ScreenerKind::GapSafe);
            o.solve.penalty = pk;
            let res = run_path(&ds, &o, &EngineKind::Exact)
                .unwrap_or_else(|e| panic!("{pk} path failed: {e:#}"));
            let head = &res.records[0];
            assert_eq!(head.kept, 0, "{pk}: λ_max head must keep nothing");
            assert!(
                head.gap <= 1e-6 * head.obj.abs().max(1.0),
                "{pk}: W=0 not optimal at its own λ_max (gap {})",
                head.gap
            );
            // every per-λ solve must have certified itself (records carry
            // the final gap); verify_safety already errored on any unsafe
            // rejection inside run_path
            for r in &res.records {
                assert!(
                    r.gap <= 10.0 * o.solve.tol * r.obj.abs().max(1.0),
                    "{pk}: unconverged at ratio {} (gap {})",
                    r.ratio,
                    r.gap
                );
            }
        }
    }

    #[test]
    fn bcd_path_agrees_with_fista_path() {
        let ds = small();
        let mut o = opts(ScreenerKind::Dpc);
        o.solver = SolverKind::Bcd;
        let b = run_path(&ds, &o, &EngineKind::Exact).unwrap();
        let f = run_path(&ds, &opts(ScreenerKind::Dpc), &EngineKind::Exact).unwrap();
        for (x, y) in b.records.iter().zip(&f.records) {
            assert!((x.obj - y.obj).abs() <= 1e-5 * y.obj.abs().max(1.0));
        }
    }
}
