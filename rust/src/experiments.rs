//! Experiment drivers shared by the CLI (`repro <exp>`) and the bench
//! targets — one function per paper table/figure (see DESIGN.md §4).
//!
//! Scales: `quick` (CI-sized, seconds), `default` (scaled-down paper dims,
//! minutes), `paper` (the printed dims — hours on this CPU testbed; shape
//! identical to `default`).

use crate::coordinator::metrics::{speedup_row, RejectionCurve, SpeedupRow};
use crate::coordinator::path::{run_path, run_path_with, EngineKind, PathOptions, ScreenerKind};
use crate::coordinator::{lambda_grid, report};
use crate::data::imagesim::{imagesim, ImageSimOptions};
use crate::data::snpsim::{snpsim, SnpSimOptions};
use crate::data::synthetic::{synthetic1, synthetic2, SynthOptions};
use crate::data::textsim::{textsim, TextSimOptions};
use crate::data::Dataset;
use crate::solver::SolveOptions;
use anyhow::Result;

/// Experiment scale: same shapes, different dimensions (DESIGN.md §4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// CI-sized — seconds end to end
    Quick,
    /// scaled-down paper dims — minutes
    Default,
    /// the paper's printed dims — hours on a CPU testbed
    Paper,
}

impl Scale {
    /// Parse a `--scale` CLI value (`quick|default|paper`).
    pub fn parse(s: &str) -> Result<Scale> {
        match s {
            "quick" => Ok(Scale::Quick),
            "default" => Ok(Scale::Default),
            "paper" => Ok(Scale::Paper),
            _ => anyhow::bail!("unknown scale '{s}' (quick|default|paper)"),
        }
    }

    /// λ-grid length (the paper uses 100 values).
    pub fn grid_len(&self) -> usize {
        match self {
            Scale::Quick => 20,
            Scale::Default => 100, // the paper's 100-value grid
            Scale::Paper => 100,
        }
    }

    /// Repeated trials per figure point (the paper averages 20).
    pub fn trials(&self) -> usize {
        match self {
            Scale::Quick => 2,
            Scale::Default => 3,
            Scale::Paper => 20, // the paper's 20 trials
        }
    }

    /// Feature dimensions swept by the synthetic figures.
    pub fn synth_dims(&self) -> Vec<usize> {
        match self {
            Scale::Quick => vec![256, 512],
            Scale::Default => vec![1000, 2000, 4000],
            Scale::Paper => vec![10_000, 20_000, 50_000],
        }
    }

    /// (T tasks, N samples per task) for the synthetic workloads.
    pub fn synth_tn(&self) -> (usize, usize) {
        match self {
            Scale::Quick => (4, 16),
            Scale::Default => (20, 50),
            Scale::Paper => (50, 50),
        }
    }
}

/// Path options used by the reproduction experiments: loose solver profile
/// (cross-validation-grade accuracy, like the paper's SLEP runs).
pub fn exp_opts(grid: usize, screener: ScreenerKind) -> PathOptions {
    PathOptions {
        ratios: lambda_grid(grid, 1.0, 0.01),
        solve: SolveOptions { tol: 1e-6, max_iters: 20_000, ..Default::default() },
        screener,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// dataset builders
// ---------------------------------------------------------------------------

/// Synthetic 1 or 2 (`which` ∈ {1, 2}) at dimension `d` and scale shape.
pub fn build_synthetic(which: u8, d: usize, scale: Scale, seed: u64) -> Dataset {
    let (t, n) = scale.synth_tn();
    let opts = SynthOptions { t, n, d, seed, ..Default::default() };
    match which {
        1 => synthetic1(&opts).0,
        2 => synthetic2(&opts).0,
        _ => unreachable!(),
    }
}

/// The AwA stand-in (block-heterogeneous image features, DESIGN.md §5).
pub fn build_animal(scale: Scale, seed: u64) -> Dataset {
    let opts = match scale {
        Scale::Quick => ImageSimOptions {
            classes: 4,
            n_pos: 8,
            blocks: vec![64, 96, 96],
            rank: 4,
            seed,
        },
        Scale::Default => ImageSimOptions {
            classes: 10,
            n_pos: 30,
            blocks: vec![288, 512, 252, 500, 500, 512, 512],
            rank: 8,
            seed,
        },
        // the paper's 20 classes x (60 x 15036)
        Scale::Paper => ImageSimOptions {
            classes: 20,
            n_pos: 30,
            blocks: vec![2688, 2000, 252, 2000, 2000, 2000, 4096],
            rank: 16,
            seed,
        },
    };
    imagesim(&opts)
}

/// The TDT2 stand-in (~99% sparse text, CSC storage, DESIGN.md §5).
pub fn build_tdt2(scale: Scale, seed: u64) -> Dataset {
    let opts = match scale {
        Scale::Quick => TextSimOptions { categories: 4, n_pos: 10, d: 600, ..Default::default() },
        Scale::Default => {
            TextSimOptions { categories: 10, n_pos: 25, d: 6000, seed, ..Default::default() }
        }
        // the paper's 30 categories x (100 x 24262)
        Scale::Paper => TextSimOptions {
            categories: 30,
            n_pos: 50,
            d: 24_262,
            doc_len: 200,
            topic_terms: 60,
            seed,
            ..Default::default()
        },
    };
    textsim(&opts)
}

/// The ADNI stand-in (d ≫ N genomics, DESIGN.md §5).
pub fn build_adni(scale: Scale, seed: u64) -> Dataset {
    let opts = match scale {
        Scale::Quick => {
            SnpSimOptions { tasks: 3, n: 12, d: 1500, causal: 12, seed, ..Default::default() }
        }
        Scale::Default => {
            SnpSimOptions { tasks: 10, n: 25, d: 20_000, causal: 40, seed, ..Default::default() }
        }
        // the paper's 20 x (50 x 504095)
        Scale::Paper => SnpSimOptions {
            tasks: 20,
            n: 50,
            d: 504_095,
            causal: 100,
            seed,
            ..Default::default()
        },
    };
    snpsim(&opts).0
}

/// Dataset lookup for the CLI's `--dataset` values (with aliases).
pub fn build_by_name(name: &str, d: usize, scale: Scale, seed: u64) -> Result<Dataset> {
    Ok(match name {
        "synth1" | "synthetic1" => build_synthetic(1, d, scale, seed),
        "synth2" | "synthetic2" => build_synthetic(2, d, scale, seed),
        "animal" | "animalsim" => build_animal(scale, seed),
        "tdt2" | "tdt2sim" | "text" => build_tdt2(scale, seed),
        "adni" | "adnisim" | "snp" => build_adni(scale, seed),
        _ => anyhow::bail!("unknown dataset '{name}'"),
    })
}

// ---------------------------------------------------------------------------
// FIG1: rejection ratios, Synthetic 1 & 2, three dimensions
// ---------------------------------------------------------------------------

/// Reproduce Figure 1: rejection-ratio curves on Synthetic 1/2 across
/// three dimensions, averaged over trials.
pub fn run_fig1(scale: Scale, engine: &EngineKind) -> Result<String> {
    let mut out = String::new();
    let opts = exp_opts(scale.grid_len(), ScreenerKind::Dpc);
    for which in [1u8, 2u8] {
        for &d in &scale.synth_dims() {
            // the per-λ observer hook streams each trial's rejection ratios
            // straight into the curve accumulator — no retained run results
            let mut curve = RejectionCurve::new(opts.ratios.len());
            for trial in 0..scale.trials() {
                let ds = build_synthetic(which, d, scale, 1000 * trial as u64 + d as u64);
                run_path_with(&ds, &opts, engine, &mut curve)?;
            }
            out.push_str(&report::render_rejection_curve(
                &format!("Fig1 synthetic{which} d={d} ({} trials)", scale.trials()),
                &curve.curve(),
            ));
            out.push('\n');
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// FIG2: rejection ratios on the three simulated real datasets
// ---------------------------------------------------------------------------

/// Reproduce Figure 2: rejection-ratio curves on the three simulated
/// real datasets.
pub fn run_fig2(scale: Scale, engine: &EngineKind) -> Result<String> {
    let mut out = String::new();
    let opts = exp_opts(scale.grid_len(), ScreenerKind::Dpc);
    let builders: Vec<(&str, Box<dyn Fn(u64) -> Dataset>)> = vec![
        ("animal-sim", Box::new(move |s| build_animal(scale, s))),
        ("tdt2-sim", Box::new(move |s| build_tdt2(scale, s))),
        ("adni-sim", Box::new(move |s| build_adni(scale, s))),
    ];
    for (name, build) in builders {
        let ds = build(7);
        let mut curve = RejectionCurve::new(opts.ratios.len());
        run_path_with(&ds, &opts, engine, &mut curve)?;
        out.push_str(&report::render_rejection_curve(
            &format!("Fig2 {name} d={}", ds.d),
            &curve.curve(),
        ));
        out.push('\n');
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// TABLE1: solver vs DPC+solver wallclock + speedup on all five datasets
// ---------------------------------------------------------------------------

/// Table 1's raw rows: baseline vs screened path timings per dataset.
pub fn table1_rows(scale: Scale, engine: &EngineKind) -> Result<Vec<SpeedupRow>> {
    let base_opts = exp_opts(scale.grid_len(), ScreenerKind::None);
    let dpc_opts = exp_opts(scale.grid_len(), ScreenerKind::Dpc);

    let mut datasets: Vec<Dataset> = Vec::new();
    for &d in &scale.synth_dims() {
        datasets.push(build_synthetic(1, d, scale, d as u64));
    }
    for &d in &scale.synth_dims() {
        datasets.push(build_synthetic(2, d, scale, d as u64));
    }
    datasets.push(build_animal(scale, 7));
    datasets.push(build_tdt2(scale, 7));
    datasets.push(build_adni(scale, 7));

    let mut rows = Vec::new();
    for ds in datasets {
        let baseline = run_path(&ds, &base_opts, engine)?;
        let screened = run_path(&ds, &dpc_opts, engine)?;
        rows.push(speedup_row(&baseline, &screened));
    }
    Ok(rows)
}

/// Reproduce Table 1 (solver vs DPC+solver wallclock and speedup).
pub fn run_table1(scale: Scale, engine: &EngineKind) -> Result<String> {
    Ok(report::render_table1(&table1_rows(scale, engine)?))
}

// ---------------------------------------------------------------------------
// ABL1/ABL2: exact QP1QC vs CS bound; sequential vs one-shot
// ---------------------------------------------------------------------------

/// The ABL1/ABL2 screener ablation table (DESIGN.md §8), extended with
/// penalty-seam rows (DESIGN.md §14): sparse-group lasso and group OWL
/// run the same grid through the GAP-safe screener, so their rejection
/// power and column-sweep cost line up against the ℓ2,1 screeners in one
/// table.
pub fn run_ablation(scale: Scale) -> Result<String> {
    use crate::penalty::PenaltyKind;
    let d = *scale.synth_dims().first().unwrap();
    let ds = build_synthetic(2, d, scale, 42);
    let engine = EngineKind::Exact;

    let mut out = String::new();
    let mut table = crate::bench::Table::new(&[
        "screener", "total rejected", "mean rejection", "screen(s)", "col-ops", "total(s)",
    ]);
    for (name, kind, dynamic_every, penalty) in [
        ("DPC (exact QP1QC, sequential)", ScreenerKind::Dpc, 0usize, PenaltyKind::L21),
        ("DPC + dynamic gap screening", ScreenerKind::Dpc, DYNAMIC_EVERY, PenaltyKind::L21),
        ("GAP-safe (gap ball, static)", ScreenerKind::GapSafe, 0, PenaltyKind::L21),
        ("DPC-CS (Cauchy-Schwarz bound)", ScreenerKind::DpcCs, 0, PenaltyKind::L21),
        ("DPC one-shot (from lambda_max)", ScreenerKind::DpcOneShot, 0, PenaltyKind::L21),
        ("no screening", ScreenerKind::None, 0, PenaltyKind::L21),
        (
            "sgl(a=0.3) + GAP-safe",
            ScreenerKind::GapSafe,
            0,
            PenaltyKind::Sgl { alpha: 0.3 },
        ),
        (
            "gowl(g=1) + GAP-safe",
            ScreenerKind::GapSafe,
            0,
            PenaltyKind::Gowl { gamma: 1.0 },
        ),
    ] {
        let mut opts = exp_opts(scale.grid_len(), kind);
        opts.solve.dynamic_every = dynamic_every;
        opts.solve.penalty = penalty;
        let res = run_path(&ds, &opts, &engine)?;
        let rejected: usize = res.records.iter().map(|r| r.rejected).sum();
        table.row(&[
            name.to_string(),
            rejected.to_string(),
            format!("{:.4}", res.mean_rejection_ratio()),
            format!("{:.3}", res.screen_secs),
            res.total_col_ops().to_string(),
            format!("{:.2}", res.total_secs),
        ]);
    }
    out.push_str(&format!("ABL1/ABL2 + penalty seam on {} (d={})\n", ds.name, ds.d));
    out.push_str(&table.render());
    Ok(out)
}

// ---------------------------------------------------------------------------
// BENCH_gap: static DPC vs gap-dynamic screening, epochs & column sweeps
// ---------------------------------------------------------------------------

/// Dynamic re-screen cadence used by the gap experiments and the bench
/// (every K solver epochs; chosen so a screen costs well under the sweep
/// work it can save).
pub const DYNAMIC_EVERY: usize = 10;

/// One configuration's cost along the synthetic2 path (`benches/kernels.rs`
/// records these into `BENCH_gap.json`).
#[derive(Debug, Clone)]
pub struct GapDynRow {
    /// configuration label (static/dynamic × screener)
    pub name: &'static str,
    /// total solver epochs along the path (FISTA iterations)
    pub epochs: usize,
    /// total column-sweep operations (see `SolveResult::col_ops`)
    pub col_ops: usize,
    /// total path wallclock, seconds
    pub secs: f64,
    /// mean rejection ratio along the path
    pub mean_rejection: f64,
}

/// Static-DPC vs gap-dynamic comparison on the synthetic2 path.
pub fn gap_dynamic_rows(scale: Scale) -> Result<Vec<GapDynRow>> {
    let d = *scale.synth_dims().first().unwrap();
    let ds = build_synthetic(2, d, scale, 42);
    let engine = EngineKind::Exact;
    let configs: [(&'static str, ScreenerKind, usize); 4] = [
        ("static-dpc", ScreenerKind::Dpc, 0),
        ("dynamic-dpc", ScreenerKind::Dpc, DYNAMIC_EVERY),
        ("static-gapsafe", ScreenerKind::GapSafe, 0),
        ("dynamic-gapsafe", ScreenerKind::GapSafe, DYNAMIC_EVERY),
    ];
    let mut rows = Vec::new();
    for (name, kind, dynamic_every) in configs {
        let mut opts = exp_opts(scale.grid_len(), kind);
        opts.solve.dynamic_every = dynamic_every;
        let res = run_path(&ds, &opts, &engine)?;
        rows.push(GapDynRow {
            name,
            epochs: res.total_iters(),
            col_ops: res.total_col_ops(),
            secs: res.total_secs,
            mean_rejection: res.mean_rejection_ratio(),
        });
    }
    Ok(rows)
}
