//! Feature-major matrix view + the dense hot-kernel entry points.
//!
//! Since the kernel-layer refactor the arithmetic lives in
//! [`super::simd`]: every function here is a thin wrapper over the
//! dispatching kernel, which follows the bit-pinned accumulation
//! contract (eight interleaved f64 accumulators per [`super::simd::ACC_BLOCK`]
//! block, fixed tree reduction — DESIGN.md §12) on the scalar, AVX2 and
//! NEON backends alike.

use super::simd;

/// A column-major (feature-major) matrix view over an `n x d` task matrix:
/// column `l` (one feature's samples) is `data[l*n .. (l+1)*n]`, contiguous.
#[derive(Debug, Clone, Copy)]
pub struct ColMajor<'a> {
    /// the backing buffer, length `n * d`
    pub data: &'a [f32],
    /// rows (samples)
    pub n: usize,
    /// columns (features)
    pub d: usize,
}

impl<'a> ColMajor<'a> {
    /// Wrap a buffer as an `n x d` feature-major view (length-checked).
    pub fn new(data: &'a [f32], n: usize, d: usize) -> Self {
        assert_eq!(data.len(), n * d, "matrix buffer size mismatch");
        ColMajor { data, n, d }
    }

    /// Column `l` as a contiguous slice.
    #[inline]
    pub fn col(&self, l: usize) -> &'a [f32] {
        debug_assert!(l < self.d);
        &self.data[l * self.n..(l + 1) * self.n]
    }
}

/// `<a, b>` with f64 accumulation under the kernel contract. The single
/// hottest kernel in the exact engine (every screening/gradient sweep is
/// a column dot).
#[inline]
pub fn dot_f32_f64(a: &[f32], b: &[f32]) -> f64 {
    simd::dot_f32_f64(a, b)
}

/// Mixed dot: f32 column against an f64 vector.
#[inline]
pub fn dot_mixed(a: &[f32], b: &[f64]) -> f64 {
    simd::dot_mixed(a, b)
}

/// `<a, b>` for two f64 vectors — same 8-lane contract as the mixed
/// kernels (it was a naive `zip().sum()` before the kernel layer).
#[inline]
pub fn dot_f64(a: &[f64], b: &[f64]) -> f64 {
    simd::dot_f64(a, b)
}

/// Euclidean norm of an f64 vector.
#[inline]
pub fn nrm2_f64(a: &[f64]) -> f64 {
    simd::dot_f64(a, a).sqrt()
}

/// `y += alpha * x` where x is an f32 column, y an f64 accumulator.
#[inline]
pub fn axpy_f64(alpha: f64, x: &[f32], y: &mut [f64]) {
    simd::axpy_f64(alpha, x, y)
}

/// `out = a + s * b` elementwise (f64).
#[inline]
pub fn scale_add(a: &[f64], s: f64, b: &[f64], out: &mut [f64]) {
    simd::scale_add(a, s, b, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn colmajor_columns() {
        // n=2 samples, d=3 features
        let data = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let m = ColMajor::new(&data, 2, 3);
        assert_eq!(m.col(0), &[1.0, 2.0]);
        assert_eq!(m.col(2), &[5.0, 6.0]);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn colmajor_size_check() {
        let data = [0.0f32; 5];
        ColMajor::new(&data, 2, 3);
    }

    #[test]
    fn dot_unroll_tail() {
        // products are exactly representable, so any association order
        // must give the exact sum — valid under the 8-lane contract too
        for n in [0usize, 1, 3, 4, 5, 7, 8, 17] {
            let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
            let b: Vec<f32> = (0..n).map(|i| (i as f32) - 2.0).collect();
            let want: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            assert_eq!(dot_f32_f64(&a, &b), want);
        }
    }

    #[test]
    fn dot_f64_matches_exact_sum() {
        let a: Vec<f64> = (0..23).map(|i| i as f64 * 0.25).collect();
        let b: Vec<f64> = (0..23).map(|i| (i as f64) - 8.0).collect();
        let want: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot_f64(&a, &b), want);
        assert_eq!(nrm2_f64(&[3.0, 4.0]), 5.0);
    }

    #[test]
    fn axpy_matches_manual() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f64, 20.0, 30.0];
        axpy_f64(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn scale_add_basic() {
        let a = [1.0, 2.0];
        let b = [10.0, 20.0];
        let mut out = [0.0; 2];
        scale_add(&a, 0.5, &b, &mut out);
        assert_eq!(out, [6.0, 12.0]);
    }
}
