//! Linear-algebra substrate (no BLAS/ndarray offline) with two storage
//! backends behind one column view (see DESIGN.md §6):
//!
//! * [`dense`] — feature-major f32 buffers (column-major, each feature's
//!   sample vector contiguous): the screening sweep `<x_l, v>` and the
//!   active-set forward product `Σ_l w_l x_l` are unit-stride scans.
//! * [`sparse`] — CSC per-column storage for the text/genomics regime
//!   (TDT2 is ~99% sparse); the same sweeps touch only stored entries.
//! * [`cache`] — the pinned-block LRU that bounds the resident set of the
//!   out-of-core sharded backend (blocks live on disk and fault in on
//!   demand; see DESIGN.md §10).
//!
//! [`ColRef`] is the seam: every consumer above this module (ops,
//! screening, solvers, coordinator) addresses columns through it and never
//! sees the storage layout. The out-of-core shard store
//! (`data::shard::ShardedDataset`, DESIGN.md §10) sits one level up — a
//! borrowed per-column view cannot outlive block eviction, so shards hand
//! out whole blocks (ordinary dense/CSC stores) and every in-RAM kernel
//! below is reused unchanged.
//!
//! Precision policy: matrices are f32 (memory: the ADNI-scale X is 2 GB at
//! paper dims), all accumulations are f64 — screening thresholds compare
//! against 1.0 at ~1e-12, which f32 accumulation cannot certify. All
//! reduction kernels live in [`simd`] behind one bit-pinned accumulation
//! contract (DESIGN.md §12): scalar, AVX2 and NEON produce identical
//! bits, and the sparse kernels share the contract over stored entries so
//! a fully-stored CSC column is bit-identical to its dense twin.

pub mod cache;
pub mod dense;
// `unsafe` is denied crate-wide (Cargo.toml [lints]); the kernel layer is
// one of the two allowlisted homes — `core::arch` SIMD intrinsics are
// unsafe by signature. Every unsafe operation sits in an inner block with
// its own `// SAFETY:` line (enforced by `unsafe_op_in_unsafe_fn` and
// repro-lint's confined-unsafe rule).
#[allow(unsafe_code)]
pub mod simd;
pub mod sparse;

pub use cache::BlockCache;
pub use dense::{
    axpy_f64, dot_f32_f64, dot_f64, nrm2_f64, scale_add, ColMajor,
};
pub use sparse::{sp_axpy_f64, sp_dot_f32_f64, sp_dot_mixed, CscMatrix};

/// A borrowed view of one feature column — the only column-access path in
/// the crate. Dispatches each hot kernel to the backend's implementation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ColRef<'a> {
    /// contiguous dense samples (length n)
    Dense(&'a [f32]),
    /// CSC column: `values[k]` lives at sample `indices[k]`; `n` is the
    /// logical column length
    Sparse { n: usize, indices: &'a [u32], values: &'a [f32] },
}

impl<'a> ColRef<'a> {
    /// Logical column length (sample count), zeros included.
    #[inline]
    pub fn n(&self) -> usize {
        match self {
            ColRef::Dense(c) => c.len(),
            ColRef::Sparse { n, .. } => *n,
        }
    }

    /// Stored nonzero count (dense backend counts exact nonzeros).
    pub fn nnz(&self) -> usize {
        match self {
            ColRef::Dense(c) => c.iter().filter(|&&v| v != 0.0).count(),
            ColRef::Sparse { values, .. } => values.len(),
        }
    }

    /// True if every entry of the column is exactly zero.
    pub fn is_zero(&self) -> bool {
        match self {
            ColRef::Dense(c) => c.iter().all(|&v| v == 0.0),
            ColRef::Sparse { values, .. } => values.iter().all(|&v| v == 0.0),
        }
    }

    /// `<col, v>` against a dense f64 vector (f64 accumulation).
    #[inline]
    pub fn dot_mixed(&self, v: &[f64]) -> f64 {
        debug_assert_eq!(self.n(), v.len());
        match self {
            ColRef::Dense(c) => dense::dot_mixed(c, v),
            ColRef::Sparse { indices, values, .. } => sparse::sp_dot_mixed(indices, values, v),
        }
    }

    /// `<col, v>` against a dense f32 vector (f64 accumulation).
    #[inline]
    pub fn dot_f32(&self, v: &[f32]) -> f64 {
        debug_assert_eq!(self.n(), v.len());
        match self {
            ColRef::Dense(c) => dense::dot_f32_f64(c, v),
            ColRef::Sparse { indices, values, .. } => sparse::sp_dot_f32_f64(indices, values, v),
        }
    }

    /// `‖col‖²` with f64 accumulation (the b² moments of Theorem 7).
    #[inline]
    pub fn sqnorm(&self) -> f64 {
        match self {
            ColRef::Dense(c) => dense::dot_f32_f64(c, c),
            ColRef::Sparse { values, .. } => dense::dot_f32_f64(values, values),
        }
    }

    /// `y += alpha * col` into an f64 accumulator.
    #[inline]
    pub fn axpy_into(&self, alpha: f64, y: &mut [f64]) {
        debug_assert_eq!(self.n(), y.len());
        match self {
            ColRef::Dense(c) => dense::axpy_f64(alpha, c, y),
            ColRef::Sparse { indices, values, .. } => {
                sparse::sp_axpy_f64(alpha, indices, values, y)
            }
        }
    }

    /// Visit every stored nonzero as `(sample_index, value)` (the AOT
    /// packers scatter into zero-initialized buffers).
    pub fn for_each_nonzero(&self, mut f: impl FnMut(usize, f32)) {
        match self {
            ColRef::Dense(c) => {
                for (i, &v) in c.iter().enumerate() {
                    if v != 0.0 {
                        f(i, v);
                    }
                }
            }
            ColRef::Sparse { indices, values, .. } => {
                for (i, v) in indices.iter().zip(values.iter()) {
                    f(*i as usize, *v);
                }
            }
        }
    }

    /// Densified copy of the column.
    pub fn to_vec(&self) -> Vec<f32> {
        match self {
            ColRef::Dense(c) => c.to_vec(),
            ColRef::Sparse { n, indices, values } => {
                let mut out = vec![0.0f32; *n];
                for (i, v) in indices.iter().zip(values.iter()) {
                    out[*i as usize] = *v;
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_f32_accumulates_in_f64() {
        // 1e8-magnitude cancellation would lose everything in f32
        let a = vec![1.0e4_f32; 1000];
        let b = vec![1.0e4_f32; 1000];
        let got = dot_f32_f64(&a, &b);
        assert_eq!(got, 1.0e8 * 1000.0);
    }

    #[test]
    fn colref_backends_agree() {
        let col = [0.0f32, 1.5, 0.0, -2.0, 3.25, 0.0, 0.5];
        let m = CscMatrix::from_dense(&col, col.len(), 1);
        let (idx, vals) = m.col(0);
        let dense_ref = ColRef::Dense(&col);
        let sparse_ref = ColRef::Sparse { n: col.len(), indices: idx, values: vals };

        assert_eq!(dense_ref.n(), sparse_ref.n());
        assert_eq!(dense_ref.nnz(), 4);
        assert_eq!(sparse_ref.nnz(), 4);
        assert!(!dense_ref.is_zero() && !sparse_ref.is_zero());

        let v64: Vec<f64> = (0..col.len()).map(|i| (i as f64) - 3.0).collect();
        let v32: Vec<f32> = v64.iter().map(|&v| v as f32).collect();
        assert!((dense_ref.dot_mixed(&v64) - sparse_ref.dot_mixed(&v64)).abs() < 1e-14);
        assert!((dense_ref.dot_f32(&v32) - sparse_ref.dot_f32(&v32)).abs() < 1e-14);
        assert!((dense_ref.sqnorm() - sparse_ref.sqnorm()).abs() < 1e-14);

        let mut ya = vec![0.5f64; col.len()];
        let mut yb = ya.clone();
        dense_ref.axpy_into(2.0, &mut ya);
        sparse_ref.axpy_into(2.0, &mut yb);
        assert_eq!(ya, yb);

        assert_eq!(sparse_ref.to_vec(), col.to_vec());

        let mut scatter = vec![0.0f32; col.len()];
        sparse_ref.for_each_nonzero(|i, v| scatter[i] = v);
        assert_eq!(scatter, col.to_vec());
    }

    #[test]
    fn zero_column_is_zero_on_both_backends() {
        let col = [0.0f32; 5];
        let m = CscMatrix::from_dense(&col, 5, 1);
        let (idx, vals) = m.col(0);
        assert!(ColRef::Dense(&col).is_zero());
        assert!(ColRef::Sparse { n: 5, indices: idx, values: vals }.is_zero());
        assert_eq!(ColRef::Sparse { n: 5, indices: idx, values: vals }.nnz(), 0);
    }
}
