//! Dense linear-algebra substrate (no BLAS/ndarray offline).
//!
//! Data matrices are stored **feature-major** (column-major, each feature's
//! sample vector contiguous): the screening sweep `<x_l, v>` and the
//! active-set forward product `Σ_l w_l x_l` are both unit-stride scans,
//! which is exactly the access pattern DPC spends its time in.
//!
//! Precision policy: matrices are f32 (memory: the ADNI-scale X is 2 GB at
//! paper dims), all accumulations are f64 — screening thresholds compare
//! against 1.0 at ~1e-12, which f32 accumulation cannot certify.

pub mod dense;

pub use dense::{
    axpy_f64, dot_f32_f64, dot_f64, nrm2_f64, scale_add, ColMajor,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_f32_accumulates_in_f64() {
        // 1e8-magnitude cancellation would lose everything in f32
        let a = vec![1.0e4_f32; 1000];
        let b = vec![1.0e4_f32; 1000];
        let got = dot_f32_f64(&a, &b);
        assert_eq!(got, 1.0e8 * 1000.0);
    }
}
