//! The kernel layer: one bit-pinned accumulation contract, three
//! implementations (scalar reference, x86-64 AVX2, aarch64 NEON), one
//! runtime dispatcher (DESIGN.md §12).
//!
//! Every reduction kernel in the crate — dense and CSC dots, `sqnorm`,
//! `dot_f64` — follows the **same canonical accumulation contract**:
//!
//! 1. The input is cut into blocks of [`ACC_BLOCK`] elements (stored
//!    entries, on the sparse kernels).
//! 2. Inside a block, eight interleaved f64 accumulators `s0..s7` run
//!    over the 8-element chunks (`s_k` sums elements `j+k`), each as
//!    round-to-nearest `s_k += a·b` — the product is rounded *before*
//!    the add, so FMA is banned on every backend.
//! 3. The eight lanes reduce in the fixed tree order
//!    `((s0+s1)+(s2+s3)) + ((s4+s5)+(s6+s7))`, then the ≤7-element tail
//!    is added left to right.
//! 4. Block partials fold left to right into an accumulator that starts
//!    at `0.0`.
//!
//! A SIMD register holding lanes `s_k..s_{k+3}` (AVX2) or `s_k, s_{k+1}`
//! (NEON) performs *exactly* the scalar per-lane adds, and the lanes are
//! extracted and reduced with the same scalar tree — so the scalar, AVX2
//! and NEON paths are **bit-identical**, not merely close. That is what
//! lets the dense/CSC parity suite, the sharded-streaming parity suite
//! and the executor determinism suite keep pinning exact bits with the
//! `simd` feature on or off (`rust/tests/simd_kernels.rs` asserts the
//! equality kernel by kernel).
//!
//! Blocking is part of the contract, not a tuning detail: the panel
//! sweeps in `ops` accumulate per column in the same [`ACC_BLOCK`]
//! boundaries, which is why a cache-blocked sweep reproduces the plain
//! per-column dot bit for bit. Elementwise kernels (`axpy_f64`,
//! `scale_add`) have no accumulator and need no blocking; their SIMD
//! forms are the scalar operation applied per element.
//!
//! Backend selection: AVX2 is detected once at runtime
//! (`is_x86_feature_detected!`) and cached; NEON is baseline on aarch64;
//! everything else — including `--no-default-features` builds — uses the
//! scalar reference. [`force_scalar`] pins the dispatcher to the scalar
//! path at runtime so tests and benches can compare backends in-process.
//! AVX2 covers the gather-based sparse dots; NEON has no gather, so the
//! sparse kernels stay on the scalar path there (still blocked, still
//! the same contract).

use std::sync::atomic::{AtomicBool, Ordering};

/// Elements per accumulation block (stored entries on sparse kernels).
///
/// Tuning: 2048 f64s = 16 KiB per operand — two operand streams fit L1
/// comfortably, and an `ops` panel re-uses one resident block of `v`
/// against many columns before moving on (L2-sized working set). The
/// value is part of the accumulation contract: changing it changes
/// results (within normal fp reassociation error) and invalidates the
/// recorded bit-parity fixtures, so treat it as a cross-cutting knob,
/// not a per-call-site one.
pub const ACC_BLOCK: usize = 2048;

/// Interleaved f64 accumulators per block (the contract's lane count).
pub const ACC_LANES: usize = 8;

/// Which kernel implementation the dispatcher is using.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// portable reference implementation (always compiled)
    Scalar,
    /// x86-64 AVX2 (runtime-detected, `simd` feature)
    Avx2,
    /// aarch64 NEON (baseline on aarch64, `simd` feature)
    Neon,
}

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Pin every dispatching kernel to the scalar reference path (`true`) or
/// restore runtime detection (`false`). Process-global; intended for
/// tests and benches that compare backends in-process. Because the
/// backends are bit-identical, flipping this mid-computation is safe —
/// it changes speed, never results.
pub fn force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

/// The implementation the dispatcher would use right now
/// (respects [`force_scalar`]).
#[inline]
pub fn active_isa() -> Isa {
    if FORCE_SCALAR.load(Ordering::Relaxed) {
        return Isa::Scalar;
    }
    detect()
}

/// [`active_isa`] as a lowercase string ("scalar" / "avx2" / "neon") for
/// logs and bench reports.
pub fn active_backend() -> &'static str {
    match active_isa() {
        Isa::Scalar => "scalar",
        Isa::Avx2 => "avx2",
        Isa::Neon => "neon",
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
fn detect() -> Isa {
    use std::sync::atomic::AtomicU8;
    // 0 = undetected, 1 = scalar, 2 = avx2 (cpuid once, then one load)
    static CACHE: AtomicU8 = AtomicU8::new(0);
    match CACHE.load(Ordering::Relaxed) {
        2 => Isa::Avx2,
        1 => Isa::Scalar,
        _ => {
            let avx2 = is_x86_feature_detected!("avx2");
            CACHE.store(if avx2 { 2 } else { 1 }, Ordering::Relaxed);
            if avx2 {
                Isa::Avx2
            } else {
                Isa::Scalar
            }
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
#[inline]
fn detect() -> Isa {
    Isa::Neon
}

#[cfg(not(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64"))))]
#[inline]
fn detect() -> Isa {
    Isa::Scalar
}

/// Fold `f(lo, hi)` over `[0, n)` in [`ACC_BLOCK`]-sized half-open
/// ranges, summing partials left to right from `0.0` (contract step 4).
#[inline]
fn fold_blocks(n: usize, mut f: impl FnMut(usize, usize) -> f64) -> f64 {
    let mut acc = 0.0f64;
    let mut i = 0usize;
    while i < n {
        let hi = (i + ACC_BLOCK).min(n);
        acc += f(i, hi);
        i = hi;
    }
    acc
}

// ---------------------------------------------------------------------------
// dispatching kernels (the crate-facing entry points)
// ---------------------------------------------------------------------------

/// `<a, b>` of one ≤[`ACC_BLOCK`] slice pair under the contract: the
/// building block the cache-blocked panel sweeps in `ops` accumulate
/// with. Dispatches per call (one relaxed atomic load, amortized over
/// the block).
#[inline]
pub fn dot_mixed_block(a: &[f32], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active_isa() == Isa::Avx2 {
        // SAFETY: active_isa() returns Avx2 only after runtime detection
        return unsafe { avx2::dot_mixed_block(a, b) };
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if active_isa() == Isa::Neon {
        // SAFETY: NEON is baseline on aarch64
        return unsafe { neon::dot_mixed_block(a, b) };
    }
    scalar::dot_mixed_block(a, b)
}

/// One-block `<a, b>` for two f32 slices (f64 accumulation).
#[inline]
pub fn dot_f32_block(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active_isa() == Isa::Avx2 {
        // SAFETY: active_isa() returns Avx2 only after runtime detection
        return unsafe { avx2::dot_f32_block(a, b) };
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if active_isa() == Isa::Neon {
        // SAFETY: NEON is baseline on aarch64
        return unsafe { neon::dot_f32_block(a, b) };
    }
    scalar::dot_f32_block(a, b)
}

/// One-block `<a, b>` for two f64 slices.
#[inline]
pub fn dot_f64_block(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active_isa() == Isa::Avx2 {
        // SAFETY: active_isa() returns Avx2 only after runtime detection
        return unsafe { avx2::dot_f64_block(a, b) };
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if active_isa() == Isa::Neon {
        // SAFETY: NEON is baseline on aarch64
        return unsafe { neon::dot_f64_block(a, b) };
    }
    scalar::dot_f64_block(a, b)
}

/// Mixed dot `<a, b>`, a f32 / b f64, blocked per the contract.
#[inline]
pub fn dot_mixed(a: &[f32], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    fold_blocks(a.len(), |lo, hi| dot_mixed_block(&a[lo..hi], &b[lo..hi]))
}

/// `<a, b>` of two f32 slices with f64 accumulation, blocked.
#[inline]
pub fn dot_f32_f64(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    fold_blocks(a.len(), |lo, hi| dot_f32_block(&a[lo..hi], &b[lo..hi]))
}

/// `<a, b>` of two f64 slices, blocked.
#[inline]
pub fn dot_f64(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    fold_blocks(a.len(), |lo, hi| dot_f64_block(&a[lo..hi], &b[lo..hi]))
}

/// `y += alpha * x` (x f32, y f64). Elementwise — the SIMD form is the
/// scalar operation per element, so it is bit-identical unblocked.
/// `alpha == 0.0` returns immediately on every backend (adding `±0.0`
/// could flip the sign bit of a `-0.0` in `y`).
#[inline]
pub fn axpy_f64(alpha: f64, x: &[f32], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    if alpha == 0.0 {
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active_isa() == Isa::Avx2 {
        // SAFETY: active_isa() returns Avx2 only after runtime detection
        unsafe { avx2::axpy_f64(alpha, x, y) };
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if active_isa() == Isa::Neon {
        // SAFETY: NEON is baseline on aarch64
        unsafe { neon::axpy_f64(alpha, x, y) };
        return;
    }
    scalar::axpy_f64(alpha, x, y);
}

/// `out = a + s * b` elementwise (f64).
#[inline]
pub fn scale_add(a: &[f64], s: f64, b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active_isa() == Isa::Avx2 {
        // SAFETY: active_isa() returns Avx2 only after runtime detection
        unsafe { avx2::scale_add(a, s, b, out) };
        return;
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if active_isa() == Isa::Neon {
        // SAFETY: NEON is baseline on aarch64
        unsafe { neon::scale_add(a, s, b, out) };
        return;
    }
    scalar::scale_add(a, s, b, out);
}

/// Sparse `<col, v>` against a dense f64 vector, blocked over *stored*
/// entries with the same contract (a fully-stored column is therefore
/// bit-identical to the dense kernel). AVX2 uses hardware gathers; the
/// gather path requires `v.len() <= i32::MAX` (gather offsets are
/// signed 32-bit) and falls back to scalar beyond that.
#[inline]
pub fn sp_dot_mixed(indices: &[u32], values: &[f32], v: &[f64]) -> f64 {
    debug_assert_eq!(indices.len(), values.len());
    fold_blocks(values.len(), |lo, hi| {
        sp_dot_mixed_block(&indices[lo..hi], &values[lo..hi], v)
    })
}

/// Sparse `<col, v>` against a dense f32 vector (f64 accumulation),
/// blocked over stored entries. Same gather policy as [`sp_dot_mixed`].
#[inline]
pub fn sp_dot_f32_f64(indices: &[u32], values: &[f32], v: &[f32]) -> f64 {
    debug_assert_eq!(indices.len(), values.len());
    fold_blocks(values.len(), |lo, hi| {
        sp_dot_f32_block(&indices[lo..hi], &values[lo..hi], v)
    })
}

/// Sparse `y += alpha * col` scatter. There is no scatter instruction in
/// AVX2/NEON, so every backend shares the scalar loop (index order —
/// strictly increasing rows — is the accumulation order).
#[inline]
pub fn sp_axpy_f64(alpha: f64, indices: &[u32], values: &[f32], y: &mut [f64]) {
    debug_assert_eq!(indices.len(), values.len());
    if alpha == 0.0 {
        return;
    }
    scalar::sp_axpy_f64(alpha, indices, values, y);
}

#[inline]
fn sp_dot_mixed_block(indices: &[u32], values: &[f32], v: &[f64]) -> f64 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active_isa() == Isa::Avx2 && v.len() <= i32::MAX as usize {
        // SAFETY: active_isa() returns Avx2 only after runtime detection;
        // the kernel bounds-checks every gathered index against v.len()
        return unsafe { avx2::sp_dot_mixed_block(indices, values, v) };
    }
    scalar::sp_dot_mixed_block(indices, values, v)
}

#[inline]
fn sp_dot_f32_block(indices: &[u32], values: &[f32], v: &[f32]) -> f64 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if active_isa() == Isa::Avx2 && v.len() <= i32::MAX as usize {
        // SAFETY: active_isa() returns Avx2 only after runtime detection;
        // the kernel bounds-checks every gathered index against v.len()
        return unsafe { avx2::sp_dot_f32_block(indices, values, v) };
    }
    scalar::sp_dot_f32_block(indices, values, v)
}

// ---------------------------------------------------------------------------
// serial statistics reductions (the pinned-order home for non-kernel sums)
// ---------------------------------------------------------------------------
//
// Coordinator/metrics/report code occasionally needs a small reduction —
// a mean of fold errors, a residual sum of squares for an objective —
// that is not worth a SIMD kernel but still feeds deterministic output.
// Iterator `.sum()` documents no association order, so repro-lint's
// kernel-reduction rule rejects ad-hoc float folds outside this file;
// these helpers are the sanctioned route: strict left-to-right
// accumulation from 0.0, defined here so the fold order is pinned in one
// place alongside the block contract.

/// Left-to-right serial sum from `0.0`.
#[inline]
pub fn sum_serial_f64(v: &[f64]) -> f64 {
    let mut acc = 0.0f64;
    for &x in v {
        acc += x;
    }
    acc
}

/// Serial mean: [`sum_serial_f64`] divided by `len.max(1)` (an empty
/// slice yields `0.0`, not NaN).
#[inline]
pub fn mean_serial_f64(v: &[f64]) -> f64 {
    sum_serial_f64(v) / v.len().max(1) as f64
}

/// Left-to-right serial `Σ xᵢ²` (each product rounds before its add).
#[inline]
pub fn sumsq_serial_f64(v: &[f64]) -> f64 {
    let mut acc = 0.0f64;
    for &x in v {
        acc += x * x;
    }
    acc
}

/// Left-to-right serial `Σ |xᵢ|` — the ℓ1 part of the sparse-group-lasso
/// penalty value (`penalty::sgl`), pinned here with the other folds.
#[inline]
pub fn abs_sum_serial_f64(v: &[f64]) -> f64 {
    let mut acc = 0.0f64;
    for &x in v {
        acc += x.abs();
    }
    acc
}

/// Left-to-right serial `Σ (xᵢ − m)²` around a precomputed center `m`.
#[inline]
pub fn centered_sumsq_serial_f64(v: &[f64], m: f64) -> f64 {
    let mut acc = 0.0f64;
    for &x in v {
        let d = x - m;
        acc += d * d;
    }
    acc
}

/// Serial `Σ_l a[l*stride + off] * (x_l as f64)` over ascending `l`,
/// skipping `a`-zeros — the per-sample image of `ops::axpy_panel`'s
/// accumulation order (each active column contributes one mul-then-add,
/// columns in ascending order; skipped zeros match `axpy_f64`'s
/// `alpha == 0` early return). `repro serve` replays one input row
/// through a row-major d×T `W` with this helper (`stride = T`,
/// `off = t`), so a served prediction carries bit-identical f64s to an
/// offline [`crate::ops::forward`] on the same sample (DESIGN.md §15).
#[inline]
pub fn dot_strided_skipz_f64(a: &[f64], stride: usize, off: usize, x: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for (l, &xl) in x.iter().enumerate() {
        let al = a[l * stride + off];
        if al != 0.0 {
            acc += al * xl as f64;
        }
    }
    acc
}

/// Continue `acc` with the serial `Σ (yᵢ/λ − tᵢ)²` of one task — the
/// dual-objective distance term. Takes and returns the running
/// accumulator so a multi-task caller keeps one global left-to-right
/// fold (splitting into per-task partials would change the bits). The
/// division by `λ` is kept as a division: `yᵢ * (1/λ)` rounds
/// differently.
#[inline]
pub fn scaled_diff_sumsq_serial(mut acc: f64, y: &[f64], t: &[f64], lam: f64) -> f64 {
    debug_assert_eq!(y.len(), t.len());
    for (&yi, &ti) in y.iter().zip(t) {
        let d = yi / lam - ti;
        acc += d * d;
    }
    acc
}

// ---------------------------------------------------------------------------
// scalar reference (the contract's defining implementation)
// ---------------------------------------------------------------------------

/// Portable reference implementation of every kernel — the definition of
/// the accumulation contract. Always compiled; the SIMD backends are
/// verified bit-identical against it (`rust/tests/simd_kernels.rs`).
pub mod scalar {
    use super::{fold_blocks, ACC_LANES};

    /// One-block mixed dot under the contract (lanes + tree + tail).
    #[inline]
    pub fn dot_mixed_block(a: &[f32], b: &[f64]) -> f64 {
        let n = a.len();
        let chunks = n / ACC_LANES;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        let (mut s4, mut s5, mut s6, mut s7) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for c in 0..chunks {
            let j = c * ACC_LANES;
            s0 += a[j] as f64 * b[j];
            s1 += a[j + 1] as f64 * b[j + 1];
            s2 += a[j + 2] as f64 * b[j + 2];
            s3 += a[j + 3] as f64 * b[j + 3];
            s4 += a[j + 4] as f64 * b[j + 4];
            s5 += a[j + 5] as f64 * b[j + 5];
            s6 += a[j + 6] as f64 * b[j + 6];
            s7 += a[j + 7] as f64 * b[j + 7];
        }
        let mut acc = ((s0 + s1) + (s2 + s3)) + ((s4 + s5) + (s6 + s7));
        for i in chunks * ACC_LANES..n {
            acc += a[i] as f64 * b[i];
        }
        acc
    }

    /// One-block f32×f32 dot (f64 accumulation) under the contract.
    #[inline]
    pub fn dot_f32_block(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len();
        let chunks = n / ACC_LANES;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        let (mut s4, mut s5, mut s6, mut s7) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for c in 0..chunks {
            let j = c * ACC_LANES;
            s0 += a[j] as f64 * b[j] as f64;
            s1 += a[j + 1] as f64 * b[j + 1] as f64;
            s2 += a[j + 2] as f64 * b[j + 2] as f64;
            s3 += a[j + 3] as f64 * b[j + 3] as f64;
            s4 += a[j + 4] as f64 * b[j + 4] as f64;
            s5 += a[j + 5] as f64 * b[j + 5] as f64;
            s6 += a[j + 6] as f64 * b[j + 6] as f64;
            s7 += a[j + 7] as f64 * b[j + 7] as f64;
        }
        let mut acc = ((s0 + s1) + (s2 + s3)) + ((s4 + s5) + (s6 + s7));
        for i in chunks * ACC_LANES..n {
            acc += a[i] as f64 * b[i] as f64;
        }
        acc
    }

    /// One-block f64×f64 dot under the contract.
    #[inline]
    pub fn dot_f64_block(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let chunks = n / ACC_LANES;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        let (mut s4, mut s5, mut s6, mut s7) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for c in 0..chunks {
            let j = c * ACC_LANES;
            s0 += a[j] * b[j];
            s1 += a[j + 1] * b[j + 1];
            s2 += a[j + 2] * b[j + 2];
            s3 += a[j + 3] * b[j + 3];
            s4 += a[j + 4] * b[j + 4];
            s5 += a[j + 5] * b[j + 5];
            s6 += a[j + 6] * b[j + 6];
            s7 += a[j + 7] * b[j + 7];
        }
        let mut acc = ((s0 + s1) + (s2 + s3)) + ((s4 + s5) + (s6 + s7));
        for i in chunks * ACC_LANES..n {
            acc += a[i] * b[i];
        }
        acc
    }

    /// One-block sparse mixed dot (lanes run over stored entries).
    #[inline]
    pub fn sp_dot_mixed_block(indices: &[u32], values: &[f32], v: &[f64]) -> f64 {
        let k = values.len();
        let chunks = k / ACC_LANES;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        let (mut s4, mut s5, mut s6, mut s7) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for c in 0..chunks {
            let j = c * ACC_LANES;
            s0 += values[j] as f64 * v[indices[j] as usize];
            s1 += values[j + 1] as f64 * v[indices[j + 1] as usize];
            s2 += values[j + 2] as f64 * v[indices[j + 2] as usize];
            s3 += values[j + 3] as f64 * v[indices[j + 3] as usize];
            s4 += values[j + 4] as f64 * v[indices[j + 4] as usize];
            s5 += values[j + 5] as f64 * v[indices[j + 5] as usize];
            s6 += values[j + 6] as f64 * v[indices[j + 6] as usize];
            s7 += values[j + 7] as f64 * v[indices[j + 7] as usize];
        }
        let mut acc = ((s0 + s1) + (s2 + s3)) + ((s4 + s5) + (s6 + s7));
        for j in chunks * ACC_LANES..k {
            acc += values[j] as f64 * v[indices[j] as usize];
        }
        acc
    }

    /// One-block sparse dot against a dense f32 vector.
    #[inline]
    pub fn sp_dot_f32_block(indices: &[u32], values: &[f32], v: &[f32]) -> f64 {
        let k = values.len();
        let chunks = k / ACC_LANES;
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        let (mut s4, mut s5, mut s6, mut s7) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for c in 0..chunks {
            let j = c * ACC_LANES;
            s0 += values[j] as f64 * v[indices[j] as usize] as f64;
            s1 += values[j + 1] as f64 * v[indices[j + 1] as usize] as f64;
            s2 += values[j + 2] as f64 * v[indices[j + 2] as usize] as f64;
            s3 += values[j + 3] as f64 * v[indices[j + 3] as usize] as f64;
            s4 += values[j + 4] as f64 * v[indices[j + 4] as usize] as f64;
            s5 += values[j + 5] as f64 * v[indices[j + 5] as usize] as f64;
            s6 += values[j + 6] as f64 * v[indices[j + 6] as usize] as f64;
            s7 += values[j + 7] as f64 * v[indices[j + 7] as usize] as f64;
        }
        let mut acc = ((s0 + s1) + (s2 + s3)) + ((s4 + s5) + (s6 + s7));
        for j in chunks * ACC_LANES..k {
            acc += values[j] as f64 * v[indices[j] as usize] as f64;
        }
        acc
    }

    /// Full blocked mixed dot (reference composite of the block kernel).
    #[inline]
    pub fn dot_mixed(a: &[f32], b: &[f64]) -> f64 {
        fold_blocks(a.len(), |lo, hi| dot_mixed_block(&a[lo..hi], &b[lo..hi]))
    }

    /// Full blocked f32×f32 dot.
    #[inline]
    pub fn dot_f32_f64(a: &[f32], b: &[f32]) -> f64 {
        fold_blocks(a.len(), |lo, hi| dot_f32_block(&a[lo..hi], &b[lo..hi]))
    }

    /// Full blocked f64×f64 dot.
    #[inline]
    pub fn dot_f64(a: &[f64], b: &[f64]) -> f64 {
        fold_blocks(a.len(), |lo, hi| dot_f64_block(&a[lo..hi], &b[lo..hi]))
    }

    /// Full blocked sparse mixed dot.
    #[inline]
    pub fn sp_dot_mixed(indices: &[u32], values: &[f32], v: &[f64]) -> f64 {
        fold_blocks(values.len(), |lo, hi| {
            sp_dot_mixed_block(&indices[lo..hi], &values[lo..hi], v)
        })
    }

    /// Full blocked sparse f32 dot.
    #[inline]
    pub fn sp_dot_f32_f64(indices: &[u32], values: &[f32], v: &[f32]) -> f64 {
        fold_blocks(values.len(), |lo, hi| {
            sp_dot_f32_block(&indices[lo..hi], &values[lo..hi], v)
        })
    }

    /// `y += alpha * x` (elementwise: mul rounds, then add).
    #[inline]
    pub fn axpy_f64(alpha: f64, x: &[f32], y: &mut [f64]) {
        if alpha == 0.0 {
            return;
        }
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * *xi as f64;
        }
    }

    /// `out = a + s * b` elementwise.
    #[inline]
    pub fn scale_add(a: &[f64], s: f64, b: &[f64], out: &mut [f64]) {
        for i in 0..a.len() {
            out[i] = a[i] + s * b[i];
        }
    }

    /// Sparse scatter `y[indices[k]] += alpha * values[k]`.
    #[inline]
    pub fn sp_axpy_f64(alpha: f64, indices: &[u32], values: &[f32], y: &mut [f64]) {
        if alpha == 0.0 {
            return;
        }
        for (i, v) in indices.iter().zip(values) {
            y[*i as usize] += alpha * *v as f64;
        }
    }
}

// ---------------------------------------------------------------------------
// x86-64 AVX2
// ---------------------------------------------------------------------------

/// AVX2 kernels. Each `__m256d` accumulator holds four of the contract's
/// eight lanes (`acc_lo` = s0..s3, `acc_hi` = s4..s7); `mul_pd` +
/// `add_pd` per chunk performs exactly the scalar `s_k += a·b` (no FMA),
/// and the reduction stores the lanes out and applies the scalar tree.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use core::arch::x86_64::*;

    /// Extract the eight lanes and reduce with the contract's tree.
    ///
    /// # Safety
    /// AVX2 must be available (every caller is
    /// `#[target_feature(enable = "avx2")]`).
    #[inline]
    unsafe fn reduce8(lo: __m256d, hi: __m256d) -> f64 {
        let mut s = [0.0f64; 8];
        // SAFETY: `s` is an 8-slot local; the two unaligned stores write
        // slots 0..4 and 4..8, entirely inside it.
        unsafe {
            _mm256_storeu_pd(s.as_mut_ptr(), lo);
            _mm256_storeu_pd(s.as_mut_ptr().add(4), hi);
        }
        ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]))
    }

    /// Widen 8 f32 lanes to two f64 quads (a[j..j+4], a[j+4..j+8]).
    ///
    /// # Safety
    /// `p` must be valid for reading 8 consecutive f32s, and AVX2 must
    /// be available.
    #[inline]
    unsafe fn widen8(p: *const f32) -> (__m256d, __m256d) {
        // SAFETY: caller guarantees 8 readable f32s at `p` (loadu has no
        // alignment requirement); the converts are register-only.
        unsafe {
            let v = _mm256_loadu_ps(p);
            let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(v));
            let hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(v));
            (lo, hi)
        }
    }

    /// # Safety
    /// AVX2 must be available — the dispatcher calls this only after
    /// `active_isa() == Isa::Avx2`. `a` and `b` must be equal length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_mixed_block(a: &[f32], b: &[f64]) -> f64 {
        let n = a.len();
        let chunks = n / 8;
        // SAFETY: chunk c reads elements j..j+8 with j = c*8 and
        // c*8 + 8 <= n, so every load stays inside the borrowed slices;
        // the tail uses checked indexing.
        unsafe {
            let mut acc_lo = _mm256_setzero_pd();
            let mut acc_hi = _mm256_setzero_pd();
            for c in 0..chunks {
                let j = c * 8;
                let (alo, ahi) = widen8(a.as_ptr().add(j));
                let blo = _mm256_loadu_pd(b.as_ptr().add(j));
                let bhi = _mm256_loadu_pd(b.as_ptr().add(j + 4));
                acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(alo, blo));
                acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(ahi, bhi));
            }
            let mut acc = reduce8(acc_lo, acc_hi);
            for i in chunks * 8..n {
                acc += a[i] as f64 * b[i];
            }
            acc
        }
    }

    /// # Safety
    /// AVX2 must be available; `a` and `b` must be equal length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f32_block(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len();
        let chunks = n / 8;
        // SAFETY: chunk c reads elements j..j+8, j = c*8, c*8 + 8 <= n —
        // inside both slices; tail is checked indexing.
        unsafe {
            let mut acc_lo = _mm256_setzero_pd();
            let mut acc_hi = _mm256_setzero_pd();
            for c in 0..chunks {
                let j = c * 8;
                let (alo, ahi) = widen8(a.as_ptr().add(j));
                let (blo, bhi) = widen8(b.as_ptr().add(j));
                acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(alo, blo));
                acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(ahi, bhi));
            }
            let mut acc = reduce8(acc_lo, acc_hi);
            for i in chunks * 8..n {
                acc += a[i] as f64 * b[i] as f64;
            }
            acc
        }
    }

    /// # Safety
    /// AVX2 must be available; `a` and `b` must be equal length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f64_block(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let chunks = n / 8;
        // SAFETY: chunk c reads elements j..j+8, j = c*8, c*8 + 8 <= n —
        // inside both slices; tail is checked indexing.
        unsafe {
            let mut acc_lo = _mm256_setzero_pd();
            let mut acc_hi = _mm256_setzero_pd();
            for c in 0..chunks {
                let j = c * 8;
                let alo = _mm256_loadu_pd(a.as_ptr().add(j));
                let ahi = _mm256_loadu_pd(a.as_ptr().add(j + 4));
                let blo = _mm256_loadu_pd(b.as_ptr().add(j));
                let bhi = _mm256_loadu_pd(b.as_ptr().add(j + 4));
                acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(alo, blo));
                acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(ahi, bhi));
            }
            let mut acc = reduce8(acc_lo, acc_hi);
            for i in chunks * 8..n {
                acc += a[i] * b[i];
            }
            acc
        }
    }

    /// Sparse mixed dot via `vgatherdpd`. Every chunk's indices are
    /// range-checked before the gather (the scalar path would panic on
    /// the same out-of-range access, so behavior matches).
    ///
    /// # Safety
    /// AVX2 must be available, and `v.len() <= i32::MAX` (gather offsets
    /// are signed 32-bit — the dispatcher checks both).
    #[target_feature(enable = "avx2")]
    pub unsafe fn sp_dot_mixed_block(indices: &[u32], values: &[f32], v: &[f64]) -> f64 {
        let k = values.len();
        let n = v.len();
        let chunks = k / 8;
        // SAFETY: chunk c reads indices/values j..j+8 with j = c*8 and
        // c*8 + 8 <= k; the gathers only touch v[idx] for indices the
        // assert just bounded below n (caller bounds n itself by
        // i32::MAX, so the 32-bit offsets cannot wrap).
        unsafe {
            let mut acc_lo = _mm256_setzero_pd();
            let mut acc_hi = _mm256_setzero_pd();
            for c in 0..chunks {
                let j = c * 8;
                let mut mx = 0u32;
                for t in 0..8 {
                    mx = mx.max(indices[j + t]);
                }
                assert!((mx as usize) < n, "sparse row index {mx} out of range (n = {n})");
                let idx_lo = _mm_loadu_si128(indices.as_ptr().add(j) as *const __m128i);
                let idx_hi = _mm_loadu_si128(indices.as_ptr().add(j + 4) as *const __m128i);
                let vlo = _mm256_i32gather_pd::<8>(v.as_ptr(), idx_lo);
                let vhi = _mm256_i32gather_pd::<8>(v.as_ptr(), idx_hi);
                let wv = _mm256_loadu_ps(values.as_ptr().add(j));
                let wlo = _mm256_cvtps_pd(_mm256_castps256_ps128(wv));
                let whi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(wv));
                acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(wlo, vlo));
                acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(whi, vhi));
            }
            let mut acc = reduce8(acc_lo, acc_hi);
            for j in chunks * 8..k {
                acc += values[j] as f64 * v[indices[j] as usize];
            }
            acc
        }
    }

    /// Sparse f32 dot via `vgatherdps`; same guard policy as
    /// [`sp_dot_mixed_block`].
    ///
    /// # Safety
    /// AVX2 must be available, and `v.len() <= i32::MAX`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sp_dot_f32_block(indices: &[u32], values: &[f32], v: &[f32]) -> f64 {
        let k = values.len();
        let n = v.len();
        let chunks = k / 8;
        // SAFETY: same argument as sp_dot_mixed_block — chunked reads
        // stay inside indices/values, gathers are asserted below n.
        unsafe {
            let mut acc_lo = _mm256_setzero_pd();
            let mut acc_hi = _mm256_setzero_pd();
            for c in 0..chunks {
                let j = c * 8;
                let mut mx = 0u32;
                for t in 0..8 {
                    mx = mx.max(indices[j + t]);
                }
                assert!((mx as usize) < n, "sparse row index {mx} out of range (n = {n})");
                let idx = _mm256_loadu_si256(indices.as_ptr().add(j) as *const __m256i);
                let g = _mm256_i32gather_ps::<4>(v.as_ptr(), idx);
                let vlo = _mm256_cvtps_pd(_mm256_castps256_ps128(g));
                let vhi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(g));
                let wv = _mm256_loadu_ps(values.as_ptr().add(j));
                let wlo = _mm256_cvtps_pd(_mm256_castps256_ps128(wv));
                let whi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(wv));
                acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(wlo, vlo));
                acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(whi, vhi));
            }
            let mut acc = reduce8(acc_lo, acc_hi);
            for j in chunks * 8..k {
                acc += values[j] as f64 * v[indices[j] as usize] as f64;
            }
            acc
        }
    }

    /// # Safety
    /// AVX2 must be available; `x` and `y` must be equal length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_f64(alpha: f64, x: &[f32], y: &mut [f64]) {
        let n = x.len();
        let chunks = n / 8;
        // SAFETY: chunk c touches x[j..j+8] and y[j..j+8] with j = c*8
        // and c*8 + 8 <= n; loads and stores on y never overlap between
        // chunks, and `y` is exclusively borrowed.
        unsafe {
            let va = _mm256_set1_pd(alpha);
            for c in 0..chunks {
                let j = c * 8;
                let (xlo, xhi) = widen8(x.as_ptr().add(j));
                let ylo = _mm256_loadu_pd(y.as_ptr().add(j));
                let yhi = _mm256_loadu_pd(y.as_ptr().add(j + 4));
                _mm256_storeu_pd(
                    y.as_mut_ptr().add(j),
                    _mm256_add_pd(ylo, _mm256_mul_pd(va, xlo)),
                );
                _mm256_storeu_pd(
                    y.as_mut_ptr().add(j + 4),
                    _mm256_add_pd(yhi, _mm256_mul_pd(va, xhi)),
                );
            }
        }
        for i in chunks * 8..n {
            y[i] += alpha * x[i] as f64;
        }
    }

    /// # Safety
    /// AVX2 must be available; `a`, `b`, and `out` must be equal length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_add(a: &[f64], s: f64, b: &[f64], out: &mut [f64]) {
        let n = a.len();
        let chunks = n / 4;
        // SAFETY: chunk c touches elements j..j+4 with j = c*4 and
        // c*4 + 4 <= n — inside all three slices; `out` is exclusively
        // borrowed.
        unsafe {
            let vs = _mm256_set1_pd(s);
            for c in 0..chunks {
                let j = c * 4;
                let av = _mm256_loadu_pd(a.as_ptr().add(j));
                let bv = _mm256_loadu_pd(b.as_ptr().add(j));
                _mm256_storeu_pd(out.as_mut_ptr().add(j), _mm256_add_pd(av, _mm256_mul_pd(vs, bv)));
            }
        }
        for i in chunks * 4..n {
            out[i] = a[i] + s * b[i];
        }
    }
}

// ---------------------------------------------------------------------------
// aarch64 NEON
// ---------------------------------------------------------------------------

/// NEON kernels. Four `float64x2_t` accumulators hold the contract's
/// eight lanes pairwise (`s01` = s0,s1 … `s67` = s6,s7); `vmulq` +
/// `vaddq` per chunk matches the scalar `s_k += a·b` (no `vfmaq` — FMA
/// would skip the product rounding the contract requires). NEON has no
/// gather, so the sparse dots stay on the scalar path.
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod neon {
    use core::arch::aarch64::*;

    /// Reduce the four lane pairs with the contract's tree.
    ///
    /// # Safety
    /// NEON must be available (baseline on aarch64; every caller is
    /// `#[target_feature(enable = "neon")]`).
    #[inline]
    unsafe fn reduce8(
        s01: float64x2_t,
        s23: float64x2_t,
        s45: float64x2_t,
        s67: float64x2_t,
    ) -> f64 {
        // SAFETY: register-only lane extracts; no memory is touched.
        unsafe {
            let p0 = vgetq_lane_f64::<0>(s01) + vgetq_lane_f64::<1>(s01);
            let p1 = vgetq_lane_f64::<0>(s23) + vgetq_lane_f64::<1>(s23);
            let p2 = vgetq_lane_f64::<0>(s45) + vgetq_lane_f64::<1>(s45);
            let p3 = vgetq_lane_f64::<0>(s67) + vgetq_lane_f64::<1>(s67);
            (p0 + p1) + (p2 + p3)
        }
    }

    /// Widen 8 f32 lanes to four f64 pairs.
    ///
    /// # Safety
    /// `p` must be valid for reading 8 consecutive f32s, and NEON must
    /// be available.
    #[inline]
    unsafe fn widen8(p: *const f32) -> (float64x2_t, float64x2_t, float64x2_t, float64x2_t) {
        // SAFETY: caller guarantees 8 readable f32s at `p`; the converts
        // are register-only.
        unsafe {
            let lo4 = vld1q_f32(p);
            let hi4 = vld1q_f32(p.add(4));
            (
                vcvt_f64_f32(vget_low_f32(lo4)),
                vcvt_high_f64_f32(lo4),
                vcvt_f64_f32(vget_low_f32(hi4)),
                vcvt_high_f64_f32(hi4),
            )
        }
    }

    /// # Safety
    /// NEON must be available; `a` and `b` must be equal length.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_mixed_block(a: &[f32], b: &[f64]) -> f64 {
        let n = a.len();
        let chunks = n / 8;
        // SAFETY: chunk c reads elements j..j+8 with j = c*8 and
        // c*8 + 8 <= n — inside both slices; tail is checked indexing.
        unsafe {
            let mut s01 = vdupq_n_f64(0.0);
            let mut s23 = vdupq_n_f64(0.0);
            let mut s45 = vdupq_n_f64(0.0);
            let mut s67 = vdupq_n_f64(0.0);
            for c in 0..chunks {
                let j = c * 8;
                let (a01, a23, a45, a67) = widen8(a.as_ptr().add(j));
                s01 = vaddq_f64(s01, vmulq_f64(a01, vld1q_f64(b.as_ptr().add(j))));
                s23 = vaddq_f64(s23, vmulq_f64(a23, vld1q_f64(b.as_ptr().add(j + 2))));
                s45 = vaddq_f64(s45, vmulq_f64(a45, vld1q_f64(b.as_ptr().add(j + 4))));
                s67 = vaddq_f64(s67, vmulq_f64(a67, vld1q_f64(b.as_ptr().add(j + 6))));
            }
            let mut acc = reduce8(s01, s23, s45, s67);
            for i in chunks * 8..n {
                acc += a[i] as f64 * b[i];
            }
            acc
        }
    }

    /// # Safety
    /// NEON must be available; `a` and `b` must be equal length.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_f32_block(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len();
        let chunks = n / 8;
        // SAFETY: chunk c reads elements j..j+8, j = c*8, c*8 + 8 <= n —
        // inside both slices; tail is checked indexing.
        unsafe {
            let mut s01 = vdupq_n_f64(0.0);
            let mut s23 = vdupq_n_f64(0.0);
            let mut s45 = vdupq_n_f64(0.0);
            let mut s67 = vdupq_n_f64(0.0);
            for c in 0..chunks {
                let j = c * 8;
                let (a01, a23, a45, a67) = widen8(a.as_ptr().add(j));
                let (b01, b23, b45, b67) = widen8(b.as_ptr().add(j));
                s01 = vaddq_f64(s01, vmulq_f64(a01, b01));
                s23 = vaddq_f64(s23, vmulq_f64(a23, b23));
                s45 = vaddq_f64(s45, vmulq_f64(a45, b45));
                s67 = vaddq_f64(s67, vmulq_f64(a67, b67));
            }
            let mut acc = reduce8(s01, s23, s45, s67);
            for i in chunks * 8..n {
                acc += a[i] as f64 * b[i] as f64;
            }
            acc
        }
    }

    /// # Safety
    /// NEON must be available; `a` and `b` must be equal length.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_f64_block(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let chunks = n / 8;
        // SAFETY: chunk c reads elements j..j+8, j = c*8, c*8 + 8 <= n —
        // inside both slices; tail is checked indexing.
        unsafe {
            let mut s01 = vdupq_n_f64(0.0);
            let mut s23 = vdupq_n_f64(0.0);
            let mut s45 = vdupq_n_f64(0.0);
            let mut s67 = vdupq_n_f64(0.0);
            for c in 0..chunks {
                let j = c * 8;
                let m0 = vmulq_f64(vld1q_f64(a.as_ptr().add(j)), vld1q_f64(b.as_ptr().add(j)));
                let m1 =
                    vmulq_f64(vld1q_f64(a.as_ptr().add(j + 2)), vld1q_f64(b.as_ptr().add(j + 2)));
                let m2 =
                    vmulq_f64(vld1q_f64(a.as_ptr().add(j + 4)), vld1q_f64(b.as_ptr().add(j + 4)));
                let m3 =
                    vmulq_f64(vld1q_f64(a.as_ptr().add(j + 6)), vld1q_f64(b.as_ptr().add(j + 6)));
                s01 = vaddq_f64(s01, m0);
                s23 = vaddq_f64(s23, m1);
                s45 = vaddq_f64(s45, m2);
                s67 = vaddq_f64(s67, m3);
            }
            let mut acc = reduce8(s01, s23, s45, s67);
            for i in chunks * 8..n {
                acc += a[i] * b[i];
            }
            acc
        }
    }

    /// # Safety
    /// NEON must be available; `x` and `y` must be equal length.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_f64(alpha: f64, x: &[f32], y: &mut [f64]) {
        let n = x.len();
        let chunks = n / 8;
        // SAFETY: chunk c touches x[j..j+8] and y[j..j+8] with j = c*8
        // and c*8 + 8 <= n; `y` is exclusively borrowed and chunks never
        // overlap.
        unsafe {
            let va = vdupq_n_f64(alpha);
            for c in 0..chunks {
                let j = c * 8;
                let (x01, x23, x45, x67) = widen8(x.as_ptr().add(j));
                let p = y.as_mut_ptr();
                vst1q_f64(p.add(j), vaddq_f64(vld1q_f64(p.add(j)), vmulq_f64(va, x01)));
                vst1q_f64(p.add(j + 2), vaddq_f64(vld1q_f64(p.add(j + 2)), vmulq_f64(va, x23)));
                vst1q_f64(p.add(j + 4), vaddq_f64(vld1q_f64(p.add(j + 4)), vmulq_f64(va, x45)));
                vst1q_f64(p.add(j + 6), vaddq_f64(vld1q_f64(p.add(j + 6)), vmulq_f64(va, x67)));
            }
        }
        for i in chunks * 8..n {
            y[i] += alpha * x[i] as f64;
        }
    }

    /// # Safety
    /// NEON must be available; `a`, `b`, and `out` must be equal length.
    #[target_feature(enable = "neon")]
    pub unsafe fn scale_add(a: &[f64], s: f64, b: &[f64], out: &mut [f64]) {
        let n = a.len();
        let chunks = n / 2;
        // SAFETY: chunk c touches elements j..j+2 with j = c*2 and
        // c*2 + 2 <= n — inside all three slices; `out` is exclusively
        // borrowed.
        unsafe {
            let vs = vdupq_n_f64(s);
            for c in 0..chunks {
                let j = c * 2;
                let av = vld1q_f64(a.as_ptr().add(j));
                let bv = vld1q_f64(b.as_ptr().add(j));
                vst1q_f64(out.as_mut_ptr().add(j), vaddq_f64(av, vmulq_f64(vs, bv)));
            }
        }
        if n % 2 == 1 {
            out[n - 1] = a[n - 1] + s * b[n - 1];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn data(n: usize, seed: u64) -> (Vec<f32>, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        (a, b)
    }

    #[test]
    fn dispatch_matches_scalar_bitwise() {
        for n in [0usize, 7, 8, 17, ACC_BLOCK, ACC_BLOCK + 3, 3 * ACC_BLOCK + 5] {
            let (a, b) = data(n, 42 + n as u64);
            let a32: Vec<f32> = b.iter().map(|&v| v as f32).collect();
            assert_eq!(dot_mixed(&a, &b).to_bits(), scalar::dot_mixed(&a, &b).to_bits());
            assert_eq!(
                dot_f32_f64(&a, &a32).to_bits(),
                scalar::dot_f32_f64(&a, &a32).to_bits()
            );
            assert_eq!(dot_f64(&b, &b).to_bits(), scalar::dot_f64(&b, &b).to_bits());
        }
    }

    #[test]
    fn force_scalar_pins_backend() {
        force_scalar(true);
        assert_eq!(active_isa(), Isa::Scalar);
        assert_eq!(active_backend(), "scalar");
        force_scalar(false);
        // whatever the platform offers, the report string is well-formed
        assert!(["scalar", "avx2", "neon"].contains(&active_backend()));
    }

    #[test]
    fn blocked_fold_starts_at_zero() {
        // empty inputs reduce to the fold's 0.0 seed on every backend
        assert_eq!(dot_mixed(&[], &[]).to_bits(), 0.0f64.to_bits());
        assert_eq!(dot_f64(&[], &[]).to_bits(), 0.0f64.to_bits());
    }
}
