//! Pinned-block LRU cache — the resident-set policy of the out-of-core
//! sharded backend (DESIGN.md §10).
//!
//! The sharded store keeps column blocks on disk and faults them into RAM
//! on demand. This cache bounds the resident bytes: blocks are handed out
//! as [`std::sync::Arc`] handles, and a block is **pinned** exactly while a
//! handle other than the cache's own is alive (`Arc::strong_count > 1`).
//! Eviction walks blocks in least-recently-used order and skips pinned
//! ones, so a block can never be freed under a live reader — the safety
//! property that lets screen-before-load sweeps borrow [`super::ColRef`]
//! views into a block without copying it first.
//!
//! When every block over budget is pinned the cache runs over budget
//! rather than failing: correctness first, the budget is a target. The
//! streaming sweeps in `ops` keep at most one block *pinned* at a time;
//! with the prefetch pipeline (DESIGN.md §11) the working set is that
//! pinned block plus the warm (unpinned) next block, so the intended-use
//! overshoot is at most two blocks.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

struct Entry<B> {
    block: Arc<B>,
    bytes: usize,
    /// logical clock of the last access (monotone; larger = more recent)
    stamp: u64,
}

struct Inner<B> {
    entries: HashMap<usize, Entry<B>>,
    clock: u64,
    resident_bytes: usize,
}

/// A byte-budgeted LRU over numbered blocks, safe for shared (`&self`)
/// use across threads. See the module docs for the pinning semantics.
pub struct BlockCache<B> {
    inner: Mutex<Inner<B>>,
    budget_bytes: usize,
}

impl<B> BlockCache<B> {
    /// Create a cache targeting at most `budget_bytes` resident bytes
    /// (pinned blocks may push it over — module docs).
    pub fn new(budget_bytes: usize) -> Self {
        BlockCache {
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                clock: 0,
                resident_bytes: 0,
            }),
            budget_bytes,
        }
    }

    /// Fetch block `id`, calling `load` on a miss. `load` returns the
    /// block plus its resident size in bytes. The lock is not held during
    /// `load`, so two threads racing on the same missing id may both load
    /// it; the later insert wins and both handles stay valid — wasted
    /// work, never wrong data.
    pub fn get_or_load(
        &self,
        id: usize,
        load: impl FnOnce() -> anyhow::Result<(B, usize)>,
    ) -> anyhow::Result<Arc<B>> {
        {
            let mut inner = self.inner.lock().unwrap();
            inner.clock += 1;
            let clock = inner.clock;
            if let Some(e) = inner.entries.get_mut(&id) {
                e.stamp = clock;
                return Ok(Arc::clone(&e.block));
            }
        }
        let (block, bytes) = load()?;
        let block = Arc::new(block);
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let stamp = inner.clock;
        if let Some(old) = inner
            .entries
            .insert(id, Entry { block: Arc::clone(&block), bytes, stamp })
        {
            inner.resident_bytes -= old.bytes;
        }
        inner.resident_bytes += bytes;
        Self::evict_over_budget(&mut inner, self.budget_bytes);
        Ok(block)
    }

    /// Evict least-recently-used *unpinned* blocks until the budget holds
    /// (or nothing else is evictable).
    fn evict_over_budget(inner: &mut Inner<B>, budget: usize) {
        while inner.resident_bytes > budget {
            let victim = inner
                .entries
                .iter()
                .filter(|(_, e)| Arc::strong_count(&e.block) == 1)
                .min_by_key(|(_, e)| e.stamp)
                .map(|(&id, _)| id);
            match victim {
                Some(id) => {
                    let e = inner.entries.remove(&id).expect("victim vanished");
                    inner.resident_bytes -= e.bytes;
                }
                None => break, // everything left is pinned
            }
        }
    }

    /// Whether block `id` is currently resident (a subsequent
    /// [`BlockCache::get_or_load`] would hit). Does not bump the LRU
    /// stamp — the prefetch pipeline uses this to count hits without
    /// perturbing eviction order.
    pub fn contains(&self, id: usize) -> bool {
        self.inner.lock().unwrap().entries.contains_key(&id)
    }

    /// Bytes currently resident (cached blocks, pinned or not).
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().resident_bytes
    }

    /// Number of blocks currently resident.
    pub fn resident_blocks(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// Drop every unpinned block (pinned ones stay until their handles
    /// die and a later eviction collects them).
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        let unpinned: Vec<usize> = inner
            .entries
            .iter()
            .filter(|(_, e)| Arc::strong_count(&e.block) == 1)
            .map(|(&id, _)| id)
            .collect();
        for id in unpinned {
            let e = inner.entries.remove(&id).expect("entry vanished");
            inner.resident_bytes -= e.bytes;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load_ok(v: u64, bytes: usize) -> impl FnOnce() -> anyhow::Result<(u64, usize)> {
        move || Ok((v, bytes))
    }

    #[test]
    fn hit_returns_cached_block_without_reloading() {
        let cache: BlockCache<u64> = BlockCache::new(1000);
        let a = cache.get_or_load(0, load_ok(7, 100)).unwrap();
        let b = cache
            .get_or_load(0, || panic!("must not reload a cached block"))
            .unwrap();
        assert_eq!(*a, 7);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.resident_bytes(), 100);
    }

    #[test]
    fn evicts_lru_first_when_over_budget() {
        let cache: BlockCache<u64> = BlockCache::new(250);
        for id in 0..3 {
            cache.get_or_load(id, load_ok(id as u64, 100)).unwrap();
        }
        // budget 250 < 300: block 0 (least recent) must be gone, 1/2 stay
        assert_eq!(cache.resident_blocks(), 2);
        assert_eq!(cache.resident_bytes(), 200);
        cache.get_or_load(2, || panic!("2 must still be resident")).unwrap();
        // touch 1 (bumps its stamp), then insert 3: the LRU is now 2
        cache.get_or_load(1, || panic!("1 must still be resident")).unwrap();
        cache.get_or_load(3, load_ok(3, 100)).unwrap();
        let mut two_reloaded = false;
        cache
            .get_or_load(2, || {
                two_reloaded = true;
                Ok((2, 100))
            })
            .unwrap();
        assert!(two_reloaded, "2 should have been the LRU victim");
    }

    #[test]
    fn pinned_blocks_survive_eviction() {
        let cache: BlockCache<u64> = BlockCache::new(150);
        let pinned = cache.get_or_load(0, load_ok(0, 100)).unwrap();
        // inserting 1 pushes resident to 200 > 150, but 0 is pinned: the
        // cache overshoots instead of freeing it
        cache.get_or_load(1, load_ok(1, 100)).unwrap();
        assert_eq!(*pinned, 0);
        cache.get_or_load(0, || panic!("pinned block was evicted")).unwrap();
        drop(pinned);
        // once unpinned, the next insert can finally evict it
        cache.get_or_load(2, load_ok(2, 100)).unwrap();
        assert!(cache.resident_bytes() <= 150 + 100);
    }

    #[test]
    fn clear_drops_unpinned_only() {
        let cache: BlockCache<u64> = BlockCache::new(1000);
        let hold = cache.get_or_load(0, load_ok(0, 10)).unwrap();
        cache.get_or_load(1, load_ok(1, 10)).unwrap();
        cache.clear();
        assert_eq!(cache.resident_blocks(), 1);
        assert_eq!(*hold, 0);
    }

    #[test]
    fn contains_probes_without_reload_or_lru_bump() {
        let cache: BlockCache<u64> = BlockCache::new(250);
        cache.get_or_load(0, load_ok(0, 100)).unwrap();
        cache.get_or_load(1, load_ok(1, 100)).unwrap();
        assert!(cache.contains(0) && cache.contains(1) && !cache.contains(2));
        // probing 0 must NOT make it recently-used: inserting 2 (over
        // budget) still evicts 0, the true LRU
        assert!(cache.contains(0));
        cache.get_or_load(2, load_ok(2, 100)).unwrap();
        assert!(!cache.contains(0), "contains() bumped the LRU stamp");
        assert!(cache.contains(1) && cache.contains(2));
    }

    #[test]
    fn load_errors_propagate_and_cache_stays_clean() {
        let cache: BlockCache<u64> = BlockCache::new(1000);
        let err = cache.get_or_load(5, || anyhow::bail!("disk on fire"));
        assert!(err.is_err());
        assert_eq!(cache.resident_blocks(), 0);
        cache.get_or_load(5, load_ok(5, 10)).unwrap();
        assert_eq!(cache.resident_blocks(), 1);
    }
}
