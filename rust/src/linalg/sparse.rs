//! CSC (compressed sparse column) storage + the sparse twins of the dense
//! hot kernels (see DESIGN.md §6).
//!
//! Layout: per-column contiguous `(indices, values)` runs delimited by
//! `col_ptr`, exactly mirroring the dense feature-major layout's "column l
//! is one contiguous scan" property — the screening sweep and the forward
//! product stay unit-stride over the *stored* entries and skip zeros
//! entirely.
//!
//! Precision/parity policy: every kernel follows the bit-pinned
//! accumulation contract of [`super::simd`] (eight interleaved f64
//! accumulators per `ACC_BLOCK` run, fixed tree reduction — DESIGN.md
//! §12), blocked over *stored* entries. A CSC matrix that stores all `n`
//! entries of a column (indices `0..n`) therefore produces
//! **bit-identical** results to the dense kernel on that column — the
//! property the dense/CSC parity suite in `rust/tests/prop_invariants.rs`
//! leans on. On AVX2 the dots use hardware gathers over the index runs;
//! NEON has no gather, so sparse dots take the scalar contract path.

use anyhow::{ensure, Result};

/// A sparse `n x d` matrix in CSC form: column `l`'s nonzeros are
/// `values[col_ptr[l]..col_ptr[l+1]]` at row positions
/// `indices[col_ptr[l]..col_ptr[l+1]]` (strictly increasing).
#[derive(Debug, Clone, PartialEq)]
pub struct CscMatrix {
    /// rows (samples)
    pub n: usize,
    /// columns (features)
    pub d: usize,
    /// length d+1, nondecreasing, `col_ptr[0] == 0`
    pub col_ptr: Vec<usize>,
    /// row index per stored entry (u32: n is capped at 2^32 samples)
    pub indices: Vec<u32>,
    /// stored entry values
    pub values: Vec<f32>,
}

impl CscMatrix {
    /// Build from a dense feature-major buffer, dropping exact zeros.
    pub fn from_dense(data: &[f32], n: usize, d: usize) -> CscMatrix {
        assert_eq!(data.len(), n * d, "dense buffer size mismatch");
        assert!(n <= u32::MAX as usize, "row count exceeds u32 index space");
        let mut col_ptr = Vec::with_capacity(d + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        col_ptr.push(0);
        for l in 0..d {
            let col = &data[l * n..(l + 1) * n];
            for (i, &v) in col.iter().enumerate() {
                if v != 0.0 {
                    indices.push(i as u32);
                    values.push(v);
                }
            }
            col_ptr.push(indices.len());
        }
        CscMatrix { n, d, col_ptr, indices, values }
    }

    /// Build from per-column `(row, value)` lists. Rows within a column
    /// need not be sorted; they are sorted here. Duplicate row entries
    /// within a column are coalesced by summing (the COO convention), so
    /// the strictly-increasing index invariant `validate()` checks holds
    /// by construction. Exact zeros — input or post-coalescing — are
    /// dropped.
    pub fn from_cols(n: usize, mut cols: Vec<Vec<(u32, f32)>>) -> CscMatrix {
        assert!(n <= u32::MAX as usize, "row count exceeds u32 index space");
        let d = cols.len();
        let nnz: usize = cols.iter().map(|c| c.len()).sum();
        let mut col_ptr = Vec::with_capacity(d + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        col_ptr.push(0);
        for col in cols.iter_mut() {
            col.sort_unstable_by_key(|e| e.0);
            let mut k = 0usize;
            while k < col.len() {
                let row = col[k].0;
                debug_assert!((row as usize) < n, "row index {row} out of range");
                let mut sum = 0.0f32;
                while k < col.len() && col[k].0 == row {
                    sum += col[k].1;
                    k += 1;
                }
                if sum != 0.0 {
                    indices.push(row);
                    values.push(sum);
                }
            }
            col_ptr.push(indices.len());
        }
        CscMatrix { n, d, col_ptr, indices, values }
    }

    /// Densify into the feature-major layout.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n * self.d];
        for l in 0..self.d {
            let (idx, vals) = self.col(l);
            for (i, v) in idx.iter().zip(vals) {
                out[l * self.n + *i as usize] = *v;
            }
        }
        out
    }

    /// Column `l` as `(row indices, values)`.
    #[inline]
    pub fn col(&self, l: usize) -> (&[u32], &[f32]) {
        debug_assert!(l < self.d);
        let (lo, hi) = (self.col_ptr[l], self.col_ptr[l + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Stored entry count.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Stored-entry fraction (1.0 for a full matrix; 0 for empty shapes).
    pub fn density(&self) -> f64 {
        let cells = self.n * self.d;
        if cells == 0 {
            0.0
        } else {
            self.nnz() as f64 / cells as f64
        }
    }

    /// Heap footprint of the three buffers, in bytes.
    pub fn mem_bytes(&self) -> usize {
        self.col_ptr.len() * std::mem::size_of::<usize>()
            + self.indices.len() * 4
            + self.values.len() * 4
    }

    /// Copy the kept columns into a compacted matrix (screening's memory
    /// win on the sparse backend: pure pointer arithmetic, no densify).
    pub fn select_cols(&self, keep: &[usize]) -> CscMatrix {
        let nnz: usize = keep.iter().map(|&l| self.col_ptr[l + 1] - self.col_ptr[l]).sum();
        let mut col_ptr = Vec::with_capacity(keep.len() + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        col_ptr.push(0);
        for &l in keep {
            let (idx, vals) = self.col(l);
            indices.extend_from_slice(idx);
            values.extend_from_slice(vals);
            col_ptr.push(indices.len());
        }
        CscMatrix { n: self.n, d: keep.len(), col_ptr, indices, values }
    }

    /// Row subset: new row `j` is old row `idx[j]` (indices must be
    /// distinct and in range; the CV / stability-selection subsamplers).
    pub fn select_rows(&self, idx: &[usize]) -> CscMatrix {
        let mut map = vec![u32::MAX; self.n];
        for (j, &i) in idx.iter().enumerate() {
            debug_assert!(map[i] == u32::MAX, "duplicate row {i} in subset");
            map[i] = j as u32;
        }
        let mut col_ptr = Vec::with_capacity(self.d + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        let mut buf: Vec<(u32, f32)> = Vec::new();
        col_ptr.push(0);
        for l in 0..self.d {
            buf.clear();
            let (ix, vals) = self.col(l);
            for (i, v) in ix.iter().zip(vals) {
                let m = map[*i as usize];
                if m != u32::MAX {
                    buf.push((m, *v));
                }
            }
            buf.sort_unstable_by_key(|e| e.0);
            for &(i, v) in &buf {
                indices.push(i);
                values.push(v);
            }
            col_ptr.push(indices.len());
        }
        CscMatrix { n: idx.len(), d: self.d, col_ptr, indices, values }
    }

    /// Scale every stored value by `s`.
    pub fn scaled(&self, s: f32) -> CscMatrix {
        CscMatrix {
            values: self.values.iter().map(|&v| v * s).collect(),
            ..self.clone()
        }
    }

    /// Structural invariants (the io layer calls this after load).
    pub fn validate(&self) -> Result<()> {
        ensure!(self.n <= u32::MAX as usize, "n {} exceeds u32 index space", self.n);
        ensure!(
            self.col_ptr.len() == self.d + 1,
            "col_ptr length {} != d+1 ({})",
            self.col_ptr.len(),
            self.d + 1
        );
        ensure!(self.col_ptr[0] == 0, "col_ptr[0] != 0");
        ensure!(
            *self.col_ptr.last().unwrap() == self.values.len(),
            "col_ptr tail {} != nnz {}",
            self.col_ptr.last().unwrap(),
            self.values.len()
        );
        ensure!(
            self.indices.len() == self.values.len(),
            "indices/values length mismatch"
        );
        // bounds/monotonicity over the whole pointer array first — col()
        // slices with these values, so they must be proven in-range before
        // any per-column walk (a corrupt file must Err, not panic)
        for l in 0..self.d {
            ensure!(
                self.col_ptr[l] <= self.col_ptr[l + 1],
                "col_ptr not monotone at column {l}"
            );
            ensure!(
                self.col_ptr[l + 1] <= self.values.len(),
                "col_ptr[{}] = {} exceeds nnz {}",
                l + 1,
                self.col_ptr[l + 1],
                self.values.len()
            );
        }
        for l in 0..self.d {
            let (idx, vals) = self.col(l);
            for w in idx.windows(2) {
                ensure!(w[0] < w[1], "column {l}: row indices not strictly increasing");
            }
            for &i in idx {
                ensure!((i as usize) < self.n, "column {l}: row {i} out of range");
            }
            for &v in vals {
                ensure!(v.is_finite(), "column {l}: non-finite value");
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// sparse kernels (accumulation contract shared with linalg::dense)
// ---------------------------------------------------------------------------

/// Sparse `<col, v>` against a dense f64 vector — the stored-entry twin
/// of [`super::dense::dot_mixed`] under the [`super::simd`] contract.
#[inline]
pub fn sp_dot_mixed(indices: &[u32], values: &[f32], v: &[f64]) -> f64 {
    debug_assert_eq!(indices.len(), values.len());
    super::simd::sp_dot_mixed(indices, values, v)
}

/// Sparse `<col, v>` against a dense f32 vector (f64 accumulation), the
/// stored-entry twin of [`super::dense::dot_f32_f64`].
#[inline]
pub fn sp_dot_f32_f64(indices: &[u32], values: &[f32], v: &[f32]) -> f64 {
    debug_assert_eq!(indices.len(), values.len());
    super::simd::sp_dot_f32_f64(indices, values, v)
}

/// Sparse `y += alpha * col` scatter into an f64 accumulator.
#[inline]
pub fn sp_axpy_f64(alpha: f64, indices: &[u32], values: &[f32], y: &mut [f64]) {
    debug_assert_eq!(indices.len(), values.len());
    super::simd::sp_axpy_f64(alpha, indices, values, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense;

    fn sample() -> CscMatrix {
        // n=4, d=3; col0 = [1,0,2,0], col1 = [0,0,0,0], col2 = [0,3,0,4]
        CscMatrix::from_dense(
            &[1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 3.0, 0.0, 4.0],
            4,
            3,
        )
    }

    #[test]
    fn from_dense_round_trip() {
        let m = sample();
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.col_ptr, vec![0, 2, 2, 4]);
        assert_eq!(m.indices, vec![0, 2, 1, 3]);
        assert_eq!(
            m.to_dense(),
            vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 3.0, 0.0, 4.0]
        );
        m.validate().unwrap();
    }

    #[test]
    fn from_cols_sorts_and_drops_zeros() {
        let m = CscMatrix::from_cols(5, vec![vec![(3, 2.0), (1, 1.0), (4, 0.0)], vec![]]);
        assert_eq!(m.d, 2);
        assert_eq!(m.indices, vec![1, 3]);
        assert_eq!(m.values, vec![1.0, 2.0]);
        m.validate().unwrap();
    }

    #[test]
    fn from_cols_coalesces_duplicate_rows() {
        // duplicates within a column sum; pairs canceling to zero vanish —
        // the result must pass validate() (strictly increasing indices)
        let m = CscMatrix::from_cols(
            6,
            vec![
                vec![(2, 1.5), (0, 1.0), (2, 0.5), (5, -1.0)],
                vec![(3, 2.0), (3, -2.0), (1, 4.0)],
            ],
        );
        m.validate().unwrap();
        assert_eq!(m.col_ptr, vec![0, 3, 4]);
        assert_eq!(m.indices, vec![0, 2, 5, 1]);
        assert_eq!(m.values, vec![1.0, 2.0, -1.0, 4.0]);
        // dense parity: the coalesced matrix equals the summed dense one
        let dense = m.to_dense();
        assert_eq!(dense[2], 2.0); // col 0, row 2: 1.5 + 0.5
        assert_eq!(dense[6 + 3], 0.0); // col 1, row 3: 2.0 − 2.0 cancelled
    }

    #[test]
    fn kernels_match_dense_on_densified_column() {
        let m = sample();
        let dense_buf = m.to_dense();
        let v64: Vec<f64> = vec![0.5, -1.0, 2.0, 3.0];
        let v32: Vec<f32> = v64.iter().map(|&v| v as f32).collect();
        for l in 0..3 {
            let (idx, vals) = m.col(l);
            let col = &dense_buf[l * 4..(l + 1) * 4];
            assert_eq!(sp_dot_mixed(idx, vals, &v64), dense::dot_mixed(col, &v64));
            assert_eq!(sp_dot_f32_f64(idx, vals, &v32), dense::dot_f32_f64(col, &v32));
            let mut ys = vec![1.0f64; 4];
            let mut yd = vec![1.0f64; 4];
            sp_axpy_f64(-1.5, idx, vals, &mut ys);
            dense::axpy_f64(-1.5, col, &mut yd);
            assert_eq!(ys, yd);
        }
    }

    #[test]
    fn full_density_is_bit_identical_to_dense() {
        // all-nonzero columns: the parity guarantee the prop tests rely on
        let n = 13; // exercises the unroll tail
        let col: Vec<f32> = (0..n).map(|i| (i as f32) * 0.37 - 2.1).collect();
        let m = CscMatrix::from_dense(&col, n, 1);
        assert_eq!(m.nnz(), n);
        let v: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let (idx, vals) = m.col(0);
        assert_eq!(sp_dot_mixed(idx, vals, &v).to_bits(), dense::dot_mixed(&col, &v).to_bits());
        assert_eq!(
            sp_dot_f32_f64(idx, vals, &col).to_bits(),
            dense::dot_f32_f64(&col, &col).to_bits()
        );
    }

    #[test]
    fn select_cols_keeps_exact_columns() {
        let m = sample();
        let r = m.select_cols(&[2, 0]);
        assert_eq!(r.d, 2);
        assert_eq!(r.col(0), m.col(2));
        assert_eq!(r.col(1), m.col(0));
        r.validate().unwrap();
    }

    #[test]
    fn select_rows_remaps_and_sorts() {
        let m = sample();
        // new rows: [old2, old0] — col0 picks up both entries, reordered
        let r = m.select_rows(&[2, 0]);
        assert_eq!(r.n, 2);
        assert_eq!(r.to_dense(), vec![2.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
        r.validate().unwrap();
    }

    #[test]
    fn scaled_scales_values_only() {
        let m = sample().scaled(2.0);
        assert_eq!(m.values, vec![2.0, 4.0, 6.0, 8.0]);
        assert_eq!(m.indices, sample().indices);
    }

    #[test]
    fn validate_rejects_corruption() {
        let mut m = sample();
        m.col_ptr[1] = 10;
        assert!(m.validate().is_err());
        let mut m2 = sample();
        m2.indices[0] = 99;
        assert!(m2.validate().is_err());
        let mut m3 = sample();
        m3.indices.swap(0, 1); // breaks strict ordering in column 0
        assert!(m3.validate().is_err());
    }

    #[test]
    fn density_and_mem() {
        let m = sample();
        assert!((m.density() - 4.0 / 12.0).abs() < 1e-12);
        assert!(m.mem_bytes() > 0);
    }
}
