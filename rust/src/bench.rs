//! Criterion-lite: a small measurement harness for the `benches/` targets
//! (criterion itself is not vendored offline). Warmup + timed samples +
//! robust summary stats, plus table/CSV printers shared by the paper
//! reproduction benches.

use crate::util::timer::format_duration;
use std::time::{Duration, Instant};

/// Measured samples of one benchmark plus its robust summary stats.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// benchmark label (printed in summaries)
    pub name: String,
    /// per-sample wallclock, in seconds
    pub samples: Vec<f64>,
}

impl BenchStats {
    /// Median sample (the headline number — robust to warmup stragglers).
    pub fn median(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }
    /// 25th-percentile sample (lower IQR bound).
    pub fn p25(&self) -> f64 {
        percentile(&self.samples, 25.0)
    }
    /// 75th-percentile sample (upper IQR bound).
    pub fn p75(&self) -> f64 {
        percentile(&self.samples, 75.0)
    }
    /// Arithmetic mean of the samples.
    pub fn mean(&self) -> f64 {
        crate::linalg::simd::mean_serial_f64(&self.samples)
    }
    /// One-line "median + IQR" summary (what [`Bencher::run`] prints).
    pub fn summary(&self) -> String {
        format!(
            "{:<44} median {:>10}  IQR [{:>10}, {:>10}]  n={}",
            self.name,
            format_duration(Duration::from_secs_f64(self.median())),
            format_duration(Duration::from_secs_f64(self.p25())),
            format_duration(Duration::from_secs_f64(self.p75())),
            self.samples.len()
        )
    }
}

fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = (p / 100.0 * (s.len() - 1) as f64).round() as usize;
    s[idx.min(s.len() - 1)]
}

/// Benchmark runner: warms up for `warmup` iterations, then measures until
/// `min_samples` samples or `max_time` is exhausted (at least 1 sample).
pub struct Bencher {
    /// untimed iterations before measurement starts
    pub warmup: usize,
    /// samples to collect (unless `max_time` runs out first)
    pub min_samples: usize,
    /// wallclock budget for the whole measurement
    pub max_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 1, min_samples: 5, max_time: Duration::from_secs(30) }
    }
}

impl Bencher {
    /// A fast profile for CI-sized runs (3 samples, 10 s budget).
    pub fn quick() -> Self {
        Bencher { warmup: 1, min_samples: 3, max_time: Duration::from_secs(10) }
    }

    /// Measure `f`, print the summary line, and return the samples.
    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let t0 = Instant::now();
        while samples.len() < self.min_samples && t0.elapsed() < self.max_time
            || samples.is_empty()
        {
            let s = Instant::now();
            std::hint::black_box(f());
            samples.push(s.elapsed().as_secs_f64());
        }
        let stats = BenchStats { name: name.to_string(), samples };
        println!("{}", stats.summary());
        stats
    }
}

/// Fixed-width ASCII table printer (paper-style tables).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append one row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render the aligned ASCII table.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("| {:>w$} ", c, w = widths[i]))
                .collect::<String>()
                + "|"
        };
        let mut out = String::new();
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let b = Bencher { warmup: 1, min_samples: 3, max_time: Duration::from_secs(5) };
        let stats = b.run("noop", || 1 + 1);
        assert!(stats.samples.len() >= 3);
        assert!(stats.median() >= 0.0);
    }

    #[test]
    fn percentile_ordering() {
        let s = BenchStats { name: "x".into(), samples: vec![5.0, 1.0, 3.0, 2.0, 4.0] };
        assert_eq!(s.median(), 3.0);
        assert!(s.p25() <= s.median() && s.median() <= s.p75());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1.23".into()]);
        t.row(&["long-name".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("| long-name |"));
        let lines: Vec<&str> = r.lines().collect();
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
