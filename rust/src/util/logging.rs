//! Minimal leveled stderr logger (no `log`/`env_logger` offline).
//!
//! Level from `MTFL_LOG` (error|warn|info|debug|trace), default info.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most to least severe.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    /// unrecoverable problems
    Error = 0,
    /// suspicious-but-continuing conditions
    Warn = 1,
    /// progress messages (the default level)
    Info = 2,
    /// verbose diagnostics
    Debug = 3,
    /// per-iteration firehose
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init_level() -> u8 {
    let lvl = match std::env::var("MTFL_LOG").unwrap_or_default().to_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Would a message at `level` be emitted under the current threshold?
pub fn enabled(level: Level) -> bool {
    let cur = LEVEL.load(Ordering::Relaxed);
    let cur = if cur == 255 { init_level() } else { cur };
    (level as u8) <= cur
}

/// Override the threshold programmatically (tests; `MTFL_LOG` otherwise).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Emit a message to stderr if `level` is enabled (the macros' backend).
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {}", tag(level), args);
    }
}

fn tag(level: Level) -> &'static str {
    match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    }
}

/// Log at [`Level::Info`] with `format!` syntax.
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*))
    };
}
/// Log at [`Level::Warn`] with `format!` syntax (named `warn_` to avoid
/// shadowing the built-in `warn` attribute in call sites).
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*))
    };
}
/// Log at [`Level::Debug`] with `format!` syntax.
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
