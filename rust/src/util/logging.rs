//! Minimal leveled stderr logger (no `log`/`env_logger` offline).
//!
//! Level from `MTFL_LOG` (error|warn|info|debug|trace), default info.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init_level() -> u8 {
    let lvl = match std::env::var("MTFL_LOG").unwrap_or_default().to_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn enabled(level: Level) -> bool {
    let cur = LEVEL.load(Ordering::Relaxed);
    let cur = if cur == 255 { init_level() } else { cur };
    (level as u8) <= cur
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {}", tag(level), args);
    }
}

fn tag(level: Level) -> &'static str {
    match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*))
    };
}
#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*))
    };
}
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
