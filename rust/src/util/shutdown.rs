//! Cooperative shutdown signal for long-lived processes (`repro serve`).
//!
//! A [`ShutdownFlag`] is a cloneable latch: any holder may
//! [`ShutdownFlag::request`] it, and loops that honor it finish their
//! current unit of work, drain what they already accepted, and return —
//! nothing is aborted mid-kernel. The executor needs no flag of its own:
//! its scopes are synchronous (a `scoped_pool` call returns only after
//! every job signed off, DESIGN.md §11), so "drain the executor" is
//! simply "return from the jobs you already submitted", which the serve
//! loop does by finishing its final tick before exiting (DESIGN.md §15).
//!
//! The latch is one `AtomicBool`; `Relaxed` ordering suffices because
//! the flag carries no data — every consumer re-checks it at a loop
//! boundary and the transition is one-way.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A one-way, cloneable "please stop" latch.
#[derive(Debug, Clone, Default)]
pub struct ShutdownFlag(Arc<AtomicBool>);

impl ShutdownFlag {
    /// A fresh latch in the running state.
    pub fn new() -> Self {
        Self::default()
    }

    /// Trip the latch (idempotent).
    pub fn request(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    /// True once any holder has requested shutdown.
    pub fn is_requested(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clones_share_the_latch() {
        let a = ShutdownFlag::new();
        let b = a.clone();
        assert!(!a.is_requested());
        b.request();
        assert!(a.is_requested());
        b.request(); // idempotent
        assert!(b.is_requested());
    }
}
