//! Substrate utilities built from scratch (no third-party crates are
//! available offline beyond `xla`/`anyhow`): RNG, timers, the persistent
//! executor every parallel sweep runs on, and a tiny logger.

// `unsafe` is denied crate-wide (Cargo.toml [lints]); the executor is one
// of the two allowlisted homes — its lifetime-erased scope protocol needs
// `unsafe impl Send` plus two transmutes, each carrying a full SAFETY
// argument and model-checked by `loom_model` below.
#[allow(unsafe_code)]
pub mod executor;
pub mod logging;
// Loom re-implementation of the executor's scope protocol; compiled only
// under `--features loom-model` (the loom CI job). Uses no unsafe — it
// exists to exhaustively model-check the barrier the executor's unsafe
// relies on.
#[cfg(feature = "loom-model")]
pub mod loom_model;
pub mod rng;
pub mod shutdown;
pub mod threads;
pub mod timer;

pub use executor::{join, parallel_chunks, scoped_pool};
pub use rng::Pcg64;
pub use shutdown::ShutdownFlag;
pub use threads::{num_threads, serial_below};
pub use timer::{Stopwatch, format_duration};
