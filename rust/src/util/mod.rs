//! Substrate utilities built from scratch (no third-party crates are
//! available offline beyond `xla`/`anyhow`): RNG, timers, the persistent
//! executor every parallel sweep runs on, and a tiny logger.

pub mod executor;
pub mod logging;
pub mod rng;
pub mod threads;
pub mod timer;

pub use executor::{join, parallel_chunks, scoped_pool};
pub use rng::Pcg64;
pub use threads::{num_threads, serial_below};
pub use timer::{Stopwatch, format_duration};
