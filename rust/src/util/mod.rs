//! Substrate utilities built from scratch (no third-party crates are
//! available offline beyond `xla`/`anyhow`): RNG, timers, a thread pool,
//! and a tiny logger.

pub mod logging;
pub mod rng;
pub mod threads;
pub mod timer;

pub use rng::Pcg64;
pub use threads::{num_threads, parallel_chunks, scoped_pool};
pub use timer::{Stopwatch, format_duration};
