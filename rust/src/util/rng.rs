//! PCG64 (DXSM) pseudo-random generator + distribution samplers.
//!
//! The `rand` crate is not vendored in this environment, so experiments use
//! this self-contained generator. PCG64-DXSM is the NumPy default bit
//! generator, which keeps our synthetic datasets statistically comparable
//! with the paper's NumPy/MATLAB-generated ones. Reproducibility: every
//! experiment seeds explicitly; `split` derives independent streams for
//! parallel trials.

/// PCG64-DXSM: 128-bit LCG state, 64-bit DXSM output permutation.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// cached second normal from the Box–Muller pair
    spare_normal: Option<f64>,
}

const PCG_MUL: u128 = 0xda942042e4dd58b5;

impl Pcg64 {
    /// Seeded generator on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xcafe_f00d_d15e_a5e5)
    }

    /// Seeded generator on an explicit stream (independent sequences
    /// share a seed but differ by stream).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc, spare_normal: None };
        rng.state = rng.state.wrapping_mul(PCG_MUL).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed as u128 ^ ((seed as u128) << 64));
        rng.state = rng.state.wrapping_mul(PCG_MUL).wrapping_add(inc);
        rng
    }

    /// Derive an independent stream (for parallel trials / tasks).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let stream = self.next_u64() | 1;
        Pcg64::with_stream(seed, stream)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // DXSM output permutation on the *pre-advance* state
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        self.state = self.state.wrapping_mul(PCG_MUL).wrapping_add(self.inc);
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(PCG_MUL as u64);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Lemire's multiply-shift rejection
    /// method (unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= 1e-300 {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Fill a buffer with N(mean, std²) samples, cast to f32.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], mean: f64, std: f64) {
        for v in out.iter_mut() {
            *v = (mean + std * self.normal()) as f32;
        }
    }

    /// Sample k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // For small k relative to n use a set-based scheme; otherwise shuffle.
        if k * 8 < n {
            let mut picked = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.below(n as u64) as usize;
                if picked.insert(v) {
                    out.push(v);
                }
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.below((n - i) as u64) as usize;
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        }
    }

    /// Geometric-ish Zipf sampler over [0, n) with exponent `s` (for the
    /// text-corpus simulator): inverse-CDF on precomputed weights is the
    /// caller's job; this is the cheap approximation used for ranks.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse transform on the continuous Zipf CDF
        let u = self.uniform().max(1e-12);
        let x = ((n as f64).powf(1.0 - s) * u + (1.0 - u)).powf(1.0 / (1.0 - s));
        (x.floor() as usize).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Pcg64::new(7);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 20_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(9);
        let n = 100_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m += z;
            v += z * z;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean={m}");
        assert!((v - 1.0).abs() < 0.03, "var={v}");
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Pcg64::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Pcg64::new(5);
        for (n, k) in [(100, 10), (50, 50), (1000, 3)] {
            let picks = r.choose_distinct(n, k);
            assert_eq!(picks.len(), k);
            let set: std::collections::HashSet<_> = picks.iter().collect();
            assert_eq!(set.len(), k);
            assert!(picks.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg64::new(11);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn zipf_in_range_and_head_heavy() {
        let mut r = Pcg64::new(13);
        let mut head = 0;
        for _ in 0..10_000 {
            let z = r.zipf(1000, 1.2);
            assert!(z < 1000);
            if z < 10 {
                head += 1;
            }
        }
        assert!(head > 3000, "zipf head mass too small: {head}");
    }
}
