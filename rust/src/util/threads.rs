//! Scoped data-parallel helpers on std::thread (no rayon/tokio offline).
//!
//! The two hot patterns in this codebase are (a) "split a feature range
//! into contiguous chunks and process each on its own core" (screening
//! sweeps, gradient sweeps) and (b) "run K independent closures" (parallel
//! trials). Both are served by [`parallel_chunks`] / [`scoped_pool`] built
//! on `std::thread::scope`, which lets workers borrow the data matrices
//! without `Arc`.

/// Number of worker threads: `MTFL_THREADS` env override, else available
/// parallelism, clamped to [1, 64].
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("MTFL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 64)
}

/// Process `0..len` in contiguous chunks, one chunk per worker. `f` receives
/// (chunk_index, start, end) and returns a per-chunk result; results come
/// back ordered by chunk index.
pub fn parallel_chunks<R, F>(len: usize, max_workers: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, usize, usize) -> R + Sync,
{
    if len == 0 {
        return Vec::new();
    }
    let workers = max_workers.min(num_threads()).min(len).max(1);
    if workers == 1 {
        return vec![f(0, 0, len)];
    }
    let chunk = len.div_ceil(workers);
    let mut out: Vec<Option<R>> = (0..workers).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(workers);
        for (i, slot) in out.iter_mut().enumerate() {
            let start = i * chunk;
            let end = ((i + 1) * chunk).min(len);
            let fref = &f;
            handles.push(s.spawn(move || {
                if start < end {
                    *slot = Some(fref(i, start, end));
                }
            }));
        }
        for h in handles {
            h.join().expect("worker panicked");
        }
    });
    out.into_iter().flatten().collect()
}

/// Run independent jobs (one closure per item) across the pool; returns
/// results in item order.
pub fn scoped_pool<T, R, F>(items: Vec<T>, max_workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = max_workers.min(num_threads()).min(n).max(1);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    use std::sync::Mutex;
    let queue: Mutex<Vec<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().rev().collect());
    let results: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let job = queue.lock().unwrap().pop();
                match job {
                    Some((i, item)) => {
                        let r = f(item);
                        results.lock().unwrap().push((i, r));
                    }
                    None => break,
                }
            });
        }
    });
    let mut rs = results.into_inner().unwrap();
    rs.sort_by_key(|(i, _)| *i);
    rs.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_range_exactly_once() {
        let hits: Vec<(usize, usize)> =
            parallel_chunks(1003, 7, |_, s, e| (s, e)).into_iter().collect();
        let mut covered = vec![false; 1003];
        for (s, e) in hits {
            for c in covered.iter_mut().take(e).skip(s) {
                assert!(!*c, "double coverage");
                *c = true;
            }
        }
        assert!(covered.into_iter().all(|c| c));
    }

    #[test]
    fn chunk_sum_matches_serial() {
        let data: Vec<f64> = (0..10_000).map(|i| i as f64).collect();
        let partial = parallel_chunks(data.len(), 8, |_, s, e| {
            data[s..e].iter().sum::<f64>()
        });
        let total: f64 = partial.into_iter().sum();
        assert_eq!(total, data.iter().sum::<f64>());
    }

    #[test]
    fn pool_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = scoped_pool(items, 8, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_inputs() {
        assert!(parallel_chunks(0, 4, |_, _, _| ()).is_empty());
        assert!(scoped_pool(Vec::<usize>::new(), 4, |i| i).is_empty());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_chunks(10, 1, |i, s, e| (i, s, e));
        assert_eq!(out, vec![(0, 0, 10)]);
    }
}
