//! Worker-count and serial-cutoff policy for the parallel sweeps.
//!
//! The *mechanism* — a persistent work-sharing pool with nested-safe
//! scopes — lives in [`super::executor`]; this module holds the two
//! *policies* every parallel call site shares:
//!
//! * [`num_threads`] — how wide the pool is (`MTFL_THREADS` override);
//! * [`serial_below`] — when a sweep is too small to be worth handing to
//!   the pool at all (`MTFL_SERIAL_CUTOFF` override).
//!
//! The cutoff used to be a magic constant copy-pasted into `ops.rs`,
//! `screening/mod.rs` and `screening/bounds.rs`; it is now one documented
//! function so benchmarks can move it (or zero it) with one env var and
//! every layer follows.

/// Number of worker threads: `MTFL_THREADS` env override, else available
/// parallelism, clamped to [1, 64]. The executor sizes its pool from this
/// at first use (`num_threads() − 1` dedicated workers plus the
/// submitting thread — DESIGN.md §11).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("MTFL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 64)
}

/// Default [`serial_cutoff`]: sweeps touching fewer stored entries than
/// this run serially. Scheduling a scope on the pool costs on the order
/// of a microsecond; below ~1 MFLOP of *stored* work (a 1%-dense CSC
/// sweep is ~100× cheaper than `d·N` suggests — gate on
/// [`crate::data::Dataset::sweep_work`], never on the dense cell count)
/// that overhead is the sweep.
pub const DEFAULT_SERIAL_CUTOFF: usize = 500_000;

/// The serial/parallel threshold in stored entries per sweep:
/// `MTFL_SERIAL_CUTOFF` env override (benchmarks set `0` to force every
/// sweep onto the pool, or a huge value to force serial), else
/// [`DEFAULT_SERIAL_CUTOFF`]. Read fresh on every call so tests and
/// benches can flip it without process restarts; the choice only moves
/// work between serial and pooled execution, never the results (the
/// determinism suite pins bit-equality across widths).
pub fn serial_cutoff() -> usize {
    if let Ok(v) = std::env::var("MTFL_SERIAL_CUTOFF") {
        if let Ok(n) = v.parse::<usize>() {
            return n;
        }
    }
    DEFAULT_SERIAL_CUTOFF
}

/// Shared sweep policy: should a sweep over `work` stored entries stay
/// serial? Call sites pass the result to the executor as a worker bound
/// (`1` vs `usize::MAX`), keeping sparse CSC problems off the pool when
/// their sweeps are cheaper than a scope dispatch.
pub fn serial_below(work: usize) -> bool {
    work < serial_cutoff()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_threads_in_range() {
        let n = num_threads();
        assert!((1..=64).contains(&n));
    }

    #[test]
    fn default_cutoff_policy() {
        // below / at the documented default (no env override in the test
        // harness sets MTFL_SERIAL_CUTOFF to something exotic; if a
        // determinism test zeroed it, both branches still hold trivially)
        let cut = serial_cutoff();
        assert!(serial_below(cut.saturating_sub(1)) || cut == 0);
        assert!(!serial_below(cut));
        assert!(!serial_below(usize::MAX));
    }
}
