//! Wallclock accounting for the coordinator's per-phase timing
//! (solver-alone vs screening vs total — the columns of Table 1).

use std::time::{Duration, Instant};

/// A resumable stopwatch accumulating total elapsed time across intervals.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    accumulated: Duration,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// A stopped stopwatch at zero.
    pub fn new() -> Self {
        Stopwatch { accumulated: Duration::ZERO, started: None }
    }

    /// A stopwatch already running from now — the `let t0 =
    /// Instant::now()` idiom, routed through the timing substrate
    /// (repro-lint's nondeterminism rule keeps raw `Instant` out of
    /// library code; this file is its allowlisted home).
    pub fn started() -> Self {
        Stopwatch { accumulated: Duration::ZERO, started: Some(Instant::now()) }
    }

    /// Start (or resume) timing; a no-op if already running.
    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    /// Stop and accumulate the running interval; a no-op if stopped.
    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.accumulated += t0.elapsed();
        }
    }

    /// Time a closure and accumulate its duration.
    pub fn time<R>(&mut self, f: impl FnOnce() -> R) -> R {
        self.start();
        let r = f();
        self.stop();
        r
    }

    /// Total accumulated time, including a still-running interval.
    pub fn elapsed(&self) -> Duration {
        self.accumulated
            + self.started.map(|t| t.elapsed()).unwrap_or(Duration::ZERO)
    }

    /// [`Stopwatch::elapsed`] in seconds.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Zero the accumulator and stop.
    pub fn reset(&mut self) {
        self.accumulated = Duration::ZERO;
        self.started = None;
    }
}

/// Human format: "1.23s", "45.1ms", "12.3m".
pub fn format_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 60.0 {
        format!("{:.2}m", s / 60.0)
    } else if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_across_intervals() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        let t1 = sw.secs();
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(sw.secs() >= t1 + 0.004);
    }

    #[test]
    fn stop_without_start_is_noop() {
        let mut sw = Stopwatch::new();
        sw.stop();
        assert_eq!(sw.elapsed(), Duration::ZERO);
    }

    #[test]
    fn format_ranges() {
        assert!(format_duration(Duration::from_secs(90)).ends_with('m'));
        assert!(format_duration(Duration::from_secs(2)).ends_with('s'));
        assert!(format_duration(Duration::from_millis(3)).ends_with("ms"));
        assert!(format_duration(Duration::from_micros(3)).ends_with("us"));
    }
}
