//! Loom model of the executor's scope protocol (DESIGN.md §11, §13).
//!
//! `util/executor.rs` rests on one load-bearing claim: a scope's
//! `ScopeState` — a stack local holding a lifetime-erased job borrow —
//! is never touched after `wait_done()` observes `runners_left == 0`,
//! and everything the job wrote is visible to the submitter at that
//! point. That claim cannot be unit-tested (a violation is a data race
//! or use-after-free, not a wrong value), so this module re-implements
//! the protocol 1:1 on `loom` primitives — injector queue under a
//! `Mutex` + `Condvar`, atomic task claiming via `fetch_add`, sign-off
//! by decrementing `runners_left` under the waiter's mutex, first-panic
//! slot with rethrow, and the `IS_WORKER` nested-inline policy — and
//! lets loom enumerate every interleaving of:
//!
//! * **sign-off barrier**: after `run_indexed` returns, every task's
//!   `Relaxed` write is visible to the submitter. `Relaxed` is the
//!   point: the data slots themselves provide no ordering, so the test
//!   passes only if the barrier (mutex-protected decrement + condvar)
//!   carries the happens-before edge the executor's `unsafe impl Send
//!   for RawRunner` relies on.
//! * **injector hand-off**: queued runner handles are always drained
//!   and run; the pool survives repeated scopes and a stop request.
//! * **nested-inline policy**: a job that submits again runs the inner
//!   scope inline on the current thread — loom completing the model
//!   proves there is no hand-off deadlock to reach.
//! * **panic rethrow**: a panicking task is caught in the runner, still
//!   signs off (so the barrier cannot hang), and resurfaces exactly
//!   once on the submitting thread.
//!
//! The model intentionally contains **no unsafe**: where the executor
//! erases the job's lifetime with a transmute, the model uses
//! `Arc<dyn Fn>`. The pointer arithmetic is not what needs checking —
//! the barrier ordering that *justifies* it is, and that is identical
//! here. Run with
//! `RUSTFLAGS="--cfg loom" cargo test --release --features loom-model loom_`.

use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::{Arc, Condvar, Mutex};
use loom::thread;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

type Job = dyn Fn(usize) + Send + Sync;

/// Model twin of `executor::ScopeState`. The real struct holds
/// `&'static dyn Fn` (transmuted); the model holds `Arc<Job>` —
/// everything else is field-for-field the same protocol.
struct Scope {
    job: Arc<Job>,
    count: usize,
    next: AtomicUsize,
    runners_left: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl Scope {
    /// Model twin of `ScopeState::run_runner`: claim tasks until the
    /// counter runs dry, stash the first panic, sign off last.
    fn run_runner(&self) {
        let result = catch_unwind(AssertUnwindSafe(|| loop {
            let i = self.next.fetch_add(1, Ordering::SeqCst);
            if i >= self.count {
                break;
            }
            (self.job)(i);
        }));
        if let Err(p) = result {
            let mut slot = self.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(p);
            }
        }
        // Sign-off: the final touch of the scope, under the same mutex
        // wait_done() sleeps on — this release/acquire pair is the whole
        // happens-before argument of the executor's unsafe.
        let mut left = self.runners_left.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }

    /// Model twin of `ScopeState::wait_done`.
    fn wait_done(&self) {
        let mut left = self.runners_left.lock().unwrap();
        while *left != 0 {
            left = self.done.wait(left).unwrap();
        }
        drop(left);
        if let Some(p) = self.panic.lock().unwrap().take() {
            resume_unwind(p);
        }
    }
}

/// Model twin of `executor::Pool`: an injector queue of scope handles
/// plus a stop flag (the real pool leaks its workers instead of
/// stopping; the model must join them so each loom execution is finite).
struct PoolModel {
    /// (pending runner handles, stop requested)
    queue: Mutex<(VecDeque<Arc<Scope>>, bool)>,
    available: Condvar,
}

loom::thread_local! {
    /// Model twin of the executor's `IS_WORKER` flag: set on pool
    /// threads and on the submitter while it runs its own runner, so a
    /// nested submission runs inline instead of re-entering the queue.
    static IS_WORKER: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

/// Model twin of `worker_main`: block on the condvar, pop, run, repeat
/// until stop is raised with the queue empty.
fn worker_main(pool: &Arc<PoolModel>) {
    IS_WORKER.with(|w| w.set(true));
    loop {
        let scope = {
            let mut q = pool.queue.lock().unwrap();
            loop {
                if let Some(s) = q.0.pop_front() {
                    break Some(s);
                }
                if q.1 {
                    break None;
                }
                q = pool.available.wait(q).unwrap();
            }
        };
        match scope {
            Some(s) => s.run_runner(),
            None => return,
        }
    }
}

struct ModelPool {
    shared: Arc<PoolModel>,
    handles: Vec<thread::JoinHandle<()>>,
}

fn spawn_pool(extra_workers: usize) -> ModelPool {
    let shared = Arc::new(PoolModel {
        queue: Mutex::new((VecDeque::new(), false)),
        available: Condvar::new(),
    });
    let handles = (0..extra_workers)
        .map(|_| {
            let s = Arc::clone(&shared);
            thread::spawn(move || worker_main(&s))
        })
        .collect();
    ModelPool { shared, handles }
}

impl ModelPool {
    fn shutdown(self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.1 = true;
        }
        self.shared.available.notify_all();
        for h in self.handles {
            h.join().unwrap();
        }
    }
}

/// Model twin of `executor::run_indexed`: enqueue `workers - 1` handles,
/// run one runner on the submitting thread (flagged as a worker so
/// nested submissions inline), then block in `wait_done`. Takes the
/// shared half of the pool so jobs can hold a clone (the nested test).
fn run_indexed(pool: &Arc<PoolModel>, workers: usize, count: usize, job: Arc<Job>) {
    if count == 0 {
        return;
    }
    if workers <= 1 || IS_WORKER.with(|w| w.get()) {
        // Nested-inline policy: a job already on a pool thread (or a
        // single-worker scope) runs every task serially right here —
        // submitting to the queue from inside a runner could deadlock
        // the pool on itself.
        for i in 0..count {
            (job)(i);
        }
        return;
    }
    let extra = workers - 1;
    let scope = Arc::new(Scope {
        job,
        count,
        next: AtomicUsize::new(0),
        runners_left: Mutex::new(extra + 1),
        done: Condvar::new(),
        panic: Mutex::new(None),
    });
    {
        let mut q = pool.queue.lock().unwrap();
        for _ in 0..extra {
            q.0.push_back(Arc::clone(&scope));
        }
    }
    pool.available.notify_all();
    let was = IS_WORKER.with(|w| w.replace(true));
    scope.run_runner();
    IS_WORKER.with(|w| w.set(was));
    scope.wait_done();
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Loom model builder with the standard preemption bound. Bounding
    /// at 3 forced preemptions keeps each model finite while still
    /// covering the interleavings where ordering bugs live (loom's
    /// documented guidance: most bugs manifest within 2-3 preemptions).
    fn model() -> loom::model::Builder {
        let mut b = loom::model::Builder::new();
        b.preemption_bound = Some(3);
        b
    }

    /// Sign-off barrier: every task's `Relaxed` write must be visible to
    /// the submitter once `run_indexed` returns. The slots deliberately
    /// carry no ordering of their own — only the runners_left decrement
    /// under the waiter's mutex can publish them. This is the memory-
    /// visibility half of the executor's `unsafe impl Send for
    /// RawRunner` argument, checked over every interleaving.
    #[test]
    fn loom_signoff_barrier_publishes_all_writes() {
        model().check(|| {
            let pool = spawn_pool(1);
            let slots: Arc<Vec<AtomicUsize>> =
                Arc::new((0..3).map(|_| AtomicUsize::new(0)).collect());
            let s = Arc::clone(&slots);
            run_indexed(
                &pool.shared,
                2,
                3,
                Arc::new(move |i| s[i].store(i + 1, Ordering::Relaxed)),
            );
            for (i, slot) in slots.iter().enumerate() {
                assert_eq!(slot.load(Ordering::Relaxed), i + 1, "task {i} write lost");
            }
            pool.shutdown();
        });
    }

    /// Injector hand-off: two back-to-back scopes over the same pool.
    /// Every queued handle must be drained and run (the second scope's
    /// barrier would hang if a handle from either scope were dropped),
    /// and shutdown must join cleanly — no handle left behind.
    #[test]
    fn loom_injector_handoff_drains_repeated_scopes() {
        model().check(|| {
            let pool = spawn_pool(1);
            let hits = Arc::new(AtomicUsize::new(0));
            for _ in 0..2 {
                let h = Arc::clone(&hits);
                run_indexed(&pool.shared, 2, 2, Arc::new(move |_| {
                    h.fetch_add(1, Ordering::Relaxed);
                }));
            }
            assert_eq!(hits.load(Ordering::Relaxed), 4);
            pool.shutdown();
        });
    }

    /// Nested-inline policy: a task that submits again must run the
    /// inner scope inline on its own thread. If the inner scope were
    /// queued instead, the lone extra worker could be the one inside the
    /// outer task, and the inner barrier would wait on a queue nobody
    /// drains — loom completing this model proves that deadlock is
    /// unreachable; the counter proves the inner tasks actually ran.
    #[test]
    fn loom_nested_submission_runs_inline() {
        model().check(|| {
            let pool = spawn_pool(1);
            let inner_hits = Arc::new(AtomicUsize::new(0));
            {
                let p = Arc::clone(&pool.shared);
                let h = Arc::clone(&inner_hits);
                run_indexed(
                    &pool.shared,
                    2,
                    2,
                    Arc::new(move |_| {
                        let hh = Arc::clone(&h);
                        run_indexed(&p, 2, 2, Arc::new(move |_| {
                            hh.fetch_add(1, Ordering::Relaxed);
                        }));
                    }),
                );
            }
            assert_eq!(inner_hits.load(Ordering::Relaxed), 4);
            pool.shutdown();
        });
    }

    /// Panic rethrow: a panicking task must (a) not kill the pool
    /// worker, (b) still sign off so the barrier cannot hang, and
    /// (c) resurface exactly once on the submitting thread. The pool is
    /// reused afterwards to prove (a).
    #[test]
    fn loom_panic_rethrows_to_submitter_once() {
        model().check(|| {
            let pool = spawn_pool(1);
            let caught = catch_unwind(AssertUnwindSafe(|| {
                run_indexed(
                    &pool.shared,
                    2,
                    2,
                    Arc::new(|i| {
                        if i == 1 {
                            std::panic::panic_any("task 1 down");
                        }
                    }),
                );
            }));
            assert!(caught.is_err(), "panic must cross wait_done to the submitter");
            // the worker caught the panic and signed off — it is still
            // alive to serve another scope
            let hits = Arc::new(AtomicUsize::new(0));
            let h = Arc::clone(&hits);
            run_indexed(&pool.shared, 2, 2, Arc::new(move |_| {
                h.fetch_add(1, Ordering::Relaxed);
            }));
            assert_eq!(hits.load(Ordering::Relaxed), 2);
            pool.shutdown();
        });
    }
}
