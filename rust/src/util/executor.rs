//! The persistent work-sharing executor every parallel sweep in the crate
//! runs on (DESIGN.md §11).
//!
//! Before this module existed, each hot sweep paid a thread-spawn tax:
//! `parallel_chunks` / `scoped_pool` built a fresh `std::thread::scope`
//! per call, so a λ-path re-spawned workers at every grid point and every
//! dynamic re-screen, and nested layers multiplied threads unchecked
//! (CV folds × FISTA's per-task power iteration × column chunks could
//! reach W³ live threads). Both problems are structural, so the fix is
//! structural:
//!
//! * **One pool, process lifetime.** The first parallel region lazily
//!   spawns `num_threads() − 1` workers that park on a condvar between
//!   scopes. After that, no code path in the crate calls
//!   `std::thread::spawn` again — [`spawn_count`] is the test hook that
//!   pins this down.
//! * **Scoped borrows, no `Arc`.** A scope enqueues lifetime-erased
//!   runner handles and *blocks until every runner finishes*, so jobs may
//!   borrow the caller's stack (data matrices, output buffers) exactly as
//!   they could under `std::thread::scope`. The public call shapes
//!   ([`parallel_chunks`], [`scoped_pool`]) are unchanged from the
//!   spawn-per-call era.
//! * **Nested-safe by construction.** A parallel call made *from a pool
//!   worker* (or from the submitting thread while it is executing scope
//!   jobs inline) runs serially inline instead of opening a new scope.
//!   Composition therefore never exceeds W live workers: CV fans its
//!   folds across the pool, and the solvers/sweeps underneath run inline
//!   on whichever worker owns the fold. Inlining is free to do because
//!   every consumer's accumulation order is per-column/per-item by
//!   construction — results are bit-identical at any worker count, which
//!   the determinism suite (`rust/tests/executor_parallel.rs`) pins.
//!
//! The submitting thread is not wasted while a scope runs: it executes
//! one runner itself (temporarily marked as a worker), so a scope of
//! width w uses the submitter plus `w − 1` pool workers — at most
//! `num_threads()` execution streams, never more. (The flip side of
//! inlining: an outer fan-out narrower than W bounds the whole
//! composition at its own width — DESIGN.md §11 discusses the
//! trade-off and the stealing upgrade path.)
//!
//! [`join`] is the two-lane primitive underneath the sharded backend's
//! prefetch pipeline: it runs `a` on the calling thread while `b` (the
//! block reader) executes on one pool worker, and is what "decode block
//! b+1 while sweeping block b" compiles down to (DESIGN.md §11).

use super::threads::num_threads;
use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

// ---------------------------------------------------------------------------
// pool
// ---------------------------------------------------------------------------

/// Total `std::thread::spawn` calls the executor has ever made. After the
/// pool is up ([`ensure_init`]) this value never changes again — the
/// zero-spawn acceptance test for the steady-state per-λ loop reads it
/// before and after a full `run_path`.
static SPAWNS: AtomicUsize = AtomicUsize::new(0);

struct Pool {
    /// pending runner handles; workers park on `available` when empty
    queue: Mutex<VecDeque<RawRunner>>,
    available: Condvar,
    /// dedicated worker threads (`num_threads() − 1` at init)
    workers: usize,
    /// runners currently executing (pool workers + inline submitters)
    active: AtomicUsize,
    /// high-water mark of `active` since the last [`reset_peak_active`]
    peak_active: AtomicUsize,
}

/// A lifetime-erased handle to one runner of a [`ScopeState`]. The scope
/// that enqueued it blocks until `runners_left` hits zero, so the pointer
/// outlives every dequeue-and-run — the same guarantee `std::thread::scope`
/// gives, enforced by the completion wait instead of the borrow checker.
struct RawRunner {
    scope: *const ScopeState,
}
// SAFETY: sending a RawRunner to a pool worker is a `&ScopeState` transfer
// in disguise. It is sound because:
//  (1) aliasing — workers only ever take shared access. ScopeState is Sync
//      (its fields are `&'static (dyn Fn + Sync)`, usize, AtomicUsize,
//      Mutex, Condvar), so concurrent `&ScopeState` use from many threads
//      is the ordinary already-safe case once the reference is delivered.
//  (2) lifetime — the pointee is a stack local of `run_indexed`/`join`,
//      and neither returns before `wait_done()` observes
//      `runners_left == 0`. Every handle decrements that counter exactly
//      once, under the same mutex the waiter sleeps on, as its *final*
//      touch of the scope (`run_runner` never uses `self` after the
//      decrement), so zero implies no runner dereferences the pointer
//      again. A queued-but-never-run handle cannot exist: handles are
//      popped only by `worker_main`, which always runs what it pops, and
//      workers never exit.
//  (3) panics keep (2) — a panicking job is caught inside `run_runner`,
//      which still signs off before returning to `worker_main`.
// The protocol this argument leans on — sign-off barrier, hand-off,
// memory visibility of job writes, panic delivery — is model-checked
// exhaustively by the loom re-implementation in `util/loom_model.rs`
// (`--features loom-model`), not merely asserted here.
unsafe impl Send for RawRunner {}

fn pool() -> &'static Pool {
    static POOL: OnceLock<&'static Pool> = OnceLock::new();
    *POOL.get_or_init(|| {
        let workers = num_threads().saturating_sub(1);
        let p: &'static Pool = Box::leak(Box::new(Pool {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            workers,
            active: AtomicUsize::new(0),
            peak_active: AtomicUsize::new(0),
        }));
        for i in 0..workers {
            SPAWNS.fetch_add(1, Ordering::SeqCst);
            std::thread::Builder::new()
                .name(format!("mtfl-exec-{i}"))
                .spawn(move || worker_main(p))
                .expect("failed to spawn executor worker");
        }
        p
    })
}

fn worker_main(pool: &'static Pool) {
    IS_WORKER.with(|w| w.set(true));
    loop {
        let runner = {
            let mut q = pool.queue.lock().unwrap();
            loop {
                if let Some(r) = q.pop_front() {
                    break r;
                }
                q = pool.available.wait(q).unwrap();
            }
        };
        // SAFETY: the owning scope is still blocked in `wait_done()` — it
        // cannot observe `runners_left == 0` until this very runner signs
        // off at the end of `run_runner` — so the pointer is live for the
        // whole call, and `ScopeState: Sync` makes the shared deref sound.
        // The `unsafe impl Send for RawRunner` above carries the full
        // argument; the barrier it relies on is loom-checked in
        // `util/loom_model.rs`.
        unsafe { (*runner.scope).run_runner(pool) };
    }
}

thread_local! {
    /// true on pool workers, and on a submitting thread while it executes
    /// its own scope's jobs inline — both must not open nested scopes
    static IS_WORKER: Cell<bool> = const { Cell::new(false) };
    /// per-thread cap on scope width (test/pipeline knob; `usize::MAX` =
    /// uncapped). Nested caps only ever tighten — see [`with_worker_cap`].
    static WORKER_CAP: Cell<usize> = const { Cell::new(usize::MAX) };
}

/// True while the current thread is executing executor jobs (a pool
/// worker, or a submitter running its inline share of a scope). Parallel
/// entry points consult this to run nested calls inline.
pub fn on_worker_thread() -> bool {
    IS_WORKER.with(|w| w.get())
}

/// The current thread's scope-width cap (see [`with_worker_cap`]).
pub fn current_worker_cap() -> usize {
    WORKER_CAP.with(|c| c.get())
}

/// Run `f` with this thread's parallel width capped at `cap` execution
/// streams (≥ 1). Caps only tighten under nesting: requesting a larger
/// cap than the current one keeps the current one. `cap = 1` forces every
/// parallel region `f` opens to run serially inline — the in-process
/// equivalent of `MTFL_THREADS=1`, which is exactly what the determinism
/// suite uses to compare serial and pooled runs bit-for-bit.
pub fn with_worker_cap<R>(cap: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            WORKER_CAP.with(|c| c.set(self.0));
        }
    }
    let old = WORKER_CAP.with(|c| c.get());
    let eff = cap.max(1).min(old);
    WORKER_CAP.with(|c| c.set(eff));
    let _restore = Restore(old);
    f()
}

/// Force the pool up (it is otherwise spawned lazily by the first
/// parallel region). Returns the number of dedicated workers. Tests call
/// this so spawn counting starts from a settled state.
pub fn ensure_init() -> usize {
    pool().workers
}

/// `std::thread::spawn` calls the executor has made so far (the pool
/// workers, spawned once at init — nothing else, ever). Steady-state
/// code asserts this does not move.
pub fn spawn_count() -> usize {
    SPAWNS.load(Ordering::SeqCst)
}

/// High-water mark of concurrently executing runners since the last
/// [`reset_peak_active`]. Counts pool workers and inline submitters, so
/// under any composition of scopes it is the number of live execution
/// streams — the nested-oversubscription regression test asserts it
/// never exceeds [`num_threads`]. (A [`join`]'s caller-side lane is
/// counted through the scopes it opens, not separately.)
pub fn peak_active() -> usize {
    pool().peak_active.load(Ordering::SeqCst)
}

/// Reset the [`peak_active`] high-water mark to the current activity.
pub fn reset_peak_active() {
    let p = pool();
    p.peak_active.store(p.active.load(Ordering::SeqCst), Ordering::SeqCst);
}

// ---------------------------------------------------------------------------
// scopes
// ---------------------------------------------------------------------------

/// Shared state of one blocking scope: `count` jobs drained by a fixed
/// set of runners through an atomic claim counter.
struct ScopeState {
    /// the job, lifetime-erased; valid until the submitting call returns
    job: &'static (dyn Fn(usize) + Sync),
    /// number of job indices to claim
    count: usize,
    /// next unclaimed job index
    next: AtomicUsize,
    /// runners (queued + inline) that have not finished yet
    runners_left: Mutex<usize>,
    done: Condvar,
    /// first panic payload from any job, re-raised on the submitter
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl ScopeState {
    /// Claim and run job indices until exhausted, then sign off. Catches
    /// job panics (stored for the submitter) so the pool thread survives.
    fn run_runner(&self, pool: &Pool) {
        let now = pool.active.fetch_add(1, Ordering::SeqCst) + 1;
        pool.peak_active.fetch_max(now, Ordering::SeqCst);
        let result = catch_unwind(AssertUnwindSafe(|| loop {
            let i = self.next.fetch_add(1, Ordering::SeqCst);
            if i >= self.count {
                break;
            }
            (self.job)(i);
        }));
        if let Err(payload) = result {
            let mut slot = self.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        pool.active.fetch_sub(1, Ordering::SeqCst);
        let mut left = self.runners_left.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }

    fn wait_done(&self) {
        let mut left = self.runners_left.lock().unwrap();
        while *left > 0 {
            left = self.done.wait(left).unwrap();
        }
    }
}

/// The scope width a parallel region will use: the caller's `max_workers`
/// clamped by [`num_threads`], the thread's cap, and the job count.
fn plan_workers(max_workers: usize, len: usize) -> usize {
    max_workers.min(num_threads()).min(current_worker_cap()).min(len).max(1)
}

/// Run `count` indexed jobs across at most `max_workers` execution
/// streams and block until all have finished. Jobs may borrow the
/// caller's stack. Runs serially inline when the plan is one worker, when
/// called from a worker thread (nested-safe), or when the pool has no
/// dedicated workers (`MTFL_THREADS=1`). Panics in jobs are re-raised
/// here after every runner has signed off.
pub fn run_indexed(count: usize, max_workers: usize, job: &(dyn Fn(usize) + Sync)) {
    if count == 0 {
        return;
    }
    let workers = plan_workers(max_workers, count);
    if workers == 1 || on_worker_thread() {
        for i in 0..count {
            job(i);
        }
        return;
    }
    let pool = pool();
    if pool.workers == 0 {
        for i in 0..count {
            job(i);
        }
        return;
    }
    // SAFETY: pure lifetime erasure — data pointer and vtable are
    // untouched; only the borrow's region is forged to 'static so it can
    // sit in ScopeState. The forgery never outlives the real borrow: the
    // only copies live in `scope`, every runner handle signs off before
    // `wait_done()` returns below (see the RawRunner Send argument), and
    // the queue is empty of this scope's handles by then, so all calls
    // through `job_static` happen while `job` is still in scope. The
    // `+ Sync` bound keeps the concurrent shared calls themselves safe.
    let job_static: &'static (dyn Fn(usize) + Sync) =
        // SAFETY: see the erasure argument above.
        unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                &'static (dyn Fn(usize) + Sync),
            >(job)
        };
    let scope = ScopeState {
        job: job_static,
        count,
        next: AtomicUsize::new(0),
        runners_left: Mutex::new(workers),
        done: Condvar::new(),
        panic: Mutex::new(None),
    };
    {
        let mut q = pool.queue.lock().unwrap();
        for _ in 0..workers - 1 {
            q.push_back(RawRunner { scope: &scope });
        }
    }
    pool.available.notify_all();
    // the submitter is the scope's last runner; while it runs jobs it is
    // a worker (nested parallel calls from those jobs must inline)
    let was_worker = IS_WORKER.with(|w| w.replace(true));
    scope.run_runner(pool);
    IS_WORKER.with(|w| w.set(was_worker));
    scope.wait_done();
    if let Some(payload) = scope.panic.lock().unwrap().take() {
        resume_unwind(payload);
    }
}

/// Whether a [`join`] from this thread would actually offload its second
/// lane (false on worker threads, under a cap of 1, or with no pool
/// workers). The shard prefetch pipeline consults this so it only
/// reserves a compute lane when the reader lane really runs concurrently.
pub fn can_offload() -> bool {
    !on_worker_thread() && current_worker_cap() > 1 && num_threads() > 1
}

/// Run `a` on the calling thread while `b` executes on one pool worker;
/// return both results. Falls back to serial `(a(), b())` whenever
/// [`can_offload`] is false. `a` may itself open parallel scopes (cap it
/// with [`with_worker_cap`] if `b`'s worker must be accounted for);
/// nested `join`s on worker threads run serially. Panics from either
/// closure are re-raised after both lanes have finished, `b`'s first.
pub fn join<RA, RB>(
    a: impl FnOnce() -> RA + Send,
    b: impl FnOnce() -> RB + Send,
) -> (RA, RB)
where
    RA: Send,
    RB: Send,
{
    if !can_offload() {
        let ra = a();
        let rb = b();
        return (ra, rb);
    }
    let pool = pool();
    if pool.workers == 0 {
        let ra = a();
        let rb = b();
        return (ra, rb);
    }
    let b_cell: Mutex<Option<_>> = Mutex::new(Some(b));
    let rb_slot: Mutex<Option<RB>> = Mutex::new(None);
    let run_b = |_i: usize| {
        let f = b_cell.lock().unwrap().take().expect("join lane claimed twice");
        let r = f();
        *rb_slot.lock().unwrap() = Some(r);
    };
    // SAFETY: same lifetime erasure as in `run_indexed`, one frame deeper:
    // `run_b` borrows the stack locals `b_cell` and `rb_slot`, and the
    // single runner holding the forged &'static signs off before
    // `scope.wait_done()` returns below — strictly before those locals
    // (and `run_b` itself) drop at the end of this function. The data
    // pointer and vtable are untouched; `+ Sync` covers the shared call.
    let job_static: &'static (dyn Fn(usize) + Sync) =
        // SAFETY: see the erasure argument above.
        unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                &'static (dyn Fn(usize) + Sync),
            >(&run_b)
        };
    let scope = ScopeState {
        job: job_static,
        count: 1,
        next: AtomicUsize::new(0),
        runners_left: Mutex::new(1),
        done: Condvar::new(),
        panic: Mutex::new(None),
    };
    {
        let mut q = pool.queue.lock().unwrap();
        q.push_back(RawRunner { scope: &scope });
    }
    pool.available.notify_one();
    let ra = catch_unwind(AssertUnwindSafe(a));
    scope.wait_done();
    if let Some(payload) = scope.panic.lock().unwrap().take() {
        resume_unwind(payload);
    }
    let ra = match ra {
        Ok(v) => v,
        Err(payload) => resume_unwind(payload),
    };
    let rb = rb_slot.into_inner().unwrap().expect("join lane produced no result");
    (ra, rb)
}

// ---------------------------------------------------------------------------
// the two public call shapes (unchanged from the spawn-per-call era)
// ---------------------------------------------------------------------------

/// Process `0..len` in contiguous chunks, one chunk per execution stream.
/// `f` receives (chunk_index, start, end) and returns a per-chunk result;
/// results come back ordered by chunk index. Chunk boundaries depend only
/// on the planned width, and every consumer accumulates per column /
/// per item, so results are bit-identical at any width.
pub fn parallel_chunks<R, F>(len: usize, max_workers: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, usize, usize) -> R + Sync,
{
    if len == 0 {
        return Vec::new();
    }
    let workers = plan_workers(max_workers, len);
    if workers == 1 {
        return vec![f(0, 0, len)];
    }
    let chunk = len.div_ceil(workers);
    let slots: Vec<Mutex<Option<R>>> = (0..workers).map(|_| Mutex::new(None)).collect();
    run_indexed(workers, workers, &|i| {
        let start = i * chunk;
        let end = ((i + 1) * chunk).min(len);
        if start < end {
            // compute before locking: a panicking job must not poison a
            // held result lock
            let r = f(i, start, end);
            *slots[i].lock().unwrap() = Some(r);
        }
    });
    slots.into_iter().filter_map(|s| s.into_inner().unwrap()).collect()
}

/// Run independent jobs (one closure per item) across the pool; returns
/// results in item order. Items are claimed dynamically (load-balanced),
/// but the result order is by item index regardless of completion order.
pub fn scoped_pool<T, R, F>(items: Vec<T>, max_workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = plan_workers(max_workers, n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let cells: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    run_indexed(n, workers, &|i| {
        let item = cells[i].lock().unwrap().take().expect("item claimed twice");
        let r = f(item);
        *slots[i].lock().unwrap() = Some(r);
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("scope finished with a hole"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::thread::ThreadId;

    #[test]
    fn chunks_cover_range_exactly_once() {
        let hits: Vec<(usize, usize)> =
            parallel_chunks(1003, 7, |_, s, e| (s, e)).into_iter().collect();
        let mut covered = vec![false; 1003];
        for (s, e) in hits {
            for c in covered.iter_mut().take(e).skip(s) {
                assert!(!*c, "double coverage");
                *c = true;
            }
        }
        assert!(covered.into_iter().all(|c| c));
    }

    #[test]
    fn chunk_sum_matches_serial() {
        let data: Vec<f64> = (0..10_000).map(|i| i as f64).collect();
        let partial =
            parallel_chunks(data.len(), 8, |_, s, e| data[s..e].iter().sum::<f64>());
        let total: f64 = partial.into_iter().sum();
        assert_eq!(total, data.iter().sum::<f64>());
    }

    #[test]
    fn pool_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = scoped_pool(items, 8, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_inputs() {
        assert!(parallel_chunks(0, 4, |_, _, _| ()).is_empty());
        assert!(scoped_pool(Vec::<usize>::new(), 4, |i| i).is_empty());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_chunks(10, 1, |i, s, e| (i, s, e));
        assert_eq!(out, vec![(0, 0, 10)]);
    }

    #[test]
    fn pool_spawns_once_ever() {
        ensure_init();
        let s0 = spawn_count();
        for round in 0..50 {
            let got = scoped_pool((0..16).collect::<Vec<_>>(), usize::MAX, |i| i + round);
            assert_eq!(got.len(), 16);
            let _ = parallel_chunks(257, usize::MAX, |_, s, e| e - s);
        }
        assert_eq!(spawn_count(), s0, "steady-state scopes must never spawn");
    }

    #[test]
    fn nested_calls_run_inline_on_their_worker() {
        // every chunk of the inner region must run on the thread that owns
        // the outer item — nesting adds zero execution streams
        let placements = scoped_pool((0..8).collect::<Vec<_>>(), usize::MAX, |_| {
            let outer: ThreadId = std::thread::current().id();
            let inner: Vec<ThreadId> =
                parallel_chunks(64, usize::MAX, |_, _, _| std::thread::current().id());
            (outer, inner)
        });
        for (outer, inner) in placements {
            for t in inner {
                assert_eq!(t, outer, "nested region escaped its worker");
            }
        }
    }

    // NB: the "peak_active() ≤ num_threads() under nesting" assertion lives
    // in rust/tests/executor_parallel.rs, where the test binary controls
    // every scope in the process — inside this lib binary, unrelated tests
    // open scopes concurrently and the global gauge counts their
    // submitters too.

    #[test]
    fn cap_of_one_is_fully_serial() {
        let here = std::thread::current().id();
        let ids: HashSet<ThreadId> = with_worker_cap(1, || {
            scoped_pool((0..32).collect::<Vec<_>>(), usize::MAX, |_| {
                std::thread::current().id()
            })
        })
        .into_iter()
        .collect();
        assert_eq!(ids.len(), 1);
        assert!(ids.contains(&here));
    }

    #[test]
    fn caps_only_tighten_under_nesting() {
        with_worker_cap(2, || {
            assert_eq!(current_worker_cap(), 2);
            with_worker_cap(64, || assert_eq!(current_worker_cap(), 2));
            with_worker_cap(1, || assert_eq!(current_worker_cap(), 1));
            assert_eq!(current_worker_cap(), 2);
        });
        assert_eq!(current_worker_cap(), usize::MAX);
    }

    #[test]
    fn join_returns_both_lanes() {
        let xs: Vec<u64> = (0..1000).collect();
        let (a, b) = join(|| xs.iter().sum::<u64>(), || xs.iter().rev().max().copied());
        assert_eq!(a, 499_500);
        assert_eq!(b, Some(999));
    }

    #[test]
    fn join_inside_scope_runs_serial() {
        let out = scoped_pool((0..4).collect::<Vec<_>>(), usize::MAX, |i| {
            let here = std::thread::current().id();
            let (ta, tb) =
                join(|| std::thread::current().id(), || std::thread::current().id());
            assert_eq!(ta, here);
            assert_eq!(tb, here, "nested join offloaded from a worker");
            i
        });
        assert_eq!(out, vec![0, 1, 2, 3]);
    }

    #[test]
    fn scope_panics_propagate_to_submitter() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            scoped_pool((0..8).collect::<Vec<_>>(), usize::MAX, |i| {
                if i == 5 {
                    panic!("job 5 exploded");
                }
                i
            })
        }));
        assert!(result.is_err(), "panic was swallowed");
        // the pool must still be usable afterwards
        let ok = scoped_pool((0..8).collect::<Vec<_>>(), usize::MAX, |i| i * 3);
        assert_eq!(ok, (0..8).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn join_panics_propagate_and_pool_survives() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            join(|| 1u32, || -> u32 { panic!("reader lane died") })
        }));
        assert!(r.is_err());
        let (a, b) = join(|| 2u32, || 3u32);
        assert_eq!((a, b), (2, 3));
    }
}
