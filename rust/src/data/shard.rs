//! Out-of-core sharded datasets — the third storage backend
//! (dense | CSC | **sharded**, DESIGN.md §10) and the reader half of the
//! MTD3 container ([`super::io`] is the writer half).
//!
//! A [`ShardedDataset`] keeps the matrix on disk in fixed-width column
//! blocks and faults blocks into RAM on demand through a pinned-block LRU
//! ([`crate::linalg::BlockCache`]). Each loaded block is an ordinary
//! in-RAM [`Dataset`] restricted to that block's column range (dense or
//! CSC, preserving the task's on-disk backend), so every kernel, screener
//! and solver below works on blocks unchanged.
//!
//! **Why this is not a [`MatrixStore`] variant.** The `ColRef` seam hands
//! out *borrowed* per-column views; a borrow into an evictable block
//! could outlive the block. The shard backend therefore sits one level
//! up, at the dataset seam: consumers iterate whole blocks (holding an
//! `Arc` pin for exactly the duration of the sweep) instead of single
//! columns. The block-streaming sweeps in [`crate::ops`] and the
//! screen-before-load pipeline in `screening::shard` are built on that
//! contract — via [`ShardedDataset::for_each_block_pipelined`], which
//! overlaps the decode of block b+1 with the sweep of block b on the
//! persistent executor (DESIGN.md §11) while consuming blocks strictly
//! in order, so results stay bit-identical to a serial stream — and
//! [`ShardedDataset::restrict`] materializes only the surviving columns
//! into a normal in-RAM dataset for the solver: peak RSS scales with the
//! active set plus the cache budget, not with `d`.

use super::io::{self, Fnv64};
use super::{Dataset, MatrixStore, Task};
use crate::linalg::{BlockCache, ColRef, CscMatrix};
use crate::util::executor;
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::{BufReader, Read, Seek, SeekFrom};
use std::ops::Range;
use std::path::{Path, PathBuf};
use crate::util::Stopwatch;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default block-cache budget (bytes) for [`ShardedDataset::open`].
pub const DEFAULT_CACHE_BYTES: usize = 256 << 20;

struct BlockEntry {
    offset: u64,
    len: u64,
    checksum: u64,
}

/// An MTD3 shard file opened for on-demand column-block access. See the
/// module docs for the memory model and `data::io` for the layout.
pub struct ShardedDataset {
    name: String,
    d: usize,
    ns: Vec<usize>,
    y: Vec<Vec<f32>>,
    block_cols: usize,
    table: Vec<BlockEntry>,
    path: PathBuf,
    file: Mutex<File>,
    cache: BlockCache<Dataset>,
    bytes_read: AtomicU64,
    blocks_loaded: AtomicU64,
    prefetch: AtomicBool,
    prefetch_issued: AtomicU64,
    prefetch_hits: AtomicU64,
    stall_nanos: AtomicU64,
}

/// Overlap accounting of the shard's prefetch pipeline (DESIGN.md §11),
/// accumulated across every pipelined streaming sweep since open (or the
/// last [`ShardedDataset::reset_prefetch_stats`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefetchStats {
    /// next-block prefetches issued alongside a block sweep
    pub issued: u64,
    /// prefetched blocks found resident when the sweep came to consume
    /// them — each one is a block decode fully hidden behind compute
    pub hits: u64,
    /// wall time the streaming loops spent blocked on a cold block load
    /// (the initial block of each sweep, plus any prefetch that lost the
    /// race or was evicted before consumption)
    pub stall_secs: f64,
}

/// Byte cursor over one block's payload with truncation checks.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
    block: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(
            self.pos + n <= self.buf.len(),
            "shard block {} truncated ({} bytes needed at offset {}, {} available)",
            self.block,
            n,
            self.pos,
            self.buf.len()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn take_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn take_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

impl ShardedDataset {
    /// Open a shard with the default cache budget
    /// ([`DEFAULT_CACHE_BYTES`]).
    pub fn open(path: &Path) -> Result<ShardedDataset> {
        Self::open_with_cache(path, DEFAULT_CACHE_BYTES)
    }

    /// Open a shard with an explicit block-cache budget in bytes. Parses
    /// and checksums the header only — no block is read until asked for.
    pub fn open_with_cache(path: &Path, cache_bytes: usize) -> Result<ShardedDataset> {
        assert!(cfg!(target_endian = "little"), "mtd format is little-endian");
        let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut r = BufReader::new(f);
        let mut hash = Fnv64::new();

        let read_hashed = |r: &mut BufReader<File>,
                               hash: &mut Fnv64,
                               n: usize|
         -> Result<Vec<u8>> {
            let mut buf = vec![0u8; n];
            r.read_exact(&mut buf).context("mtd3 header truncated")?;
            hash.update(&buf);
            Ok(buf)
        };

        let magic = read_hashed(&mut r, &mut hash, 4)?;
        if magic != io::MAGIC_V3 {
            bail!(
                "{} is not an mtd3 shard file (bad magic) — convert a .mtd \
                 dataset with `repro shard`",
                path.display()
            );
        }
        let name_len =
            u32::from_le_bytes(read_hashed(&mut r, &mut hash, 4)?.try_into().unwrap())
                as usize;
        if name_len > 4096 {
            bail!("unreasonable name length {name_len}");
        }
        let name = String::from_utf8(read_hashed(&mut r, &mut hash, name_len)?)
            .context("dataset name not utf8")?;
        let d = u64::from_le_bytes(read_hashed(&mut r, &mut hash, 8)?.try_into().unwrap())
            as usize;
        let t = u64::from_le_bytes(read_hashed(&mut r, &mut hash, 8)?.try_into().unwrap())
            as usize;
        if d == 0 || t == 0 || d > 100_000_000 || t > 100_000 {
            bail!("corrupt mtd3 header: d={d} t={t}");
        }
        let mut ns = Vec::with_capacity(t);
        for _ in 0..t {
            let n = u64::from_le_bytes(
                read_hashed(&mut r, &mut hash, 8)?.try_into().unwrap(),
            ) as usize;
            if n == 0 || n > u32::MAX as usize || n.checked_mul(d).is_none() {
                bail!("corrupt mtd3 task header: n={n}");
            }
            ns.push(n);
        }
        let mut y = Vec::with_capacity(t);
        for &n in &ns {
            y.push(io::bytes_to_f32s(&read_hashed(&mut r, &mut hash, n * 4)?));
        }
        let block_cols = u64::from_le_bytes(
            read_hashed(&mut r, &mut hash, 8)?.try_into().unwrap(),
        ) as usize;
        let n_blocks = u64::from_le_bytes(
            read_hashed(&mut r, &mut hash, 8)?.try_into().unwrap(),
        ) as usize;
        if block_cols == 0 || block_cols > d || n_blocks != d.div_ceil(block_cols) {
            bail!("corrupt mtd3 header: block_cols={block_cols} n_blocks={n_blocks} d={d}");
        }
        let table_bytes = read_hashed(&mut r, &mut hash, n_blocks * 24)?;
        let words = io::bytes_to_u64s(&table_bytes);
        let table: Vec<BlockEntry> = words
            .chunks_exact(3)
            .map(|w| BlockEntry { offset: w[0], len: w[1], checksum: w[2] })
            .collect();

        let mut digest_bytes = [0u8; 8];
        r.read_exact(&mut digest_bytes).context("mtd3 header truncated")?;
        if u64::from_le_bytes(digest_bytes) != hash.digest() {
            bail!(
                "mtd3 header checksum mismatch in {} — the file is corrupt; \
                 regenerate it with `repro shard`",
                path.display()
            );
        }

        Ok(ShardedDataset {
            name,
            d,
            ns,
            y,
            block_cols,
            table,
            path: path.to_path_buf(),
            file: Mutex::new(r.into_inner()),
            cache: BlockCache::new(cache_bytes),
            bytes_read: AtomicU64::new(0),
            blocks_loaded: AtomicU64::new(0),
            prefetch: AtomicBool::new(true),
            prefetch_issued: AtomicU64::new(0),
            prefetch_hits: AtomicU64::new(0),
            stall_nanos: AtomicU64::new(0),
        })
    }

    /// Dataset name carried in the shard header.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Feature count (shared across tasks).
    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of tasks.
    pub fn t(&self) -> usize {
        self.ns.len()
    }

    /// Per-task sample counts.
    pub fn ns(&self) -> &[usize] {
        &self.ns
    }

    /// Total sample count N = Σ N_t.
    pub fn total_n(&self) -> usize {
        self.ns.iter().sum()
    }

    /// The response vectors (resident in the header — O(N), never paged).
    pub fn y(&self) -> &[Vec<f32>] {
        &self.y
    }

    /// Responses widened to the stacked f64 form the dual machinery uses
    /// (`ops::Stacked`).
    pub fn y64(&self) -> Vec<Vec<f64>> {
        self.y.iter().map(|yt| yt.iter().map(|&v| v as f64).collect()).collect()
    }

    /// Columns per block (the last block may be narrower).
    pub fn block_cols(&self) -> usize {
        self.block_cols
    }

    /// Number of column blocks in the shard.
    pub fn n_blocks(&self) -> usize {
        self.table.len()
    }

    /// Column range `[first, last)` covered by block `b`.
    pub fn block_range(&self, b: usize) -> Range<usize> {
        let first = b * self.block_cols;
        first..(first + self.block_cols).min(self.d)
    }

    /// The block containing column `l`.
    pub fn block_of(&self, l: usize) -> usize {
        debug_assert!(l < self.d);
        l / self.block_cols
    }

    /// Bytes a dense in-RAM load of the full matrix would cost
    /// (Σ_t N_t · d · 4) — the denominator of the memory-saving metric in
    /// `BENCH_shard.json`.
    pub fn dense_bytes(&self) -> u64 {
        self.ns.iter().map(|&n| (n as u64) * (self.d as u64) * 4).sum()
    }

    /// Total on-disk block payload bytes (what a full sequential stream
    /// reads once).
    pub fn payload_bytes(&self) -> u64 {
        self.table.iter().map(|e| e.len).sum()
    }

    /// Bytes read from disk so far (cache misses only).
    pub fn bytes_read(&self) -> u64 {
        self.bytes_read.load(Ordering::Relaxed)
    }

    /// Block loads from disk so far (cache misses only).
    pub fn blocks_loaded(&self) -> u64 {
        self.blocks_loaded.load(Ordering::Relaxed)
    }

    /// Reset the I/O counters (per-phase accounting in benches).
    pub fn reset_io_stats(&self) {
        self.bytes_read.store(0, Ordering::Relaxed);
        self.blocks_loaded.store(0, Ordering::Relaxed);
    }

    /// Bytes currently resident in the block cache.
    pub fn cache_resident_bytes(&self) -> usize {
        self.cache.resident_bytes()
    }

    /// Enable or disable the next-block prefetch pipeline (on by
    /// default). Results are bit-identical either way — prefetch only
    /// warms the cache — so this is a benchmarking/ablation knob
    /// (`cargo bench --bench exec` measures the overlap it buys).
    pub fn set_prefetch(&self, on: bool) {
        self.prefetch.store(on, Ordering::Relaxed);
    }

    /// Whether the prefetch pipeline is enabled (see
    /// [`ShardedDataset::set_prefetch`]).
    pub fn prefetch_enabled(&self) -> bool {
        self.prefetch.load(Ordering::Relaxed)
    }

    /// Overlap accounting accumulated by the pipelined streaming sweeps.
    pub fn prefetch_stats(&self) -> PrefetchStats {
        PrefetchStats {
            issued: self.prefetch_issued.load(Ordering::Relaxed),
            hits: self.prefetch_hits.load(Ordering::Relaxed),
            stall_secs: self.stall_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
        }
    }

    /// Reset the prefetch/stall counters (per-phase accounting in benches
    /// and [`crate::coordinator::path::ShardRunResult`]).
    pub fn reset_prefetch_stats(&self) {
        self.prefetch_issued.store(0, Ordering::Relaxed);
        self.prefetch_hits.store(0, Ordering::Relaxed);
        self.stall_nanos.store(0, Ordering::Relaxed);
    }

    /// Fetch block `b` for in-order consumption, attributing the fetch to
    /// the pipeline's overlap ledger: a resident block after a prefetch
    /// counts as a hit, a cold load counts its wall time as stall.
    fn consume_block(&self, b: usize, prefetched: bool) -> Result<Arc<Dataset>> {
        if self.cache.contains(b) {
            if prefetched {
                self.prefetch_hits.fetch_add(1, Ordering::Relaxed);
            }
            return self.block(b);
        }
        let sw = Stopwatch::started();
        let blk = self.block(b);
        self.stall_nanos
            .fetch_add(sw.elapsed().as_nanos() as u64, Ordering::Relaxed);
        blk
    }

    /// Stream every block through `f` **in block order** — the iteration
    /// the block-streaming sweeps ([`crate::ops::stream_gscore`] and
    /// friends) are built on — while a reader lane decodes block b+1
    /// (seek + read + checksum + parse into the cache) on one pool worker
    /// as `f` sweeps block b (DESIGN.md §11). Consumption order, and
    /// therefore per-column accumulation order, is exactly the serial
    /// loop's: results are bit-identical with prefetch on, off, or
    /// unavailable (worker thread, `MTFL_THREADS=1`). While the reader
    /// lane runs, `f`'s own parallel sweeps are capped one stream short
    /// so the composition still totals `num_threads()`.
    ///
    /// Errors: a failing sweep surfaces first (as in the serial loop); a
    /// failing read (I/O, checksum) surfaces when its block is reached.
    pub fn for_each_block_pipelined<F>(&self, f: F) -> Result<()>
    where
        F: FnMut(usize, &Dataset) -> Result<()> + Send,
    {
        self.for_each_block_range_pipelined(0..self.n_blocks(), f)
    }

    /// [`Self::for_each_block_pipelined`] over a contiguous *sub-range*
    /// of blocks — the unit a distributed worker sweeps (DESIGN.md §16):
    /// a worker assigned blocks `[s, e)` streams exactly those through
    /// its own cache + prefetch pipeline, and because every sweep writes
    /// per-block output slices, the concatenation over workers equals
    /// the full-range stream bit-for-bit. Consumption stays strictly in
    /// block order within the range; the same overlap ledger applies.
    pub fn for_each_block_range_pipelined<F>(
        &self,
        blocks: Range<usize>,
        mut f: F,
    ) -> Result<()>
    where
        F: FnMut(usize, &Dataset) -> Result<()> + Send,
    {
        let nb = self.n_blocks();
        anyhow::ensure!(
            blocks.start <= blocks.end && blocks.end <= nb,
            "block range {blocks:?} out of bounds for {nb} blocks"
        );
        if blocks.is_empty() {
            return Ok(());
        }
        let mut cur = self.consume_block(blocks.start, false)?;
        let mut prefetched_next = false;
        for b in blocks.clone() {
            let next = b + 1;
            let nb = blocks.end;
            // only pipeline when the next block genuinely needs decoding:
            // on a warm cache the sweep keeps its full width and the
            // issued/hits ledger measures real decode-behind-compute
            // overlap, not ordinary residency
            let pipelined = next < nb
                && self.prefetch_enabled()
                && executor::can_offload()
                && !self.cache.contains(next);
            if pipelined {
                self.prefetch_issued.fetch_add(1, Ordering::Relaxed);
                // leave one execution stream for the reader lane, inside
                // whatever width the caller already capped us to
                let sweep_cap = executor::current_worker_cap()
                    .min(crate::util::num_threads())
                    .saturating_sub(1)
                    .max(1);
                let fref = &mut f;
                let cur_ref: &Dataset = &cur;
                let (sweep, load): (Result<()>, Result<()>) = executor::join(
                    move || {
                        executor::with_worker_cap(sweep_cap, || fref(b, cur_ref))
                    },
                    || self.block(next).map(drop),
                );
                sweep?;
                load?;
                prefetched_next = true;
            } else {
                let cur_ref: &Dataset = &cur;
                f(b, cur_ref)?;
                prefetched_next = false;
            }
            if next < nb {
                cur = self.consume_block(next, prefetched_next)?;
            }
        }
        Ok(())
    }

    /// Fetch block `b` as an in-RAM [`Dataset`] over its column range
    /// (cached; checksum-verified on every disk load). The returned `Arc`
    /// pins the block against eviction while held. Block tasks carry
    /// **empty `y` vectors** — the responses live once in the shard
    /// header ([`ShardedDataset::y`]), not per cached block, so the cache
    /// budget is spent on matrix bytes only; the block sweeps
    /// (correlation, scores, norms) never read `y`.
    pub fn block(&self, b: usize) -> Result<Arc<Dataset>> {
        anyhow::ensure!(
            b < self.table.len(),
            "block {b} out of range ({} blocks)",
            self.table.len()
        );
        self.cache.get_or_load(b, || {
            let e = &self.table[b];
            let mut buf = vec![0u8; e.len as usize];
            {
                let mut f = self.file.lock().unwrap();
                f.seek(SeekFrom::Start(e.offset))
                    .and_then(|_| f.read_exact(&mut buf))
                    .with_context(|| {
                        format!("read block {b} of {}", self.path.display())
                    })?;
            }
            let mut h = Fnv64::new();
            h.update(&buf);
            if h.digest() != e.checksum {
                bail!(
                    "shard block {b} checksum mismatch in {} — the file is \
                     corrupt; regenerate it with `repro shard`",
                    self.path.display()
                );
            }
            let ds = self.parse_block(b, &buf)?;
            self.blocks_loaded.fetch_add(1, Ordering::Relaxed);
            self.bytes_read.fetch_add(e.len, Ordering::Relaxed);
            let resident = ds.mem_bytes();
            Ok((ds, resident))
        })
    }

    fn parse_block(&self, b: usize, buf: &[u8]) -> Result<Dataset> {
        let range = self.block_range(b);
        let cols = range.len();
        let mut cur = Cursor { buf, pos: 0, block: b };
        let mut tasks = Vec::with_capacity(self.t());
        for (ti, &n) in self.ns.iter().enumerate() {
            let x = match cur.take_u8()? {
                io::STORAGE_DENSE => {
                    MatrixStore::Dense(io::bytes_to_f32s(cur.take(cols * n * 4)?))
                }
                io::STORAGE_CSC => {
                    let nnz = cur.take_u64()? as usize;
                    anyhow::ensure!(
                        nnz <= cols * n,
                        "shard block {b}: nnz={nnz} > cols*n={}",
                        cols * n
                    );
                    let col_ptr: Vec<usize> =
                        io::bytes_to_u64s(cur.take((cols + 1) * 8)?)
                            .into_iter()
                            .map(|p| p as usize)
                            .collect();
                    let indices = io::bytes_to_u32s(cur.take(nnz * 4)?);
                    let values = io::bytes_to_f32s(cur.take(nnz * 4)?);
                    let m = CscMatrix { n, d: cols, col_ptr, indices, values };
                    m.validate().with_context(|| {
                        format!("shard block {b}: corrupt csc section (task {ti})")
                    })?;
                    MatrixStore::Csc(m)
                }
                other => bail!("shard block {b}: unknown storage tag {other}"),
            };
            // responses stay header-resident (see `block` docs): y is empty
            tasks.push(Task { x, y: Vec::new(), n });
        }
        anyhow::ensure!(
            cur.pos == buf.len(),
            "shard block {b}: {} trailing bytes",
            buf.len() - cur.pos
        );
        Ok(Dataset { name: format!("{}[block {b}]", self.name), d: cols, tasks })
    }

    /// Materialize the kept columns into an in-RAM dataset — the
    /// screen-before-load step that turns a certified keep-set into a
    /// solver-ready problem. `keep` must be sorted, distinct and
    /// in-range (the contract of [`Dataset::restrict`], whose output this
    /// matches column-for-column, backend included). Touches only the
    /// blocks that contain surviving columns.
    pub fn restrict(&self, keep: &[usize]) -> Result<Dataset> {
        for w in keep.windows(2) {
            anyhow::ensure!(w[0] < w[1], "keep indices must be sorted and distinct");
        }
        if let Some(&last) = keep.last() {
            anyhow::ensure!(last < self.d, "keep index {last} out of range (d={})", self.d);
        }
        if keep.is_empty() {
            // degenerate but contract-honoring: empty stores in each
            // task's on-disk backend (read off block 0), like
            // `Dataset::restrict(&[])` on the materialized dataset
            let blk = self.block(0)?;
            let tasks = blk
                .tasks
                .iter()
                .enumerate()
                .map(|(ti, task)| {
                    let n = self.ns[ti];
                    let x = match &task.x {
                        MatrixStore::Dense(_) => MatrixStore::Dense(Vec::new()),
                        MatrixStore::Csc(_) => MatrixStore::Csc(CscMatrix {
                            n,
                            d: 0,
                            col_ptr: vec![0],
                            indices: Vec::new(),
                            values: Vec::new(),
                        }),
                    };
                    Task { x, y: self.y[ti].clone(), n }
                })
                .collect();
            return Ok(Dataset { name: format!("{}[0]", self.name), d: 0, tasks });
        }
        enum Acc {
            Dense(Vec<f32>),
            Csc { col_ptr: Vec<usize>, indices: Vec<u32>, values: Vec<f32> },
        }
        let t_count = self.t();
        let mut accs: Vec<Option<Acc>> = (0..t_count).map(|_| None).collect();
        let mut i = 0usize;
        while i < keep.len() {
            let b = self.block_of(keep[i]);
            let range = self.block_range(b);
            let mut j = i;
            while j < keep.len() && keep[j] < range.end {
                j += 1;
            }
            let blk = self.block(b)?; // Arc pin lives for this iteration only
            for (ti, task) in blk.tasks.iter().enumerate() {
                let acc = accs[ti].get_or_insert_with(|| match &task.x {
                    MatrixStore::Dense(_) => Acc::Dense(Vec::new()),
                    MatrixStore::Csc(_) => Acc::Csc {
                        col_ptr: vec![0],
                        indices: Vec::new(),
                        values: Vec::new(),
                    },
                });
                for &l in &keep[i..j] {
                    let col = task.col(l - range.start);
                    match acc {
                        // the backend is per-task uniform across blocks, so
                        // the dense arm always sees a dense ColRef; to_vec
                        // is only the mixed-backend fallback
                        Acc::Dense(buf) => match col {
                            ColRef::Dense(c) => buf.extend_from_slice(c),
                            sparse => buf.extend_from_slice(&sparse.to_vec()),
                        },
                        Acc::Csc { col_ptr, indices, values } => {
                            match col {
                                ColRef::Sparse { indices: ix, values: vs, .. } => {
                                    indices.extend_from_slice(ix);
                                    values.extend_from_slice(vs);
                                }
                                ColRef::Dense(c) => {
                                    for (ri, &v) in c.iter().enumerate() {
                                        if v != 0.0 {
                                            indices.push(ri as u32);
                                            values.push(v);
                                        }
                                    }
                                }
                            }
                            col_ptr.push(indices.len());
                        }
                    }
                }
            }
            i = j;
        }
        let tasks: Vec<Task> = accs
            .into_iter()
            .enumerate()
            .map(|(ti, acc)| {
                let n = self.ns[ti];
                let x = match acc {
                    // non-empty keep touched ≥ 1 block, initializing every task
                    None => unreachable!("accumulator initialized by the first block"),
                    Some(Acc::Dense(buf)) => MatrixStore::Dense(buf),
                    Some(Acc::Csc { col_ptr, indices, values }) => {
                        MatrixStore::Csc(CscMatrix {
                            n,
                            d: keep.len(),
                            col_ptr,
                            indices,
                            values,
                        })
                    }
                };
                Task { x, y: self.y[ti].clone(), n }
            })
            .collect();
        Ok(Dataset { name: format!("{}[{}]", self.name, keep.len()), d: keep.len(), tasks })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::io::save_sharded;
    use crate::data::synthetic::{synthetic1, SynthOptions};

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("mtfl_shard_{}_{}", std::process::id(), name))
    }

    fn small() -> Dataset {
        synthetic1(&SynthOptions { t: 3, n: 9, d: 37, seed: 21, ..Default::default() }).0
    }

    #[test]
    fn header_round_trip_and_block_geometry() {
        let ds = small();
        let p = tmp("geom.mtd3");
        // ~7 columns per block at n=9, t=3: 3·9·4 = 108 B/col
        let summary = save_sharded(&ds, &p, 108 * 7).unwrap();
        let sh = ShardedDataset::open(&p).unwrap();
        assert_eq!(sh.name(), ds.name);
        assert_eq!(sh.d(), 37);
        assert_eq!(sh.t(), 3);
        assert_eq!(sh.ns(), &[9, 9, 9]);
        assert_eq!(sh.block_cols(), summary.block_cols);
        assert_eq!(sh.n_blocks(), summary.blocks);
        assert_eq!(sh.n_blocks(), 37usize.div_ceil(summary.block_cols));
        // ranges tile [0, d) exactly
        let mut covered = 0usize;
        for b in 0..sh.n_blocks() {
            let r = sh.block_range(b);
            assert_eq!(r.start, covered);
            covered = r.end;
            for l in r.clone() {
                assert_eq!(sh.block_of(l), b);
            }
        }
        assert_eq!(covered, 37);
        for (ti, task) in ds.tasks.iter().enumerate() {
            assert_eq!(sh.y()[ti], task.y);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn blocks_reproduce_columns_exactly() {
        let ds = small();
        let p = tmp("cols.mtd3");
        save_sharded(&ds, &p, 200).unwrap();
        let sh = ShardedDataset::open(&p).unwrap();
        for b in 0..sh.n_blocks() {
            let blk = sh.block(b).unwrap();
            let range = sh.block_range(b);
            assert_eq!(blk.d, range.len());
            for t in 0..ds.t() {
                for (local, l) in range.clone().enumerate() {
                    assert_eq!(blk.col(t, local).to_vec(), ds.col(t, l).to_vec());
                }
            }
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn cache_hits_do_not_reread_disk() {
        let ds = small();
        let p = tmp("cachehit.mtd3");
        save_sharded(&ds, &p, 1 << 20).unwrap(); // one block
        let sh = ShardedDataset::open(&p).unwrap();
        assert_eq!(sh.n_blocks(), 1);
        sh.block(0).unwrap();
        let after_first = sh.bytes_read();
        assert!(after_first > 0);
        sh.block(0).unwrap();
        assert_eq!(sh.bytes_read(), after_first, "second access must hit the cache");
        assert_eq!(sh.blocks_loaded(), 1);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn tiny_cache_bounds_residency_but_stays_correct() {
        let ds = small();
        let p = tmp("tinycache.mtd3");
        save_sharded(&ds, &p, 150).unwrap(); // several narrow blocks
        // budget of one byte: every unpinned block is evicted immediately
        let sh = ShardedDataset::open_with_cache(&p, 1).unwrap();
        assert!(sh.n_blocks() > 2);
        let keep: Vec<usize> = (0..ds.d).collect();
        let back = sh.restrict(&keep).unwrap();
        for t in 0..ds.t() {
            for l in 0..ds.d {
                assert_eq!(back.col(t, l).to_vec(), ds.col(t, l).to_vec());
            }
        }
        // with no handles held, at most one block's bytes stay resident
        let one_block = sh.block(0).unwrap().mem_bytes() + 3 * 9 * 4;
        assert!(
            sh.cache_resident_bytes() <= one_block,
            "cache kept {} bytes with a 1-byte budget",
            sh.cache_resident_bytes()
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn restrict_matches_in_ram_restrict() {
        let ds = small();
        let p = tmp("restrict.mtd3");
        save_sharded(&ds, &p, 150).unwrap();
        let sh = ShardedDataset::open(&p).unwrap();
        let keep = vec![0usize, 3, 11, 12, 20, 36];
        let a = sh.restrict(&keep).unwrap();
        let b = ds.restrict(&keep);
        assert_eq!(a.name, b.name);
        assert_eq!(a.d, b.d);
        for t in 0..ds.t() {
            match (&a.tasks[t].x, &b.tasks[t].x) {
                (MatrixStore::Dense(x), MatrixStore::Dense(y)) => assert_eq!(x, y),
                other => panic!("backend mismatch: {other:?}"),
            }
            assert_eq!(a.tasks[t].y, b.tasks[t].y);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn pipelined_stream_visits_blocks_in_order_with_identical_contents() {
        let ds = small();
        let p = tmp("pipeline.mtd3");
        save_sharded(&ds, &p, 150).unwrap(); // several narrow blocks
        let sh = ShardedDataset::open(&p).unwrap();
        assert!(sh.n_blocks() > 3);
        for prefetch in [true, false] {
            sh.set_prefetch(prefetch);
            let mut seen: Vec<usize> = Vec::new();
            sh.for_each_block_pipelined(|b, blk| {
                let range = sh.block_range(b);
                assert_eq!(blk.d, range.len());
                for t in 0..ds.t() {
                    for (local, l) in range.clone().enumerate() {
                        assert_eq!(blk.col(t, local).to_vec(), ds.col(t, l).to_vec());
                    }
                }
                seen.push(b);
                Ok(())
            })
            .unwrap();
            assert_eq!(
                seen,
                (0..sh.n_blocks()).collect::<Vec<_>>(),
                "prefetch={prefetch}: consumption escaped block order"
            );
        }
        let stats = sh.prefetch_stats();
        assert!(stats.hits <= stats.issued, "hits {} > issued {}", stats.hits, stats.issued);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn range_stream_concatenation_matches_full_stream() {
        // the distribution invariant (DESIGN.md §16): sweeping [0, m) and
        // [m, nb) separately and concatenating the per-block outputs must
        // visit the same blocks with the same contents as one full sweep
        let ds = small();
        let p = tmp("rangestream.mtd3");
        save_sharded(&ds, &p, 150).unwrap();
        let sh = ShardedDataset::open(&p).unwrap();
        let nb = sh.n_blocks();
        assert!(nb > 3);
        let mut full: Vec<(usize, usize)> = Vec::new();
        sh.for_each_block_pipelined(|b, blk| {
            full.push((b, blk.d));
            Ok(())
        })
        .unwrap();
        let mid = nb / 2;
        let mut split: Vec<(usize, usize)> = Vec::new();
        for range in [0..mid, mid..nb] {
            sh.for_each_block_range_pipelined(range, |b, blk| {
                split.push((b, blk.d));
                Ok(())
            })
            .unwrap();
        }
        assert_eq!(full, split);
        // empty ranges are fine; out-of-bounds ranges are not
        sh.for_each_block_range_pipelined(mid..mid, |_, _| panic!("must not run")).unwrap();
        assert!(sh.for_each_block_range_pipelined(0..nb + 1, |_, _| Ok(())).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn pipelined_stream_propagates_sweep_errors() {
        let ds = small();
        let p = tmp("pipeerr.mtd3");
        save_sharded(&ds, &p, 150).unwrap();
        let sh = ShardedDataset::open(&p).unwrap();
        let mut calls = 0usize;
        let err = sh
            .for_each_block_pipelined(|b, _| {
                calls += 1;
                if b == 1 {
                    anyhow::bail!("sweep failed on block {b}")
                }
                Ok(())
            })
            .unwrap_err();
        assert!(err.to_string().contains("block 1"), "got: {err}");
        assert_eq!(calls, 2, "must stop at the failing block");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn prefetch_stats_reset_and_accumulate() {
        let ds = small();
        let p = tmp("pfstats.mtd3");
        save_sharded(&ds, &p, 150).unwrap();
        let sh = ShardedDataset::open(&p).unwrap();
        sh.for_each_block_pipelined(|_, _| Ok(())).unwrap();
        // the initial block of the sweep is always a cold (stalled) load,
        // so the stall ledger must have moved
        assert!(
            sh.prefetch_stats().stall_secs > 0.0,
            "cold initial block load recorded no stall time"
        );
        sh.reset_prefetch_stats();
        assert_eq!(
            sh.prefetch_stats(),
            PrefetchStats { issued: 0, hits: 0, stall_secs: 0.0 }
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn restrict_rejects_unsorted_keep() {
        let ds = small();
        let p = tmp("unsorted.mtd3");
        save_sharded(&ds, &p, 150).unwrap();
        let sh = ShardedDataset::open(&p).unwrap();
        assert!(sh.restrict(&[3, 1]).is_err());
        assert!(sh.restrict(&[0, 0]).is_err());
        assert!(sh.restrict(&[999]).is_err());
        std::fs::remove_file(&p).ok();
    }
}
