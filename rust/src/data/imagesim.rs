//! Animal-with-Attributes-like image-feature workload (simulated — see
//! DESIGN.md §5).
//!
//! The real set concatenates seven descriptor families (color histograms,
//! LSS, PHOG, SIFT, colorSIFT, SURF, DECAF) into 15036 dims; 20 one-vs-rest
//! tasks with ±30 images. The screening-relevant structure: feature
//! *blocks* with very different scales and intra-block correlation, and
//! class signal concentrated in a subset of blocks. We simulate each block
//! as a low-rank-plus-noise Gaussian with a per-block scale, plus per-class
//! mean offsets on a sparse subset of dimensions.

use super::{Dataset, Task};
use crate::util::Pcg64;

/// Knobs of the AwA-like generator.
#[derive(Debug, Clone)]
pub struct ImageSimOptions {
    /// number of classes == number of one-vs-rest tasks
    pub classes: usize,
    /// positive (== negative) samples per task
    pub n_pos: usize,
    /// per-block dims; total d = sum (default mirrors 7 heterogeneous blocks)
    pub blocks: Vec<usize>,
    /// rank of the intra-block correlation structure
    pub rank: usize,
    /// RNG seed (every experiment seeds explicitly)
    pub seed: u64,
}

impl Default for ImageSimOptions {
    fn default() -> Self {
        ImageSimOptions {
            classes: 10,
            n_pos: 30,
            // scaled-down echo of the 7 descriptor families
            blocks: vec![288, 512, 252, 1000, 1000, 512, 1024],
            rank: 8,
            seed: 0,
        }
    }
}

/// Generate the AwA-shaped workload (block-heterogeneous image features,
/// DESIGN.md §5).
pub fn imagesim(opts: &ImageSimOptions) -> Dataset {
    let ImageSimOptions { classes, n_pos, ref blocks, rank, seed } = *opts;
    let d: usize = blocks.iter().sum();
    let mut root = Pcg64::with_stream(seed, 0x1a6e);

    // per-block scale (descriptor families differ by orders of magnitude)
    let scales: Vec<f64> = blocks.iter().map(|_| 10f64.powf(root.uniform_in(-1.0, 1.0))).collect();
    // per-block mixing matrix (rank x dim) for intra-block correlation
    let mixers: Vec<Vec<f64>> = blocks
        .iter()
        .map(|&bd| (0..rank * bd).map(|_| root.normal() * 0.7).collect())
        .collect();
    // per-class sparse mean offsets
    let class_means: Vec<Vec<(usize, f64)>> = (0..classes)
        .map(|_| {
            let k = (d / 50).max(4);
            root.choose_distinct(d, k)
                .into_iter()
                .map(|l| (l, root.normal() * 1.5))
                .collect()
        })
        .collect();

    let gen_image = |rng: &mut Pcg64, class: usize, out: &mut [f64]| {
        let mut off = 0usize;
        for (bi, &bd) in blocks.iter().enumerate() {
            let z: Vec<f64> = (0..rank).map(|_| rng.normal()).collect();
            let m = &mixers[bi];
            for j in 0..bd {
                let mut v = rng.normal() * 0.5;
                for (r, zr) in z.iter().enumerate() {
                    // repro-lint: allow(kernel-reduction): rank-length (~4) mixing fold in the generator, strided access no kernel serves
                    v += m[r * bd + j] * zr;
                }
                out[off + j] = v * scales[bi];
            }
            off += bd;
        }
        for &(l, mu) in &class_means[class] {
            // repro-lint: allow(kernel-reduction): one scatter-add of a class mean per pixel, not a reduction
            out[l] += mu * scales[0].max(1.0);
        }
    };

    let n = 2 * n_pos;
    let mut tasks = Vec::with_capacity(classes);
    let mut img = vec![0.0f64; d];
    for cls in 0..classes {
        let mut rng = root.split(cls as u64);
        let mut x = vec![0.0f32; n * d];
        let mut y = vec![0.0f32; n];
        for ni in 0..n {
            let positive = ni < n_pos;
            y[ni] = if positive { 1.0 } else { -1.0 };
            let src = if positive {
                cls
            } else {
                let mut o = rng.below(classes as u64) as usize;
                if o == cls {
                    o = (o + 1) % classes;
                }
                o
            };
            gen_image(&mut rng, src, &mut img);
            for (l, &v) in img.iter().enumerate() {
                x[l * n + ni] = v as f32;
            }
        }
        tasks.push(Task::dense(x, y, n));
    }
    Dataset { name: "animalsim".into(), d, tasks }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_opts() -> ImageSimOptions {
        ImageSimOptions {
            classes: 3,
            n_pos: 8,
            blocks: vec![32, 64, 16],
            rank: 4,
            seed: 1,
        }
    }

    #[test]
    fn shape() {
        let ds = imagesim(&small_opts());
        ds.validate().unwrap();
        assert_eq!(ds.d, 112);
        assert_eq!(ds.t(), 3);
        assert_eq!(ds.uniform_n(), Some(16));
    }

    #[test]
    fn blocks_have_heterogeneous_scales() {
        let ds = imagesim(&small_opts());
        let b2 = ds.col_sqnorms();
        let t = ds.t();
        let mean_norm = |range: std::ops::Range<usize>| {
            let mut s = 0.0;
            let mut c = 0;
            for l in range {
                s += b2[l * t];
                c += 1;
            }
            (s / c as f64).sqrt()
        };
        let a = mean_norm(0..32);
        let b = mean_norm(32..96);
        let c = mean_norm(96..112);
        let max = a.max(b).max(c);
        let min = a.min(b).min(c);
        assert!(max / min > 1.5, "block scales should differ: {a} {b} {c}");
    }

    #[test]
    fn intra_block_correlation_exceeds_cross_block() {
        let mut o = small_opts();
        o.n_pos = 200; // enough samples for stable correlation
        let ds = imagesim(&o);
        let col = |l: usize| ds.col(0, l).to_vec();
        // single pairs can be weakly correlated by chance at low rank —
        // compare the *average* |corr| over many pairs instead
        let mut r_in = 0.0;
        let mut r_cross = 0.0;
        let mut pairs = 0;
        for i in 0..24 {
            r_in += corr_abs(&col(i), &col(i + 4)); // both in block 0 (dims 0..32)
            r_cross += corr_abs(&col(i), &col(96 + (i % 16))); // block 0 vs block 2
            pairs += 1;
        }
        r_in /= pairs as f64;
        r_cross /= pairs as f64;
        assert!(
            r_in > r_cross + 0.05,
            "mean intra {r_in} not above mean cross {r_cross}"
        );
    }

    fn corr_abs(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len() as f64;
        let ma = a.iter().map(|v| *v as f64).sum::<f64>() / n;
        let mb = b.iter().map(|v| *v as f64).sum::<f64>() / n;
        let mut num = 0.0;
        let (mut va, mut vb) = (0.0, 0.0);
        for i in 0..a.len() {
            let x = a[i] as f64 - ma;
            let y = b[i] as f64 - mb;
            num += x * y;
            va += x * x;
            vb += y * y;
        }
        (num / (va.sqrt() * vb.sqrt())).abs()
    }

    #[test]
    fn deterministic() {
        let o = small_opts();
        assert_eq!(imagesim(&o).tasks[0].x, imagesim(&o).tasks[0].x);
    }
}
