//! Synthetic 1 & Synthetic 2 from the paper (§5.1).
//!
//! Both have T tasks of N samples: `y_t = X_t w*_t + 0.01 ε`, ε ~ N(0,1).
//! Synthetic 1: i.i.d. standard Gaussian entries.
//! Synthetic 2: Gaussian with corr(x_i, x_j) = 0.5^{|i-j|} — an AR(1)
//! process across the feature axis, generated per sample by the standard
//! recursion x_j = φ x_{j-1} + sqrt(1-φ²) ζ_j (exact for AR(1)).
//! The shared support is 10% of features; active rows of W* are standard
//! Gaussian across tasks.

use super::{Dataset, GroundTruth, Task};
use crate::util::Pcg64;

/// Knobs shared by the Synthetic 1/2 generators.
#[derive(Debug, Clone)]
pub struct SynthOptions {
    /// number of tasks
    pub t: usize,
    /// samples per task
    pub n: usize,
    /// shared feature count
    pub d: usize,
    /// fraction of features in the true support
    pub support_frac: f64,
    /// response noise std (the paper uses 0.01)
    pub noise: f64,
    /// RNG seed (every experiment seeds explicitly)
    pub seed: u64,
}

impl Default for SynthOptions {
    fn default() -> Self {
        SynthOptions { t: 20, n: 50, d: 2000, support_frac: 0.10, noise: 0.01, seed: 0 }
    }
}

fn build(opts: &SynthOptions, corr: Option<f64>, name: &str) -> (Dataset, GroundTruth) {
    let SynthOptions { t, n, d, support_frac, noise, seed } = *opts;
    let mut root = Pcg64::with_stream(seed, 0x5e7);

    // shared support (same rows active in every task: the MTFL premise)
    let k = ((support_frac * d as f64).round() as usize).clamp(1, d);
    let mut active = root.choose_distinct(d, k);
    active.sort_unstable();
    let mut w = vec![0.0f64; d * t];
    for &l in &active {
        for ti in 0..t {
            w[l * t + ti] = root.normal();
        }
    }

    let mut tasks = Vec::with_capacity(t);
    for ti in 0..t {
        let mut rng = root.split(ti as u64);
        // generate row-major sample-by-sample (AR(1) runs along features),
        // then transpose into the feature-major layout
        let mut row = vec![0.0f64; d];
        let mut x = vec![0.0f32; n * d];
        let mut y = vec![0.0f32; n];
        for ni in 0..n {
            match corr {
                None => {
                    for v in row.iter_mut() {
                        *v = rng.normal();
                    }
                }
                Some(phi) => {
                    let s = (1.0 - phi * phi).sqrt();
                    row[0] = rng.normal();
                    for j in 1..d {
                        row[j] = phi * row[j - 1] + s * rng.normal();
                    }
                }
            }
            let mut acc = 0.0f64;
            for (j, &v) in row.iter().enumerate() {
                x[j * n + ni] = v as f32;
                // repro-lint: allow(kernel-reduction): generator-side y = Xw fused with filling X — row never exists as a slice to hand a kernel
                acc += v * w[j * t + ti];
            }
            y[ni] = (acc + noise * rng.normal()) as f32;
        }
        tasks.push(Task::dense(x, y, n));
    }

    (
        Dataset { name: name.to_string(), d, tasks },
        GroundTruth { active, w },
    )
}

/// Synthetic 1: i.i.d. N(0,1) entries, zero pairwise correlation.
pub fn synthetic1(opts: &SynthOptions) -> (Dataset, GroundTruth) {
    build(opts, None, "synthetic1")
}

/// Synthetic 2: AR(1) feature correlation 0.5^{|i-j|}.
pub fn synthetic2(opts: &SynthOptions) -> (Dataset, GroundTruth) {
    build(opts, Some(0.5), "synthetic2")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let opts = SynthOptions { t: 4, n: 10, d: 50, seed: 3, ..Default::default() };
        let (a, gta) = synthetic1(&opts);
        let (b, gtb) = synthetic1(&opts);
        a.validate().unwrap();
        assert_eq!(a.tasks[2].x, b.tasks[2].x);
        assert_eq!(gta.active, gtb.active);
        assert_eq!(gta.active.len(), 5); // 10% of 50
    }

    #[test]
    fn seeds_change_data() {
        let o1 = SynthOptions { t: 2, n: 8, d: 30, seed: 1, ..Default::default() };
        let o2 = SynthOptions { seed: 2, ..o1.clone() };
        let (a, _) = synthetic1(&o1);
        let (b, _) = synthetic1(&o2);
        assert_ne!(a.tasks[0].x, b.tasks[0].x);
    }

    #[test]
    fn synthetic2_has_ar1_correlation() {
        let opts = SynthOptions { t: 1, n: 4000, d: 30, seed: 5, ..Default::default() };
        let (ds, _) = synthetic2(&opts);
        // empirical corr of adjacent columns ~ 0.5; lag-2 ~ 0.25
        let c01 = corr(&ds.col(0, 10).to_vec(), &ds.col(0, 11).to_vec());
        let c02 = corr(&ds.col(0, 10).to_vec(), &ds.col(0, 12).to_vec());
        assert!((c01 - 0.5).abs() < 0.06, "lag-1 corr {c01}");
        assert!((c02 - 0.25).abs() < 0.06, "lag-2 corr {c02}");
    }

    #[test]
    fn synthetic1_uncorrelated() {
        let opts = SynthOptions { t: 1, n: 4000, d: 10, seed: 6, ..Default::default() };
        let (ds, _) = synthetic1(&opts);
        let c = corr(&ds.col(0, 3).to_vec(), &ds.col(0, 4).to_vec());
        assert!(c.abs() < 0.06, "corr {c}");
    }

    #[test]
    fn responses_follow_model() {
        // with zero noise, y must equal X w* exactly (up to f32 rounding)
        let opts =
            SynthOptions { t: 2, n: 12, d: 40, noise: 0.0, seed: 7, ..Default::default() };
        let (ds, gt) = synthetic1(&opts);
        for t in 0..2 {
            for ni in 0..12 {
                let mut acc = 0.0f64;
                for l in 0..40 {
                    acc += ds.col(t, l).to_vec()[ni] as f64 * gt.w[l * 2 + t];
                }
                assert!((acc - ds.tasks[t].y[ni] as f64).abs() < 1e-4);
            }
        }
    }

    fn corr(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len() as f64;
        let ma = a.iter().map(|v| *v as f64).sum::<f64>() / n;
        let mb = b.iter().map(|v| *v as f64).sum::<f64>() / n;
        let mut num = 0.0;
        let (mut va, mut vb) = (0.0, 0.0);
        for i in 0..a.len() {
            let x = a[i] as f64 - ma;
            let y = b[i] as f64 - mb;
            num += x * y;
            va += x * x;
            vb += y * y;
        }
        num / (va.sqrt() * vb.sqrt())
    }
}
