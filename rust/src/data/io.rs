//! `.mtd` — a tiny self-describing binary container for multi-task
//! datasets (no serde offline). Little-endian layout, two revisions:
//!
//! ```text
//! v1  magic "MTD1" | u32 name_len | name bytes | u64 d | u64 t
//!     per task: u64 n | n*d f32 x (feature-major) | n f32 y
//! v2  magic "MTD2" | u32 name_len | name bytes | u64 d | u64 t
//!     per task: u64 n | u8 storage (0=dense, 1=csc)
//!       dense: n*d f32 x (feature-major)
//!       csc:   u64 nnz | (d+1) u64 col_ptr | nnz u32 indices | nnz f32 values
//!     then: n f32 y
//! both: trailing u64 FNV-1a checksum of everything before it
//! ```
//!
//! `save` always writes v2 (it can carry either backend); `load` accepts
//! both, so pre-refactor datasets remain readable.

use super::{Dataset, MatrixStore, Task};
use crate::linalg::CscMatrix;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC_V1: &[u8; 4] = b"MTD1";
const MAGIC_V2: &[u8; 4] = b"MTD2";

const STORAGE_DENSE: u8 = 0;
const STORAGE_CSC: u8 = 1;

/// FNV-1a 64 over the byte stream (checksum; not cryptographic).
#[derive(Clone)]
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(0xcbf29ce484222325)
    }
    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
    pub fn digest(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

struct HashingWriter<W: Write> {
    inner: W,
    hash: Fnv64,
}

impl<W: Write> HashingWriter<W> {
    fn write_all_hashed(&mut self, buf: &[u8]) -> std::io::Result<()> {
        self.hash.update(buf);
        self.inner.write_all(buf)
    }
}

fn f32s_as_bytes(v: &[f32]) -> &[u8] {
    // f32 -> LE bytes without a copy (we only ship little-endian targets;
    // asserted at save/load below)
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn u32s_as_bytes(v: &[u32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

fn bytes_to_u32s(b: &[u8]) -> Vec<u32> {
    b.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

fn bytes_to_u64s(b: &[u8]) -> Vec<u64> {
    b.chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect()
}

pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    assert!(cfg!(target_endian = "little"), "mtd format is little-endian");
    ds.validate()?;
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = HashingWriter { inner: BufWriter::new(f), hash: Fnv64::new() };

    w.write_all_hashed(MAGIC_V2)?;
    let name = ds.name.as_bytes();
    w.write_all_hashed(&(name.len() as u32).to_le_bytes())?;
    w.write_all_hashed(name)?;
    w.write_all_hashed(&(ds.d as u64).to_le_bytes())?;
    w.write_all_hashed(&(ds.t() as u64).to_le_bytes())?;
    for task in &ds.tasks {
        w.write_all_hashed(&(task.n as u64).to_le_bytes())?;
        match &task.x {
            MatrixStore::Dense(x) => {
                w.write_all_hashed(&[STORAGE_DENSE])?;
                w.write_all_hashed(f32s_as_bytes(x))?;
            }
            MatrixStore::Csc(m) => {
                w.write_all_hashed(&[STORAGE_CSC])?;
                w.write_all_hashed(&(m.nnz() as u64).to_le_bytes())?;
                let mut ptr_bytes = Vec::with_capacity(m.col_ptr.len() * 8);
                for &p in &m.col_ptr {
                    ptr_bytes.extend_from_slice(&(p as u64).to_le_bytes());
                }
                w.write_all_hashed(&ptr_bytes)?;
                w.write_all_hashed(u32s_as_bytes(&m.indices))?;
                w.write_all_hashed(f32s_as_bytes(&m.values))?;
            }
        }
        w.write_all_hashed(f32s_as_bytes(&task.y))?;
    }
    let digest = w.hash.digest();
    w.inner.write_all(&digest.to_le_bytes())?;
    w.inner.flush()?;
    Ok(())
}

pub fn load(path: &Path) -> Result<Dataset> {
    assert!(cfg!(target_endian = "little"), "mtd format is little-endian");
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut hash = Fnv64::new();

    let read_hashed = |r: &mut BufReader<std::fs::File>,
                           hash: &mut Fnv64,
                           n: usize|
     -> Result<Vec<u8>> {
        let mut buf = vec![0u8; n];
        r.read_exact(&mut buf)?;
        hash.update(&buf);
        Ok(buf)
    };

    let magic = read_hashed(&mut r, &mut hash, 4)?;
    let v2 = if magic == MAGIC_V2 {
        true
    } else if magic == MAGIC_V1 {
        false
    } else {
        bail!("not an mtd file: bad magic");
    };
    let name_len =
        u32::from_le_bytes(read_hashed(&mut r, &mut hash, 4)?.try_into().unwrap()) as usize;
    if name_len > 4096 {
        bail!("unreasonable name length {name_len}");
    }
    let name = String::from_utf8(read_hashed(&mut r, &mut hash, name_len)?)
        .context("dataset name not utf8")?;
    let d = u64::from_le_bytes(read_hashed(&mut r, &mut hash, 8)?.try_into().unwrap()) as usize;
    let t = u64::from_le_bytes(read_hashed(&mut r, &mut hash, 8)?.try_into().unwrap()) as usize;
    if d == 0 || t == 0 || d > 100_000_000 || t > 100_000 {
        bail!("corrupt header: d={d} t={t}");
    }

    let mut tasks = Vec::with_capacity(t);
    for _ in 0..t {
        let n =
            u64::from_le_bytes(read_hashed(&mut r, &mut hash, 8)?.try_into().unwrap()) as usize;
        if n == 0 || n > u32::MAX as usize || n.checked_mul(d).is_none() {
            bail!("corrupt task header: n={n}");
        }
        let storage = if v2 { read_hashed(&mut r, &mut hash, 1)?[0] } else { STORAGE_DENSE };
        let x = match storage {
            STORAGE_DENSE => {
                MatrixStore::Dense(bytes_to_f32s(&read_hashed(&mut r, &mut hash, n * d * 4)?))
            }
            STORAGE_CSC => {
                let nnz = u64::from_le_bytes(
                    read_hashed(&mut r, &mut hash, 8)?.try_into().unwrap(),
                ) as usize;
                if nnz > n * d {
                    bail!("corrupt csc block: nnz={nnz} > n*d={}", n * d);
                }
                let col_ptr: Vec<usize> =
                    bytes_to_u64s(&read_hashed(&mut r, &mut hash, (d + 1) * 8)?)
                        .into_iter()
                        .map(|p| p as usize)
                        .collect();
                let indices = bytes_to_u32s(&read_hashed(&mut r, &mut hash, nnz * 4)?);
                let values = bytes_to_f32s(&read_hashed(&mut r, &mut hash, nnz * 4)?);
                let m = CscMatrix { n, d, col_ptr, indices, values };
                m.validate().context("corrupt csc block")?;
                MatrixStore::Csc(m)
            }
            other => bail!("unknown storage tag {other}"),
        };
        let y = bytes_to_f32s(&read_hashed(&mut r, &mut hash, n * 4)?);
        tasks.push(Task { x, y, n });
    }

    let mut digest_bytes = [0u8; 8];
    r.read_exact(&mut digest_bytes)?;
    let want = u64::from_le_bytes(digest_bytes);
    if want != hash.digest() {
        bail!("checksum mismatch: file corrupt");
    }

    let ds = Dataset { name, d, tasks };
    ds.validate()?;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, SynthOptions};
    use crate::data::textsim::{textsim, TextSimOptions};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("mtfl_test_{}_{}", std::process::id(), name))
    }

    #[test]
    fn round_trip() {
        let (ds, _) = synthetic1(&SynthOptions { t: 3, n: 7, d: 11, ..Default::default() });
        let p = tmp("roundtrip.mtd");
        save(&ds, &p).unwrap();
        let back = load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(back.name, ds.name);
        assert_eq!(back.d, ds.d);
        for (a, b) in back.tasks.iter().zip(&ds.tasks) {
            assert_eq!(a.n, b.n);
            assert_eq!(a.x, b.x);
            assert_eq!(a.y, b.y);
        }
    }

    #[test]
    fn sparse_round_trip_preserves_csc_exactly() {
        let ds = textsim(&TextSimOptions {
            categories: 2,
            n_pos: 5,
            d: 300,
            doc_len: 40,
            ..Default::default()
        });
        assert!(ds.is_sparse());
        let p = tmp("sparse_roundtrip.mtd");
        save(&ds, &p).unwrap();
        let back = load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert!(back.is_sparse(), "CSC storage must survive the round trip");
        for (a, b) in back.tasks.iter().zip(&ds.tasks) {
            assert_eq!(a.x, b.x);
            assert_eq!(a.y, b.y);
        }
    }

    #[test]
    fn loads_legacy_v1_files() {
        // hand-write a v1 file: 1 task, n=2, d=2, dense
        let p = tmp("legacy_v1.mtd");
        let mut hash = Fnv64::new();
        let mut bytes: Vec<u8> = Vec::new();
        let put = |b: &[u8], bytes: &mut Vec<u8>, hash: &mut Fnv64| {
            bytes.extend_from_slice(b);
            hash.update(b);
        };
        put(b"MTD1", &mut bytes, &mut hash);
        put(&2u32.to_le_bytes(), &mut bytes, &mut hash); // name len
        put(b"v1", &mut bytes, &mut hash);
        put(&2u64.to_le_bytes(), &mut bytes, &mut hash); // d
        put(&1u64.to_le_bytes(), &mut bytes, &mut hash); // t
        put(&2u64.to_le_bytes(), &mut bytes, &mut hash); // n
        for v in [1.0f32, 2.0, 3.0, 4.0, 0.5, -0.5] {
            // x (4) then y (2)
            put(&v.to_le_bytes(), &mut bytes, &mut hash);
        }
        bytes.extend_from_slice(&hash.digest().to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let ds = load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(ds.name, "v1");
        assert_eq!(ds.d, 2);
        assert_eq!(ds.col(0, 1).to_vec(), vec![3.0, 4.0]);
        assert_eq!(ds.tasks[0].y, vec![0.5, -0.5]);
    }

    #[test]
    fn detects_corruption() {
        let (ds, _) = synthetic1(&SynthOptions { t: 2, n: 5, d: 6, ..Default::default() });
        let p = tmp("corrupt.mtd");
        save(&ds, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p);
        std::fs::remove_file(&p).ok();
        assert!(err.is_err());
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.mtd");
        std::fs::write(&p, b"definitely not a dataset").unwrap();
        let err = load(&p);
        std::fs::remove_file(&p).ok();
        assert!(err.is_err());
    }
}
