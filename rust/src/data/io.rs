//! `.mtd` — a tiny self-describing binary container for multi-task
//! datasets (no serde offline). Little-endian layout, three revisions:
//!
//! ```text
//! v1  magic "MTD1" | u32 name_len | name bytes | u64 d | u64 t
//!     per task: u64 n | n*d f32 x (feature-major) | n f32 y
//! v2  magic "MTD2" | u32 name_len | name bytes | u64 d | u64 t
//!     per task: u64 n | u8 storage (0=dense, 1=csc)
//!       dense: n*d f32 x (feature-major)
//!       csc:   u64 nnz | (d+1) u64 col_ptr | nnz u32 indices | nnz f32 values
//!     then: n f32 y
//! both: trailing u64 FNV-1a checksum of everything before it
//! ```
//!
//! `save` always writes v2 (it can carry either backend); `load` accepts
//! both, so pre-refactor datasets remain readable.
//!
//! **MTD3 — the sharded layout** (DESIGN.md §10). v1/v2 interleave x and y
//! per task, so reading *any* column means materializing the whole file.
//! The third revision regroups the matrix into fixed-width column blocks
//! so the screen-before-load pipeline can stream, score, and discard them
//! without ever holding the dataset in RAM:
//!
//! ```text
//! v3  magic "MTD3" | u32 name_len | name bytes | u64 d | u64 t
//!     per task: u64 n
//!     per task: n f32 y            (responses live in the header: O(N))
//!     u64 block_cols | u64 n_blocks  (= ceil(d / block_cols))
//!     per block: u64 offset | u64 byte_len | u64 fnv64 checksum
//!     u64 header_checksum          (fnv64 of every header byte above)
//!     -- blocks, back to back --
//!     block b covers columns [b·block_cols, min((b+1)·block_cols, d)):
//!       per task: u8 storage (0=dense, 1=csc)
//!         dense: cols*n f32 (feature-major within the block)
//!         csc:   u64 nnz | (cols+1) u64 col_ptr | nnz u32 idx | nnz f32 val
//! ```
//!
//! Per-block offsets make any column range one seek away; per-block
//! checksums localize corruption to the block that actually gets read
//! (a streamed screen over a 100 GB shard must not checksum 100 GB
//! first). [`save_sharded`] writes v3 from an in-RAM dataset (the
//! `repro shard` CLI converter); the out-of-core reader lives in
//! [`super::shard`].

use super::{Dataset, MatrixStore, Task};
use crate::linalg::CscMatrix;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

const MAGIC_V1: &[u8; 4] = b"MTD1";
const MAGIC_V2: &[u8; 4] = b"MTD2";
pub(crate) const MAGIC_V3: &[u8; 4] = b"MTD3";

pub(crate) const STORAGE_DENSE: u8 = 0;
pub(crate) const STORAGE_CSC: u8 = 1;

/// FNV-1a 64 over the byte stream (checksum; not cryptographic).
#[derive(Clone)]
pub struct Fnv64(u64);

impl Fnv64 {
    /// Fresh hasher at the FNV offset basis.
    pub fn new() -> Self {
        Fnv64(0xcbf29ce484222325)
    }
    /// Absorb bytes into the running hash.
    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
    /// The current 64-bit digest.
    pub fn digest(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

struct HashingWriter<W: Write> {
    inner: W,
    hash: Fnv64,
}

impl<W: Write> HashingWriter<W> {
    fn write_all_hashed(&mut self, buf: &[u8]) -> std::io::Result<()> {
        self.hash.update(buf);
        self.inner.write_all(buf)
    }

    /// Write an f32 slice as LE bytes through a bounded staging buffer —
    /// no full-slice copy (the dense X of one task can be gigabytes) and
    /// no unsafe cast; byte-identical to the raw in-memory bytes on the
    /// little-endian targets the format asserts at save/load.
    fn write_f32s_hashed(&mut self, v: &[f32]) -> std::io::Result<()> {
        let mut buf = [0u8; 4096];
        for chunk in v.chunks(1024) {
            for (i, &x) in chunk.iter().enumerate() {
                buf[i * 4..i * 4 + 4].copy_from_slice(&x.to_le_bytes());
            }
            self.write_all_hashed(&buf[..chunk.len() * 4])?;
        }
        Ok(())
    }

    /// u32 twin of [`Self::write_f32s_hashed`].
    fn write_u32s_hashed(&mut self, v: &[u32]) -> std::io::Result<()> {
        let mut buf = [0u8; 4096];
        for chunk in v.chunks(1024) {
            for (i, &x) in chunk.iter().enumerate() {
                buf[i * 4..i * 4 + 4].copy_from_slice(&x.to_le_bytes());
            }
            self.write_all_hashed(&buf[..chunk.len() * 4])?;
        }
        Ok(())
    }
}

/// Append an f32 slice to `buf` as LE bytes (in-memory serialization
/// twin of [`HashingWriter::write_f32s_hashed`]).
fn push_f32s(buf: &mut Vec<u8>, v: &[f32]) {
    buf.reserve(v.len() * 4);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// u32 twin of [`push_f32s`].
fn push_u32s(buf: &mut Vec<u8>, v: &[u32]) {
    buf.reserve(v.len() * 4);
    for &x in v {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

pub(crate) fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

pub(crate) fn bytes_to_u32s(b: &[u8]) -> Vec<u32> {
    b.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

pub(crate) fn bytes_to_u64s(b: &[u8]) -> Vec<u64> {
    b.chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect()
}

/// Write `ds` as an `.mtd` (v2) file — carries dense and CSC backends.
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    assert!(cfg!(target_endian = "little"), "mtd format is little-endian");
    ds.validate()?;
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = HashingWriter { inner: BufWriter::new(f), hash: Fnv64::new() };

    w.write_all_hashed(MAGIC_V2)?;
    let name = ds.name.as_bytes();
    w.write_all_hashed(&(name.len() as u32).to_le_bytes())?;
    w.write_all_hashed(name)?;
    w.write_all_hashed(&(ds.d as u64).to_le_bytes())?;
    w.write_all_hashed(&(ds.t() as u64).to_le_bytes())?;
    for task in &ds.tasks {
        w.write_all_hashed(&(task.n as u64).to_le_bytes())?;
        match &task.x {
            MatrixStore::Dense(x) => {
                w.write_all_hashed(&[STORAGE_DENSE])?;
                w.write_f32s_hashed(x)?;
            }
            MatrixStore::Csc(m) => {
                w.write_all_hashed(&[STORAGE_CSC])?;
                w.write_all_hashed(&(m.nnz() as u64).to_le_bytes())?;
                let mut ptr_bytes = Vec::with_capacity(m.col_ptr.len() * 8);
                for &p in &m.col_ptr {
                    ptr_bytes.extend_from_slice(&(p as u64).to_le_bytes());
                }
                w.write_all_hashed(&ptr_bytes)?;
                w.write_u32s_hashed(&m.indices)?;
                w.write_f32s_hashed(&m.values)?;
            }
        }
        w.write_f32s_hashed(&task.y)?;
    }
    let digest = w.hash.digest();
    w.inner.write_all(&digest.to_le_bytes())?;
    w.inner.flush()?;
    Ok(())
}

/// Load an `.mtd` file (v1 or v2), verifying its trailing checksum.
pub fn load(path: &Path) -> Result<Dataset> {
    assert!(cfg!(target_endian = "little"), "mtd format is little-endian");
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut hash = Fnv64::new();

    let read_hashed = |r: &mut BufReader<std::fs::File>,
                           hash: &mut Fnv64,
                           n: usize|
     -> Result<Vec<u8>> {
        let mut buf = vec![0u8; n];
        r.read_exact(&mut buf)?;
        hash.update(&buf);
        Ok(buf)
    };

    let magic = read_hashed(&mut r, &mut hash, 4)?;
    let v2 = if magic == MAGIC_V2 {
        true
    } else if magic == MAGIC_V1 {
        false
    } else {
        bail!("not an mtd file: bad magic");
    };
    let name_len =
        u32::from_le_bytes(read_hashed(&mut r, &mut hash, 4)?.try_into().unwrap()) as usize;
    if name_len > 4096 {
        bail!("unreasonable name length {name_len}");
    }
    let name = String::from_utf8(read_hashed(&mut r, &mut hash, name_len)?)
        .context("dataset name not utf8")?;
    let d = u64::from_le_bytes(read_hashed(&mut r, &mut hash, 8)?.try_into().unwrap()) as usize;
    let t = u64::from_le_bytes(read_hashed(&mut r, &mut hash, 8)?.try_into().unwrap()) as usize;
    if d == 0 || t == 0 || d > 100_000_000 || t > 100_000 {
        bail!("corrupt header: d={d} t={t}");
    }

    let mut tasks = Vec::with_capacity(t);
    for _ in 0..t {
        let n =
            u64::from_le_bytes(read_hashed(&mut r, &mut hash, 8)?.try_into().unwrap()) as usize;
        if n == 0 || n > u32::MAX as usize || n.checked_mul(d).is_none() {
            bail!("corrupt task header: n={n}");
        }
        let storage = if v2 { read_hashed(&mut r, &mut hash, 1)?[0] } else { STORAGE_DENSE };
        let x = match storage {
            STORAGE_DENSE => {
                MatrixStore::Dense(bytes_to_f32s(&read_hashed(&mut r, &mut hash, n * d * 4)?))
            }
            STORAGE_CSC => {
                let nnz = u64::from_le_bytes(
                    read_hashed(&mut r, &mut hash, 8)?.try_into().unwrap(),
                ) as usize;
                if nnz > n * d {
                    bail!("corrupt csc block: nnz={nnz} > n*d={}", n * d);
                }
                let col_ptr: Vec<usize> =
                    bytes_to_u64s(&read_hashed(&mut r, &mut hash, (d + 1) * 8)?)
                        .into_iter()
                        .map(|p| p as usize)
                        .collect();
                let indices = bytes_to_u32s(&read_hashed(&mut r, &mut hash, nnz * 4)?);
                let values = bytes_to_f32s(&read_hashed(&mut r, &mut hash, nnz * 4)?);
                let m = CscMatrix { n, d, col_ptr, indices, values };
                m.validate().context("corrupt csc block")?;
                MatrixStore::Csc(m)
            }
            other => bail!("unknown storage tag {other}"),
        };
        let y = bytes_to_f32s(&read_hashed(&mut r, &mut hash, n * 4)?);
        tasks.push(Task { x, y, n });
    }

    let mut digest_bytes = [0u8; 8];
    r.read_exact(&mut digest_bytes)?;
    let want = u64::from_le_bytes(digest_bytes);
    if want != hash.digest() {
        bail!("checksum mismatch: file corrupt");
    }

    let ds = Dataset { name, d, tasks };
    ds.validate()?;
    Ok(ds)
}

// ---------------------------------------------------------------------------
// MTD3: the sharded column-block layout (writer; reader in data::shard)
// ---------------------------------------------------------------------------

/// What [`save_sharded`] wrote (also printed by the `repro shard` CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSummary {
    /// columns per block (the last block may be narrower)
    pub block_cols: usize,
    /// number of column blocks written
    pub blocks: usize,
    /// total block payload bytes (excludes the header)
    pub payload_bytes: u64,
}

/// Block width hitting a target of ~`shard_bytes` serialized bytes per
/// block: divides the target by the mean per-column stored cost across
/// tasks (dense: 4·n bytes per column; CSC: ~8 bytes per stored entry
/// plus a column pointer). Clamped to `[1, d]`.
pub fn block_cols_for(ds: &Dataset, shard_bytes: usize) -> usize {
    let mut per_col = 0.0f64;
    for task in &ds.tasks {
        per_col += match &task.x {
            MatrixStore::Dense(_) => 4.0 * task.n as f64,
            MatrixStore::Csc(m) => 8.0 * m.nnz() as f64 / ds.d.max(1) as f64 + 8.0,
        };
    }
    ((shard_bytes as f64 / per_col.max(1.0)) as usize).clamp(1, ds.d)
}

fn serialize_block(ds: &Dataset, first: usize, cols: usize) -> Vec<u8> {
    let mut buf = Vec::new();
    for task in &ds.tasks {
        match &task.x {
            MatrixStore::Dense(x) => {
                buf.push(STORAGE_DENSE);
                push_f32s(&mut buf, &x[first * task.n..(first + cols) * task.n]);
            }
            MatrixStore::Csc(m) => {
                buf.push(STORAGE_CSC);
                let lo = m.col_ptr[first];
                let hi = m.col_ptr[first + cols];
                buf.extend_from_slice(&((hi - lo) as u64).to_le_bytes());
                for l in first..=first + cols {
                    buf.extend_from_slice(&((m.col_ptr[l] - lo) as u64).to_le_bytes());
                }
                push_u32s(&mut buf, &m.indices[lo..hi]);
                push_f32s(&mut buf, &m.values[lo..hi]);
            }
        }
    }
    buf
}

/// Write `ds` in the sharded MTD3 layout (module docs), targeting
/// ~`shard_bytes` serialized bytes per column block. The storage backend
/// of every task is preserved block-by-block, so a CSC dataset shards
/// into CSC blocks. This is the `repro shard` converter; the out-of-core
/// reader is [`super::shard::ShardedDataset`].
pub fn save_sharded(ds: &Dataset, path: &Path, shard_bytes: usize) -> Result<ShardSummary> {
    assert!(cfg!(target_endian = "little"), "mtd format is little-endian");
    ds.validate()?;
    anyhow::ensure!(shard_bytes > 0, "shard size must be positive");
    let block_cols = block_cols_for(ds, shard_bytes);
    let n_blocks = ds.d.div_ceil(block_cols);

    // header built fully in memory (it is O(N + n_blocks) small); the
    // block table and header checksum are patched in after the blocks
    // stream out, then the header is rewritten in place
    let mut header: Vec<u8> = Vec::new();
    header.extend_from_slice(MAGIC_V3);
    let name = ds.name.as_bytes();
    header.extend_from_slice(&(name.len() as u32).to_le_bytes());
    header.extend_from_slice(name);
    header.extend_from_slice(&(ds.d as u64).to_le_bytes());
    header.extend_from_slice(&(ds.t() as u64).to_le_bytes());
    for task in &ds.tasks {
        header.extend_from_slice(&(task.n as u64).to_le_bytes());
    }
    for task in &ds.tasks {
        push_f32s(&mut header, &task.y);
    }
    header.extend_from_slice(&(block_cols as u64).to_le_bytes());
    header.extend_from_slice(&(n_blocks as u64).to_le_bytes());
    let table_pos = header.len();
    header.resize(table_pos + n_blocks * 24 + 8, 0u8);

    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    f.write_all(&header)?;

    // stream the blocks, one serialized buffer in RAM at a time
    let mut offset = header.len() as u64;
    let mut payload_bytes = 0u64;
    for b in 0..n_blocks {
        let first = b * block_cols;
        let cols = block_cols.min(ds.d - first);
        let buf = serialize_block(ds, first, cols);
        let mut h = Fnv64::new();
        h.update(&buf);
        let entry = table_pos + b * 24;
        header[entry..entry + 8].copy_from_slice(&offset.to_le_bytes());
        header[entry + 8..entry + 16]
            .copy_from_slice(&(buf.len() as u64).to_le_bytes());
        header[entry + 16..entry + 24].copy_from_slice(&h.digest().to_le_bytes());
        f.write_all(&buf)?;
        offset += buf.len() as u64;
        payload_bytes += buf.len() as u64;
    }

    let csum_pos = header.len() - 8;
    let mut h = Fnv64::new();
    h.update(&header[..csum_pos]);
    header[csum_pos..].copy_from_slice(&h.digest().to_le_bytes());
    f.seek(SeekFrom::Start(0))?;
    f.write_all(&header)?;
    f.flush()?;
    Ok(ShardSummary { block_cols, blocks: n_blocks, payload_bytes })
}

// ---------------------------------------------------------------------------
// Generic checksummed records (checkpoints and other small sidecar files)
// ---------------------------------------------------------------------------

/// Write `magic | payload | fnv64(magic+payload)` to `path` atomically:
/// the bytes land in `path.tmp` first and are renamed into place, so a
/// crash mid-write leaves either the old record or no record — never a
/// torn one. Used for the per-λ path checkpoints (DESIGN.md §16); the
/// payload layout is the caller's contract.
pub fn write_record_atomic(path: &Path, magic: &[u8; 4], payload: &[u8]) -> Result<()> {
    let mut bytes = Vec::with_capacity(4 + payload.len() + 8);
    bytes.extend_from_slice(magic);
    bytes.extend_from_slice(payload);
    let mut h = Fnv64::new();
    h.update(&bytes);
    bytes.extend_from_slice(&h.digest().to_le_bytes());
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &bytes)
        .with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} into place", tmp.display()))?;
    Ok(())
}

/// Read a record written by [`write_record_atomic`], verifying the magic
/// and the trailing checksum; returns the payload bytes. Truncated or
/// bit-flipped files fail loudly rather than decoding garbage.
pub fn read_record(path: &Path, magic: &[u8; 4]) -> Result<Vec<u8>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("read {}", path.display()))?;
    anyhow::ensure!(
        bytes.len() >= 12,
        "{}: truncated record ({} bytes, need at least 12)",
        path.display(),
        bytes.len()
    );
    anyhow::ensure!(
        &bytes[..4] == magic,
        "{}: bad magic (expected {:?})",
        path.display(),
        String::from_utf8_lossy(magic)
    );
    let body = &bytes[..bytes.len() - 8];
    let want = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    let mut h = Fnv64::new();
    h.update(body);
    anyhow::ensure!(
        h.digest() == want,
        "{}: checksum mismatch — record corrupt or truncated",
        path.display()
    );
    Ok(body[4..].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, SynthOptions};
    use crate::data::textsim::{textsim, TextSimOptions};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("mtfl_test_{}_{}", std::process::id(), name))
    }

    #[test]
    fn round_trip() {
        let (ds, _) = synthetic1(&SynthOptions { t: 3, n: 7, d: 11, ..Default::default() });
        let p = tmp("roundtrip.mtd");
        save(&ds, &p).unwrap();
        let back = load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(back.name, ds.name);
        assert_eq!(back.d, ds.d);
        for (a, b) in back.tasks.iter().zip(&ds.tasks) {
            assert_eq!(a.n, b.n);
            assert_eq!(a.x, b.x);
            assert_eq!(a.y, b.y);
        }
    }

    #[test]
    fn sparse_round_trip_preserves_csc_exactly() {
        let ds = textsim(&TextSimOptions {
            categories: 2,
            n_pos: 5,
            d: 300,
            doc_len: 40,
            ..Default::default()
        });
        assert!(ds.is_sparse());
        let p = tmp("sparse_roundtrip.mtd");
        save(&ds, &p).unwrap();
        let back = load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert!(back.is_sparse(), "CSC storage must survive the round trip");
        for (a, b) in back.tasks.iter().zip(&ds.tasks) {
            assert_eq!(a.x, b.x);
            assert_eq!(a.y, b.y);
        }
    }

    #[test]
    fn loads_legacy_v1_files() {
        // hand-write a v1 file: 1 task, n=2, d=2, dense
        let p = tmp("legacy_v1.mtd");
        let mut hash = Fnv64::new();
        let mut bytes: Vec<u8> = Vec::new();
        let put = |b: &[u8], bytes: &mut Vec<u8>, hash: &mut Fnv64| {
            bytes.extend_from_slice(b);
            hash.update(b);
        };
        put(b"MTD1", &mut bytes, &mut hash);
        put(&2u32.to_le_bytes(), &mut bytes, &mut hash); // name len
        put(b"v1", &mut bytes, &mut hash);
        put(&2u64.to_le_bytes(), &mut bytes, &mut hash); // d
        put(&1u64.to_le_bytes(), &mut bytes, &mut hash); // t
        put(&2u64.to_le_bytes(), &mut bytes, &mut hash); // n
        for v in [1.0f32, 2.0, 3.0, 4.0, 0.5, -0.5] {
            // x (4) then y (2)
            put(&v.to_le_bytes(), &mut bytes, &mut hash);
        }
        bytes.extend_from_slice(&hash.digest().to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let ds = load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(ds.name, "v1");
        assert_eq!(ds.d, 2);
        assert_eq!(ds.col(0, 1).to_vec(), vec![3.0, 4.0]);
        assert_eq!(ds.tasks[0].y, vec![0.5, -0.5]);
    }

    #[test]
    fn detects_corruption() {
        let (ds, _) = synthetic1(&SynthOptions { t: 2, n: 5, d: 6, ..Default::default() });
        let p = tmp("corrupt.mtd");
        save(&ds, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p);
        std::fs::remove_file(&p).ok();
        assert!(err.is_err());
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage.mtd");
        std::fs::write(&p, b"definitely not a dataset").unwrap();
        let err = load(&p);
        std::fs::remove_file(&p).ok();
        assert!(err.is_err());
    }

    #[test]
    fn record_round_trip_and_corruption() {
        let p = tmp("record.mtc1");
        let payload = b"hello checkpoint payload".to_vec();
        write_record_atomic(&p, b"MTC1", &payload).unwrap();
        assert_eq!(read_record(&p, b"MTC1").unwrap(), payload);
        // the tmp staging file must not linger
        assert!(!p.with_extension("tmp").exists());

        // wrong magic is rejected by name
        let err = read_record(&p, b"MTXX").unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");

        // a flipped payload bit trips the checksum
        let mut bytes = std::fs::read(&p).unwrap();
        bytes[7] ^= 0x01;
        std::fs::write(&p, &bytes).unwrap();
        let err = read_record(&p, b"MTC1").unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");

        // truncation below the minimum record size is its own error
        std::fs::write(&p, b"MTC1").unwrap();
        let err = read_record(&p, b"MTC1").unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        std::fs::remove_file(&p).ok();
    }
}
