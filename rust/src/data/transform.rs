//! The paper's §2 equivalent MTFL formulations, reduced to problem (1) by
//! dataset transforms — so DPC screens them unchanged:
//!
//! * **Weighted loss**  Σ_t 1/(2ρ_t)‖y_t − X_t w_t‖² + λ‖W‖₂,₁
//!   reduces via ỹ_t = y_t/√ρ_t, X̃_t = X_t/√ρ_t.
//! * **ℓ2,1 + Frobenius (elastic-net style)**
//!   Σ_t ½‖y_t − X_t w_t‖² + λ‖W‖₂,₁ + ρ‖W‖_F²
//!   reduces via row-augmentation X̄_t = [X_t; √(2ρ)·I], ȳ_t = [y_t; 0].
//!
//! Both transforms preserve the optimal W exactly (the objectives are
//! equal as functions of W), so safe screening on the transformed problem
//! is safe screening on the original — verified in the tests below.

use super::{Dataset, MatrixStore, Task};
use crate::linalg::CscMatrix;

/// Weighted-loss reduction: scales each task by 1/√ρ_t. Preserves the
/// storage backend (scaling touches only stored values).
pub fn weighted(ds: &Dataset, rho: &[f64]) -> Dataset {
    assert_eq!(rho.len(), ds.t(), "one weight per task");
    assert!(rho.iter().all(|&r| r > 0.0), "weights must be positive");
    let tasks = ds
        .tasks
        .iter()
        .zip(rho)
        .map(|(task, &r)| {
            let s = (1.0 / r.sqrt()) as f32;
            Task {
                x: task.x.scaled(s),
                y: task.y.iter().map(|&v| v * s).collect(),
                n: task.n,
            }
        })
        .collect();
    Dataset { name: format!("{}-weighted", ds.name), d: ds.d, tasks }
}

/// Elastic-net reduction: appends √(2ρ)·I rows to every task (n grows by d).
///
/// Note the memory cost on the dense backend (each task gains a d×d
/// identity block); on CSC the identity adds just one stored entry per
/// column. For d ≫ n the ridge term is usually applied through the solver
/// instead — this transform exists to prove DPC compatibility, matching
/// the paper's reduction.
pub fn elastic_net(ds: &Dataset, rho: f64) -> Dataset {
    assert!(rho > 0.0);
    let s = (2.0 * rho).sqrt() as f32;
    let d = ds.d;
    let tasks = ds
        .tasks
        .iter()
        .map(|task| {
            let n_new = task.n + d;
            let x = match &task.x {
                MatrixStore::Dense(xd) => {
                    let mut x = vec![0.0f32; n_new * d];
                    for l in 0..d {
                        // original column samples
                        x[l * n_new..l * n_new + task.n]
                            .copy_from_slice(&xd[l * task.n..(l + 1) * task.n]);
                        // identity row for this feature
                        x[l * n_new + task.n + l] = s;
                    }
                    MatrixStore::Dense(x)
                }
                MatrixStore::Csc(m) => {
                    let mut cols: Vec<Vec<(u32, f32)>> = Vec::with_capacity(d);
                    for l in 0..d {
                        let (idx, vals) = m.col(l);
                        let mut col: Vec<(u32, f32)> = idx
                            .iter()
                            .zip(vals)
                            .map(|(&i, &v)| (i, v))
                            .collect();
                        col.push(((task.n + l) as u32, s));
                        cols.push(col);
                    }
                    MatrixStore::Csc(CscMatrix::from_cols(n_new, cols))
                }
            };
            let mut y = task.y.clone();
            y.extend(std::iter::repeat(0.0f32).take(d));
            Task { x, y, n: n_new }
        })
        .collect();
    Dataset { name: format!("{}-enet", ds.name), d, tasks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, SynthOptions};
    use crate::ops;
    use crate::screening::dpc::{DpcScreener, DualRef};
    use crate::screening::safety;
    use crate::solver::{fista, SolveOptions};

    fn base() -> Dataset {
        synthetic1(&SynthOptions { t: 3, n: 10, d: 24, seed: 31, ..Default::default() }).0
    }

    #[test]
    fn weighted_matches_manual_objective() {
        let ds = base();
        let rho = vec![0.5, 2.0, 1.3];
        let tds = weighted(&ds, &rho);
        let mut rng = crate::util::Pcg64::new(3);
        let w: Vec<f64> = (0..ds.d * 3).map(|_| rng.normal() * 0.2).collect();
        let lam = 0.7;
        // manual weighted objective on the original data
        let r = ops::residual(&ds, &w);
        let manual: f64 = r
            .iter()
            .zip(&rho)
            .map(|(rt, &p)| rt.iter().map(|v| v * v).sum::<f64>() / (2.0 * p))
            .sum::<f64>()
            + lam * ops::l21_norm(&w, 3);
        let transformed = ops::primal_obj(&tds, &w, lam);
        assert!((manual - transformed).abs() < 1e-6 * manual.max(1.0));
    }

    #[test]
    fn elastic_net_matches_manual_objective() {
        let ds = base();
        let rho = 0.8;
        let tds = elastic_net(&ds, rho);
        let mut rng = crate::util::Pcg64::new(4);
        let w: Vec<f64> = (0..ds.d * 3).map(|_| rng.normal() * 0.2).collect();
        let lam = 0.5;
        let fro2: f64 = w.iter().map(|v| v * v).sum();
        let manual = ops::primal_obj(&ds, &w, lam) + rho * fro2;
        let transformed = ops::primal_obj(&tds, &w, lam);
        assert!(
            (manual - transformed).abs() < 1e-6 * manual.max(1.0),
            "{manual} vs {transformed}"
        );
    }

    #[test]
    fn dpc_is_safe_on_transformed_problems() {
        for tds in [weighted(&base(), &[0.5, 2.0, 1.3]), elastic_net(&base(), 0.4)] {
            let (dref, lmax) = DualRef::at_lambda_max(&tds);
            let lam = 0.5 * lmax;
            let out = DpcScreener::new(&tds).screen(&tds, &dref, lam);
            let sol = fista(&tds, lam, None, &SolveOptions::tight());
            let report = safety::verify(&tds, &sol.w, lam, &out.rejected, 1e-7);
            assert!(report.is_safe(), "{}: {:?}", tds.name, report.violations);
        }
    }

    #[test]
    fn transforms_preserve_sparse_backend_and_agree_with_dense() {
        let ds = base();
        let sp = ds.to_csc();
        let rho = vec![0.5, 2.0, 1.3];
        let wd = weighted(&ds, &rho);
        let ws = weighted(&sp, &rho);
        assert!(ws.is_sparse());
        let ed = elastic_net(&ds, 0.4);
        let es = elastic_net(&sp, 0.4);
        assert!(es.is_sparse());
        for (dense_ds, sparse_ds) in [(&wd, &ws), (&ed, &es)] {
            sparse_ds.validate().unwrap();
            for t in 0..dense_ds.t() {
                for l in 0..dense_ds.d {
                    assert_eq!(
                        dense_ds.col(t, l).to_vec(),
                        sparse_ds.col(t, l).to_vec(),
                        "t={t} l={l}"
                    );
                }
            }
        }
    }

    #[test]
    fn elastic_net_shrinks_but_preserves_support_ordering() {
        // ridge shrinkage must not create new active features at the same lam
        let ds = base();
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.3 * lmax;
        let plain = fista(&ds, lam, None, &SolveOptions::tight());
        let enet = fista(&elastic_net(&ds, 2.0), lam, None, &SolveOptions::tight());
        let n_plain = ops::l21_norm(&plain.w, 3);
        let n_enet = ops::l21_norm(&enet.w, 3);
        assert!(n_enet <= n_plain + 1e-9, "ridge did not shrink: {n_enet} > {n_plain}");
    }
}
