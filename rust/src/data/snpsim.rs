//! ADNI-like SNP→brain-volume regression workload (simulated — see
//! DESIGN.md §5; the real ADNI genotypes are access-controlled).
//!
//! Real setting: 747 patients x 504095 SNPs; 20 tasks, each regressing one
//! randomly chosen brain-region volume on the SNPs of 50 randomly chosen
//! patients. The regime that matters for DPC: d >> N by four orders of
//! magnitude, discrete {0,1,2} minor-allele counts, LD-block correlation,
//! and a tiny causal set shared across regions. We simulate:
//!
//! * MAF per SNP ~ Beta(0.8, 2.3) clamped to [0.01, `maf_max`] (realistic
//!   site frequency spectrum; `maf_max` is the density knob in sparse mode);
//! * LD: SNPs come in blocks of `ld_block`; within a block, each SNP copies
//!   the previous one's genotype with prob `ld_rho` per allele;
//! * `causal` SNPs with Gaussian effects shared across tasks (plus small
//!   per-task deviation), y standardized per task.
//!
//! Storage (DESIGN.md §6): the default (dense) mode stores mean-centered
//! genotypes `g − 2·maf`, which are never exactly zero — faithful to the
//! usual GWAS preprocessing but incompressible. `sparse: true` skips the
//! centering and emits raw allele counts in CSC: a homozygous-major sample
//! (g = 0, the overwhelming majority at low MAF) is simply not stored, so
//! the matrix density is ≈ E[1 − (1−maf)²] and `maf_max` tunes it.

use super::{Dataset, GroundTruth, Task};
use crate::linalg::CscMatrix;
use crate::util::Pcg64;

/// Knobs of the ADNI-like genotype generator.
#[derive(Debug, Clone)]
pub struct SnpSimOptions {
    /// number of tasks (cognitive scores in the paper)
    pub tasks: usize,
    /// samples (subjects) per task
    pub n: usize,
    /// SNP count (feature dimension; d ≫ n in this regime)
    pub d: usize,
    /// size of the shared causal SNP set
    pub causal: usize,
    /// linkage-disequilibrium block width (sites copied together)
    pub ld_block: usize,
    /// within-block copying probability (LD strength)
    pub ld_rho: f64,
    /// response noise std
    pub noise: f64,
    /// RNG seed (every experiment seeds explicitly)
    pub seed: u64,
    /// emit raw (uncentered) allele counts in CSC storage
    pub sparse: bool,
    /// MAF clamp ceiling — with `sparse`, the density knob
    pub maf_max: f64,
}

impl Default for SnpSimOptions {
    fn default() -> Self {
        SnpSimOptions {
            tasks: 20,
            n: 50,
            d: 50_000,
            causal: 60,
            ld_block: 25,
            ld_rho: 0.7,
            noise: 0.3,
            seed: 0,
            sparse: false,
            maf_max: 0.5,
        }
    }
}

fn beta_maf(rng: &mut Pcg64, maf_max: f64) -> f64 {
    // Beta(a,b) via Johnk-ish two-gamma; gamma by Marsaglia-Tsang for a<1
    fn gamma(rng: &mut Pcg64, a: f64) -> f64 {
        if a < 1.0 {
            let u = rng.uniform().max(1e-12);
            return gamma(rng, a + 1.0) * u.powf(1.0 / a);
        }
        let d = a - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = rng.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = rng.uniform().max(1e-12);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }
    let g1 = gamma(rng, 0.8);
    let g2 = gamma(rng, 2.3);
    // lower bound yields to maf_max so sub-1% density settings stay valid
    let lo = 0.01f64.min(maf_max);
    (g1 / (g1 + g2)).clamp(lo, maf_max)
}

/// Generate the ADNI-shaped workload (d ≫ N genotypes, DESIGN.md §5).
pub fn snpsim(opts: &SnpSimOptions) -> (Dataset, GroundTruth) {
    let SnpSimOptions { tasks, n, d, causal, ld_block, ld_rho, noise, seed, sparse, maf_max } =
        *opts;
    let mut root = Pcg64::with_stream(seed, 0xad71);

    let mafs: Vec<f64> = (0..d).map(|_| beta_maf(&mut root, maf_max)).collect();
    let mut active = root.choose_distinct(d, causal.min(d));
    active.sort_unstable();
    // shared effect + small per-task deviation
    let mut w = vec![0.0f64; d * tasks];
    for &l in &active {
        let shared = root.normal();
        for t in 0..tasks {
            w[l * tasks + t] = shared + 0.2 * root.normal();
        }
    }

    let mut out_tasks = Vec::with_capacity(tasks);
    for t in 0..tasks {
        let mut rng = root.split(t as u64);
        let mut x = if sparse { Vec::new() } else { vec![0.0f32; n * d] };
        let mut cols: Vec<Vec<(u32, f32)>> = if sparse { vec![Vec::new(); d] } else { Vec::new() };
        let mut y64 = vec![0.0f64; n];
        let mut geno_prev = vec![0u8; n];
        for l in 0..d {
            let maf = mafs[l];
            let fresh_block = l % ld_block == 0;
            let col_start = l * n;
            for ni in 0..n {
                let g = if fresh_block || rng.uniform() >= ld_rho {
                    // two Bernoulli(maf) alleles
                    (rng.uniform() < maf) as u8 + (rng.uniform() < maf) as u8
                } else {
                    geno_prev[ni] // LD copy
                };
                geno_prev[ni] = g;
                let wl = w[l * tasks + t];
                if sparse {
                    // raw allele count: zeros (the common case) are not stored
                    if g != 0 {
                        cols[l].push((ni as u32, g as f32));
                    }
                    if wl != 0.0 {
                        // repro-lint: allow(kernel-reduction): generator-side y accumulation fused with streaming genotype synthesis
                        y64[ni] += g as f64 * wl;
                    }
                } else {
                    // standardize genotype column to mean 0 (population-level)
                    let centered = g as f64 - 2.0 * maf;
                    x[col_start + ni] = centered as f32;
                    if wl != 0.0 {
                        // repro-lint: allow(kernel-reduction): dense twin of the sparse fused accumulation above
                        y64[ni] += centered * wl;
                    }
                }
            }
        }
        // per-task standardization of y + noise (mirrors volume z-scoring);
        // serial pinned-order moments — (v-m)² groups like the old powi(2)
        let m = crate::linalg::simd::sum_serial_f64(&y64) / n as f64;
        let var = crate::linalg::simd::centered_sumsq_serial_f64(&y64, m) / n as f64;
        let sd = var.sqrt().max(1e-9);
        let y: Vec<f32> = y64
            .iter()
            .map(|v| (((v - m) / sd) + noise * rng.normal()) as f32)
            .collect();
        out_tasks.push(if sparse {
            Task::csc(CscMatrix::from_cols(n, cols), y)
        } else {
            Task::dense(x, y, n)
        });
    }

    (
        Dataset { name: "adnisim".into(), d, tasks: out_tasks },
        GroundTruth { active, w },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SnpSimOptions {
        SnpSimOptions {
            tasks: 3,
            n: 20,
            d: 400,
            causal: 10,
            ld_block: 10,
            ld_rho: 0.7,
            noise: 0.1,
            seed: 2,
            ..Default::default()
        }
    }

    #[test]
    fn shape_and_determinism() {
        let (a, gt) = snpsim(&small());
        let (b, _) = snpsim(&small());
        a.validate().unwrap();
        assert_eq!(a.d, 400);
        assert_eq!(a.t(), 3);
        assert_eq!(gt.active.len(), 10);
        assert_eq!(a.tasks[1].x, b.tasks[1].x);
    }

    #[test]
    fn genotypes_take_three_centered_levels() {
        let (ds, _) = snpsim(&small());
        // every column has at most 3 distinct values: {0,1,2} - 2*maf
        for l in (0..ds.d).step_by(37) {
            let col = ds.col(1, l).to_vec();
            let mut vals: Vec<i64> = col.iter().map(|v| (v * 1e4).round() as i64).collect();
            vals.sort_unstable();
            vals.dedup();
            assert!(vals.len() <= 3, "column {l} has {} levels", vals.len());
        }
    }

    #[test]
    fn ld_within_block_exceeds_between() {
        let mut o = small();
        o.n = 600;
        o.d = 200;
        let (ds, _) = snpsim(&o);
        // columns 1,2 in one LD block; 9,10 cross a boundary
        let within = corr_abs(&ds.col(0, 1).to_vec(), &ds.col(0, 2).to_vec());
        let across = corr_abs(&ds.col(0, 9).to_vec(), &ds.col(0, 10).to_vec());
        assert!(within > across + 0.1, "within {within} across {across}");
    }

    #[test]
    fn y_is_standardized() {
        let (ds, _) = snpsim(&small());
        for t in &ds.tasks {
            let m: f64 = t.y.iter().map(|v| *v as f64).sum::<f64>() / t.n as f64;
            let v: f64 =
                t.y.iter().map(|v| (*v as f64 - m).powi(2)).sum::<f64>() / t.n as f64;
            assert!(m.abs() < 0.3, "mean {m}");
            assert!(v > 0.5 && v < 2.5, "var {v}");
        }
    }

    #[test]
    fn sparse_mode_emits_csc_with_tunable_density() {
        let opts = SnpSimOptions { sparse: true, maf_max: 0.05, ..small() };
        let (ds, gt) = snpsim(&opts);
        ds.validate().unwrap();
        assert!(ds.is_sparse());
        assert!(!gt.active.is_empty());
        // density ≈ E[1 − (1−maf)²] ≤ 2·maf_max = 0.1
        let density = ds.density();
        assert!(density < 0.15, "maf_max=0.05 should keep density low, got {density}");
        // columns hold raw allele counts 1 or 2
        for l in (0..ds.d).step_by(29) {
            ds.col(0, l).for_each_nonzero(|_, v| assert!(v == 1.0 || v == 2.0));
        }
    }

    fn corr_abs(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len() as f64;
        let ma = a.iter().map(|v| *v as f64).sum::<f64>() / n;
        let mb = b.iter().map(|v| *v as f64).sum::<f64>() / n;
        let mut num = 0.0;
        let (mut va, mut vb) = (0.0, 0.0);
        for i in 0..a.len() {
            let x = a[i] as f64 - ma;
            let y = b[i] as f64 - mb;
            num += x * y;
            va += x * x;
            vb += y * y;
        }
        if va == 0.0 || vb == 0.0 {
            return 0.0;
        }
        (num / (va.sqrt() * vb.sqrt())).abs()
    }
}
