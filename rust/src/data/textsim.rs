//! TDT2-like text workload (simulated — see DESIGN.md §5).
//!
//! The real TDT2 set is 9394 documents over 36771 terms, 30 one-vs-rest
//! classification tasks with ±50 samples each. What matters for screening
//! is the *statistical shape* the dual sweep sees: extremely sparse
//! documents, Zipf-distributed term frequencies (heavy-tailed column
//! norms, many near-zero columns), and per-category topical terms shared
//! across the positive class. This generator reproduces exactly that:
//!
//! * vocabulary of `d` terms with Zipf(1.1) global frequencies;
//! * each category owns a small set of "topic" terms boosted for its docs;
//! * documents draw ~`doc_len` terms; counts are log-scaled (1+log tf);
//! * task t = category t vs rest, y = ±1, ±`n_pos` docs per side.
//!
//! Storage: the matrix is built **directly in CSC** (DESIGN.md §6) — at
//! default dims ~99% of cells are empty, so densifying first would throw
//! away exactly the structure DPC exploits. `doc_len / d` is the density
//! knob; set `dense: true` to force the dense backend (parity tests,
//! AOT packing experiments).

use super::{Dataset, Task};
use crate::linalg::CscMatrix;
use crate::util::Pcg64;

/// Knobs of the TDT2-like generator.
#[derive(Debug, Clone)]
pub struct TextSimOptions {
    /// number of categories == number of tasks
    pub categories: usize,
    /// positive (== negative) samples per task
    pub n_pos: usize,
    /// vocabulary size (feature count)
    pub d: usize,
    /// terms drawn per document — with `d`, the density knob
    /// (density ≈ distinct(doc_len) / d)
    pub doc_len: usize,
    /// topical terms boosted per category
    pub topic_terms: usize,
    /// RNG seed (every experiment seeds explicitly)
    pub seed: u64,
    /// force dense storage (default: CSC)
    pub dense: bool,
}

impl Default for TextSimOptions {
    fn default() -> Self {
        TextSimOptions {
            categories: 10,
            n_pos: 25,
            d: 8000,
            doc_len: 120,
            topic_terms: 40,
            seed: 0,
            dense: false,
        }
    }
}

fn draw_doc(
    rng: &mut Pcg64,
    d: usize,
    doc_len: usize,
    topic: &[usize],
    topic_boost: f64,
) -> Vec<(usize, f32)> {
    use std::collections::HashMap;
    let mut counts: HashMap<usize, u32> = HashMap::with_capacity(doc_len);
    for _ in 0..doc_len {
        let term = if !topic.is_empty() && rng.uniform() < topic_boost {
            topic[rng.below(topic.len() as u64) as usize]
        } else {
            rng.zipf(d, 1.1)
        };
        *counts.entry(term).or_insert(0) += 1;
    }
    counts
        .into_iter()
        .map(|(t, c)| (t, (1.0 + (c as f32).ln())))
        .collect()
}

/// Build the one-vs-rest multi-task text dataset.
pub fn textsim(opts: &TextSimOptions) -> Dataset {
    let TextSimOptions { categories, n_pos, d, doc_len, topic_terms, seed, dense } = *opts;
    let mut root = Pcg64::with_stream(seed, 0x7d72);

    // each category's topical terms (disjointish, drawn from mid-frequency ranks)
    let topics: Vec<Vec<usize>> = (0..categories)
        .map(|_| root.choose_distinct(d, topic_terms))
        .collect();

    let n = 2 * n_pos;
    let mut tasks = Vec::with_capacity(categories);
    for cat in 0..categories {
        let mut rng = root.split(cat as u64);
        // per-term (document, tf-idf) lists — documents are generated in
        // ascending order, so each column arrives presorted
        let mut cols: Vec<Vec<(u32, f32)>> = vec![Vec::new(); d];
        let mut y = vec![0.0f32; n];
        for ni in 0..n {
            let positive = ni < n_pos;
            y[ni] = if positive { 1.0 } else { -1.0 };
            // negatives come from a random *other* category (one-vs-rest)
            let src = if positive {
                cat
            } else {
                let mut o = rng.below(categories as u64) as usize;
                if o == cat {
                    o = (o + 1) % categories;
                }
                o
            };
            for (term, tfidf) in draw_doc(&mut rng, d, doc_len, &topics[src], 0.35) {
                cols[term].push((ni as u32, tfidf));
            }
        }
        let m = CscMatrix::from_cols(n, cols);
        tasks.push(if dense {
            Task::dense(m.to_dense(), y, n)
        } else {
            Task::csc(m, y)
        });
    }
    Dataset { name: "tdt2sim".into(), d, tasks }
}

/// Indices of features that are nonzero in at least one task (the real-TDT2
/// preprocessing removes the rest; the paper reports 24262 kept of 36771).
pub fn nonzero_features(ds: &Dataset) -> Vec<usize> {
    (0..ds.d)
        .filter(|&l| ds.tasks.iter().any(|t| !t.col(l).is_zero()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_labels() {
        let ds = textsim(&TextSimOptions { categories: 4, n_pos: 6, d: 500, ..Default::default() });
        ds.validate().unwrap();
        assert_eq!(ds.t(), 4);
        assert_eq!(ds.uniform_n(), Some(12));
        assert!(ds.is_sparse(), "textsim must emit CSC by default");
        for t in &ds.tasks {
            assert_eq!(t.y.iter().filter(|&&v| v > 0.0).count(), 6);
        }
    }

    #[test]
    fn documents_are_sparse() {
        let ds =
            textsim(&TextSimOptions { categories: 3, n_pos: 10, d: 2000, ..Default::default() });
        let density = ds.density();
        assert!(density < 0.08, "text matrix should be sparse, density={density}");
        // the CSC representation should be far smaller than the dense one
        let dense_bytes: usize = ds.tasks.iter().map(|t| t.n * ds.d * 4).sum();
        assert!(ds.mem_bytes() < dense_bytes / 4, "CSC did not save memory");
    }

    #[test]
    fn dense_knob_produces_identical_matrix() {
        let sparse_opts =
            TextSimOptions { categories: 2, n_pos: 5, d: 400, seed: 3, ..Default::default() };
        let dense_opts = TextSimOptions { dense: true, ..sparse_opts.clone() };
        let a = textsim(&sparse_opts);
        let b = textsim(&dense_opts);
        assert!(a.is_sparse() && !b.is_sparse());
        for t in 0..a.t() {
            for l in 0..a.d {
                assert_eq!(a.col(t, l).to_vec(), b.col(t, l).to_vec(), "t={t} l={l}");
            }
            assert_eq!(a.tasks[t].y, b.tasks[t].y);
        }
    }

    #[test]
    fn column_norms_are_heavy_tailed() {
        let ds =
            textsim(&TextSimOptions { categories: 2, n_pos: 20, d: 2000, ..Default::default() });
        let b2 = ds.col_sqnorms();
        let mut per_feature: Vec<f64> =
            (0..ds.d).map(|l| b2[l * 2] + b2[l * 2 + 1]).collect();
        per_feature.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let head: f64 = per_feature[..20].iter().sum();
        let total: f64 = per_feature.iter().sum();
        assert!(head / total > 0.2, "Zipf head mass {head}/{total}");
    }

    #[test]
    fn zero_feature_pruning_finds_dead_terms() {
        let ds = textsim(&TextSimOptions {
            categories: 2,
            n_pos: 5,
            d: 5000,
            doc_len: 40,
            ..Default::default()
        });
        let kept = nonzero_features(&ds);
        assert!(kept.len() < ds.d, "tiny corpus must leave unused vocabulary");
        assert!(!kept.is_empty());
        // pruning a CSC dataset keeps it CSC
        assert!(ds.restrict(&kept).is_sparse());
    }

    #[test]
    fn deterministic() {
        let o = TextSimOptions { categories: 2, n_pos: 4, d: 300, seed: 9, ..Default::default() };
        assert_eq!(textsim(&o).tasks[1].x, textsim(&o).tasks[1].x);
    }
}
