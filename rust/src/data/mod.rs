//! Multi-task dataset substrate: the in-memory representation, the paper's
//! five workloads (two synthetic, three simulated "real" sets — see
//! DESIGN.md §5 for the substitution rationale), and a binary on-disk
//! format.

pub mod imagesim;
pub mod io;
pub mod snpsim;
pub mod synthetic;
pub mod textsim;
pub mod transform;

use crate::linalg::ColMajor;

/// One task: an `n x d` feature-major matrix and its response vector.
#[derive(Debug, Clone)]
pub struct Task {
    /// feature-major buffer, length `n * d`; column l = samples of feature l
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub n: usize,
}

impl Task {
    pub fn view(&self, d: usize) -> ColMajor<'_> {
        ColMajor::new(&self.x, self.n, d)
    }
}

/// A multi-task dataset: `T` tasks sharing the same `d` features, each with
/// its **own** data matrix (the setting that makes DPC novel — single-matrix
/// screening rules do not apply).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub d: usize,
    pub tasks: Vec<Task>,
}

impl Dataset {
    pub fn t(&self) -> usize {
        self.tasks.len()
    }

    /// Total sample count N = Σ N_t.
    pub fn total_n(&self) -> usize {
        self.tasks.iter().map(|t| t.n).sum()
    }

    /// All tasks have the same N (required by the AOT engine's (T,N,D) ABI).
    pub fn uniform_n(&self) -> Option<usize> {
        let n0 = self.tasks.first()?.n;
        self.tasks.iter().all(|t| t.n == n0).then_some(n0)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.tasks.is_empty(), "dataset has no tasks");
        anyhow::ensure!(self.d > 0, "dataset has no features");
        for (i, t) in self.tasks.iter().enumerate() {
            anyhow::ensure!(t.n > 0, "task {i} has no samples");
            anyhow::ensure!(
                t.x.len() == t.n * self.d,
                "task {i}: x buffer {} != n*d {}",
                t.x.len(),
                t.n * self.d
            );
            anyhow::ensure!(t.y.len() == t.n, "task {i}: y length mismatch");
            anyhow::ensure!(
                t.x.iter().all(|v| v.is_finite()) && t.y.iter().all(|v| v.is_finite()),
                "task {i}: non-finite entries"
            );
        }
        Ok(())
    }

    /// Column l of task t.
    #[inline]
    pub fn col(&self, t: usize, l: usize) -> &[f32] {
        let task = &self.tasks[t];
        &task.x[l * task.n..(l + 1) * task.n]
    }

    /// Copy the retained features into a compacted dataset (the memory
    /// saving screening buys). `keep` must be sorted & in-range.
    pub fn restrict(&self, keep: &[usize]) -> Dataset {
        let tasks = self
            .tasks
            .iter()
            .map(|task| {
                let mut x = Vec::with_capacity(task.n * keep.len());
                for &l in keep {
                    x.extend_from_slice(&task.x[l * task.n..(l + 1) * task.n]);
                }
                Task { x, y: task.y.clone(), n: task.n }
            })
            .collect();
        Dataset { name: format!("{}[{}]", self.name, keep.len()), d: keep.len(), tasks }
    }

    /// ||x_l^{(t)}||^2 for every (l, t): the b² moments of Theorem 7.
    /// Computed once per dataset and cached by the screeners.
    pub fn col_sqnorms(&self) -> Vec<f64> {
        let t_count = self.t();
        let mut out = vec![0.0f64; self.d * t_count];
        for (ti, task) in self.tasks.iter().enumerate() {
            for l in 0..self.d {
                let col = &task.x[l * task.n..(l + 1) * task.n];
                out[l * t_count + ti] = crate::linalg::dot_f32_f64(col, col);
            }
        }
        out
    }

    /// Pack into the dense (T, N, D) f32 layout of the AOT ABI
    /// (row-major over [t][n][l]). Requires uniform N.
    pub fn to_tnd(&self) -> anyhow::Result<Vec<f32>> {
        let n = self
            .uniform_n()
            .ok_or_else(|| anyhow::anyhow!("AOT packing requires uniform task sizes"))?;
        let t_count = self.t();
        let mut out = vec![0.0f32; t_count * n * self.d];
        for (ti, task) in self.tasks.iter().enumerate() {
            for l in 0..self.d {
                let col = &task.x[l * task.n..(l + 1) * task.n];
                for (ni, &v) in col.iter().enumerate() {
                    out[(ti * n + ni) * self.d + l] = v;
                }
            }
        }
        Ok(out)
    }

    /// Stack y into (T, N) row-major. Requires uniform N.
    pub fn y_tn(&self) -> anyhow::Result<Vec<f32>> {
        let n = self
            .uniform_n()
            .ok_or_else(|| anyhow::anyhow!("AOT packing requires uniform task sizes"))?;
        let mut out = Vec::with_capacity(self.t() * n);
        for task in &self.tasks {
            out.extend_from_slice(&task.y);
        }
        Ok(out)
    }
}

/// The ground-truth used by synthetic generators (for recovery metrics).
#[derive(Debug, Clone)]
pub struct GroundTruth {
    /// active feature indices (rows of W* that are nonzero)
    pub active: Vec<usize>,
    /// full weight matrix, row-major (d x T)
    pub w: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::synthetic::{synthetic1, SynthOptions};
    use super::*;

    fn tiny() -> Dataset {
        let (ds, _) = synthetic1(&SynthOptions { t: 3, n: 8, d: 20, ..Default::default() });
        ds
    }

    #[test]
    fn validate_ok_and_shape_accessors() {
        let ds = tiny();
        ds.validate().unwrap();
        assert_eq!(ds.t(), 3);
        assert_eq!(ds.total_n(), 24);
        assert_eq!(ds.uniform_n(), Some(8));
    }

    #[test]
    fn restrict_keeps_exact_columns() {
        let ds = tiny();
        let keep = vec![1usize, 5, 19];
        let r = ds.restrict(&keep);
        assert_eq!(r.d, 3);
        for t in 0..ds.t() {
            for (new_l, &old_l) in keep.iter().enumerate() {
                assert_eq!(r.col(t, new_l), ds.col(t, old_l));
            }
            assert_eq!(r.tasks[t].y, ds.tasks[t].y);
        }
    }

    #[test]
    fn tnd_round_trip() {
        let ds = tiny();
        let tnd = ds.to_tnd().unwrap();
        let n = 8;
        for t in 0..3 {
            for l in 0..20 {
                let col = ds.col(t, l);
                for ni in 0..n {
                    assert_eq!(tnd[(t * n + ni) * 20 + l], col[ni]);
                }
            }
        }
        let y = ds.y_tn().unwrap();
        assert_eq!(&y[8..16], ds.tasks[1].y.as_slice());
    }

    #[test]
    fn col_sqnorms_match_manual() {
        let ds = tiny();
        let b2 = ds.col_sqnorms();
        for t in 0..ds.t() {
            for l in 0..ds.d {
                let want: f64 = ds.col(t, l).iter().map(|v| (*v as f64).powi(2)).sum();
                assert!((b2[l * ds.t() + t] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn validate_rejects_bad_buffer() {
        let mut ds = tiny();
        ds.tasks[0].x.pop();
        assert!(ds.validate().is_err());
    }
}
