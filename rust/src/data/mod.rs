//! Multi-task dataset substrate: the pluggable in-RAM matrix backends
//! ([`MatrixStore`], see DESIGN.md §6), the out-of-core sharded backend
//! ([`shard::ShardedDataset`], DESIGN.md §10), the paper's five workloads
//! (two synthetic, three simulated "real" sets — see DESIGN.md §5 for the
//! substitution rationale), and the binary on-disk formats ([`io`]).

pub mod imagesim;
pub mod io;
pub mod shard;
pub mod snpsim;
pub mod synthetic;
pub mod textsim;
pub mod transform;

pub use shard::{PrefetchStats, ShardedDataset};

use crate::linalg::{ColRef, CscMatrix};

/// Backend-tagged storage for one task's `n x d` feature-major matrix.
/// Every consumer reaches columns through [`ColRef`] (via [`Task::col`] /
/// [`Dataset::col`]); nothing above `linalg` sees the layout.
#[derive(Debug, Clone, PartialEq)]
pub enum MatrixStore {
    /// feature-major buffer, length `n * d`; column l = samples of feature l
    Dense(Vec<f32>),
    /// CSC per-column storage (text/genomics regime)
    Csc(CscMatrix),
}

impl MatrixStore {
    /// Column `l` as a backend-tagged view. `n` is the task's sample count
    /// (the dense buffer does not carry its own shape).
    #[inline]
    pub fn col(&self, l: usize, n: usize) -> ColRef<'_> {
        match self {
            MatrixStore::Dense(x) => ColRef::Dense(&x[l * n..(l + 1) * n]),
            MatrixStore::Csc(m) => {
                let (indices, values) = m.col(l);
                ColRef::Sparse { n: m.n, indices, values }
            }
        }
    }

    /// True for CSC storage.
    pub fn is_sparse(&self) -> bool {
        matches!(self, MatrixStore::Csc(_))
    }

    /// Stored nonzero count (dense counts exact nonzeros).
    pub fn nnz(&self, n: usize, d: usize) -> usize {
        match self {
            MatrixStore::Dense(x) => {
                debug_assert_eq!(x.len(), n * d);
                x.iter().filter(|&&v| v != 0.0).count()
            }
            MatrixStore::Csc(m) => m.nnz(),
        }
    }

    /// Stored entries one full column sweep touches: every cell for a
    /// dense buffer, only the stored nonzeros for CSC. O(1) on both
    /// backends — this is the work estimate, not a zero count.
    pub fn stored_entries(&self) -> usize {
        match self {
            MatrixStore::Dense(x) => x.len(),
            MatrixStore::Csc(m) => m.nnz(),
        }
    }

    /// Heap footprint in bytes (the memory win sparse storage buys).
    pub fn mem_bytes(&self) -> usize {
        match self {
            MatrixStore::Dense(x) => x.len() * 4,
            MatrixStore::Csc(m) => m.mem_bytes(),
        }
    }

    /// Densify (feature-major copy).
    pub fn to_dense(&self, n: usize, d: usize) -> Vec<f32> {
        match self {
            MatrixStore::Dense(x) => {
                debug_assert_eq!(x.len(), n * d);
                x.clone()
            }
            MatrixStore::Csc(m) => m.to_dense(),
        }
    }

    /// Convert to CSC (drops exact zeros; a CSC store is cloned).
    pub fn to_csc(&self, n: usize, d: usize) -> CscMatrix {
        match self {
            MatrixStore::Dense(x) => CscMatrix::from_dense(x, n, d),
            MatrixStore::Csc(m) => m.clone(),
        }
    }

    /// Row subset preserving the backend: new row `j` is old row `idx[j]`
    /// (distinct, in-range indices — the CV / stability subsamplers).
    pub fn select_rows(&self, idx: &[usize], n: usize, d: usize) -> MatrixStore {
        match self {
            MatrixStore::Dense(x) => {
                let n_new = idx.len();
                let mut out = vec![0.0f32; n_new * d];
                for l in 0..d {
                    let col = &x[l * n..(l + 1) * n];
                    for (j, &i) in idx.iter().enumerate() {
                        out[l * n_new + j] = col[i];
                    }
                }
                MatrixStore::Dense(out)
            }
            MatrixStore::Csc(m) => MatrixStore::Csc(m.select_rows(idx)),
        }
    }

    /// Scale every entry by `s`, preserving the backend.
    pub fn scaled(&self, s: f32) -> MatrixStore {
        match self {
            MatrixStore::Dense(x) => MatrixStore::Dense(x.iter().map(|&v| v * s).collect()),
            MatrixStore::Csc(m) => MatrixStore::Csc(m.scaled(s)),
        }
    }
}

/// One task: an `n x d` feature-major matrix (dense or CSC) and its
/// response vector.
#[derive(Debug, Clone)]
pub struct Task {
    /// the task's feature matrix (dense or CSC)
    pub x: MatrixStore,
    /// the task's response vector, length `n`
    pub y: Vec<f32>,
    /// sample count
    pub n: usize,
}

impl Task {
    /// A dense-backed task from a feature-major buffer.
    pub fn dense(x: Vec<f32>, y: Vec<f32>, n: usize) -> Task {
        Task { x: MatrixStore::Dense(x), y, n }
    }

    /// A CSC-backed task (n is taken from the matrix).
    pub fn csc(x: CscMatrix, y: Vec<f32>) -> Task {
        let n = x.n;
        Task { x: MatrixStore::Csc(x), y, n }
    }

    /// Column l of this task's matrix.
    #[inline]
    pub fn col(&self, l: usize) -> ColRef<'_> {
        self.x.col(l, self.n)
    }

    /// True if this task uses CSC storage.
    pub fn is_sparse(&self) -> bool {
        self.x.is_sparse()
    }
}

/// A multi-task dataset: `T` tasks sharing the same `d` features, each with
/// its **own** data matrix (the setting that makes DPC novel — single-matrix
/// screening rules do not apply). Tasks may mix backends, though the
/// generators emit one backend per dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// workload name (carried through reports and the on-disk formats)
    pub name: String,
    /// shared feature count
    pub d: usize,
    /// the per-task matrices and responses
    pub tasks: Vec<Task>,
}

impl Dataset {
    /// Number of tasks T.
    pub fn t(&self) -> usize {
        self.tasks.len()
    }

    /// Total sample count N = Σ N_t.
    pub fn total_n(&self) -> usize {
        self.tasks.iter().map(|t| t.n).sum()
    }

    /// All tasks have the same N (required by the AOT engine's (T,N,D) ABI).
    pub fn uniform_n(&self) -> Option<usize> {
        let n0 = self.tasks.first()?.n;
        self.tasks.iter().all(|t| t.n == n0).then_some(n0)
    }

    /// True if every task uses CSC storage.
    pub fn is_sparse(&self) -> bool {
        !self.tasks.is_empty() && self.tasks.iter().all(|t| t.is_sparse())
    }

    /// Stored-nonzero fraction across all tasks.
    pub fn density(&self) -> f64 {
        let cells: usize = self.tasks.iter().map(|t| t.n * self.d).sum();
        if cells == 0 {
            return 0.0;
        }
        let nnz: usize = self.tasks.iter().map(|t| t.x.nnz(t.n, self.d)).sum();
        nnz as f64 / cells as f64
    }

    /// Heap footprint of all task matrices, in bytes.
    pub fn mem_bytes(&self) -> usize {
        self.tasks.iter().map(|t| t.x.mem_bytes()).sum()
    }

    /// Entries one full column sweep actually touches (Σ_t stored entries).
    /// The "spawn worker threads?" heuristics gate on this, so a 1%-dense
    /// CSC dataset is not threaded as if it were dense (its sweep is ~100×
    /// cheaper than d·N suggests).
    pub fn sweep_work(&self) -> usize {
        self.tasks.iter().map(|t| t.x.stored_entries()).sum()
    }

    /// Structural invariants: shapes, finite entries, CSC well-formedness.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.tasks.is_empty(), "dataset has no tasks");
        anyhow::ensure!(self.d > 0, "dataset has no features");
        for (i, t) in self.tasks.iter().enumerate() {
            anyhow::ensure!(t.n > 0, "task {i} has no samples");
            match &t.x {
                MatrixStore::Dense(x) => {
                    anyhow::ensure!(
                        x.len() == t.n * self.d,
                        "task {i}: x buffer {} != n*d {}",
                        x.len(),
                        t.n * self.d
                    );
                    anyhow::ensure!(
                        x.iter().all(|v| v.is_finite()),
                        "task {i}: non-finite entries"
                    );
                }
                MatrixStore::Csc(m) => {
                    anyhow::ensure!(
                        m.n == t.n && m.d == self.d,
                        "task {i}: CSC shape {}x{} != {}x{}",
                        m.n,
                        m.d,
                        t.n,
                        self.d
                    );
                    m.validate()
                        .map_err(|e| anyhow::anyhow!("task {i}: {e}"))?;
                }
            }
            anyhow::ensure!(t.y.len() == t.n, "task {i}: y length mismatch");
            anyhow::ensure!(
                t.y.iter().all(|v| v.is_finite()),
                "task {i}: non-finite responses"
            );
        }
        Ok(())
    }

    /// Column l of task t.
    #[inline]
    pub fn col(&self, t: usize, l: usize) -> ColRef<'_> {
        self.tasks[t].col(l)
    }

    /// Copy the retained features into a compacted dataset (the memory
    /// saving screening buys). `keep` must be sorted & in-range. A sparse
    /// task stays sparse — compaction is pointer arithmetic, no densify.
    pub fn restrict(&self, keep: &[usize]) -> Dataset {
        let tasks = self
            .tasks
            .iter()
            .map(|task| {
                let x = match &task.x {
                    MatrixStore::Dense(x) => {
                        let mut out = Vec::with_capacity(task.n * keep.len());
                        for &l in keep {
                            out.extend_from_slice(&x[l * task.n..(l + 1) * task.n]);
                        }
                        MatrixStore::Dense(out)
                    }
                    MatrixStore::Csc(m) => MatrixStore::Csc(m.select_cols(keep)),
                };
                Task { x, y: task.y.clone(), n: task.n }
            })
            .collect();
        Dataset { name: format!("{}[{}]", self.name, keep.len()), d: keep.len(), tasks }
    }

    /// ||x_l^{(t)}||^2 for every (l, t): the b² moments of Theorem 7.
    /// Computed once per dataset and cached by the screeners. Each column
    /// is one pass through the contract kernels (`ColRef::sqnorm` →
    /// SIMD-dispatched `dot_f32_f64`, DESIGN.md §12); no panel blocking
    /// applies because no vector is shared across columns.
    pub fn col_sqnorms(&self) -> Vec<f64> {
        let t_count = self.t();
        let mut out = vec![0.0f64; self.d * t_count];
        for (ti, task) in self.tasks.iter().enumerate() {
            for l in 0..self.d {
                out[l * t_count + ti] = task.col(l).sqnorm();
            }
        }
        out
    }

    /// Convert every task to CSC storage (drops exact zeros).
    pub fn to_csc(&self) -> Dataset {
        let tasks = self
            .tasks
            .iter()
            .map(|t| Task {
                x: MatrixStore::Csc(t.x.to_csc(t.n, self.d)),
                y: t.y.clone(),
                n: t.n,
            })
            .collect();
        Dataset { name: self.name.clone(), d: self.d, tasks }
    }

    /// Convert every task to dense storage.
    pub fn to_dense_backend(&self) -> Dataset {
        let tasks = self
            .tasks
            .iter()
            .map(|t| Task {
                x: MatrixStore::Dense(t.x.to_dense(t.n, self.d)),
                y: t.y.clone(),
                n: t.n,
            })
            .collect();
        Dataset { name: self.name.clone(), d: self.d, tasks }
    }

    /// Pack into the dense (T, N, D) f32 layout of the AOT ABI
    /// (row-major over `[t][n][l]`). Requires uniform N.
    pub fn to_tnd(&self) -> anyhow::Result<Vec<f32>> {
        let n = self
            .uniform_n()
            .ok_or_else(|| anyhow::anyhow!("AOT packing requires uniform task sizes"))?;
        let t_count = self.t();
        let d = self.d;
        let mut out = vec![0.0f32; t_count * n * d];
        for (ti, task) in self.tasks.iter().enumerate() {
            for l in 0..d {
                task.col(l).for_each_nonzero(|ni, v| {
                    out[(ti * n + ni) * d + l] = v;
                });
            }
        }
        Ok(out)
    }

    /// Stack y into (T, N) row-major. Requires uniform N.
    pub fn y_tn(&self) -> anyhow::Result<Vec<f32>> {
        let n = self
            .uniform_n()
            .ok_or_else(|| anyhow::anyhow!("AOT packing requires uniform task sizes"))?;
        let mut out = Vec::with_capacity(self.t() * n);
        for task in &self.tasks {
            out.extend_from_slice(&task.y);
        }
        Ok(out)
    }
}

/// The ground-truth used by synthetic generators (for recovery metrics).
#[derive(Debug, Clone)]
pub struct GroundTruth {
    /// active feature indices (rows of W* that are nonzero)
    pub active: Vec<usize>,
    /// full weight matrix, row-major (d x T)
    pub w: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::synthetic::{synthetic1, SynthOptions};
    use super::*;

    fn tiny() -> Dataset {
        let (ds, _) = synthetic1(&SynthOptions { t: 3, n: 8, d: 20, ..Default::default() });
        ds
    }

    #[test]
    fn validate_ok_and_shape_accessors() {
        let ds = tiny();
        ds.validate().unwrap();
        assert_eq!(ds.t(), 3);
        assert_eq!(ds.total_n(), 24);
        assert_eq!(ds.uniform_n(), Some(8));
        assert!(!ds.is_sparse());
    }

    #[test]
    fn restrict_keeps_exact_columns() {
        let ds = tiny();
        let keep = vec![1usize, 5, 19];
        let r = ds.restrict(&keep);
        assert_eq!(r.d, 3);
        for t in 0..ds.t() {
            for (new_l, &old_l) in keep.iter().enumerate() {
                assert_eq!(r.col(t, new_l).to_vec(), ds.col(t, old_l).to_vec());
            }
            assert_eq!(r.tasks[t].y, ds.tasks[t].y);
        }
    }

    #[test]
    fn restrict_preserves_sparse_backend() {
        let ds = tiny().to_csc();
        let keep = vec![0usize, 7, 13, 19];
        let r = ds.restrict(&keep);
        assert!(r.is_sparse());
        r.validate().unwrap();
        for t in 0..ds.t() {
            for (new_l, &old_l) in keep.iter().enumerate() {
                assert_eq!(r.col(t, new_l).to_vec(), ds.col(t, old_l).to_vec());
            }
        }
    }

    #[test]
    fn tnd_round_trip() {
        let ds = tiny();
        let tnd = ds.to_tnd().unwrap();
        let n = 8;
        for t in 0..3 {
            for l in 0..20 {
                let col = ds.col(t, l).to_vec();
                for ni in 0..n {
                    assert_eq!(tnd[(t * n + ni) * 20 + l], col[ni]);
                }
            }
        }
        let y = ds.y_tn().unwrap();
        assert_eq!(&y[8..16], ds.tasks[1].y.as_slice());
        // CSC packing produces the identical buffer
        assert_eq!(ds.to_csc().to_tnd().unwrap(), tnd);
    }

    #[test]
    fn col_sqnorms_match_manual() {
        let ds = tiny();
        let b2 = ds.col_sqnorms();
        for t in 0..ds.t() {
            for l in 0..ds.d {
                let want: f64 =
                    ds.col(t, l).to_vec().iter().map(|v| (*v as f64).powi(2)).sum();
                assert!((b2[l * ds.t() + t] - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn csc_round_trip_preserves_columns() {
        let ds = tiny();
        let sp = ds.to_csc();
        sp.validate().unwrap();
        assert!(sp.is_sparse());
        let back = sp.to_dense_backend();
        for t in 0..ds.t() {
            for l in 0..ds.d {
                assert_eq!(back.col(t, l).to_vec(), ds.col(t, l).to_vec());
            }
        }
        // Gaussian entries: no exact zeros, density 1
        assert!((sp.density() - 1.0).abs() < 1e-12);
        assert!(ds.mem_bytes() > 0);
    }

    #[test]
    fn sweep_work_counts_stored_entries_per_backend() {
        let ds = tiny(); // dense 3 tasks × (8 × 20)
        assert_eq!(ds.sweep_work(), 3 * 8 * 20);
        // Gaussian entries: no exact zeros, CSC stores everything
        assert_eq!(ds.to_csc().sweep_work(), 3 * 8 * 20);
        // a CSC store with dropped zeros reports only stored nonzeros
        let m = crate::linalg::CscMatrix::from_dense(&[1.0, 0.0, 0.0, 2.0, 0.0, 0.0], 3, 2);
        let store = MatrixStore::Csc(m);
        assert_eq!(store.stored_entries(), 2);
    }

    #[test]
    fn select_rows_agrees_across_backends() {
        let ds = tiny();
        let idx = vec![5usize, 0, 3];
        let a = ds.tasks[1].x.select_rows(&idx, 8, ds.d);
        let b = ds.to_csc().tasks[1].x.select_rows(&idx, 8, ds.d);
        for l in 0..ds.d {
            assert_eq!(a.col(l, 3).to_vec(), b.col(l, 3).to_vec());
        }
    }

    #[test]
    fn validate_rejects_bad_buffer() {
        let mut ds = tiny();
        match &mut ds.tasks[0].x {
            MatrixStore::Dense(x) => {
                x.pop();
            }
            MatrixStore::Csc(_) => unreachable!("synthetic data is dense"),
        }
        assert!(ds.validate().is_err());
    }
}
