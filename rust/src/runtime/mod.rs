//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the coordinator's hot
//! path. Python never runs here — the artifacts are self-contained.
//!
//! * [`manifest`] — parse `artifacts/manifest.tsv` (the ABI registry);
//! * [`engine`]   — compile-on-first-use executable cache + typed call
//!   helpers for each artifact kind (lammax / screen / lipschitz / fista);
//! * [`buckets`]  — shape-bucketing policy mapping screened (reduced-d)
//!   problems onto the fixed-shape solver executables.

pub mod buckets;
pub mod engine;
pub mod manifest;

pub use buckets::pick_bucket;
pub use engine::AotEngine;
pub use manifest::{ArtifactMeta, Manifest};
