//! Shape bucketing: screened problems have arbitrary reduced dimension d',
//! but HLO executables are fixed-shape. The coordinator packs the retained
//! columns into the smallest bucket ≥ d' and zero-pads the rest.
//!
//! Correctness: a zero column contributes nothing to X w (its weight row
//! stays zero under the prox since its gradient is identically zero), so
//! the solution on the retained coordinates is unchanged — verified by
//! `padding_preserves_solution` in rust/tests/integration_runtime.rs.

/// Smallest bucket ≥ d', or None if d' exceeds every bucket.
pub fn pick_bucket(buckets: &[usize], d_reduced: usize) -> Option<usize> {
    buckets.iter().copied().filter(|&b| b >= d_reduced).min()
}

/// Pack a reduced (T,N,d') problem into a (T,N,db) row-major f32 buffer.
/// `cols[t]` is the task's feature-major buffer, `keep` the retained
/// feature indices (into the *original* d).
pub fn pack_tnd(
    tasks: &[crate::data::Task],
    keep: &[usize],
    db: usize,
) -> Vec<f32> {
    let t_count = tasks.len();
    let n = tasks.first().map(|t| t.n).unwrap_or(0);
    assert!(keep.len() <= db, "bucket too small: {} > {db}", keep.len());
    let mut out = vec![0.0f32; t_count * n * db];
    for (ti, task) in tasks.iter().enumerate() {
        debug_assert_eq!(task.n, n, "uniform N required for AOT packing");
        for (j, &l) in keep.iter().enumerate() {
            // scatter stored entries into the zero-initialized bucket
            task.col(l).for_each_nonzero(|ni, v| {
                out[(ti * n + ni) * db + j] = v;
            });
        }
    }
    out
}

/// Pack a full-d (d x T) f64 weight matrix into a (db x T) f32 buffer over
/// the kept features (for warm starts into the bucketed solver).
pub fn pack_w(w: &[f64], t_count: usize, keep: &[usize], db: usize) -> Vec<f32> {
    assert!(keep.len() <= db);
    let mut out = vec![0.0f32; db * t_count];
    for (j, &l) in keep.iter().enumerate() {
        for t in 0..t_count {
            out[j * t_count + t] = w[l * t_count + t] as f32;
        }
    }
    out
}

/// Scatter a bucketed (db x T) f32 solution back to full-d f64 (zeros on
/// screened features). Padding columns (j >= keep.len()) must be ~zero.
pub fn unpack_w(
    wb: &[f32],
    t_count: usize,
    keep: &[usize],
    db: usize,
    d_full: usize,
) -> Vec<f64> {
    assert_eq!(wb.len(), db * t_count);
    let mut out = vec![0.0f64; d_full * t_count];
    for (j, &l) in keep.iter().enumerate() {
        for t in 0..t_count {
            out[l * t_count + t] = wb[j * t_count + t] as f64;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Task;

    #[test]
    fn bucket_selection() {
        let buckets = [64, 128, 256];
        assert_eq!(pick_bucket(&buckets, 1), Some(64));
        assert_eq!(pick_bucket(&buckets, 64), Some(64));
        assert_eq!(pick_bucket(&buckets, 65), Some(128));
        assert_eq!(pick_bucket(&buckets, 256), Some(256));
        assert_eq!(pick_bucket(&buckets, 257), None);
    }

    #[test]
    fn pack_places_columns_and_zero_pads() {
        // 1 task, n=2, d=3; keep features [2, 0] into bucket 4
        let task = Task::dense(vec![1., 2., 3., 4., 5., 6.], vec![0., 0.], 2);
        let packed = pack_tnd(&[task], &[2, 0], 4);
        // layout (t*n + ni)*db + j
        assert_eq!(packed[0], 5.0); // n0, slot0 <- old col2
        assert_eq!(packed[1], 1.0); // n0, slot1 <- old col0
        assert_eq!(packed[2], 0.0); // padding
        assert_eq!(packed[4], 6.0); // n1, slot0
        assert_eq!(packed[5], 2.0);
    }

    #[test]
    fn w_round_trip() {
        let t_count = 2;
        let d_full = 5;
        let mut w = vec![0.0f64; d_full * t_count];
        w[3 * 2] = 1.5;
        w[3 * 2 + 1] = -2.5;
        w[1 * 2] = 0.25;
        let keep = [1usize, 3];
        let wb = pack_w(&w, t_count, &keep, 4);
        assert_eq!(wb[0], 0.25);
        assert_eq!(wb[1 * 2], 1.5);
        let back = unpack_w(&wb, t_count, &keep, 4, d_full);
        assert_eq!(back, w);
    }
}
