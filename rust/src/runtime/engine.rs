//! The AOT execution engine: PJRT CPU client + compile-on-first-use
//! executable cache + typed wrappers for each artifact kind.
//!
//! Interchange is HLO *text* (see aot.py for why), parsed and re-id'd by
//! `HloModuleProto::from_text_file`, compiled once per process, and
//! executed with f32 literals. All wrappers validate shapes against the
//! manifest ABI before touching PJRT.
//!
//! The PJRT path needs the external `xla` crate, which is not available in
//! the offline build environment, so everything touching it is gated
//! behind the `aot` cargo feature. The default build keeps the full public
//! API (so the coordinator, CLI, and benches compile unchanged) but
//! `AotEngine::new` returns an error directing callers to the exact
//! engine.

use super::manifest::Manifest;
#[cfg(feature = "aot")]
use super::manifest::ArtifactMeta;
use anyhow::Result;
#[cfg(feature = "aot")]
use anyhow::Context;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// The PJRT executor: compiles the AOT HLO artifacts on first use and
/// serves the typed call wrappers. The default (offline) build ships a
/// stub whose constructor errors with a pointer at the exact engine.
pub struct AotEngine {
    #[cfg(feature = "aot")]
    client: xla::PjRtClient,
    /// the artifact registry parsed from `manifest.tsv`
    pub manifest: Manifest,
    #[cfg(feature = "aot")]
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// compile wallclock per artifact (perf accounting)
    pub compile_secs: Mutex<HashMap<String, f64>>,
}

/// Outputs of the `lammax` artifact (Theorem 1 on the accelerator).
#[derive(Debug, Clone)]
pub struct LamMaxOut {
    /// λ_max
    pub lam_max: f32,
    /// n(lambda_max), row-major (T, N)
    pub normal: Vec<f32>,
    /// g_l(y) per feature
    pub g: Vec<f32>,
}

/// Outputs of one `fista` chunk artifact (a fixed number of steps).
#[derive(Debug, Clone)]
pub struct FistaChunkOut {
    /// iterate W, bucketed (db x T)
    pub w: Vec<f32>,
    /// momentum point V, bucketed (db x T)
    pub v: Vec<f32>,
    /// momentum scalar t
    pub t: f32,
    /// residual X W − y, row-major (T, N)
    pub r: Vec<f32>,
    /// primal objective at W
    pub obj: f32,
    /// duality gap at W
    pub gap: f32,
}

#[cfg(feature = "aot")]
fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let elems: usize = shape.iter().product();
    anyhow::ensure!(elems == data.len(), "literal shape {shape:?} != data len {}", data.len());
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&v| v as i64).collect();
    Ok(lit.reshape(&dims)?)
}

#[cfg(feature = "aot")]
fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(feature = "aot")]
impl AotEngine {
    /// Load the manifest and create a PJRT CPU client.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(AotEngine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            compile_secs: Mutex::new(HashMap::new()),
        })
    }

    fn executable(&self, meta: &ArtifactMeta) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(&meta.name) {
            return Ok(exe.clone());
        }
        let sw = crate::util::Stopwatch::started();
        let proto = xla::HloModuleProto::from_text_file(&meta.path)
            .with_context(|| format!("parse HLO text {}", meta.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client.compile(&comp).with_context(|| format!("compile {}", meta.name))?,
        );
        self.compile_secs
            .lock()
            .unwrap()
            .insert(meta.name.clone(), sw.secs());
        self.cache.lock().unwrap().insert(meta.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile every artifact of a config (so timing runs don't pay
    /// compile cost inside the measured region).
    pub fn warmup_config(&self, cfg: &str) -> Result<()> {
        let metas: Vec<ArtifactMeta> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.cfg == cfg)
            .cloned()
            .collect();
        for meta in metas {
            self.executable(&meta)?;
        }
        Ok(())
    }

    /// Execute artifact `name` with raw f32 buffers; returns one f32 buffer
    /// per output (the aot.py convention is a single tuple output).
    pub fn call(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let meta = self
            .manifest
            .find(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?
            .clone();
        anyhow::ensure!(
            inputs.len() == meta.inputs.len(),
            "{name}: expected {} inputs, got {}",
            meta.inputs.len(),
            inputs.len()
        );
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .zip(&meta.inputs)
            .enumerate()
            .map(|(i, (data, spec))| {
                literal_f32(data, &spec.shape)
                    .with_context(|| format!("{name}: input {i} ({:?})", spec.shape))
            })
            .collect::<Result<_>>()?;

        let exe = self.executable(&meta)?;
        let result = exe.execute::<xla::Literal>(&lits)?;
        let first = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow::anyhow!("{name}: empty execution result"))?;
        let lit = first.to_literal_sync()?;
        // aot.py lowers with return_tuple=True: single tuple output
        let mut lit = lit;
        let parts = lit.decompose_tuple()?;
        let outs: Vec<Vec<f32>> = if parts.is_empty() {
            vec![literal_to_f32(&lit)?]
        } else {
            parts.iter().map(literal_to_f32).collect::<Result<_>>()?
        };
        anyhow::ensure!(
            outs.len() == meta.outputs.len(),
            "{name}: expected {} outputs, got {}",
            meta.outputs.len(),
            outs.len()
        );
        for (i, (out, spec)) in outs.iter().zip(&meta.outputs).enumerate() {
            anyhow::ensure!(
                out.len() == spec.elems(),
                "{name}: output {i} has {} elems, ABI says {:?}",
                out.len(),
                spec.shape
            );
        }
        Ok(outs)
    }
}

/// Stub build (no `aot` feature): the type exists and the coordinator/CLI
/// compile, but construction fails with a pointer at the exact engine.
#[cfg(not(feature = "aot"))]
impl AotEngine {
    /// Stub constructor: always errors (the `xla` crate is absent).
    pub fn new(_artifact_dir: &Path) -> Result<Self> {
        anyhow::bail!(
            "built without the `aot` feature: the PJRT engine needs the external \
             `xla` crate (unavailable offline); use the exact engine instead"
        )
    }

    /// Stub: always errors (see [`AotEngine::new`]).
    pub fn warmup_config(&self, _cfg: &str) -> Result<()> {
        anyhow::bail!("AOT engine unavailable: built without the `aot` feature")
    }

    /// Stub: always errors (see [`AotEngine::new`]).
    pub fn call(&self, _name: &str, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        anyhow::bail!("AOT engine unavailable: built without the `aot` feature")
    }
}

// -- typed wrappers (shared shape validation lives in `call`) --------------

impl AotEngine {
    /// lammax artifact: (X, y) -> (lam_max, n, g).
    pub fn lammax(&self, cfg: &str, x_tnd: &[f32], y_tn: &[f32]) -> Result<LamMaxOut> {
        let outs = self.call(&format!("lammax_{cfg}"), &[x_tnd, y_tn])?;
        Ok(LamMaxOut { lam_max: outs[0][0], normal: outs[1].clone(), g: outs[2].clone() })
    }

    /// screen artifact: (X, y, theta0, n(lam0), lam) -> s.
    pub fn screen(
        &self,
        cfg: &str,
        x_tnd: &[f32],
        y_tn: &[f32],
        theta0: &[f32],
        normal: &[f32],
        lam: f32,
    ) -> Result<Vec<f32>> {
        let mut outs = self.call(
            &format!("screen_{cfg}"),
            &[x_tnd, y_tn, theta0, normal, &[lam]],
        )?;
        Ok(outs.remove(0))
    }

    /// lipschitz artifact for a bucket: (X,) -> L.
    pub fn lipschitz(&self, cfg: &str, bucket: usize, x_tnd: &[f32]) -> Result<f32> {
        let outs = self.call(&format!("lipschitz_{cfg}_b{bucket}"), &[x_tnd])?;
        Ok(outs[0][0])
    }

    /// One fista chunk: returns (W, V, t, R, obj, gap).
    #[allow(clippy::too_many_arguments)]
    pub fn fista_chunk(
        &self,
        cfg: &str,
        bucket: usize,
        x_tnd: &[f32],
        y_tn: &[f32],
        w: &[f32],
        v: &[f32],
        t: f32,
        lam: f32,
        lcap: f32,
    ) -> Result<FistaChunkOut> {
        let outs = self.call(
            &format!("fista_{cfg}_b{bucket}"),
            &[x_tnd, y_tn, w, v, &[t], &[lam], &[lcap]],
        )?;
        let mut it = outs.into_iter();
        Ok(FistaChunkOut {
            w: it.next().unwrap(),
            v: it.next().unwrap(),
            t: it.next().unwrap()[0],
            r: it.next().unwrap(),
            obj: it.next().unwrap()[0],
            gap: it.next().unwrap()[0],
        })
    }

    /// Iterate fista chunks until the relative duality gap reaches `tol`.
    /// Returns the final chunk output plus the chunk count.
    #[allow(clippy::too_many_arguments)]
    pub fn fista_solve(
        &self,
        cfg: &str,
        bucket: usize,
        x_tnd: &[f32],
        y_tn: &[f32],
        w0: &[f32],
        lam: f32,
        tol: f32,
        max_chunks: usize,
    ) -> Result<(FistaChunkOut, usize)> {
        let lcap = self.lipschitz(cfg, bucket, x_tnd)?;
        let mut w = w0.to_vec();
        let mut v = w0.to_vec();
        let mut t = 1.0f32;
        let mut chunks = 0usize;
        let mut last: Option<FistaChunkOut> = None;
        while chunks < max_chunks {
            let out = self.fista_chunk(cfg, bucket, x_tnd, y_tn, &w, &v, t, lam, lcap)?;
            chunks += 1;
            let done = out.gap <= tol * out.obj.abs().max(1.0);
            w = out.w.clone();
            v = out.v.clone();
            t = out.t;
            last = Some(out);
            if done {
                break;
            }
        }
        Ok((last.expect("max_chunks >= 1"), chunks))
    }
}
