//! `artifacts/manifest.tsv` — the ABI registry emitted by aot.py.
//!
//! Columns: name, kind, cfg, T, N, D, bucket, steps, inputs, outputs.
//! Shape syntax: `4x16x256:f32;4x16:f32` (semicolon-separated tensors).

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One tensor of an artifact's ABI: shape plus dtype string.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// dimensions, outermost first
    pub shape: Vec<usize>,
    /// dtype name as emitted by aot.py (currently always `f32`)
    pub dtype: String,
}

impl TensorSpec {
    /// Element count (product of dims).
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

fn parse_specs(s: &str) -> Result<Vec<TensorSpec>> {
    s.split(';')
        .filter(|p| !p.is_empty())
        .map(|part| {
            let (dims, dtype) =
                part.split_once(':').with_context(|| format!("bad tensor spec '{part}'"))?;
            let shape = dims
                .split('x')
                .map(|d| d.parse::<usize>().map_err(|_| anyhow::anyhow!("bad dim '{d}'")))
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSpec { shape, dtype: dtype.to_string() })
        })
        .collect()
}

/// One artifact's registry row: identity, shape, and ABI.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// artifact name (also its `.hlo.txt` stem)
    pub name: String,
    /// artifact kind: lammax | screen | lipschitz | fista
    pub kind: String,
    /// shape-config label shared by one dataset shape's artifacts
    pub cfg: String,
    /// task count the graph was lowered for
    pub t: usize,
    /// per-task sample count the graph was lowered for
    pub n: usize,
    /// full feature dimension the graph was lowered for
    pub d: usize,
    /// solver bucket width (0 for non-solver artifacts)
    pub bucket: usize,
    /// steps fused into one solver chunk (0 for non-solver artifacts)
    pub steps: usize,
    /// input tensor ABI, in call order
    pub inputs: Vec<TensorSpec>,
    /// output tensor ABI, in return order
    pub outputs: Vec<TensorSpec>,
    /// path of the HLO text file
    pub path: PathBuf,
}

/// The parsed artifact registry of one `artifacts/` directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// every artifact row, in file order
    pub artifacts: Vec<ArtifactMeta>,
    /// the directory the manifest was loaded from
    pub dir: PathBuf,
}

impl Manifest {
    /// Parse `dir/manifest.tsv`, checking every referenced file exists.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        let mut lines = text.lines();
        let header = lines.next().context("empty manifest")?;
        if !header.starts_with("name\tkind") {
            bail!("unexpected manifest header: {header}");
        }
        let mut artifacts = Vec::new();
        for (lineno, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 10 {
                bail!("manifest line {}: expected 10 columns, got {}", lineno + 2, cols.len());
            }
            let meta = ArtifactMeta {
                name: cols[0].to_string(),
                kind: cols[1].to_string(),
                cfg: cols[2].to_string(),
                t: cols[3].parse().context("T")?,
                n: cols[4].parse().context("N")?,
                d: cols[5].parse().context("D")?,
                bucket: cols[6].parse().context("bucket")?,
                steps: cols[7].parse().context("steps")?,
                inputs: parse_specs(cols[8])?,
                outputs: parse_specs(cols[9])?,
                path: dir.join(format!("{}.hlo.txt", cols[0])),
            };
            if !meta.path.exists() {
                bail!("manifest references missing artifact {}", meta.path.display());
            }
            artifacts.push(meta);
        }
        Ok(Manifest { artifacts, dir: dir.to_path_buf() })
    }

    /// Look up an artifact by exact name.
    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// The config whose full shape matches this dataset, if any.
    pub fn config_for(&self, t: usize, n: usize, d: usize) -> Option<&str> {
        self.artifacts
            .iter()
            .find(|a| a.kind == "lammax" && a.t == t && a.n == n && a.d == d)
            .map(|a| a.cfg.as_str())
    }

    /// Solver buckets available for a config, ascending.
    pub fn buckets_for(&self, cfg: &str) -> Vec<usize> {
        let mut b: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.cfg == cfg && a.kind == "fista")
            .map(|a| a.bucket)
            .collect();
        b.sort_unstable();
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tensor_specs() {
        let specs = parse_specs("4x16x256:f32;1:f32").unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].shape, vec![4, 16, 256]);
        assert_eq!(specs[0].elems(), 16384);
        assert_eq!(specs[1].shape, vec![1]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_specs("4x16").is_err());
        assert!(parse_specs("axb:f32").is_err());
    }

    #[test]
    fn manifest_round_trip() {
        let dir = std::env::temp_dir().join(format!("mtfl_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("foo_quick.hlo.txt"), "HloModule fake").unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "name\tkind\tcfg\tT\tN\tD\tbucket\tsteps\tinputs\toutputs\n\
             foo_quick\tlammax\tquick\t4\t16\t256\t0\t0\t4x16x256:f32;4x16:f32\t1:f32\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        assert_eq!(m.config_for(4, 16, 256), Some("quick"));
        assert_eq!(m.config_for(4, 16, 999), None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_artifact_file_detected() {
        let dir = std::env::temp_dir().join(format!("mtfl_manifest2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "name\tkind\tcfg\tT\tN\tD\tbucket\tsteps\tinputs\toutputs\n\
             ghost\tlammax\tq\t1\t1\t1\t0\t0\t1:f32\t1:f32\n",
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
