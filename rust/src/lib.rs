//! # mtfl-dpc
//!
//! Reproduction of *"Safe Screening for Multi-Task Feature Learning with
//! Multiple Data Matrices"* (Wang & Ye, ICML 2015): the **DPC** safe
//! screening rule for the multi-task group-Lasso
//!
//! ```text
//! min_W  Σ_t ½‖y_t − X_t w_t‖² + λ‖W‖₂,₁
//! ```
//!
//! plus everything needed to run it as a system: dataset substrates with
//! pluggable dense / CSC-sparse matrix backends (see DESIGN.md §6), exact
//! f64 solvers (FISTA / BCD), the DPC rule (Theorems 1, 5, 7, 8), a λ-path
//! coordinator with sequential screening (Corollary 9), and an AOT engine
//! that executes JAX/Pallas-lowered HLO artifacts through PJRT.
//!
//! Layer map (see DESIGN.md §3):
//! * L3 (this crate): coordination, data, exact math, metrics, benches.
//! * L2/L1 (python/compile, build-time only): JAX graphs + Pallas kernels,
//!   lowered once to `artifacts/*.hlo.txt`.
//! * runtime: [`runtime`] loads those artifacts via the `xla` crate
//!   (gated behind the `aot` cargo feature; unavailable offline).
//!
//! Large-d problems that do not fit in RAM run through the out-of-core
//! sharded backend and its screen-before-load pipeline (DESIGN.md §10):
//! [`data::ShardedDataset`], `screening::shard`,
//! [`coordinator::path::run_path_sharded`].
//!
//! The regularizer is a seam, not a constant (DESIGN.md §14): every layer
//! programs against the [`penalty::Penalty`] trait, with the paper's ℓ2,1
//! norm as the bit-identical default and sparse-group lasso / group OWL
//! as drop-in instances (`--penalty sgl|gowl`).
//!
//! Long-lived serving (DESIGN.md §15): `repro serve` holds warm fitted
//! models and answers predict/fit/cv over a length-prefixed JSON TCP
//! protocol, batching request work onto the persistent executor —
//! [`serve::Server`], with `repro load` ([`serve::run_load`]) as its
//! RPS-ramp load harness.

#![warn(missing_docs)]

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod ops;
pub mod penalty;
pub mod runtime;
pub mod screening;
pub mod serve;
pub mod solver;
pub mod testing;
pub mod util;

pub use data::Dataset;
pub use penalty::{Penalty, PenaltyKind};
pub use screening::dpc::DpcScreener;
pub use solver::{SolveOptions, SolveResult};
