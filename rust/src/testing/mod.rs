//! proptest-lite: a minimal property-testing harness (proptest is not
//! vendored offline). Runs a property over `cases` randomly generated
//! inputs from an explicit seed; on failure it reports the case seed so
//! the exact input can be replayed deterministically.

use crate::util::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct PropConfig {
    /// randomized cases per property (`MTFL_PROP_CASES` override)
    pub cases: usize,
    /// base seed; case i replays from seed + i (`MTFL_PROP_SEED` override)
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // MTFL_PROP_CASES / MTFL_PROP_SEED env overrides for reproduction
        let cases = std::env::var("MTFL_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(if scale::shrunk() { 4 } else { 32 });
        let seed = std::env::var("MTFL_PROP_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0x9d5f_11e7);
        PropConfig { cases, seed }
    }
}

/// Problem-size knobs for interpreter-speed test runs.
///
/// Miri executes roughly 1000x slower than native code and loom multiplies
/// every test body by the number of explored interleavings, so the CI legs
/// that run under them (`cargo miri test`, `--features loom-model`) need
/// much smaller inputs than a native run. These helpers pick the size once,
/// so every test states its native size and shrinks the same way.
///
/// The shrunk sizes are NOT arbitrary: anything fed to the accumulation
/// kernels must still cross the internal block boundaries that the
/// bit-pinned contract (DESIGN.md §12) is defined over — a vector shorter
/// than ACC_BLOCK (2048) plus a ragged tail would leave the block-fold and
/// tail paths unexercised, and Miri would be checking a dead branch.
/// `kernel_len` therefore never shrinks below one full block plus a tail
/// that is not a multiple of the 8-wide lane group.
pub mod scale {
    /// True when running under an interpreter/model-checker leg that needs
    /// shrunk problem sizes (Miri, or a loom-enabled build).
    pub const fn shrunk() -> bool {
        cfg!(miri) || cfg!(loom)
    }

    /// Pick `native` normally, `small` under Miri/loom.
    pub const fn pick(native: usize, small: usize) -> usize {
        if shrunk() {
            small
        } else {
            native
        }
    }

    /// A reduction length for kernel tests. The shrunk value 2061 =
    /// ACC_BLOCK + 13 still crosses the block boundary AND leaves a tail
    /// (13) that is not a multiple of the 8 accumulator lanes, so the
    /// block fold, the lane tree, and the ragged tail all execute.
    pub const fn kernel_len(native: usize) -> usize {
        pick(native, 2061)
    }

    /// A feature-count (d) for end-to-end solver/screening tests.
    pub const fn d(native: usize) -> usize {
        pick(native, 24)
    }

    /// A sample-count (n) for end-to-end solver/screening tests.
    pub const fn n(native: usize) -> usize {
        pick(native, 8)
    }

    /// A grid/path length (number of lambda values, CV points, ...).
    pub const fn grid(native: usize) -> usize {
        pick(native, 3)
    }
}

/// Run `prop(rng, case_index)`; panics with the replay seed on failure.
/// The property signals failure by returning `Err(message)`.
pub fn check<F>(name: &str, cfg: &PropConfig, prop: F)
where
    F: Fn(&mut Pcg64, usize) -> Result<(), String> + std::panic::RefUnwindSafe,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut rng = Pcg64::new(case_seed);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case)
        }));
        match outcome {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => panic!(
                "property '{name}' failed on case {case} \
                 (replay: MTFL_PROP_SEED={} MTFL_PROP_CASES=1): {msg}",
                cfg.seed.wrapping_add(case as u64)
            ),
            Err(p) => panic!(
                "property '{name}' panicked on case {case} (replay: MTFL_PROP_SEED={}): {:?}",
                cfg.seed.wrapping_add(case as u64),
                p.downcast_ref::<String>()
            ),
        }
    }
}

/// Convenience generators for property tests.
pub mod gen {
    use crate::util::Pcg64;

    /// Uniform usize in `lo..=hi`.
    pub fn usize_in(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `lo..hi`.
    pub fn f64_in(rng: &mut Pcg64, lo: f64, hi: f64) -> f64 {
        rng.uniform_in(lo, hi)
    }

    /// A vector of n scaled standard normals.
    pub fn vec_normal(rng: &mut Pcg64, n: usize, scale: f64) -> Vec<f64> {
        (0..n).map(|_| rng.normal() * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counted = std::sync::atomic::AtomicUsize::new(0);
        check("count", &PropConfig { cases: 10, seed: 1 }, |_, _| {
            counted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            Ok(())
        });
        assert_eq!(counted.load(std::sync::atomic::Ordering::Relaxed), 10);
    }

    #[test]
    #[should_panic(expected = "replay")]
    fn failing_property_reports_seed() {
        check("fail", &PropConfig { cases: 3, seed: 2 }, |_, case| {
            if case == 2 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generator_determinism() {
        let mut a = Pcg64::new(5);
        let mut b = Pcg64::new(5);
        assert_eq!(gen::vec_normal(&mut a, 8, 1.0), gen::vec_normal(&mut b, 8, 1.0));
    }
}
