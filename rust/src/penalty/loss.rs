//! The smooth-loss seam stub: the counterpart of the [`super::Penalty`]
//! trait for the data-fit term, scoped so multinomial-logistic
//! multi-class MTFL (Ndiaye et al. 2015's other axis) lands as a
//! follow-up without another stack-wide refactor.
//!
//! Today every layer hardcodes the squared loss `Σ_t ½‖X_t w_t − y_t‖²`
//! — its gradient factors as `X_tᵀ(X_t w_t − y_t)`, its dual is the
//! λ²-strongly-concave quadratic `ops::dual_obj` computes, and the
//! GAP-safe radius `√(2·gap)/λ` comes from exactly that strong
//! concavity. [`SmoothLoss`] names the three loss-owned pieces
//! (residual-like gradient seed, loss value, dual strong-concavity
//! constant); [`SquaredLoss`] delegates to the existing `ops` functions,
//! and [`MultinomialLogistic`] is a documented stub that fails loudly —
//! its per-sample softmax residual and 1/λ²-scaled dual curvature slot
//! into the same three methods, which is the point of the seam.

use crate::data::Dataset;
use crate::ops::{self, Stacked};

/// A smooth, separable-over-tasks data-fit term `L(W)`. The three
/// operations are what the solver/gap layers consume: the gradient seed
/// `∇L` in sample space (the generalized residual), the loss value, and
/// the strong-concavity constant of the dual at regularization λ (which
/// sets the certified GAP-ball radius `√(2·gap·κ(λ))`).
pub trait SmoothLoss: std::fmt::Debug + Send + Sync {
    /// Human-readable name (report labels).
    fn name(&self) -> String;

    /// The sample-space gradient seed at `w`: the stacked vector `r` with
    /// `∇_w L = X_tᵀ r_t` per task (for squared loss, the residual
    /// `X_t w_t − y_t`).
    fn gradient_seed(&self, ds: &Dataset, w: &[f64]) -> Stacked;

    /// The loss value `L(W)`.
    fn value(&self, ds: &Dataset, w: &[f64]) -> f64;

    /// `κ(λ)` with `‖θ − θ*‖² ≤ 2·gap·κ(λ)`: the inverse strong-concavity
    /// constant of the dual objective (squared loss: `1/λ²`, giving the
    /// classic `√(2·gap)/λ` radius).
    fn dual_curvature(&self, lam: f64) -> f64;
}

/// The paper's squared loss — delegates to the existing `ops` sweeps, so
/// it is definitionally identical to what every layer computes today.
#[derive(Debug, Clone, Copy, Default)]
pub struct SquaredLoss;

impl SmoothLoss for SquaredLoss {
    fn name(&self) -> String {
        "squared".to_string()
    }

    fn gradient_seed(&self, ds: &Dataset, w: &[f64]) -> Stacked {
        ops::residual(ds, w)
    }

    fn value(&self, ds: &Dataset, w: &[f64]) -> f64 {
        let r = ops::residual(ds, w);
        0.5 * ops::stacked_sqnorm(&r)
    }

    fn dual_curvature(&self, lam: f64) -> f64 {
        1.0 / (lam * lam)
    }
}

/// Multinomial-logistic loss for multi-class MTFL — **stub**. The class
/// scores per task are `X_t w_t`, the gradient seed is the softmax
/// residual `p − y` (1-Lipschitz ⇒ the dual curvature is `4/λ²` by the
/// standard 1/4-smoothness bound), and the dual feasible set keeps the
/// same per-feature correlation structure the [`super::Penalty`] seam
/// already abstracts. Every method panics with a pointer here until the
/// follow-up lands; the type exists so callers can already be written
/// against `&dyn SmoothLoss`.
#[derive(Debug, Clone, Copy, Default)]
pub struct MultinomialLogistic;

impl SmoothLoss for MultinomialLogistic {
    fn name(&self) -> String {
        "multinomial-logistic".to_string()
    }

    fn gradient_seed(&self, _ds: &Dataset, _w: &[f64]) -> Stacked {
        unimplemented!(
            "multinomial-logistic MTFL is the scoped follow-up of the penalty seam \
             (penalty/loss.rs module docs): softmax residual p − y goes here"
        )
    }

    fn value(&self, _ds: &Dataset, _w: &[f64]) -> f64 {
        unimplemented!("multinomial-logistic MTFL is a scoped follow-up (penalty/loss.rs)")
    }

    fn dual_curvature(&self, lam: f64) -> f64 {
        // 1/4-smoothness of softmax ⇒ κ(λ) = 4/λ² (kept real so radius
        // plumbing can be exercised before the gradient lands)
        4.0 / (lam * lam)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, SynthOptions};

    #[test]
    fn squared_loss_matches_ops_definitions() {
        let ds =
            synthetic1(&SynthOptions { t: 3, n: 9, d: 15, seed: 3, ..Default::default() }).0;
        let w = vec![0.01f64; 15 * 3];
        let loss = SquaredLoss;
        let seed = loss.gradient_seed(&ds, &w);
        let reference = ops::residual(&ds, &w);
        assert_eq!(seed, reference);
        let v = loss.value(&ds, &w);
        assert!((v - 0.5 * ops::stacked_sqnorm(&reference)).abs() < 1e-12 * v.max(1.0));
        // squared loss: the GAP radius κ(λ) reproduces √(2g)/λ
        let lam = 2.0;
        let g = 0.3;
        let radius = (2.0 * g * loss.dual_curvature(lam)).sqrt();
        assert!((radius - (2.0f64 * g).sqrt() / lam).abs() < 1e-15);
    }

    #[test]
    fn multinomial_stub_fails_loudly_but_exposes_curvature() {
        let m = MultinomialLogistic;
        assert!(m.dual_curvature(2.0) > SquaredLoss.dual_curvature(2.0));
        let caught = std::panic::catch_unwind(|| {
            let ds = synthetic1(&SynthOptions {
                t: 2,
                n: 5,
                d: 4,
                seed: 1,
                ..Default::default()
            })
            .0;
            m.value(&ds, &vec![0.0; 8])
        });
        assert!(caught.is_err(), "stub must refuse to pretend it computes a loss");
    }
}
