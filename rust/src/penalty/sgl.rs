//! Sparse-group lasso: `Ω(W) = Σ_l α‖w^l‖₁ + (1−α)‖w^l‖₂`, `α ∈ [0, 1)`.
//!
//! The multi-task analogue of Simon et al.'s sparse-group lasso: the
//! group part keeps whole feature rows sparse (the paper's structure),
//! the elementwise part additionally zeroes individual (feature, task)
//! coefficients inside surviving rows. `α = 0` recovers ℓ2,1 exactly.
//!
//! **Dual geometry.** The row dual norm of `u ↦ α‖u‖₁ + (1−α)‖u‖₂`
//! satisfies the classic characterization
//!
//! ```text
//! Ω°_row(c) ≤ 1   ⇔   ‖S_α(c)‖₂ ≤ 1 − α
//! ```
//!
//! where `S_α` soft-thresholds each coordinate at `α`. Everything below
//! is that one fact, pushed through the seam's five operations:
//!
//! * **projection / λ_max** ([`SparseGroupLasso::infeasibility`]): the
//!   minimal scale `s` with `‖S_{αs}(c_l)‖₂ ≤ (1−α)s` for every feature.
//!   Per feature the slack `g(s) = ‖S_{αs}(c)‖₂ − (1−α)s` is strictly
//!   decreasing, so a bisection bracketed by `[0, ‖c‖₂/(1−α)]` converges
//!   deterministically; the feasible (upper) endpoint is returned so the
//!   scaled point is always inside the dual set.
//! * **screening** ([`SparseGroupLasso::ball_scores`]): over a ball of
//!   radius δ around `o`, `‖c_l(θ) − c_l(o)‖₂ ≤ δ·max_t ‖x_l^{(t)}‖`
//!   (Cauchy–Schwarz per task), and `S_α` is 1-Lipschitz, so
//!   `s_l = (‖S_α(c_l(o))‖₂ + δ·max_t b_t) / (1−α) < 1` certifies the
//!   dual constraint strictly slack on the whole ball ⇒ row l of W* is
//!   zero. Conservative next to ℓ2,1's exact QP1QC maximization (it
//!   collapses the per-task radii to their max), but safe at any δ —
//!   `tests/gap_safety.rs` gates it with independent tight solves.
//! * **prox** ([`SparseGroupLasso::prox_inplace`]): prox of the sum =
//!   elementwise soft-threshold at `κα`, then group shrink at `κ(1−α)`
//!   (the standard composition — the ℓ1 prox output stays fixed under
//!   the group shrink's scaling).

use super::{ActiveRowCount, Penalty};
use crate::linalg::nrm2_f64;
use crate::linalg::simd::abs_sum_serial_f64;

/// Sparse-group lasso penalty with ℓ1 mixing weight `alpha ∈ [0, 1)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SparseGroupLasso {
    /// weight of the elementwise ℓ1 part; `1 − alpha` weights the group ℓ2
    pub alpha: f64,
}

/// Elementwise soft-threshold at `t ≥ 0`.
#[inline]
fn soft(v: f64, t: f64) -> f64 {
    if v > t {
        v - t
    } else if v < -t {
        v + t
    } else {
        0.0
    }
}

impl SparseGroupLasso {
    /// `‖S_{αs}(c)‖₂` into a caller-provided scratch buffer (len T).
    fn thresholded_norm(&self, c: &[f64], s: f64, scratch: &mut [f64]) -> f64 {
        let t = self.alpha * s;
        for (o, &v) in scratch.iter_mut().zip(c) {
            *o = soft(v, t);
        }
        nrm2_f64(scratch)
    }

    /// Per-feature minimal feasibility scale: smallest `s ≥ 0` with
    /// `‖S_{αs}(c)‖₂ ≤ (1−α)s`. Bisection on the strictly decreasing
    /// slack; returns the feasible (upper) endpoint of the final bracket.
    fn feature_scale(&self, c: &[f64], scratch: &mut [f64]) -> f64 {
        let norm = nrm2_f64(c);
        if norm == 0.0 {
            return 0.0;
        }
        let one_minus = 1.0 - self.alpha;
        // g(0) = ‖c‖ > 0; at hi = ‖c‖/(1−α): ‖S(c)‖ ≤ ‖c‖ = (1−α)·hi ⇒ g(hi) ≤ 0
        let mut lo = 0.0f64;
        let mut hi = norm / one_minus;
        for _ in 0..90 {
            let mid = 0.5 * (lo + hi);
            if mid <= lo || mid >= hi {
                break; // bracket at f64 resolution
            }
            if self.thresholded_norm(c, mid, scratch) > one_minus * mid {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        hi
    }
}

impl Penalty for SparseGroupLasso {
    fn name(&self) -> String {
        format!("sgl(alpha={})", self.alpha)
    }

    fn value(&self, w: &[f64], t_count: usize) -> f64 {
        let per_row: Vec<f64> = w
            .chunks_exact(t_count)
            .map(|row| self.alpha * abs_sum_serial_f64(row) + (1.0 - self.alpha) * nrm2_f64(row))
            .collect();
        crate::linalg::simd::sum_serial_f64(&per_row)
    }

    fn prox_inplace(&self, w: &mut [f64], t_count: usize, kappa: f64) -> ActiveRowCount {
        debug_assert_eq!(w.len() % t_count, 0);
        let ka = kappa * self.alpha;
        let kg = kappa * (1.0 - self.alpha);
        let mut alive = 0usize;
        for row in w.chunks_exact_mut(t_count) {
            for v in row.iter_mut() {
                *v = soft(*v, ka);
            }
            let norm = nrm2_f64(row);
            if norm <= kg {
                row.fill(0.0);
            } else {
                let s = 1.0 - kg / norm;
                for v in row.iter_mut() {
                    *v *= s;
                }
                alive += 1;
            }
        }
        alive
    }

    /// Per-row minimal feasibility scale (the bisection) — row-local, so
    /// the sharded path streams it block-by-block.
    fn infeas_features(&self, corr: &[f64], t_count: usize) -> Vec<f64> {
        let mut scratch = vec![0.0f64; t_count];
        corr.chunks_exact(t_count).map(|c| self.feature_scale(c, &mut scratch)).collect()
    }

    /// First-strict-maximum of the per-row scales — the global scale is
    /// the max because every row constraint must hold simultaneously.
    fn infeas_finish(&self, feats: &[f64]) -> (f64, usize) {
        let mut best = f64::MIN;
        let mut arg = 0usize;
        for (l, &s) in feats.iter().enumerate() {
            if s > best {
                best = s;
                arg = l;
            }
        }
        (best.max(0.0), arg)
    }

    fn ball_scores(&self, corr: &[f64], b2: &[f64], t_count: usize, delta: f64) -> Vec<f64> {
        debug_assert_eq!(corr.len(), b2.len());
        let rows = corr.len() / t_count;
        let one_minus = 1.0 - self.alpha;
        let mut scratch = vec![0.0f64; t_count];
        let mut out = vec![0.0f64; rows];
        for l in 0..rows {
            let c = &corr[l * t_count..(l + 1) * t_count];
            let b2l = &b2[l * t_count..(l + 1) * t_count];
            let rho = b2l.iter().cloned().fold(0.0f64, f64::max).sqrt();
            // ‖S_α(c(θ))‖ ≤ ‖S_α(c(o))‖ + δ·ρ on the ball (module docs)
            out[l] = (self.thresholded_norm(c, 1.0, &mut scratch) + delta * rho) / one_minus;
        }
        out
    }

    fn dual_constraints(&self, corr: &[f64], t_count: usize) -> Vec<f64> {
        let one_minus = 1.0 - self.alpha;
        let mut scratch = vec![0.0f64; t_count];
        corr.chunks_exact(t_count)
            .map(|c| {
                let r = self.thresholded_norm(c, 1.0, &mut scratch) / one_minus;
                r * r // squared, matching the ℓ2,1 g_l convention
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, SynthOptions};
    use crate::ops;

    const T: usize = 3;

    #[test]
    fn alpha_zero_prox_and_value_match_l21() {
        let pen = SparseGroupLasso { alpha: 0.0 };
        let w0 = vec![3.0, 4.0, 0.5, 0.1, -0.2, 0.05, 2.0, -1.0, 0.3];
        assert!((pen.value(&w0, T) - ops::l21_norm(&w0, T)).abs() < 1e-12);
        let mut a = w0.clone();
        let mut b = w0.clone();
        let na = pen.prox_inplace(&mut a, T, 0.8);
        let nb = crate::solver::prox::prox21_inplace(&mut b, T, 0.8);
        assert_eq!(na, nb);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-14, "alpha=0 prox diverged: {x} vs {y}");
        }
    }

    #[test]
    fn prox_satisfies_subgradient_optimality() {
        // v = prox_κ(z) ⇒ z − v ∈ κ·∂Ω(v): for a nonzero output entry,
        // z_i − v_i = κ(α·sign(v_i) + (1−α)·v_i/‖v‖)
        let pen = SparseGroupLasso { alpha: 0.4 };
        let z = vec![3.0, -4.0, 0.2];
        let mut v = z.clone();
        let kappa = 1.1;
        pen.prox_inplace(&mut v, T, kappa);
        let vn = nrm2_f64(&v);
        assert!(vn > 0.0);
        for i in 0..T {
            if v[i] != 0.0 {
                let want = kappa * (0.4 * v[i].signum() + 0.6 * v[i] / vn);
                assert!(
                    ((z[i] - v[i]) - want).abs() < 1e-12,
                    "KKT residual at {i}: {} vs {want}",
                    z[i] - v[i]
                );
            } else {
                // zeroed coordinate: |z_i − v_i| ≤ κα (the ℓ1 subdifferential)
                assert!(z[i].abs() <= kappa * 0.4 + 1e-12);
            }
        }
    }

    #[test]
    fn infeasibility_scale_lands_exactly_on_the_constraint() {
        let pen = SparseGroupLasso { alpha: 0.3 };
        let ds =
            synthetic1(&SynthOptions { t: 3, n: 10, d: 30, seed: 5, ..Default::default() }).0;
        let corr = ops::task_corr(&ds, &ops::y64(&ds));
        let (s, lstar) = pen.infeasibility(&corr, ds.t());
        assert!(s > 0.0);
        // at the returned scale every feature is feasible ...
        let scaled: Vec<f64> = corr.iter().map(|v| v / s).collect();
        for (l, g) in pen.dual_constraints(&scaled, ds.t()).iter().enumerate() {
            assert!(*g <= 1.0 + 1e-9, "feature {l} infeasible after scaling: {g}");
        }
        // ... and the witness feature saturates it
        let g_star = pen.dual_constraints(&scaled, ds.t())[lstar];
        assert!((g_star - 1.0).abs() < 1e-6, "witness slack: {g_star}");
    }

    #[test]
    fn alpha_zero_infeasibility_matches_l21_lambda_max() {
        let pen = SparseGroupLasso { alpha: 0.0 };
        let ds =
            synthetic1(&SynthOptions { t: 3, n: 10, d: 30, seed: 6, ..Default::default() }).0;
        let corr = ops::task_corr(&ds, &ops::y64(&ds));
        let (s, _) = pen.infeasibility(&corr, ds.t());
        let (lmax, _, _) = ops::lambda_max(&ds);
        assert!((s - lmax).abs() <= 1e-10 * lmax, "{s} vs {lmax}");
    }

    #[test]
    fn ball_scores_are_safe_upper_bounds() {
        // score < 1 at radius δ must imply the constraint holds strictly
        // at every probe point within δ of the center
        let pen = SparseGroupLasso { alpha: 0.5 };
        let ds =
            synthetic1(&SynthOptions { t: 3, n: 8, d: 20, seed: 7, ..Default::default() }).0;
        let y = ops::y64(&ds);
        let (lmax, _) = pen.infeasibility(&ops::task_corr(&ds, &y), ds.t());
        let o = ops::stacked_scale(&y, 1.0 / lmax);
        let b2 = ds.col_sqnorms();
        let delta = 0.05;
        let corr_o = ops::task_corr(&ds, &o);
        let scores = pen.ball_scores(&corr_o, &b2, ds.t(), delta);
        // probe: shift every task vector by delta/√(T·n_t) in each unit dir
        let mut probe = o.clone();
        let shift = delta / (ds.t() as f64).sqrt();
        for pt in probe.iter_mut() {
            let n = pt.len() as f64;
            for v in pt.iter_mut() {
                *v += shift / n.sqrt();
            }
        }
        let corr_p = ops::task_corr(&ds, &probe);
        let g_probe = pen.dual_constraints(&corr_p, ds.t());
        for (l, (&s, &g)) in scores.iter().zip(&g_probe).enumerate() {
            if s < 1.0 {
                assert!(g < 1.0, "feature {l}: score {s} < 1 but probe constraint {g} >= 1");
            }
        }
    }
}
