//! The penalty seam (DESIGN.md §14): the five operations every layer of
//! the stack used to assume were the ℓ2,1 norm, abstracted into the
//! [`Penalty`] trait so the *same* solvers, gap machinery, screeners and
//! coordinators run any row-structured penalty.
//!
//! The problem family is
//!
//! ```text
//! min_W  Σ_t ½‖y_t − X_t w_t‖² + λ·Ω(W)          (generalized problem (1))
//! ```
//!
//! where `Ω` is a convex, row-structured penalty on the (d × T) weight
//! matrix. Everything the repo does with the paper's ℓ2,1 norm factors
//! through exactly five penalty-specific operations:
//!
//! | op | consumer layers |
//! |---|---|
//! | [`Penalty::value`] | `ops` (primal objective, duality gap), path records |
//! | [`Penalty::prox_inplace`] | FISTA's iterate update |
//! | [`Penalty::infeasibility`] | `ops::dual_feasible_for` (dual projection) **and** `ops::lambda_max_for` — they are the same computation, see below |
//! | [`Penalty::infeas_features`] + [`Penalty::infeas_finish`] | the streamed split of `infeasibility`: `ops::stream_infeas_features` runs the per-feature half block-by-block over an MTD3 shard (or ships it to distributed workers) and the coordinator folds the finish half once — out-of-core and cluster paths for every penalty (DESIGN.md §16) |
//! | [`Penalty::ball_scores`] | DPC / GAP-safe / dynamic screening sweeps |
//! | [`Penalty::dual_constraints`] | `screening::safety` (post-hoc KKT certificate) |
//!
//! **Dual-scaling convention.** The dual feasible set of the generalized
//! problem is `F = {θ : Ω°(c(θ)) ≤ 1}` where `c_l(θ)_t = ⟨x_l^{(t)}, θ_t⟩`
//! is the per-feature correlation row and `Ω°` is the dual (polar) norm of
//! `Ω`. [`Penalty::infeasibility`] returns the smallest `s ≥ Ω°(c(z))`
//! such that `z / max(1, s)` is feasible — exactly the paper's Eq. 15
//! scaling for ℓ2,1, generalized.
//!
//! **Why λ_max belongs to the penalty.** `λ_max` is the smallest λ for
//! which `W = 0` is optimal, i.e. the smallest λ with `y/λ ∈ F`. By
//! positive homogeneity of the feasibility scale that is *precisely*
//! `infeasibility(c(y))` — the same number the dual projection computes,
//! evaluated at `z = y`. One penalty-owned operation therefore serves
//! both; [`Penalty::lambda_max`] is a provided method that delegates.
//!
//! Three concrete instances ship:
//!
//! * [`l21::L21`] — the paper's ℓ2,1 norm. Delegates to the *exact*
//!   pre-seam free functions (`ops::l21_norm`, `prox::prox21_inplace`,
//!   the Theorem-7 secular solve), so results are bit-identical to the
//!   hardcoded code path (`rust/tests/penalty_parity.rs` pins this).
//! * [`sgl::SparseGroupLasso`] — sparse-group lasso,
//!   `Ω(W) = Σ_l α‖w^l‖₁ + (1−α)‖w^l‖₂`.
//! * [`gowl::GroupOwl`] — group ordered-weighted-ℓ1 (OWL on sorted row
//!   norms, Bao et al. 2025 in PAPERS.md) with the sorted-prefix dual
//!   projection and a pool-adjacent-violators prox.
//!
//! [`loss`] holds the matching smooth-loss seam stub (squared vs.
//! multinomial-logistic) so multi-class MTFL lands without re-threading.

pub mod gowl;
pub mod l21;
pub mod loss;
pub mod sgl;

pub use gowl::GroupOwl;
pub use l21::L21;
pub use sgl::SparseGroupLasso;

/// The number of rows left nonzero by a prox application — the working
/// row count FISTA's dynamic bookkeeping tracks. (Named type for what
/// used to be an undocumented bare `usize` return of `prox21_inplace`.)
pub type ActiveRowCount = usize;

/// A convex row-structured penalty `Ω` on a row-major (d × T) weight
/// matrix — the seam every solver/screening/coordinator layer programs
/// against (module docs have the op-per-layer contract table).
///
/// Implementations must be deterministic and obey the DESIGN.md §12
/// accumulation contract: any float reduction either routes through the
/// kernel layer (`linalg`) or carries a pinned serial order.
pub trait Penalty: std::fmt::Debug + Send + Sync {
    /// Human-readable name (CLI/report labels), e.g. `"l21"`.
    fn name(&self) -> String;

    /// Ω(W) for a row-major (d × T) matrix.
    fn value(&self, w: &[f64], t_count: usize) -> f64;

    /// In-place proximal operator `w ← argmin_u ½‖u − w‖² + κ·Ω(u)`.
    /// Returns the number of rows left nonzero (see [`ActiveRowCount`]):
    /// a row counts as alive iff at least one of its entries is nonzero
    /// after the prox, so the count always equals the number of nonzero
    /// rows of the output.
    fn prox_inplace(&self, w: &mut [f64], t_count: usize, kappa: f64) -> ActiveRowCount;

    /// Dual-infeasibility scale of a correlation buffer `c` (row-major
    /// d × T): the smallest `s` such that `c/s` satisfies every dual
    /// constraint, together with the first feature attaining the maximal
    /// constraint (the λ_max argmax / Theorem-1 witness). A point `z`
    /// with correlations `c(z)` is projected into the feasible set as
    /// `z / max(1, s)`; evaluated at `z = y` this same `s` *is* λ_max
    /// (module docs).
    ///
    /// Provided: the composition of [`Self::infeas_features`] and
    /// [`Self::infeas_finish`]. Implementations supply the two halves —
    /// the split is what lets the sharded and distributed paths stream
    /// the per-feature half block-by-block and fold the finish once.
    fn infeasibility(&self, corr: &[f64], t_count: usize) -> (f64, usize) {
        self.infeas_finish(&self.infeas_features(corr, t_count))
    }

    /// Per-feature half of [`Self::infeasibility`]: one statistic per
    /// correlation row (ℓ2,1: the paper's `g_l`; SGL: the per-row
    /// feasibility scale; GOWL: the row norm). Feature `l`'s statistic
    /// depends only on row `l` of `corr`, so the buffer may be any
    /// contiguous *chunk* of features — the sharded path evaluates this
    /// per MTD3 block and concatenates in block order, bit-identical to
    /// one full-width call (DESIGN.md §16).
    fn infeas_features(&self, corr: &[f64], t_count: usize) -> Vec<f64>;

    /// Global fold of [`Self::infeas_features`] over all `d` features:
    /// the `(scale, witness-feature)` pair of [`Self::infeasibility`].
    /// Runs once on the coordinator, on the fully assembled feature
    /// vector — GOWL's sorted-prefix fold is why this half cannot
    /// stream.
    fn infeas_finish(&self, feats: &[f64]) -> (f64, usize);

    /// λ_max = the smallest λ for which W = 0 is optimal, from the
    /// correlation buffer of the response `c(y)`. Provided: identical to
    /// [`Self::infeasibility`] by homogeneity of the dual norm.
    fn lambda_max(&self, corr_y: &[f64], t_count: usize) -> (f64, usize) {
        self.infeasibility(corr_y, t_count)
    }

    /// Safe-screening scores for one contiguous feature chunk: `corr` is
    /// the chunk's (rows × T) correlation buffer at the ball center `o`,
    /// `b2` the matching slice of the per-(feature, task) squared column
    /// norms, and `delta` the ball radius. Returns one score per row with
    /// the uniform convention **score < 1 ⇒ the feature's dual constraint
    /// is strictly slack everywhere on the ball ⇒ its row of W* is zero**
    /// (rejection is safe). Scores may be conservative (over-estimates
    /// reject less, never unsafely).
    fn ball_scores(&self, corr: &[f64], b2: &[f64], t_count: usize, delta: f64) -> Vec<f64>;

    /// Per-feature dual-constraint values at a correlation buffer,
    /// normalized so `< 1` means strictly slack — the post-hoc KKT
    /// certificate `screening::safety` reports for rejected features.
    /// For ℓ2,1 this is the paper's `g_l` (so the existing reports keep
    /// their meaning bit-for-bit).
    fn dual_constraints(&self, corr: &[f64], t_count: usize) -> Vec<f64>;

    /// Whether BCD's per-row secular solve (`solver::bcd::row_nu`) is an
    /// exact minimizer for this penalty. Only true for ℓ2,1; BCD refuses
    /// other penalties rather than silently solving the wrong problem.
    fn supports_row_secular(&self) -> bool {
        false
    }

    /// Whether the DPC Theorem-5 ball construction (projection geometry
    /// of the ℓ2,1 dual set) applies. Other penalties screen with
    /// GAP-safe balls, which only need [`Self::ball_scores`].
    fn supports_dpc_geometry(&self) -> bool {
        false
    }
}

/// The penalty selector threaded through [`crate::solver::SolveOptions`]
/// (and from there through every path/CV/stability/experiment driver and
/// the CLI's `--penalty` flag). `Copy` so options stay cheap to clone;
/// enum dispatch (not trait objects) so the solver hot loops stay
/// monomorphic-friendly and `SolveOptions` stays `Clone + Debug`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PenaltyKind {
    /// The paper's ℓ2,1 norm (default — bit-identical to the pre-seam code).
    L21,
    /// Sparse-group lasso with mixing weight `alpha ∈ [0, 1)`.
    Sgl {
        /// weight of the elementwise ℓ1 part (0 recovers ℓ2,1)
        alpha: f64,
    },
    /// Group OWL with decay `gamma ≥ 0` (0 recovers ℓ2,1 weights).
    Gowl {
        /// weight-sequence decay: sorted weight i is `1 + gamma/(i+1)`
        gamma: f64,
    },
}

impl Default for PenaltyKind {
    fn default() -> Self {
        PenaltyKind::L21
    }
}

impl PenaltyKind {
    /// Parse a CLI `--penalty` value with its knobs (`--penalty-alpha`,
    /// `--penalty-gamma`). Errors name the valid spellings and ranges.
    pub fn parse(name: &str, alpha: f64, gamma: f64) -> anyhow::Result<Self> {
        match name {
            "l21" => Ok(PenaltyKind::L21),
            "sgl" => {
                anyhow::ensure!(
                    (0.0..1.0).contains(&alpha),
                    "--penalty-alpha must be in [0, 1) (got {alpha}); alpha = 1 is a pure \
                     lasso with no group structure — use a per-feature model instead"
                );
                Ok(PenaltyKind::Sgl { alpha })
            }
            "gowl" => {
                anyhow::ensure!(
                    gamma >= 0.0,
                    "--penalty-gamma must be >= 0 (got {gamma})"
                );
                Ok(PenaltyKind::Gowl { gamma })
            }
            other => anyhow::bail!("unknown penalty '{other}' (expected l21 | sgl | gowl)"),
        }
    }

    /// True for the ℓ2,1 instance — the coordinators use this to keep the
    /// exact pre-seam code path (DPC geometry, BCD, sharded streaming)
    /// and to reject penalty/algorithm combinations that would be wrong.
    pub fn is_l21(&self) -> bool {
        matches!(self, PenaltyKind::L21)
    }
}

impl std::fmt::Display for PenaltyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&Penalty::name(self))
    }
}

impl Penalty for PenaltyKind {
    fn name(&self) -> String {
        match *self {
            PenaltyKind::L21 => L21.name(),
            PenaltyKind::Sgl { alpha } => SparseGroupLasso { alpha }.name(),
            PenaltyKind::Gowl { gamma } => GroupOwl { gamma }.name(),
        }
    }

    fn value(&self, w: &[f64], t_count: usize) -> f64 {
        match *self {
            PenaltyKind::L21 => L21.value(w, t_count),
            PenaltyKind::Sgl { alpha } => SparseGroupLasso { alpha }.value(w, t_count),
            PenaltyKind::Gowl { gamma } => GroupOwl { gamma }.value(w, t_count),
        }
    }

    fn prox_inplace(&self, w: &mut [f64], t_count: usize, kappa: f64) -> ActiveRowCount {
        match *self {
            PenaltyKind::L21 => L21.prox_inplace(w, t_count, kappa),
            PenaltyKind::Sgl { alpha } => {
                SparseGroupLasso { alpha }.prox_inplace(w, t_count, kappa)
            }
            PenaltyKind::Gowl { gamma } => GroupOwl { gamma }.prox_inplace(w, t_count, kappa),
        }
    }

    fn infeas_features(&self, corr: &[f64], t_count: usize) -> Vec<f64> {
        match *self {
            PenaltyKind::L21 => L21.infeas_features(corr, t_count),
            PenaltyKind::Sgl { alpha } => {
                SparseGroupLasso { alpha }.infeas_features(corr, t_count)
            }
            PenaltyKind::Gowl { gamma } => GroupOwl { gamma }.infeas_features(corr, t_count),
        }
    }

    fn infeas_finish(&self, feats: &[f64]) -> (f64, usize) {
        match *self {
            PenaltyKind::L21 => L21.infeas_finish(feats),
            PenaltyKind::Sgl { alpha } => SparseGroupLasso { alpha }.infeas_finish(feats),
            PenaltyKind::Gowl { gamma } => GroupOwl { gamma }.infeas_finish(feats),
        }
    }

    fn ball_scores(&self, corr: &[f64], b2: &[f64], t_count: usize, delta: f64) -> Vec<f64> {
        match *self {
            PenaltyKind::L21 => L21.ball_scores(corr, b2, t_count, delta),
            PenaltyKind::Sgl { alpha } => {
                SparseGroupLasso { alpha }.ball_scores(corr, b2, t_count, delta)
            }
            PenaltyKind::Gowl { gamma } => {
                GroupOwl { gamma }.ball_scores(corr, b2, t_count, delta)
            }
        }
    }

    fn dual_constraints(&self, corr: &[f64], t_count: usize) -> Vec<f64> {
        match *self {
            PenaltyKind::L21 => L21.dual_constraints(corr, t_count),
            PenaltyKind::Sgl { alpha } => {
                SparseGroupLasso { alpha }.dual_constraints(corr, t_count)
            }
            PenaltyKind::Gowl { gamma } => GroupOwl { gamma }.dual_constraints(corr, t_count),
        }
    }

    fn supports_row_secular(&self) -> bool {
        match *self {
            PenaltyKind::L21 => L21.supports_row_secular(),
            PenaltyKind::Sgl { alpha } => SparseGroupLasso { alpha }.supports_row_secular(),
            PenaltyKind::Gowl { gamma } => GroupOwl { gamma }.supports_row_secular(),
        }
    }

    fn supports_dpc_geometry(&self) -> bool {
        match *self {
            PenaltyKind::L21 => L21.supports_dpc_geometry(),
            PenaltyKind::Sgl { alpha } => SparseGroupLasso { alpha }.supports_dpc_geometry(),
            PenaltyKind::Gowl { gamma } => GroupOwl { gamma }.supports_dpc_geometry(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_cookbook_spellings() {
        assert_eq!(PenaltyKind::parse("l21", 0.5, 1.0).unwrap(), PenaltyKind::L21);
        assert_eq!(
            PenaltyKind::parse("sgl", 0.3, 1.0).unwrap(),
            PenaltyKind::Sgl { alpha: 0.3 }
        );
        assert_eq!(
            PenaltyKind::parse("gowl", 0.5, 2.0).unwrap(),
            PenaltyKind::Gowl { gamma: 2.0 }
        );
    }

    #[test]
    fn parse_rejects_bad_knobs() {
        assert!(PenaltyKind::parse("sgl", 1.0, 0.0).is_err(), "alpha = 1 must be rejected");
        assert!(PenaltyKind::parse("sgl", -0.1, 0.0).is_err());
        assert!(PenaltyKind::parse("gowl", 0.0, -1.0).is_err());
        assert!(PenaltyKind::parse("elastic", 0.0, 0.0).is_err());
    }

    #[test]
    fn default_is_l21_and_only_l21_gets_the_exact_algorithms() {
        let def = PenaltyKind::default();
        assert!(def.is_l21());
        assert!(def.supports_row_secular() && def.supports_dpc_geometry());
        for pk in [PenaltyKind::Sgl { alpha: 0.4 }, PenaltyKind::Gowl { gamma: 1.0 }] {
            assert!(!pk.is_l21());
            assert!(!pk.supports_row_secular(), "{pk}: BCD must refuse");
            assert!(!pk.supports_dpc_geometry(), "{pk}: DPC must refuse");
        }
    }
}
