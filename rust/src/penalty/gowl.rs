//! Group OWL: ordered weighted ℓ1 applied to the sorted row norms
//! (Bao et al. 2025, "safe screening rules for group OWL models" —
//! PAPERS.md), `Ω(W) = Σ_i λ̃_i · ‖W‖_[i]` where `‖W‖_[i]` is the i-th
//! largest row ℓ2 norm and `λ̃` is a fixed non-increasing weight
//! sequence.
//!
//! **Weight sequence.** `λ̃_i = 1 + γ/(i + 1)` (i = 0, 1, …): strictly
//! decreasing toward 1, with `γ = 0` recovering the flat ℓ2,1 weights
//! exactly. The harmonic form is chosen deliberately: the weights depend
//! only on the *rank* i, not on the problem size, so when screening
//! compacts the live problem to its top-k rows, the compacted penalty is
//! the same [`GroupOwl`] — zero rows pair with the smallest (tail)
//! weights and contribute nothing, and the surviving rows keep the head
//! weights `λ̃_0..λ̃_{k−1}`. A d-dependent sequence would change the
//! restricted problem under compaction and break warm starts.
//!
//! **Dual geometry.** On sorted constraint magnitudes, the OWL dual set
//! is the prefix polytope `{c : Σ_{i≤k} u_[i] ≤ Σ_{i≤k} λ̃_i ∀k}` with
//! `u_l = ‖c_l‖₂`. Scaling shrinks every prefix linearly, so the minimal
//! feasibility scale is exact: `s = max_k (Σ_{i≤k} u_[i]) / (Σ_{i≤k}
//! λ̃_i)` ([`GroupOwl::infeasibility`]) — the "sorted-weights dual
//! projection". Evaluated at `c(y)` this is λ_max (seam convention).
//!
//! **Screening.** Conservative decoupled test: every weight satisfies
//! `λ̃_i > 1`, and at an optimum a nonzero row l forces
//! `‖c_l(θ*)‖ = λ̃_{rank(l)} ≥ min_i λ̃_i > 1`. So if the Theorem-7
//! maximum of `g_l = ‖c_l‖²` over the ball stays below 1, row l is
//! certifiably zero — the *identical* per-feature QP1QC solve as ℓ2,1,
//! reused verbatim, just read against the weight floor. (The coupled
//! prefix test of Bao et al. rejects more; the decoupled one is safe and
//! costs nothing new — `tests/gap_safety.rs` gates it.)
//!
//! **Prox.** Prox of OWL-on-row-norms: sort row norms descending, shrink
//! by `κλ̃`, restore monotonicity with pool-adjacent-violators (isotonic
//! regression), clamp at 0, and rescale each row to its new norm — the
//! standard OWL prox lifted to groups.

use super::{ActiveRowCount, Penalty};
use crate::linalg::nrm2_f64;
use crate::linalg::simd::sum_serial_f64;

/// Group OWL penalty with harmonic weight decay `gamma ≥ 0`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupOwl {
    /// weight decay: sorted-rank weight i is `1 + gamma/(i + 1)`
    pub gamma: f64,
}

impl GroupOwl {
    /// The rank-i weight `λ̃_i = 1 + γ/(i+1)` (non-increasing in i).
    #[inline]
    pub fn weight(&self, i: usize) -> f64 {
        1.0 + self.gamma / (i as f64 + 1.0)
    }

    /// Row norms with their original indices, sorted by norm descending
    /// (ties broken by index ascending — a total, deterministic order).
    fn sorted_row_norms(&self, w: &[f64], t_count: usize) -> Vec<(usize, f64)> {
        let mut v: Vec<(usize, f64)> = w
            .chunks_exact(t_count)
            .enumerate()
            .map(|(l, row)| (l, nrm2_f64(row)))
            .collect();
        v.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        v
    }
}

/// Isotonic regression onto the non-increasing cone (pool adjacent
/// violators): returns the Euclidean projection of `z` onto
/// `{p : p_0 ≥ p_1 ≥ …}`. Plain-variable left-to-right pooling — the
/// block sums are float adds of slice elements with a pinned order.
fn pav_nonincreasing(z: &[f64]) -> Vec<f64> {
    // blocks of (sum, count); merge while the tail mean exceeds its
    // predecessor's (a violation of non-increase)
    let mut sums: Vec<f64> = Vec::with_capacity(z.len());
    let mut counts: Vec<usize> = Vec::with_capacity(z.len());
    for &zi in z {
        sums.push(zi);
        counts.push(1);
        while sums.len() >= 2 {
            let k = sums.len();
            if sums[k - 1] * counts[k - 2] as f64 > sums[k - 2] * counts[k - 1] as f64 {
                let s = sums.pop().unwrap();
                let c = counts.pop().unwrap();
                sums[k - 2] += s;
                counts[k - 2] += c;
            } else {
                break;
            }
        }
    }
    let mut out = Vec::with_capacity(z.len());
    for (s, c) in sums.iter().zip(&counts) {
        let mean = s / *c as f64;
        for _ in 0..*c {
            out.push(mean);
        }
    }
    out
}

impl Penalty for GroupOwl {
    fn name(&self) -> String {
        format!("gowl(gamma={})", self.gamma)
    }

    fn value(&self, w: &[f64], t_count: usize) -> f64 {
        let sorted = self.sorted_row_norms(w, t_count);
        let weighted: Vec<f64> =
            sorted.iter().enumerate().map(|(i, &(_, u))| self.weight(i) * u).collect();
        sum_serial_f64(&weighted)
    }

    fn prox_inplace(&self, w: &mut [f64], t_count: usize, kappa: f64) -> ActiveRowCount {
        debug_assert_eq!(w.len() % t_count, 0);
        let sorted = self.sorted_row_norms(w, t_count);
        // shifted norms in sorted order, isotonic-projected, clamped at 0
        let z: Vec<f64> =
            sorted.iter().enumerate().map(|(i, &(_, u))| u - kappa * self.weight(i)).collect();
        let p = pav_nonincreasing(&z);
        let mut alive = 0usize;
        for (i, &(l, u)) in sorted.iter().enumerate() {
            let row = &mut w[l * t_count..(l + 1) * t_count];
            let target = p[i].max(0.0);
            if target <= 0.0 || u <= 0.0 {
                row.fill(0.0);
            } else {
                let s = target / u;
                for v in row.iter_mut() {
                    *v *= s;
                }
                alive += 1;
            }
        }
        alive
    }

    /// Per-row ℓ2 norm in row order — the only row-local ingredient the
    /// prefix fold needs, so it is what the sharded path streams.
    fn infeas_features(&self, corr: &[f64], t_count: usize) -> Vec<f64> {
        corr.chunks_exact(t_count).map(nrm2_f64).collect()
    }

    /// Sorted-prefix fold over *all* row norms. The sort is why group
    /// OWL's finish half cannot stream: it needs the full feature vector
    /// (which is exactly what [`Penalty::infeas_features`] assembles).
    fn infeas_finish(&self, feats: &[f64]) -> (f64, usize) {
        let mut sorted: Vec<(usize, f64)> = feats.iter().cloned().enumerate().collect();
        sorted.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        if sorted.is_empty() {
            return (0.0, 0);
        }
        // max over prefixes of Σ u_[i] / Σ λ̃_i — plain running adds
        let mut pu = 0.0f64;
        let mut pw = 0.0f64;
        let mut best = f64::MIN;
        for (i, &(_, u)) in sorted.iter().enumerate() {
            pu += u;
            pw += self.weight(i);
            let ratio = pu / pw;
            if ratio > best {
                best = ratio;
            }
        }
        // witness: the largest-norm feature (the rank-0 row — the feature
        // that saturates the first prefix constraint as γ → 0)
        (best.max(0.0), sorted[0].0)
    }

    fn ball_scores(&self, corr: &[f64], b2: &[f64], t_count: usize, delta: f64) -> Vec<f64> {
        // identical QP1QC maximization as ℓ2,1 (module docs: the weight
        // floor min_i λ̃_i > 1 makes the g < 1 test safe for group OWL)
        super::L21.ball_scores(corr, b2, t_count, delta)
    }

    fn dual_constraints(&self, corr: &[f64], t_count: usize) -> Vec<f64> {
        // decoupled certificate against the weight floor (g_l vs 1)
        crate::ops::gscore_from_corr(corr, t_count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, SynthOptions};
    use crate::ops;

    const T: usize = 2;

    #[test]
    fn pav_projects_onto_nonincreasing() {
        let p = pav_nonincreasing(&[3.0, 1.0, 2.0, 0.5]);
        for i in 1..p.len() {
            assert!(p[i - 1] >= p[i] - 1e-15, "not monotone: {p:?}");
        }
        // pooled block [1,2] averages to 1.5; untouched values pass through
        assert!((p[0] - 3.0).abs() < 1e-15);
        assert!((p[1] - 1.5).abs() < 1e-15 && (p[2] - 1.5).abs() < 1e-15);
        assert!((p[3] - 0.5).abs() < 1e-15);
        // already-sorted input is a fixed point
        let q = pav_nonincreasing(&[5.0, 4.0, 2.0]);
        assert_eq!(q, vec![5.0, 4.0, 2.0]);
    }

    #[test]
    fn gamma_zero_matches_l21_value_and_prox() {
        let pen = GroupOwl { gamma: 0.0 };
        let w0 = vec![3.0, 4.0, 0.3, 0.4, -1.0, 2.0];
        assert!((pen.value(&w0, T) - ops::l21_norm(&w0, T)).abs() < 1e-12);
        let mut a = w0.clone();
        let mut b = w0.clone();
        let na = pen.prox_inplace(&mut a, T, 1.0);
        let nb = crate::solver::prox::prox21_inplace(&mut b, T, 1.0);
        assert_eq!(na, nb);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-12, "gamma=0 prox diverged: {x} vs {y}");
        }
    }

    #[test]
    fn value_weights_larger_rows_more() {
        // two rows with norms 2 and 1: value = λ̃_0·2 + λ̃_1·1
        let pen = GroupOwl { gamma: 1.0 };
        let w = vec![2.0, 0.0, 0.0, 1.0];
        let want = (1.0 + 1.0) * 2.0 + (1.0 + 0.5) * 1.0;
        assert!((pen.value(&w, T) - want).abs() < 1e-12);
    }

    #[test]
    fn prox_output_norms_are_nonincreasing_in_input_rank() {
        let pen = GroupOwl { gamma: 2.0 };
        let mut w = vec![5.0, 0.0, 0.0, 4.9, 4.8, 0.0, 0.1, 0.0];
        pen.prox_inplace(&mut w, T, 1.0);
        let norms: Vec<f64> = w.chunks_exact(T).map(nrm2_f64).collect();
        // rank order of the input was rows 0,1,2,3 (descending norms)
        for i in 1..norms.len() {
            assert!(norms[i - 1] >= norms[i] - 1e-12, "rank inversion: {norms:?}");
        }
        // the near-tied head rows must have pooled close together
        assert!((norms[0] - norms[1]).abs() < 0.2, "{norms:?}");
    }

    #[test]
    fn infeasibility_scale_is_exact_on_the_prefix_polytope() {
        let pen = GroupOwl { gamma: 1.5 };
        let ds =
            synthetic1(&SynthOptions { t: 3, n: 10, d: 25, seed: 13, ..Default::default() }).0;
        let corr = ops::task_corr(&ds, &ops::y64(&ds));
        let (s, _) = pen.infeasibility(&corr, ds.t());
        assert!(s > 0.0);
        // after scaling by s every prefix constraint holds, one tightly
        let scaled: Vec<f64> = corr.iter().map(|v| v / s).collect();
        let sorted = pen.sorted_row_norms(&scaled, ds.t());
        let mut pu = 0.0;
        let mut pw = 0.0;
        let mut max_ratio = 0.0f64;
        for (i, &(_, u)) in sorted.iter().enumerate() {
            pu += u;
            pw += pen.weight(i);
            max_ratio = max_ratio.max(pu / pw);
        }
        assert!(max_ratio <= 1.0 + 1e-12, "still infeasible: {max_ratio}");
        assert!(max_ratio >= 1.0 - 1e-9, "scale not minimal: {max_ratio}");
    }

    #[test]
    fn gamma_zero_infeasibility_matches_l21_lambda_max() {
        let pen = GroupOwl { gamma: 0.0 };
        let ds =
            synthetic1(&SynthOptions { t: 3, n: 10, d: 25, seed: 14, ..Default::default() }).0;
        let corr = ops::task_corr(&ds, &ops::y64(&ds));
        let (s, lstar) = pen.infeasibility(&corr, ds.t());
        let (lmax, lstar_ref, _) = ops::lambda_max(&ds);
        // flat weights: the max prefix ratio is attained at k = 1 with
        // value u_[0] = max_l ‖c_l‖ = λ_max
        assert!((s - lmax).abs() <= 1e-12 * lmax.max(1.0), "{s} vs {lmax}");
        assert_eq!(lstar, lstar_ref);
    }
}
