//! The paper's ℓ2,1 norm as a [`Penalty`] instance.
//!
//! Every method **delegates to the exact pre-seam free function** — the
//! same code the hardcoded stack called before the seam existed
//! (`ops::l21_norm`, `prox::prox21_inplace`, `ops::gscore_from_corr`,
//! `secular::qp1qc_max`, and `ops::lambda_max`'s first-strict-maximum
//! fold) — so routing through the trait is bit-identical to `main` before
//! this refactor. `rust/tests/penalty_parity.rs` pins the equality
//! operation by operation and path by path.

use super::{ActiveRowCount, Penalty};

/// The ℓ2,1 norm Ω(W) = Σ_l ‖w^l‖₂ (problem (1) of the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct L21;

impl Penalty for L21 {
    fn name(&self) -> String {
        "l21".to_string()
    }

    fn value(&self, w: &[f64], t_count: usize) -> f64 {
        crate::ops::l21_norm(w, t_count)
    }

    fn prox_inplace(&self, w: &mut [f64], t_count: usize, kappa: f64) -> ActiveRowCount {
        crate::solver::prox::prox21_inplace(w, t_count, kappa)
    }

    /// The paper's per-feature `g_l = Σ_t c_{l,t}²` — row-local, so the
    /// sharded path streams it per block (identically to
    /// `ops::stream_gscore`, which computes the same numbers).
    fn infeas_features(&self, corr: &[f64], t_count: usize) -> Vec<f64> {
        crate::ops::gscore_from_corr(corr, t_count)
    }

    /// Eq. 15 scale: `max_l √g_l` with the identical first-strict-maximum
    /// fold as `ops::lambda_max`, so both the dual projection
    /// (`ops::dual_feasible`) and the Theorem-1 argmax witness come out
    /// bit-for-bit as before the seam.
    fn infeas_finish(&self, feats: &[f64]) -> (f64, usize) {
        let (lstar, gmax) = feats
            .iter()
            .enumerate()
            .fold((0usize, f64::MIN), |acc, (i, &v)| if v > acc.1 { (i, v) } else { acc });
        (gmax.max(0.0).sqrt(), lstar)
    }

    /// Theorem-7 QP1QC score maximization per feature — the identical
    /// per-row secular solve `screening::ball_scores` always ran.
    fn ball_scores(&self, corr: &[f64], b2: &[f64], t_count: usize, delta: f64) -> Vec<f64> {
        debug_assert_eq!(corr.len(), b2.len());
        let rows = corr.len() / t_count;
        let mut out = vec![0.0f64; rows];
        for l in 0..rows {
            let a = &corr[l * t_count..(l + 1) * t_count];
            let b2l = &b2[l * t_count..(l + 1) * t_count];
            out[l] = crate::screening::secular::qp1qc_max(a, b2l, delta).s;
        }
        out
    }

    /// The paper's g_l(θ) = Σ_t c_{l,t}² (Eq. 15/16 constraint values).
    fn dual_constraints(&self, corr: &[f64], t_count: usize) -> Vec<f64> {
        crate::ops::gscore_from_corr(corr, t_count)
    }

    fn supports_row_secular(&self) -> bool {
        true
    }

    fn supports_dpc_geometry(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, SynthOptions};
    use crate::ops;

    #[test]
    fn value_and_prox_delegate_bit_for_bit() {
        let w0 = vec![3.0, 4.0, 0.1, -0.2, 0.0, 0.0, -1.5, 2.5];
        assert_eq!(L21.value(&w0, 2).to_bits(), ops::l21_norm(&w0, 2).to_bits());
        let mut via_trait = w0.clone();
        let mut via_fn = w0.clone();
        let n_trait = L21.prox_inplace(&mut via_trait, 2, 0.7);
        let n_fn = crate::solver::prox::prox21_inplace(&mut via_fn, 2, 0.7);
        assert_eq!(n_trait, n_fn);
        for (a, b) in via_trait.iter().zip(&via_fn) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn infeasibility_matches_lambda_max_fold() {
        let ds =
            synthetic1(&SynthOptions { t: 3, n: 10, d: 40, seed: 21, ..Default::default() }).0;
        let corr = ops::task_corr(&ds, &ops::y64(&ds));
        let (s, lstar) = L21.infeasibility(&corr, ds.t());
        let (lmax, lstar_ref, _) = ops::lambda_max(&ds);
        assert_eq!(s.to_bits(), lmax.to_bits());
        assert_eq!(lstar, lstar_ref);
    }
}
