//! Dataset-level linear operators shared by the solvers and the screeners.
//!
//! Vectors that live in the dual/sample space (y, θ, residuals, the ball
//! center o) are "stacked": one f64 vector per task, `Stacked = Vec<Vec<f64>>`.
//! Weight matrices are row-major `(d x T)` f64 slices (`w[l*T + t]`).
//!
//! The two sweeps that dominate runtime — `task_corr` (X_tᵀ v_t for all
//! tasks/features) and `forward` (X_t w_t) — are parallelized over
//! contiguous feature chunks / tasks via [`crate::util::parallel_chunks`]
//! on the persistent executor (DESIGN.md §11): no sweep ever spawns a
//! thread, and sweeps issued from inside another parallel region run
//! inline on their worker. On the dense backend both are additionally
//! **cache-blocked** ([`corr_panel`] / [`axpy_panel`]): the sweep walks
//! [`crate::linalg::simd::ACC_BLOCK`]-sized sample blocks in the outer
//! loop and streams many columns past each resident block of `v_t` /
//! `z_t`, instead of re-streaming the whole vector per column. Because
//! blocking runs on the kernel layer's accumulation-contract boundaries
//! (DESIGN.md §12), the blocked sweep is bit-identical to the plain
//! per-column dot. CSC columns go through [`crate::linalg::ColRef`]
//! unblocked — their inner loops touch only stored nonzeros (DESIGN.md
//! §6). Sweeps below [`crate::util::serial_below`]'s cutoff skip the
//! pool entirely.

use crate::data::{Dataset, MatrixStore, ShardedDataset, Task};
use crate::linalg::simd;
use crate::util::{parallel_chunks, scoped_pool, serial_below};

/// One f64 vector per task (sample-space block vector).
pub type Stacked = Vec<Vec<f64>>;

// ---------------------------------------------------------------------------
// stacked-vector helpers
// ---------------------------------------------------------------------------

/// A zero stacked vector with the dataset's per-task lengths.
pub fn stacked_zeros_like(ds: &Dataset) -> Stacked {
    ds.tasks.iter().map(|t| vec![0.0f64; t.n]).collect()
}

/// The responses widened to f64, one vector per task.
pub fn y64(ds: &Dataset) -> Stacked {
    ds.tasks.iter().map(|t| t.y.iter().map(|&v| v as f64).collect()).collect()
}

/// Inner product of two stacked vectors (sum over tasks).
pub fn stacked_dot(a: &Stacked, b: &Stacked) -> f64 {
    a.iter().zip(b).map(|(x, y)| crate::linalg::dot_f64(x, y)).sum()
}

/// Squared Euclidean norm of a stacked vector.
pub fn stacked_sqnorm(a: &Stacked) -> f64 {
    stacked_dot(a, a)
}

/// out = a + s*b (allocating).
pub fn stacked_scale_add(a: &Stacked, s: f64, b: &Stacked) -> Stacked {
    a.iter()
        .zip(b)
        .map(|(x, y)| x.iter().zip(y).map(|(xi, yi)| xi + s * yi).collect())
        .collect()
}

/// out = a + s*b written into an existing buffer (no allocation — the
/// solvers' hot loops use the `_into`/`_inplace` family).
pub fn stacked_scale_add_into(a: &Stacked, s: f64, b: &Stacked, out: &mut Stacked) {
    debug_assert_eq!(a.len(), out.len());
    for ((x, y), o) in a.iter().zip(b).zip(out.iter_mut()) {
        crate::linalg::scale_add(x, s, y, o);
    }
}

/// out = s*a (allocating).
pub fn stacked_scale(a: &Stacked, s: f64) -> Stacked {
    a.iter().map(|x| x.iter().map(|v| v * s).collect()).collect()
}

/// out = s*a written into an existing buffer (no allocation).
pub fn stacked_scale_into(a: &Stacked, s: f64, out: &mut Stacked) {
    debug_assert_eq!(a.len(), out.len());
    for (x, o) in a.iter().zip(out.iter_mut()) {
        for (oi, &xi) in o.iter_mut().zip(x) {
            *oi = xi * s;
        }
    }
}

/// a *= s in place.
pub fn stacked_scale_inplace(a: &mut Stacked, s: f64) {
    for x in a.iter_mut() {
        for v in x.iter_mut() {
            *v *= s;
        }
    }
}

// ---------------------------------------------------------------------------
// the two hot sweeps
// ---------------------------------------------------------------------------

/// Cache-blocked panel of column dots for one task:
/// `out[(l-start)*stride] += <x_l, vt>` for `l` in `[start, end)`.
///
/// Dense backend: the outer loop walks [`simd::ACC_BLOCK`]-sized sample
/// blocks and the inner loop streams the panel's columns past the
/// resident block of `vt`, so `vt` is read once per block instead of
/// once per column. Per-column block partials accumulate in the same
/// order as [`crate::linalg::dense::dot_mixed`]'s internal fold — the
/// blocked sweep is bit-identical to the plain dot (DESIGN.md §12). CSC
/// columns are one stored-entry scan each and need no panel blocking.
///
/// Accumulates with `+=` into a zeroed buffer: the contract's fold also
/// starts at `0.0`, so this cannot differ (even on signed zeros) from
/// assigning the dot directly.
pub(crate) fn corr_panel(
    task: &Task,
    start: usize,
    end: usize,
    vt: &[f64],
    out: &mut [f64],
    stride: usize,
) {
    match &task.x {
        MatrixStore::Dense(x) => {
            let n = task.n;
            debug_assert_eq!(vt.len(), n);
            let mut b0 = 0usize;
            while b0 < n {
                let b1 = (b0 + simd::ACC_BLOCK).min(n);
                let vblk = &vt[b0..b1];
                for l in start..end {
                    let col = &x[l * n + b0..l * n + b1];
                    out[(l - start) * stride] += simd::dot_mixed_block(col, vblk);
                }
                b0 = b1;
            }
            // n == 0: no blocks, out stays zero — matches an empty dot
        }
        MatrixStore::Csc(_) => {
            for l in start..end {
                out[(l - start) * stride] += task.col(l).dot_mixed(vt);
            }
        }
    }
}

/// The `(end-start) × T` row-major slice of the correlation matrix —
/// [`task_corr`]'s per-worker body, shared with the screening score
/// sweeps so every consumer gets the blocked panels.
pub(crate) fn corr_chunk(ds: &Dataset, start: usize, end: usize, v: &Stacked) -> Vec<f64> {
    let t_count = ds.t();
    let mut part = vec![0.0f64; (end - start) * t_count];
    for (ti, task) in ds.tasks.iter().enumerate() {
        corr_panel(task, start, end, &v[ti], &mut part[ti..], t_count);
    }
    part
}

/// c[l*T + t] = <x_l^{(t)}, v_t>  — the correlation sweep (Eq. 8's m^l rows,
/// FISTA's gradient, the screening moments). Parallel over feature chunks,
/// cache-blocked per panel ([`corr_panel`]).
pub fn task_corr(ds: &Dataset, v: &Stacked) -> Vec<f64> {
    let t_count = ds.t();
    debug_assert_eq!(v.len(), t_count);
    let d = ds.d;
    let mut out = vec![0.0f64; d * t_count];
    // shared policy (util::threads): even a pooled dispatch has overhead,
    // so sweeps below the stored-entry cutoff stay serial
    let workers = if serial_below(ds.sweep_work()) { 1 } else { usize::MAX };
    // parallel over feature chunks: each worker fills a disjoint slice
    let chunks =
        parallel_chunks(d, workers, |_, start, end| (start, corr_chunk(ds, start, end, v)));
    for (start, part) in chunks {
        out[start * t_count..start * t_count + part.len()].copy_from_slice(&part);
    }
    out
}

/// g_l(v) = sum_t c[l,t]^2 from a correlation buffer (contract kernel:
/// identical to the naive sum for T < 8, SIMD-dispatched beyond).
pub fn gscore_from_corr(corr: &[f64], t_count: usize) -> Vec<f64> {
    corr.chunks_exact(t_count).map(|row| crate::linalg::dot_f64(row, row)).collect()
}

/// g_l(v) for all features (Eq. 16).
pub fn gscore(ds: &Dataset, v: &Stacked) -> Vec<f64> {
    gscore_from_corr(&task_corr(ds, v), ds.t())
}

/// Blocked multi-column axpy panel: `z += Σ w_l · x_l` over the given
/// `(column, weight)` pairs (weights must be nonzero — callers filter).
///
/// Dense backend: sample-block outer loop keeps one [`simd::ACC_BLOCK`]
/// block of `z` resident while every active column's matching block
/// streams past. axpy is elementwise (no cross-element accumulator), so
/// only the *column order within each element* matters — preserved — and
/// the blocked panel is bit-identical to per-column axpys. CSC columns
/// scatter once each, unblocked.
pub(crate) fn axpy_panel(task: &Task, cols: &[(usize, f64)], z: &mut [f64]) {
    match &task.x {
        MatrixStore::Dense(x) => {
            let n = task.n;
            debug_assert_eq!(z.len(), n);
            let mut b0 = 0usize;
            while b0 < n {
                let b1 = (b0 + simd::ACC_BLOCK).min(n);
                let zblk = &mut z[b0..b1];
                for &(l, wl) in cols {
                    simd::axpy_f64(wl, &x[l * n + b0..l * n + b1], zblk);
                }
                b0 = b1;
            }
        }
        MatrixStore::Csc(_) => {
            for &(l, wl) in cols {
                task.col(l).axpy_into(wl, z);
            }
        }
    }
}

/// z_t = X_t w_t for all tasks. Skips zero rows of W, so the cost scales
/// with the *active* set — the asymmetry screening exploits. Parallel over
/// tasks, cache-blocked per panel ([`axpy_panel`]).
pub fn forward(ds: &Dataset, w: &[f64]) -> Stacked {
    let t_count = ds.t();
    debug_assert_eq!(w.len(), ds.d * t_count);
    let tasks: Vec<usize> = (0..t_count).collect();
    let workers = if serial_below(ds.sweep_work()) { 1 } else { usize::MAX };
    scoped_pool(tasks, workers, |ti| {
        let task = &ds.tasks[ti];
        let mut z = vec![0.0f64; task.n];
        let active: Vec<(usize, f64)> = (0..ds.d)
            .filter_map(|l| {
                let wl = w[l * t_count + ti];
                (wl != 0.0).then_some((l, wl))
            })
            .collect();
        axpy_panel(task, &active, &mut z);
        z
    })
}

/// Residual R_t = X_t w_t - y_t.
pub fn residual(ds: &Dataset, w: &[f64]) -> Stacked {
    let mut z = forward(ds, w);
    for (zt, task) in z.iter_mut().zip(&ds.tasks) {
        for (zi, &yi) in zt.iter_mut().zip(&task.y) {
            *zi -= yi as f64;
        }
    }
    z
}

// ---------------------------------------------------------------------------
// objective / duality machinery
// ---------------------------------------------------------------------------

/// ‖W‖₂,₁ = Σ_l ‖w^l‖ over the rows of a row-major (d × T) matrix.
/// Row norms go through [`crate::linalg::nrm2_f64`] — the same contract
/// kernel the prox row pass uses, so activity thresholds agree.
pub fn l21_norm(w: &[f64], t_count: usize) -> f64 {
    w.chunks_exact(t_count).map(crate::linalg::nrm2_f64).sum()
}

/// ‖w^l‖ > tol — the row-activity predicate shared by the path runners'
/// ground-truth bookkeeping and stability selection's union-over-λ mask.
pub fn row_is_active(row: &[f64], tol: f64) -> bool {
    crate::linalg::nrm2_f64(row) > tol
}

/// F(W) = ½ Σ_t ||X_t w_t − y_t||² + λ||W||₂,₁ (problem (1)).
pub fn primal_obj(ds: &Dataset, w: &[f64], lam: f64) -> f64 {
    let r = residual(ds, w);
    0.5 * stacked_sqnorm(&r) + lam * l21_norm(w, ds.t())
}

/// Scale a sample-space point into the dual-feasible set
/// F = {θ : g_l(θ) ≤ 1 ∀l} (Eq. 15): θ = z / max(1, max_l √g_l(z)).
/// Returns (θ, scale). This is the certified dual point every gap-based
/// bound is anchored to — screening and the GAP-safe ball both consume it.
pub fn dual_feasible(ds: &Dataset, z: Stacked) -> (Stacked, f64) {
    let m = gscore(ds, &z).into_iter().fold(0.0f64, f64::max).sqrt();
    if m > 1.0 {
        let mut theta = z;
        stacked_scale_inplace(&mut theta, 1.0 / m);
        (theta, m)
    } else {
        (z, 1.0)
    }
}

/// [`dual_feasible`] generalized over the penalty seam (DESIGN.md §14):
/// scale `z` by `1/max(1, s)` where `s` is the penalty's dual
/// infeasibility of the correlations `c(z)`. For the ℓ2,1 instance the
/// scale equals [`dual_feasible`]'s `max_l √g_l` (same correlation
/// sweep, same maximum), so the projected point is numerically
/// identical; non-ℓ2,1 penalties supply their own dual norm.
pub fn dual_feasible_for(
    ds: &Dataset,
    z: Stacked,
    pen: &dyn crate::penalty::Penalty,
) -> (Stacked, f64) {
    let corr = task_corr(ds, &z);
    let (m, _) = pen.infeasibility(&corr, ds.t());
    if m > 1.0 {
        let mut theta = z;
        stacked_scale_inplace(&mut theta, 1.0 / m);
        (theta, m)
    } else {
        (z, 1.0)
    }
}

/// Dual objective D(θ) = ½‖y‖² − λ²/2 ‖y/λ − θ‖² at a (feasible) θ.
pub fn dual_obj(y: &Stacked, theta: &Stacked, lam: f64) -> f64 {
    // one global left-to-right fold threaded across tasks (splitting into
    // per-task partials would regroup the adds and change the bits)
    let mut diff_sq = 0.0;
    for (yt, tt) in y.iter().zip(theta) {
        diff_sq = crate::linalg::simd::scaled_diff_sumsq_serial(diff_sq, yt, tt, lam);
    }
    0.5 * stacked_sqnorm(y) - 0.5 * lam * lam * diff_sq
}

/// Duality gap via the scaled-residual feasible point. Returns
/// (obj, gap, theta_feasible).
pub fn duality_gap(ds: &Dataset, w: &[f64], lam: f64) -> (f64, f64, Stacked) {
    let y = y64(ds);
    let mut r = residual(ds, w);
    let obj = 0.5 * stacked_sqnorm(&r) + lam * l21_norm(w, ds.t());
    // z = (y - Xw)/lam = -r/lam, scaled in place (the residual buffer is
    // ours), then projected into the feasible set F
    stacked_scale_inplace(&mut r, -1.0 / lam);
    let (theta, _) = dual_feasible(ds, r);
    let dual = dual_obj(&y, &theta, lam);
    (obj, obj - dual, theta)
}

/// Generalized primal objective F(W) = ½ Σ_t ‖X_t w_t − y_t‖² + λ·Ω(W)
/// for any [`crate::penalty::Penalty`] Ω.
pub fn primal_obj_for(ds: &Dataset, w: &[f64], lam: f64, pen: &dyn crate::penalty::Penalty) -> f64 {
    let r = residual(ds, w);
    0.5 * stacked_sqnorm(&r) + lam * pen.value(w, ds.t())
}

/// [`duality_gap`] generalized over the penalty seam: the primal uses the
/// penalty's value and the dual point is projected with the penalty's
/// dual norm ([`dual_feasible_for`]). The dual objective itself is
/// loss-owned (squared loss here — `penalty::loss`), not penalty-owned,
/// so [`dual_obj`] is shared. For ℓ2,1 this evaluates the identical
/// sweeps in the identical order as [`duality_gap`]
/// (`rust/tests/penalty_parity.rs` pins the equality).
pub fn duality_gap_for(
    ds: &Dataset,
    w: &[f64],
    lam: f64,
    pen: &dyn crate::penalty::Penalty,
) -> (f64, f64, Stacked) {
    let y = y64(ds);
    let mut r = residual(ds, w);
    let obj = 0.5 * stacked_sqnorm(&r) + lam * pen.value(w, ds.t());
    stacked_scale_inplace(&mut r, -1.0 / lam);
    let (theta, _) = dual_feasible_for(ds, r, pen);
    let dual = dual_obj(&y, &theta, lam);
    (obj, obj - dual, theta)
}

// ---------------------------------------------------------------------------
// Theorem 1: lambda_max and the normal vector at y/lambda_max
// ---------------------------------------------------------------------------

/// (lambda_max, argmax feature l*, g_l(y) for all l).
pub fn lambda_max(ds: &Dataset) -> (f64, usize, Vec<f64>) {
    let g = gscore(ds, &y64(ds));
    let (lstar, gmax) = g
        .iter()
        .enumerate()
        .fold((0usize, f64::MIN), |acc, (i, &v)| if v > acc.1 { (i, v) } else { acc });
    (gmax.max(0.0).sqrt(), lstar, g)
}

/// Theorem 1 generalized over the penalty seam: λ_max is the smallest λ
/// with `y/λ` dual-feasible, i.e. the penalty's dual infeasibility of
/// `c(y)` (DESIGN.md §14 — the same operation [`dual_feasible_for`]
/// scales with, evaluated at `z = y`). Returns (λ_max, witness feature).
/// For ℓ2,1 both numbers match [`lambda_max`] exactly (same correlation
/// sweep, same first-strict-maximum fold).
pub fn lambda_max_for(ds: &Dataset, pen: &dyn crate::penalty::Penalty) -> (f64, usize) {
    let corr = task_corr(ds, &y64(ds));
    pen.lambda_max(&corr, ds.t())
}

/// n(lambda_max) = ∇g_{l*}(y/λmax): n_t = 2 <x_{l*}^{(t)}, y_t/λmax> x_{l*}^{(t)}.
pub fn normal_at_lmax(ds: &Dataset, lstar: usize, lmax: f64) -> Stacked {
    ds.tasks
        .iter()
        .map(|task| {
            let col = task.col(lstar);
            let c = 2.0 * col.dot_f32(&task.y) / lmax;
            let mut out = vec![0.0f64; task.n];
            col.axpy_into(c, &mut out);
            out
        })
        .collect()
}

// ---------------------------------------------------------------------------
// block-streaming sweeps over sharded datasets (DESIGN.md §10)
// ---------------------------------------------------------------------------

/// g_l(v) for every feature of a sharded dataset, one column block at a
/// time. Blocks are *consumed* strictly in order — per-column results are
/// bit-identical to [`gscore`] on the materialized dataset (each column
/// is the same dot in the same association order) — but the shard's
/// prefetch pipeline decodes block b+1 (read + checksum + parse) on a
/// pool worker while block b is swept, so the disk and the sweep overlap
/// ([`ShardedDataset::for_each_block_pipelined`], DESIGN.md §11). Inside
/// a block the sweep reuses [`gscore`]'s `parallel_chunks` workers over
/// the block's columns.
pub fn stream_gscore(sh: &ShardedDataset, v: &Stacked) -> anyhow::Result<Vec<f64>> {
    debug_assert_eq!(v.len(), sh.t());
    let mut out = vec![0.0f64; sh.d()];
    sh.for_each_block_pipelined(|b, blk| {
        let part = gscore(blk, v);
        out[sh.block_range(b)].copy_from_slice(&part);
        Ok(())
    })?;
    Ok(out)
}

/// The ‖x_l^{(t)}‖² table (d × T row-major) streamed block-by-block — the
/// λ-independent b² moments of Theorem 7, computed once per shard by the
/// screen-before-load pipeline. Matches [`Dataset::col_sqnorms`] on the
/// materialized dataset exactly.
pub fn stream_col_sqnorms(sh: &ShardedDataset) -> anyhow::Result<Vec<f64>> {
    let t_count = sh.t();
    let mut out = vec![0.0f64; sh.d() * t_count];
    sh.for_each_block_pipelined(|b, blk| {
        let part = blk.col_sqnorms();
        let range = sh.block_range(b);
        out[range.start * t_count..range.end * t_count].copy_from_slice(&part);
        Ok(())
    })?;
    Ok(out)
}

/// (λ_max, argmax feature l*, g_l(y) for all l) of a sharded dataset —
/// Theorem 1 evaluated without ever materializing the matrix. Uses the
/// identical first-strict-maximum fold as [`lambda_max`], so the argmax
/// (and therefore the sequential screening reference) agrees with the
/// in-RAM path bit-for-bit.
pub fn stream_lambda_max(sh: &ShardedDataset) -> anyhow::Result<(f64, usize, Vec<f64>)> {
    let g = stream_gscore(sh, &sh.y64())?;
    let (lstar, gmax) = g
        .iter()
        .enumerate()
        .fold((0usize, f64::MIN), |acc, (i, &v)| if v > acc.1 { (i, v) } else { acc });
    Ok((gmax.max(0.0).sqrt(), lstar, g))
}

/// The penalty's per-feature infeasibility statistics
/// ([`crate::penalty::Penalty::infeas_features`]) streamed one column
/// block at a time — the generalized half of [`stream_gscore`] (for ℓ2,1
/// the two produce identical bits: both are `gscore` per block). The
/// caller folds the assembled vector with
/// [`crate::penalty::Penalty::infeas_finish`]; feature statistics are
/// row-local, so block-order concatenation equals one full-width call.
pub fn stream_infeas_features(
    sh: &ShardedDataset,
    v: &Stacked,
    pen: &dyn crate::penalty::Penalty,
) -> anyhow::Result<Vec<f64>> {
    debug_assert_eq!(v.len(), sh.t());
    let t_count = sh.t();
    let mut out = vec![0.0f64; sh.d()];
    sh.for_each_block_pipelined(|b, blk| {
        let corr = task_corr(blk, v);
        let part = pen.infeas_features(&corr, t_count);
        out[sh.block_range(b)].copy_from_slice(&part);
        Ok(())
    })?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{synthetic1, SynthOptions};

    fn ds() -> Dataset {
        synthetic1(&SynthOptions { t: 3, n: 10, d: 25, seed: 4, ..Default::default() }).0
    }

    #[test]
    fn corr_matches_naive() {
        let ds = ds();
        let v = y64(&ds);
        let c = task_corr(&ds, &v);
        for t in 0..3 {
            for l in 0..25 {
                let want: f64 = ds
                    .col(t, l)
                    .to_vec()
                    .iter()
                    .zip(&v[t])
                    .map(|(&x, &vv)| x as f64 * vv)
                    .sum();
                assert!((c[l * 3 + t] - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn forward_skips_zeros_correctly() {
        let ds = ds();
        let mut w = vec![0.0f64; 25 * 3];
        w[5 * 3 + 1] = 2.0;
        w[7 * 3 + 0] = -1.5;
        let z = forward(&ds, &w);
        let c15 = ds.col(1, 5).to_vec();
        let c07 = ds.col(0, 7).to_vec();
        for ni in 0..10 {
            assert!((z[1][ni] - 2.0 * c15[ni] as f64).abs() < 1e-10);
            assert!((z[0][ni] + 1.5 * c07[ni] as f64).abs() < 1e-10);
            assert_eq!(z[2][ni], 0.0);
        }
    }

    #[test]
    fn lambda_max_makes_y_over_lam_feasible() {
        let ds = ds();
        let (lmax, lstar, g) = lambda_max(&ds);
        assert!((g[lstar].sqrt() - lmax).abs() < 1e-12);
        let yl = stacked_scale(&y64(&ds), 1.0 / lmax);
        let gm = gscore(&ds, &yl).into_iter().fold(0.0f64, f64::max);
        assert!((gm - 1.0).abs() < 1e-9, "max g at y/lmax = {gm}");
    }

    #[test]
    fn gap_nonnegative_and_zero_solution_at_lmax() {
        let ds = ds();
        let (lmax, _, _) = lambda_max(&ds);
        let w = vec![0.0f64; 25 * 3];
        let (obj, gap, _) = duality_gap(&ds, &w, lmax * 1.001);
        assert!(gap >= -1e-9);
        // at lam >= lmax, W = 0 is optimal: gap must be ~0
        assert!(gap <= 1e-9 * obj.max(1.0), "gap {gap} obj {obj}");
    }

    #[test]
    fn l21_matches_manual() {
        let w = vec![3.0, 4.0, 0.0, 0.0, 1.0, 0.0];
        // rows: [3,4] -> 5 ; [0,0] -> 0 ; [1,0] -> 1   (t=2)
        assert!((l21_norm(&w, 2) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn normal_at_lmax_matches_gradient_definition() {
        let ds = ds();
        let (lmax, lstar, g) = lambda_max(&ds);
        let n = normal_at_lmax(&ds, lstar, lmax);
        // <y, n> = Σ_t 2<x_{l*}, y_t>²/λmax = 2·g_{l*}(y)/λmax = 2·λmax
        // (Theorem 5 part 2): assert the gradient identity against the
        // computed value, both via g and via λmax itself
        let y = y64(&ds);
        let ip = stacked_dot(&y, &n);
        let want = 2.0 * lmax;
        assert!(
            (ip - want).abs() <= 1e-9 * want.max(1.0),
            "<y, n(λmax)> = {ip}, want 2λmax = {want}"
        );
        // independent check: recompute g_{l*}(y) = Σ_t <x_{l*}, y_t>² with
        // naive dots, bypassing lambda_max/task_corr entirely
        let g_naive: f64 = ds
            .tasks
            .iter()
            .map(|task| {
                let col = task.col(lstar).to_vec();
                let dot: f64 =
                    col.iter().zip(&task.y).map(|(&x, &yv)| x as f64 * yv as f64).sum();
                dot * dot
            })
            .sum();
        assert!((g_naive - g[lstar]).abs() <= 1e-9 * g[lstar].max(1.0));
        assert!(
            (ip - 2.0 * g_naive / lmax).abs() <= 1e-9 * want.max(1.0),
            "<y, n(λmax)> = {ip} disagrees with 2 g_l*(y)/λmax = {}",
            2.0 * g_naive / lmax
        );
    }
}
