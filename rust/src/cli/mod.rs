//! Minimal CLI argument parser (clap is not vendored offline).
//!
//! Supports: `binary <subcommand> [--flag] [--key value] [--key=value]`.
//! Typed getters with defaults + "unknown argument" detection.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: one optional subcommand plus `--key value` /
/// `--key=value` pairs and bare `--flag`s, with consumption tracking so
/// [`Args::finish`] can reject typos.
#[derive(Debug, Clone)]
pub struct Args {
    /// the leading non-flag token, if any
    pub subcommand: Option<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an explicit token list (first token = subcommand unless
    /// it starts with `--`).
    pub fn parse_from(tokens: &[String]) -> Result<Args> {
        let mut subcommand = None;
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut i = 0usize;
        if let Some(first) = tokens.first() {
            if !first.starts_with("--") {
                subcommand = Some(first.clone());
                i = 1;
            }
        }
        while i < tokens.len() {
            let tok = &tokens[i];
            let Some(stripped) = tok.strip_prefix("--") else {
                bail!("positional argument '{tok}' not understood (flags are --key value)");
            };
            if let Some((k, v)) = stripped.split_once('=') {
                values.insert(k.to_string(), v.to_string());
            } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                values.insert(stripped.to_string(), tokens[i + 1].clone());
                i += 1;
            } else {
                flags.push(stripped.to_string());
            }
            i += 1;
        }
        Ok(Args { subcommand, values, flags, consumed: Default::default() })
    }

    /// Parse from the process arguments (skipping argv\[0\]).
    pub fn parse() -> Result<Args> {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Self::parse_from(&tokens)
    }

    /// True if the bare flag `--name` was given.
    pub fn flag(&self, name: &str) -> bool {
        self.consumed.borrow_mut().insert(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().insert(name.to_string());
        self.values.get(name).map(|s| s.as_str())
    }

    /// The value of `--name`, or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// `--name` parsed as usize, or `default`; a typed error on garbage.
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// `--name` parsed as f64, or `default`; a typed error on garbage.
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// `--name` parsed as u64, or `default`; a typed error on garbage.
    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Error if any provided argument was never consumed (typo guard).
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .values
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(k.as_str()))
            .collect();
        if !unknown.is_empty() {
            bail!("unknown argument(s): {:?}", unknown);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|v| v.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_kv_and_flags() {
        let a = Args::parse_from(&toks(&["table1", "--d", "5000", "--scale=paper", "--verbose"]))
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.get_usize("d", 0).unwrap(), 5000);
        assert_eq!(a.get("scale"), Some("paper"));
        assert!(a.flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_args_detected() {
        let a = Args::parse_from(&toks(&["run", "--oops", "1"])).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn typed_errors() {
        let a = Args::parse_from(&toks(&["run", "--d", "abc"])).unwrap();
        assert!(a.get_usize("d", 0).is_err());
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse_from(&toks(&["run", "stray"])).is_err());
    }

    #[test]
    fn no_subcommand() {
        let a = Args::parse_from(&toks(&["--k", "v"])).unwrap();
        assert!(a.subcommand.is_none());
        assert_eq!(a.get("k"), Some("v"));
    }
}
