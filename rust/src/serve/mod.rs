//! The serving layer (DESIGN.md §15): `repro serve`, a long-lived
//! solve/predict daemon over a length-prefixed JSON TCP protocol, and
//! `repro load`, its RPS-ramp load harness.
//!
//! The daemon holds warm fitted models — one full `W` per λ/λ_max grid
//! ratio, captured through the same [`crate::coordinator::path::PathObserver`]
//! seam CV and stability selection consume — and answers:
//!
//! | op        | does                                                        |
//! |-----------|-------------------------------------------------------------|
//! | `ping`    | liveness                                                    |
//! | `info`    | dataset shape, λ_max, penalty, fitted ratios                |
//! | `predict` | batched rows × cached `W`, bit-identical to offline forward |
//! | `fit`     | single-λ solve, warm-started from the nearest cached model  |
//! | `cv`      | k-fold CV over the configured grid                          |
//! | `stats`   | per-op latency percentiles, cache + executor counters       |
//! | `shutdown`| stop accepting, drain in-flight work, exit 0                |
//!
//! Submodules: [`json`] (in-tree parser/serializer with bit-exact f64
//! round-trip), [`proto`] (frame codec + request/reply model), [`cache`]
//! (warm-model store), [`stats`] (latency rings), [`server`] (the
//! tick-driven event loop), [`load`] (the ramp harness).

pub mod cache;
pub mod json;
pub mod load;
pub mod proto;
pub mod server;
pub mod stats;

pub use cache::{ModelCache, ModelEntry};
pub use load::{run_load, run_soak, LoadOptions, LoadReport, SoakOptions, SoakReport};
pub use server::{Server, ServerOptions};
