//! Warm-model cache: the daemon's reason to be long-lived.
//!
//! One entry per fitted λ/λ_max ratio, holding the full row-major `W`
//! (d×T) plus its optimality certificate (objective, duality gap).
//! Lookup is exact on the ratio's f64 bits — `predict` must apply the
//! *same* model every time, never a silently-nearest one. Warm starts
//! go the other way: [`ModelCache::nearest`] hands `fit` the cached `W`
//! whose log-ratio is closest, the same neighbor-in-log-space heuristic
//! the λ-path coordinator exploits (Corollary 9 sequential screening
//! feeds on exactly this continuity).
//!
//! Entries are never evicted: a grid of models is a few d×T f64 arrays —
//! memory is bounded by the fit requests the operator chose to send, and
//! dropping a model a client might still predict against would turn a
//! cache policy into a correctness event (DESIGN.md §15).

/// One fitted model at a grid point.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    /// λ/λ_max this model was fitted at
    pub ratio: f64,
    /// absolute λ
    pub lam: f64,
    /// row-major weights, d×T
    pub w: Vec<f64>,
    /// primal objective at the solution
    pub obj: f64,
    /// duality gap at the solution (the optimality certificate)
    pub gap: f64,
    /// solver iterations spent
    pub iters: usize,
}

/// The daemon's model store, with hit/miss accounting for `stats`.
#[derive(Debug, Default)]
pub struct ModelCache {
    entries: Vec<ModelEntry>,
    hits: u64,
    misses: u64,
}

impl ModelCache {
    /// An empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert (or replace, on identical ratio bits) a fitted model.
    pub fn insert(&mut self, e: ModelEntry) {
        match self.entries.iter_mut().find(|x| x.ratio.to_bits() == e.ratio.to_bits()) {
            Some(slot) => *slot = e,
            None => self.entries.push(e),
        }
    }

    /// Exact-bits lookup, counted as a hit or miss.
    pub fn get(&mut self, ratio: f64) -> Option<&ModelEntry> {
        let found = self.entries.iter().position(|e| e.ratio.to_bits() == ratio.to_bits());
        match found {
            Some(i) => {
                self.hits += 1;
                Some(&self.entries[i])
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Exact-bits lookup without touching the hit/miss counters (used by
    /// `fit` to distinguish "already fitted" from a predict-path hit).
    pub fn peek(&self, ratio: f64) -> Option<&ModelEntry> {
        self.entries.iter().find(|e| e.ratio.to_bits() == ratio.to_bits())
    }

    /// The fitted model nearest `ratio` in log-space (warm-start donor).
    pub fn nearest(&self, ratio: f64) -> Option<&ModelEntry> {
        self.entries.iter().min_by(|a, b| {
            let da = (a.ratio.ln() - ratio.ln()).abs();
            let db = (b.ratio.ln() - ratio.ln()).abs();
            da.total_cmp(&db)
        })
    }

    /// Fitted ratios, descending (for actionable "unfitted λ" errors).
    pub fn ratios(&self) -> Vec<f64> {
        let mut r: Vec<f64> = self.entries.iter().map(|e| e.ratio).collect();
        r.sort_by(|a, b| b.total_cmp(a));
        r
    }

    /// Number of cached models.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if nothing is fitted yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// (hits, misses) counters for `stats`.
    pub fn counters(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(ratio: f64) -> ModelEntry {
        ModelEntry { ratio, lam: ratio * 2.0, w: vec![ratio; 4], obj: 0.0, gap: 0.0, iters: 1 }
    }

    #[test]
    fn exact_bits_lookup_and_counters() {
        let mut c = ModelCache::new();
        c.insert(entry(0.5));
        assert!(c.get(0.5).is_some());
        assert!(c.get(0.5000001).is_none(), "no silent nearest on predict");
        assert_eq!(c.counters(), (1, 1));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn replace_on_same_ratio() {
        let mut c = ModelCache::new();
        c.insert(entry(0.5));
        let mut e = entry(0.5);
        e.iters = 99;
        c.insert(e);
        assert_eq!(c.len(), 1);
        assert_eq!(c.peek(0.5).unwrap().iters, 99);
    }

    #[test]
    fn nearest_is_log_space() {
        let mut c = ModelCache::new();
        c.insert(entry(1.0));
        c.insert(entry(0.1));
        // 0.35 is closer to 0.1 linearly but closer to 1.0 in log-space
        let n = c.nearest(0.35).unwrap();
        assert_eq!(n.ratio, 1.0);
        assert_eq!(c.ratios(), vec![1.0, 0.1]);
    }
}
