//! The `repro serve` daemon core: a tick-driven, single-threaded TCP
//! event loop that batches request work onto the persistent executor.
//!
//! ## Why a tick loop and not a thread per connection
//!
//! The repo's concurrency contract confines `thread::spawn` to the
//! executor (repro-lint `no-spawn`, DESIGN.md §11/§13), and the
//! executor's scopes are synchronous fork-join — ideal for data-parallel
//! sweeps, wrong for an unbounded set of blocking socket reads. So the
//! daemon owns every socket on one thread in nonblocking mode and makes
//! progress in discrete [`Server::tick`]s: accept, read, decode, process
//! the decoded batch, flush replies. CPU work — the only part that
//! scales with load — is fanned out per tick as one executor scope over
//! every predict row decoded this tick, so concurrent clients batch onto
//! the same `scoped_pool` lanes the offline solvers use, bounded by
//! `MTFL_THREADS`. Fit/CV jobs run inline on the coordinator thread
//! (their solvers parallelize internally through the same executor) and
//! simply make the current tick long; predict traffic queues in kernel
//! socket buffers meanwhile and drains next tick — the protocol is
//! pipelined, replies stay in per-connection order (DESIGN.md §15).
//!
//! Tests drive [`Server::tick`] directly (client and daemon interleave
//! deterministically on one thread at any `MTFL_THREADS`); the CLI runs
//! [`Server::run`], which is the same tick in a sleep loop plus
//! drain-on-shutdown.
//!
//! ## Bit-parity contract
//!
//! A served prediction at ratio r must equal the offline pipeline
//! (`run_path` → [`crate::ops::forward`]) bit-for-bit. Per sample,
//! `forward` accumulates active columns in ascending `l` with one
//! mul-then-add each ([`crate::ops`]'s `axpy_panel` over
//! [`crate::linalg::simd::axpy_f64`]); the serve path replays exactly
//! that order through [`crate::linalg::simd::dot_strided_skipz_f64`],
//! and the JSON layer round-trips every f64 bit-exactly
//! ([`crate::serve::json`]). The warm-model cache stores the path's own
//! `W` arrays unchanged, so there is nothing left to drift.

use crate::coordinator::path::{
    run_path_with, EngineKind, FnObserver, PathOptions, ScreenerKind, SolverKind,
};
use crate::data::Dataset;
use crate::linalg::simd;
use crate::penalty::Penalty;
use crate::screening::dpc::DualRef;
use crate::serve::cache::{ModelCache, ModelEntry};
use crate::serve::json::{self, Value};
use crate::serve::proto::{self, FrameDecoder, Request};
use crate::serve::stats::ServeStats;
use crate::solver::{bcd, fista};
use crate::util::{executor, num_threads, ShutdownFlag, Stopwatch};
use anyhow::{Context, Result};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

/// Idle sleep between ticks when nothing was processed ([`Server::run`]).
const IDLE: Duration = Duration::from_millis(1);

/// Drain window after shutdown: in-flight frames and unflushed replies
/// get this long to complete before sockets are dropped.
const DRAIN_SECS: f64 = 2.0;

/// Daemon configuration (the CLI builds this from `repro serve` flags).
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// grid/screener/solver/penalty configuration — the same
    /// [`PathOptions`] the offline coordinator takes, so a daemon fit is
    /// the offline fit
    pub path: PathOptions,
    /// run the full λ-path at startup, caching every grid model
    pub prefit: bool,
    /// per-frame payload cap in bytes ([`proto::DEFAULT_MAX_FRAME`])
    pub max_frame: usize,
}

/// One client connection's sockets + buffers.
struct Conn {
    stream: TcpStream,
    dec: FrameDecoder,
    outbox: Vec<u8>,
    outpos: usize,
    /// still accepting request frames (false after EOF or a poisoned
    /// stream; queued replies still flush)
    open: bool,
    /// framing poisoned (oversize header): buffered bytes are garbage,
    /// stop decoding — the one-shot error reply still flushes
    poisoned: bool,
    /// socket usable at all (false after a hard I/O error)
    alive: bool,
}

/// A deferred predict decoded this tick, awaiting the executor batch.
struct PendingPredict {
    ratio: f64,
    rows: Vec<Vec<f32>>,
    sw: Stopwatch,
}

/// Reply slot for one decoded frame, in per-connection arrival order.
enum Slot {
    Ready(&'static str, String, Stopwatch),
    Predict(usize),
}

/// The `repro serve` daemon: dataset + warm-model cache + event loop.
pub struct Server {
    ds: Dataset,
    lam_max: f64,
    opts: ServerOptions,
    cache: ModelCache,
    stats: ServeStats,
    listener: TcpListener,
    conns: Vec<Conn>,
    shutdown: ShutdownFlag,
    uptime: Stopwatch,
    requests: u64,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port), validate the
    /// penalty/solver capability gates, and optionally prefit the grid.
    pub fn bind(addr: &str, ds: Dataset, opts: ServerOptions) -> Result<Server> {
        ds.validate()?;
        let pen: &dyn Penalty = &opts.path.solve.penalty;
        if !opts.path.solve.penalty.is_l21() {
            // same capability gates as the path coordinator (DESIGN.md
            // §14): fail at bind, not on the first client request
            anyhow::ensure!(
                matches!(opts.path.screener, ScreenerKind::None | ScreenerKind::GapSafe),
                "screener {:?} is ℓ2,1-only; penalty {} serves with --screener gap or none",
                opts.path.screener,
                pen.name()
            );
            anyhow::ensure!(
                matches!(opts.path.solver, SolverKind::Fista),
                "solver Bcd is ℓ2,1-only; penalty {} serves with --solver fista",
                pen.name()
            );
        }
        let lam_max = if opts.path.solve.penalty.is_l21() {
            DualRef::at_lambda_max(&ds).1
        } else {
            crate::ops::lambda_max_for(&ds, pen).0
        };
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        listener.set_nonblocking(true).context("set_nonblocking")?;
        executor::ensure_init();
        let mut srv = Server {
            ds,
            lam_max,
            opts,
            cache: ModelCache::new(),
            stats: ServeStats::new(),
            listener,
            conns: Vec::new(),
            shutdown: ShutdownFlag::new(),
            uptime: Stopwatch::started(),
            requests: 0,
        };
        if srv.opts.prefit {
            srv.prefit()?;
        }
        Ok(srv)
    }

    /// The bound address (read the ephemeral port from here).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// A clone of the shutdown latch (trip it to stop [`Server::run`]).
    pub fn shutdown_flag(&self) -> ShutdownFlag {
        self.shutdown.clone()
    }

    /// Fitted ratios, descending (test hook + CLI logging).
    pub fn fitted_ratios(&self) -> Vec<f64> {
        self.cache.ratios()
    }

    /// Run the configured λ-path once, caching every per-λ `W` through
    /// the observer seam — the same `run_path_with` hook CV and
    /// stability selection consume, so the cached models *are* the
    /// offline path's models.
    pub fn prefit(&mut self) -> Result<()> {
        let mut captured: Vec<ModelEntry> = Vec::new();
        let mut obs = FnObserver(
            |ratio: f64, lam: f64, w: &[f64], rec: &crate::coordinator::path::LambdaRecord| {
                captured.push(ModelEntry {
                    ratio,
                    lam,
                    w: w.to_vec(),
                    obj: rec.obj,
                    gap: rec.gap,
                    iters: rec.solver_iters,
                });
            },
        );
        run_path_with(&self.ds, &self.opts.path, &EngineKind::Exact, &mut obs)?;
        for e in captured {
            self.cache.insert(e);
        }
        Ok(())
    }

    /// Serve until the shutdown latch trips, then drain and return.
    /// This is `tick` + idle sleep; exit code 0 is the contract — every
    /// failure mode that isn't a bind/prefit error is an error *reply*.
    pub fn run(&mut self) -> Result<()> {
        while !self.shutdown.is_requested() {
            if self.tick()? == 0 {
                std::thread::sleep(IDLE);
            }
        }
        self.drain()
    }

    /// Post-shutdown drain: finish work already on the wire (decoded or
    /// decodable frames, unflushed replies) within [`DRAIN_SECS`], then
    /// drop every socket. Nothing in-flight is abandoned unless the
    /// deadline passes — a wedged peer cannot hold the process hostage.
    pub fn drain(&mut self) -> Result<()> {
        let sw = Stopwatch::started();
        loop {
            let n = self.tick()?;
            let flushed = self.conns.iter().all(|c| c.outpos == c.outbox.len());
            if n == 0 && flushed {
                break;
            }
            if sw.secs() > DRAIN_SECS {
                break;
            }
            std::thread::sleep(IDLE);
        }
        self.conns.clear();
        Ok(())
    }

    /// One scheduling quantum: accept new connections (unless shutting
    /// down), read and decode every connection, process the decoded
    /// request batch (predict rows fan out as one executor scope), queue
    /// and flush replies. Returns the number of requests processed, so
    /// callers can idle-sleep on 0. Tests call this directly to
    /// interleave client and daemon deterministically on one thread.
    pub fn tick(&mut self) -> Result<usize> {
        if !self.shutdown.is_requested() {
            self.accept_new()?;
        }
        self.read_all();

        // decode + dispatch, building per-conn ordered reply slots
        let mut slots: Vec<(usize, Slot)> = Vec::new();
        let mut pendings: Vec<PendingPredict> = Vec::new();
        for ci in 0..self.conns.len() {
            loop {
                if !self.conns[ci].alive || self.conns[ci].poisoned {
                    break;
                }
                let frame = match self.conns[ci].dec.next(self.opts.max_frame) {
                    Ok(Some(f)) => f,
                    Ok(None) => break,
                    Err(e) => {
                        // poisoned framing: reply once, then close after flush
                        slots.push((
                            ci,
                            Slot::Ready(
                                "error",
                                proto::err_reply(&e.to_string()),
                                Stopwatch::started(),
                            ),
                        ));
                        self.conns[ci].open = false;
                        self.conns[ci].poisoned = true;
                        break;
                    }
                };
                let slot = self.dispatch(&frame, &mut pendings);
                slots.push((ci, slot));
            }
        }

        // batch every predict row decoded this tick onto one executor
        // scope; results come back in item order
        let flat: Vec<(usize, usize)> = pendings
            .iter()
            .enumerate()
            .flat_map(|(pi, p)| (0..p.rows.len()).map(move |ri| (pi, ri)))
            .collect();
        let t_count = self.ds.t();
        let preds: Vec<Vec<f64>> = {
            let cache = &self.cache;
            let pend = &pendings;
            executor::scoped_pool(flat.clone(), usize::MAX, move |(pi, ri)| {
                let p = &pend[pi];
                // model presence was checked (and counted) at dispatch
                let w = &cache.peek(p.ratio).expect("checked at dispatch").w;
                let row = &p.rows[ri];
                (0..t_count)
                    .map(|t| simd::dot_strided_skipz_f64(w, t_count, t, row))
                    .collect()
            })
        };
        let mut by_pending: Vec<Vec<Vec<f64>>> =
            pendings.iter().map(|p| Vec::with_capacity(p.rows.len())).collect();
        for ((pi, _ri), pred) in flat.into_iter().zip(preds) {
            by_pending[pi].push(pred);
        }

        // resolve slots into framed replies, in per-conn arrival order
        let processed = slots.len();
        for (ci, slot) in slots {
            let (op, reply, sw) = match slot {
                Slot::Ready(op, reply, sw) => (op, reply, sw),
                Slot::Predict(pi) => {
                    let rows = std::mem::take(&mut by_pending[pi]);
                    let result = Value::Arr(rows.into_iter().map(|p| Value::num_arr(&p)).collect());
                    ("predict", proto::ok_reply(result), pendings[pi].sw.clone())
                }
            };
            self.stats.record(op, sw.secs());
            self.requests += 1;
            let conn = &mut self.conns[ci];
            proto::encode_frame(reply.as_bytes(), &mut conn.outbox);
        }

        self.flush_all();
        self.conns.retain(|c| c.alive && (c.open || c.outpos < c.outbox.len()));
        Ok(processed)
    }

    // -- tick phases --------------------------------------------------------

    fn accept_new(&mut self) -> Result<()> {
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    stream.set_nonblocking(true).context("conn set_nonblocking")?;
                    stream.set_nodelay(true).ok();
                    self.conns.push(Conn {
                        stream,
                        dec: FrameDecoder::new(),
                        outbox: Vec::new(),
                        outpos: 0,
                        open: true,
                        poisoned: false,
                        alive: true,
                    });
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("accept"),
            }
        }
    }

    fn read_all(&mut self) {
        let mut buf = [0u8; 16 * 1024];
        for c in &mut self.conns {
            if !c.open || !c.alive {
                continue;
            }
            loop {
                match c.stream.read(&mut buf) {
                    Ok(0) => {
                        // EOF: no more requests; queued replies still flush
                        c.open = false;
                        break;
                    }
                    Ok(n) => c.dec.extend(&buf[..n]),
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        c.open = false;
                        c.alive = false;
                        break;
                    }
                }
            }
        }
    }

    fn flush_all(&mut self) {
        for c in &mut self.conns {
            if !c.alive {
                continue;
            }
            while c.outpos < c.outbox.len() {
                match c.stream.write(&c.outbox[c.outpos..]) {
                    Ok(0) => {
                        c.alive = false;
                        break;
                    }
                    Ok(n) => c.outpos += n,
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        c.alive = false;
                        break;
                    }
                }
            }
            if c.outpos == c.outbox.len() && c.outpos > 0 {
                c.outbox.clear();
                c.outpos = 0;
            }
        }
    }

    /// Decode + handle one frame; predicts defer to the tick's batch.
    fn dispatch(&mut self, frame: &[u8], pendings: &mut Vec<PendingPredict>) -> Slot {
        let sw = Stopwatch::started();
        let req = std::str::from_utf8(frame)
            .map_err(|_| "frame payload is not utf-8".to_string())
            .and_then(|s| json::parse(s).map_err(|e| format!("bad json: {e}")))
            .and_then(|v| Request::from_json(&v));
        let req = match req {
            Ok(r) => r,
            Err(e) => return Slot::Ready("error", proto::err_reply(&e), sw),
        };
        let op = req.op_name();
        match req {
            Request::Ping => Slot::Ready(op, proto::ok_reply(Value::Str("pong".into())), sw),
            Request::Info => Slot::Ready(op, proto::ok_reply(self.info()), sw),
            Request::Stats => Slot::Ready(op, proto::ok_reply(self.stats_json()), sw),
            Request::Shutdown => {
                self.shutdown.request();
                let v = Value::Obj(vec![("stopping".into(), Value::Bool(true))]);
                Slot::Ready(op, proto::ok_reply(v), sw)
            }
            Request::Fit { ratio } => {
                let reply = match self.handle_fit(ratio) {
                    Ok(v) => proto::ok_reply(v),
                    Err(e) => proto::err_reply(&e),
                };
                Slot::Ready(op, reply, sw)
            }
            Request::Cv { folds, seed } => {
                let reply = match self.handle_cv(folds, seed) {
                    Ok(v) => proto::ok_reply(v),
                    Err(e) => proto::err_reply(&e),
                };
                Slot::Ready(op, reply, sw)
            }
            Request::Predict { ratio, rows } => {
                if let Some(bad) = rows.iter().position(|r| r.len() != self.ds.d) {
                    let e = format!(
                        "row {bad} has {} values; this model expects d={}",
                        rows[bad].len(),
                        self.ds.d
                    );
                    return Slot::Ready(op, proto::err_reply(&e), sw);
                }
                // counted lookup: predicts are the cache's hit/miss story
                if self.cache.get(ratio).is_none() {
                    let fitted = self.cache.ratios();
                    let e = format!(
                        "no fitted model at ratio {ratio}; fitted ratios: {fitted:?}; \
                         fit it first with {{\"op\":\"fit\",\"ratio\":{ratio}}}"
                    );
                    return Slot::Ready(op, proto::err_reply(&e), sw);
                }
                pendings.push(PendingPredict { rows, sw, ratio });
                Slot::Predict(pendings.len() - 1)
            }
        }
    }

    // -- op handlers --------------------------------------------------------

    fn info(&self) -> Value {
        let n = match self.ds.uniform_n() {
            Some(n) => Value::Num(n as f64),
            None => Value::Null,
        };
        Value::Obj(vec![
            ("dataset".into(), Value::Str(self.ds.name.clone())),
            ("d".into(), Value::Num(self.ds.d as f64)),
            ("tasks".into(), Value::Num(self.ds.t() as f64)),
            ("n".into(), n),
            ("lam_max".into(), Value::Num(self.lam_max)),
            ("penalty".into(), Value::Str(self.opts.path.solve.penalty.name().into())),
            ("fitted".into(), Value::num_arr(&self.cache.ratios())),
            ("threads".into(), Value::Num(num_threads() as f64)),
        ])
    }

    fn stats_json(&self) -> Value {
        let endpoints = self
            .stats
            .rows()
            .into_iter()
            .map(|(op, count, p50, p95, p99)| {
                Value::Obj(vec![
                    ("op".into(), Value::Str(op.into())),
                    ("count".into(), Value::Num(count as f64)),
                    ("p50_ms".into(), Value::Num(p50)),
                    ("p95_ms".into(), Value::Num(p95)),
                    ("p99_ms".into(), Value::Num(p99)),
                ])
            })
            .collect();
        let (hits, misses) = self.cache.counters();
        Value::Obj(vec![
            ("uptime_secs".into(), Value::Num(self.uptime.secs())),
            ("requests".into(), Value::Num(self.requests as f64)),
            ("connections".into(), Value::Num(self.conns.len() as f64)),
            ("models".into(), Value::Num(self.cache.len() as f64)),
            ("cache_hits".into(), Value::Num(hits as f64)),
            ("cache_misses".into(), Value::Num(misses as f64)),
            ("executor_peak_active".into(), Value::Num(executor::peak_active() as f64)),
            ("executor_spawns".into(), Value::Num(executor::spawn_count() as f64)),
            ("endpoints".into(), Value::Arr(endpoints)),
        ])
    }

    /// Fit at `ratio`, warm-starting from the nearest cached model; a
    /// ratio already fitted returns its cached certificate unchanged.
    fn handle_fit(&mut self, ratio: f64) -> Result<Value, String> {
        if let Some(e) = self.cache.peek(ratio) {
            return Ok(fit_reply(e, true, None, 0.0));
        }
        let warm: Option<(f64, Vec<f64>)> =
            self.cache.nearest(ratio).map(|e| (e.ratio, e.w.clone()));
        let lam = ratio * self.lam_max;
        let sw = Stopwatch::started();
        let w0 = warm.as_ref().map(|(_, w)| w.as_slice());
        // single-λ fits solve the full (unscreened) problem — screening
        // is the path coordinator's cross-λ optimization; gap tolerance
        // and penalty come from the same SolveOptions the path uses
        let sr = match self.opts.path.solver {
            SolverKind::Fista => fista(&self.ds, lam, w0, &self.opts.path.solve),
            SolverKind::Bcd => bcd(&self.ds, lam, w0, &self.opts.path.solve),
        };
        let secs = sw.secs();
        if !sr.converged {
            return Err(format!(
                "fit at ratio {ratio} did not converge in {} iters (gap {:.3e}); \
                 raise max_iters or loosen tol",
                sr.iters, sr.gap
            ));
        }
        let entry = ModelEntry { ratio, lam, w: sr.w, obj: sr.obj, gap: sr.gap, iters: sr.iters };
        let reply = fit_reply(&entry, false, warm.as_ref().map(|(r, _)| *r), secs);
        self.cache.insert(entry);
        Ok(reply)
    }

    fn handle_cv(&mut self, folds: usize, seed: u64) -> Result<Value, String> {
        let cv = crate::coordinator::cv::cross_validate(&self.ds, &self.opts.path, folds, seed)
            .map_err(|e| format!("cv failed: {e:#}"))?;
        Ok(Value::Obj(vec![
            ("best_ratio".into(), Value::Num(cv.best_ratio)),
            ("best_index".into(), Value::Num(cv.best_index as f64)),
            ("ratios".into(), Value::num_arr(&cv.ratios)),
            ("mse".into(), Value::num_arr(&cv.mse)),
            ("col_ops".into(), Value::Num(cv.col_ops as f64)),
            ("total_secs".into(), Value::Num(cv.total_secs)),
        ]))
    }
}

fn fit_reply(e: &ModelEntry, cached: bool, warm_from: Option<f64>, secs: f64) -> Value {
    Value::Obj(vec![
        ("ratio".into(), Value::Num(e.ratio)),
        ("lam".into(), Value::Num(e.lam)),
        ("obj".into(), Value::Num(e.obj)),
        ("gap".into(), Value::Num(e.gap)),
        ("iters".into(), Value::Num(e.iters as f64)),
        ("cached".into(), Value::Bool(cached)),
        (
            "warm_from".into(),
            warm_from.map(Value::Num).unwrap_or(Value::Null),
        ),
        ("solve_secs".into(), Value::Num(secs)),
    ])
}
