//! Minimal JSON tree + recursive-descent parser + serializer (serde is
//! not vendored offline — this mirrors the in-tree substrate policy of
//! `cli` and `bench`).
//!
//! The serving protocol needs exactly one nontrivial property from its
//! encoding: **f64 round-trip fidelity**. Predictions travel as JSON
//! numbers; if serialize→parse perturbed even one ULP, the bit-parity
//! contract between `predict` and an offline [`crate::ops::forward`]
//! (DESIGN.md §15) would be unverifiable. Numbers are therefore printed
//! with Rust's `{:?}` float formatting — the shortest decimal string
//! that parses back to the identical f64 — and parsed with
//! `str::parse::<f64>()`, which is exact on such strings. Training data
//! is f32; an f32 → f64 → JSON → f64 → f32 trip is the identity.
//!
//! Objects keep insertion order (a `Vec` of pairs, not a map) so every
//! reply serializes deterministically.

/// A parsed JSON document.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any JSON number (always carried as f64)
    Num(f64),
    /// a string
    Str(String),
    /// an array
    Arr(Vec<Value>),
    /// an object, in insertion order
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Member `key` of an object (`None` for absent keys or non-objects).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The number, if this is a [`Value::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number as a usize, if it is a non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= 2f64.powi(53) => {
                Some(*x as usize)
            }
            _ => None,
        }
    }

    /// The number as a u64, if it is a non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_usize().map(|v| v as u64)
    }

    /// The string, if this is a [`Value::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// The boolean, if this is a [`Value::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is a [`Value::Arr`].
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// An array of numbers from an `&[f64]`.
    pub fn num_arr(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(x) => {
                if x.is_finite() {
                    // `{:?}` = shortest round-trip decimal (see module docs)
                    out.push_str(&format!("{x:?}"));
                } else {
                    // NaN/inf have no JSON encoding; null is the honest lie
                    out.push_str("null");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Nesting depth cap: a hostile frame of `[[[[…` must exhaust this
/// counter, not the parser's stack.
const MAX_DEPTH: usize = 64;

/// Parse one JSON document; trailing non-whitespace is an error.
pub fn parse(src: &str) -> Result<Value, String> {
    let mut p = Parser { b: src.as_bytes(), pos: 0 };
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(format!("trailing bytes at offset {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.pos) {
            if matches!(c, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&mut self) -> Result<u8, String> {
        self.skip_ws();
        self.b.get(self.pos).copied().ok_or_else(|| "unexpected end of input".into())
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek()? == c {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at offset {}", c as char, self.pos))
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.pos))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH}"));
        }
        match self.peek()? {
            b'n' => self.lit("null", Value::Null),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => {
                self.pos += 1;
                let mut items = Vec::new();
                if self.peek()? == b']' {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                loop {
                    items.push(self.value(depth + 1)?);
                    match self.peek()? {
                        b',' => self.pos += 1,
                        b']' => {
                            self.pos += 1;
                            return Ok(Value::Arr(items));
                        }
                        c => return Err(format!("expected ',' or ']', got '{}'", c as char)),
                    }
                }
            }
            b'{' => {
                self.pos += 1;
                let mut pairs = Vec::new();
                if self.peek()? == b'}' {
                    self.pos += 1;
                    return Ok(Value::Obj(pairs));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.expect(b':')?;
                    pairs.push((k, self.value(depth + 1)?));
                    match self.peek()? {
                        b',' => self.pos += 1,
                        b'}' => {
                            self.pos += 1;
                            return Ok(Value::Obj(pairs));
                        }
                        c => return Err(format!("expected ',' or '}}', got '{}'", c as char)),
                    }
                }
            }
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(format!("unexpected '{}' at offset {}", c as char, self.pos)),
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        while let Some(&c) = self.b.get(self.pos) {
            if matches!(c, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| "invalid utf-8 in number".to_string())?;
        s.parse::<f64>().map(Value::Num).map_err(|_| format!("bad number '{s}'"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self
                .b
                .get(self.pos)
                .ok_or_else(|| "unterminated string".to_string())?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self
                        .b
                        .get(self.pos)
                        .ok_or_else(|| "unterminated escape".to_string())?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair (non-BMP chars like emoji)
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.pos) == Some(&b'\\')
                                    && self.b.get(self.pos + 1) == Some(&b'u')
                                {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo.wrapping_sub(0xDC00));
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| "bad \\u escape".to_string())?);
                        }
                        e => return Err(format!("bad escape '\\{}'", e as char)),
                    }
                }
                c if c < 0x20 => return Err("raw control byte in string".into()),
                _ => {
                    // recover the full UTF-8 char starting at c
                    let start = self.pos - 1;
                    let width = utf8_width(c);
                    let end = start + width;
                    let s = self
                        .b
                        .get(start..end)
                        .and_then(|b| std::str::from_utf8(b).ok())
                        .ok_or_else(|| "invalid utf-8 in string".to_string())?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let s = self
            .b
            .get(self.pos..self.pos + 4)
            .and_then(|b| std::str::from_utf8(b).ok())
            .ok_or_else(|| "truncated \\u escape".to_string())?;
        self.pos += 4;
        u32::from_str_radix(s, 16).map_err(|_| format!("bad hex '{s}'"))
    }
}

fn utf8_width(lead: u8) -> usize {
    match lead {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_structures() {
        let src = r#"{"op":"predict","rows":[[1.5,-2.25],[0.0,3.0]],"tag":"a\"b","ok":true}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("op").unwrap().as_str(), Some("predict"));
        let back = parse(&v.to_json()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn f64_roundtrip_is_bit_exact() {
        for &x in &[1.0 / 3.0, 0.1f32 as f64, -2.2250738585072014e-308, 1e300, 5.0] {
            let s = Value::Num(x).to_json();
            let y = parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(x.to_bits(), y.to_bits(), "{s}");
        }
    }

    #[test]
    fn f32_survives_the_wire() {
        for &x in &[0.1f32, -3.75, 1.1754944e-38, 3.4028235e38] {
            let s = Value::Num(x as f64).to_json();
            let y = parse(&s).unwrap().as_f64().unwrap() as f32;
            assert_eq!(x.to_bits(), y.to_bits(), "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("[1,2").is_err());
        assert!(parse("01x").is_err());
        assert!(parse("{} trailing").is_err());
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&deep).is_err(), "depth cap");
    }

    #[test]
    fn escapes_control_chars() {
        let v = Value::Str("a\n\t\"\\\u{0001}".into());
        let s = v.to_json();
        assert_eq!(parse(&s).unwrap(), v);
    }
}
