//! Per-endpoint latency accounting for the `stats` op.
//!
//! Each endpoint keeps a bounded ring of recent latencies (seconds, via
//! [`crate::util::Stopwatch`] — the repro-lint `nondeterminism` rule
//! keeps raw `Instant` out of this layer) plus a lifetime request
//! counter. Percentiles are nearest-rank over the ring, so `stats` is
//! O(ring log ring) and the daemon's memory is bounded no matter how
//! long it runs.

/// Retained samples per endpoint (~the last 4096 requests).
const RING: usize = 4096;

/// Nearest-rank percentile of an **unsorted** sample set (`q` in [0,1]).
/// Returns 0.0 on an empty set. Shared with the load harness so the
/// server- and client-side reports agree on the estimator.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut s = samples.to_vec();
    s.sort_by(f64::total_cmp);
    let rank = ((q * s.len() as f64).ceil() as usize).clamp(1, s.len());
    s[rank - 1]
}

/// Latency ring for one endpoint.
#[derive(Debug)]
struct Endpoint {
    name: &'static str,
    ring: Vec<f64>,
    next: usize,
    count: u64,
}

impl Endpoint {
    fn record(&mut self, secs: f64) {
        self.count += 1;
        if self.ring.len() < RING {
            self.ring.push(secs);
        } else {
            self.ring[self.next] = secs;
            self.next = (self.next + 1) % RING;
        }
    }
}

/// All endpoint recorders; one per op name, created on first use.
#[derive(Debug, Default)]
pub struct ServeStats {
    endpoints: Vec<Endpoint>,
}

impl ServeStats {
    /// An empty recorder set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one request's latency under its op name.
    pub fn record(&mut self, op: &'static str, secs: f64) {
        match self.endpoints.iter_mut().find(|e| e.name == op) {
            Some(e) => e.record(secs),
            None => {
                let mut e = Endpoint { name: op, ring: Vec::new(), next: 0, count: 0 };
                e.record(secs);
                self.endpoints.push(e);
            }
        }
    }

    /// Per-endpoint summary rows: `(op, count, p50_ms, p95_ms, p99_ms)`.
    pub fn rows(&self) -> Vec<(&'static str, u64, f64, f64, f64)> {
        self.endpoints
            .iter()
            .map(|e| {
                (
                    e.name,
                    e.count,
                    percentile(&e.ring, 0.50) * 1e3,
                    percentile(&e.ring, 0.95) * 1e3,
                    percentile(&e.ring, 0.99) * 1e3,
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_percentiles() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 50.0);
        assert_eq!(percentile(&v, 0.95), 95.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 0.5), 2.0, "sorts internally");
    }

    #[test]
    fn ring_is_bounded_but_count_is_not() {
        let mut s = ServeStats::new();
        for i in 0..(RING as u64 + 100) {
            s.record("ping", i as f64);
        }
        let rows = s.rows();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].1, RING as u64 + 100);
    }
}
