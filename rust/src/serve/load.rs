//! RPS-ramp load harness for the serve daemon (`repro load`), in the
//! style of the Internet-Computer scalability suite: offer
//! `initial_rps`, step by `increment_rps` up to `target_rps`, hold each
//! level for `step_secs`, and declare saturation when the achieved
//! throughput falls below 90% of the offered rate. The report carries
//! per-level latency percentiles (client-side, send → reply) and the
//! saturation RPS — the numbers written to `BENCH_serve.json`.
//!
//! The generator is deterministic: a seeded [`Pcg64`] pre-builds a
//! small pool of predict payloads (random rows of the served model's
//! d), and the pacing clock is a [`Stopwatch`] (the repro-lint
//! `nondeterminism` rule applies to this file like any other library
//! code — wall-clock reads route through the timing substrate).
//!
//! Like the server, the client is single-threaded and nonblocking: it
//! keeps `conns` pipelined connections, each with a FIFO of send
//! timestamps — the protocol guarantees per-connection reply order, so
//! the head of the FIFO always matches the next decoded reply. The
//! `idle` hook runs once per pacing iteration; benches pass the
//! in-process server's `tick` so one thread can drive both ends
//! deterministically, the CLI passes a no-op.
//!
//! Besides the ramp there is a *soak* mode ([`run_soak`], `repro load
//! --soak RPS --duration S`): hold one fixed offered rate for a long
//! window and watch for latency **drift** — the slow p95 climb of a
//! leak or an unbounded queue that a short ramp level never sees. The
//! run is sliced into fixed windows; if the mean windowed p95 of the
//! second half exceeds the first half by more than the drift threshold,
//! the report flags `drifted` (saturation is flagged separately, same
//! 90%-of-offered rule as the ramp).

use super::json::{self, Value};
use super::proto::{self, FrameDecoder};
use super::stats::percentile;
use crate::util::{Pcg64, Stopwatch};
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Ramp configuration for [`run_load`].
#[derive(Debug, Clone)]
pub struct LoadOptions {
    /// first level's offered request rate (req/s)
    pub initial_rps: f64,
    /// offered-rate increase per level
    pub increment_rps: f64,
    /// stop ramping past this offered rate
    pub target_rps: f64,
    /// seconds to hold each level
    pub step_secs: f64,
    /// pipelined connections
    pub conns: usize,
    /// rows per predict request
    pub rows: usize,
    /// λ/λ_max of the model to predict against (must be fitted)
    pub ratio: f64,
    /// workload-generator seed
    pub seed: u64,
    /// feature dimension of generated rows (from the `info` op)
    pub d: usize,
}

impl Default for LoadOptions {
    fn default() -> Self {
        LoadOptions {
            initial_rps: 20.0,
            increment_rps: 20.0,
            target_rps: 100.0,
            step_secs: 2.0,
            conns: 4,
            rows: 4,
            ratio: 0.1,
            seed: 0,
            d: 0,
        }
    }
}

/// One ramp level's outcome.
#[derive(Debug, Clone)]
pub struct LevelStats {
    /// offered request rate
    pub offered_rps: f64,
    /// completed replies per second over the level window
    pub achieved_rps: f64,
    /// requests sent
    pub sent: u64,
    /// replies received
    pub completed: u64,
    /// `ok:false` replies + transport failures
    pub errors: u64,
    /// median latency, ms
    pub p50_ms: f64,
    /// 95th-percentile latency, ms
    pub p95_ms: f64,
    /// 99th-percentile latency, ms
    pub p99_ms: f64,
}

/// The full ramp report ([`run_load`]'s result, → `BENCH_serve.json`).
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// per-level outcomes, in ramp order
    pub levels: Vec<LevelStats>,
    /// achieved RPS at the first saturated level (None: never saturated)
    pub saturation_rps: Option<f64>,
    /// best achieved RPS across levels
    pub max_achieved_rps: f64,
    /// total requests completed across the ramp
    pub total_completed: u64,
    /// the options the ramp ran with
    pub opts: LoadOptions,
}

impl LoadReport {
    /// JSON form (the `levels`/`saturation_rps` schema of
    /// `BENCH_serve.json`).
    pub fn to_json(&self, provisional: bool) -> Value {
        let levels = self
            .levels
            .iter()
            .map(|l| {
                Value::Obj(vec![
                    ("offered_rps".into(), Value::Num(l.offered_rps)),
                    ("achieved_rps".into(), Value::Num(l.achieved_rps)),
                    ("sent".into(), Value::Num(l.sent as f64)),
                    ("completed".into(), Value::Num(l.completed as f64)),
                    ("errors".into(), Value::Num(l.errors as f64)),
                    ("p50_ms".into(), Value::Num(l.p50_ms)),
                    ("p95_ms".into(), Value::Num(l.p95_ms)),
                    ("p99_ms".into(), Value::Num(l.p99_ms)),
                ])
            })
            .collect();
        Value::Obj(vec![
            ("bench".into(), Value::Str("serve".into())),
            ("provisional".into(), Value::Bool(provisional)),
            ("d".into(), Value::Num(self.opts.d as f64)),
            ("rows_per_request".into(), Value::Num(self.opts.rows as f64)),
            ("ratio".into(), Value::Num(self.opts.ratio)),
            ("conns".into(), Value::Num(self.opts.conns as f64)),
            ("step_secs".into(), Value::Num(self.opts.step_secs)),
            (
                "saturation_rps".into(),
                self.saturation_rps.map(Value::Num).unwrap_or(Value::Null),
            ),
            ("saturated".into(), Value::Bool(self.saturation_rps.is_some())),
            ("max_achieved_rps".into(), Value::Num(self.max_achieved_rps)),
            ("total_completed".into(), Value::Num(self.total_completed as f64)),
            ("levels".into(), Value::Arr(levels)),
        ])
    }
}

struct LoadConn {
    stream: TcpStream,
    dec: FrameDecoder,
    out: Vec<u8>,
    outpos: usize,
    /// send timestamps of in-flight requests (replies are in-order)
    inflight: VecDeque<f64>,
}

/// Run the ramp against a serve daemon at `addr`. `idle` runs once per
/// pacing iteration — pass the in-process server's `tick` to co-drive
/// client and daemon on one thread (benches/tests), or a no-op when the
/// daemon is a separate process (the CLI).
pub fn run_load(
    addr: &str,
    opts: &LoadOptions,
    idle: &mut dyn FnMut() -> Result<()>,
) -> Result<LoadReport> {
    anyhow::ensure!(opts.d > 0, "LoadOptions.d must be set (from the info op)");
    anyhow::ensure!(opts.conns > 0 && opts.rows > 0, "conns and rows must be >= 1");
    let payloads = build_payloads(opts.d, opts.rows, opts.ratio, opts.seed);
    let mut conns = connect_pool(addr, opts.conns)?;

    let clock = Stopwatch::started();
    let mut levels = Vec::new();
    let mut saturation_rps = None;
    let mut total_completed = 0u64;
    let mut offered = opts.initial_rps;
    let mut payload_rr = 0usize;
    let mut conn_rr = 0usize;

    while offered <= opts.target_rps + 1e-9 {
        let t0 = clock.secs();
        let mut sent = 0u64;
        let mut completed = 0u64;
        let mut errors = 0u64;
        let mut samples: Vec<(f64, f64)> = Vec::new();

        // hold the level, then grace-drain stragglers (up to step_secs)
        let mut draining = false;
        loop {
            let now = clock.secs() - t0;
            if !draining && now >= opts.step_secs {
                draining = true;
            }
            if draining {
                let outstanding: usize = conns.iter().map(|c| c.inflight.len()).sum();
                if outstanding == 0 || now >= 2.0 * opts.step_secs {
                    break;
                }
            } else {
                // open-loop pacing: sends due so far at the offered rate
                let due = (now * offered) as u64;
                while sent < due {
                    let c = &mut conns[conn_rr % conns.len()];
                    conn_rr += 1;
                    proto::encode_frame(
                        payloads[payload_rr % payloads.len()].as_bytes(),
                        &mut c.out,
                    );
                    payload_rr += 1;
                    c.inflight.push_back(clock.secs());
                    sent += 1;
                }
            }
            pump(&mut conns, &clock, &mut samples, &mut completed, &mut errors)?;
            idle()?;
            std::thread::sleep(Duration::from_micros(200));
        }

        // level wall time includes the drain: a saturated server either
        // stretches the drain or strands replies — both depress this
        let elapsed = (clock.secs() - t0).max(1e-9);
        let achieved = completed as f64 / elapsed;
        total_completed += completed;
        let latencies: Vec<f64> = samples.iter().map(|s| s.1).collect();
        levels.push(LevelStats {
            offered_rps: offered,
            achieved_rps: achieved,
            sent,
            completed,
            errors,
            p50_ms: percentile(&latencies, 0.50) * 1e3,
            p95_ms: percentile(&latencies, 0.95) * 1e3,
            p99_ms: percentile(&latencies, 0.99) * 1e3,
        });
        if achieved < 0.9 * offered {
            saturation_rps = Some(achieved);
            break;
        }
        offered += opts.increment_rps;
        if opts.increment_rps <= 0.0 {
            break;
        }
    }

    let max_achieved_rps =
        levels.iter().map(|l| l.achieved_rps).fold(0.0f64, f64::max);
    Ok(LoadReport {
        levels,
        saturation_rps,
        max_achieved_rps,
        total_completed,
        opts: opts.clone(),
    })
}

/// Deterministic request pool: a few distinct predict payloads with
/// seeded-random rows (values in [-1, 1]).
fn build_payloads(d: usize, rows: usize, ratio: f64, seed: u64) -> Vec<String> {
    let mut rng = Pcg64::new(seed);
    (0..8)
        .map(|_| {
            let rows: Vec<Value> = (0..rows)
                .map(|_| {
                    Value::Arr(
                        (0..d)
                            // f32 images so the wire trip is exact
                            .map(|_| Value::Num(rng.uniform_in(-1.0, 1.0) as f32 as f64))
                            .collect(),
                    )
                })
                .collect();
            Value::Obj(vec![
                ("op".into(), Value::Str("predict".into())),
                ("ratio".into(), Value::Num(ratio)),
                ("rows".into(), Value::Arr(rows)),
            ])
            .to_json()
        })
        .collect()
}

/// Open `n` nonblocking pipelined connections to the daemon.
fn connect_pool(addr: &str, n: usize) -> Result<Vec<LoadConn>> {
    let mut conns = Vec::with_capacity(n);
    for _ in 0..n {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nonblocking(true).context("set_nonblocking")?;
        stream.set_nodelay(true).ok();
        conns.push(LoadConn {
            stream,
            dec: FrameDecoder::new(),
            out: Vec::new(),
            outpos: 0,
            inflight: VecDeque::new(),
        });
    }
    Ok(conns)
}

/// Flush writes, read replies, account latencies/errors. Each completed
/// reply appends `(completed_at, latency)` in clock seconds — the ramp
/// uses only the latency, the soak's drift windows also need the time.
fn pump(
    conns: &mut [LoadConn],
    clock: &Stopwatch,
    samples: &mut Vec<(f64, f64)>,
    completed: &mut u64,
    errors: &mut u64,
) -> Result<()> {
    let mut buf = [0u8; 16 * 1024];
    for c in conns.iter_mut() {
        // writes
        while c.outpos < c.out.len() {
            match c.stream.write(&c.out[c.outpos..]) {
                Ok(0) => anyhow::bail!("server closed the connection mid-write"),
                Ok(n) => c.outpos += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("write"),
            }
        }
        if c.outpos == c.out.len() && c.outpos > 0 {
            c.out.clear();
            c.outpos = 0;
        }
        // reads
        loop {
            match c.stream.read(&mut buf) {
                Ok(0) => {
                    if !c.inflight.is_empty() {
                        anyhow::bail!("server closed with {} replies outstanding", c.inflight.len());
                    }
                    break;
                }
                Ok(n) => c.dec.extend(&buf[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("read"),
            }
        }
        // decode
        while let Some(payload) = c
            .dec
            .next(proto::DEFAULT_MAX_FRAME)
            .map_err(|e| anyhow::anyhow!("reply framing: {e}"))?
        {
            let sent_at = c
                .inflight
                .pop_front()
                .ok_or_else(|| anyhow::anyhow!("reply with no request in flight"))?;
            let done_at = clock.secs();
            samples.push((done_at, done_at - sent_at));
            *completed += 1;
            let ok = json::parse(std::str::from_utf8(&payload).unwrap_or("{}"))
                .ok()
                .and_then(|v| v.get("ok").and_then(Value::as_bool))
                .unwrap_or(false);
            if !ok {
                *errors += 1;
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// soak mode: fixed rate, long hold, latency-drift detection
// ---------------------------------------------------------------------------

/// Configuration for [`run_soak`] (`repro load --soak RPS --duration S`).
#[derive(Debug, Clone)]
pub struct SoakOptions {
    /// the one fixed offered rate (req/s)
    pub rps: f64,
    /// seconds to hold it
    pub duration_secs: f64,
    /// seconds per drift window (the p95 sampling grain)
    pub window_secs: f64,
    /// `drifted` when mean p95 of the run's second half exceeds the
    /// first half by more than this factor
    pub drift_threshold: f64,
    /// pipelined connections
    pub conns: usize,
    /// rows per predict request
    pub rows: usize,
    /// λ/λ_max of the model to predict against (must be fitted)
    pub ratio: f64,
    /// workload-generator seed
    pub seed: u64,
    /// feature dimension of generated rows (from the `info` op)
    pub d: usize,
}

impl Default for SoakOptions {
    fn default() -> Self {
        SoakOptions {
            rps: 50.0,
            duration_secs: 30.0,
            window_secs: 5.0,
            drift_threshold: 1.5,
            conns: 4,
            rows: 4,
            ratio: 0.1,
            seed: 0,
            d: 0,
        }
    }
}

/// One drift window of a soak run.
#[derive(Debug, Clone)]
pub struct SoakWindow {
    /// window start, seconds since the soak began
    pub t0_secs: f64,
    /// replies completed inside the window
    pub completed: u64,
    /// windowed 95th-percentile latency, ms
    pub p95_ms: f64,
}

/// [`run_soak`]'s result (→ `BENCH_soak.json`).
#[derive(Debug, Clone)]
pub struct SoakReport {
    /// the fixed offered rate
    pub offered_rps: f64,
    /// completed replies per second over the whole run (drain included)
    pub achieved_rps: f64,
    /// requests sent
    pub sent: u64,
    /// replies received
    pub completed: u64,
    /// `ok:false` replies + transport failures
    pub errors: u64,
    /// whole-run median latency, ms
    pub p50_ms: f64,
    /// whole-run 95th-percentile latency, ms
    pub p95_ms: f64,
    /// whole-run 99th-percentile latency, ms
    pub p99_ms: f64,
    /// per-window p95 series, in time order (empty windows skipped)
    pub windows: Vec<SoakWindow>,
    /// mean windowed p95 of the second half over the first half
    pub drift_ratio: f64,
    /// `drift_ratio > drift_threshold`: latency is climbing under a
    /// constant load — a leak or an unbounded queue, not saturation
    pub drifted: bool,
    /// achieved < 90% of offered (the ramp's saturation rule)
    pub saturated: bool,
    /// the options the soak ran with
    pub opts: SoakOptions,
}

impl SoakReport {
    /// JSON form (the schema of `BENCH_soak.json`).
    pub fn to_json(&self, provisional: bool) -> Value {
        let windows = self
            .windows
            .iter()
            .map(|w| {
                Value::Obj(vec![
                    ("t0_secs".into(), Value::Num(w.t0_secs)),
                    ("completed".into(), Value::Num(w.completed as f64)),
                    ("p95_ms".into(), Value::Num(w.p95_ms)),
                ])
            })
            .collect();
        Value::Obj(vec![
            ("bench".into(), Value::Str("soak".into())),
            ("provisional".into(), Value::Bool(provisional)),
            ("d".into(), Value::Num(self.opts.d as f64)),
            ("rows_per_request".into(), Value::Num(self.opts.rows as f64)),
            ("ratio".into(), Value::Num(self.opts.ratio)),
            ("conns".into(), Value::Num(self.opts.conns as f64)),
            ("duration_secs".into(), Value::Num(self.opts.duration_secs)),
            ("window_secs".into(), Value::Num(self.opts.window_secs)),
            ("offered_rps".into(), Value::Num(self.offered_rps)),
            ("achieved_rps".into(), Value::Num(self.achieved_rps)),
            ("sent".into(), Value::Num(self.sent as f64)),
            ("completed".into(), Value::Num(self.completed as f64)),
            ("errors".into(), Value::Num(self.errors as f64)),
            ("p50_ms".into(), Value::Num(self.p50_ms)),
            ("p95_ms".into(), Value::Num(self.p95_ms)),
            ("p99_ms".into(), Value::Num(self.p99_ms)),
            ("drift_ratio".into(), Value::Num(self.drift_ratio)),
            ("drift_threshold".into(), Value::Num(self.opts.drift_threshold)),
            ("drifted".into(), Value::Bool(self.drifted)),
            ("saturated".into(), Value::Bool(self.saturated)),
            ("windows".into(), Value::Arr(windows)),
        ])
    }
}

/// Hold one fixed offered rate for the soak duration, then fold the
/// completion stream into drift windows (module docs). Same client
/// machinery and `idle` contract as [`run_load`].
pub fn run_soak(
    addr: &str,
    opts: &SoakOptions,
    idle: &mut dyn FnMut() -> Result<()>,
) -> Result<SoakReport> {
    anyhow::ensure!(opts.d > 0, "SoakOptions.d must be set (from the info op)");
    anyhow::ensure!(opts.conns > 0 && opts.rows > 0, "conns and rows must be >= 1");
    anyhow::ensure!(opts.rps > 0.0, "--soak needs an offered rate > 0");
    anyhow::ensure!(opts.duration_secs > 0.0, "--duration must be > 0");
    anyhow::ensure!(opts.window_secs > 0.0, "--window must be > 0");
    let payloads = build_payloads(opts.d, opts.rows, opts.ratio, opts.seed);
    let mut conns = connect_pool(addr, opts.conns)?;

    let clock = Stopwatch::started();
    let mut sent = 0u64;
    let mut completed = 0u64;
    let mut errors = 0u64;
    let mut samples: Vec<(f64, f64)> = Vec::new();
    let mut payload_rr = 0usize;
    let mut conn_rr = 0usize;

    // hold the rate, then grace-drain stragglers (up to one window)
    let mut draining = false;
    loop {
        let now = clock.secs();
        if !draining && now >= opts.duration_secs {
            draining = true;
        }
        if draining {
            let outstanding: usize = conns.iter().map(|c| c.inflight.len()).sum();
            if outstanding == 0 || now >= opts.duration_secs + opts.window_secs {
                break;
            }
        } else {
            let due = (now * opts.rps) as u64;
            while sent < due {
                let c = &mut conns[conn_rr % conns.len()];
                conn_rr += 1;
                proto::encode_frame(
                    payloads[payload_rr % payloads.len()].as_bytes(),
                    &mut c.out,
                );
                payload_rr += 1;
                c.inflight.push_back(clock.secs());
                sent += 1;
            }
        }
        pump(&mut conns, &clock, &mut samples, &mut completed, &mut errors)?;
        idle()?;
        std::thread::sleep(Duration::from_micros(200));
    }

    let elapsed = clock.secs().max(1e-9);
    let latencies: Vec<f64> = samples.iter().map(|s| s.1).collect();
    let (windows, drift_ratio) = drift_windows(&samples, opts.window_secs);
    let achieved = completed as f64 / elapsed;
    Ok(SoakReport {
        offered_rps: opts.rps,
        achieved_rps: achieved,
        sent,
        completed,
        errors,
        p50_ms: percentile(&latencies, 0.50) * 1e3,
        p95_ms: percentile(&latencies, 0.95) * 1e3,
        p99_ms: percentile(&latencies, 0.99) * 1e3,
        windows,
        drift_ratio,
        drifted: drift_ratio > opts.drift_threshold,
        saturated: achieved < 0.9 * opts.rps,
        opts: opts.clone(),
    })
}

/// Slice `(completed_at, latency)` samples into fixed windows and
/// compare the halves: ratio of the second half's mean windowed p95 to
/// the first half's. 1.0 (no drift) when fewer than two non-empty
/// windows exist or the first half saw no latency.
fn drift_windows(samples: &[(f64, f64)], window_secs: f64) -> (Vec<SoakWindow>, f64) {
    let mut windows: Vec<SoakWindow> = Vec::new();
    if samples.is_empty() {
        return (windows, 1.0);
    }
    let end = samples.iter().map(|s| s.0).fold(0.0f64, f64::max);
    let n_win = (end / window_secs).floor() as usize + 1;
    for w in 0..n_win {
        let (lo, hi) = (w as f64 * window_secs, (w as f64 + 1.0) * window_secs);
        let lats: Vec<f64> = samples
            .iter()
            .filter(|s| s.0 >= lo && s.0 < hi)
            .map(|s| s.1)
            .collect();
        if lats.is_empty() {
            continue;
        }
        windows.push(SoakWindow {
            t0_secs: lo,
            completed: lats.len() as u64,
            p95_ms: percentile(&lats, 0.95) * 1e3,
        });
    }
    if windows.len() < 2 {
        return (windows, 1.0);
    }
    let p95s: Vec<f64> = windows.iter().map(|w| w.p95_ms).collect();
    let half = p95s.len() / 2;
    let first = crate::linalg::simd::mean_serial_f64(&p95s[..half]);
    let last = crate::linalg::simd::mean_serial_f64(&p95s[half..]);
    let ratio = if first > 0.0 { last / first } else { 1.0 };
    (windows, ratio)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_samples(spec: &[(f64, f64, u64)]) -> Vec<(f64, f64)> {
        // (window_center, latency, count) triples → flat samples
        let mut out = Vec::new();
        for &(t, lat, n) in spec {
            for k in 0..n {
                out.push((t + k as f64 * 1e-3, lat));
            }
        }
        out
    }

    #[test]
    fn flat_latency_does_not_drift() {
        let s = fake_samples(&[
            (0.5, 0.010, 20),
            (1.5, 0.010, 20),
            (2.5, 0.010, 20),
            (3.5, 0.010, 20),
        ]);
        let (windows, ratio) = drift_windows(&s, 1.0);
        assert_eq!(windows.len(), 4);
        assert!((ratio - 1.0).abs() < 1e-12, "flat p95 must give ratio 1 (got {ratio})");
    }

    #[test]
    fn climbing_latency_drifts() {
        // p95 doubles twice across the run: second half ≫ 1.5× first
        let s = fake_samples(&[
            (0.5, 0.010, 20),
            (1.5, 0.012, 20),
            (2.5, 0.030, 20),
            (3.5, 0.040, 20),
        ]);
        let (windows, ratio) = drift_windows(&s, 1.0);
        assert_eq!(windows.len(), 4);
        assert!(ratio > 1.5, "climbing p95 must trip the 1.5 threshold (got {ratio})");
    }

    #[test]
    fn sparse_runs_fall_back_to_no_drift() {
        let (w, ratio) = drift_windows(&[], 1.0);
        assert!(w.is_empty());
        assert_eq!(ratio, 1.0);
        let (w, ratio) = drift_windows(&[(0.1, 0.01), (0.2, 0.01)], 1.0);
        assert_eq!(w.len(), 1, "one non-empty window");
        assert_eq!(ratio, 1.0, "a single window cannot drift");
    }

    #[test]
    fn empty_windows_are_skipped_not_zeroed() {
        // a gap in completions (stalled server) must not fabricate a
        // zero-latency window that would mask drift on either side
        let s = fake_samples(&[(0.5, 0.010, 20), (4.5, 0.030, 20)]);
        let (windows, ratio) = drift_windows(&s, 1.0);
        assert_eq!(windows.len(), 2);
        assert_eq!(windows[0].t0_secs, 0.0);
        assert_eq!(windows[1].t0_secs, 4.0);
        assert!(ratio > 1.5);
    }
}
