//! Wire protocol for `repro serve` (DESIGN.md §15).
//!
//! A frame is `[u32 big-endian payload length][payload]` where the
//! payload is one UTF-8 JSON document. Requests are objects with an
//! `"op"` member; replies are `{"ok":true,"result":…}` or
//! `{"ok":false,"error":"…"}`, written strictly in per-connection
//! request order (clients may pipeline).
//!
//! The length prefix is the protocol's whole failure surface, so it is
//! policed at the seam: a frame longer than the server's `max_frame`
//! yields an actionable error reply and the connection is closed (the
//! stream offset can no longer be trusted); a truncated frame is simply
//! an incomplete read — the decoder waits for more bytes, and a peer
//! that hangs up mid-frame costs nothing but the buffer.

use super::json::{self, Value};
use std::io::{Read, Write};

/// Default cap on a single frame's payload (8 MiB — a 1000-row predict
/// batch at d=10⁵ needs chunking anyway; see `--max-frame-mb`).
pub const DEFAULT_MAX_FRAME: usize = 8 << 20;

/// Bytes in the length prefix.
pub const HEADER_LEN: usize = 4;

/// Append one frame (header + payload) to `out`.
pub fn encode_frame(payload: &[u8], out: &mut Vec<u8>) {
    debug_assert!(payload.len() <= u32::MAX as usize);
    out.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    out.extend_from_slice(payload);
}

/// Why a decoder rejected its stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// The declared payload length exceeds the server's cap. The
    /// connection must be closed: the next header offset is unknowable.
    Oversize {
        /// declared payload length
        declared: usize,
        /// the cap it exceeded
        max: usize,
    },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Oversize { declared, max } => write!(
                f,
                "frame of {declared} bytes exceeds the {max}-byte limit; split the \
                 request (e.g. fewer predict rows per frame) or restart the server \
                 with a larger --max-frame-mb"
            ),
        }
    }
}

/// Incremental frame decoder over an arbitrary byte stream.
#[derive(Debug, Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    pos: usize,
}

impl FrameDecoder {
    /// An empty decoder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed freshly-read bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Pop the next complete payload, if one is buffered. `Ok(None)`
    /// means "incomplete — feed more bytes"; [`FrameError::Oversize`]
    /// poisons the stream (close the connection).
    pub fn next(&mut self, max_frame: usize) -> Result<Option<Vec<u8>>, FrameError> {
        let avail = self.buf.len() - self.pos;
        if avail < HEADER_LEN {
            self.compact();
            return Ok(None);
        }
        let h = &self.buf[self.pos..self.pos + HEADER_LEN];
        let declared = u32::from_be_bytes([h[0], h[1], h[2], h[3]]) as usize;
        if declared > max_frame {
            return Err(FrameError::Oversize { declared, max: max_frame });
        }
        if avail < HEADER_LEN + declared {
            self.compact();
            return Ok(None);
        }
        let start = self.pos + HEADER_LEN;
        let payload = self.buf[start..start + declared].to_vec();
        self.pos = start + declared;
        self.compact();
        Ok(Some(payload))
    }

    /// True if undecoded bytes remain (a partial frame in flight).
    pub fn has_partial(&self) -> bool {
        self.pos < self.buf.len()
    }

    fn compact(&mut self) {
        if self.pos > 0 && (self.pos == self.buf.len() || self.pos >= 64 * 1024) {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }
}

// ---------------------------------------------------------------------------
// requests and replies
// ---------------------------------------------------------------------------

/// A decoded client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// liveness probe; replies `"pong"`
    Ping,
    /// dataset + model-cache metadata (the client's d/T discovery call)
    Info,
    /// predictions for `rows` under the model fitted at `ratio` (λ/λ_max)
    Predict {
        /// λ/λ_max of the cached model to apply
        ratio: f64,
        /// row-major input rows, each of length d (f32 images as f64)
        rows: Vec<Vec<f32>>,
    },
    /// fit (or return the cached) model at `ratio`, warm-starting from
    /// the nearest fitted neighbor
    Fit {
        /// λ/λ_max to fit
        ratio: f64,
    },
    /// k-fold CV over the server's configured grid
    Cv {
        /// fold count
        folds: usize,
        /// fold-split seed
        seed: u64,
    },
    /// serving statistics (latency percentiles, cache + executor counters)
    Stats,
    /// stop accepting, drain in-flight work, exit the serve loop
    Shutdown,
}

impl Request {
    /// Endpoint label used for per-op latency stats.
    pub fn op_name(&self) -> &'static str {
        match self {
            Request::Ping => "ping",
            Request::Info => "info",
            Request::Predict { .. } => "predict",
            Request::Fit { .. } => "fit",
            Request::Cv { .. } => "cv",
            Request::Stats => "stats",
            Request::Shutdown => "shutdown",
        }
    }

    /// Decode a request object; errors name the missing/invalid member.
    pub fn from_json(v: &Value) -> Result<Request, String> {
        let op = v
            .get("op")
            .and_then(Value::as_str)
            .ok_or_else(|| "request must be an object with a string \"op\"".to_string())?;
        match op {
            "ping" => Ok(Request::Ping),
            "info" => Ok(Request::Info),
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown),
            "predict" => {
                let ratio = need_ratio(v)?;
                let rows = v
                    .get("rows")
                    .and_then(Value::as_arr)
                    .ok_or_else(|| "predict needs \"rows\": [[...], ...]".to_string())?;
                let rows: Result<Vec<Vec<f32>>, String> = rows
                    .iter()
                    .map(|row| {
                        row.as_arr()
                            .ok_or_else(|| "each row must be an array of numbers".to_string())?
                            .iter()
                            .map(|x| {
                                x.as_f64()
                                    .map(|v| v as f32)
                                    .ok_or_else(|| "each row must be an array of numbers".into())
                            })
                            .collect()
                    })
                    .collect();
                Ok(Request::Predict { ratio, rows: rows? })
            }
            "fit" => Ok(Request::Fit { ratio: need_ratio(v)? }),
            "cv" => {
                let folds = v.get("folds").and_then(Value::as_usize).unwrap_or(5);
                let seed = v.get("seed").and_then(Value::as_u64).unwrap_or(0);
                if folds < 2 {
                    return Err("cv needs \"folds\" >= 2".into());
                }
                Ok(Request::Cv { folds, seed })
            }
            other => Err(format!(
                "unknown op '{other}' (ping|info|predict|fit|cv|stats|shutdown)"
            )),
        }
    }
}

fn need_ratio(v: &Value) -> Result<f64, String> {
    let r = v
        .get("ratio")
        .and_then(Value::as_f64)
        .ok_or_else(|| "missing numeric \"ratio\" (λ/λ_max)".to_string())?;
    if r.is_finite() && r > 0.0 && r <= 1.0 {
        Ok(r)
    } else {
        Err(format!("\"ratio\" must be in (0, 1], got {r}"))
    }
}

/// Serialize a success reply.
pub fn ok_reply(result: Value) -> String {
    Value::Obj(vec![("ok".into(), Value::Bool(true)), ("result".into(), result)]).to_json()
}

/// Serialize an error reply.
pub fn err_reply(msg: &str) -> String {
    Value::Obj(vec![
        ("ok".into(), Value::Bool(false)),
        ("error".into(), Value::Str(msg.into())),
    ])
    .to_json()
}

// ---------------------------------------------------------------------------
// blocking client side (tests, `repro load`, the CLI shutdown helper)
// ---------------------------------------------------------------------------

/// Write one frame to a blocking stream.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(HEADER_LEN + payload.len());
    encode_frame(payload, &mut buf);
    w.write_all(&buf)
}

/// Read one complete frame from a blocking stream.
pub fn read_frame(r: &mut impl Read, max_frame: usize) -> std::io::Result<Vec<u8>> {
    let mut h = [0u8; HEADER_LEN];
    r.read_exact(&mut h)?;
    let declared = u32::from_be_bytes(h) as usize;
    if declared > max_frame {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            FrameError::Oversize { declared, max: max_frame }.to_string(),
        ));
    }
    let mut payload = vec![0u8; declared];
    r.read_exact(&mut payload)?;
    Ok(payload)
}

/// One blocking request/reply round trip; errors carry the server's
/// `"error"` text when the reply is `ok:false`.
pub fn call(stream: &mut std::net::TcpStream, req: &Value) -> anyhow::Result<Value> {
    write_frame(stream, req.to_json().as_bytes())?;
    let reply = read_frame(stream, DEFAULT_MAX_FRAME)?;
    let v = json::parse(std::str::from_utf8(&reply)?)
        .map_err(|e| anyhow::anyhow!("bad reply json: {e}"))?;
    match v.get("ok").and_then(Value::as_bool) {
        Some(true) => Ok(v.get("result").cloned().unwrap_or(Value::Null)),
        Some(false) => anyhow::bail!(
            "server error: {}",
            v.get("error").and_then(Value::as_str).unwrap_or("unknown")
        ),
        None => anyhow::bail!("malformed reply (no \"ok\" member)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decoder_reassembles_split_frames() {
        let mut wire = Vec::new();
        encode_frame(b"{\"op\":\"ping\"}", &mut wire);
        encode_frame(b"{\"op\":\"info\"}", &mut wire);
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        for chunk in wire.chunks(3) {
            dec.extend(chunk);
            while let Some(p) = dec.next(1024).unwrap() {
                got.push(String::from_utf8(p).unwrap());
            }
        }
        assert_eq!(got, vec!["{\"op\":\"ping\"}", "{\"op\":\"info\"}"]);
        assert!(!dec.has_partial());
    }

    #[test]
    fn oversize_header_poisons_the_stream() {
        let mut dec = FrameDecoder::new();
        dec.extend(&(1_000_000u32).to_be_bytes());
        let err = dec.next(1024).unwrap_err();
        assert_eq!(err, FrameError::Oversize { declared: 1_000_000, max: 1024 });
        assert!(err.to_string().contains("--max-frame-mb"), "{err}");
    }

    #[test]
    fn requests_parse_and_validate() {
        let v = crate::serve::json::parse(
            r#"{"op":"predict","ratio":0.5,"rows":[[1.0,2.0]]}"#,
        )
        .unwrap();
        assert!(matches!(Request::from_json(&v).unwrap(), Request::Predict { .. }));
        let v = crate::serve::json::parse(r#"{"op":"fit","ratio":1.5}"#).unwrap();
        assert!(Request::from_json(&v).unwrap_err().contains("(0, 1]"));
        let v = crate::serve::json::parse(r#"{"op":"nope"}"#).unwrap();
        assert!(Request::from_json(&v).unwrap_err().contains("unknown op"));
    }
}
