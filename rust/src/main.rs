//! `repro` — the mtfl-dpc command-line launcher.
//!
//! Subcommands (all experiment output formats match EXPERIMENTS.md):
//!   table1    reproduce Table 1 (solver vs DPC+solver timing + speedup)
//!   fig1      reproduce Figure 1 (rejection ratios, synthetic)
//!   fig2      reproduce Figure 2 (rejection ratios, simulated real sets)
//!   ablation  ABL1/ABL2 screener ablations
//!   path      run one λ-path on a chosen dataset (in-RAM or out-of-core)
//!   cv        k-fold cross-validation over the λ grid (screened)
//!   stability stability selection over half-subsamples (screened)
//!   gen       generate a dataset and save it as .mtd
//!   shard     convert a dataset to the sharded .mtd3 layout (out-of-core)
//!   serve     long-lived solve/predict daemon (warm-model cache, TCP)
//!   load      RPS-ramp / fixed-rate-soak load harness against a daemon
//!   worker    shard-sweep worker for a distributed path coordinator
//!   info      print the AOT artifact manifest

use anyhow::{Context, Result};
use mtfl_dpc::cli::Args;
use mtfl_dpc::coordinator::path::{run_path, EngineKind, PathOptions, ScreenerKind, SolverKind};
use mtfl_dpc::coordinator::report;
use mtfl_dpc::experiments::{self, Scale};
use mtfl_dpc::runtime::AotEngine;
use std::path::PathBuf;

const USAGE: &str = "usage: \
repro <table1|fig1|fig2|ablation|path|cv|stability|gen|shard|serve|load|worker|info> [options]

common options:
  --scale quick|default|paper   experiment scale (default: default)
  --engine exact|aot            compute engine (default: exact)
  --artifacts DIR               AOT artifact dir (default: artifacts)

path / cv / stability options:
  --dataset synth1|synth2|animal|tdt2|adni   (default synth1)
  --d N            feature dimension for synthetic sets
  --grid K         lambda-grid length (default from scale)
  --screener dpc|gap|cs|oneshot|none
  --dynamic-every K   re-screen inside the solver every K epochs on the
                      live duality-gap ball (0 = off, default)
  --solver fista|bcd
  --penalty l21|sgl|gowl   row-structured penalty (default l21, the paper's
                      norm; sgl/gowl require --screener gap|none + fista)
  --penalty-alpha A   sgl mixing weight in [0,1) (default 0.5)
  --penalty-gamma G   gowl weight decay, >= 0 (default 1.0)
  --seed S

path options (storage backend):
  --in FILE           run on a saved dataset (.mtd loads in RAM; .mtd3
                      runs out-of-core with screen-before-load)
  --backend auto|dense|csc|sharded   storage backend (default auto);
                      'sharded' shards the dataset to a temp file and
                      runs it out-of-core — the zero-setup demo of the
                      d >> RAM screen-before-load pipeline
  --shard-bytes N     target bytes per column block (default 4 MiB)
  --cache-mb M        block-cache budget for sharded runs (default 256)

path options (distributed sweeps + checkpointing, sharded backend only):
  --distributed N     fan the block sweeps out to N worker processes
                      (spawned locally by default; bit-identical results)
  --no-spawn          don't spawn workers; wait for external
                      `repro worker --connect ADDR` processes instead
  --listen HOST:PORT  coordinator listen address (default 127.0.0.1:0)
  --worker-timeout S  worker connect/reply deadline (default 120)
  --checkpoint DIR    write a resumable record after every λ step
  --resume            continue from the newest checkpoint in DIR
  --out FILE          write the timing-free path result as JSON (the
                      deterministic fields only, for bitwise comparison)

worker options:
  --connect HOST:PORT coordinator to serve sweeps for (required)
  --cache-mb M        worker block-cache budget (default 256)

cv options:       --folds K (default 5)
stability options: --subsamples B (default 20) --threshold F (default 0.8)

gen options:
  --dataset ... --d N --seed S --out FILE.mtd
shard options:
  --in FILE.mtd | --dataset ... --d N --seed S
  --out FILE.mtd3 --shard-bytes N

serve options (plus the path grid/screener/solver/penalty options above):
  --addr HOST:PORT    listen address (default 127.0.0.1:7878; port 0 picks
                      an ephemeral port, printed at startup)
  --in FILE           serve a saved dataset (.mtd, or .mtd3 — materialized
                      into RAM: serving is a latency path)
  --no-prefit         skip the startup λ-path; models are fitted on demand
  --max-frame-mb M    per-frame payload cap in MiB (default 8)

load options:
  --addr HOST:PORT    daemon to ramp against (default 127.0.0.1:7878)
  --initial-rps R --increment-rps R --target-rps R --step-secs S
                      the RPS ramp (defaults 20/20/100/2.0); each level
                      holds step-secs, saturation = achieved < 0.9 offered
  --conns C --rows N  pipelined connections / rows per predict (4/4)
  --ratio R           fitted λ/λ_max to predict at (default: smallest
                      fitted ratio from the daemon's info reply)
  --seed S            workload-generator seed
  --out FILE          JSON report path (default BENCH_serve.json, or
                      BENCH_soak.json in soak mode)
  --shutdown          send a shutdown op after the run (daemon drains)
  --soak RPS          soak mode: hold one fixed rate instead of ramping
  --duration S        soak hold time (default 30)
  --window S          soak drift-window size (default 5); drifted =
                      second-half mean windowed p95 > threshold x first
  --drift-threshold F latency-drift trip factor (default 1.5)
";

/// First four bytes of a file (container magic sniffing).
fn sniff_magic(path: &std::path::Path) -> Result<[u8; 4]> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut m = [0u8; 4];
    f.read_exact(&mut m).with_context(|| format!("read {}", path.display()))?;
    Ok(m)
}

/// Bytes → MiB for the memory-model summary lines.
fn mib(b: u64) -> f64 {
    b as f64 / (1024.0 * 1024.0)
}

/// The per-run summary both `path` branches print: totals line plus the
/// rejection curve (kept in one place so the format cannot drift).
fn print_path_summary(res: &mtfl_dpc::coordinator::PathRunResult, title: &str) {
    println!(
        "total {:.2}s (screen {:.3}s, solve {:.2}s), mean rejection {:.4}, \
         solver col-ops {}",
        res.total_secs,
        res.screen_secs,
        res.solve_secs,
        res.mean_rejection_ratio(),
        res.total_col_ops()
    );
    let curve: Vec<(f64, f64)> =
        res.records.iter().map(|r| (r.ratio, r.rejection_ratio)).collect();
    println!("{}", report::render_rejection_curve(title, &curve));
}

/// Timing-free JSON view of a path run (`path --out`): only fields the
/// determinism contract bit-pins (DESIGN.md §12), plus an fnv64 digest
/// of the final solution's f64 bits — so two runs of the same problem
/// at different worker or thread counts, or a resumed grid, must
/// produce byte-identical files (`cmp` in CI).
fn path_result_json(res: &mtfl_dpc::coordinator::PathRunResult) -> mtfl_dpc::serve::json::Value {
    use mtfl_dpc::serve::json::Value;
    let records = res
        .records
        .iter()
        .map(|r| {
            Value::Obj(vec![
                ("ratio".into(), Value::Num(r.ratio)),
                ("lam".into(), Value::Num(r.lam)),
                ("rejected".into(), Value::Num(r.rejected as f64)),
                ("kept".into(), Value::Num(r.kept as f64)),
                ("inactive".into(), Value::Num(r.inactive as f64)),
                ("solver_iters".into(), Value::Num(r.solver_iters as f64)),
                ("col_ops".into(), Value::Num(r.col_ops as f64)),
                ("obj".into(), Value::Num(r.obj)),
                ("gap".into(), Value::Num(r.gap)),
            ])
        })
        .collect();
    let mut h = mtfl_dpc::data::io::Fnv64::new();
    for x in &res.last_w {
        h.update(&x.to_bits().to_le_bytes());
    }
    Value::Obj(vec![
        ("dataset".into(), Value::Str(res.dataset.clone())),
        ("d".into(), Value::Num(res.d as f64)),
        ("lam_max".into(), Value::Num(res.lam_max)),
        ("last_w_fnv64".into(), Value::Str(format!("{:016x}", h.digest()))),
        ("records".into(), Value::Arr(records)),
    ])
}

fn parse_screener(args: &Args) -> Result<ScreenerKind> {
    Ok(match args.get_or("screener", "dpc") {
        "dpc" => ScreenerKind::Dpc,
        "gap" | "gapsafe" => ScreenerKind::GapSafe,
        "cs" => ScreenerKind::DpcCs,
        "oneshot" => ScreenerKind::DpcOneShot,
        "none" => ScreenerKind::None,
        s => anyhow::bail!("unknown screener '{s}'"),
    })
}

fn parse_solver(args: &Args) -> Result<SolverKind> {
    Ok(match args.get_or("solver", "fista") {
        "fista" => SolverKind::Fista,
        "bcd" => SolverKind::Bcd,
        s => anyhow::bail!("unknown solver '{s}'"),
    })
}

/// Shared --screener/--solver/--penalty/--dynamic-every parsing + options
/// assembly for the grid subcommands (path, cv, stability).
fn grid_opts(args: &Args, grid: usize) -> Result<PathOptions> {
    let mut opts = experiments::exp_opts(grid, parse_screener(args)?);
    opts.solver = parse_solver(args)?;
    opts.solve.dynamic_every = args.get_usize("dynamic-every", 0)?;
    opts.solve.penalty = mtfl_dpc::PenaltyKind::parse(
        args.get_or("penalty", "l21"),
        args.get_f64("penalty-alpha", 0.5)?,
        args.get_f64("penalty-gamma", 1.0)?,
    )?;
    Ok(opts)
}

/// cv/stability fold the λ grid over data splits and run exact-engine
/// paths only; accept an explicit `--engine exact` but reject `aot`.
fn require_exact_engine(args: &Args, cmd: &str) -> Result<()> {
    match args.get_or("engine", "exact") {
        "exact" => Ok(()),
        other => anyhow::bail!(
            "`{cmd}` runs on the exact engine only (per-split AOT artifact shapes \
             don't exist); got --engine {other}"
        ),
    }
}

fn engine_kind<'a>(
    args: &Args,
    holder: &'a mut Option<AotEngine>,
) -> Result<EngineKind<'a>> {
    match args.get_or("engine", "exact") {
        "exact" => Ok(EngineKind::Exact),
        "aot" => {
            let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
            *holder = Some(AotEngine::new(&dir)?);
            Ok(EngineKind::Aot(holder.as_ref().unwrap()))
        }
        other => anyhow::bail!("unknown engine '{other}'"),
    }
}

fn main() -> Result<()> {
    let args = Args::parse()?;
    let Some(cmd) = args.subcommand.clone() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let scale = Scale::parse(args.get_or("scale", "default"))?;
    let mut engine_holder = None;

    match cmd.as_str() {
        "table1" => {
            let engine = engine_kind(&args, &mut engine_holder)?;
            args.finish()?;
            println!("{}", experiments::run_table1(scale, &engine)?);
        }
        "fig1" => {
            let engine = engine_kind(&args, &mut engine_holder)?;
            args.finish()?;
            println!("{}", experiments::run_fig1(scale, &engine)?);
        }
        "fig2" => {
            let engine = engine_kind(&args, &mut engine_holder)?;
            args.finish()?;
            println!("{}", experiments::run_fig2(scale, &engine)?);
        }
        "ablation" => {
            args.finish()?;
            println!("{}", experiments::run_ablation(scale)?);
        }
        "path" => {
            let name = args.get_or("dataset", "synth1").to_string();
            let d = args.get_usize("d", 1000)?;
            let seed = args.get_u64("seed", 0)?;
            let grid = args.get_usize("grid", scale.grid_len())?;
            let backend = args.get_or("backend", "auto").to_string();
            let shard_bytes = args.get_usize("shard-bytes", 4 << 20)?;
            let cache_mb = args.get_usize("cache-mb", 256)?;
            let cache_bytes = cache_mb << 20;
            let input = args.get("in").map(PathBuf::from);
            let distributed = args.get_usize("distributed", 0)?;
            let listen = args.get_or("listen", "127.0.0.1:0").to_string();
            let no_spawn = args.flag("no-spawn");
            let worker_timeout = args.get_f64("worker-timeout", 120.0)?;
            let ckpt_dir = args.get("checkpoint").map(PathBuf::from);
            let resume = args.flag("resume");
            let out = args.get("out").map(PathBuf::from);
            let mut opts = grid_opts(&args, grid)?;
            let engine = engine_kind(&args, &mut engine_holder)?;
            args.finish()?;
            anyhow::ensure!(
                !resume || ckpt_dir.is_some(),
                "--resume needs --checkpoint DIR (the directory to resume from)"
            );

            anyhow::ensure!(
                matches!(backend.as_str(), "auto" | "dense" | "csc" | "sharded"),
                "unknown backend '{backend}' (auto|dense|csc|sharded)"
            );
            let input_is_shard = match &input {
                Some(p) => sniff_magic(p)? == *b"MTD3",
                None => false,
            };
            if input_is_shard {
                anyhow::ensure!(
                    matches!(backend.as_str(), "auto" | "sharded"),
                    "--in points at an .mtd3 shard, which runs out-of-core; \
                     --backend {backend} cannot apply (load the .mtd instead)"
                );
            }
            if input_is_shard || backend == "sharded" {
                anyhow::ensure!(
                    matches!(engine, EngineKind::Exact),
                    "the sharded backend runs on the exact engine only"
                );
                let ckpt_cfg = ckpt_dir
                    .as_ref()
                    .map(|d| mtfl_dpc::coordinator::CheckpointCfg {
                        dir: d.clone(),
                        resume,
                    });
                // run an existing shard in place, or shard the requested
                // dataset into a temp file first (the zero-setup demo)
                let (shard_path, temp) = match (&input, input_is_shard) {
                    (Some(p), true) => (p.clone(), false),
                    _ => {
                        let ds = match &input {
                            Some(p) => mtfl_dpc::data::io::load(p)?,
                            None => experiments::build_by_name(&name, d, scale, seed)?,
                        };
                        let p = std::env::temp_dir()
                            .join(format!("mtfl_path_{}.mtd3", std::process::id()));
                        let s = mtfl_dpc::data::io::save_sharded(&ds, &p, shard_bytes)?;
                        println!(
                            "sharded {} into {} blocks x {} cols at {}",
                            ds.name,
                            s.blocks,
                            s.block_cols,
                            p.display()
                        );
                        (p, true)
                    }
                };
                // open + run inside one fallible block so the temp shard
                // is removed on ANY failure, not just a failed run
                let outcome = (|| {
                    let sh = mtfl_dpc::data::ShardedDataset::open_with_cache(
                        &shard_path,
                        cache_bytes,
                    )?;
                    let mut noop = mtfl_dpc::coordinator::FnObserver(
                        |_: f64, _: f64, _: &[f64], _: &mtfl_dpc::coordinator::LambdaRecord| {},
                    );
                    let res = if distributed > 0 {
                        let dopts = mtfl_dpc::coordinator::DistribOptions {
                            workers: distributed,
                            listen: listen.clone(),
                            spawn_local: !no_spawn,
                            worker_timeout_secs: worker_timeout,
                            cache_mb,
                        };
                        mtfl_dpc::coordinator::run_path_distributed(
                            &sh,
                            &shard_path,
                            &opts,
                            &dopts,
                            &mut noop,
                            ckpt_cfg.as_ref(),
                        )?
                    } else {
                        mtfl_dpc::coordinator::run_path_sharded_checkpointed(
                            &sh,
                            &opts,
                            &mut noop,
                            ckpt_cfg.as_ref(),
                        )?
                    };
                    Ok::<_, anyhow::Error>((sh, res))
                })();
                if temp {
                    std::fs::remove_file(&shard_path).ok();
                }
                let (sh, res) = outcome?;
                println!(
                    "dataset={} d={} lam_max={:.4} [sharded: {} blocks x {} cols]",
                    res.path.dataset,
                    res.path.d,
                    res.path.lam_max,
                    sh.n_blocks(),
                    sh.block_cols()
                );
                println!(
                    "memory: peak materialized {:.2} MiB of {:.2} MiB dense ({:.1}%), \
                     {:.2} MiB read from disk over {} block loads",
                    mib(res.peak_materialized_bytes as u64),
                    mib(res.dense_bytes),
                    100.0 * res.peak_materialized_bytes as f64
                        / res.dense_bytes.max(1) as f64,
                    mib(res.bytes_read),
                    res.blocks_loaded
                );
                println!(
                    "pipeline: {}/{} prefetches consumed warm, {:.3}s stalled on \
                     cold block loads",
                    res.prefetch.hits, res.prefetch.issued, res.prefetch.stall_secs
                );
                for w in &res.workers {
                    println!(
                        "worker {}: {} blocks, {} sweeps, {:.2} MiB shipped, \
                         {:.2} MiB read over {} block loads, {:.0}% busy",
                        w.addr,
                        w.blocks,
                        w.sweeps,
                        mib(w.bytes_shipped),
                        mib(w.bytes_read),
                        w.blocks_loaded,
                        100.0 * w.busy_secs / res.path.total_secs.max(1e-9)
                    );
                }
                print_path_summary(
                    &res.path,
                    &format!("path {} (sharded)", res.path.dataset),
                );
                if let Some(out) = &out {
                    std::fs::write(out, path_result_json(&res.path).to_json() + "\n")
                        .with_context(|| format!("write {}", out.display()))?;
                    println!("wrote {}", out.display());
                }
            } else {
                anyhow::ensure!(
                    distributed == 0 && ckpt_dir.is_none() && out.is_none(),
                    "--distributed, --checkpoint and --out apply to the sharded \
                     backend only (pass --backend sharded or --in FILE.mtd3)"
                );
                let ds = match &input {
                    Some(p) => mtfl_dpc::data::io::load(p)?,
                    None => experiments::build_by_name(&name, d, scale, seed)?,
                };
                let ds = match backend.as_str() {
                    "dense" => ds.to_dense_backend(),
                    "csc" => ds.to_csc(),
                    _ => ds, // "auto": the generator's natural backend
                };
                if matches!(engine, EngineKind::Aot(_)) {
                    opts.aot_margin = 1e-3; // f32 engine needs a float-safety margin
                }
                let res = run_path(&ds, &opts, &engine)?;
                println!(
                    "dataset={} d={} lam_max={:.4}",
                    res.dataset, res.d, res.lam_max
                );
                print_path_summary(&res, &format!("path {name}"));
            }
        }
        "cv" => {
            let name = args.get_or("dataset", "synth1").to_string();
            let d = args.get_usize("d", 500)?;
            let seed = args.get_u64("seed", 0)?;
            let grid = args.get_usize("grid", 20)?;
            let k = args.get_usize("folds", 5)?;
            let opts = grid_opts(&args, grid)?;
            require_exact_engine(&args, "cv")?;
            args.finish()?;
            let ds = experiments::build_by_name(&name, d, scale, seed)?;
            let cv = mtfl_dpc::coordinator::cv::cross_validate(&ds, &opts, k, seed)?;
            println!(
                "{}-fold CV on {} (d={}): best lambda/lambda_max = {:.4} (index {}) \
                 in {:.1}s, solver col-ops {} (one screened path per fold)",
                k, ds.name, ds.d, cv.best_ratio, cv.best_index, cv.total_secs, cv.col_ops
            );
            println!("# ratio, mean validation MSE");
            for (r, m) in cv.ratios.iter().zip(&cv.mse) {
                println!("{r:.4}, {m:.6}");
            }
        }
        "stability" => {
            let name = args.get_or("dataset", "synth1").to_string();
            let d = args.get_usize("d", 500)?;
            let seed = args.get_u64("seed", 0)?;
            let grid = args.get_usize("grid", 12)?;
            let b = args.get_usize("subsamples", 20)?;
            let thr = args.get_f64("threshold", 0.8)?;
            let opts = grid_opts(&args, grid)?;
            require_exact_engine(&args, "stability")?;
            args.finish()?;
            let ds = experiments::build_by_name(&name, d, scale, seed)?;
            let st = mtfl_dpc::coordinator::stability::stability_selection(
                &ds, &opts, b, thr, seed,
            )?;
            println!(
                "stability selection on {} (d={}, B={b}, thr={thr}): {} stable features in {:.1}s",
                ds.name,
                ds.d,
                st.stable.len(),
                st.total_secs
            );
            for &l in st.stable.iter().take(50) {
                println!("  feature {l}: frequency {:.2}", st.frequency[l]);
            }
        }
        "gen" => {
            let name = args.get_or("dataset", "synth1").to_string();
            let d = args.get_usize("d", 1000)?;
            let seed = args.get_u64("seed", 0)?;
            let out = PathBuf::from(
                args.get("out").context("--out FILE.mtd is required for gen")?,
            );
            args.finish()?;
            let ds = experiments::build_by_name(&name, d, scale, seed)?;
            mtfl_dpc::data::io::save(&ds, &out)?;
            println!(
                "wrote {} (T={} N={:?} d={}) to {}",
                ds.name,
                ds.t(),
                ds.uniform_n(),
                ds.d,
                out.display()
            );
        }
        "shard" => {
            let out = PathBuf::from(
                args.get("out").context("--out FILE.mtd3 is required for shard")?,
            );
            let shard_bytes = args.get_usize("shard-bytes", 4 << 20)?;
            let ds = match args.get("in") {
                Some(p) => mtfl_dpc::data::io::load(std::path::Path::new(p))?,
                None => {
                    let name = args.get_or("dataset", "synth1").to_string();
                    let d = args.get_usize("d", 1000)?;
                    let seed = args.get_u64("seed", 0)?;
                    experiments::build_by_name(&name, d, scale, seed)?
                }
            };
            args.finish()?;
            let s = mtfl_dpc::data::io::save_sharded(&ds, &out, shard_bytes)?;
            println!(
                "sharded {} (T={} d={}) into {}: {} blocks x {} cols, payload {:.2} MiB",
                ds.name,
                ds.t(),
                ds.d,
                out.display(),
                s.blocks,
                s.block_cols,
                mib(s.payload_bytes)
            );
            println!(
                "run it out-of-core with: repro path --in {}",
                out.display()
            );
        }
        "serve" => {
            let addr = args.get_or("addr", "127.0.0.1:7878").to_string();
            let name = args.get_or("dataset", "synth1").to_string();
            let d = args.get_usize("d", 500)?;
            let seed = args.get_u64("seed", 0)?;
            let grid = args.get_usize("grid", scale.grid_len())?;
            let input = args.get("in").map(PathBuf::from);
            let max_frame = args.get_usize("max-frame-mb", 8)? << 20;
            let prefit = !args.flag("no-prefit");
            let popts = grid_opts(&args, grid)?;
            require_exact_engine(&args, "serve")?;
            args.finish()?;
            let ds = match &input {
                Some(p) if sniff_magic(p)? == *b"MTD3" => {
                    // serving is a latency path: materialize the shard
                    let sh = mtfl_dpc::data::ShardedDataset::open(p)?;
                    let all: Vec<usize> = (0..sh.d()).collect();
                    println!(
                        "materializing {} (d={}) from {} into RAM for serving",
                        sh.name(),
                        sh.d(),
                        p.display()
                    );
                    sh.restrict(&all)?
                }
                Some(p) => mtfl_dpc::data::io::load(p)?,
                None => experiments::build_by_name(&name, d, scale, seed)?,
            };
            let sopts = mtfl_dpc::serve::ServerOptions { path: popts, prefit, max_frame };
            let mut srv = mtfl_dpc::serve::Server::bind(&addr, ds, sopts)?;
            println!(
                "serving on {} ({} models warm) — ops: \
                 ping|info|predict|fit|cv|stats|shutdown",
                srv.local_addr()?,
                srv.fitted_ratios().len()
            );
            srv.run()?;
            println!("shutdown: drained in-flight work, stopping");
        }
        "load" => {
            use mtfl_dpc::serve::json::Value;
            use mtfl_dpc::serve::proto;
            let addr = args.get_or("addr", "127.0.0.1:7878").to_string();
            let mut lopts = mtfl_dpc::serve::LoadOptions {
                initial_rps: args.get_f64("initial-rps", 20.0)?,
                increment_rps: args.get_f64("increment-rps", 20.0)?,
                target_rps: args.get_f64("target-rps", 100.0)?,
                step_secs: args.get_f64("step-secs", 2.0)?,
                conns: args.get_usize("conns", 4)?,
                rows: args.get_usize("rows", 4)?,
                seed: args.get_u64("seed", 0)?,
                ..Default::default()
            };
            let ratio_arg = args.get_f64("ratio", 0.0)?;
            let soak_rps = args.get_f64("soak", 0.0)?;
            let duration = args.get_f64("duration", 30.0)?;
            let window = args.get_f64("window", 5.0)?;
            let drift_threshold = args.get_f64("drift-threshold", 1.5)?;
            let default_out = if soak_rps > 0.0 { "BENCH_soak.json" } else { "BENCH_serve.json" };
            let out = PathBuf::from(args.get_or("out", default_out));
            let do_shutdown = args.flag("shutdown");
            args.finish()?;

            // discover d and the fitted grid from the daemon
            let mut probe = std::net::TcpStream::connect(&addr)
                .with_context(|| format!("connect {addr} (is `repro serve` running?)"))?;
            let info = proto::call(
                &mut probe,
                &Value::Obj(vec![("op".into(), Value::Str("info".into()))]),
            )?;
            lopts.d = info
                .get("d")
                .and_then(Value::as_usize)
                .context("info reply missing d")?;
            lopts.ratio = if ratio_arg > 0.0 {
                ratio_arg
            } else {
                info.get("fitted")
                    .and_then(Value::as_arr)
                    .and_then(|a| a.last())
                    .and_then(Value::as_f64)
                    .context(
                        "daemon has no fitted models — run serve without --no-prefit, \
                         send a fit op first, or pass --ratio",
                    )?
            };
            if soak_rps > 0.0 {
                let sopts = mtfl_dpc::serve::SoakOptions {
                    rps: soak_rps,
                    duration_secs: duration,
                    window_secs: window,
                    drift_threshold,
                    conns: lopts.conns,
                    rows: lopts.rows,
                    ratio: lopts.ratio,
                    seed: lopts.seed,
                    d: lopts.d,
                };
                println!(
                    "soaking {addr} at {soak_rps} rps for {duration}s ({window}s \
                     drift windows): d={} ratio={} conns={} rows={}",
                    sopts.d, sopts.ratio, sopts.conns, sopts.rows
                );
                let report = mtfl_dpc::serve::run_soak(&addr, &sopts, &mut || Ok(()))?;
                for w in &report.windows {
                    println!(
                        "  t={:>6.1}s | completed {:>6} | p95 {:>7.2}ms",
                        w.t0_secs, w.completed, w.p95_ms
                    );
                }
                println!(
                    "achieved {:.1}/{:.1} rps, p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms, \
                     errors {}",
                    report.achieved_rps,
                    report.offered_rps,
                    report.p50_ms,
                    report.p95_ms,
                    report.p99_ms,
                    report.errors
                );
                println!(
                    "latency drift: ratio {:.3} (threshold {:.2}) — {}{}",
                    report.drift_ratio,
                    drift_threshold,
                    if report.drifted { "DRIFTED" } else { "stable" },
                    if report.saturated { " (and saturated: achieved < 90% offered)" } else { "" }
                );
                // a CLI-run soak is a real measurement: provisional=false
                std::fs::write(&out, report.to_json(false).to_json() + "\n")
                    .with_context(|| format!("write {}", out.display()))?;
            } else {
                println!(
                    "ramping {} → {} rps (step {} rps / {}s) against {addr}: d={} ratio={} \
                     conns={} rows={}",
                    lopts.initial_rps,
                    lopts.target_rps,
                    lopts.increment_rps,
                    lopts.step_secs,
                    lopts.d,
                    lopts.ratio,
                    lopts.conns,
                    lopts.rows
                );
                let report = mtfl_dpc::serve::run_load(&addr, &lopts, &mut || Ok(()))?;
                for l in &report.levels {
                    println!(
                        "offered {:>7.1} rps | achieved {:>7.1} | p50 {:>7.2}ms | \
                         p95 {:>7.2}ms | p99 {:>7.2}ms | errors {}",
                        l.offered_rps, l.achieved_rps, l.p50_ms, l.p95_ms, l.p99_ms, l.errors
                    );
                }
                match report.saturation_rps {
                    Some(r) => println!("saturated at {r:.1} rps achieved"),
                    None => println!(
                        "no saturation up to {:.1} rps (max achieved {:.1})",
                        lopts.target_rps, report.max_achieved_rps
                    ),
                }
                // a CLI-run ramp is a real measurement: provisional=false
                std::fs::write(&out, report.to_json(false).to_json() + "\n")
                    .with_context(|| format!("write {}", out.display()))?;
            }
            println!("wrote {}", out.display());
            if do_shutdown {
                proto::call(
                    &mut probe,
                    &Value::Obj(vec![("op".into(), Value::Str("shutdown".into()))]),
                )?;
                println!("sent shutdown; daemon is draining");
            }
        }
        "worker" => {
            let connect = args
                .get("connect")
                .context("--connect HOST:PORT is required for worker")?
                .to_string();
            let cache_mb = args.get_usize("cache-mb", 256)?;
            args.finish()?;
            mtfl_dpc::coordinator::run_worker(&connect, cache_mb)?;
        }
        "info" => {
            let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
            args.finish()?;
            let manifest = mtfl_dpc::runtime::Manifest::load(&dir)?;
            println!("{} artifacts in {}", manifest.artifacts.len(), dir.display());
            for a in &manifest.artifacts {
                println!(
                    "  {:<28} kind={:<10} cfg={:<10} T={} N={} D={} bucket={} steps={}",
                    a.name, a.kind, a.cfg, a.t, a.n, a.d, a.bucket, a.steps
                );
            }
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
