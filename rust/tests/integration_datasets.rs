//! Dataset substrate integration: statistical shape of each simulated
//! workload at moderate size, IO round trips, and AOT packing identities.

use mtfl_dpc::data::imagesim::{imagesim, ImageSimOptions};
use mtfl_dpc::data::snpsim::{snpsim, SnpSimOptions};
use mtfl_dpc::data::synthetic::{synthetic1, synthetic2, SynthOptions};
use mtfl_dpc::data::textsim::{nonzero_features, textsim, TextSimOptions};
use mtfl_dpc::ops;
use mtfl_dpc::runtime::buckets;

#[test]
fn all_generators_validate() {
    synthetic1(&SynthOptions { t: 3, n: 10, d: 100, ..Default::default() }).0.validate().unwrap();
    synthetic2(&SynthOptions { t: 3, n: 10, d: 100, ..Default::default() }).0.validate().unwrap();
    textsim(&TextSimOptions { categories: 3, n_pos: 6, d: 400, ..Default::default() })
        .validate()
        .unwrap();
    imagesim(&ImageSimOptions { classes: 3, n_pos: 6, blocks: vec![32, 32], rank: 3, seed: 0 })
        .validate()
        .unwrap();
    snpsim(&SnpSimOptions { tasks: 3, n: 10, d: 300, causal: 6, ..Default::default() })
        .0
        .validate()
        .unwrap();
}

#[test]
fn ground_truth_support_is_recoverable_at_moderate_lambda() {
    // features with strong true signal must survive screening at mid-λ:
    // the screened-path solution's active set intersects the true support
    let (ds, gt) =
        synthetic1(&SynthOptions { t: 4, n: 30, d: 60, support_frac: 0.1, noise: 0.01, seed: 9 });
    let (lmax, _, _) = ops::lambda_max(&ds);
    let sol =
        mtfl_dpc::solver::fista(&ds, 0.05 * lmax, None, &mtfl_dpc::solver::SolveOptions::default());
    let active = sol.active_set(ds.t(), 1e-6);
    let hits = gt.active.iter().filter(|l| active.contains(l)).count();
    assert!(
        hits * 2 >= gt.active.len(),
        "recovered only {hits}/{} true features",
        gt.active.len()
    );
}

#[test]
fn snpsim_extreme_aspect_ratio() {
    let (ds, _) =
        snpsim(&SnpSimOptions { tasks: 2, n: 10, d: 5000, causal: 10, ..Default::default() });
    assert_eq!(ds.d, 5000);
    assert_eq!(ds.total_n(), 20); // d/N = 250: the DPC sweet spot
    // lambda_max must still be computable and positive
    let (lmax, _, _) = ops::lambda_max(&ds);
    assert!(lmax > 0.0 && lmax.is_finite());
}

#[test]
fn textsim_pruning_then_restrict_is_consistent() {
    let ds = textsim(&TextSimOptions {
        categories: 3,
        n_pos: 8,
        d: 3000,
        doc_len: 60,
        ..Default::default()
    });
    let kept = nonzero_features(&ds);
    let pruned = ds.restrict(&kept);
    pruned.validate().unwrap();
    // no zero feature remains
    let b2 = pruned.col_sqnorms();
    let t = pruned.t();
    for l in 0..pruned.d {
        let total: f64 = (0..t).map(|ti| b2[l * t + ti]).sum();
        assert!(total > 0.0, "zero feature {l} survived pruning");
    }
}

#[test]
fn mtd_io_round_trip_every_generator() {
    let dir = std::env::temp_dir();
    let sets = vec![
        synthetic2(&SynthOptions { t: 2, n: 8, d: 40, ..Default::default() }).0,
        textsim(&TextSimOptions { categories: 2, n_pos: 5, d: 200, ..Default::default() }),
        snpsim(&SnpSimOptions { tasks: 2, n: 8, d: 100, causal: 5, ..Default::default() }).0,
    ];
    for (i, ds) in sets.into_iter().enumerate() {
        let p = dir.join(format!("mtfl_io_{}_{i}.mtd", std::process::id()));
        mtfl_dpc::data::io::save(&ds, &p).unwrap();
        let back = mtfl_dpc::data::io::load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(back.d, ds.d);
        for (a, b) in back.tasks.iter().zip(&ds.tasks) {
            assert_eq!(a.x, b.x);
        }
    }
}

#[test]
fn packing_is_consistent_with_restrict() {
    // pack_tnd(keep) must equal restrict(keep).to_tnd() zero-padded
    let (ds, _) = synthetic1(&SynthOptions { t: 3, n: 6, d: 20, seed: 3, ..Default::default() });
    let keep = vec![2usize, 7, 11, 19];
    let db = 6;
    let packed = buckets::pack_tnd(&ds.tasks, &keep, db);
    let restricted = ds.restrict(&keep);
    let tnd = restricted.to_tnd().unwrap();
    let n = 6;
    for t in 0..3 {
        for ni in 0..n {
            for j in 0..keep.len() {
                assert_eq!(packed[(t * n + ni) * db + j], tnd[(t * n + ni) * keep.len() + j]);
            }
            for j in keep.len()..db {
                assert_eq!(packed[(t * n + ni) * db + j], 0.0);
            }
        }
    }
}

#[test]
fn zero_padding_preserves_exact_solution() {
    // the bucketing correctness claim: solving on a zero-padded dataset
    // returns the same solution on the real coordinates, zeros on padding
    let (ds, _) = synthetic1(&SynthOptions { t: 2, n: 10, d: 16, seed: 5, ..Default::default() });
    let (lmax, _, _) = ops::lambda_max(&ds);
    let lam = 0.4 * lmax;

    // build a padded dataset: 16 real features + 8 zero columns
    let padded = {
        let mut tasks = Vec::new();
        for task in &ds.tasks {
            let mut x = task.x.to_dense(task.n, ds.d);
            x.extend(std::iter::repeat(0.0f32).take(8 * task.n));
            tasks.push(mtfl_dpc::data::Task::dense(x, task.y.clone(), task.n));
        }
        mtfl_dpc::data::Dataset { name: "padded".into(), d: 24, tasks }
    };

    let a = mtfl_dpc::solver::fista(&ds, lam, None, &mtfl_dpc::solver::SolveOptions::tight());
    let b = mtfl_dpc::solver::fista(&padded, lam, None, &mtfl_dpc::solver::SolveOptions::tight());
    for l in 0..16 {
        for t in 0..2 {
            assert!(
                (a.w[l * 2 + t] - b.w[l * 2 + t]).abs() < 1e-8,
                "padding perturbed w[{l},{t}]"
            );
        }
    }
    for l in 16..24 {
        for t in 0..2 {
            assert_eq!(b.w[l * 2 + t], 0.0, "padding row {l} became nonzero");
        }
    }
}
