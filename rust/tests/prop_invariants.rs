//! Property-based invariants (proptest-lite harness, see
//! `mtfl_dpc::testing`): randomized coordinator/screening/solver
//! invariants that must hold for *any* input.

use mtfl_dpc::data::synthetic::{synthetic1, synthetic2, SynthOptions};
use mtfl_dpc::ops;
use mtfl_dpc::screening::dpc::{ball, DpcScreener, DualRef};
use mtfl_dpc::screening::secular::qp1qc_max;
use mtfl_dpc::screening::{bounds, safety};
use mtfl_dpc::solver::{bcd, fista, prox::prox21_inplace, SolveOptions};
use mtfl_dpc::testing::{check, gen, PropConfig};
use mtfl_dpc::util::Pcg64;

fn cfg(cases: usize) -> PropConfig {
    PropConfig { cases, ..Default::default() }
}

fn random_problem(rng: &mut Pcg64) -> mtfl_dpc::Dataset {
    let t = gen::usize_in(rng, 1, 4);
    let n = gen::usize_in(rng, 4, 16);
    let d = gen::usize_in(rng, 8, 60);
    let which = gen::usize_in(rng, 1, 2);
    let opts = SynthOptions {
        t,
        n,
        d,
        support_frac: gen::f64_in(rng, 0.05, 0.4),
        noise: gen::f64_in(rng, 0.0, 0.1),
        seed: rng.next_u64(),
    };
    if which == 1 {
        synthetic1(&opts).0
    } else {
        synthetic2(&opts).0
    }
}

#[test]
fn prop_qp1qc_upper_bounds_ball_samples() {
    check("qp1qc-upper-bound", &cfg(40), |rng, _| {
        let t = gen::usize_in(rng, 1, 6);
        let a = gen::vec_normal(rng, t, 2.0);
        let b2: Vec<f64> = (0..t).map(|_| rng.normal().abs() + 1e-6).collect();
        let delta = gen::f64_in(rng, 0.0, 3.0);
        let s = qp1qc_max(&a, &b2, delta).s;
        // sample points in the parametrized ball and check g <= s
        for _ in 0..500 {
            let mut u = gen::vec_normal(rng, t, 1.0);
            let norm = u.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-300);
            let scale = delta * rng.uniform() / norm;
            for v in u.iter_mut() {
                *v *= scale;
            }
            let g: f64 = (0..t)
                .map(|i| {
                    let b = b2[i].sqrt();
                    (a[i].abs() + u[i].abs() * b).powi(2)
                })
                .sum();
            if g > s + 1e-8 * s.max(1.0) {
                return Err(format!("sampled g={g} exceeds certified s={s}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cs_bound_dominates_exact() {
    check("cs-dominates", &cfg(30), |rng, _| {
        let ds = random_problem(rng);
        let (dref, lmax) = DualRef::at_lambda_max(&ds);
        let lam = gen::f64_in(rng, 0.1, 0.9) * lmax;
        let (o, delta) = ball(&ds, &dref, lam);
        let exact = DpcScreener::new(&ds).scores(&ds, &o, delta);
        let cs = bounds::cs_scores(&ds, &ds.col_sqnorms(), &o, delta);
        for l in 0..ds.d {
            if cs[l] < exact[l] - 1e-9 * exact[l].max(1.0) {
                return Err(format!("CS {} < exact {} at feature {l}", cs[l], exact[l]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dpc_safety_random_problems() {
    check("dpc-safety", &cfg(15), |rng, _| {
        let ds = random_problem(rng);
        let (dref, lmax) = DualRef::at_lambda_max(&ds);
        let lam = gen::f64_in(rng, 0.15, 0.95) * lmax;
        let out = DpcScreener::new(&ds).screen(&ds, &dref, lam);
        let sol = fista(&ds, lam, None, &SolveOptions::tight());
        let report = safety::verify(&ds, &sol.w, lam, &out.rejected, 1e-7);
        if !report.is_safe() {
            return Err(format!(
                "violations {:?} (d={}, lam/lmax={})",
                report.violations,
                ds.d,
                lam / lmax
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_ball_contains_dual_optimum() {
    check("ball-contains-theta", &cfg(10), |rng, _| {
        let ds = random_problem(rng);
        let (_, lmax) = DualRef::at_lambda_max(&ds);
        let r0 = gen::f64_in(rng, 0.4, 0.9);
        let r1 = gen::f64_in(rng, 0.1, r0);
        let sol0 = fista(&ds, r0 * lmax, None, &SolveOptions::tight());
        let dref = DualRef::from_solution(&ds, r0 * lmax, &sol0.w);
        let (o, delta) = ball(&ds, &dref, r1 * lmax);
        let sol1 = fista(&ds, r1 * lmax, None, &SolveOptions::tight());
        let theta = ops::stacked_scale(&ops::residual(&ds, &sol1.w), -1.0 / (r1 * lmax));
        let diff = ops::stacked_scale_add(&theta, -1.0, &o);
        let dist = ops::stacked_sqnorm(&diff).sqrt();
        if dist > delta + 1e-5 {
            return Err(format!("theta* outside ball: dist={dist} delta={delta}"));
        }
        Ok(())
    });
}

#[test]
fn prop_prox_is_projection_like() {
    check("prox-firm-nonexpansive", &cfg(50), |rng, _| {
        let t = gen::usize_in(rng, 1, 5);
        let d = gen::usize_in(rng, 1, 20);
        let kappa = gen::f64_in(rng, 0.0, 2.0);
        let mut a = gen::vec_normal(rng, d * t, 2.0);
        let mut b = gen::vec_normal(rng, d * t, 2.0);
        let d0: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).powi(2)).sum();
        prox21_inplace(&mut a, t, kappa);
        prox21_inplace(&mut b, t, kappa);
        let d1: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).powi(2)).sum();
        if d1 > d0 + 1e-9 {
            return Err(format!("prox expanded distances: {d1} > {d0}"));
        }
        Ok(())
    });
}

#[test]
fn prop_restrict_preserves_solutions() {
    // solving on restrict(keep-all) == solving on the original
    check("restrict-identity", &cfg(8), |rng, _| {
        let ds = random_problem(rng);
        let keep: Vec<usize> = (0..ds.d).collect();
        let r = ds.restrict(&keep);
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = 0.4 * lmax;
        let a = fista(&ds, lam, None, &SolveOptions::default());
        let b = fista(&r, lam, None, &SolveOptions::default());
        let dmax = a.w.iter().zip(&b.w).map(|(x, y)| (x - y).abs()).fold(0.0f64, f64::max);
        if dmax > 1e-7 {
            return Err(format!("restrict(all) changed the solution by {dmax}"));
        }
        Ok(())
    });
}

#[test]
fn prop_solvers_agree() {
    check("fista-vs-bcd", &cfg(8), |rng, _| {
        let ds = random_problem(rng);
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = gen::f64_in(rng, 0.25, 0.8) * lmax;
        let a = fista(&ds, lam, None, &SolveOptions::tight());
        let b = bcd(&ds, lam, None, &SolveOptions::tight());
        if (a.obj - b.obj).abs() > 1e-7 * a.obj.abs().max(1.0) {
            return Err(format!("objective mismatch {} vs {}", a.obj, b.obj));
        }
        let dmax = a.w.iter().zip(&b.w).map(|(x, y)| (x - y).abs()).fold(0.0f64, f64::max);
        if dmax > 1e-4 {
            return Err(format!("solution mismatch {dmax}"));
        }
        Ok(())
    });
}

#[test]
fn prop_duality_gap_nonnegative() {
    check("weak-duality", &cfg(25), |rng, _| {
        let ds = random_problem(rng);
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = gen::f64_in(rng, 0.05, 1.2) * lmax;
        // arbitrary W, not just solutions
        let w = gen::vec_normal(rng, ds.d * ds.t(), 0.3);
        let (_, gap, _) = ops::duality_gap(&ds, &w, lam);
        if gap < -1e-8 {
            return Err(format!("negative duality gap {gap}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// dense / CSC backend parity (DESIGN.md §6): a CSC-converted dataset must be
// indistinguishable from its dense twin through every consumer — the sparse
// kernels replicate the dense accumulation order, so on fully-stored columns
// the results are bit-identical and 1e-12 is a loose bound.
// ---------------------------------------------------------------------------

#[test]
fn prop_dense_csc_parity_moments_and_scores() {
    check("dense-csc-parity", &cfg(12), |rng, _| {
        let ds = random_problem(rng);
        let sp = ds.to_csc();
        sp.validate().map_err(|e| format!("csc validate: {e}"))?;

        let b2_d = ds.col_sqnorms();
        let b2_s = sp.col_sqnorms();
        for l in 0..b2_d.len() {
            if (b2_d[l] - b2_s[l]).abs() > 1e-12 * b2_d[l].max(1.0) {
                return Err(format!("col_sqnorms diverge at {l}: {} vs {}", b2_d[l], b2_s[l]));
            }
        }

        let (lmax_d, lstar_d, g_d) = ops::lambda_max(&ds);
        let (lmax_s, lstar_s, g_s) = ops::lambda_max(&sp);
        if (lmax_d - lmax_s).abs() > 1e-12 * lmax_d.max(1.0) || lstar_d != lstar_s {
            return Err(format!("lambda_max diverges: {lmax_d}/{lstar_d} vs {lmax_s}/{lstar_s}"));
        }
        for l in 0..g_d.len() {
            if (g_d[l] - g_s[l]).abs() > 1e-12 * g_d[l].abs().max(1.0) {
                return Err(format!("g scores diverge at {l}"));
            }
        }

        let (dref_d, _) = DualRef::at_lambda_max(&ds);
        let (dref_s, _) = DualRef::at_lambda_max(&sp);
        let lam = gen::f64_in(rng, 0.2, 0.9) * lmax_d;
        let (o_d, delta_d) = ball(&ds, &dref_d, lam);
        let (o_s, delta_s) = ball(&sp, &dref_s, lam);
        if (delta_d - delta_s).abs() > 1e-12 * delta_d.max(1.0) {
            return Err(format!("ball radius diverges: {delta_d} vs {delta_s}"));
        }
        let s_d = DpcScreener::new(&ds).scores(&ds, &o_d, delta_d);
        let s_s = DpcScreener::new(&sp).scores(&sp, &o_s, delta_s);
        for l in 0..s_d.len() {
            if (s_d[l] - s_s[l]).abs() > 1e-12 * s_d[l].abs().max(1.0) {
                return Err(format!("DPC scores diverge at {l}: {} vs {}", s_d[l], s_s[l]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dense_csc_parity_fista_solutions() {
    check("dense-csc-fista", &cfg(6), |rng, _| {
        let ds = random_problem(rng);
        let sp = ds.to_csc();
        let (lmax, _, _) = ops::lambda_max(&ds);
        let lam = gen::f64_in(rng, 0.25, 0.8) * lmax;
        let a = fista(&ds, lam, None, &SolveOptions::default());
        let b = fista(&sp, lam, None, &SolveOptions::default());
        // identical trajectories: same kernels, same accumulation order
        let dmax = a.w.iter().zip(&b.w).map(|(x, y)| (x - y).abs()).fold(0.0f64, f64::max);
        if dmax > 1e-12 {
            return Err(format!("FISTA solutions diverge across backends by {dmax}"));
        }
        if a.iters != b.iters {
            return Err(format!("iteration counts diverge: {} vs {}", a.iters, b.iters));
        }
        Ok(())
    });
}

#[test]
fn prop_restrict_round_trips_on_both_backends() {
    check("restrict-backends", &cfg(12), |rng, _| {
        let ds = random_problem(rng);
        let sp = ds.to_csc();
        let k = gen::usize_in(rng, 1, ds.d);
        let mut keep: Vec<usize> = {
            let mut r = rng.split(7);
            r.choose_distinct(ds.d, k)
        };
        keep.sort_unstable();
        let rd = ds.restrict(&keep);
        let rs = sp.restrict(&keep);
        if !rs.is_sparse() {
            return Err("restrict densified a CSC dataset".into());
        }
        rs.validate().map_err(|e| format!("restricted csc invalid: {e}"))?;
        for t in 0..ds.t() {
            for (j, &l) in keep.iter().enumerate() {
                let want = ds.col(t, l).to_vec();
                if rd.col(t, j).to_vec() != want {
                    return Err(format!("dense restrict broke column {l}"));
                }
                if rs.col(t, j).to_vec() != want {
                    return Err(format!("csc restrict broke column {l}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_textsim_parity_with_true_zeros() {
    // textsim has genuine zero cells: the CSC store drops them, so the
    // accumulation orders differ — scores must still agree to 1e-12.
    use mtfl_dpc::data::textsim::{textsim, TextSimOptions};
    check("textsim-parity", &cfg(6), |rng, _| {
        let opts = TextSimOptions {
            categories: gen::usize_in(rng, 2, 3),
            n_pos: gen::usize_in(rng, 4, 8),
            d: gen::usize_in(rng, 60, 150),
            doc_len: 30,
            seed: rng.next_u64(),
            ..Default::default()
        };
        let sp = textsim(&opts);
        if !sp.is_sparse() {
            return Err("textsim did not emit CSC".into());
        }
        let ds = sp.to_dense_backend();
        let b2_d = ds.col_sqnorms();
        let b2_s = sp.col_sqnorms();
        for l in 0..b2_d.len() {
            if (b2_d[l] - b2_s[l]).abs() > 1e-12 * b2_d[l].max(1.0) {
                return Err(format!("textsim col_sqnorms diverge at {l}"));
            }
        }
        let (lmax_d, _, _) = ops::lambda_max(&ds);
        let (lmax_s, _, _) = ops::lambda_max(&sp);
        if (lmax_d - lmax_s).abs() > 1e-12 * lmax_d.max(1.0) {
            return Err(format!("textsim lambda_max diverges: {lmax_d} vs {lmax_s}"));
        }
        let (dref, _) = DualRef::at_lambda_max(&sp);
        let lam = 0.5 * lmax_s;
        let (o, delta) = ball(&sp, &dref, lam);
        let s_s = DpcScreener::new(&sp).scores(&sp, &o, delta);
        let s_d = DpcScreener::new(&ds).scores(&ds, &o, delta);
        for l in 0..s_d.len() {
            if (s_d[l] - s_s[l]).abs() > 1e-12 * s_d[l].abs().max(1.0) {
                return Err(format!("textsim DPC scores diverge at {l}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_theorem5_sign_identities() {
    check("thm5-signs", &cfg(12), |rng, _| {
        let ds = random_problem(rng);
        let (_, lmax) = DualRef::at_lambda_max(&ds);
        let r0 = gen::f64_in(rng, 0.3, 0.9);
        let sol = fista(&ds, r0 * lmax, None, &SolveOptions::tight());
        let dref = DualRef::from_solution(&ds, r0 * lmax, &sol.w);
        let y = ops::y64(&ds);
        // part 2: <y, n> >= 0
        if ops::stacked_dot(&y, &dref.normal) < -1e-6 {
            return Err("negative <y, n>".into());
        }
        // part 3: <r(lam,lam0), n> >= 0 for lam < lam0
        let lam = gen::f64_in(rng, 0.05, r0) * lmax;
        let r = ops::stacked_scale_add(&ops::stacked_scale(&y, 1.0 / lam), -1.0, &dref.theta0);
        if ops::stacked_dot(&r, &dref.normal) < -1e-6 {
            return Err("negative <r, n>".into());
        }
        Ok(())
    });
}
