//! End-to-end serving suite (ISSUE 9 / DESIGN.md §15): the daemon runs
//! in-process on an ephemeral port and the test interleaves client I/O
//! with explicit `Server::tick` calls, so client and daemon share one
//! thread and the schedule is deterministic at any `MTFL_THREADS`.
//!
//! Contracts pinned here:
//! * `predict` replies are **bit-identical** to the offline pipeline
//!   (`run_path` observer `W` + `ops::forward`) on the same dataset/λ —
//!   including the JSON round trip.
//! * four pipelined clients get the same bits as the same requests
//!   issued serially (the executor batch is order-stable).
//! * a warm-started `fit` matches a cold solve within the documented
//!   tolerance: both carry duality-gap certificates, so the two
//!   objectives differ by at most `gap_warm + gap_cold` (plus f64 noise).
//! * fault injection: malformed JSON, truncated frames, oversized
//!   frames, unfitted-λ requests — all are error *replies* (or clean
//!   connection drops), never panics, and the daemon keeps serving.
//! * shutdown drains: a predict pipelined ahead of `shutdown` on the
//!   same connection is answered before the daemon stops, and
//!   `Server::run` returns `Ok`.
//!
//! Problem sizes route through `testing::scale` so cfg(miri)/cfg(loom)
//! runs shrink them without changing the contracts.

use mtfl_dpc::coordinator::path::{
    run_path_with, EngineKind, FnObserver, LambdaRecord, ScreenerKind,
};
use mtfl_dpc::experiments::{build_by_name, exp_opts, Scale};
use mtfl_dpc::ops;
use mtfl_dpc::serve::json::{self, Value};
use mtfl_dpc::serve::proto::{self, FrameDecoder};
use mtfl_dpc::serve::{Server, ServerOptions};
use mtfl_dpc::solver::fista;
use mtfl_dpc::testing::scale;
use mtfl_dpc::Dataset;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;

const MAX_FRAME: usize = 1 << 20;
const TICK_BUDGET: usize = 50_000;

fn dataset() -> Dataset {
    build_by_name("synth1", scale::d(60), Scale::Quick, 7).unwrap()
}

fn server(ds: Dataset, prefit: bool) -> Server {
    let opts = ServerOptions {
        path: exp_opts(scale::grid(8), ScreenerKind::Dpc),
        prefit,
        max_frame: MAX_FRAME,
    };
    Server::bind("127.0.0.1:0", ds, opts).unwrap()
}

/// A nonblocking test client owning its half of the framed stream.
struct Client {
    stream: TcpStream,
    dec: FrameDecoder,
}

impl Client {
    fn connect(srv: &Server) -> Client {
        let addr = srv.local_addr().unwrap();
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nonblocking(true).unwrap();
        Client { stream, dec: FrameDecoder::new() }
    }

    /// Queue one request frame (ticking the server if the write blocks).
    fn send(&mut self, srv: &mut Server, req: &Value) {
        let mut bytes = Vec::new();
        proto::encode_frame(req.to_json().as_bytes(), &mut bytes);
        self.send_raw(srv, &bytes);
    }

    fn send_raw(&mut self, srv: &mut Server, bytes: &[u8]) {
        let mut pos = 0;
        while pos < bytes.len() {
            match self.stream.write(&bytes[pos..]) {
                Ok(n) => pos += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    srv.tick().unwrap();
                }
                Err(e) => panic!("client write: {e}"),
            }
        }
    }

    /// Tick the server until one reply frame decodes.
    fn recv(&mut self, srv: &mut Server) -> Value {
        for _ in 0..TICK_BUDGET {
            srv.tick().unwrap();
            self.pump_reads();
            if let Some(p) = self.dec.next(MAX_FRAME).unwrap() {
                return json::parse(std::str::from_utf8(&p).unwrap()).unwrap();
            }
        }
        panic!("no reply within {TICK_BUDGET} ticks");
    }

    /// Read without expecting a frame; true once the server closed.
    fn saw_eof(&mut self, srv: &mut Server) -> bool {
        for _ in 0..200 {
            srv.tick().unwrap();
            let mut buf = [0u8; 4096];
            match self.stream.read(&mut buf) {
                Ok(0) => return true,
                Ok(n) => self.dec.extend(&buf[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                Err(_) => return true,
            }
        }
        false
    }

    fn pump_reads(&mut self) {
        let mut buf = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => self.dec.extend(&buf[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => panic!("client read: {e}"),
            }
        }
    }

    fn call(&mut self, srv: &mut Server, req: &Value) -> Value {
        self.send(srv, req);
        self.recv(srv)
    }
}

fn op(name: &str) -> Value {
    Value::Obj(vec![("op".into(), Value::Str(name.into()))])
}

fn predict_req(ratio: f64, rows: &[Vec<f32>]) -> Value {
    let rows = rows
        .iter()
        .map(|r| Value::Arr(r.iter().map(|&x| Value::Num(x as f64)).collect()))
        .collect();
    Value::Obj(vec![
        ("op".into(), Value::Str("predict".into())),
        ("ratio".into(), Value::Num(ratio)),
        ("rows".into(), Value::Arr(rows)),
    ])
}

fn result_of(reply: &Value) -> &Value {
    assert_eq!(reply.get("ok").and_then(Value::as_bool), Some(true), "{}", reply.to_json());
    reply.get("result").unwrap()
}

fn error_of(reply: &Value) -> &str {
    assert_eq!(reply.get("ok").and_then(Value::as_bool), Some(false), "{}", reply.to_json());
    reply.get("error").and_then(Value::as_str).unwrap()
}

/// Row i of task t's design matrix, as the f32 vector a client would send.
fn training_row(ds: &Dataset, t: usize, i: usize) -> Vec<f32> {
    (0..ds.d).map(|l| ds.tasks[t].col(l).to_vec()[i]).collect()
}

/// Offline reference: the path's W at `ratio` + `ops::forward`.
fn offline_model(ds: &Dataset, ratio: f64) -> Vec<f64> {
    let opts = exp_opts(scale::grid(8), ScreenerKind::Dpc);
    let mut w_at = None;
    let mut obs = FnObserver(|r: f64, _lam: f64, w: &[f64], _rec: &LambdaRecord| {
        if r.to_bits() == ratio.to_bits() {
            w_at = Some(w.to_vec());
        }
    });
    run_path_with(ds, &opts, &EngineKind::Exact, &mut obs).unwrap();
    w_at.expect("ratio is on the grid")
}

#[test]
fn predict_is_bit_identical_to_offline_forward() {
    let ds = dataset();
    let opts = exp_opts(scale::grid(8), ScreenerKind::Dpc);
    let ratio = opts.ratios[opts.ratios.len() / 2];
    let w = offline_model(&ds, ratio);
    let z = ops::forward(&ds, &w);

    let mut srv = server(ds.clone(), true);
    let mut cl = Client::connect(&srv);
    for t in 0..ds.t() {
        let n = ds.tasks[t].n;
        let rows: Vec<Vec<f32>> = (0..n.min(3)).map(|i| training_row(&ds, t, i)).collect();
        let reply = cl.call(&mut srv, &predict_req(ratio, &rows));
        let preds = result_of(&reply).as_arr().unwrap();
        for (i, pred) in preds.iter().enumerate() {
            let got = pred.as_arr().unwrap()[t].as_f64().unwrap();
            assert_eq!(
                got.to_bits(),
                z[t][i].to_bits(),
                "task {t} sample {i}: served {got:e} vs offline {:e}",
                z[t][i]
            );
        }
    }
}

#[test]
fn four_pipelined_clients_match_serial_bits() {
    let ds = dataset();
    let opts = exp_opts(scale::grid(8), ScreenerKind::Dpc);
    let ratio = opts.ratios[opts.ratios.len() / 2];
    let mut srv = server(ds.clone(), true);

    let reqs: Vec<Value> = (0..4)
        .map(|k| {
            let rows: Vec<Vec<f32>> =
                (0..2).map(|i| training_row(&ds, k % ds.t(), (i + k) % ds.tasks[0].n)).collect();
            predict_req(ratio, &rows)
        })
        .collect();

    // serial: one client, one request at a time
    let mut serial = Vec::new();
    {
        let mut cl = Client::connect(&srv);
        for r in &reqs {
            serial.push(cl.call(&mut srv, r).to_json());
        }
    }

    // concurrent: four clients, all requests on the wire before any tick
    let mut clients: Vec<Client> = (0..4).map(|_| Client::connect(&srv)).collect();
    for (cl, r) in clients.iter_mut().zip(&reqs) {
        cl.send(&mut srv, r);
    }
    let concurrent: Vec<String> =
        clients.iter_mut().map(|cl| cl.recv(&mut srv).to_json()).collect();

    assert_eq!(serial, concurrent, "width-4 batch must reproduce serial bits");
}

#[test]
fn warm_fit_matches_cold_solve_within_gap_tolerance() {
    let ds = dataset();
    let mut srv = server(ds.clone(), true);
    let mut cl = Client::connect(&srv);

    let info = cl.call(&mut srv, &op("info"));
    let lam_max = result_of(&info).get("lam_max").unwrap().as_f64().unwrap();
    let fitted = result_of(&info).get("fitted").unwrap().as_arr().unwrap().len();
    assert!(fitted >= 2, "prefit should cache the grid");

    // an off-grid ratio: warm-started on the daemon, cold offline
    let grid = exp_opts(scale::grid(8), ScreenerKind::Dpc);
    let ratio = (grid.ratios[1] * grid.ratios[2]).sqrt();
    let fit = cl.call(
        &mut srv,
        &Value::Obj(vec![
            ("op".into(), Value::Str("fit".into())),
            ("ratio".into(), Value::Num(ratio)),
        ]),
    );
    let r = result_of(&fit);
    assert_eq!(r.get("cached").unwrap().as_bool(), Some(false));
    assert!(r.get("warm_from").unwrap().as_f64().is_some(), "must warm-start");
    let obj_warm = r.get("obj").unwrap().as_f64().unwrap();
    let gap_warm = r.get("gap").unwrap().as_f64().unwrap();

    let cold = fista(&ds, ratio * lam_max, None, &grid.solve);
    assert!(cold.converged);

    // documented tolerance: each objective sits within its own duality
    // gap of the shared optimum, so the difference is bounded by the sum
    // of the two certificates (plus f64 noise)
    let tol = gap_warm + cold.gap + 1e-9 * obj_warm.abs().max(1.0);
    assert!(
        (obj_warm - cold.obj).abs() <= tol,
        "warm {obj_warm} vs cold {} exceeds gap tolerance {tol}",
        cold.obj
    );

    // refitting the same ratio must come straight from the cache
    let again = cl.call(
        &mut srv,
        &Value::Obj(vec![
            ("op".into(), Value::Str("fit".into())),
            ("ratio".into(), Value::Num(ratio)),
        ]),
    );
    assert_eq!(result_of(&again).get("cached").unwrap().as_bool(), Some(true));
}

#[test]
fn malformed_frames_get_error_replies_not_panics() {
    let ds = dataset();
    let mut srv = server(ds, false);
    let mut cl = Client::connect(&srv);

    // not JSON at all
    let mut bytes = Vec::new();
    proto::encode_frame(b"this is not json", &mut bytes);
    cl.send_raw(&mut srv, &bytes);
    assert!(error_of(&cl.recv(&mut srv)).contains("bad json"));

    // JSON but not a request
    let mut bytes = Vec::new();
    proto::encode_frame(b"[1,2,3]", &mut bytes);
    cl.send_raw(&mut srv, &bytes);
    assert!(error_of(&cl.recv(&mut srv)).contains("op"));

    // unknown op
    assert!(error_of(&cl.call(&mut srv, &op("frobnicate"))).contains("unknown op"));

    // the connection survived all three
    assert_eq!(result_of(&cl.call(&mut srv, &op("ping"))).as_str(), Some("pong"));
}

#[test]
fn truncated_frame_then_hangup_is_a_clean_drop() {
    let ds = dataset();
    let mut srv = server(ds, false);

    let mut cl = Client::connect(&srv);
    // header promises 100 bytes; send 10 and hang up
    let mut partial = (100u32).to_be_bytes().to_vec();
    partial.extend_from_slice(b"0123456789");
    cl.send_raw(&mut srv, &partial);
    for _ in 0..20 {
        srv.tick().unwrap();
    }
    drop(cl);
    for _ in 0..200 {
        srv.tick().unwrap();
    }

    // the daemon is unbothered
    let mut probe = Client::connect(&srv);
    assert_eq!(result_of(&probe.call(&mut srv, &op("ping"))).as_str(), Some("pong"));
}

#[test]
fn oversized_frame_is_rejected_actionably_and_closed() {
    let ds = dataset();
    let mut srv = server(ds, false);
    let mut cl = Client::connect(&srv);

    // header declares 2x the cap; no payload needed to trigger
    cl.send_raw(&mut srv, &((2 * MAX_FRAME) as u32).to_be_bytes());
    let err = error_of(&cl.recv(&mut srv)).to_string();
    assert!(err.contains("exceeds"), "{err}");
    assert!(err.contains("--max-frame-mb"), "actionable cure: {err}");
    assert!(cl.saw_eof(&mut srv), "poisoned framing must close the connection");

    let mut probe = Client::connect(&srv);
    assert_eq!(result_of(&probe.call(&mut srv, &op("ping"))).as_str(), Some("pong"));
}

#[test]
fn unfitted_ratio_names_the_fitted_grid() {
    let ds = dataset();
    let mut srv = server(ds.clone(), true);
    let mut cl = Client::connect(&srv);

    let rows = vec![vec![0.0f32; ds.d]];
    let err = error_of(&cl.call(&mut srv, &predict_req(0.123456789, &rows))).to_string();
    assert!(err.contains("no fitted model at ratio 0.123456789"), "{err}");
    assert!(err.contains("fitted ratios"), "{err}");
    assert!(err.contains("\"op\":\"fit\""), "cure must name the fit op: {err}");

    // wrong row width is caught before the batch
    let bad = vec![vec![0.0f32; ds.d + 1]];
    let grid = exp_opts(scale::grid(8), ScreenerKind::Dpc);
    let err = error_of(&cl.call(&mut srv, &predict_req(grid.ratios[1], &bad))).to_string();
    assert!(err.contains(&format!("expects d={}", ds.d)), "{err}");
}

#[test]
fn shutdown_drains_pipelined_work_and_run_returns_ok() {
    let ds = dataset();
    let opts = exp_opts(scale::grid(8), ScreenerKind::Dpc);
    let ratio = opts.ratios[1];
    let mut srv = server(ds.clone(), true);
    let mut cl = Client::connect(&srv);

    // predict + shutdown pipelined in one write: the daemon must answer
    // the predict (in order) before stopping
    let rows = vec![training_row(&ds, 0, 0)];
    let mut bytes = Vec::new();
    proto::encode_frame(predict_req(ratio, &rows).to_json().as_bytes(), &mut bytes);
    proto::encode_frame(op("shutdown").to_json().as_bytes(), &mut bytes);
    cl.send_raw(&mut srv, &bytes);

    // run() owns the loop from here: process both frames, drain, return
    srv.run().unwrap();

    cl.pump_reads();
    let first = json::parse(
        std::str::from_utf8(&cl.dec.next(MAX_FRAME).unwrap().unwrap()).unwrap(),
    )
    .unwrap();
    let preds = result_of(&first).as_arr().unwrap();
    assert_eq!(preds.len(), 1, "the in-flight predict was answered");
    let second = json::parse(
        std::str::from_utf8(&cl.dec.next(MAX_FRAME).unwrap().unwrap()).unwrap(),
    )
    .unwrap();
    assert_eq!(
        result_of(&second).get("stopping").unwrap().as_bool(),
        Some(true),
        "shutdown ack follows the drained predict"
    );
}

#[test]
fn stats_reports_endpoints_cache_and_executor() {
    let ds = dataset();
    let opts = exp_opts(scale::grid(8), ScreenerKind::Dpc);
    let ratio = opts.ratios[1];
    let mut srv = server(ds.clone(), true);
    let mut cl = Client::connect(&srv);

    let rows = vec![training_row(&ds, 0, 0)];
    cl.call(&mut srv, &predict_req(ratio, &rows));
    cl.call(&mut srv, &predict_req(0.987654, &rows)); // a miss
    let stats = cl.call(&mut srv, &op("stats"));
    let r = result_of(&stats);
    assert!(r.get("cache_hits").unwrap().as_f64().unwrap() >= 1.0);
    assert!(r.get("cache_misses").unwrap().as_f64().unwrap() >= 1.0);
    assert!(r.get("executor_peak_active").is_some());
    let eps = r.get("endpoints").unwrap().as_arr().unwrap();
    let predict_row = eps
        .iter()
        .find(|e| e.get("op").and_then(Value::as_str) == Some("predict"))
        .expect("predict endpoint row");
    assert!(predict_row.get("p99_ms").unwrap().as_f64().unwrap() >= 0.0);
}
