//! Out-of-core shard backend integration (DESIGN.md §10): MTD3 save →
//! shard → load parity with the in-RAM dataset, actionable corruption
//! errors, and the headline screen-before-load contract — a sharded path
//! run produces identical keep-sets and (to solver tolerance) identical
//! solutions to the dense backend while materializing far less than the
//! dataset at high λ ratios.

use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::coordinator::path::{
    run_path_sharded, run_path_sharded_with, EngineKind, FnObserver, LambdaRecord,
    PathOptions, ScreenerKind,
};
use mtfl_dpc::data::io::{save, save_sharded};
use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::data::textsim::{textsim, TextSimOptions};
use mtfl_dpc::data::{Dataset, ShardedDataset};
use mtfl_dpc::solver::SolveOptions;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mtfl_shardit_{}_{}", std::process::id(), name))
}

fn dense_problem() -> Dataset {
    synthetic1(&SynthOptions {
        t: 3,
        n: 14,
        d: 120,
        support_frac: 0.08,
        noise: 0.05,
        seed: 77,
    })
    .0
}

fn shard_of(ds: &Dataset, tag: &str, shard_bytes: usize) -> (ShardedDataset, PathBuf) {
    let p = tmp(tag);
    save_sharded(ds, &p, shard_bytes).unwrap();
    (ShardedDataset::open(&p).unwrap(), p)
}

fn path_opts(screener: ScreenerKind) -> PathOptions {
    PathOptions {
        ratios: lambda_grid(10, 1.0, 0.05),
        solve: SolveOptions { tol: 1e-7, ..Default::default() },
        screener,
        ..Default::default()
    }
}

#[test]
fn mtd3_round_trip_matches_in_ram_dataset() {
    // save → shard → load: the fully materialized shard equals the
    // original, column for column, on the dense backend
    let ds = dense_problem();
    let (sh, p) = shard_of(&ds, "roundtrip.mtd3", 2000);
    assert!(sh.n_blocks() > 1, "want a multi-block shard");
    let all: Vec<usize> = (0..ds.d).collect();
    let back = sh.restrict(&all).unwrap();
    assert_eq!(back.d, ds.d);
    for t in 0..ds.t() {
        for l in 0..ds.d {
            assert_eq!(back.col(t, l).to_vec(), ds.col(t, l).to_vec());
        }
        assert_eq!(back.tasks[t].y, ds.tasks[t].y);
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn mtd3_round_trip_preserves_csc_blocks() {
    // a CSC dataset shards into CSC blocks and materializes back sparse
    let ds = textsim(&TextSimOptions {
        categories: 3,
        n_pos: 8,
        d: 400,
        doc_len: 60,
        seed: 21,
        ..Default::default()
    });
    assert!(ds.is_sparse(), "textsim must emit CSC");
    let (sh, p) = shard_of(&ds, "csc.mtd3", 4000);
    assert!(sh.n_blocks() > 1);
    let all: Vec<usize> = (0..ds.d).collect();
    let back = sh.restrict(&all).unwrap();
    assert!(back.is_sparse(), "CSC storage must survive the shard round trip");
    back.validate().unwrap();
    for t in 0..ds.t() {
        for l in 0..ds.d {
            assert_eq!(back.col(t, l).to_vec(), ds.col(t, l).to_vec());
        }
    }
    // degenerate restrict honors the backend contract too
    let empty = sh.restrict(&[]).unwrap();
    assert_eq!(empty.d, 0);
    assert!(empty.tasks.iter().all(|t| t.is_sparse()), "empty restrict lost CSC");
    // .mtd (v2) and .mtd3 carry the same data: cross-check via save/load
    let p2 = tmp("csc_v2.mtd");
    save(&ds, &p2).unwrap();
    let v2 = mtfl_dpc::data::io::load(&p2).unwrap();
    assert_eq!(v2.tasks[0].x, back.tasks[0].x);
    std::fs::remove_file(&p).ok();
    std::fs::remove_file(&p2).ok();
}

#[test]
fn corrupt_block_is_an_actionable_error_and_localized() {
    let ds = dense_problem();
    let p = tmp("corrupt.mtd3");
    save_sharded(&ds, &p, 2000).unwrap();
    // flip one byte near the END of the file: some late block's payload
    let mut bytes = std::fs::read(&p).unwrap();
    let hit = bytes.len() - 64;
    bytes[hit] ^= 0xff;
    std::fs::write(&p, &bytes).unwrap();
    // the header is intact, so open succeeds — corruption is detected at
    // the damaged block only, with an error that names the remedy
    let sh = ShardedDataset::open(&p).unwrap();
    let mut saw_error = false;
    let mut clean_blocks = 0usize;
    for b in 0..sh.n_blocks() {
        match sh.block(b) {
            Ok(_) => clean_blocks += 1,
            Err(e) => {
                saw_error = true;
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("checksum mismatch") && msg.contains("repro shard"),
                    "error must say what broke and how to fix it, got: {msg}"
                );
            }
        }
    }
    assert!(saw_error, "corruption went undetected");
    assert!(clean_blocks > 0, "undamaged blocks must still load");
    std::fs::remove_file(&p).ok();
}

#[test]
fn corrupt_header_fails_open() {
    let ds = dense_problem();
    let p = tmp("corrupt_header.mtd3");
    save_sharded(&ds, &p, 2000).unwrap();
    let mut bytes = std::fs::read(&p).unwrap();
    bytes[10] ^= 0xff; // inside the name/shape region
    std::fs::write(&p, &bytes).unwrap();
    let err = ShardedDataset::open(&p);
    assert!(err.is_err(), "damaged header must not open");
    std::fs::remove_file(&p).ok();
}

#[test]
fn garbage_is_rejected_with_conversion_hint() {
    let p = tmp("garbage.mtd3");
    std::fs::write(&p, b"definitely not a shard").unwrap();
    let err = ShardedDataset::open(&p).unwrap_err();
    assert!(format!("{err:#}").contains("repro shard"), "got: {err:#}");
    std::fs::remove_file(&p).ok();
}

/// The headline parity + memory contract: sharded screen-before-load
/// produces the dense path's keep-sets exactly and its solutions to
/// solver tolerance, while materializing only the survivors.
fn parity_case(screener: ScreenerKind) {
    let ds = dense_problem();
    let (sh, p) = shard_of(&ds, &format!("parity_{screener:?}.mtd3"), 2500);
    assert!(sh.n_blocks() > 2, "blocks: {}", sh.n_blocks());
    let opts = path_opts(screener);

    let mut dense_ws: Vec<Vec<f64>> = Vec::new();
    let mut obs_dense = FnObserver(|_: f64, _: f64, w: &[f64], _: &LambdaRecord| {
        dense_ws.push(w.to_vec());
    });
    let dense = mtfl_dpc::coordinator::path::run_path_with(
        &ds,
        &opts,
        &EngineKind::Exact,
        &mut obs_dense,
    )
    .unwrap();
    drop(obs_dense);

    let mut shard_ws: Vec<Vec<f64>> = Vec::new();
    let mut obs_shard = FnObserver(|_: f64, _: f64, w: &[f64], _: &LambdaRecord| {
        shard_ws.push(w.to_vec());
    });
    let sharded = run_path_sharded_with(&sh, &opts, &mut obs_shard).unwrap();
    drop(obs_shard);
    std::fs::remove_file(&p).ok();

    assert_eq!(dense.records.len(), sharded.path.records.len());
    for (a, b) in dense.records.iter().zip(&sharded.path.records) {
        assert_eq!(a.ratio, b.ratio);
        // identical keep-sets: same counts at every λ (the per-feature
        // agreement is pinned bitwise by the screening unit tests)
        assert_eq!(a.kept, b.kept, "kept-count mismatch at ratio {}", a.ratio);
        assert_eq!(a.rejected, b.rejected, "rejected mismatch at ratio {}", a.ratio);
        assert!(
            (a.obj - b.obj).abs() <= 1e-9 * a.obj.abs().max(1.0),
            "objective mismatch at ratio {}: {} vs {}",
            a.ratio,
            a.obj,
            b.obj
        );
    }
    // streamed per-λ solutions agree to solver tolerance
    assert_eq!(dense_ws.len(), shard_ws.len());
    for (i, (wa, wb)) in dense_ws.iter().zip(&shard_ws).enumerate() {
        let dmax =
            wa.iter().zip(wb).map(|(x, y)| (x - y).abs()).fold(0.0f64, f64::max);
        assert!(dmax < 1e-7, "solution diverged at grid index {i}: {dmax}");
    }
    let dmax = dense
        .last_w
        .iter()
        .zip(&sharded.path.last_w)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f64, f64::max);
    assert!(dmax < 1e-7, "final W mismatch {dmax}");

    // the memory model: every solve saw less than the full dataset, and
    // near λ_max the DPC-screened materialized slice is a small fraction
    // of it (GapSafe's W=0 warm-start ball is loose at the grid head, so
    // the << claim is asserted on the DPC variants it is benched with)
    let full = sharded.dense_bytes as usize;
    assert!(sharded.peak_materialized_bytes <= full);
    if !matches!(screener, ScreenerKind::GapSafe) {
        let head = sharded.materialized_bytes[1]; // first screened grid point
        assert!(
            head * 2 < full,
            "high-λ materialization {head} is not << full {full}"
        );
    }
    assert!(sharded.bytes_read > 0 && sharded.blocks_loaded > 0);
}

#[test]
fn sharded_path_matches_dense_path_dpc() {
    parity_case(ScreenerKind::Dpc);
}

#[test]
fn sharded_path_matches_dense_path_gapsafe() {
    parity_case(ScreenerKind::GapSafe);
}

#[test]
fn sharded_path_matches_dense_path_oneshot() {
    parity_case(ScreenerKind::DpcOneShot);
}

#[test]
fn sharded_lambda_max_matches_exact() {
    let ds = dense_problem();
    let (sh, p) = shard_of(&ds, "lmax.mtd3", 2500);
    let (lmax, lstar, g) = mtfl_dpc::ops::lambda_max(&ds);
    let (slmax, slstar, sg) = mtfl_dpc::ops::stream_lambda_max(&sh).unwrap();
    assert_eq!(slmax.to_bits(), lmax.to_bits());
    assert_eq!(slstar, lstar);
    assert_eq!(sg.len(), g.len());
    for l in 0..g.len() {
        assert_eq!(sg[l].to_bits(), g[l].to_bits(), "g mismatch at {l}");
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn unsupported_screeners_error_out_of_core() {
    let ds = dense_problem();
    let (sh, p) = shard_of(&ds, "unsupported.mtd3", 2500);
    let err = run_path_sharded(&sh, &path_opts(ScreenerKind::None)).unwrap_err();
    assert!(format!("{err:#}").contains("not supported out-of-core"), "got {err:#}");
    let mut opts = path_opts(ScreenerKind::Dpc);
    opts.verify_safety = true;
    let err = run_path_sharded(&sh, &opts).unwrap_err();
    assert!(format!("{err:#}").contains("verify_safety"), "got {err:#}");
    std::fs::remove_file(&p).ok();
}

#[test]
fn tiny_cache_changes_io_not_results() {
    // the LRU budget is a performance knob, never a correctness one: a
    // pathological 1-byte budget re-reads blocks constantly but yields the
    // identical run
    let ds = dense_problem();
    let p = tmp("tiny.mtd3");
    save_sharded(&ds, &p, 2500).unwrap();
    let roomy = ShardedDataset::open(&p).unwrap();
    let tiny = ShardedDataset::open_with_cache(&p, 1).unwrap();
    let opts = path_opts(ScreenerKind::Dpc);
    let a = run_path_sharded(&roomy, &opts).unwrap();
    let b = run_path_sharded(&tiny, &opts).unwrap();
    std::fs::remove_file(&p).ok();
    for (x, y) in a.path.records.iter().zip(&b.path.records) {
        assert_eq!(x.kept, y.kept);
        assert_eq!(x.obj.to_bits(), y.obj.to_bits(), "ratio {}", x.ratio);
    }
    assert_eq!(a.path.last_w, b.path.last_w);
    assert!(
        b.bytes_read > a.bytes_read,
        "1-byte cache should re-read more: {} vs {}",
        b.bytes_read,
        a.bytes_read
    );
}
