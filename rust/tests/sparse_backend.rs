//! CSC-backend integration: DPC safety on genuinely sparse workloads run
//! end-to-end (mirrors `dpc_is_safe_from_lmax` and the screened-path
//! equivalence suite, but on the sparse storage path — DESIGN.md §6).

use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::coordinator::path::{run_path, EngineKind, PathOptions, ScreenerKind};
use mtfl_dpc::data::snpsim::{snpsim, SnpSimOptions};
use mtfl_dpc::data::textsim::{textsim, TextSimOptions};
use mtfl_dpc::screening::dpc::{DpcScreener, DualRef};
use mtfl_dpc::solver::{fista, SolveOptions};

fn sparse_text() -> mtfl_dpc::Dataset {
    let ds = textsim(&TextSimOptions {
        categories: 3,
        n_pos: 8,
        d: 400,
        doc_len: 60,
        seed: 21,
        ..Default::default()
    });
    assert!(ds.is_sparse(), "textsim must emit CSC");
    assert!(ds.density() < 0.25, "workload is not sparse: {}", ds.density());
    ds
}

#[test]
fn dpc_is_safe_from_lmax_on_csc() {
    // rejected row ⇒ solver row-norm < 1e-8, at several one-shot ratios
    let ds = sparse_text();
    let (dref, lmax) = DualRef::at_lambda_max(&ds);
    let screener = DpcScreener::new(&ds);
    for ratio in [0.8, 0.5, 0.3] {
        let lam = ratio * lmax;
        let out = screener.screen(&ds, &dref, lam);
        let sol = fista(&ds, lam, None, &SolveOptions::tight());
        let rn = sol.row_norms(ds.t());
        for (l, (&rej, &norm)) in out.rejected.iter().zip(&rn).enumerate() {
            if rej {
                assert!(
                    norm < 1e-8,
                    "UNSAFE on CSC: rejected active row {l} (norm {norm}) at ratio {ratio}"
                );
            }
        }
    }
}

#[test]
fn sequential_path_on_sparse_textsim_has_zero_unsafe_rejections() {
    // the satellite regression: a sparse dataset through the sequential
    // λ-path with the post-hoc verifier armed at every λ — run_path errors
    // on any unsafe rejection, and we re-assert against tight solves below
    let ds = sparse_text();
    let opts = PathOptions {
        ratios: lambda_grid(10, 1.0, 0.05),
        solve: SolveOptions { tol: 1e-7, ..Default::default() },
        screener: ScreenerKind::Dpc,
        verify_safety: true,
        ..Default::default()
    };
    let run = run_path(&ds, &opts, &EngineKind::Exact).unwrap();
    assert!(run.records.iter().skip(1).any(|r| r.rejected > 0), "screening never fired");

    // independent re-check at a few grid points with a tight solver
    let (_, lmax) = DualRef::at_lambda_max(&ds);
    let screener = DpcScreener::new(&ds);
    for r in run.records.iter().step_by(3).skip(1) {
        let sol0 = fista(&ds, r.lam, None, &SolveOptions::tight());
        let dref = DualRef::from_solution(&ds, r.lam, &sol0.w);
        let lam_next = (r.lam * 0.9).min(r.lam);
        let out = screener.screen(&ds, &dref, lam_next);
        let sol = fista(&ds, lam_next, None, &SolveOptions::tight());
        let rn = sol.row_norms(ds.t());
        for (l, (&rej, &norm)) in out.rejected.iter().zip(&rn).enumerate() {
            assert!(
                !rej || norm < 1e-8,
                "UNSAFE sequential rejection of row {l} (norm {norm}) at lam {lam_next} \
                 (lmax {lmax})"
            );
        }
    }
}

#[test]
fn sparse_and_dense_paths_agree_end_to_end() {
    let sp = sparse_text();
    let ds = sp.to_dense_backend();
    let mk = || PathOptions {
        ratios: lambda_grid(8, 1.0, 0.1),
        solve: SolveOptions { tol: 1e-7, ..Default::default() },
        screener: ScreenerKind::Dpc,
        ..Default::default()
    };
    let a = run_path(&sp, &mk(), &EngineKind::Exact).unwrap();
    let b = run_path(&ds, &mk(), &EngineKind::Exact).unwrap();
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.kept, rb.kept, "kept-set size diverges at ratio {}", ra.ratio);
        // textsim has true zero cells, so the two backends accumulate in
        // different orders: trajectories agree to rounding, not bitwise
        // (the ≤1e-12 parity claim is carried by prop_invariants on
        // fully-stored columns)
        assert!(
            (ra.obj - rb.obj).abs() <= 1e-7 * rb.obj.abs().max(1.0),
            "objective diverges at ratio {}: {} vs {}",
            ra.ratio,
            ra.obj,
            rb.obj
        );
    }
    let dmax = a
        .last_w
        .iter()
        .zip(&b.last_w)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f64, f64::max);
    assert!(dmax < 1e-6, "final W diverges across backends by {dmax}");
}

#[test]
fn sparse_snpsim_screens_safely() {
    let (ds, _) = snpsim(&SnpSimOptions {
        tasks: 3,
        n: 16,
        d: 250,
        causal: 8,
        ld_block: 10,
        ld_rho: 0.6,
        noise: 0.2,
        seed: 5,
        sparse: true,
        maf_max: 0.15,
    });
    assert!(ds.is_sparse());
    ds.validate().unwrap();
    let (dref, lmax) = DualRef::at_lambda_max(&ds);
    let screener = DpcScreener::new(&ds);
    let lam = 0.5 * lmax;
    let out = screener.screen(&ds, &dref, lam);
    let sol = fista(&ds, lam, None, &SolveOptions::tight());
    let rn = sol.row_norms(ds.t());
    for (l, (&rej, &norm)) in out.rejected.iter().zip(&rn).enumerate() {
        assert!(!rej || norm < 1e-8, "UNSAFE on sparse snpsim: row {l} norm {norm}");
    }
}
