//! AOT runtime integration: artifacts → PJRT → numerics against the exact
//! engine. Requires `make artifacts` (the `quick` config); tests skip with
//! a notice when artifacts are absent so `cargo test` works standalone.

use mtfl_dpc::coordinator::lambda_grid;
use mtfl_dpc::coordinator::path::{run_path, EngineKind, PathOptions, ScreenerKind};
use mtfl_dpc::data::synthetic::{synthetic1, SynthOptions};
use mtfl_dpc::ops;
use mtfl_dpc::runtime::AotEngine;
use mtfl_dpc::solver::SolveOptions;
use std::path::PathBuf;

fn artifact_dir() -> Option<PathBuf> {
    let dir = std::env::var("MTFL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    dir.join("manifest.tsv").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifact_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: no artifacts (run `make artifacts`)");
                return;
            }
        }
    };
}

/// quick config shape: T=4 N=16 D=256
fn quick_dataset(seed: u64) -> mtfl_dpc::Dataset {
    synthetic1(&SynthOptions { t: 4, n: 16, d: 256, seed, ..Default::default() }).0
}

#[test]
fn lammax_artifact_matches_exact() {
    let dir = require_artifacts!();
    let engine = AotEngine::new(&dir).unwrap();
    let ds = quick_dataset(1);
    let x = ds.to_tnd().unwrap();
    let y = ds.y_tn().unwrap();
    let out = engine.lammax("quick", &x, &y).unwrap();
    let (lmax, lstar, _) = ops::lambda_max(&ds);
    assert!(
        ((out.lam_max as f64) - lmax).abs() < 1e-3 * lmax,
        "aot {} vs exact {lmax}",
        out.lam_max
    );
    // normal vector matches the exact gradient direction
    let n_exact = ops::normal_at_lmax(&ds, lstar, lmax);
    let flat: Vec<f64> = n_exact.iter().flatten().copied().collect();
    for (i, (&a, &b)) in out.normal.iter().zip(&flat).enumerate() {
        assert!(
            (a as f64 - b).abs() < 1e-3 * (b.abs() + 1.0),
            "normal[{i}]: {a} vs {b}"
        );
    }
}

#[test]
fn screen_artifact_matches_exact_scores() {
    let dir = require_artifacts!();
    let engine = AotEngine::new(&dir).unwrap();
    let ds = quick_dataset(2);
    let x = ds.to_tnd().unwrap();
    let y = ds.y_tn().unwrap();

    let (dref, lmax) = mtfl_dpc::screening::dpc::DualRef::at_lambda_max(&ds);
    let lam = 0.5 * lmax;
    let theta0: Vec<f32> = dref.theta0.iter().flatten().map(|&v| v as f32).collect();
    let normal: Vec<f32> = dref.normal.iter().flatten().map(|&v| v as f32).collect();
    let s_aot = engine
        .screen("quick", &x, &y, &theta0, &normal, lam as f32)
        .unwrap();

    let (o, delta) = mtfl_dpc::screening::dpc::ball(&ds, &dref, lam);
    let s_exact = mtfl_dpc::screening::dpc::DpcScreener::new(&ds).scores(&ds, &o, delta);
    let mut max_rel = 0.0f64;
    for l in 0..ds.d {
        let rel = ((s_aot[l] as f64) - s_exact[l]).abs() / s_exact[l].max(1e-3);
        max_rel = max_rel.max(rel);
    }
    assert!(max_rel < 5e-3, "f32 screen scores deviate {max_rel}");
}

#[test]
fn fista_artifact_converges_and_matches_exact() {
    let dir = require_artifacts!();
    let engine = AotEngine::new(&dir).unwrap();
    let ds = quick_dataset(3);
    let x = ds.to_tnd().unwrap();
    let y = ds.y_tn().unwrap();
    let (lmax, _, _) = ops::lambda_max(&ds);
    let lam = (0.4 * lmax) as f32;

    let w0 = vec![0.0f32; 256 * 4];
    let (out, chunks) = engine
        .fista_solve("quick", 256, &x, &y, &w0, lam, 1e-5, 200)
        .unwrap();
    assert!(out.gap <= 1e-5 * out.obj.abs().max(1.0), "gap {}", out.gap);
    assert!(chunks > 0);

    let exact = mtfl_dpc::solver::fista(&ds, lam as f64, None, &SolveOptions::tight());
    assert!(
        ((out.obj as f64) - exact.obj).abs() < 1e-3 * exact.obj.max(1.0),
        "obj {} vs {}",
        out.obj,
        exact.obj
    );
    // active sets agree
    let t = 4usize;
    for l in 0..256 {
        let aot_n: f32 = (0..t).map(|ti| out.w[l * t + ti].powi(2)).sum::<f32>().sqrt();
        let ex_n: f64 =
            (0..t).map(|ti| exact.w[l * t + ti].powi(2)).sum::<f64>().sqrt();
        if ex_n > 1e-3 {
            assert!(aot_n > 1e-4, "feature {l} active exactly but ~0 in AOT");
        }
        if ex_n < 1e-9 {
            assert!(aot_n < 1e-2, "feature {l} inactive exactly but {aot_n} in AOT");
        }
    }
}

#[test]
fn bucketed_solve_matches_full_bucket() {
    // pack a 100-feature subproblem into bucket 128 vs bucket 256:
    // identical retained solutions
    let dir = require_artifacts!();
    let engine = AotEngine::new(&dir).unwrap();
    let ds = quick_dataset(4);
    let y = ds.y_tn().unwrap();
    let keep: Vec<usize> = (0..100).map(|i| i * 2).collect();
    let (lmax, _, _) = ops::lambda_max(&ds);
    let lam = (0.3 * lmax) as f32;

    let mut sols = Vec::new();
    for db in [128usize, 256] {
        let x = mtfl_dpc::runtime::buckets::pack_tnd(&ds.tasks, &keep, db);
        let w0 = vec![0.0f32; db * 4];
        let (out, _) = engine.fista_solve("quick", db, &x, &y, &w0, lam, 1e-6, 400).unwrap();
        sols.push(mtfl_dpc::runtime::buckets::unpack_w(&out.w, 4, &keep, db, ds.d));
    }
    let dmax = sols[0]
        .iter()
        .zip(&sols[1])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    assert!(dmax < 1e-3, "bucket choice changed the solution by {dmax}");
}

#[test]
fn aot_path_end_to_end_matches_exact_path() {
    let dir = require_artifacts!();
    let engine = AotEngine::new(&dir).unwrap();
    let ds = quick_dataset(5);

    let mk_opts = |aot_margin: f64| PathOptions {
        ratios: lambda_grid(8, 1.0, 0.05),
        solve: SolveOptions { tol: 1e-6, max_iters: 20_000, ..Default::default() },
        screener: ScreenerKind::Dpc,
        aot_margin,
        ..Default::default()
    };
    let aot = run_path(&ds, &mk_opts(1e-3), &EngineKind::Aot(&engine)).unwrap();
    let exact = run_path(&ds, &mk_opts(0.0), &EngineKind::Exact).unwrap();
    for (a, b) in aot.records.iter().zip(&exact.records) {
        assert!(
            (a.obj - b.obj).abs() <= 5e-3 * b.obj.abs().max(1.0),
            "ratio {}: obj {} vs {}",
            a.ratio,
            a.obj,
            b.obj
        );
        // AOT margin keeps a superset of exact's kept features
        assert!(a.kept >= b.kept.saturating_sub(1), "ratio {}: {} < {}", a.ratio, a.kept, b.kept);
    }
    // the engines must agree on screening power (absolute levels are a
    // property of the problem size, not the engine — this quick-config
    // problem is tiny, so small-lambda rejection is genuinely modest)
    let (ra, re) = (aot.mean_rejection_ratio(), exact.mean_rejection_ratio());
    assert!((ra - re).abs() < 0.05, "engines disagree on rejection: {ra} vs {re}");
    assert!(ra > 0.3, "screening did nothing: {ra}");
}

#[test]
fn engine_rejects_bad_shapes() {
    let dir = require_artifacts!();
    let engine = AotEngine::new(&dir).unwrap();
    let bad = vec![0.0f32; 7];
    assert!(engine.call("lammax_quick", &[&bad, &bad]).is_err());
    assert!(engine.call("definitely_missing", &[]).is_err());
}
